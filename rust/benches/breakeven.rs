//! §5 / Appendix A.4 break-even bench: measured crossover of the native
//! AQUA sparse score kernel vs the dense baseline, against the paper's
//! analytic bound i+1 > d²/(d−k). Regenerates the A.4 numerical-example
//! table on real hardware.

use aqua_serve::bench::Bencher;
use aqua_serve::eval::experiments as exp;

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let b = if fast { Bencher::quick() } else { Bencher { warmup: 2, iters: 20, ..Default::default() } };
    // d=128 is the paper's numerical example; d=32 is our serving model.
    let rows = exp::breakeven(&[32, 64, 128], &[0.125, 0.25, 0.5, 0.75, 0.875], &b);
    exp::print_breakeven(&rows);

    // Sanity summary: measured crossovers must exist whenever the bound is
    // finite (pruning eventually wins).
    let finite = rows.iter().filter(|r| r.paper_bound.is_some()).count();
    let found = rows
        .iter()
        .filter(|r| r.paper_bound.is_some() && r.measured_crossover.is_some())
        .count();
    println!("\ncrossover found for {found}/{finite} finite-bound configs");
}
