//! §5 / Appendix A.4 break-even bench: measured crossover of the native
//! AQUA sparse *and* dim-major packed score kernels vs the dense baseline,
//! against the paper's analytic bound i+1 > d²/(d−k). Regenerates the A.4
//! numerical-example table on real hardware and writes the
//! `kernel_breakeven` section of `BENCH_decode.json` (see BENCHES.md).

use std::path::Path;

use aqua_serve::bench::report::{default_path, BenchReport};
use aqua_serve::bench::Bencher;
use aqua_serve::eval::experiments as exp;
use aqua_serve::util::json::Json;

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let b = if fast {
        Bencher::quick()
    } else {
        Bencher { warmup: 2, iters: 20, ..Default::default() }
    };
    // d=128 is the paper's numerical example; d=32 is our serving model.
    let rows = exp::breakeven(&[32, 64, 128], &[0.125, 0.25, 0.5, 0.75, 0.875], &b);
    exp::print_breakeven(&rows);

    // Sanity summary: measured crossovers must exist whenever the bound is
    // finite (pruning eventually wins).
    let finite = rows.iter().filter(|r| r.paper_bound.is_some()).count();
    let found = rows
        .iter()
        .filter(|r| r.paper_bound.is_some() && r.measured_crossover.is_some())
        .count();
    println!("\ncrossover found for {found}/{finite} finite-bound configs");

    let opt_num = |v: Option<usize>| match v {
        Some(n) => Json::Num(n as f64),
        None => Json::Null,
    };
    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("d", Json::Num(r.d as f64)),
                ("k", Json::Num(r.k as f64)),
                ("paper_bound", opt_num(r.paper_bound)),
                ("sparse_crossover", opt_num(r.measured_crossover)),
                ("packed_crossover", opt_num(r.packed_crossover)),
            ])
        })
        .collect();
    let section = Json::obj(vec![
        ("rows", Json::Arr(json_rows)),
        ("units", Json::Str("crossover = smallest measured context length i+1 (tokens)".into())),
        ("fast", Json::Bool(fast)),
    ]);
    let path = Path::new(default_path());
    let mut rep = BenchReport::load_or_new(path);
    rep.set_section("kernel_breakeven", section);
    match rep.save(path) {
        Ok(()) => println!("wrote kernel_breakeven section to {}", path.display()),
        Err(e) => eprintln!("warning: could not write {}: {e:#}", path.display()),
    }
}
