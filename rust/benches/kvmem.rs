//! KV-memory trajectory: resident bytes-per-token and lanes-per-budget vs
//! the AQUA-Memory knob (`kv_keep = 1 - s_ratio`) through the paged KV
//! pool — the memory half of the paper's claim, measured on the pool the
//! backend actually allocates instead of projected from the cost model.
//!
//! For each `kv_keep` operating point the bench serves the same hermetic
//! workload through a full engine (admission → prefill/decode → H2O →
//! retire) and records:
//!
//! * `bytes_per_token` — resident KV bytes per token slot of the pool's
//!   page layout (truncated keys + full values, all layers);
//! * `resident_ratio` — measured peak resident pool bytes over the dense
//!   `[L, B, n_kv, 2d, max_seq]` preallocation the pre-pool backends used
//!   (paging and truncation both push this down);
//! * `max_lanes` — worst-case concurrent lanes a fixed `budget_mb` KV
//!   budget admits (`budget_pages / pages_per_lane`) — "serve more lanes
//!   per byte".
//!
//! The sweep runs twice: once on the f32 pool (the trajectory every prior
//! PR baselined) and once with `kv_quant=int8` — per-page block-scaled
//! resident KV decoded through the fused dequantizing kernels — showing
//! the quantization saving compounding with AQUA-Memory truncation.
//!
//! Writes the `kvmem` section of `BENCH_kvmem.json` (schema in BENCHES.md,
//! validated by `aqua benchcheck`; `--strict` asserts the kv_keep=0.5
//! acceptance bound). Pass `--fast` for a smoke run (CI).

use std::path::Path;

use aqua_serve::aqua::policy::AquaConfig;
use aqua_serve::bench::report::{kvmem_path, BenchReport};
use aqua_serve::coordinator::{Engine, EngineConfig, GenRequest};
use aqua_serve::kvpool::{budget_pages, KvQuant, PoolLayout, DEFAULT_PAGE_SLOTS};
use aqua_serve::model::config::ModelConfig;
use aqua_serve::runtime::{corpus_or_synthetic, BackendSpec};
use aqua_serve::tokenizer::ByteTokenizer;
use aqua_serve::util::json::Json;
use aqua_serve::util::prng::Rng;

const GEN_LEN: usize = 32;
const BATCH: usize = 4;
const BUDGET_MB: f64 = 1.0;

fn workload(corpus: &[u8], n: usize, max_prompt: usize, rng: &mut Rng) -> Vec<GenRequest> {
    let tok = ByteTokenizer;
    let lines: Vec<&[u8]> = corpus.split(|&b| b == b'\n').filter(|l| l.len() > 8).collect();
    (0..n)
        .map(|i| {
            let line = lines[rng.below(lines.len())];
            let cut = (4 + rng.below(line.len() - 4)).min(max_prompt);
            let mut r = GenRequest::new(i as u64 + 1, tok.encode_bytes(&line[..cut]), GEN_LEN);
            r.stop_token = Some(b'\n' as i32);
            r
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let n_requests = if fast { 8 } else { 24 };
    let cfg = ModelConfig::tiny("llama-analog");
    let (d, nkv, nl, s_cap) = (cfg.d_head, cfg.n_kv_heads, cfg.n_layers, cfg.max_seq);
    let spec = BackendSpec::native(cfg.clone(), 0)?;
    let corpus = corpus_or_synthetic(1 << 15);
    let max_prompt = spec.max_prompt(GEN_LEN);
    // what every lane preallocated before the pool: full-width K + V
    let dense_alloc = BATCH * nl * nkv * s_cap * 2 * d * 4;
    let dense_bytes_per_token = nl * nkv * 2 * d * 4;

    println!(
        "# kvmem — resident KV vs kv_keep ({n_requests} requests, batch={BATCH}, S={s_cap}, \
         dense preallocation {dense_alloc} B)\n"
    );
    println!(
        "{:>8} {:>6} {:>9} {:>11} {:>14} {:>15} {:>10}",
        "kv_keep", "quant", "mem_dims", "B/token", "peak resident", "resident ratio", "max lanes"
    );

    let mut rows: Vec<Json> = vec![];
    // f32 first (the pre-quantization trajectory the acceptance bounds
    // are stated on), then the int8-resident sweep compounding on top
    for quant in [KvQuant::F32, KvQuant::Int8] {
        for keep in [1.0f64, 0.75, 0.5, 0.25] {
            let aqua = AquaConfig { s_ratio: 1.0 - keep, ..Default::default() };
            let mem_dims = aqua.mem_dims(d);
            let layout = PoolLayout {
                page_slots: DEFAULT_PAGE_SLOTS,
                key_dims: mem_dims,
                head_dim: d,
                layers: nl,
                kv_heads: nkv,
                kv_quant: quant,
            };
            let bytes_per_token = layout.bytes_per_slot();
            let pages_per_lane = layout.pages_for_slots(s_cap);
            let max_lanes = budget_pages(BUDGET_MB, &layout).unwrap_or(0) / pages_per_lane.max(1);

            let ecfg = EngineConfig { batch: BATCH, aqua, kv_quant: quant, ..Default::default() };
            let mut engine = Engine::with_spec(&spec, ecfg)?;
            let mut rng = Rng::new(11);
            engine.run_batch(workload(&corpus, n_requests, max_prompt, &mut rng))?;
            let snap = engine.metrics.snapshot();
            let peak = snap.kv_resident_peak_bytes;
            let ratio = peak as f64 / dense_alloc as f64;

            println!(
                "{:>8.2} {:>6} {:>9} {:>11} {:>13}B {:>15.3} {:>10}",
                keep,
                quant.as_str(),
                mem_dims,
                bytes_per_token,
                peak,
                ratio,
                max_lanes
            );
            rows.push(Json::obj(vec![
                ("kv_keep", Json::Num(keep)),
                ("kv_quant", Json::Str(quant.as_str().into())),
                ("mem_dims", Json::Num(mem_dims as f64)),
                ("page_slots", Json::Num(layout.page_slots as f64)),
                ("bytes_per_token", Json::Num(bytes_per_token as f64)),
                ("dense_bytes_per_token", Json::Num(dense_bytes_per_token as f64)),
                ("peak_resident_bytes", Json::Num(peak as f64)),
                ("resident_ratio", Json::Num(ratio)),
                ("max_lanes", Json::Num(max_lanes as f64)),
                ("budget_mb", Json::Num(BUDGET_MB)),
            ]));
        }
    }

    let section = Json::obj(vec![
        ("rows", Json::Arr(rows)),
        ("model", Json::Str(cfg.name.clone())),
        ("requests", Json::Num(n_requests as f64)),
        ("batch", Json::Num(BATCH as f64)),
        (
            "units",
            Json::Str(
                "bytes_per_token = resident pool bytes per slot; resident_ratio = peak leased \
                 pages vs dense [L,B,n_kv,2d,S] preallocation; max_lanes = worst-case lanes a \
                 budget_mb KV budget admits"
                    .into(),
            ),
        ),
        ("fast", Json::Bool(fast)),
    ]);
    let path = Path::new(kvmem_path());
    let mut rep = BenchReport::load_or_new(path);
    rep.set_section("kvmem", section);
    rep.save(path)?;
    println!("\nwrote kvmem section to {}", path.display());
    Ok(())
}
