//! Continuous-batching throughput: the same request trace served at batch
//! 1 vs 4 (the L3 coordinator's contribution to serving throughput).
//!
//! Runs hermetically on the native backend; picks up the PJRT artifacts
//! automatically when built with `--features pjrt` after `make artifacts`.

use aqua_serve::aqua::policy::AquaConfig;
use aqua_serve::coordinator::{Engine, EngineConfig, GenRequest};
use aqua_serve::runtime::{corpus_or_synthetic, default_spec};
use aqua_serve::tokenizer::ByteTokenizer;
use aqua_serve::util::prng::Rng;

fn trace(corpus: &[u8], n: usize, max_prompt: usize) -> Vec<GenRequest> {
    let tok = ByteTokenizer;
    let mut rng = Rng::new(11);
    let lines: Vec<&[u8]> = corpus.split(|&b| b == b'\n').filter(|l| l.len() > 10).collect();
    (0..n)
        .map(|i| {
            let line = lines[rng.below(lines.len())];
            let cut = (6 + rng.below(line.len() - 6)).min(max_prompt);
            let mut r = GenRequest::new(i as u64 + 1, tok.encode_bytes(&line[..cut]), 24);
            r.stop_token = Some(b'\n' as i32);
            r
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let spec = default_spec("llama-analog", 0)?;
    let corpus = corpus_or_synthetic(1 << 15);
    let max_prompt = spec.max_prompt(24); // trace() generates 24 tokens
    let n = 16;

    println!("# continuous batching: {n}-request trace, AQUA k=0.75, {} backend\n", spec.name());
    // warm both batch sizes (compiles the executables on the pjrt path)
    for batch in [1usize, 4] {
        let mut warm = Engine::with_spec(&spec, EngineConfig { batch, ..Default::default() })?;
        warm.run_batch(trace(&corpus, 2, max_prompt))?;
    }
    for batch in [1usize, 4] {
        let mut engine = Engine::with_spec(
            &spec,
            EngineConfig {
                batch,
                aqua: AquaConfig { k_ratio: 0.75, ..Default::default() },
                ..Default::default()
            },
        )?;
        let reqs = trace(&corpus, n, max_prompt);
        let t0 = std::time::Instant::now();
        let results = engine.run_batch(reqs)?;
        let wall = t0.elapsed().as_secs_f64();
        let toks: usize = results.iter().map(|r| r.tokens.len()).sum();
        let s = engine.metrics.snapshot();
        println!(
            "batch={batch}: {:.2}s wall, {:.1} gen tok/s, ttft p50 {:.2}ms p99 {:.2}ms, {} decode calls",
            wall, toks as f64 / wall, s.p50_ttft_ms, s.p99_ttft_ms, s.decode_calls
        );
    }
    Ok(())
}
