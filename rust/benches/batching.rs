//! Continuous-batching throughput: the same request trace served at batch
//! 1 vs 4 (the L3 coordinator's contribution to serving throughput).
//!
//! Requires `make artifacts`; skips gracefully otherwise.

use std::sync::Arc;

use aqua_serve::aqua::policy::AquaConfig;
use aqua_serve::coordinator::{Engine, EngineConfig, GenRequest};
use aqua_serve::runtime::{Artifacts, ModelRuntime};
use aqua_serve::tokenizer::ByteTokenizer;
use aqua_serve::util::prng::Rng;

fn trace(corpus: &[u8], n: usize) -> Vec<GenRequest> {
    let tok = ByteTokenizer;
    let mut rng = Rng::new(11);
    let lines: Vec<&[u8]> = corpus.split(|&b| b == b'\n').filter(|l| l.len() > 10).collect();
    (0..n)
        .map(|i| {
            let line = lines[rng.below(lines.len())];
            let cut = 6 + rng.below(line.len() - 6);
            let mut r = GenRequest::new(i as u64 + 1, tok.encode_bytes(&line[..cut]), 24);
            r.stop_token = Some(b'\n' as i32);
            r
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let Ok(arts) = Artifacts::load(aqua_serve::ARTIFACTS_DIR) else {
        println!("skipped: artifacts not built (run `make artifacts`)");
        return Ok(());
    };
    let corpus = std::fs::read(arts.corpus_path("valid")?)?;
    let rt = Arc::new(ModelRuntime::load(arts.model("llama-analog")?)?);
    let n = 16;

    println!("# continuous batching: {n}-request trace, AQUA k=0.75\n");
    // warm both batch sizes' executables so compile time stays out of wall
    for batch in [1usize, 4] {
        let mut warm = Engine::new(rt.clone(), EngineConfig { batch, ..Default::default() })?;
        warm.run_batch(trace(&corpus, 2))?;
    }
    for batch in [1usize, 4] {
        let mut engine = Engine::new(
            rt.clone(),
            EngineConfig {
                batch,
                aqua: AquaConfig { k_ratio: 0.75, ..Default::default() },
                ..Default::default()
            },
        )?;
        let reqs = trace(&corpus, n);
        let t0 = std::time::Instant::now();
        let results = engine.run_batch(reqs)?;
        let wall = t0.elapsed().as_secs_f64();
        let toks: usize = results.iter().map(|r| r.tokens.len()).sum();
        let s = engine.metrics.snapshot();
        println!(
            "batch={batch}: {:.2}s wall, {:.1} gen tok/s, ttft p50 {:.0}ms p99 {:.0}ms, {} decode calls",
            wall, toks as f64 / wall, s.p50_ttft_ms, s.p99_ttft_ms, s.decode_calls
        );
    }
    Ok(())
}
