//! Scheduler trajectory: inter-token latency of an in-flight decode batch
//! while a max_seq-scale prompt prefills, with and without chunked-prefill
//! interleaving — the prefill-starves-decode fix measured end to end.
//!
//! For each scheduler mode the bench drives one engine through three
//! windows, stepping the loop by hand (`Engine::step`) so the gap between
//! decode-advancing steps — exactly the ITL every in-flight lane sees —
//! can be clocked from outside:
//!
//! * **warmup** — fill all but one lane with short-prompt / long-gen decode
//!   work and run until every lane streams tokens (also sizes the lazy
//!   metrics buffers, so the no-alloc window below is steady-state);
//! * **quiet** — decode-only baseline. Every step runs with a counting
//!   `#[global_allocator]` armed: the engine's hot loop must add **zero**
//!   heap allocations on top of the native backend's two per-call output
//!   buffers (logits + attention mass — its return-by-value API), or the
//!   row's `steady_decode_allocs` goes nonzero and `aqua benchcheck`
//!   refuses the file at the *schema* level — and the engine runs with
//!   `trace=full`, so the bound also proves the flight recorder never
//!   allocates at steady state;
//! * **in-flight** — inject a prompt sized at ~max_seq and keep clocking
//!   decode gaps until it completes. Legacy FIFO (`interleave = false`)
//!   runs that prefill to completion first, so the batch's ITL spikes by
//!   the whole multi-chunk prefill; the duty-cycled scheduler alternates
//!   chunk-sized prefill passes with decode passes and bounds the spike.
//!
//! The batch is sized so one decode pass costs more than one prefill
//! chunk (chunk 16 vs 23 live lanes) — that is the regime the 2x
//! acceptance bound (`itl_ratio <= 2.0`, `aqua benchcheck --strict`)
//! targets; outputs stay bit-identical either way, so the rows only claim
//! latency. Writes the `interleave` section of `BENCH_interleave.json`
//! (schema in BENCHES.md). `--fast` shrinks the windows for CI smoke.

use std::alloc::{GlobalAlloc, Layout, System};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Instant;

use aqua_serve::bench::report::{interleave_path, BenchReport};
use aqua_serve::coordinator::{Engine, EngineConfig, GenRequest};
use aqua_serve::model::config::ModelConfig;
use aqua_serve::runtime::{BackendSpec, NATIVE_PREFILL_CHUNK};
use aqua_serve::trace::TraceMode;
use aqua_serve::util::json::Json;
use aqua_serve::util::percentile;

/// Counts heap allocations while armed (quiet decode window only).
struct CountingAlloc;

static ARMED: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Allocations the native backend makes per decode call by API contract:
/// the `StepOut` logits and attention-mass buffers it returns by value.
const BACKEND_ALLOCS_PER_STEP: u64 = 2;

const BATCH: usize = 24;
const SHORT_PROMPT: usize = 8;
const LONG_GEN: usize = 4;

struct ModeOut {
    quiet_p99_ms: f64,
    inflight_p99_ms: f64,
    steady_decode_allocs: i64,
    prefill_tokens_per_step: f64,
    batch_occupancy: f64,
    long_prompt_tokens: usize,
    max_prefill_tokens: usize,
}

fn short_prompt(i: usize) -> Vec<i32> {
    (0..SHORT_PROMPT).map(|j| 32 + ((7 * i + j) % 90) as i32).collect()
}

fn run_mode(interleave: bool, fast: bool) -> anyhow::Result<ModeOut> {
    let cfg = ModelConfig::tiny("llama-analog");
    let spec = BackendSpec::native(cfg, 0)?;
    // One chunk per interleaved prefill pass: the tightest duty cycle.
    let max_prefill_tokens = if interleave { NATIVE_PREFILL_CHUNK } else { 0 };
    let ecfg = EngineConfig {
        batch: BATCH,
        interleave,
        max_batch_prefill_tokens: max_prefill_tokens,
        // Flight recorder at its most verbose: the no-alloc window below
        // proves tracing rides the hot loop for free (preallocated ring,
        // in-place slot overwrites — see `trace::TraceRecorder`).
        trace: TraceMode::Full,
        ..Default::default()
    };
    let mut engine = Engine::with_spec(&spec, ecfg)?;
    let max_seq = engine.model_config().max_seq;
    // Nine whole chunks, well under max_seq with the generation margin.
    let long_prompt_tokens = (max_seq - 2 * LONG_GEN) / NATIVE_PREFILL_CHUNK * NATIVE_PREFILL_CHUNK;

    // All but one lane: short prompts, generation long enough to outlive
    // every measurement window below (lanes finish by Length afterwards).
    let decode_lanes = BATCH - 1;
    for i in 0..decode_lanes {
        assert!(engine.submit(GenRequest::new(i as u64 + 1, short_prompt(i), max_seq - SHORT_PROMPT)));
    }

    // Warmup: run until every lane streams (2+ tokens each), sizing the
    // lazy metrics buffers so the armed window below is steady-state.
    let mut guard = 0;
    while engine.metrics.snapshot().tokens_generated < 2 * decode_lanes as u64 {
        engine.step()?;
        guard += 1;
        assert!(guard < 2_000, "warmup did not converge");
    }

    // Quiet window: decode-only baseline, allocation-counted.
    let quiet_steps: u64 = if fast { 40 } else { 90 };
    let mut last_gen = engine.metrics.snapshot().tokens_generated;
    let mut last_t = Instant::now();
    let mut quiet_gaps_ms: Vec<f64> = Vec::with_capacity(quiet_steps as usize);
    ALLOCS.store(0, Ordering::Relaxed);
    for _ in 0..quiet_steps {
        ARMED.store(true, Ordering::Relaxed);
        engine.step()?;
        ARMED.store(false, Ordering::Relaxed);
        let now = Instant::now();
        let gen = engine.metrics.snapshot().tokens_generated;
        if gen > last_gen {
            quiet_gaps_ms.push(now.duration_since(last_t).as_secs_f64() * 1e3);
            last_t = now;
            last_gen = gen;
        }
    }
    let steady_decode_allocs =
        ALLOCS.load(Ordering::Relaxed) as i64 - (BACKEND_ALLOCS_PER_STEP * quiet_steps) as i64;

    // In-flight window: inject the long prompt, clock decode gaps until it
    // completes. FIFO stalls every lane for the whole prefill; the
    // interleaved scheduler bounds each gap to ~one chunk of prefill work.
    let long_id = 1000;
    let long: Vec<i32> = (0..long_prompt_tokens).map(|j| 32 + (j % 90) as i32).collect();
    assert!(engine.submit(GenRequest::new(long_id, long, LONG_GEN)));
    let mut inflight_gaps_ms: Vec<f64> = vec![];
    last_t = Instant::now();
    let mut guard = 0;
    loop {
        engine.step()?;
        let now = Instant::now();
        let gen = engine.metrics.snapshot().tokens_generated;
        if gen > last_gen {
            inflight_gaps_ms.push(now.duration_since(last_t).as_secs_f64() * 1e3);
            last_t = now;
            last_gen = gen;
        }
        if engine.take_result(long_id).is_some() {
            break;
        }
        guard += 1;
        assert!(guard < 50_000, "long request did not complete");
    }

    let snap = engine.metrics.snapshot();
    Ok(ModeOut {
        quiet_p99_ms: percentile(&quiet_gaps_ms, 99.0),
        inflight_p99_ms: percentile(&inflight_gaps_ms, 99.0),
        steady_decode_allocs,
        prefill_tokens_per_step: snap.prefill_tokens_per_step,
        batch_occupancy: snap.batch_occupancy,
        long_prompt_tokens,
        max_prefill_tokens,
    })
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    println!(
        "# interleave — {} decode lanes + 1 injected ~max_seq prompt, chunk {} \
         (itl_ratio = in-flight p99 gap / quiet p99 gap)\n",
        BATCH - 1,
        NATIVE_PREFILL_CHUNK
    );
    println!(
        "{:>11} {:>11} {:>13} {:>10} {:>13} {:>10} {:>7}",
        "mode", "quiet p99", "in-flight p99", "ratio", "prefill t/s", "occupancy", "allocs"
    );

    let mut rows: Vec<Json> = vec![];
    for (mode, interleave) in [("interleave", true), ("fifo", false)] {
        let out = run_mode(interleave, fast)?;
        let ratio = out.inflight_p99_ms / out.quiet_p99_ms;
        println!(
            "{:>11} {:>9.3}ms {:>11.3}ms {:>9.2}x {:>13.1} {:>9.0}% {:>7}",
            mode,
            out.quiet_p99_ms,
            out.inflight_p99_ms,
            ratio,
            out.prefill_tokens_per_step,
            100.0 * out.batch_occupancy,
            out.steady_decode_allocs
        );
        rows.push(Json::obj(vec![
            ("mode", Json::Str(mode.into())),
            ("backend", Json::Str("native".into())),
            ("batch", Json::Num(BATCH as f64)),
            ("max_prefill_tokens", Json::Num(out.max_prefill_tokens as f64)),
            ("prompt_tokens", Json::Num(out.long_prompt_tokens as f64)),
            ("quiet_p99_itl_ms", Json::Num(out.quiet_p99_ms)),
            ("inflight_p99_itl_ms", Json::Num(out.inflight_p99_ms)),
            ("itl_ratio", Json::Num(ratio)),
            ("prefill_tokens_per_step", Json::Num(out.prefill_tokens_per_step)),
            ("batch_occupancy", Json::Num(out.batch_occupancy)),
            ("steady_decode_allocs", Json::Num(out.steady_decode_allocs as f64)),
        ]));
    }

    let section = Json::obj(vec![
        ("rows", Json::Arr(rows)),
        ("model", Json::Str("llama-analog".into())),
        ("decode_lanes", Json::Num((BATCH - 1) as f64)),
        (
            "units",
            Json::Str(
                "itl = wall-clock gap between decode-advancing engine steps, p99 over the window; \
                 itl_ratio = inflight_p99_itl_ms / quiet_p99_itl_ms (strict bound: <= 2.0 with \
                 interleave on, and the fifo row must be worse); steady_decode_allocs = heap \
                 allocations per quiet decode window beyond the backend's 2-per-step output \
                 buffers, must be 0"
                    .into(),
            ),
        ),
        ("fast", Json::Bool(fast)),
    ]);
    let path = Path::new(interleave_path());
    let mut rep = BenchReport::load_or_new(path);
    rep.set_section("interleave", section);
    rep.save(path)?;
    println!("\nwrote interleave section to {}", path.display());
    Ok(())
}
