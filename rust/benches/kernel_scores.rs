//! Score-kernel microbench: dense vs AQUA sparse vs masked-dense vs packed
//! layouts across sequence lengths (the §5 cost decomposition, plus the
//! layout experiment behind DESIGN.md §Hardware-Adaptation).

use aqua_serve::aqua::native;
use aqua_serve::bench::{black_box, Bencher};
use aqua_serve::tensor::topk::{topk_indices_by_abs, topk_mask_by_abs};
use aqua_serve::util::prng::Rng;

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let bench = if fast { Bencher::quick() } else { Bencher::default() };
    let mut rng = Rng::new(7);
    let d = 128;
    let k = 32; // k_ratio 0.25
    println!("# score kernels, d={d}, k={k} (k_ratio {:.2})\n", k as f64 / d as f64);
    for seq in [128usize, 512, 2048, 8192] {
        let q = rng.normal_vec(d, 1.0);
        let keys = rng.normal_vec(seq * d, 1.0);
        let mut out = vec![0.0f32; seq];

        let r = bench.run(&format!("dense          seq={seq}"), || {
            native::dense_scores(&q, &keys, seq, d, &mut out);
            black_box(&out);
        });
        println!("{}", r.report());

        let r = bench.run(&format!("aqua sparse    seq={seq}"), || {
            native::aqua_scores_sparse(&q, &keys, seq, d, k, &mut out);
            black_box(&out);
        });
        println!("{}", r.report());

        let mask = topk_mask_by_abs(&q, k);
        let r = bench.run(&format!("masked dense   seq={seq}"), || {
            native::aqua_scores_masked(&q, &mask, &keys, seq, d, &mut out);
            black_box(&out);
        });
        println!("{}", r.report());

        let idx = topk_indices_by_abs(&q, k);
        let qk: Vec<f32> = idx.iter().map(|&i| q[i]).collect();
        let packed = native::pack_keys(&keys, seq, d, &idx);
        let r = bench.run(&format!("packed sparse  seq={seq}"), || {
            native::aqua_scores_packed(&qk, &packed, seq, k, &mut out);
            black_box(&out);
        });
        println!("{}\n", r.report());
    }
}
