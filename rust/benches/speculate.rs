//! Self-speculative decoding trajectory: draft acceptance rate, effective
//! tokens per verify cycle, and wall-clock inter-token latency vs the
//! plain dense baseline — the AQUA-sparse-draft / dense-verify duty cycle
//! measured end to end through the engine.
//!
//! One engine per (`k_ratio`, `speculate`) operating point, all greedy,
//! H2O off, native backend. The first point — `k_ratio = 1.0`,
//! `speculate = 0` — is the exact-decode baseline every other row's
//! `itl_ratio_vs_off` is measured against; because speculation is
//! lossless, every speculative row must reproduce the baseline's tokens
//! bit-for-bit (asserted here, and formally in `tests/speculative.rs`).
//!
//! Each point runs three windows:
//!
//! * **warmup** — admit the batch, stream a few cycles so the lazy
//!   metrics buffers are sized and the measurement below is steady-state;
//! * **armed** — a fixed number of engine steps with a counting
//!   `#[global_allocator]`: beyond the native backend's two
//!   return-by-value buffers per call (logits + attention mass, times
//!   `speculate` draft calls + 1 verify call per step), the draft/verify
//!   loop must add **zero** heap allocations — with `trace=full`, so the
//!   bound covers the new draft_block/verify_block/rollback events too.
//!   The window is also the throughput clock: committed tokens over
//!   elapsed wall time;
//! * **drain** — run to completion un-timed, collect outputs for the
//!   losslessness assertion and the final draft-ledger counters.
//!
//! Writes the `speculate` section of `BENCH_speculate.json` (schema in
//! BENCHES.md; `aqua benchcheck` re-derives the acceptance rate and
//! effective-tokens ratios from the raw counters and refuses the file if
//! they disagree). `--fast` shrinks the windows for CI smoke.

use std::alloc::{GlobalAlloc, Layout, System};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Instant;

use aqua_serve::aqua::policy::AquaConfig;
use aqua_serve::bench::report::{speculate_path, BenchReport};
use aqua_serve::coordinator::{Engine, EngineConfig, GenRequest};
use aqua_serve::model::config::ModelConfig;
use aqua_serve::runtime::BackendSpec;
use aqua_serve::trace::TraceMode;
use aqua_serve::util::json::Json;

/// Counts heap allocations while armed (the measured decode window only).
struct CountingAlloc;

static ARMED: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Allocations the native backend makes per call by API contract: the
/// `StepOut` logits and attention-mass buffers it returns by value. A
/// speculative step makes `speculate` draft calls + 1 verify call.
const BACKEND_ALLOCS_PER_CALL: u64 = 2;

const BATCH: usize = 4;
const PROMPT: usize = 8;

struct PointOut {
    tokens: Vec<Vec<i32>>,
    tok_per_s: f64,
    itl_ms: f64,
    steady_spec_allocs: i64,
    drafted: u64,
    accepted: u64,
    rejected: u64,
    committed: u64,
    lane_cycles: u64,
    acceptance_rate: f64,
    tokens_per_step_effective: f64,
}

fn prompt(lane: usize) -> Vec<i32> {
    (0..PROMPT).map(|j| 32 + ((11 * lane + 3 * j) % 90) as i32).collect()
}

fn run_point(k_ratio: f64, speculate: usize, fast: bool) -> anyhow::Result<PointOut> {
    let cfg = ModelConfig::tiny("llama-analog");
    let spec = BackendSpec::native(cfg, 0)?;
    let ecfg = EngineConfig {
        batch: BATCH,
        speculate,
        aqua: AquaConfig { k_ratio, ..Default::default() },
        // most verbose recorder: the no-alloc window proves the new
        // draft/verify/rollback events ride the hot loop for free
        trace: TraceMode::Full,
        ..Default::default()
    };
    let mut engine = Engine::with_spec(&spec, ecfg)?;
    // Sized so no lane can finish before the armed window closes: warmup
    // + armed steps each commit at most `speculate + 1` tokens per lane.
    let (warmup_steps, armed_steps) = if fast { (5u64, 10u64) } else { (5u64, 20u64) };
    let worst = ((warmup_steps + armed_steps) * (speculate as u64 + 1) + 4) as usize;
    let max_new = worst.min(engine.model_config().max_seq - PROMPT - 1);
    for lane in 0..BATCH {
        assert!(engine.submit(GenRequest::new(lane as u64 + 1, prompt(lane), max_new)));
    }

    // Warmup: prefill + first decode cycles (sizes the lazy ITL buffers).
    for _ in 0..warmup_steps + 1 {
        engine.step()?;
    }

    // Armed window: allocation-counted, and the throughput clock.
    let gen0 = engine.metrics.snapshot().tokens_generated;
    ALLOCS.store(0, Ordering::Relaxed);
    let t0 = Instant::now();
    for _ in 0..armed_steps {
        ARMED.store(true, Ordering::Relaxed);
        engine.step()?;
        ARMED.store(false, Ordering::Relaxed);
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let window_tokens = engine.metrics.snapshot().tokens_generated - gen0;
    assert!(window_tokens > 0, "armed window generated nothing");
    let calls_per_step = if speculate > 0 { speculate as u64 + 1 } else { 1 };
    let steady_spec_allocs = ALLOCS.load(Ordering::Relaxed) as i64
        - (BACKEND_ALLOCS_PER_CALL * calls_per_step * armed_steps) as i64;

    // Drain un-timed; collect outputs for the losslessness assertion.
    engine.run_until_idle()?;
    let mut tokens = vec![];
    for lane in 0..BATCH {
        let r = engine.take_result(lane as u64 + 1).expect("lane result");
        tokens.push(r.tokens);
    }
    let snap = engine.metrics.snapshot();
    Ok(PointOut {
        tokens,
        tok_per_s: window_tokens as f64 / elapsed,
        itl_ms: elapsed * 1e3 / window_tokens as f64,
        steady_spec_allocs,
        drafted: snap.spec_drafted,
        accepted: snap.spec_accepted,
        rejected: snap.spec_rejected,
        committed: snap.spec_committed,
        lane_cycles: snap.spec_lane_cycles,
        acceptance_rate: snap.spec_acceptance_rate,
        tokens_per_step_effective: snap.tokens_per_step_effective,
    })
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    // Baseline first: exact decode, no speculation. Every speculative
    // point is lossless against it (bit-identical committed tokens).
    let points: &[(f64, usize)] = &[(1.0, 0), (0.25, 2), (0.25, 4), (0.5, 4), (1.0, 4)];
    println!(
        "# speculate — {} lanes, greedy, native backend \
         (itl_ratio_vs_off = row wall-clock per token / baseline's)\n",
        BATCH
    );
    println!(
        "{:>8} {:>10} {:>9} {:>9} {:>11} {:>10} {:>10} {:>7}",
        "k_ratio", "speculate", "accept%", "eff t/s", "tok/s", "itl ms", "itl ratio", "allocs"
    );

    let mut rows: Vec<Json> = vec![];
    let mut baseline: Option<PointOut> = None;
    for &(k, s) in points {
        let out = run_point(k, s, fast)?;
        if let Some(base) = &baseline {
            // truncate to the shorter run: points size max_new to their
            // own window, but the shared prefix must match bit-for-bit
            for lane in 0..BATCH {
                let n = out.tokens[lane].len().min(base.tokens[lane].len());
                assert_eq!(
                    out.tokens[lane][..n],
                    base.tokens[lane][..n],
                    "speculation must be lossless (k={k}, speculate={s}, lane {lane})"
                );
            }
        }
        let itl_ratio = match &baseline {
            Some(base) => out.itl_ms / base.itl_ms,
            None => 1.0,
        };
        println!(
            "{:>8.2} {:>10} {:>8.1}% {:>9.2} {:>11.1} {:>10.4} {:>9.2}x {:>7}",
            k,
            s,
            100.0 * out.acceptance_rate,
            out.tokens_per_step_effective,
            out.tok_per_s,
            out.itl_ms,
            itl_ratio,
            out.steady_spec_allocs
        );
        rows.push(Json::obj(vec![
            ("backend", Json::Str("native".into())),
            ("k_ratio", Json::Num(k)),
            ("speculate", Json::Num(s as f64)),
            ("batch", Json::Num(BATCH as f64)),
            ("drafted", Json::Num(out.drafted as f64)),
            ("accepted", Json::Num(out.accepted as f64)),
            ("rejected", Json::Num(out.rejected as f64)),
            ("committed", Json::Num(out.committed as f64)),
            ("lane_cycles", Json::Num(out.lane_cycles as f64)),
            ("acceptance_rate", Json::Num(out.acceptance_rate)),
            ("tokens_per_step_effective", Json::Num(out.tokens_per_step_effective)),
            ("tok_per_s", Json::Num(out.tok_per_s)),
            ("itl_ms", Json::Num(out.itl_ms)),
            ("itl_ratio_vs_off", Json::Num(itl_ratio)),
            ("steady_spec_allocs", Json::Num(out.steady_spec_allocs as f64)),
        ]));
        if baseline.is_none() {
            baseline = Some(out);
        }
    }

    let section = Json::obj(vec![
        ("rows", Json::Arr(rows)),
        ("model", Json::Str("llama-analog".into())),
        (
            "units",
            Json::Str(
                "acceptance_rate = accepted/drafted; tokens_per_step_effective = \
                 committed/lane_cycles (> 1.0 means speculation pays); itl_ratio_vs_off = \
                 wall-clock ms per committed token relative to the k_ratio=1.0 speculate=0 \
                 exact baseline (< 1.0 is a win); steady_spec_allocs = heap allocations per \
                 armed window beyond the backend's 2-per-call output buffers, must be 0"
                    .into(),
            ),
        ),
        ("fast", Json::Bool(fast)),
    ]);
    let path = Path::new(speculate_path());
    let mut rep = BenchReport::load_or_new(path);
    rep.set_section("speculate", section);
    rep.save(path)?;
    println!("\nwrote speculate section to {}", path.display());
    Ok(())
}
