//! End-to-end decode latency through the execution backends (the serving
//! headline numbers; EXPERIMENTS.md §Perf before/after tracks this bench).
//!
//! Two matrices, both written to the `decode_e2e` section of
//! `BENCH_decode.json` (see BENCHES.md):
//!
//! * **score-kernel routing** on the native backend: the masked-dense
//!   oracle vs the sparse, dim-major packed, and page-fused streaming
//!   kernels at k = d/4, plus the k = d dense reference and an
//!   int8-resident-KV fused point — the steady-state form of the §5
//!   break-even claim (the deep fused trajectory lives in the `fused`
//!   bench / BENCH_fused.json);
//! * **sharded scaling**: the lane-sharded backend at 1/2/4 worker
//!   threads on a batch-8 decode workload, vs the single-threaded native
//!   backend.
//!
//! Pass `--fast` for a smoke run (CI uses it before validating the JSON).

use std::path::Path;
use std::sync::Arc;

use aqua_serve::aqua::policy::AquaConfig;
use aqua_serve::bench::report::{default_path, BenchReport};
use aqua_serve::bench::{black_box, BenchResult, Bencher};
use aqua_serve::kvpool::{KvPoolConfig, KvQuant};
use aqua_serve::model::config::ModelConfig;
use aqua_serve::runtime::{
    AquaKnobs, ExecBackend, NativeBackend, NativeModel, ScoreMode, ShardedBackend,
};
use aqua_serve::util::json::Json;

struct Row {
    backend: &'static str,
    score_mode: &'static str,
    kv_quant: &'static str,
    k_ratio: f64,
    batch: usize,
    threads: usize,
    result: BenchResult,
}

impl Row {
    fn tok_per_s(&self) -> f64 {
        self.batch as f64 * 1e9 / self.result.mean_ns
    }

    fn json(&self) -> Json {
        Json::obj(vec![
            ("backend", Json::Str(self.backend.into())),
            ("score_mode", Json::Str(self.score_mode.into())),
            ("kv_quant", Json::Str(self.kv_quant.into())),
            ("k_ratio", Json::Num(self.k_ratio)),
            ("batch", Json::Num(self.batch as f64)),
            ("threads", Json::Num(self.threads as f64)),
            ("mean_step_us", Json::Num(self.result.mean_ns / 1e3)),
            ("p50_step_us", Json::Num(self.result.p50_ns / 1e3)),
            ("p99_step_us", Json::Num(self.result.p99_ns / 1e3)),
            ("tok_per_s", Json::Num(self.tok_per_s())),
        ])
    }
}

/// Steady-state decode: `ctx` committed slots, every step rewrites the
/// same position (the cache stays warm, the attendable set fixed). The
/// context is *really written* first — with the paged KV pool, unleased
/// pages cost nothing to score, so a mask-only context would understate
/// the kernel work the bench is meant to measure.
fn measure_decode(
    be: &mut dyn ExecBackend,
    bench: &Bencher,
    name: &str,
    b: usize,
    k_ratio: f64,
) -> BenchResult {
    let cfg = be.model_config().clone();
    let ctx = cfg.max_seq / 2;
    be.empty_cache(b).expect("empty_cache");
    let aqua = AquaConfig { k_ratio, ..Default::default() };
    let knobs = AquaKnobs::from_config(&aqua, cfg.d_head);
    let mut slot_mask = vec![0.0f32; b * cfg.max_seq];
    for i in 0..ctx {
        let toks = vec![(32 + (i % 64)) as i32; b];
        let ppos = vec![i as i32; b];
        be.decode(b, &toks, &ppos, &slot_mask, &knobs).expect("context decode");
        for lane in 0..b {
            slot_mask[lane * cfg.max_seq + i] = 1.0;
        }
    }
    let tokens = vec![5i32; b];
    let pos = vec![ctx as i32; b];
    bench.run(name, || {
        let out = be.decode(b, &tokens, &pos, &slot_mask, &knobs).expect("decode");
        black_box(out.logits.len());
    })
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let bench = if fast {
        Bencher { warmup: 1, iters: 12, ..Bencher::quick() }
    } else {
        Bencher { warmup: 3, iters: 25, ..Default::default() }
    };
    let model = Arc::new(NativeModel::new(ModelConfig::tiny("llama-analog"), 0)?);
    let cfg = model.cfg.clone();
    let ctx = cfg.max_seq / 2;
    println!(
        "# decode step latency (backend round trip), S={}, {} live slots, d={}\n",
        cfg.max_seq, ctx, cfg.d_head
    );

    let mut rows: Vec<Row> = vec![];

    // ---- score-kernel routing on the native backend ----------------------
    let kernel_grid: [(&str, ScoreMode, f64); 5] = [
        ("dense", ScoreMode::Auto, 1.0),
        ("masked", ScoreMode::MaskedDense, 0.25),
        ("sparse", ScoreMode::Sparse, 0.25),
        ("packed", ScoreMode::Packed, 0.25),
        ("fused", ScoreMode::Fused, 0.25),
    ];
    for b in [1usize, 4] {
        for (label, mode, k_ratio) in kernel_grid {
            let mut be = NativeBackend::from_model(model.clone());
            be.set_score_mode(mode);
            let name = format!("native b={b} {label} k={k_ratio:.2}");
            let result = measure_decode(&mut be, &bench, &name, b, k_ratio);
            println!("{}  ({:.1} tok/s)", result.report(), b as f64 * 1e9 / result.mean_ns);
            rows.push(Row {
                backend: "native",
                score_mode: label,
                kv_quant: "f32",
                k_ratio,
                batch: b,
                threads: 1,
                result,
            });
        }
        println!();
    }

    // ---- int8 resident KV (fused dequantizing kernels) -------------------
    {
        let (b, k_ratio) = (4usize, 0.25);
        let mut be = NativeBackend::from_model(model.clone());
        be.configure_kv_pool(KvPoolConfig { kv_quant: KvQuant::Int8, ..Default::default() })
            .expect("configure_kv_pool");
        be.set_score_mode(ScoreMode::Fused);
        let name = format!("native b={b} fused int8 k={k_ratio:.2}");
        let result = measure_decode(&mut be, &bench, &name, b, k_ratio);
        println!("{}  ({:.1} tok/s)\n", result.report(), b as f64 * 1e9 / result.mean_ns);
        rows.push(Row {
            backend: "native",
            score_mode: "fused",
            kv_quant: "int8",
            k_ratio,
            batch: b,
            threads: 1,
            result,
        });
    }

    // ---- sharded scaling at batch 8 --------------------------------------
    let b = 8usize;
    let k_ratio = 0.25;
    {
        let mut be = NativeBackend::from_model(model.clone());
        let name = format!("native b={b} auto k={k_ratio:.2}");
        let result = measure_decode(&mut be, &bench, &name, b, k_ratio);
        println!("{}  ({:.1} tok/s)", result.report(), b as f64 * 1e9 / result.mean_ns);
        rows.push(Row {
            backend: "native",
            score_mode: "auto",
            kv_quant: "f32",
            k_ratio,
            batch: b,
            threads: 1,
            result,
        });
    }
    for threads in [1usize, 2, 4] {
        let mut be = ShardedBackend::from_model(model.clone(), threads);
        let name = format!("sharded t={threads} b={b} auto k={k_ratio:.2}");
        let result = measure_decode(&mut be, &bench, &name, b, k_ratio);
        println!("{}  ({:.1} tok/s)", result.report(), b as f64 * 1e9 / result.mean_ns);
        rows.push(Row {
            backend: "sharded",
            score_mode: "auto",
            kv_quant: "f32",
            k_ratio,
            batch: b,
            threads,
            result,
        });
    }

    // ---- PJRT round trip (only when --features pjrt + artifacts) ---------
    // `default_backend` resolves to pjrt exactly when the production path
    // is available; the native rows above already cover the fallback.
    if let Ok(mut be) = aqua_serve::runtime::default_backend("llama-analog", 0) {
        if be.name() == "pjrt" {
            for (label, k_ratio) in [("dense", 1.0), ("masked", 0.25)] {
                let name = format!("pjrt b=4 {label} k={k_ratio:.2}");
                let result = measure_decode(be.as_mut(), &bench, &name, 4, k_ratio);
                println!("{}  ({:.1} tok/s)", result.report(), 4.0 * 1e9 / result.mean_ns);
                rows.push(Row {
                    backend: "pjrt",
                    score_mode: label,
                    kv_quant: "f32",
                    k_ratio,
                    batch: 4,
                    threads: 1,
                    result,
                });
            }
        }
    }

    let section = Json::obj(vec![
        ("rows", Json::Arr(rows.iter().map(Row::json).collect())),
        ("model", Json::Str(cfg.name.clone())),
        ("live_slots", Json::Num(ctx as f64)),
        ("units", Json::Str("mean_step_us per decode call; tok_per_s = batch/mean_step".into())),
        ("fast", Json::Bool(fast)),
    ]);
    let path = Path::new(default_path());
    let mut rep = BenchReport::load_or_new(path);
    rep.set_section("decode_e2e", section);
    rep.save(path)?;
    println!("\nwrote decode_e2e section to {}", path.display());
    Ok(())
}
