//! End-to-end decode latency through the full PJRT stack, across AQUA
//! operating points and batch sizes (the serving headline numbers;
//! EXPERIMENTS.md §Perf before/after tracks this bench).
//!
//! Requires `make artifacts`; skips gracefully otherwise.

use std::sync::Arc;

use aqua_serve::aqua::policy::AquaConfig;
use aqua_serve::bench::Bencher;
use aqua_serve::runtime::{Artifacts, ModelRuntime};

fn main() -> anyhow::Result<()> {
    let Ok(arts) = Artifacts::load(aqua_serve::ARTIFACTS_DIR) else {
        println!("skipped: artifacts not built (run `make artifacts`)");
        return Ok(());
    };
    let rt = Arc::new(ModelRuntime::load(arts.model("llama-analog")?)?);
    let cfg = rt.cfg.clone();
    let bench = Bencher { warmup: 3, iters: 25, ..Default::default() };

    println!("# decode step latency (full PJRT round trip), S={}\n", cfg.max_seq);
    for b in [1usize, 4] {
        let (k_cache, v_cache) = rt.empty_cache(b)?;
        let tokens = vec![5i32; b];
        let pos = vec![100i32; b];
        let mut slot_mask = vec![0.0f32; b * cfg.max_seq];
        for lane in 0..b {
            for s in 0..100 {
                slot_mask[lane * cfg.max_seq + s] = 1.0;
            }
        }
        for (label, aqua) in [
            ("baseline P=I k=d", AquaConfig::baseline()),
            ("aqua k=0.75", AquaConfig { k_ratio: 0.75, ..Default::default() }),
            ("aqua k=0.25", AquaConfig { k_ratio: 0.25, ..Default::default() }),
            ("aqua-mem S=0.25 k=0.75",
             AquaConfig { k_ratio: 0.75, s_ratio: 0.25, ..Default::default() }),
        ] {
            let k_dims = aqua.k_dims(cfg.d_head) as i32;
            let keep = aqua.dim_keep_mask(cfg.d_head);
            let r = bench.run(&format!("decode b={b} {label}"), || {
                let out = rt
                    .decode(b, &tokens, &pos, &k_cache, &v_cache, &slot_mask, k_dims,
                            &keep, aqua.use_projection)
                    .expect("decode");
                aqua_serve::bench::black_box(out.logits.len());
            });
            println!("{}  ({:.1} tok/s/lane)", r.report(), 1e9 / r.mean_ns);
        }
        println!();
    }
    Ok(())
}
