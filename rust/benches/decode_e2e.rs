//! End-to-end decode latency through the execution backend, across AQUA
//! operating points and batch sizes (the serving headline numbers;
//! EXPERIMENTS.md §Perf before/after tracks this bench).
//!
//! Backend-generic: runs the hermetic native backend by default, the full
//! PJRT round trip when built with `--features pjrt` after `make
//! artifacts`.

use aqua_serve::aqua::policy::AquaConfig;
use aqua_serve::bench::Bencher;
use aqua_serve::runtime::{default_backend, AquaKnobs, ExecBackend};

fn main() -> anyhow::Result<()> {
    let mut backend = default_backend("llama-analog", 0)?;
    let cfg = backend.model_config().clone();
    let bench = Bencher { warmup: 3, iters: 25, ..Default::default() };
    let ctx = cfg.max_seq / 2;

    println!(
        "# decode step latency ({} backend round trip), S={}, {} live slots\n",
        backend.name(),
        cfg.max_seq,
        ctx
    );
    for b in [1usize, 4] {
        backend.empty_cache(b)?;
        let tokens = vec![5i32; b];
        let pos = vec![ctx as i32; b];
        let mut slot_mask = vec![0.0f32; b * cfg.max_seq];
        for lane in 0..b {
            for s in 0..ctx {
                slot_mask[lane * cfg.max_seq + s] = 1.0;
            }
        }
        for (label, aqua) in [
            ("baseline P=I k=d", AquaConfig::baseline()),
            ("aqua k=0.75", AquaConfig { k_ratio: 0.75, ..Default::default() }),
            ("aqua k=0.25", AquaConfig { k_ratio: 0.25, ..Default::default() }),
            ("aqua-mem S=0.25 k=0.75",
             AquaConfig { k_ratio: 0.75, s_ratio: 0.25, ..Default::default() }),
        ] {
            let knobs = AquaKnobs::from_config(&aqua, cfg.d_head);
            let r = bench.run(&format!("decode b={b} {label}"), || {
                let out = backend
                    .decode(b, &tokens, &pos, &slot_mask, &knobs)
                    .expect("decode");
                aqua_serve::bench::black_box(out.logits.len());
            });
            println!("{}  ({:.1} tok/s/lane)", r.report(), 1e9 / r.mean_ns);
        }
        println!();
    }
    Ok(())
}
