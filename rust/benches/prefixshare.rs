//! Prefix-sharing trajectory: TTFT, prefill token-work, and resident KV
//! bytes vs the shared-prefix fraction of the workload × the AQUA-Memory
//! knob (`kv_keep = 1 - s_ratio`) — the "one prefill, many lanes" half of
//! the memory story, measured on the pages the pool actually holds.
//!
//! For each operating point the bench serves the same workload twice
//! through a full engine — prefix cache on and off — after priming the
//! cache with one donor request: a batch of lanes whose prompts share a
//! `shared_frac` token prefix then attach the donor's page chain instead
//! of re-running prefill. Recorded per row:
//!
//! * `hit_tokens` / `prefill_tokens` — prompt tokens served from the
//!   cache vs computed (they reconcile to `total_prompt_tokens`, so
//!   skipped prefill work is exactly proportional to the hit rate);
//! * `peak_resident_bytes` and `resident_ratio_vs_unshared` — measured
//!   peak leased-page bytes, and the ratio against the sharing-disabled
//!   run of the *same* workload (shared pages counted once vs per lane);
//! * `mean_ttft_ms` — attach is O(pages), so warm lanes reach their first
//!   token without paying the shared prefix's prefill latency.
//!
//! Sharing compounds with `kv_keep`: shared pages store truncated
//! resident keys, so the kv_keep=0.5 rows shrink byte-for-byte on top of
//! the page-dedup saving. Writes the `prefixshare` section of
//! `BENCH_prefix.json` (schema in BENCHES.md; `aqua benchcheck --strict`
//! asserts the ≤0.65× @ 50%-shared acceptance bound). `--fast` is
//! accepted for CI symmetry (the workload is already smoke-sized).

use std::path::Path;

use aqua_serve::aqua::policy::AquaConfig;
use aqua_serve::bench::report::{prefix_path, BenchReport};
use aqua_serve::coordinator::{Engine, EngineConfig, GenRequest};
use aqua_serve::kvpool::DEFAULT_PAGE_SLOTS;
use aqua_serve::model::config::ModelConfig;
use aqua_serve::runtime::BackendSpec;
use aqua_serve::util::json::Json;
use aqua_serve::util::prng::Rng;

const PROMPT_LEN: usize = 96;
const GEN_LEN: usize = 8;
const BATCH: usize = 8;

/// `len` deterministic byte-range tokens: `shared` prefix + seeded tail.
fn prompt(shared: &[i32], tail_seed: u64, len: usize) -> Vec<i32> {
    let mut p = shared.to_vec();
    p.truncate(len);
    let mut rng = Rng::new(tail_seed);
    while p.len() < len {
        p.push(32 + rng.below(90) as i32);
    }
    p
}

struct RunOut {
    peak_bytes: u64,
    hit_tokens: u64,
    prefill_tokens: u64,
    total_prompt_tokens: u64,
    mean_ttft_ms: f64,
}

/// One operating point: prime the cache with a donor request, then serve
/// `BATCH` lanes whose prompts share `shared` as a prefix.
fn run(keep: f64, shared: &[i32], cache_on: bool) -> anyhow::Result<RunOut> {
    let cfg = ModelConfig::tiny("llama-analog");
    let spec = BackendSpec::native(cfg, 0)?;
    let aqua = AquaConfig { s_ratio: 1.0 - keep, ..Default::default() };
    let ecfg = EngineConfig { batch: BATCH, aqua, prefix_cache: cache_on, ..Default::default() };
    let mut engine = Engine::with_spec(&spec, ecfg)?;

    // donor: registers the shared prefix's pages (cached after retire)
    engine.run_batch(vec![GenRequest::new(1, prompt(shared, 999, PROMPT_LEN), GEN_LEN)])?;
    // main wave: every lane shares the prefix, tails diverge
    let reqs: Vec<GenRequest> = (0..BATCH)
        .map(|i| GenRequest::new(i as u64 + 2, prompt(shared, 1 + i as u64, PROMPT_LEN), GEN_LEN))
        .collect();
    let results = engine.run_batch(reqs)?;
    let mean_ttft_ms =
        results.iter().map(|r| r.ttft_us as f64 / 1e3).sum::<f64>() / results.len() as f64;

    let snap = engine.metrics.snapshot();
    Ok(RunOut {
        peak_bytes: snap.kv_resident_peak_bytes,
        hit_tokens: snap.prefix_hit_tokens,
        prefill_tokens: snap.prompt_tokens,
        total_prompt_tokens: ((BATCH + 1) * PROMPT_LEN) as u64,
        mean_ttft_ms,
    })
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let cfg = ModelConfig::tiny("llama-analog");
    let mut shared_full = vec![];
    let mut rng = Rng::new(0xA11CE);
    while shared_full.len() < PROMPT_LEN {
        shared_full.push(32 + rng.below(90) as i32);
    }

    println!(
        "# prefixshare — {BATCH} lanes + 1 donor, prompt {PROMPT_LEN} tok, gen {GEN_LEN} \
         (resident ratio = shared pool vs the same workload unshared)\n"
    );
    println!(
        "{:>8} {:>12} {:>7} {:>9} {:>14} {:>15} {:>10}",
        "kv_keep", "shared_frac", "cache", "hit rate", "peak resident", "ratio vs cold", "ttft"
    );

    let mut rows: Vec<Json> = vec![];
    for keep in [1.0f64, 0.5] {
        let mem_dims = AquaConfig { s_ratio: 1.0 - keep, ..Default::default() }.mem_dims(cfg.d_head);
        for frac in [0.0f64, 0.5, 0.9] {
            let shared = &shared_full[..(PROMPT_LEN as f64 * frac) as usize];
            let cold = run(keep, shared, false)?;
            let warm = run(keep, shared, true)?;
            for (on, out) in [(false, &cold), (true, &warm)] {
                let ratio = out.peak_bytes as f64 / cold.peak_bytes as f64;
                let hit_rate = out.hit_tokens as f64 / out.total_prompt_tokens as f64;
                println!(
                    "{:>8.2} {:>12.2} {:>7} {:>8.0}% {:>13}B {:>15.3} {:>8.2}ms",
                    keep,
                    frac,
                    if on { "on" } else { "off" },
                    100.0 * hit_rate,
                    out.peak_bytes,
                    ratio,
                    out.mean_ttft_ms
                );
                rows.push(Json::obj(vec![
                    ("kv_keep", Json::Num(keep)),
                    ("shared_frac", Json::Num(frac)),
                    ("prefix_cache", Json::Bool(on)),
                    ("mem_dims", Json::Num(mem_dims as f64)),
                    ("page_slots", Json::Num(DEFAULT_PAGE_SLOTS as f64)),
                    ("requests", Json::Num((BATCH + 1) as f64)),
                    ("batch", Json::Num(BATCH as f64)),
                    ("hit_tokens", Json::Num(out.hit_tokens as f64)),
                    ("prefill_tokens", Json::Num(out.prefill_tokens as f64)),
                    ("total_prompt_tokens", Json::Num(out.total_prompt_tokens as f64)),
                    ("hit_rate", Json::Num(hit_rate)),
                    ("peak_resident_bytes", Json::Num(out.peak_bytes as f64)),
                    (
                        "resident_per_lane_bytes",
                        Json::Num(out.peak_bytes as f64 / BATCH as f64),
                    ),
                    ("resident_ratio_vs_unshared", Json::Num(ratio)),
                    ("mean_ttft_ms", Json::Num(out.mean_ttft_ms)),
                ]));
            }
        }
    }

    let section = Json::obj(vec![
        ("rows", Json::Arr(rows)),
        ("model", Json::Str("llama-analog".into())),
        ("prompt_len", Json::Num(PROMPT_LEN as f64)),
        ("gen_len", Json::Num(GEN_LEN as f64)),
        (
            "units",
            Json::Str(
                "hit_tokens + prefill_tokens == total_prompt_tokens (skipped prefill work is the \
                 hit rate); resident_ratio_vs_unshared = peak leased bytes vs the same workload \
                 with sharing disabled; rows come in on/off pairs per (kv_keep, shared_frac)"
                    .into(),
            ),
        ),
        ("fast", Json::Bool(fast)),
    ]);
    let path = Path::new(prefix_path());
    let mut rep = BenchReport::load_or_new(path);
    rep.set_section("prefixshare", section);
    rep.save(path)?;
    println!("\nwrote prefixshare section to {}", path.display());
    Ok(())
}
