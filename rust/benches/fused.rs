//! Fused-kernel trajectory: the page-fused streaming decode path
//! (`ScoreMode::Fused` — packed AQUA scores + online softmax + value
//! reduction in one pass per resident KV page) vs the three-pass packed
//! baseline, plus the int8-quantized resident pool riding the same fused
//! loop.
//!
//! One row per (`mode`, `kv_quant`, `context_slots`) operating point on a
//! long-sequence analog (`max_seq = 576`, so the strict 1.3x bound is
//! measured at `context_slots >= 512` where the three-pass S-scratch walk
//! actually hurts). Per row the bench:
//!
//! * writes the context **for real** (unleased pages score for free — a
//!   mask-only context would understate the streamed page work);
//! * takes one instrumented decode to read `KernelCounters` — asserting
//!   the read-each-page-once invariant (`fused_passes == lanes x layers x
//!   heads x resident pages`) and recording per-page-pass ns, SIMD lane
//!   width, and int8 dequant time;
//! * checks parity against the packed three-pass baseline's logits on the
//!   identical content (f32 fused is bit-identical by construction; int8
//!   must stay inside the quantization bound);
//! * runs an alloc-armed window with a counting `#[global_allocator]`:
//!   beyond the backend's two return-by-value buffers per call, the fused
//!   decode loop must add **zero** heap allocations;
//! * times the steady-state step with the shared `Bencher`.
//!
//! A final engine-level leg drives `kv_quant=int8` (which routes decode
//! through the fused kernels) with `trace=full` and the same allocation
//! gate, so the no-alloc claim covers the production path with the most
//! verbose recorder attached.
//!
//! Writes the `fused` section of `BENCH_fused.json` (schema in BENCHES.md,
//! validated by `aqua benchcheck`; `--strict` asserts the 1.3x throughput
//! bound). Pass `--fast` for a smoke run (CI).

use std::alloc::{GlobalAlloc, Layout, System};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use aqua_serve::aqua::policy::AquaConfig;
use aqua_serve::bench::report::{fused_path, BenchReport};
use aqua_serve::bench::{black_box, BenchResult, Bencher};
use aqua_serve::coordinator::{Engine, EngineConfig, GenRequest};
use aqua_serve::kvpool::{KvPoolConfig, KvQuant, PoolLayout, DEFAULT_PAGE_SLOTS};
use aqua_serve::model::config::ModelConfig;
use aqua_serve::runtime::{
    AquaKnobs, BackendSpec, ExecBackend, NativeBackend, NativeModel, ScoreMode,
};
use aqua_serve::trace::TraceMode;
use aqua_serve::util::json::Json;

/// Counts heap allocations while armed (the measured windows only).
struct CountingAlloc;

static ARMED: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Allocations the native backend makes per call by API contract: the
/// `StepOut` logits and attention-mass buffers it returns by value.
const BACKEND_ALLOCS_PER_CALL: u64 = 2;

const BATCH: usize = 4;
const K_RATIO: f64 = 0.25;

/// Long-sequence analog: `tiny` widths, but enough KV capacity that the
/// strict fused-vs-packed bound is measured at `context_slots >= 512`.
fn long_cfg() -> ModelConfig {
    ModelConfig { max_seq: 576, ..ModelConfig::tiny("llama-analog-long") }
}

/// Write `ctx` real context slots (identical token stream per backend, so
/// cross-backend logits are comparable bit-for-bit) and return the
/// steady-state decode arguments.
fn write_context(
    be: &mut dyn ExecBackend,
    ctx: usize,
    knobs: &AquaKnobs,
) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
    let s_cap = be.model_config().max_seq;
    be.empty_cache(BATCH).expect("empty_cache");
    let mut slot_mask = vec![0.0f32; BATCH * s_cap];
    for i in 0..ctx {
        let toks = vec![(32 + (i % 64)) as i32; BATCH];
        let ppos = vec![i as i32; BATCH];
        be.decode(BATCH, &toks, &ppos, &slot_mask, knobs).expect("context decode");
        for lane in 0..BATCH {
            slot_mask[lane * s_cap + i] = 1.0;
        }
    }
    (vec![5i32; BATCH], vec![ctx as i32; BATCH], slot_mask)
}

struct Point {
    result: BenchResult,
    logits: Vec<f32>,
    fused_passes: u64,
    simd_lanes: u64,
    dequant_ns: u64,
    score_ns: u64,
    resident_bytes: u64,
    steady_decode_allocs: i64,
}

fn run_point(
    model: &Arc<NativeModel>,
    mode: ScoreMode,
    quant: KvQuant,
    ctx: usize,
    bench: &Bencher,
    name: &str,
) -> Point {
    let mut be = NativeBackend::from_model(model.clone());
    be.configure_kv_pool(KvPoolConfig { kv_quant: quant, ..Default::default() })
        .expect("configure_kv_pool");
    be.set_score_mode(mode);
    let d = model.cfg.d_head;
    let aqua = AquaConfig { k_ratio: K_RATIO, ..Default::default() };
    let knobs = AquaKnobs::from_config(&aqua, d);
    let (tokens, pos, slot_mask) = write_context(&mut be, ctx, &knobs);

    // one instrumented call: counters + logits for the parity check
    let out = be.decode(BATCH, &tokens, &pos, &slot_mask, &knobs).expect("decode");
    let (fused_passes, simd_lanes, dequant_ns, score_ns) = (
        out.kernels.fused_passes,
        out.kernels.simd_lanes_used,
        out.kernels.dequant_ns,
        out.kernels.score_ns,
    );
    let resident_bytes = out.kv.resident_bytes;

    // alloc-armed window: the steady decode loop must not touch the heap
    // beyond the backend's two return-by-value buffers per call
    let armed_calls = 8u64;
    ALLOCS.store(0, Ordering::Relaxed);
    for _ in 0..armed_calls {
        ARMED.store(true, Ordering::Relaxed);
        let o = be.decode(BATCH, &tokens, &pos, &slot_mask, &knobs).expect("decode");
        ARMED.store(false, Ordering::Relaxed);
        black_box(o.logits.len());
    }
    let steady_decode_allocs =
        ALLOCS.load(Ordering::Relaxed) as i64 - (BACKEND_ALLOCS_PER_CALL * armed_calls) as i64;

    let result = bench.run(name, || {
        let o = be.decode(BATCH, &tokens, &pos, &slot_mask, &knobs).expect("decode");
        black_box(o.logits.len());
    });
    Point {
        result,
        logits: out.logits,
        fused_passes,
        simd_lanes,
        dequant_ns,
        score_ns,
        resident_bytes,
        steady_decode_allocs,
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b.iter()).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
}

/// Engine-level no-alloc gate: `kv_quant=int8` routes decode through the
/// fused kernels, `trace=full` attaches the most verbose recorder — the
/// fused path must still add zero steady-state heap allocations.
fn engine_trace_full_allocs(fast: bool) -> anyhow::Result<i64> {
    let spec = BackendSpec::native(ModelConfig::tiny("llama-analog"), 0)?;
    let ecfg = EngineConfig {
        batch: BATCH,
        kv_quant: KvQuant::Int8,
        aqua: AquaConfig { k_ratio: K_RATIO, ..Default::default() },
        trace: TraceMode::Full,
        ..Default::default()
    };
    let mut engine = Engine::with_spec(&spec, ecfg)?;
    let (warmup_steps, armed_steps) = if fast { (5u64, 8u64) } else { (5u64, 16u64) };
    // sized so no lane retires before the armed window closes
    let max_new = (warmup_steps + armed_steps + 4) as usize;
    for lane in 0..BATCH {
        let prompt: Vec<i32> = (0..8).map(|j| 32 + ((11 * lane + 3 * j) % 90) as i32).collect();
        assert!(engine.submit(GenRequest::new(lane as u64 + 1, prompt, max_new)));
    }
    for _ in 0..warmup_steps + 1 {
        engine.step()?;
    }
    ALLOCS.store(0, Ordering::Relaxed);
    for _ in 0..armed_steps {
        ARMED.store(true, Ordering::Relaxed);
        engine.step()?;
        ARMED.store(false, Ordering::Relaxed);
    }
    engine.run_until_idle()?;
    Ok(ALLOCS.load(Ordering::Relaxed) as i64 - (BACKEND_ALLOCS_PER_CALL * armed_steps) as i64)
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let bench = if fast {
        Bencher { warmup: 1, iters: 10, ..Bencher::quick() }
    } else {
        Bencher { warmup: 3, iters: 25, ..Default::default() }
    };
    let cfg = long_cfg();
    let (d, nq, nkv, nl) = (cfg.d_head, cfg.n_q_heads, cfg.n_kv_heads, cfg.n_layers);
    let model = Arc::new(NativeModel::new(cfg.clone(), 0)?);
    let ps = DEFAULT_PAGE_SLOTS;
    let layout_for = |quant: KvQuant| PoolLayout {
        page_slots: ps,
        key_dims: d,
        head_dim: d,
        layers: nl,
        kv_heads: nkv,
        kv_quant: quant,
    };
    println!(
        "# fused — page-fused streaming decode vs three-pass packed, S={}, batch={BATCH}, \
         k={K_RATIO:.2}\n",
        cfg.max_seq
    );

    let grid: [(&str, ScoreMode, KvQuant); 3] = [
        ("packed", ScoreMode::Packed, KvQuant::F32),
        ("fused", ScoreMode::Fused, KvQuant::F32),
        ("fused", ScoreMode::Fused, KvQuant::Int8),
    ];
    let contexts: [usize; 2] = [128, 560];

    let mut rows: Vec<Json> = vec![];
    for ctx in contexts {
        // resident pages per lane: slots 0..=ctx (the step's own write
        // lands at `ctx`), all leased because the context was written
        let pages = ctx / ps + 1;
        let expected_fused = (BATCH * nl * nq * pages) as u64;
        let mut packed_logits: Option<Vec<f32>> = None;
        let mut f32_resident: Option<u64> = None;
        for (label, mode, quant) in grid {
            let name = format!("{label} {} ctx={ctx}", quant.as_str());
            let pt = run_point(&model, mode, quant, ctx, &bench, &name);
            let fused = mode == ScoreMode::Fused;
            if fused {
                assert_eq!(
                    pt.fused_passes, expected_fused,
                    "{name}: fused passes != lanes x layers x heads x resident pages \
                     (a page was re-read or skipped)"
                );
            } else {
                assert_eq!(pt.fused_passes, 0, "{name}: packed baseline took fused passes");
            }
            assert_eq!(pt.steady_decode_allocs, 0, "{name}: steady decode loop allocated");
            let parity = match &packed_logits {
                Some(base) => max_abs_diff(base, &pt.logits) as f64,
                None => 0.0,
            };
            if packed_logits.is_none() {
                packed_logits = Some(pt.logits.clone());
            }
            let ratio = match (quant, f32_resident) {
                (KvQuant::Int8, Some(f)) => pt.resident_bytes as f64 / f as f64,
                _ => {
                    f32_resident = Some(pt.resident_bytes);
                    1.0
                }
            };
            let page_pass_ns = if fused && pt.fused_passes > 0 {
                pt.score_ns as f64 / pt.fused_passes as f64
            } else {
                0.0
            };
            // fused streams with one page-sized score strip; the
            // three-pass baseline carries the S-length score scratch
            let scratch_bytes = if fused { ps * 4 } else { cfg.max_seq * 4 };
            let tok_per_s = BATCH as f64 * 1e9 / pt.result.mean_ns;
            println!(
                "{}  ({tok_per_s:.1} tok/s, parity {parity:.2e}, {} passes, allocs {})",
                pt.result.report(),
                pt.fused_passes,
                pt.steady_decode_allocs
            );
            rows.push(Json::obj(vec![
                ("backend", Json::Str("native".into())),
                ("mode", Json::Str(label.into())),
                ("kv_quant", Json::Str(quant.as_str().into())),
                ("k_ratio", Json::Num(K_RATIO)),
                ("batch", Json::Num(BATCH as f64)),
                ("threads", Json::Num(1.0)),
                ("context_slots", Json::Num(ctx as f64)),
                ("page_slots", Json::Num(ps as f64)),
                ("page_bytes", Json::Num(layout_for(quant).page_bytes() as f64)),
                ("scratch_bytes", Json::Num(scratch_bytes as f64)),
                ("mean_step_us", Json::Num(pt.result.mean_ns / 1e3)),
                ("tok_per_s", Json::Num(tok_per_s)),
                ("page_pass_ns", Json::Num(page_pass_ns)),
                ("fused_passes_per_step", Json::Num(pt.fused_passes as f64)),
                (
                    "expected_page_loads_per_step",
                    Json::Num(if fused { expected_fused as f64 } else { 0.0 }),
                ),
                ("parity_max_abs_delta", Json::Num(parity)),
                ("resident_bytes_ratio_vs_f32", Json::Num(ratio)),
                ("dequant_ns_per_step", Json::Num(pt.dequant_ns as f64)),
                ("steady_decode_allocs", Json::Num(pt.steady_decode_allocs as f64)),
                ("simd_lanes", Json::Num(pt.simd_lanes as f64)),
            ]));
        }
        println!();
    }

    let engine_allocs = engine_trace_full_allocs(fast)?;
    assert_eq!(engine_allocs, 0, "int8 engine decode under trace=full allocated");
    println!("engine int8 trace=full steady allocs: {engine_allocs}");

    let section = Json::obj(vec![
        ("rows", Json::Arr(rows)),
        ("model", Json::Str(cfg.name.clone())),
        ("batch", Json::Num(BATCH as f64)),
        ("engine_trace_full_steady_allocs", Json::Num(engine_allocs as f64)),
        (
            "units",
            Json::Str(
                "page_pass_ns = score-path ns per fused page pass (scores + online softmax + \
                 value mix, one load of the page); scratch_bytes = kernel score scratch (fused: \
                 one page strip, packed: S-length); parity_max_abs_delta = max |logit delta| vs \
                 the packed three-pass baseline on identical content (0 for the baseline row); \
                 resident_bytes_ratio_vs_f32 = measured resident pool bytes vs the f32 row at \
                 the same operating point; steady_decode_allocs = heap allocations per armed \
                 window beyond the backend's 2-per-call output buffers, must be 0"
                    .into(),
            ),
        ),
        ("fast", Json::Bool(fast)),
    ]);
    let path = Path::new(fused_path());
    let mut rep = BenchReport::load_or_new(path);
    rep.set_section("fused", section);
    rep.save(path)?;
    println!("\nwrote fused section to {}", path.display());
    Ok(())
}
