//! H2O policy overhead: (a) the pure-policy microbench (accumulate + evict
//! on synthetic lanes — the coordinator-side cost AQUA-H2O adds per step),
//! and (b) end-to-end engine throughput with eviction on vs off, through
//! whichever execution backend is available (native by default).

use aqua_serve::bench::{black_box, Bencher};
use aqua_serve::coordinator::h2o::H2oPolicy;
use aqua_serve::coordinator::kvcache::LaneKv;
use aqua_serve::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    let bench = Bencher::default();
    let mut rng = Rng::new(3);

    println!("# H2O policy microbench (per decode step, one lane)\n");
    for cap in [512usize, 2048] {
        let acc: Vec<f32> = (0..cap).map(|_| rng.f32()).collect();
        for ratio in [1.0, 0.5, 0.25] {
            let policy = H2oPolicy::new(ratio, 16);
            let r = bench.run(&format!("S={cap} h2o_ratio={ratio}"), || {
                let mut lane = LaneKv::new(cap);
                lane.commit_write(cap * 3 / 4);
                lane.accumulate(&acc);
                let evicted = policy.apply(&mut lane);
                black_box(evicted);
            });
            println!("{}", r.report());
        }
        println!();
    }

    // End-to-end engine comparison (native backend unless pjrt artifacts
    // are available).
    use aqua_serve::aqua::policy::AquaConfig;
    use aqua_serve::coordinator::{Engine, EngineConfig, GenRequest};
    use aqua_serve::runtime::{corpus_or_synthetic, default_spec};
    use aqua_serve::tokenizer::ByteTokenizer;

    let spec = default_spec("llama-analog", 0)?;
    let corpus = corpus_or_synthetic(1 << 14);
    let tok = ByteTokenizer;
    let prompt_len = (spec.model_config().max_seq / 2).min(120);
    println!("# engine: 8 requests, h2o on/off ({} backend)\n", spec.name());
    {
        // warm (compiles executables on the pjrt path)
        let mut warm = Engine::with_spec(&spec, EngineConfig { batch: 4, ..Default::default() })?;
        let mut r = GenRequest::new(999, tok.encode_bytes(&corpus[..64]), 4);
        r.stop_token = None;
        warm.run_batch(vec![r])?;
    }
    for h2o in [1.0, 0.25] {
        let mut engine = Engine::with_spec(
            &spec,
            EngineConfig {
                batch: 4,
                aqua: AquaConfig { k_ratio: 0.75, h2o_ratio: h2o, ..Default::default() },
                ..Default::default()
            },
        )?;
        let reqs: Vec<GenRequest> = (0..8)
            .map(|i| {
                let start = (i as usize * 97) % (corpus.len() - prompt_len - 8);
                let mut r = GenRequest::new(
                    i + 1,
                    tok.encode_bytes(&corpus[start..start + prompt_len]),
                    24,
                );
                r.stop_token = None;
                r
            })
            .collect();
        let t0 = std::time::Instant::now();
        engine.run_batch(reqs)?;
        let s = engine.metrics.snapshot();
        println!("h2o_ratio={h2o}: {:.2}s wall, {} evictions, decode {:.1} tok/s",
                 t0.elapsed().as_secs_f64(), s.h2o_evictions, s.decode_tok_per_s);
    }
    Ok(())
}
