//! §Perf L1/L2 ablation: decode-step latency of the shipped
//! pallas-interpret-lowered HLO vs a pure-jnp-lowered variant of the same
//! decode function, both executed through the rust PJRT runtime
//! (xla_extension 0.5.1). Quantifies the interpret-mode lowering overhead
//! the old XLA cannot fuse away.
//!
//! Usage: cargo bench --features pjrt --bench hlo_variants -- [alt-hlo-path]
//! (defaults to the shipped decode_b4; pass /tmp/decode_jnp_b4.hlo.txt
//! produced by `python -m compile.aot` variants to compare.)

use std::sync::Arc;

use aqua_serve::bench::{black_box, Bencher};
use aqua_serve::runtime::{Artifacts, ModelRuntime};

fn main() -> anyhow::Result<()> {
    let Ok(arts) = Artifacts::load(aqua_serve::ARTIFACTS_DIR) else {
        println!("skipped: artifacts not built");
        return Ok(());
    };
    let mart = arts.model("llama-analog")?.clone();
    let b = 4usize;

    // Variant A: shipped (pallas-lowered) decode.
    let rt = Arc::new(ModelRuntime::load(&mart)?);
    let bench = Bencher { warmup: 3, iters: 30, ..Default::default() };
    let cfg = rt.cfg.clone();
    let (kc, vc) = rt.empty_cache(b)?;
    let tokens = vec![5i32; b];
    let pos = vec![64i32; b];
    let mut mask = vec![0.0f32; b * cfg.max_seq];
    for lane in 0..b {
        for s in 0..64 {
            mask[lane * cfg.max_seq + s] = 1.0;
        }
    }
    let keep = vec![1.0f32; cfg.d_head];
    let r = bench.run("decode_b4 pallas-lowered (shipped)", || {
        let out = rt
            .decode(b, &tokens, &pos, &kc, &vc, &mask, cfg.d_head as i32, &keep, true)
            .unwrap();
        black_box(out.logits.len());
    });
    println!("{}", r.report());

    // Variant B: alternate HLO file (e.g. jnp-lowered), same signature.
    let alt = std::env::args()
        .nth(1)
        .filter(|a| a.ends_with(".hlo.txt"))
        .unwrap_or_else(|| "/tmp/decode_jnp_b4.hlo.txt".to_string());
    if std::path::Path::new(&alt).exists() {
        let mut mart2 = mart.clone();
        mart2.hlo.insert("decode_b4".into(), alt.clone().into());
        let rt2 = Arc::new(ModelRuntime::load(&mart2)?);
        let r2 = bench.run(&format!("decode_b4 alt ({alt})"), || {
            let out = rt2
                .decode(b, &tokens, &pos, &kc, &vc, &mask, cfg.d_head as i32, &keep, true)
                .unwrap();
            black_box(out.logits.len());
        });
        println!("{}", r2.report());
        println!("\nratio alt/shipped = {:.2}×", r.mean_ns / r2.mean_ns.max(1.0));
    } else {
        println!("(no alternate HLO at {alt}; generate with python/compile variants)");
    }
    Ok(())
}
