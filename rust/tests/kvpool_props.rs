//! Paged KV-pool properties and parity (hermetic):
//!
//! * the page allocator never leaks or double-frees across random
//!   lease/free interleavings, and recycles fully after a lane drop;
//! * the engine-side page accounting (`LaneKv::resident_pages`) and the
//!   backend pool's gauges agree step for step under H2O eviction;
//! * `kv_keep = 1.0` through the pool is bit-identical to the PR 2 packed
//!   path (pinned by the masked-dense oracle and by page-size invariance);
//! * `kv_keep < 1.0` (truncated resident keys) stays within oracle
//!   tolerance, shrinks measured resident bytes to the acceptance bound,
//!   and the sharded backend remains bitwise identical to native;
//! * memory-pressure admission sheds deterministically with the distinct
//!   429 instead of panicking or over-allocating.
//!
//! CI runs this file under `--release` too (like the decode parity suite).

use std::sync::Arc;

use aqua_serve::aqua::policy::AquaConfig;
use aqua_serve::coordinator::h2o::H2oPolicy;
use aqua_serve::coordinator::kvcache::LaneKv;
use aqua_serve::coordinator::{Engine, EngineConfig, FinishReason, GenRequest};
use aqua_serve::kvpool::{budget_pages, KvPoolConfig, KvQuant, PagePool, PoolLayout, DEFAULT_PAGE_SLOTS};
use aqua_serve::model::config::ModelConfig;
use aqua_serve::registry::ModelRegistry;
use aqua_serve::runtime::{
    AquaKnobs, BackendSpec, ExecBackend, NativeBackend, NativeModel, ScoreMode, ShardedBackend,
};
use aqua_serve::server::http::Request;
use aqua_serve::server::route;
use aqua_serve::util::json::Json;
use aqua_serve::util::prng::Rng;
use aqua_serve::util::testkit::check;

fn tiny() -> ModelConfig {
    ModelConfig::tiny("kvpool-test")
}

// ---------------------------------------------------------------------------
// Allocator properties
// ---------------------------------------------------------------------------

#[test]
fn prop_allocator_never_leaks_or_double_frees() {
    check(
        "kvpool-lease-free-interleavings",
        120,
        |g| {
            let max_pages = 1 + g.rng.below(24);
            let ops: Vec<u64> = (0..g.rng.below(200)).map(|_| g.rng.next_u64()).collect();
            (max_pages, ops)
        },
        |(max_pages, ops)| {
            let layout = PoolLayout {
                page_slots: 4,
                key_dims: 2,
                head_dim: 4,
                layers: 1,
                kv_heads: 1,
                kv_quant: KvQuant::F32,
            };
            let mut pool = PagePool::new(layout, *max_pages);
            let mut model: Vec<u32> = vec![]; // leased ids, oracle
            for &op in ops {
                if op % 3 != 0 {
                    // lease: must succeed iff below capacity
                    match pool.lease() {
                        Ok(id) => {
                            if model.contains(&id) {
                                return Err(format!("page {id} leased twice"));
                            }
                            model.push(id);
                        }
                        Err(_) if model.len() == *max_pages => {}
                        Err(e) => return Err(format!("lease failed below capacity: {e}")),
                    }
                } else if !model.is_empty() {
                    // free a random leased page; a second free must error
                    let id = model.swap_remove((op / 3) as usize % model.len());
                    pool.free(id).map_err(|e| format!("valid free failed: {e}"))?;
                    if pool.free(id).is_ok() {
                        return Err(format!("double free of {id} accepted"));
                    }
                }
                let g = pool.gauges();
                if g.pages_in_use as usize != model.len() {
                    return Err(format!("in_use {} != model {}", g.pages_in_use, model.len()));
                }
                if g.pages_hwm as usize > *max_pages {
                    return Err(format!("hwm {} exceeds max {max_pages}", g.pages_hwm));
                }
                if g.resident_bytes != g.pages_in_use * g.page_bytes {
                    return Err("resident_bytes != pages_in_use * page_bytes".into());
                }
            }
            // full drain → full reuse without growth
            let hwm = pool.gauges().pages_hwm;
            for id in model.drain(..) {
                pool.free(id).map_err(|e| format!("drain free failed: {e}"))?;
            }
            if pool.pages_in_use() != 0 {
                return Err("drained pool still has leased pages".into());
            }
            for _ in 0..hwm {
                pool.lease().map_err(|e| format!("re-lease after drain failed: {e}"))?;
            }
            if pool.gauges().pages_hwm != hwm {
                return Err("re-leasing after a full drain grew the pool".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Engine-side vs pool-side page accounting
// ---------------------------------------------------------------------------

#[test]
fn prop_lanekv_page_accounting_matches_pool_gauges() {
    let cfg = tiny();
    let d = cfg.d_head;
    let model = Arc::new(NativeModel::new(cfg.clone(), 0x9A6E).unwrap());
    check(
        "lanekv-vs-pool-pages",
        12,
        |g| {
            let b = 1 + g.rng.below(3);
            let steps = 8 + g.rng.below(40);
            let ratio = 0.2 + g.rng.f64() * 0.8;
            (b, steps.min(cfg.max_seq - 1), ratio, g.rng.next_u64())
        },
        |(b, steps, ratio, seed)| {
            let (b, steps) = (*b, *steps);
            let h2o = H2oPolicy::new(*ratio, 3);
            let mut be = NativeBackend::from_model(model.clone());
            be.empty_cache(b).unwrap();
            let knobs = AquaKnobs { k_dims: d / 2, dim_keep: vec![1.0; d], use_projection: true };
            let mut rng = Rng::new(*seed);
            let mut lanes: Vec<LaneKv> = (0..b).map(|_| LaneKv::new(cfg.max_seq)).collect();
            for step in 0..steps {
                let tokens: Vec<i32> = (0..b).map(|_| 32 + rng.below(90) as i32).collect();
                let pos: Vec<i32> = lanes.iter().map(|l| l.len as i32).collect();
                let mut mask = vec![0.0f32; b * cfg.max_seq];
                for (lane, kv) in lanes.iter().enumerate() {
                    mask[lane * cfg.max_seq..(lane + 1) * cfg.max_seq]
                        .copy_from_slice(&kv.slot_mask);
                }
                let out = be.decode(b, &tokens, &pos, &mask, &knobs).unwrap();
                for lane in lanes.iter_mut() {
                    lane.commit_write(1);
                }
                // the engine-side page formula must equal the pool's gauges
                // (backend reclaimed with this call's mask, then leased the
                // write positions)
                let expect: usize =
                    lanes.iter().map(|l| l.resident_pages(DEFAULT_PAGE_SLOTS)).sum();
                if out.kv.pages_in_use as usize != expect {
                    return Err(format!(
                        "step {step}: pool has {} pages, LaneKv accounting says {expect}",
                        out.kv.pages_in_use
                    ));
                }
                if out.kv.resident_bytes != out.kv.pages_in_use * out.kv.page_bytes {
                    return Err("gauge identity violated".into());
                }
                // LaneKv::live_bytes (the engine-side byte view behind
                // Engine::kv_resident_bytes) must equal the pool's bytes
                let bps = (out.kv.page_bytes / out.kv.page_slots) as usize;
                let bytes: usize =
                    lanes.iter().map(|l| l.live_bytes(DEFAULT_PAGE_SLOTS, bps)).sum();
                if bytes as u64 != out.kv.resident_bytes {
                    return Err(format!(
                        "live_bytes {bytes} != pool resident {}",
                        out.kv.resident_bytes
                    ));
                }
                // evictions take effect on the next call's mask
                for lane in lanes.iter_mut() {
                    h2o.apply(lane);
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Parity: kv_keep = 1.0 pooled path vs oracle, across page sizes
// ---------------------------------------------------------------------------

/// Drive identical decode traffic (H2O evictions fed from the first
/// backend's attention mass) and return per-step logits per backend.
fn drive(
    backends: &mut [&mut dyn ExecBackend],
    b: usize,
    knobs: &AquaKnobs,
    steps: usize,
    h2o: &H2oPolicy,
    seed: u64,
) -> Vec<Vec<Vec<f32>>> {
    let cfg = backends[0].model_config().clone();
    let (s_cap, n_layers) = (cfg.max_seq, cfg.n_layers);
    let mut rng = Rng::new(seed);
    for be in backends.iter_mut() {
        be.empty_cache(b).unwrap();
    }
    let mut lanes: Vec<LaneKv> = (0..b).map(|_| LaneKv::new(s_cap)).collect();
    let mut outs: Vec<Vec<Vec<f32>>> = vec![vec![]; backends.len()];
    for _ in 0..steps {
        let tokens: Vec<i32> = (0..b).map(|_| 32 + rng.below(90) as i32).collect();
        let pos: Vec<i32> = lanes.iter().map(|l| l.len as i32).collect();
        let mut mask = vec![0.0f32; b * s_cap];
        for (lane, kv) in lanes.iter().enumerate() {
            mask[lane * s_cap..(lane + 1) * s_cap].copy_from_slice(&kv.slot_mask);
        }
        let mut step_outs = vec![];
        for be in backends.iter_mut() {
            step_outs.push(be.decode(b, &tokens, &pos, &mask, knobs).unwrap());
        }
        for lane in 0..b {
            lanes[lane].commit_write(1);
            let mut mass = vec![0.0f32; s_cap];
            for l in 0..n_layers {
                let base = (l * b + lane) * s_cap;
                for s in 0..s_cap {
                    mass[s] += step_outs[0].attn_acc[base + s];
                }
            }
            lanes[lane].accumulate(&mass);
            h2o.apply(&mut lanes[lane]);
        }
        for (i, o) in step_outs.into_iter().enumerate() {
            outs[i].push(o.logits);
        }
    }
    outs
}

#[test]
fn full_width_pool_is_bit_identical_across_page_sizes_and_to_oracle() {
    // kv_keep = 1.0: the paged packed path must equal the PR 2 dense
    // packed path bit for bit. The masked-dense oracle (dense shadow
    // cache, pre-pool write path) pins the old semantics; page-size
    // invariance (4 vs 16 vs one-page-per-lane 160) pins that paging
    // itself never changes a single bit.
    let cfg = tiny();
    let d = cfg.d_head;
    let model = Arc::new(NativeModel::new(cfg.clone(), 0xB17).unwrap());
    let h2o = H2oPolicy::new(0.4, 3);
    let knobs = AquaKnobs { k_dims: d / 2, dim_keep: vec![1.0; d], use_projection: true };

    let mut oracle = NativeBackend::from_model(model.clone());
    oracle.set_score_mode(ScoreMode::MaskedDense);
    let mut paged4 = NativeBackend::from_model(model.clone());
    paged4.configure_kv_pool(KvPoolConfig { page_slots: Some(4), ..Default::default() }).unwrap();
    let mut paged16 = NativeBackend::from_model(model.clone());
    let mut one_page = NativeBackend::from_model(model.clone());
    one_page
        .configure_kv_pool(KvPoolConfig { page_slots: Some(cfg.max_seq), ..Default::default() })
        .unwrap();

    let mut bes: Vec<&mut dyn ExecBackend> =
        vec![&mut oracle, &mut paged4, &mut paged16, &mut one_page];
    let outs = drive(&mut bes, 3, &knobs, 40, &h2o, 0xCAFE);
    for (name, i) in [("page_slots=4", 1usize), ("page_slots=16", 2), ("one-page", 3)] {
        assert_eq!(outs[0], outs[i], "{name} diverged from the masked-dense oracle");
    }
}

#[test]
fn truncated_keys_match_oracle_and_sharded_stays_bitwise() {
    // kv_keep = 0.5: the oracle writes the same dim_keep-zeroed keys at
    // full width, so outputs must still agree exactly; the sharded
    // backend (workers with their own sub-pools) must equal native bit
    // for bit at every thread count.
    let cfg = tiny();
    let d = cfg.d_head;
    let aqua = AquaConfig { s_ratio: 0.5, ..Default::default() };
    let knobs = AquaKnobs::from_config(&aqua, d);
    let kd = aqua.mem_dims(d);
    let pool_cfg = KvPoolConfig { key_dims: Some(kd), ..Default::default() };
    let model = Arc::new(NativeModel::new(cfg.clone(), 0x51AB).unwrap());
    let h2o = H2oPolicy::new(0.5, 4);

    let mut oracle = NativeBackend::from_model(model.clone());
    oracle.set_score_mode(ScoreMode::MaskedDense);
    let mut native = NativeBackend::from_model(model.clone());
    native.configure_kv_pool(pool_cfg).unwrap();
    let mut sharded2 = ShardedBackend::from_model(model.clone(), 2);
    sharded2.configure_kv_pool(pool_cfg).unwrap();
    let mut sharded4 = ShardedBackend::from_model(model.clone(), 4);
    sharded4.configure_kv_pool(pool_cfg).unwrap();

    let mut bes: Vec<&mut dyn ExecBackend> =
        vec![&mut oracle, &mut native, &mut sharded2, &mut sharded4];
    let outs = drive(&mut bes, 6, &knobs, 30, &h2o, 0xD1CE);
    assert_eq!(outs[0], outs[1], "truncated native pool diverged from the oracle");
    assert_eq!(outs[1], outs[2], "sharded(2) diverged from native through the pool");
    assert_eq!(outs[1], outs[3], "sharded(4) diverged from native through the pool");
}

// ---------------------------------------------------------------------------
// The memory claim, measured end to end
// ---------------------------------------------------------------------------

/// Fixed-length workload (no stop token) so page usage is identical
/// across operating points.
fn fixed_workload(n: usize, prompt_len: usize, gen: usize) -> Vec<GenRequest> {
    (0..n).map(|i| GenRequest::new(i as u64 + 1, vec![40 + i as i32; prompt_len], gen)).collect()
}

#[test]
fn resident_bytes_beat_the_dense_baseline_at_equal_load() {
    let cfg = tiny();
    let (d, nkv, nl, s_cap) = (cfg.d_head, cfg.n_kv_heads, cfg.n_layers, cfg.max_seq);
    let batch = 4;
    // what every lane preallocated before the pool (full-width K + V)
    let dense_alloc = (batch * nl * nkv * s_cap * 2 * d * 4) as u64;
    let run = |s_ratio: f64| -> u64 {
        let spec = BackendSpec::native(cfg.clone(), 9).unwrap();
        let aqua = AquaConfig { s_ratio, ..Default::default() };
        let mut engine =
            Engine::with_spec(&spec, EngineConfig { batch, aqua, ..Default::default() }).unwrap();
        engine.run_batch(fixed_workload(8, 20, 24)).unwrap();
        engine.metrics.snapshot().kv_resident_peak_bytes
    };
    let full = run(0.0);
    let half = run(0.5);
    // acceptance: kv_keep = 0.5 resident ≤ ~60% of the dense baseline
    assert!(
        (half as f64) <= 0.6 * dense_alloc as f64,
        "kv_keep=0.5 peak {half} B vs dense {dense_alloc} B exceeds the 0.6 bound"
    );
    // identical page usage (fixed lengths) → bytes scale exactly by the
    // truncated layout: (d/2 + d) / 2d = 0.75
    assert_eq!(4 * half, 3 * full, "expected exact 0.75x from key truncation");
    // paging alone already beats dense preallocation at this load
    assert!(full < dense_alloc);
}

#[test]
fn memory_sheds_have_distinct_http_status_and_counters() {
    let reg = ModelRegistry::new("no-such-dir");
    // tiny model: 4096 B/page at full width; 0.02 MiB → 5 pages
    let spec_json = r#"{"name": "m", "backend": "native", "batch": 2, "kv_budget_mb": 0.02}"#;
    let post = |path: &str, body: &str| Request {
        method: "POST".to_string(),
        path: path.to_string(),
        headers: vec![],
        body: body.to_string(),
    };
    let get = |path: &str| Request {
        method: "GET".to_string(),
        path: path.to_string(),
        headers: vec![],
        body: String::new(),
    };
    assert_eq!(route(&post("/models", spec_json), &reg).status, 200);

    // worst case 6+120 slots = 8 pages > the whole 5-page budget: a
    // permanent 413 telling the client retrying cannot succeed — not the
    // retryable capacity/pressure 429s
    let big = r#"{"prompt": "hello!", "max_new_tokens": 120, "stop_newline": false}"#;
    let resp = route(&post("/generate", big), &reg);
    assert_eq!(resp.status, 413);
    assert!(resp.body.contains("cannot succeed"), "413 body: {}", resp.body);
    assert!(!resp.body.contains("in-flight"), "wrong shed reason: {}", resp.body);

    // a request that fits completes, and /metrics splits the counters
    let small = r#"{"prompt": "hi", "max_new_tokens": 8, "stop_newline": false}"#;
    assert_eq!(route(&post("/generate", small), &reg).status, 200);
    let metrics = route(&get("/metrics"), &reg);
    let doc = Json::parse(&metrics.body).unwrap();
    let m = doc.get("models").get("m");
    assert_eq!(m.get("shed_memory_total").as_i64(), Some(1));
    assert_eq!(m.get("shed_capacity_total").as_i64(), Some(0));
    assert_eq!(m.get("shed_total").as_i64(), Some(1));
    assert_eq!(m.get("kv_pages_total").as_i64(), Some(5));
    assert_eq!(m.get("kv_reserved_pages").as_i64(), Some(0), "reservation released");
    assert!(m.get("kv_resident_bytes").as_f64().is_some());
    reg.shutdown_all().unwrap();
}

#[test]
fn engine_budget_defers_instead_of_stalling_for_all_backends() {
    // Memory-aware admission is the *global* budget bound: with 6 pages
    // (full width: 4096 B each) and requests needing 3 pages apiece, only
    // two lanes hold requests at a time — the rest defer at admission and
    // everything completes with zero pool stalls. Holds for the sharded
    // backend too (per-worker caps are just a backstop, so threads must
    // not multiply the budget).
    let cfg = tiny();
    let budget_mb = 6.0 * 4096.0 / (1u64 << 20) as f64;
    let specs = [
        BackendSpec::native(cfg.clone(), 3).unwrap(),
        BackendSpec::sharded(cfg.clone(), 3, 2).unwrap(),
    ];
    for spec in specs {
        let mut engine = Engine::with_spec(
            &spec,
            EngineConfig { batch: 4, kv_budget_mb: budget_mb, ..Default::default() },
        )
        .unwrap();
        let results = engine.run_batch(fixed_workload(6, 20, 24)).unwrap();
        assert_eq!(results.len(), 6);
        assert!(results.iter().all(|r| r.tokens.len() == 24), "deferred requests must finish");
        assert_eq!(engine.kv_resident_bytes(), 0, "all lanes retired, nothing resident");
        let snap = engine.metrics.snapshot();
        assert_eq!(snap.kv_alloc_stalls, 0, "{}: budget must never stall the pool", spec.name());
        assert!(
            snap.kv_resident_peak_bytes <= 6 * 4096,
            "{}: resident {} B exceeds the 6-page budget",
            spec.name(),
            snap.kv_resident_peak_bytes
        );
        // a request whose worst case exceeds the whole budget resolves
        // deterministically — with the budget-specific reason, not a
        // misattributed prompt-length reject — instead of hanging the
        // queue (100 + 40 slots fits max_seq, only the budget is short)
        let too_big = GenRequest::new(99, vec![65; 100], 40);
        let res = engine.run_batch(vec![too_big]).unwrap().remove(0);
        assert_eq!(res.finish, FinishReason::OverKvBudget);
        assert!(res.tokens.is_empty());
    }
}

#[test]
fn budget_pages_and_engine_pool_agree() {
    // the admission gate and the engine's pool cap must be the same
    // number — a request that passes the gate can never stall the pool
    let cfg = tiny();
    let aqua = AquaConfig { s_ratio: 0.5, ..Default::default() };
    let layout = PoolLayout {
        page_slots: DEFAULT_PAGE_SLOTS,
        key_dims: aqua.mem_dims(cfg.d_head),
        head_dim: cfg.d_head,
        layers: cfg.n_layers,
        kv_heads: cfg.n_kv_heads,
        kv_quant: KvQuant::F32,
    };
    let pages = budget_pages(0.05, &layout).unwrap();
    let spec = BackendSpec::native(cfg.clone(), 1).unwrap();
    let mut engine = Engine::with_spec(
        &spec,
        EngineConfig { batch: 1, aqua, kv_budget_mb: 0.05, ..Default::default() },
    )
    .unwrap();
    // a workload sized exactly to the budget runs without a single stall
    let slots = pages * DEFAULT_PAGE_SLOTS;
    let gen = 8;
    let prompt = slots.saturating_sub(gen).min(cfg.max_seq - gen);
    engine.run_batch(vec![GenRequest::new(1, vec![65; prompt], gen)]).unwrap();
    let snap = engine.metrics.snapshot();
    assert_eq!(snap.kv_alloc_stalls, 0, "budget-sized load must never stall the pool");
    assert!(snap.kv_resident_peak_bytes > 0);
}

// ---------------------------------------------------------------------------
// Prefix sharing & copy-on-write (PR 5)
// ---------------------------------------------------------------------------

/// Attach whatever the prefix cache resolves for each lane, then feed the
/// rest of every prompt through chunked prefill calls. Returns the tokens
/// attached per lane (0 = served cold). Lanes with empty prompts are left
/// untouched, so a donor can be fed alone in a multi-lane batch —
/// `base_mask` must carry the true slot mask of any lane already holding
/// context (the backend's reclaim trusts the mask, like the engine's
/// `flat_mask` contract).
fn feed_prompts(
    be: &mut dyn ExecBackend,
    prompts: &[Vec<i32>],
    base_mask: &[f32],
    knobs: &AquaKnobs,
) -> Vec<usize> {
    let b = prompts.len();
    let s_cap = be.model_config().max_seq;
    let chunk = be.prefill_chunk();
    let mut mask = base_mask.to_vec();
    assert_eq!(mask.len(), b * s_cap, "base mask shape");
    let mut fed: Vec<usize> = (0..b)
        .map(|lane| be.attach_prefix(lane, &prompts[lane], knobs).unwrap().tokens)
        .collect();
    let attached = fed.clone();
    for lane in 0..b {
        for s in 0..fed[lane] {
            mask[lane * s_cap + s] = 1.0;
        }
    }
    loop {
        let mut tokens = vec![-1i32; b * chunk];
        let mut pos0 = vec![0i32; b];
        let mut n_now = vec![0usize; b];
        let mut any = false;
        for lane in 0..b {
            pos0[lane] = fed[lane] as i32;
            let rem = prompts[lane].len() - fed[lane];
            if rem > 0 {
                let n = rem.min(chunk);
                tokens[lane * chunk..lane * chunk + n]
                    .copy_from_slice(&prompts[lane][fed[lane]..fed[lane] + n]);
                n_now[lane] = n;
                any = true;
            }
        }
        if !any {
            break;
        }
        be.prefill(b, &tokens, &pos0, &mask, knobs).unwrap();
        for lane in 0..b {
            for s in fed[lane]..fed[lane] + n_now[lane] {
                mask[lane * s_cap + s] = 1.0;
            }
            fed[lane] += n_now[lane];
        }
    }
    attached
}

#[test]
fn shared_prefix_is_bit_identical_under_h2o_and_across_backends() {
    // One donor prefill, many lanes: warm backends adopt the registered
    // page chain while the cold backend recomputes everything — and every
    // decode step must stay *bit-identical* across cold native, warm
    // native, and warm sharded at 2 and 4 threads, under an H2O eviction
    // interleaving driven by the cold backend's attention mass (identical
    // masks for all, so sharing is the only variable).
    let cfg = tiny();
    let d = cfg.d_head;
    let model = Arc::new(NativeModel::new(cfg.clone(), 0x5AFE).unwrap());
    let knobs = AquaKnobs { k_dims: d / 2, dim_keep: vec![1.0; d], use_projection: true };
    let pool_on = KvPoolConfig { prefix_cache: true, ..Default::default() };
    let b = 4;
    let mut rng = Rng::new(0xBEE);
    let shared: Vec<i32> =
        (0..2 * DEFAULT_PAGE_SLOTS).map(|_| 32 + rng.below(90) as i32).collect();
    let prompts: Vec<Vec<i32>> = (0..b)
        .map(|lane| {
            let mut p = shared.clone();
            for _ in 0..8 {
                p.push(40 + lane as i32 + rng.below(50) as i32);
            }
            p
        })
        .collect();

    let mut cold = NativeBackend::from_model(model.clone());
    let mut warm = NativeBackend::from_model(model.clone());
    warm.configure_kv_pool(pool_on).unwrap();
    let mut warm2 = ShardedBackend::from_model(model.clone(), 2);
    warm2.configure_kv_pool(pool_on).unwrap();
    let mut warm4 = ShardedBackend::from_model(model.clone(), 4);
    warm4.configure_kv_pool(pool_on).unwrap();
    let mut bes: Vec<&mut dyn ExecBackend> = vec![&mut cold, &mut warm, &mut warm2, &mut warm4];

    for be in bes.iter_mut() {
        be.empty_cache(b).unwrap();
        // donor pass on every lane (so each sharded worker caches the
        // chain), then retire: warm pools now hold the prefix cached
        let donor: Vec<Vec<i32>> = (0..b).map(|_| shared.clone()).collect();
        feed_prompts(&mut **be, &donor, &vec![0.0; b * cfg.max_seq], &knobs);
        for lane in 0..b {
            be.retire_lane(lane);
        }
        assert_eq!(be.kv_gauges().pages_in_use, 0, "donor retire must drain");
    }

    // main wave: warm backends attach the full shared prefix, cold none
    let attached: Vec<Vec<usize>> = bes
        .iter_mut()
        .map(|be| feed_prompts(&mut **be, &prompts, &vec![0.0; b * cfg.max_seq], &knobs))
        .collect();
    assert!(attached[0].iter().all(|&a| a == 0), "prefix-cache-off backend must serve cold");
    for (i, name) in [(1usize, "native"), (2, "sharded2"), (3, "sharded4")] {
        assert!(
            attached[i].iter().all(|&a| a == shared.len()),
            "{name} should attach the whole shared prefix, got {:?}",
            attached[i]
        );
    }
    let g = bes[1].kv_gauges();
    assert!(g.shared_pages >= 1, "warm native should hold shared pages, gauges {g:?}");
    assert!(
        g.pages_in_use < attached[0].len() as u64 * (shared.len() / DEFAULT_PAGE_SLOTS + 1) as u64,
        "sharing should dedup resident prompt pages"
    );

    // decode under H2O: masks evolve from the cold backend's mass, applied
    // to every backend identically
    let h2o = H2oPolicy::new(0.5, 3);
    let (s_cap, n_layers) = (cfg.max_seq, cfg.n_layers);
    let mut lanes: Vec<LaneKv> = (0..b)
        .map(|lane| {
            let mut l = LaneKv::new(s_cap);
            l.commit_write(prompts[lane].len());
            l
        })
        .collect();
    let mut rng = Rng::new(0xD0D0);
    for step in 0..20 {
        let tokens: Vec<i32> = (0..b).map(|_| 32 + rng.below(90) as i32).collect();
        let pos: Vec<i32> = lanes.iter().map(|l| l.len as i32).collect();
        let mut mask = vec![0.0f32; b * s_cap];
        for (lane, kv) in lanes.iter().enumerate() {
            mask[lane * s_cap..(lane + 1) * s_cap].copy_from_slice(&kv.slot_mask);
        }
        let mut outs = vec![];
        for be in bes.iter_mut() {
            outs.push(be.decode(b, &tokens, &pos, &mask, &knobs).unwrap());
        }
        for (i, name) in [(1usize, "native"), (2, "sharded2"), (3, "sharded4")] {
            assert_eq!(
                outs[0].logits, outs[i].logits,
                "warm {name} diverged from cold at step {step}"
            );
        }
        for lane in 0..b {
            lanes[lane].commit_write(1);
            let mut mass = vec![0.0f32; s_cap];
            for l in 0..n_layers {
                let base = (l * b + lane) * s_cap;
                for s in 0..s_cap {
                    mass[s] += outs[0].attn_acc[base + s];
                }
            }
            lanes[lane].accumulate(&mass);
            h2o.apply(&mut lanes[lane]);
        }
    }

    // full retirement returns every page (refcounts drained exactly once)
    for be in bes.iter_mut() {
        for lane in 0..b {
            be.retire_lane(lane);
        }
        let g = be.kv_gauges();
        assert_eq!(g.pages_in_use, 0, "{}: retire must drain the pool", be.name());
        assert_eq!(g.shared_pages, 0);
    }
}

#[test]
fn cow_write_preserves_the_donor_lane() {
    // A write landing inside a shared page must copy first: the sharer
    // diverges on its own copy while the donor's context — and therefore
    // its logits — stay bit-identical to a run that never shared.
    let cfg = tiny();
    let d = cfg.d_head;
    let model = Arc::new(NativeModel::new(cfg.clone(), 0xC0DE).unwrap());
    let knobs = AquaKnobs { k_dims: d, dim_keep: vec![1.0; d], use_projection: true };
    let pool_on = KvPoolConfig { prefix_cache: true, ..Default::default() };
    let s_cap = cfg.max_seq;
    let mut rng = Rng::new(7);
    let prompt: Vec<i32> =
        (0..DEFAULT_PAGE_SLOTS + 4).map(|_| 32 + rng.below(90) as i32).collect();

    let run_donor_decode = |be: &mut NativeBackend| -> Vec<f32> {
        let mut mask = vec![0.0f32; 2 * s_cap];
        for s in 0..prompt.len() {
            mask[s] = 1.0;
        }
        let out = be.decode(2, &[70, -1], &[prompt.len() as i32, 0], &mask, &knobs).unwrap();
        out.logits[..cfg.vocab].to_vec()
    };

    // control: donor alone, never shared
    let zeros = vec![0.0f32; 2 * s_cap];
    let mut control = NativeBackend::from_model(model.clone());
    control.configure_kv_pool(pool_on).unwrap();
    control.empty_cache(2).unwrap();
    feed_prompts(&mut control, &[prompt.clone(), vec![]], &zeros, &knobs);
    let want = run_donor_decode(&mut control);

    // shared: lane 1 adopts lane 0's live page, then writes into it
    let mut be = NativeBackend::from_model(model);
    be.configure_kv_pool(pool_on).unwrap();
    be.empty_cache(2).unwrap();
    feed_prompts(&mut be, &[prompt.clone(), vec![]], &zeros, &knobs);
    // lane 0 stays live: its slots must be masked attendable while lane 1
    // is fed, or the backend's mask-driven reclaim would free its pages
    let mut donor_mask = vec![0.0f32; 2 * s_cap];
    for s in 0..prompt.len() {
        donor_mask[s] = 1.0;
    }
    let attached = feed_prompts(&mut be, &[vec![], prompt.clone()], &donor_mask, &knobs);
    assert_eq!(attached[1], DEFAULT_PAGE_SLOTS, "lane 1 should adopt the donor's full page");
    assert_eq!(ExecBackend::kv_gauges(&mut be).shared_pages, 1);

    // lane 1 overwrites a position *inside* the shared page — the engine
    // never does this (tails start at page boundaries), but the backend
    // contract must survive it: copy-on-write, donor untouched. Both
    // lanes' true masks ride along (an all-dead mask row would be an
    // eviction order for the donor's pages).
    let mut mask = vec![0.0f32; 2 * s_cap];
    for s in 0..prompt.len() {
        mask[s] = 1.0;
        mask[s + s_cap] = 1.0;
    }
    be.decode(2, &[-1, 71], &[0, 5], &mask, &knobs).unwrap();
    let g = ExecBackend::kv_gauges(&mut be);
    assert_eq!(g.cow_copies, 1, "the shared-page write must copy");
    assert_eq!(g.shared_pages, 0, "after cow the page is no longer shared");

    let got = run_donor_decode(&mut be);
    assert_eq!(want, got, "sharer's write leaked into the donor's context");
}

#[test]
fn knob_changes_never_alias_prefix_chains() {
    // the chain hash is seeded with the cache-shaping knobs: content
    // written under one dim_keep/projection setting must never be
    // attached under another
    let cfg = tiny();
    let d = cfg.d_head;
    let model = Arc::new(NativeModel::new(cfg, 0xF00D).unwrap());
    let proj = AquaKnobs { k_dims: d, dim_keep: vec![1.0; d], use_projection: true };
    let ident = AquaKnobs { k_dims: d, dim_keep: vec![1.0; d], use_projection: false };
    let prompt: Vec<i32> = (0..DEFAULT_PAGE_SLOTS + 2).map(|i| 40 + (i as i32 % 50)).collect();

    let mut be = NativeBackend::from_model(model);
    be.configure_kv_pool(KvPoolConfig { prefix_cache: true, ..Default::default() }).unwrap();
    be.empty_cache(1).unwrap();
    let zeros = vec![0.0f32; be.model_config().max_seq];
    feed_prompts(&mut be, &[prompt.clone()], &zeros, &proj);
    be.retire_lane(0);
    assert_eq!(be.attach_prefix(0, &prompt, &ident).unwrap().tokens, 0, "knob mismatch");
    assert_eq!(be.attach_prefix(0, &prompt, &proj).unwrap().tokens, DEFAULT_PAGE_SLOTS);
    be.retire_lane(0);
}

#[test]
fn prefix_churn_never_underflows_and_drains_to_zero() {
    // admit → share → diverge → evict → retire across >= 120 requests on
    // random lanes: refcounts never underflow (the pool errors loudly and
    // the step would fail), gauges stay coherent, and a full drain leaves
    // zero pages in use with every page reusable.
    let cfg = tiny();
    let d = cfg.d_head;
    let model = Arc::new(NativeModel::new(cfg.clone(), 0x17).unwrap());
    let knobs = AquaKnobs { k_dims: d / 2, dim_keep: vec![1.0; d], use_projection: true };
    let mut be = NativeBackend::from_model(model);
    be.configure_kv_pool(KvPoolConfig { prefix_cache: true, ..Default::default() }).unwrap();
    let b = 4;
    be.empty_cache(b).unwrap();
    let s_cap = cfg.max_seq;
    let mut rng = Rng::new(0xCAB);
    let families: Vec<Vec<i32>> = (0..3)
        .map(|f: usize| {
            (0..2 * DEFAULT_PAGE_SLOTS).map(|i| 33 + ((f * 37 + i * 11) % 80) as i32).collect()
        })
        .collect();
    let mut lanes: Vec<Option<LaneKv>> = (0..b).map(|_| None).collect();
    let mut served = 0usize;
    let mut rounds = 0usize;
    while served < 120 {
        rounds += 1;
        assert!(rounds < 4000, "churn made no progress");
        for lane in 0..b {
            if lanes[lane].is_some() && rng.below(3) == 0 {
                be.retire_lane(lane);
                lanes[lane] = None;
            }
            if lanes[lane].is_none() {
                let mut prompt = families[rng.below(families.len())].clone();
                for _ in 0..1 + rng.below(8) {
                    prompt.push(32 + rng.below(90) as i32);
                }
                let mut prompts: Vec<Vec<i32>> = (0..b).map(|_| vec![]).collect();
                prompts[lane] = prompt.clone();
                // live occupants keep their true masks during the feed
                let mut base = vec![0.0f32; b * s_cap];
                for (l, kv) in lanes.iter().enumerate() {
                    if let Some(kv) = kv {
                        base[l * s_cap..(l + 1) * s_cap].copy_from_slice(&kv.slot_mask);
                    }
                }
                feed_prompts(&mut be, &prompts, &base, &knobs);
                let mut kv = LaneKv::new(s_cap);
                kv.commit_write(prompt.len());
                lanes[lane] = Some(kv);
                served += 1;
            }
        }
        // a couple of divergent decode steps with random evictions
        for _ in 0..2 {
            let mut tokens = vec![-1i32; b];
            let mut pos = vec![0i32; b];
            let mut mask = vec![0.0f32; b * s_cap];
            for lane in 0..b {
                if let Some(kv) = &lanes[lane] {
                    if kv.len < s_cap {
                        tokens[lane] = 32 + rng.below(90) as i32;
                        pos[lane] = kv.len as i32;
                    }
                    mask[lane * s_cap..(lane + 1) * s_cap].copy_from_slice(&kv.slot_mask);
                }
            }
            let out = be.decode(b, &tokens, &pos, &mask, &knobs).unwrap();
            assert_eq!(
                out.kv.resident_bytes,
                out.kv.pages_in_use * out.kv.page_bytes,
                "gauge identity violated under churn"
            );
            for lane in 0..b {
                if tokens[lane] >= 0 {
                    let kv = lanes[lane].as_mut().unwrap();
                    kv.commit_write(1);
                    // random eviction (the mask is the engine's authority;
                    // the backend reclaims drained pages, shared or not)
                    if kv.len > 2 && rng.below(2) == 0 {
                        let slot = rng.below(kv.len - 1);
                        kv.evict(slot);
                    }
                }
            }
        }
    }
    for lane in 0..b {
        be.retire_lane(lane);
    }
    let g = be.kv_gauges();
    assert_eq!(g.pages_in_use, 0, "churn must drain to zero pages in use");
    assert_eq!(g.shared_pages, 0);
    assert_eq!(g.leases, g.frees, "every lease must have been returned exactly once");
    assert_eq!(g.alloc_stalls, 0);
}

#[test]
fn engine_prefix_cache_is_invisible_and_reconciles() {
    // Acceptance: with the prefix cache enabled, greedy outputs are
    // bit-identical to the sharing-disabled path on native; sharded stays
    // equal to native; the hit counters reconcile with the prefill work
    // they displaced; resident pages shrink.
    let cfg = tiny();
    let shared: Vec<i32> = (0..40).map(|i| 40 + (i % 60) as i32).collect();
    let mk_reqs = || -> Vec<GenRequest> {
        (0..8)
            .map(|i: usize| {
                let mut p = shared.clone();
                p.extend((0..6).map(|j| 35 + ((i * 7 + j) % 70) as i32));
                GenRequest::new(i as u64 + 1, p, 12)
            })
            .collect()
    };
    let run = |spec: &BackendSpec, on: bool| {
        let ecfg = EngineConfig { batch: 2, prefix_cache: on, ..Default::default() };
        let mut engine = Engine::with_spec(spec, ecfg).unwrap();
        // donor first (alone), so with the cache on *every* later wave
        // attaches and the peak-resident comparison isn't dominated by a
        // cold first batch
        engine.run_batch(vec![GenRequest::new(99, shared.clone(), 4)]).unwrap();
        let results = engine.run_batch(mk_reqs()).unwrap();
        let snap = engine.metrics.snapshot();
        assert_eq!(engine.kv_gauges().pages_in_use, 0, "drained engine holds no pages");
        (results.into_iter().map(|r| r.tokens).collect::<Vec<_>>(), snap)
    };
    let native = BackendSpec::native(cfg.clone(), 9).unwrap();
    let (cold_tokens, cold_snap) = run(&native, false);
    let (warm_tokens, warm_snap) = run(&native, true);
    assert_eq!(cold_tokens, warm_tokens, "sharing must be invisible to greedy outputs");
    assert!(warm_snap.prefix_hit_tokens > 0, "the shared prefix must actually hit");
    assert_eq!(cold_snap.prefix_hit_tokens, 0);
    // skipped prefill work reconciles exactly: computed + hits == total
    assert_eq!(warm_snap.prompt_tokens + warm_snap.prefix_hit_tokens, cold_snap.prompt_tokens);
    assert!(
        warm_snap.kv_resident_peak_bytes < cold_snap.kv_resident_peak_bytes,
        "sharing should shrink peak resident bytes ({} vs {})",
        warm_snap.kv_resident_peak_bytes,
        cold_snap.kv_resident_peak_bytes
    );
    // sharded engine with the cache on produces the same bytes
    let sharded = BackendSpec::sharded(cfg, 9, 2).unwrap();
    let (sh_tokens, sh_snap) = run(&sharded, true);
    assert_eq!(sh_tokens, warm_tokens, "sharded + prefix cache diverged from native");
    assert!(sh_snap.prefix_hit_tokens > 0, "per-worker caches should still hit");
}

#[test]
fn share_aware_admission_overlaps_lanes_within_budget() {
    // Satellite: the memory-aware deferral credits pages the prefix index
    // provably shares with a live holder, so two 5-page requests overlap
    // inside an 8-page budget (the old worst-case sum, 10, would have
    // serialized them). Resurrected cached pages stay fully charged.
    let cfg = tiny();
    let budget_mb = 8.0 * 4096.0 / (1u64 << 20) as f64;
    let shared: Vec<i32> = (0..64).map(|i| 40 + (i % 60) as i32).collect();
    let reqs: Vec<GenRequest> =
        (0..2).map(|i| GenRequest::new(i + 1, shared.clone(), 16)).collect();
    let spec = BackendSpec::native(cfg, 3).unwrap();
    let ecfg = EngineConfig {
        batch: 2,
        kv_budget_mb: budget_mb,
        prefix_cache: true,
        ..Default::default()
    };
    let mut engine = Engine::with_spec(&spec, ecfg).unwrap();
    let results = engine.run_batch(reqs).unwrap();
    assert!(results.iter().all(|r| r.tokens.len() == 16), "both requests must finish");
    let snap = engine.metrics.snapshot();
    assert_eq!(snap.kv_alloc_stalls, 0, "the credited deferral must never stall the pool");
    assert!(snap.prefix_hit_tokens >= 32, "the second lane should attach shared pages");
    assert!(
        snap.kv_resident_peak_bytes >= 6 * 4096,
        "the lanes should overlap (peak {} B says they serialized)",
        snap.kv_resident_peak_bytes
    );
    assert!(snap.kv_resident_peak_bytes <= 8 * 4096, "budget exceeded");
    assert_eq!(engine.kv_gauges().pages_in_use, 0);
}

#[test]
fn engine_prefix_churn_drains_and_reuses_every_page() {
    // >= 110 requests with mixed shared-prefix depths through a prefix-on
    // engine: after the drain, zero pages in use, lease/free parity, and
    // a follow-up full-capacity wave proves every page is reusable.
    let cfg = tiny();
    let spec = BackendSpec::native(cfg, 21).unwrap();
    let ecfg = EngineConfig { batch: 4, prefix_cache: true, ..Default::default() };
    let mut engine = Engine::with_spec(&spec, ecfg).unwrap();
    let shared: Vec<i32> = (0..48).map(|i| 40 + (i % 60) as i32).collect();
    let reqs: Vec<GenRequest> = (0..110)
        .map(|i: usize| {
            let mut p = shared[..16 + 16 * (i % 3)].to_vec();
            p.extend((0..4).map(|j| 33 + ((i * 13 + j) % 77) as i32));
            GenRequest::new(i as u64 + 1, p, 6)
        })
        .collect();
    let results = engine.run_batch(reqs).unwrap();
    assert_eq!(results.len(), 110);
    let snap = engine.metrics.snapshot();
    assert!(snap.prefix_hit_tokens > 0, "the families' prefixes should hit");
    let g = engine.kv_gauges();
    assert_eq!(g.pages_in_use, 0, "after churn every page must be back in the pool");
    assert_eq!(g.shared_pages, 0);
    assert_eq!(g.leases, g.frees, "refcount audit: every lease freed exactly once");
    assert_eq!(g.alloc_stalls, 0);

    // every page is reusable: a full-capacity wave recycles the cached
    // chains without a single stall
    let big: Vec<GenRequest> =
        (0..4).map(|i| GenRequest::new(500 + i, vec![65 + i as i32; 120], 8)).collect();
    engine.run_batch(big).unwrap();
    let g2 = engine.kv_gauges();
    assert_eq!(g2.pages_in_use, 0);
    assert_eq!(g2.alloc_stalls, 0, "recycled cache pages must lease cleanly");
}
