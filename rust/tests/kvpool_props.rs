//! Paged KV-pool properties and parity (hermetic):
//!
//! * the page allocator never leaks or double-frees across random
//!   lease/free interleavings, and recycles fully after a lane drop;
//! * the engine-side page accounting (`LaneKv::resident_pages`) and the
//!   backend pool's gauges agree step for step under H2O eviction;
//! * `kv_keep = 1.0` through the pool is bit-identical to the PR 2 packed
//!   path (pinned by the masked-dense oracle and by page-size invariance);
//! * `kv_keep < 1.0` (truncated resident keys) stays within oracle
//!   tolerance, shrinks measured resident bytes to the acceptance bound,
//!   and the sharded backend remains bitwise identical to native;
//! * memory-pressure admission sheds deterministically with the distinct
//!   429 instead of panicking or over-allocating.
//!
//! CI runs this file under `--release` too (like the decode parity suite).

use std::sync::Arc;

use aqua_serve::aqua::policy::AquaConfig;
use aqua_serve::coordinator::h2o::H2oPolicy;
use aqua_serve::coordinator::kvcache::LaneKv;
use aqua_serve::coordinator::{Engine, EngineConfig, FinishReason, GenRequest};
use aqua_serve::kvpool::{budget_pages, KvPoolConfig, PagePool, PoolLayout, DEFAULT_PAGE_SLOTS};
use aqua_serve::model::config::ModelConfig;
use aqua_serve::registry::ModelRegistry;
use aqua_serve::runtime::{
    AquaKnobs, BackendSpec, ExecBackend, NativeBackend, NativeModel, ScoreMode, ShardedBackend,
};
use aqua_serve::server::http::Request;
use aqua_serve::server::route;
use aqua_serve::util::json::Json;
use aqua_serve::util::prng::Rng;
use aqua_serve::util::testkit::check;

fn tiny() -> ModelConfig {
    ModelConfig::tiny("kvpool-test")
}

// ---------------------------------------------------------------------------
// Allocator properties
// ---------------------------------------------------------------------------

#[test]
fn prop_allocator_never_leaks_or_double_frees() {
    check(
        "kvpool-lease-free-interleavings",
        120,
        |g| {
            let max_pages = 1 + g.rng.below(24);
            let ops: Vec<u64> = (0..g.rng.below(200)).map(|_| g.rng.next_u64()).collect();
            (max_pages, ops)
        },
        |(max_pages, ops)| {
            let layout =
                PoolLayout { page_slots: 4, key_dims: 2, head_dim: 4, layers: 1, kv_heads: 1 };
            let mut pool = PagePool::new(layout, *max_pages);
            let mut model: Vec<u32> = vec![]; // leased ids, oracle
            for &op in ops {
                if op % 3 != 0 {
                    // lease: must succeed iff below capacity
                    match pool.lease() {
                        Ok(id) => {
                            if model.contains(&id) {
                                return Err(format!("page {id} leased twice"));
                            }
                            model.push(id);
                        }
                        Err(_) if model.len() == *max_pages => {}
                        Err(e) => return Err(format!("lease failed below capacity: {e}")),
                    }
                } else if !model.is_empty() {
                    // free a random leased page; a second free must error
                    let id = model.swap_remove((op / 3) as usize % model.len());
                    pool.free(id).map_err(|e| format!("valid free failed: {e}"))?;
                    if pool.free(id).is_ok() {
                        return Err(format!("double free of {id} accepted"));
                    }
                }
                let g = pool.gauges();
                if g.pages_in_use as usize != model.len() {
                    return Err(format!("in_use {} != model {}", g.pages_in_use, model.len()));
                }
                if g.pages_hwm as usize > *max_pages {
                    return Err(format!("hwm {} exceeds max {max_pages}", g.pages_hwm));
                }
                if g.resident_bytes != g.pages_in_use * g.page_bytes {
                    return Err("resident_bytes != pages_in_use * page_bytes".into());
                }
            }
            // full drain → full reuse without growth
            let hwm = pool.gauges().pages_hwm;
            for id in model.drain(..) {
                pool.free(id).map_err(|e| format!("drain free failed: {e}"))?;
            }
            if pool.pages_in_use() != 0 {
                return Err("drained pool still has leased pages".into());
            }
            for _ in 0..hwm {
                pool.lease().map_err(|e| format!("re-lease after drain failed: {e}"))?;
            }
            if pool.gauges().pages_hwm != hwm {
                return Err("re-leasing after a full drain grew the pool".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Engine-side vs pool-side page accounting
// ---------------------------------------------------------------------------

#[test]
fn prop_lanekv_page_accounting_matches_pool_gauges() {
    let cfg = tiny();
    let d = cfg.d_head;
    let model = Arc::new(NativeModel::new(cfg.clone(), 0x9A6E).unwrap());
    check(
        "lanekv-vs-pool-pages",
        12,
        |g| {
            let b = 1 + g.rng.below(3);
            let steps = 8 + g.rng.below(40);
            let ratio = 0.2 + g.rng.f64() * 0.8;
            (b, steps.min(cfg.max_seq - 1), ratio, g.rng.next_u64())
        },
        |(b, steps, ratio, seed)| {
            let (b, steps) = (*b, *steps);
            let h2o = H2oPolicy::new(*ratio, 3);
            let mut be = NativeBackend::from_model(model.clone());
            be.empty_cache(b).unwrap();
            let knobs = AquaKnobs { k_dims: d / 2, dim_keep: vec![1.0; d], use_projection: true };
            let mut rng = Rng::new(*seed);
            let mut lanes: Vec<LaneKv> = (0..b).map(|_| LaneKv::new(cfg.max_seq)).collect();
            for step in 0..steps {
                let tokens: Vec<i32> = (0..b).map(|_| 32 + rng.below(90) as i32).collect();
                let pos: Vec<i32> = lanes.iter().map(|l| l.len as i32).collect();
                let mut mask = vec![0.0f32; b * cfg.max_seq];
                for (lane, kv) in lanes.iter().enumerate() {
                    mask[lane * cfg.max_seq..(lane + 1) * cfg.max_seq]
                        .copy_from_slice(&kv.slot_mask);
                }
                let out = be.decode(b, &tokens, &pos, &mask, &knobs).unwrap();
                for lane in lanes.iter_mut() {
                    lane.commit_write(1);
                }
                // the engine-side page formula must equal the pool's gauges
                // (backend reclaimed with this call's mask, then leased the
                // write positions)
                let expect: usize =
                    lanes.iter().map(|l| l.resident_pages(DEFAULT_PAGE_SLOTS)).sum();
                if out.kv.pages_in_use as usize != expect {
                    return Err(format!(
                        "step {step}: pool has {} pages, LaneKv accounting says {expect}",
                        out.kv.pages_in_use
                    ));
                }
                if out.kv.resident_bytes != out.kv.pages_in_use * out.kv.page_bytes {
                    return Err("gauge identity violated".into());
                }
                // LaneKv::live_bytes (the engine-side byte view behind
                // Engine::kv_resident_bytes) must equal the pool's bytes
                let bps = (out.kv.page_bytes / out.kv.page_slots) as usize;
                let bytes: usize =
                    lanes.iter().map(|l| l.live_bytes(DEFAULT_PAGE_SLOTS, bps)).sum();
                if bytes as u64 != out.kv.resident_bytes {
                    return Err(format!(
                        "live_bytes {bytes} != pool resident {}",
                        out.kv.resident_bytes
                    ));
                }
                // evictions take effect on the next call's mask
                for lane in lanes.iter_mut() {
                    h2o.apply(lane);
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Parity: kv_keep = 1.0 pooled path vs oracle, across page sizes
// ---------------------------------------------------------------------------

/// Drive identical decode traffic (H2O evictions fed from the first
/// backend's attention mass) and return per-step logits per backend.
fn drive(
    backends: &mut [&mut dyn ExecBackend],
    b: usize,
    knobs: &AquaKnobs,
    steps: usize,
    h2o: &H2oPolicy,
    seed: u64,
) -> Vec<Vec<Vec<f32>>> {
    let cfg = backends[0].model_config().clone();
    let (s_cap, n_layers) = (cfg.max_seq, cfg.n_layers);
    let mut rng = Rng::new(seed);
    for be in backends.iter_mut() {
        be.empty_cache(b).unwrap();
    }
    let mut lanes: Vec<LaneKv> = (0..b).map(|_| LaneKv::new(s_cap)).collect();
    let mut outs: Vec<Vec<Vec<f32>>> = vec![vec![]; backends.len()];
    for _ in 0..steps {
        let tokens: Vec<i32> = (0..b).map(|_| 32 + rng.below(90) as i32).collect();
        let pos: Vec<i32> = lanes.iter().map(|l| l.len as i32).collect();
        let mut mask = vec![0.0f32; b * s_cap];
        for (lane, kv) in lanes.iter().enumerate() {
            mask[lane * s_cap..(lane + 1) * s_cap].copy_from_slice(&kv.slot_mask);
        }
        let mut step_outs = vec![];
        for be in backends.iter_mut() {
            step_outs.push(be.decode(b, &tokens, &pos, &mask, knobs).unwrap());
        }
        for lane in 0..b {
            lanes[lane].commit_write(1);
            let mut mass = vec![0.0f32; s_cap];
            for l in 0..n_layers {
                let base = (l * b + lane) * s_cap;
                for s in 0..s_cap {
                    mass[s] += step_outs[0].attn_acc[base + s];
                }
            }
            lanes[lane].accumulate(&mass);
            h2o.apply(&mut lanes[lane]);
        }
        for (i, o) in step_outs.into_iter().enumerate() {
            outs[i].push(o.logits);
        }
    }
    outs
}

#[test]
fn full_width_pool_is_bit_identical_across_page_sizes_and_to_oracle() {
    // kv_keep = 1.0: the paged packed path must equal the PR 2 dense
    // packed path bit for bit. The masked-dense oracle (dense shadow
    // cache, pre-pool write path) pins the old semantics; page-size
    // invariance (4 vs 16 vs one-page-per-lane 160) pins that paging
    // itself never changes a single bit.
    let cfg = tiny();
    let d = cfg.d_head;
    let model = Arc::new(NativeModel::new(cfg.clone(), 0xB17).unwrap());
    let h2o = H2oPolicy::new(0.4, 3);
    let knobs = AquaKnobs { k_dims: d / 2, dim_keep: vec![1.0; d], use_projection: true };

    let mut oracle = NativeBackend::from_model(model.clone());
    oracle.set_score_mode(ScoreMode::MaskedDense);
    let mut paged4 = NativeBackend::from_model(model.clone());
    paged4.configure_kv_pool(KvPoolConfig { page_slots: Some(4), ..Default::default() }).unwrap();
    let mut paged16 = NativeBackend::from_model(model.clone());
    let mut one_page = NativeBackend::from_model(model.clone());
    one_page
        .configure_kv_pool(KvPoolConfig { page_slots: Some(cfg.max_seq), ..Default::default() })
        .unwrap();

    let mut bes: Vec<&mut dyn ExecBackend> =
        vec![&mut oracle, &mut paged4, &mut paged16, &mut one_page];
    let outs = drive(&mut bes, 3, &knobs, 40, &h2o, 0xCAFE);
    for (name, i) in [("page_slots=4", 1usize), ("page_slots=16", 2), ("one-page", 3)] {
        assert_eq!(outs[0], outs[i], "{name} diverged from the masked-dense oracle");
    }
}

#[test]
fn truncated_keys_match_oracle_and_sharded_stays_bitwise() {
    // kv_keep = 0.5: the oracle writes the same dim_keep-zeroed keys at
    // full width, so outputs must still agree exactly; the sharded
    // backend (workers with their own sub-pools) must equal native bit
    // for bit at every thread count.
    let cfg = tiny();
    let d = cfg.d_head;
    let aqua = AquaConfig { s_ratio: 0.5, ..Default::default() };
    let knobs = AquaKnobs::from_config(&aqua, d);
    let kd = aqua.mem_dims(d);
    let pool_cfg = KvPoolConfig { key_dims: Some(kd), ..Default::default() };
    let model = Arc::new(NativeModel::new(cfg.clone(), 0x51AB).unwrap());
    let h2o = H2oPolicy::new(0.5, 4);

    let mut oracle = NativeBackend::from_model(model.clone());
    oracle.set_score_mode(ScoreMode::MaskedDense);
    let mut native = NativeBackend::from_model(model.clone());
    native.configure_kv_pool(pool_cfg).unwrap();
    let mut sharded2 = ShardedBackend::from_model(model.clone(), 2);
    sharded2.configure_kv_pool(pool_cfg).unwrap();
    let mut sharded4 = ShardedBackend::from_model(model.clone(), 4);
    sharded4.configure_kv_pool(pool_cfg).unwrap();

    let mut bes: Vec<&mut dyn ExecBackend> =
        vec![&mut oracle, &mut native, &mut sharded2, &mut sharded4];
    let outs = drive(&mut bes, 6, &knobs, 30, &h2o, 0xD1CE);
    assert_eq!(outs[0], outs[1], "truncated native pool diverged from the oracle");
    assert_eq!(outs[1], outs[2], "sharded(2) diverged from native through the pool");
    assert_eq!(outs[1], outs[3], "sharded(4) diverged from native through the pool");
}

// ---------------------------------------------------------------------------
// The memory claim, measured end to end
// ---------------------------------------------------------------------------

/// Fixed-length workload (no stop token) so page usage is identical
/// across operating points.
fn fixed_workload(n: usize, prompt_len: usize, gen: usize) -> Vec<GenRequest> {
    (0..n).map(|i| GenRequest::new(i as u64 + 1, vec![40 + i as i32; prompt_len], gen)).collect()
}

#[test]
fn resident_bytes_beat_the_dense_baseline_at_equal_load() {
    let cfg = tiny();
    let (d, nkv, nl, s_cap) = (cfg.d_head, cfg.n_kv_heads, cfg.n_layers, cfg.max_seq);
    let batch = 4;
    // what every lane preallocated before the pool (full-width K + V)
    let dense_alloc = (batch * nl * nkv * s_cap * 2 * d * 4) as u64;
    let run = |s_ratio: f64| -> u64 {
        let spec = BackendSpec::native(cfg.clone(), 9).unwrap();
        let aqua = AquaConfig { s_ratio, ..Default::default() };
        let mut engine =
            Engine::with_spec(&spec, EngineConfig { batch, aqua, ..Default::default() }).unwrap();
        engine.run_batch(fixed_workload(8, 20, 24)).unwrap();
        engine.metrics.snapshot().kv_resident_peak_bytes
    };
    let full = run(0.0);
    let half = run(0.5);
    // acceptance: kv_keep = 0.5 resident ≤ ~60% of the dense baseline
    assert!(
        (half as f64) <= 0.6 * dense_alloc as f64,
        "kv_keep=0.5 peak {half} B vs dense {dense_alloc} B exceeds the 0.6 bound"
    );
    // identical page usage (fixed lengths) → bytes scale exactly by the
    // truncated layout: (d/2 + d) / 2d = 0.75
    assert_eq!(4 * half, 3 * full, "expected exact 0.75x from key truncation");
    // paging alone already beats dense preallocation at this load
    assert!(full < dense_alloc);
}

#[test]
fn memory_sheds_have_distinct_http_status_and_counters() {
    let reg = ModelRegistry::new("no-such-dir");
    // tiny model: 4096 B/page at full width; 0.02 MiB → 5 pages
    let spec_json = r#"{"name": "m", "backend": "native", "batch": 2, "kv_budget_mb": 0.02}"#;
    let post = |path: &str, body: &str| Request {
        method: "POST".to_string(),
        path: path.to_string(),
        headers: vec![],
        body: body.to_string(),
    };
    let get = |path: &str| Request {
        method: "GET".to_string(),
        path: path.to_string(),
        headers: vec![],
        body: String::new(),
    };
    assert_eq!(route(&post("/models", spec_json), &reg).status, 200);

    // worst case 6+120 slots = 8 pages > the whole 5-page budget: a
    // permanent 413 telling the client retrying cannot succeed — not the
    // retryable capacity/pressure 429s
    let big = r#"{"prompt": "hello!", "max_new_tokens": 120, "stop_newline": false}"#;
    let resp = route(&post("/generate", big), &reg);
    assert_eq!(resp.status, 413);
    assert!(resp.body.contains("cannot succeed"), "413 body: {}", resp.body);
    assert!(!resp.body.contains("in-flight"), "wrong shed reason: {}", resp.body);

    // a request that fits completes, and /metrics splits the counters
    let small = r#"{"prompt": "hi", "max_new_tokens": 8, "stop_newline": false}"#;
    assert_eq!(route(&post("/generate", small), &reg).status, 200);
    let metrics = route(&get("/metrics"), &reg);
    let doc = Json::parse(&metrics.body).unwrap();
    let m = doc.get("models").get("m");
    assert_eq!(m.get("shed_memory_total").as_i64(), Some(1));
    assert_eq!(m.get("shed_capacity_total").as_i64(), Some(0));
    assert_eq!(m.get("shed_total").as_i64(), Some(1));
    assert_eq!(m.get("kv_pages_total").as_i64(), Some(5));
    assert_eq!(m.get("kv_reserved_pages").as_i64(), Some(0), "reservation released");
    assert!(m.get("kv_resident_bytes").as_f64().is_some());
    reg.shutdown_all().unwrap();
}

#[test]
fn engine_budget_defers_instead_of_stalling_for_all_backends() {
    // Memory-aware admission is the *global* budget bound: with 6 pages
    // (full width: 4096 B each) and requests needing 3 pages apiece, only
    // two lanes hold requests at a time — the rest defer at admission and
    // everything completes with zero pool stalls. Holds for the sharded
    // backend too (per-worker caps are just a backstop, so threads must
    // not multiply the budget).
    let cfg = tiny();
    let budget_mb = 6.0 * 4096.0 / (1u64 << 20) as f64;
    let specs = [
        BackendSpec::native(cfg.clone(), 3).unwrap(),
        BackendSpec::sharded(cfg.clone(), 3, 2).unwrap(),
    ];
    for spec in specs {
        let mut engine = Engine::with_spec(
            &spec,
            EngineConfig { batch: 4, kv_budget_mb: budget_mb, ..Default::default() },
        )
        .unwrap();
        let results = engine.run_batch(fixed_workload(6, 20, 24)).unwrap();
        assert_eq!(results.len(), 6);
        assert!(results.iter().all(|r| r.tokens.len() == 24), "deferred requests must finish");
        assert_eq!(engine.kv_resident_bytes(), 0, "all lanes retired, nothing resident");
        let snap = engine.metrics.snapshot();
        assert_eq!(snap.kv_alloc_stalls, 0, "{}: budget must never stall the pool", spec.name());
        assert!(
            snap.kv_resident_peak_bytes <= 6 * 4096,
            "{}: resident {} B exceeds the 6-page budget",
            spec.name(),
            snap.kv_resident_peak_bytes
        );
        // a request whose worst case exceeds the whole budget resolves
        // deterministically — with the budget-specific reason, not a
        // misattributed prompt-length reject — instead of hanging the
        // queue (100 + 40 slots fits max_seq, only the budget is short)
        let too_big = GenRequest::new(99, vec![65; 100], 40);
        let res = engine.run_batch(vec![too_big]).unwrap().remove(0);
        assert_eq!(res.finish, FinishReason::OverKvBudget);
        assert!(res.tokens.is_empty());
    }
}

#[test]
fn budget_pages_and_engine_pool_agree() {
    // the admission gate and the engine's pool cap must be the same
    // number — a request that passes the gate can never stall the pool
    let cfg = tiny();
    let aqua = AquaConfig { s_ratio: 0.5, ..Default::default() };
    let layout = PoolLayout {
        page_slots: DEFAULT_PAGE_SLOTS,
        key_dims: aqua.mem_dims(cfg.d_head),
        head_dim: cfg.d_head,
        layers: cfg.n_layers,
        kv_heads: cfg.n_kv_heads,
    };
    let pages = budget_pages(0.05, &layout).unwrap();
    let spec = BackendSpec::native(cfg.clone(), 1).unwrap();
    let mut engine = Engine::with_spec(
        &spec,
        EngineConfig { batch: 1, aqua, kv_budget_mb: 0.05, ..Default::default() },
    )
    .unwrap();
    // a workload sized exactly to the budget runs without a single stall
    let slots = pages * DEFAULT_PAGE_SLOTS;
    let gen = 8;
    let prompt = slots.saturating_sub(gen).min(cfg.max_seq - gen);
    engine.run_batch(vec![GenRequest::new(1, vec![65; prompt], gen)]).unwrap();
    let snap = engine.metrics.snapshot();
    assert_eq!(snap.kv_alloc_stalls, 0, "budget-sized load must never stall the pool");
    assert!(snap.kv_resident_peak_bytes > 0);
}
