//! Chaos suite: fault containment, supervised engine lifecycle, and
//! end-to-end cancellation/deadlines — hermetic, driven entirely by the
//! deterministic `fault:` backend wrapper (scripted errors, panics, and
//! latency spikes; see `runtime::fault`).
//!
//! Acceptance surface (ROADMAP PR 7): injected step errors never kill the
//! engine and leave surviving lanes bit-identical; failed/cancelled/
//! expired lanes release their KV capacity; a panicked engine flushes
//! terminal results to every waiter in < 1s, restarts under its budget,
//! and sheds with 503 while unhealthy; `/metrics` outcome counters
//! reconcile across the whole story.

use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use aqua_serve::coordinator::{Engine, EngineConfig, FinishReason, GenRequest, Health, Snapshot};
use aqua_serve::registry::{Admission, DeploymentSpec, ModelRegistry, ShedReason};
use aqua_serve::runtime::BackendSpec;
use aqua_serve::server;
use aqua_serve::tokenizer::ByteTokenizer;
use aqua_serve::util::json::Json;

// ---------------------------------------------------------------- helpers

fn registry_of(specs: &[&str]) -> Arc<ModelRegistry> {
    let reg = ModelRegistry::new("no-such-artifacts-dir");
    for s in specs {
        reg.deploy(DeploymentSpec::parse_kv(s).unwrap()).unwrap();
    }
    Arc::new(reg)
}

fn start_server(registry: Arc<ModelRegistry>) -> SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let _ = server::serve_on(listener, registry);
    });
    addr
}

fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    server::http::client_request(addr, method, path, body).expect("http request")
}

fn prompt_tokens(text: &str) -> Vec<i32> {
    ByteTokenizer.encode(text)
}

/// The outcome identity every snapshot must satisfy: each submission that
/// reached the engine resolved to exactly one terminal bucket.
fn assert_reconciled(s: &Snapshot) {
    assert_eq!(
        s.requests_done,
        s.requests_served
            + s.requests_rejected
            + s.requests_cancelled
            + s.requests_expired
            + s.requests_failed,
        "outcome counters must reconcile: {s:?}"
    );
}

fn wait_for<F: FnMut() -> bool>(what: &str, deadline: Duration, mut cond: F) {
    let end = Instant::now() + deadline;
    while !cond() {
        assert!(Instant::now() < end, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

// ------------------------------------------------------------------ tests

/// A scripted backend error retires only the blamed lane; every surviving
/// request's greedy output is bit-identical to a fault-free run — on the
/// single-threaded native backend and the lane-sharded one.
#[test]
fn injected_faults_leave_surviving_lanes_bit_identical() {
    for kind in ["native", "sharded"] {
        let reqs: Vec<GenRequest> = (0..4)
            .map(|i| GenRequest::new(i + 1, prompt_tokens(&format!("the color {i} of ")), 4))
            .collect();

        let clean_spec = BackendSpec::from_kind(kind, "chaos", 3, 2, "x").unwrap();
        let cfg = EngineConfig { batch: 2, ..EngineConfig::default() };
        let mut clean = Engine::with_spec(&clean_spec, cfg.clone()).unwrap();
        let clean_res = clean.run_batch(reqs.clone()).unwrap();

        // first pass errs once, blamed on lane 1 (request id 2)
        let faulty_spec = BackendSpec::from_kind(
            &format!("fault:{kind},err_every=1,err_count=1,err_lane=1"),
            "chaos",
            3,
            2,
            "x",
        )
        .unwrap();
        let mut faulty = Engine::with_spec(&faulty_spec, cfg).unwrap();
        let res = faulty.run_batch(reqs).unwrap();

        assert_eq!(res[1].finish, FinishReason::BackendError, "{kind}: blamed lane fails");
        assert!(res[1].tokens.is_empty(), "{kind}: failed before generating");
        for i in [0usize, 2, 3] {
            assert_eq!(res[i].finish, clean_res[i].finish, "{kind}: req {i} finish");
            assert_eq!(
                res[i].tokens, clean_res[i].tokens,
                "{kind}: surviving req {i} must be bit-identical to the fault-free run"
            );
        }
        // every lane (including the failed one) released its KV pages
        assert_eq!(faulty.kv_gauges().pages_in_use, 0, "{kind}: pages leak");
        let snap = faulty.metrics.snapshot();
        assert_eq!(snap.requests_failed, 1);
        assert_eq!(snap.lane_failures, 1);
        assert_eq!(snap.requests_served, 3);
        assert_reconciled(&snap);
    }
}

/// An engine panic mid-decode: the waiter gets a terminal `EngineFailed`
/// in under a second (no hang), the supervisor restarts the engine within
/// its budget, and the reborn engine serves bit-identical results — with
/// the shared metrics accumulator reconciling across the incarnations.
#[test]
fn supervisor_restart_preserves_service_and_reconciles_metrics() {
    let reg = registry_of(&[
        "name=chaotic,backend=fault:native;panic_at=12,seed=0,k=1.0,batch=1,queue=4,\
         restart=1,restart_backoff_ms=1",
    ]);
    let dep = reg.get(Some("chaotic")).unwrap();

    // a short request completes well before the scripted panic step
    let short = |id: u64| GenRequest::new(id, prompt_tokens("hi"), 3);
    let id1 = dep.fresh_id();
    assert_eq!(dep.submit(short(id1)).unwrap(), Admission::Accepted);
    let res1 = dep.wait_result(id1, Duration::from_secs(30)).expect("short request result");
    assert_eq!(res1.finish, FinishReason::Length);
    assert_eq!(res1.tokens.len(), 3);

    // a long request crosses backend step 12 → scripted panic. The waiter
    // must get a terminal answer fast, not hang to the HTTP deadline.
    let id2 = dep.fresh_id();
    assert_eq!(
        dep.submit(GenRequest::new(id2, prompt_tokens("hi"), 100)).unwrap(),
        Admission::Accepted
    );
    let t0 = Instant::now();
    let res2 = dep.wait_result(id2, Duration::from_secs(10)).expect("terminal result for waiter");
    assert_eq!(res2.finish, FinishReason::EngineFailed);
    assert!(
        t0.elapsed() < Duration::from_secs(1),
        "waiter must be flushed promptly, took {:?}",
        t0.elapsed()
    );

    // the supervisor restarts (budget 1) and publishes health
    wait_for("engine restart to Healthy", Duration::from_secs(10), || {
        dep.health() == Health::Healthy
    });
    assert_eq!(dep.admission_stats().engine_restarts, 1);

    // the reborn engine serves, bit-identical to the first incarnation
    // (same deterministic weights, fresh fault-step clock)
    let id3 = dep.fresh_id();
    assert_eq!(dep.submit(short(id3)).unwrap(), Admission::Accepted);
    let res3 = dep.wait_result(id3, Duration::from_secs(30)).expect("post-restart result");
    assert_eq!(res3.finish, FinishReason::Length);
    assert_eq!(res3.tokens, res1.tokens, "restart must not perturb the model");

    // one shared accumulator across incarnations: 2 served + 1 failed
    let snap = dep.stats().unwrap();
    assert_eq!(snap.requests_done, 3);
    assert_eq!(snap.requests_served, 2);
    assert_eq!(snap.requests_failed, 1);
    assert_reconciled(&snap);
    reg.shutdown_all().unwrap();
}

/// A deployment whose restart budget is exhausted goes `Failed` for good:
/// `/healthz` flips to 503 naming it, `/generate` sheds with 503 instead
/// of hanging, `GET /models` exposes the state — and the *other*
/// deployment in the fleet keeps serving 200s, untouched.
#[test]
fn failed_engine_sheds_503_and_fleet_stays_up() {
    let reg = registry_of(&[
        "name=doomed,backend=fault:native;panic_at=1,seed=0,k=1.0,batch=1,queue=4,restart=0",
        "name=steady,backend=native,seed=0,k=1.0,batch=2,queue=8",
    ]);
    let addr = start_server(reg.clone());
    assert_eq!(http(addr, "GET", "/healthz", "").1, "ok", "healthy fleet before the fault");

    // first backend call panics; restart budget 0 → Failed for good
    let (status, body) = http(
        addr,
        "POST",
        "/generate",
        r#"{"prompt": "x", "max_new_tokens": 4, "model": "doomed"}"#,
    );
    assert_eq!(status, 503, "waiter gets a terminal shed, got: {body}");
    let dep = reg.get(Some("doomed")).unwrap();
    wait_for("doomed engine to report Failed", Duration::from_secs(10), || {
        dep.health() == Health::Failed
    });

    // new work is shed at admission (503, not a hang), and counted
    let (status, body) = http(
        addr,
        "POST",
        "/generate",
        r#"{"prompt": "x", "max_new_tokens": 4, "model": "doomed"}"#,
    );
    assert_eq!(status, 503, "unhealthy deployment must shed: {body}");
    assert!(body.contains("failed"), "shed body names the state: {body}");
    // the API-level shed carries the typed reason too
    let id = dep.fresh_id();
    assert_eq!(
        dep.submit(GenRequest::new(id, prompt_tokens("x"), 2)).unwrap(),
        Admission::Shed(ShedReason::Unhealthy)
    );
    assert!(dep.admission_stats().shed_unhealthy >= 2);

    // liveness names the sick deployment; the healthy one still serves
    let (status, body) = http(addr, "GET", "/healthz", "");
    assert_eq!(status, 503);
    assert!(body.contains("doomed=failed"), "healthz names the sick engine: {body}");
    let (status, _) = http(
        addr,
        "POST",
        "/generate",
        r#"{"prompt": "the capital of ", "max_new_tokens": 8, "model": "steady"}"#,
    );
    assert_eq!(status, 200, "fault containment: the healthy deployment is unaffected");

    // fleet surfaces: /models health field, /metrics unhealthy-shed counter
    let (_, body) = http(addr, "GET", "/models", "");
    let doc = Json::parse(&body).unwrap();
    let health_of = |name: &str| {
        doc.get("models")
            .as_arr()
            .unwrap()
            .iter()
            .find(|m| m.get("name").as_str() == Some(name))
            .unwrap()
            .get("health")
            .as_str()
            .unwrap()
            .to_string()
    };
    assert_eq!(health_of("doomed"), "failed");
    assert_eq!(health_of("steady"), "healthy");
    let (_, body) = http(addr, "GET", "/metrics", "");
    let m = Json::parse(&body).unwrap();
    assert!(m.get("models").get("doomed").get("shed_unhealthy_total").as_i64().unwrap() >= 1);
    reg.shutdown_all().unwrap();
}

/// Deadlines fire end-to-end over HTTP: both the spec's default and the
/// per-request `deadline_ms` JSON field map to 504 with partial progress
/// reported, and the expiry shows up in `/metrics`. The latency-spike
/// fault knob pins decode slow enough that the deadline always lands
/// mid-request.
#[test]
fn deadlines_expire_mid_decode_over_http() {
    let reg = registry_of(&[
        // every backend step sleeps 5ms → ~140 tokens can never finish
        // inside a 60ms budget
        "name=slow_default,backend=fault:native;delay_every=1;delay_ms=5,seed=0,k=1.0,\
         batch=1,queue=4,deadline_ms=60",
        "name=slow_nodefault,backend=fault:native;delay_every=1;delay_ms=5,seed=0,k=1.0,\
         batch=1,queue=4",
    ]);
    let addr = start_server(reg.clone());

    // spec-default deadline
    let (status, body) = http(
        addr,
        "POST",
        "/generate",
        r#"{"prompt": "x", "max_new_tokens": 140, "stop_newline": false,
            "model": "slow_default"}"#,
    );
    assert_eq!(status, 504, "expired request maps to 504: {body}");
    assert!(body.contains("deadline expired"), "504 explains itself: {body}");

    // per-request JSON field on a deployment with no default
    let (status, body) = http(
        addr,
        "POST",
        "/generate",
        r#"{"prompt": "x", "max_new_tokens": 140, "stop_newline": false,
            "model": "slow_nodefault", "deadline_ms": 60}"#,
    );
    assert_eq!(status, 504, "per-request deadline maps to 504: {body}");

    let (_, body) = http(addr, "GET", "/metrics", "");
    let m = Json::parse(&body).unwrap();
    for name in ["slow_default", "slow_nodefault"] {
        let snap = m.get("models").get(name);
        assert_eq!(snap.get("requests_expired").as_i64(), Some(1), "{name}");
        assert_eq!(snap.get("requests_done").as_i64(), Some(1), "{name}");
    }
    assert_eq!(m.get("requests_expired").as_i64(), Some(2), "fleet aggregate");
    reg.shutdown_all().unwrap();
}

/// Cancellation is a capacity event: an explicit cancel frees the lane
/// (the queued request behind it completes) and zeroes the KV
/// reservation; a client that hangs up mid-generation is detected and
/// cancelled server-side instead of decoding into the void.
#[test]
fn cancel_frees_capacity_and_disconnect_cancels() {
    let reg = registry_of(&[
        "name=slowpoke,backend=fault:native;delay_every=1;delay_ms=5,seed=0,k=1.0,\
         batch=1,queue=2",
    ]);
    let dep = reg.get(Some("slowpoke")).unwrap();

    // long request occupies the single lane; a short one waits behind it
    let id1 = dep.fresh_id();
    assert_eq!(
        dep.submit(GenRequest::new(id1, prompt_tokens("the capital of "), 100)).unwrap(),
        Admission::Accepted
    );
    let id2 = dep.fresh_id();
    assert_eq!(
        dep.submit(GenRequest::new(id2, prompt_tokens("hi"), 2)).unwrap(),
        Admission::Accepted
    );
    std::thread::sleep(Duration::from_millis(30));
    dep.cancel(id1);
    let t0 = Instant::now();
    let r1 = dep.wait_result(id1, Duration::from_secs(10)).expect("cancelled result");
    assert_eq!(r1.finish, FinishReason::Cancelled);
    assert!(t0.elapsed() < Duration::from_secs(1), "cancel must resolve promptly");
    assert!(r1.tokens.len() < 100, "cancelled mid-flight");
    // ...and the freed lane serves the queued request to completion
    let r2 = dep.wait_result(id2, Duration::from_secs(30)).expect("queued request result");
    assert_eq!(r2.finish, FinishReason::Length);
    assert_eq!(r2.tokens.len(), 2);
    let adm = dep.admission_stats();
    assert_eq!(adm.queue_depth, 0);
    assert_eq!(adm.kv_reserved_pages, 0, "cancelled lane must release its KV reservation");

    // disconnect path: send a long /generate, hang up immediately — the
    // worker's probe detects it and cancels the lane
    let addr = start_server(reg.clone());
    {
        let mut s = TcpStream::connect(addr).unwrap();
        let body = r#"{"prompt": "the capital of ", "max_new_tokens": 100,
                       "stop_newline": false, "model": "slowpoke"}"#;
        write!(
            s,
            "POST /generate HTTP/1.1\r\nHost: aqua\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        s.flush().unwrap();
        // dropping the stream closes the socket: the client is gone
    }
    wait_for("disconnect-triggered cancel", Duration::from_secs(15), || {
        dep.stats().map(|s| s.requests_cancelled >= 2).unwrap_or(false)
    });
    let snap = dep.stats().unwrap();
    assert_eq!(snap.requests_cancelled, 2);
    assert_reconciled(&snap);
    reg.shutdown_all().unwrap();
}

/// A scripted fault landing *mid-verify* — after the lane's draft tokens
/// were written to shared KV pages but before the exact pass vouched for
/// them — retires only the blamed lane. The speculative pass must restore
/// every enrolled lane's committed state before containment re-runs the
/// cycle, so survivors stay bit-identical to a plain dense engine and the
/// drafted-but-unverified pages all return to the pool.
#[test]
fn mid_verify_fault_retires_only_blamed_lane_and_releases_draft_pages() {
    use aqua_serve::aqua::policy::AquaConfig;

    let reqs: Vec<GenRequest> = (0..4)
        .map(|i| GenRequest::new(i + 1, prompt_tokens(&format!("the color {i} of ")), 4))
        .collect();

    // ground truth: dense greedy, no speculation, no faults
    let clean_spec = BackendSpec::from_kind("native", "chaos", 3, 2, "x").unwrap();
    let dense_cfg = EngineConfig { batch: 2, ..EngineConfig::default() };
    let mut clean = Engine::with_spec(&clean_spec, dense_cfg).unwrap();
    let clean_res = clean.run_batch(reqs.clone()).unwrap();

    // speculative engine behind the fault wrapper. The injection clock
    // counts prefill + draft + verify calls: step 1 is the batched
    // prefill, the first duty cycle drafts twice (steps 2, 3) and
    // verifies at step 4 — `err_every=4,err_count=1` fires exactly there,
    // blaming lane 1 (request id 2) while both lanes hold drafted pages.
    let faulty_spec = BackendSpec::from_kind(
        "fault:native,err_every=4,err_count=1,err_lane=1",
        "chaos",
        3,
        2,
        "x",
    )
    .unwrap();
    let spec_cfg = EngineConfig {
        batch: 2,
        speculate: 2,
        aqua: AquaConfig { k_ratio: 0.25, ..Default::default() },
        ..EngineConfig::default()
    };
    let mut faulty = Engine::with_spec(&faulty_spec, spec_cfg).unwrap();
    let res = faulty.run_batch(reqs).unwrap();

    assert_eq!(res[1].finish, FinishReason::BackendError, "blamed lane fails mid-verify");
    // whatever the failed lane got out before the fault is a prefix of
    // the clean stream — never an unverified draft token
    assert_eq!(
        res[1].tokens,
        clean_res[1].tokens[..res[1].tokens.len()],
        "failed lane leaked unverified drafts"
    );
    for i in [0usize, 2, 3] {
        assert_eq!(res[i].finish, clean_res[i].finish, "req {i} finish");
        assert_eq!(
            res[i].tokens, clean_res[i].tokens,
            "surviving req {i} must be bit-identical to the fault-free dense run"
        );
    }
    assert_eq!(faulty.kv_gauges().pages_in_use, 0, "drafted pages leak after the fault");
    let snap = faulty.metrics.snapshot();
    assert_eq!(snap.requests_failed, 1);
    assert_eq!(snap.lane_failures, 1);
    assert_eq!(snap.requests_served, 3);
    assert!(snap.spec_drafted > 0, "speculation never engaged");
    assert_eq!(snap.spec_accepted + snap.spec_rejected, snap.spec_drafted);
    assert_reconciled(&snap);
}
