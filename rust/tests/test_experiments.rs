//! Figure/analysis regenerators produce the paper's qualitative *shape*
//! (the actual series are recorded in EXPERIMENTS.md). The npz-dump-based
//! figure tests need the pjrt feature + artifacts (they skip without the
//! latter); the break-even measurement is pure rust and always runs.

use aqua_serve::eval::experiments as exp;
#[cfg(feature = "pjrt")]
use aqua_serve::runtime::Artifacts;

#[test]
#[cfg(feature = "pjrt")]
fn fig2_shape_matches_paper() {
    let Ok(arts) = Artifacts::load(aqua_serve::ARTIFACTS_DIR) else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rows = exp::fig2(&arts, "llama-analog").unwrap();
    assert_eq!(rows.len(), 4);
    let find = |s: &str| {
        rows.iter()
            .find(|r| r.condition.contains(s))
            .unwrap_or_else(|| panic!("missing condition {s}"))
    };
    let online_mag = find("Same Matrix (online SVD) / Top-K by Magnitude");
    let offline_mag = find("Different Dataset (offline P) / Top-K by Magnitude");
    let offline_slice = find("Different Dataset (offline P) / Top-K by Dimension");

    for i in 0..online_mag.series.len() {
        let (ratio, lo) = online_mag.series[i];
        let (_, lf) = offline_mag.series[i];
        let (_, ls) = offline_slice.series[i];
        // (a) offline ≈ online (paper's validation of offline calibration)
        assert!((lf - lo).abs() < 0.05 + 0.1 * lo,
                "offline far from online at {ratio}: {lf} vs {lo}");
        // (b) magnitude beats slicing (paper §7.2 "halves the loss")
        if ratio < 0.95 {
            assert!(lf < ls, "magnitude ({lf}) not better than slice ({ls}) at {ratio}");
        }
        // (c) loss vanishes at k=d (lossless rotation)
        if ratio > 0.99 {
            assert!(lf < 1e-3);
        }
    }
}

#[test]
#[cfg(feature = "pjrt")]
fn fig3_crosslingual_transfer() {
    let Ok(arts) = Artifacts::load(aqua_serve::ARTIFACTS_DIR) else {
        eprintln!("skipping");
        return;
    };
    let rows = exp::fig3(&arts, "llama-analog").unwrap();
    // K + Q0..Q3, two languages each
    assert_eq!(rows.len(), 2 * (1 + 4));
    for m in ["K", "Q0", "Q1", "Q2", "Q3"] {
        let ang = rows.iter().find(|r| r.matrix == m && r.language.starts_with("anglish")).unwrap();
        let dev = rows.iter().find(|r| r.matrix == m && r.language.starts_with("devan")).unwrap();
        for ((ra, la), (_, ld)) in ang.series.iter().zip(&dev.series) {
            // Paper Fig. 3: profiles are "remarkably similar". Allow a loose
            // envelope — the cross-lingual loss must not blow up.
            assert!((ld - la).abs() < 0.22, "matrix {m} at {ra}: anglish {la} devan {ld}");
        }
    }
}

#[test]
#[cfg(feature = "pjrt")]
fn fig5_overlap_increases_with_kp() {
    let Ok(arts) = Artifacts::load(aqua_serve::ARTIFACTS_DIR) else {
        eprintln!("skipping");
        return;
    };
    let rows = exp::fig5(&arts, "llama-analog").unwrap();
    for (label, stats) in &rows {
        // overlap must rise along K' for fixed K, and be well below 1 at
        // small K' (the paper's mismatch finding)
        for w in stats.chunks(4) {
            for pair in w.windows(2) {
                assert!(pair[1].mean >= pair[0].mean - 1e-9,
                        "{label}: overlap not monotone in K'");
            }
        }
        let small = &stats[0]; // K=K'=0.125
        assert!(small.mean < 0.85, "{label}: top-12.5% magnitude dims fully inside top-12.5% PCA — no mismatch, suspicious");
    }
}

#[test]
#[cfg(feature = "pjrt")]
fn ablation_combined_projection_not_worse_for_queries() {
    let Ok(arts) = Artifacts::load(aqua_serve::ARTIFACTS_DIR) else {
        eprintln!("skipping");
        return;
    };
    let rows = exp::ablation_projection_source(&arts, "llama-analog").unwrap();
    assert_eq!(rows.len(), 3);
    let get = |s: &str| rows.iter().find(|r| r.source.contains(s)).unwrap();
    let keys_only = get("keys only");
    let combined = get("combined");
    // The paper's claim (§1): pooling queries+keys aligns the projection
    // with what the *query-magnitude* selection reads. On held-out query
    // vectors the combined P must not lose to the key-only P.
    for ((r, lc), (_, lk)) in combined.series.iter().zip(&keys_only.series) {
        assert!(*lc <= lk + 0.01, "combined P worse than key-only at k/d={r}: {lc} vs {lk}");
    }
}

#[test]
fn breakeven_bound_sanity() {
    use aqua_serve::bench::Bencher;
    // tiny measurement (pure rust, no artifacts needed)
    let rows = exp::breakeven(&[64], &[0.25], &Bencher::quick());
    assert_eq!(rows.len(), 1);
    let r = &rows[0];
    assert_eq!(r.paper_bound, Some((64.0f64 * 64.0 / 48.0).ceil() as usize));
    if let Some(c) = r.measured_crossover {
        // measured crossover within two orders of the analytic bound — this
        // is a noisy CPU, the *existence* and rough location is the claim
        assert!(c <= r.paper_bound.unwrap() * 64, "crossover implausibly late: {c}");
    }
}
