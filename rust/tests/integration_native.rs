//! Hermetic integration tests: the full serving path (admission →
//! continuous batching → prefill/decode → H2O → sampling → metrics) driven
//! end-to-end through the native `ExecBackend`. No artifacts, no network —
//! this is the tier-1 proof that the engine works.

use aqua_serve::aqua::policy::AquaConfig;
use aqua_serve::coordinator::{Engine, EngineConfig, FinishReason, GenRequest};
use aqua_serve::model::config::ModelConfig;
use aqua_serve::runtime::{synthetic_corpus, BackendSpec};
use aqua_serve::tokenizer::ByteTokenizer;

fn spec() -> BackendSpec {
    BackendSpec::native(ModelConfig::tiny("native-test"), 42).unwrap()
}

/// AQUA sparsity on (k_dims = 6 < d = 8) for the whole batch run.
fn sparse_aqua() -> AquaConfig {
    AquaConfig { k_ratio: 0.75, ..Default::default() }
}

fn engine(spec: &BackendSpec, batch: usize) -> Engine {
    Engine::with_spec(
        spec,
        EngineConfig { batch, aqua: sparse_aqua(), ..Default::default() },
    )
    .unwrap()
}

#[test]
fn run_batch_end_to_end_with_aqua_sparsity() {
    let spec = spec();
    let max_seq = spec.model_config().max_seq;
    assert_eq!(max_seq, 160, "test assumes the tiny preset capacity");
    let tok = ByteTokenizer;
    let corpus = synthetic_corpus(4096, 9);

    // Mixed prompt lengths, mixed max_new, score-only, and two rejects.
    let prompts: Vec<(usize, usize, bool)> = vec![
        (12, 8, false),  // short prompt, short gen
        (30, 16, false), // medium
        (3, 4, false),   // tiny
        (20, 0, true),   // score-only
        (60, 100, false),// fills the KV cache exactly (60 + 100 = max_seq)
    ];
    let mut reqs = vec![];
    for (i, &(plen, max_new, score)) in prompts.iter().enumerate() {
        let mut r = GenRequest::new(
            i as u64 + 1,
            tok.encode_bytes(&corpus[i * 97..i * 97 + plen]),
            max_new,
        );
        r.score_only = score;
        reqs.push(r);
    }
    reqs.push(GenRequest::new(6, vec![1i32; max_seq + 40], 4)); // too long
    reqs.push(GenRequest::new(7, vec![], 4)); // empty prompt

    let mut e = engine(&spec, 4);
    let results = e.run_batch(reqs.clone()).unwrap();

    // --- completion order: results come back in submission order ----------
    let ids: Vec<u64> = results.iter().map(|r| r.id).collect();
    assert_eq!(ids, vec![1, 2, 3, 4, 5, 6, 7]);

    // --- finish reasons ----------------------------------------------------
    for (i, &(_, max_new, score)) in prompts.iter().enumerate() {
        let r = &results[i];
        assert_eq!(r.finish, FinishReason::Length, "req {} finish", r.id);
        if score {
            assert!(r.tokens.is_empty());
        } else {
            assert_eq!(r.tokens.len(), max_new, "req {} length", r.id);
            assert_eq!(r.gen_logprobs.len(), max_new);
            assert!(r.gen_logprobs.iter().all(|&lp| lp <= 0.0 && lp.is_finite()));
            assert!(r.ttft_us <= r.total_us);
        }
    }
    assert_eq!(results[5].finish, FinishReason::PromptTooLong);
    assert_eq!(results[6].finish, FinishReason::PromptTooLong);
    assert!(results[5].tokens.is_empty() && results[6].tokens.is_empty());

    // score-only returns teacher-forced logprobs over the whole prompt
    let score_res = &results[3];
    assert_eq!(score_res.prompt_logprobs.len(), prompts[3].0 - 1);
    assert!(score_res.prompt_logprobs.iter().all(|&lp| lp <= 0.0 && lp.is_finite()));

    // --- metrics reconcile with the emitted tokens -------------------------
    let s = e.metrics.snapshot();
    // every submission reaches a terminal state: 5 served + 2 rejected
    // (rejects never ran but still reconcile through requests_done)
    assert_eq!(s.requests_done, prompts.len() as u64 + 2);
    assert_eq!(s.requests_rejected, 2);
    let expected_prompt_tokens: u64 = prompts.iter().map(|&(p, _, _)| p as u64).sum();
    assert_eq!(s.prompt_tokens, expected_prompt_tokens);
    // every request's first token is sampled during prefill; the rest are
    // decode-generated, one per live lane per decode call
    let expected_decode_tokens: u64 = results
        .iter()
        .map(|r| (r.tokens.len() as u64).saturating_sub(1))
        .sum();
    assert_eq!(s.tokens_generated, expected_decode_tokens);
    assert!(s.decode_calls > 0 && s.prefill_calls > 0);

    // --- determinism: a fresh engine over the same spec reproduces ---------
    let mut e2 = engine(&spec, 4);
    let again = e2.run_batch(reqs).unwrap();
    for (a, b) in results.iter().zip(&again) {
        assert_eq!(a.tokens, b.tokens, "req {} not deterministic", a.id);
        assert_eq!(a.finish, b.finish);
    }
}

#[test]
fn batch_lanes_match_single_lane_runs() {
    let spec = spec();
    let tok = ByteTokenizer;
    let corpus = synthetic_corpus(2048, 3);
    let prompts: Vec<Vec<i32>> = (0..4)
        .map(|i| tok.encode_bytes(&corpus[i * 53..i * 53 + 10 + 7 * i]))
        .collect();

    // batch of 4 (mixed lengths finish at different times → lane churn)
    let mut e4 = engine(&spec, 4);
    let reqs: Vec<GenRequest> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| GenRequest::new(i as u64 + 1, p.clone(), 12))
        .collect();
    let batched = e4.run_batch(reqs).unwrap();

    // each prompt alone at batch=1 must produce identical greedy tokens
    for (i, p) in prompts.iter().enumerate() {
        let mut e1 = engine(&spec, 1);
        let single = e1
            .run_batch(vec![GenRequest::new(99, p.clone(), 12)])
            .unwrap()
            .remove(0);
        assert_eq!(batched[i].tokens, single.tokens, "lane cross-talk on prompt {i}");
    }
}

#[test]
fn stop_token_finishes_with_stop_reason() {
    let spec = spec();
    let tok = ByteTokenizer;
    let prompt = tok.encode("the capital of velor is ");

    // discover what the model emits first, then stop on exactly that token
    let mut probe = engine(&spec, 1);
    let first = probe
        .run_batch(vec![GenRequest::new(1, prompt.clone(), 4)])
        .unwrap()
        .remove(0)
        .tokens[0];

    let mut e = engine(&spec, 1);
    let mut req = GenRequest::new(2, prompt, 16);
    req.stop_token = Some(first);
    let res = e.run_batch(vec![req]).unwrap().remove(0);
    assert_eq!(res.finish, FinishReason::Stop);
    assert_eq!(res.tokens, vec![first]);
}

#[test]
fn h2o_eviction_engages_under_budget_pressure() {
    let spec = spec();
    let tok = ByteTokenizer;
    let corpus = synthetic_corpus(2048, 5);
    let long_prompt = tok.encode_bytes(&corpus[..120]);

    let mut e = Engine::with_spec(
        &spec,
        EngineConfig {
            batch: 1,
            aqua: AquaConfig { k_ratio: 0.75, h2o_ratio: 0.25, ..Default::default() },
            h2o_recent_window: 8,
            ..Default::default()
        },
    )
    .unwrap();
    let res = e.run_batch(vec![GenRequest::new(1, long_prompt, 16)]).unwrap().remove(0);
    assert_eq!(res.tokens.len(), 16);
    assert!(res.gen_logprobs.iter().all(|&lp| lp.is_finite()));
    assert!(e.metrics.snapshot().h2o_evictions > 0, "H2O at ratio 0.25 must evict");

    // eviction off on the same spec: no evictions
    let mut e_off = engine(&spec, 1);
    let long_prompt = tok.encode_bytes(&corpus[..120]);
    e_off.run_batch(vec![GenRequest::new(1, long_prompt, 16)]).unwrap();
    assert_eq!(e_off.metrics.snapshot().h2o_evictions, 0);
}

#[test]
fn rotation_invariance_through_the_engine() {
    // Orthogonal P at k = d must match the identity-P baseline through the
    // whole stack (Lemma A.4), measured on teacher-forced logprobs.
    let spec = spec();
    let tok = ByteTokenizer;
    let prompt = tok.encode("the color of the sky is blue .");
    let score = |aqua: AquaConfig| -> Vec<f32> {
        let mut e = Engine::with_spec(
            &spec,
            EngineConfig { batch: 1, aqua, ..Default::default() },
        )
        .unwrap();
        let mut r = GenRequest::new(1, prompt.clone(), 0);
        r.score_only = true;
        e.run_batch(vec![r]).unwrap().remove(0).prompt_logprobs
    };
    let base = score(AquaConfig::baseline());
    let rot = score(AquaConfig { k_ratio: 1.0, ..Default::default() });
    let diff = base.iter().zip(&rot).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
    assert!(diff < 2e-3, "rotation changed teacher-forced scores by {diff}");

    // moderate pruning stays closer to baseline than aggressive pruning
    let sum = |v: &[f32]| v.iter().map(|&x| x as f64).sum::<f64>();
    let lp75 = sum(&score(AquaConfig { k_ratio: 0.75, ..Default::default() }));
    let lp25 = sum(&score(AquaConfig { k_ratio: 0.25, ..Default::default() }));
    let b = sum(&base);
    // (small slack: a random tiny model on one prompt is noisy, but the
    // ordering must hold up to that noise)
    assert!(
        (b - lp75).abs() <= (b - lp25).abs() + 0.25,
        "k=0.75 ({lp75:.3}) should be at least as close to baseline ({b:.3}) as k=0.25 ({lp25:.3})"
    );
}

#[test]
fn aqua_knobs_swap_mid_engine() {
    let spec = spec();
    let tok = ByteTokenizer;
    let mut e = engine(&spec, 1);
    let gen = |e: &mut Engine| -> Vec<i32> {
        e.run_batch(vec![GenRequest::new(1, tok.encode("the king of "), 10)])
            .unwrap()
            .remove(0)
            .tokens
    };
    let sparse = gen(&mut e);
    e.with_aqua(AquaConfig::baseline());
    let dense = gen(&mut e);
    e.with_aqua(sparse_aqua());
    let sparse_again = gen(&mut e);
    assert_eq!(sparse, sparse_again, "knob swap must be stateless across runs");
    // dense vs sparse may or may not produce identical greedy tokens, but
    // both must be well-formed
    assert_eq!(dense.len(), 10);
}
