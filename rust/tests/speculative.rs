//! Self-speculative decoding acceptance surface (ROADMAP PR 9), hermetic
//! and release-tested: drafting through the AQUA-sparse score path and
//! verifying with one exact batched pass over the *same* paged KV cache
//! must be **lossless** — bit-identical tokens, finish reasons, and
//! per-token logprobs versus plain dense greedy decoding — on the native
//! backend and the lane-sharded backend at every thread count; rolled-back
//! draft pages must return to the pool; and the draft-ledger counters
//! (`spec_drafted = spec_accepted + spec_rejected`) must reconcile with
//! the derived rates the server exports.

use aqua_serve::aqua::policy::AquaConfig;
use aqua_serve::coordinator::{Engine, EngineConfig, FinishReason, GenRequest, Snapshot};
use aqua_serve::model::config::ModelConfig;
use aqua_serve::runtime::BackendSpec;
use aqua_serve::trace::TraceMode;

const BATCH: usize = 4;

/// Deterministic per-lane prompts of different lengths so lanes sit at
/// different KV depths (staggered draft plans, staggered retirement).
fn prompt(lane: usize) -> Vec<i32> {
    let len = 6 + 3 * lane;
    (0..len).map(|j| 32 + ((17 * lane + 5 * j) % 90) as i32).collect()
}

/// Staggered budgets: lanes retire at different cycles, so late cycles
/// run partially-empty verify batches (the `-1` row-padding path).
fn budget(lane: usize) -> usize {
    24 + 7 * lane
}

fn requests(stop_token: Option<i32>) -> Vec<GenRequest> {
    (0..BATCH)
        .map(|lane| {
            let mut r = GenRequest::new(lane as u64 + 1, prompt(lane), budget(lane));
            r.stop_token = stop_token;
            r
        })
        .collect()
}

struct RunOut {
    results: Vec<aqua_serve::coordinator::GenResult>,
    snap: Snapshot,
    pages_in_use_after: u64,
}

/// Drive one engine over the shared request set and drain it.
fn run(spec: &BackendSpec, speculate: usize, k_ratio: f64, stop: Option<i32>) -> RunOut {
    let cfg = EngineConfig {
        batch: BATCH,
        speculate,
        aqua: AquaConfig { k_ratio, ..Default::default() },
        trace: TraceMode::Full,
        ..Default::default()
    };
    let mut engine = Engine::with_spec(spec, cfg).expect("engine");
    for r in requests(stop) {
        assert!(engine.submit(r), "submit refused");
    }
    engine.run_until_idle().expect("drain");
    let results: Vec<_> = (0..BATCH)
        .map(|lane| engine.take_result(lane as u64 + 1).expect("result"))
        .collect();
    let pages = engine.kv_gauges().pages_in_use;
    RunOut { results, snap: engine.metrics.snapshot(), pages_in_use_after: pages }
}

/// Every observable client output must match bit-for-bit: tokens, finish
/// reason, generated-token logprobs, and teacher-forced prompt logprobs.
fn assert_bit_identical(a: &RunOut, b: &RunOut, what: &str) {
    for lane in 0..BATCH {
        let (x, y) = (&a.results[lane], &b.results[lane]);
        assert_eq!(x.tokens, y.tokens, "{what}: lane {lane} tokens diverge");
        assert_eq!(x.finish, y.finish, "{what}: lane {lane} finish diverges");
        assert_eq!(
            x.gen_logprobs.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            y.gen_logprobs.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "{what}: lane {lane} gen_logprobs not bit-identical"
        );
        assert_eq!(
            x.prompt_logprobs.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            y.prompt_logprobs.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "{what}: lane {lane} prompt_logprobs not bit-identical"
        );
    }
}

/// The schema-level identity `aqua benchcheck` re-derives, asserted on the
/// live counters: the ledger balances and the exported rates are exactly
/// the ratios of the raw counters.
fn assert_spec_reconciled(s: &Snapshot, what: &str) {
    assert_eq!(s.spec_accepted + s.spec_rejected, s.spec_drafted, "{what}: draft ledger");
    if s.spec_drafted > 0 {
        let rate = s.spec_accepted as f64 / s.spec_drafted as f64;
        assert!((s.spec_acceptance_rate - rate).abs() < 1e-12, "{what}: acceptance rate");
    } else {
        assert_eq!(s.spec_acceptance_rate, 0.0, "{what}: rate without drafts");
    }
    if s.spec_lane_cycles > 0 {
        let eff = s.spec_committed as f64 / s.spec_lane_cycles as f64;
        assert!((s.tokens_per_step_effective - eff).abs() < 1e-12, "{what}: effective t/s");
        assert!(eff >= 1.0, "{what}: every verify cycle commits at least one token");
    } else {
        assert_eq!(s.tokens_per_step_effective, 0.0, "{what}: eff without cycles");
    }
}

// ------------------------------------------------------------- losslessness

/// The headline guarantee: speculation at any draft depth and any draft
/// sparsity reproduces plain dense greedy decoding exactly — on the
/// single-threaded native backend and on the lane-sharded backend at 2
/// and 4 threads (which must themselves stay bit-identical to native).
#[test]
fn speculation_is_lossless_vs_exact_decode() {
    let model = ModelConfig::tiny("llama-analog");
    let native = BackendSpec::native(model.clone(), 0xA11A).unwrap();
    let baseline = run(&native, 0, 1.0, None);
    assert_eq!(baseline.snap.spec_drafted, 0, "baseline must not draft");

    for &(speculate, k_ratio) in &[(1usize, 0.25f64), (4, 0.25), (3, 0.5), (4, 1.0)] {
        let out = run(&native, speculate, k_ratio, None);
        assert_bit_identical(&out, &baseline, &format!("native spec={speculate} k={k_ratio}"));
        assert!(out.snap.spec_drafted > 0, "speculation never engaged");
        assert_spec_reconciled(&out.snap, "native");
    }

    for &threads in &[2usize, 4] {
        let sharded = BackendSpec::sharded(model.clone(), 0xA11A, threads).unwrap();
        let out = run(&sharded, 4, 0.25, None);
        assert_bit_identical(&out, &baseline, &format!("sharded x{threads} spec=4"));
        assert!(out.snap.spec_drafted > 0, "sharded speculation never engaged");
        assert_spec_reconciled(&out.snap, "sharded");
    }
}

/// Stop tokens fire mid-draft-plan too: pick a token the baseline really
/// emits mid-stream, re-run both engines with it as `stop_token`, and the
/// speculative engine must truncate at exactly the same position with
/// `FinishReason::Stop` (the drafted overshoot rolled back, not emitted).
#[test]
fn stop_token_parity_under_speculation() {
    let model = ModelConfig::tiny("llama-analog");
    let native = BackendSpec::native(model.clone(), 0xA11A).unwrap();
    let probe = run(&native, 0, 1.0, None);
    // a token from the middle of lane 0's stream — guaranteed reachable
    let mid = probe.results[0].tokens.len() / 2;
    let stop = probe.results[0].tokens[mid];

    let exact = run(&native, 0, 1.0, Some(stop));
    let spec = run(&native, 4, 0.25, Some(stop));
    assert_bit_identical(&spec, &exact, "stop-token");
    assert!(
        exact.results.iter().any(|r| r.finish == FinishReason::Stop),
        "probe token never stopped any lane"
    );
    assert_spec_reconciled(&spec.snap, "stop-token");
}

// ------------------------------------------------- rollback page accounting

/// Rejected draft tokens wrote real KV pages; rollback must hand every one
/// of them back — after a full drain the pool gauge reads zero, on both
/// backends, exactly as for non-speculative decoding.
#[test]
fn rollback_releases_drafted_pages() {
    let model = ModelConfig::tiny("llama-analog");
    for (name, spec) in [
        ("native", BackendSpec::native(model.clone(), 0xD0D0).unwrap()),
        ("sharded", BackendSpec::sharded(model.clone(), 0xD0D0, 2).unwrap()),
    ] {
        let out = run(&spec, 4, 0.25, None);
        assert_eq!(out.pages_in_use_after, 0, "{name}: drafted pages leaked after drain");
        assert!(out.snap.spec_drafted > 0, "{name}: speculation never engaged");
    }
}

// --------------------------------------------------- metrics reconciliation

/// The counters the server exports (`/stats`, `/metrics`) reconcile with
/// the client-visible token streams: committed speculative tokens are a
/// subset of `tokens_generated`, every verify pass is accounted, and the
/// derived rates re-derive from the raw ledger.
#[test]
fn acceptance_metrics_reconcile_with_output() {
    let model = ModelConfig::tiny("llama-analog");
    let spec = BackendSpec::native(model, 0xFACE).unwrap();
    let out = run(&spec, 4, 0.25, None);
    let s = &out.snap;
    assert_spec_reconciled(s, "reconcile");
    assert!(s.spec_verify_passes > 0, "no verify pass recorded");
    assert!(s.spec_lane_cycles >= s.spec_verify_passes, "cycles undercount passes");
    // each lane-cycle commits >= 1 token; committed tokens all reached
    // clients, so the global generation counter bounds the spec ledger
    assert!(s.spec_committed >= s.spec_lane_cycles);
    assert!(s.spec_committed <= s.tokens_generated, "committed exceeds generated");
    let client_tokens: u64 = out.results.iter().map(|r| r.tokens.len() as u64).sum();
    assert_eq!(s.tokens_generated, client_tokens, "generated != delivered");
}

// ------------------------------------------------------------ off == legacy

/// `speculate = 0` is byte-identical to the legacy engine: same outputs as
/// a default-config engine that never heard of speculation, and the spec
/// ledger stays all-zero (so dashboards on non-speculative deployments
/// render zeros, not NaNs).
#[test]
fn speculate_zero_is_legacy_decode() {
    let model = ModelConfig::tiny("llama-analog");
    let spec = BackendSpec::native(model, 0xBEEF).unwrap();

    let mut legacy = Engine::with_spec(
        &spec,
        EngineConfig { batch: BATCH, ..Default::default() },
    )
    .expect("engine");
    for r in requests(None) {
        assert!(legacy.submit(r));
    }
    legacy.run_until_idle().expect("drain");
    let legacy_out = RunOut {
        results: (0..BATCH).map(|l| legacy.take_result(l as u64 + 1).unwrap()).collect(),
        pages_in_use_after: legacy.kv_gauges().pages_in_use,
        snap: legacy.metrics.snapshot(),
    };

    let off = run(&spec, 0, 1.0, None);
    assert_bit_identical(&off, &legacy_out, "speculate=0 vs legacy");
    for s in [&off.snap, &legacy_out.snap] {
        assert_eq!(s.spec_drafted, 0);
        assert_eq!(s.spec_accepted, 0);
        assert_eq!(s.spec_rejected, 0);
        assert_eq!(s.spec_committed, 0);
        assert_eq!(s.spec_lane_cycles, 0);
        assert_eq!(s.spec_verify_passes, 0);
        assert_eq!(s.spec_acceptance_rate, 0.0);
        assert_eq!(s.tokens_per_step_effective, 0.0);
    }
}
