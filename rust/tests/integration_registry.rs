//! Integration tests for the multi-model registry and the HTTP router
//! over it — hermetic: native backends only, real TCP on loopback
//! ephemeral ports.
//!
//! Covers the fleet acceptance surface: routing by name with isolated
//! per-model metrics, admission shed under overload, runtime fleet
//! mutation (add/delete with drain), HTTP edge cases, and the size-1
//! registry behaving exactly like the pre-registry single-engine path.

use std::net::{SocketAddr, TcpListener};
use std::sync::Arc;
use std::time::{Duration, Instant};

use aqua_serve::coordinator::{Engine, EngineConfig, GenRequest};
use aqua_serve::model::config::ModelConfig;
use aqua_serve::registry::{Admission, DeploymentSpec, ModelRegistry, ShedReason};
use aqua_serve::runtime::BackendSpec;
use aqua_serve::server;
use aqua_serve::tokenizer::ByteTokenizer;
use aqua_serve::util::json::Json;

// ---------------------------------------------------------------- helpers

fn registry_of(specs: &[&str]) -> Arc<ModelRegistry> {
    let reg = ModelRegistry::new("no-such-artifacts-dir");
    for s in specs {
        reg.deploy(DeploymentSpec::parse_kv(s).unwrap()).unwrap();
    }
    Arc::new(reg)
}

fn start_server(registry: Arc<ModelRegistry>) -> SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let _ = server::serve_on(listener, registry);
    });
    addr
}

fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    server::http::client_request(addr, method, path, body).expect("http request")
}

fn generate(addr: SocketAddr, model: Option<&str>, prompt: &str, max_new: usize) -> (u16, Json) {
    let model_field = match model {
        Some(m) => format!(", \"model\": \"{m}\""),
        None => String::new(),
    };
    let body = format!("{{\"prompt\": \"{prompt}\", \"max_new_tokens\": {max_new}{model_field}}}");
    let (status, resp) = http(addr, "POST", "/generate", &body);
    let doc = if status == 200 { Json::parse(&resp).expect("json body") } else { Json::Null };
    (status, doc)
}

/// Greedy reference text straight through an in-process engine with the
/// same knobs a deployment spec pins (newline stop, like the server).
fn direct_engine_text(
    seed: u64,
    k_ratio: f64,
    batch: usize,
    prompt: &str,
    max_new: usize,
) -> String {
    let spec = BackendSpec::native(ModelConfig::tiny("llama-analog"), seed).unwrap();
    let mut cfg = EngineConfig { batch, seed, ..Default::default() };
    cfg.aqua.k_ratio = k_ratio;
    let mut engine = Engine::with_spec(&spec, cfg).unwrap();
    let tok = ByteTokenizer;
    let mut req = GenRequest::new(1, tok.encode(prompt), max_new);
    req.stop_token = Some(b'\n' as i32);
    let res = engine.run_batch(vec![req]).unwrap().remove(0);
    tok.decode(&res.tokens)
}

fn metrics(addr: SocketAddr) -> Json {
    let (status, body) = http(addr, "GET", "/metrics", "");
    assert_eq!(status, 200, "metrics failed: {body}");
    Json::parse(&body).unwrap()
}

// ------------------------------------------------------------------ tests

#[test]
fn two_models_route_by_name_with_isolated_metrics() {
    let reg = registry_of(&[
        "name=exact,backend=native,seed=0,k=1.0,batch=2,queue=8",
        "name=pruned,backend=native,seed=0,k=0.25,batch=2,queue=8",
    ]);
    let addr = start_server(reg.clone());
    let prompt = "the capital of ";

    // routing by name reproduces each operating point's direct-engine text
    let (status, doc) = generate(addr, Some("exact"), prompt, 16);
    assert_eq!(status, 200);
    assert_eq!(doc.get("model").as_str(), Some("exact"));
    let exact_text = doc.get("text").as_str().unwrap().to_string();
    assert_eq!(exact_text, direct_engine_text(0, 1.0, 2, prompt, 16));

    let (status, doc) = generate(addr, Some("pruned"), prompt, 16);
    assert_eq!(status, 200);
    assert_eq!(doc.get("model").as_str(), Some("pruned"));
    let pruned_text = doc.get("text").as_str().unwrap().to_string();
    assert_eq!(pruned_text, direct_engine_text(0, 0.25, 2, prompt, 16));

    // omitted model routes to the fleet default (first deployed)
    let (status, doc) = generate(addr, None, prompt, 16);
    assert_eq!(status, 200);
    assert_eq!(doc.get("model").as_str(), Some("exact"));
    assert_eq!(doc.get("text").as_str(), Some(exact_text.as_str()));

    // concurrent traffic to both models
    let mut joins = vec![];
    for model in ["exact", "pruned"] {
        joins.push(std::thread::spawn(move || {
            for _ in 0..3 {
                let (status, _) = generate(addr, Some(model), "the color of ", 12);
                assert_eq!(status, 200);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }

    // per-model metrics stay isolated: request counts and, crucially, the
    // kernel counters — k=1.0 routes dense, k=0.25 routes packed.
    let m = metrics(addr);
    assert_eq!(m.get("requests_done").as_i64(), Some(9), "fleet aggregate");
    let exact = m.get("models").get("exact");
    let pruned = m.get("models").get("pruned");
    assert_eq!(exact.get("requests_done").as_i64(), Some(5));
    assert_eq!(pruned.get("requests_done").as_i64(), Some(4));
    assert!(exact.get("kernel_dense").as_i64().unwrap() > 0);
    assert_eq!(exact.get("kernel_packed").as_i64(), Some(0));
    assert_eq!(exact.get("kernel_sparse").as_i64(), Some(0));
    assert!(pruned.get("kernel_packed").as_i64().unwrap() > 0);
    assert_eq!(pruned.get("kernel_dense").as_i64(), Some(0));
    assert_eq!(exact.get("backend").as_str(), Some("native"));
    assert_eq!(m.get("default_model").as_str(), Some("exact"));
    // admission counters present and sane
    assert_eq!(exact.get("queue_depth").as_i64(), Some(0));
    assert_eq!(exact.get("shed_total").as_i64(), Some(0));
    assert_eq!(exact.get("submitted_total").as_i64(), Some(5));

    reg.shutdown_all().unwrap();
}

#[test]
fn admission_control_sheds_and_recovers() {
    let reg = registry_of(&["name=slow,backend=native,seed=0,k=1.0,batch=1,queue=1"]);
    let dep = reg.get(Some("slow")).unwrap();
    let tok = ByteTokenizer;

    // deterministic shed at the API level: one long request occupies the
    // single in-flight slot; the second submit must shed
    let id = dep.fresh_id();
    let long = GenRequest::new(id, tok.encode("a reasonably long prompt here"), 120);
    assert_eq!(dep.submit(long).unwrap(), Admission::Accepted);
    let id2 = dep.fresh_id();
    let second = GenRequest::new(id2, tok.encode("hi"), 4);
    assert_eq!(dep.submit(second).unwrap(), Admission::Shed(ShedReason::Capacity));
    let adm = dep.admission_stats();
    assert_eq!(adm.shed, 1);
    assert_eq!(adm.submitted, 1);
    assert_eq!(adm.queue_depth, 1);

    // the admitted request still completes in full
    let res = dep.wait_result(id, Duration::from_secs(60)).expect("result");
    assert_eq!(res.tokens.len(), 120);
    assert_eq!(dep.admission_stats().queue_depth, 0, "slot released after completion");
    assert!(dep.take_result(id2).is_none(), "shed request produced no result");

    // over-capacity under concurrent HTTP load: some 429s, never a hang
    let addr = start_server(reg.clone());
    let mut joins = vec![];
    for _ in 0..6 {
        joins.push(std::thread::spawn(move || {
            let body = r#"{"prompt": "the capital of ", "max_new_tokens": 120,
                           "stop_newline": false, "model": "slow"}"#;
            http(addr, "POST", "/generate", body).0
        }));
    }
    let statuses: Vec<u16> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let ok = statuses.iter().filter(|&&s| s == 200).count();
    let shed = statuses.iter().filter(|&&s| s == 429).count();
    assert_eq!(ok + shed, 6, "only 200/429 expected, got {statuses:?}");
    assert!(ok >= 1, "at least one request must be admitted: {statuses:?}");
    assert!(shed >= 1, "queue=1 under 6 concurrent posts must shed: {statuses:?}");

    let m = metrics(addr);
    let slow = m.get("models").get("slow");
    assert_eq!(slow.get("shed_total").as_i64(), Some(1 + shed as i64));
    assert_eq!(slow.get("queue_depth").as_i64(), Some(0));
    reg.shutdown_all().unwrap();
}

#[test]
fn http_edge_cases_and_runtime_admin() {
    let reg = registry_of(&["name=base,backend=native,seed=0,k=1.0,batch=2,queue=4"]);
    let addr = start_server(reg.clone());

    // malformed body / missing fields / unknown model
    assert_eq!(http(addr, "POST", "/generate", "{oops").0, 400);
    assert_eq!(http(addr, "POST", "/generate", "42").0, 400);
    assert_eq!(http(addr, "POST", "/generate", r#"{"max_new_tokens": 4}"#).0, 400);
    let (status, body) = http(addr, "POST", "/generate", r#"{"prompt": "x", "model": "ghost"}"#);
    assert_eq!(status, 404);
    assert!(body.contains("ghost"), "404 names the unknown model: {body}");
    assert_eq!(http(addr, "GET", "/nope", "").0, 404);
    assert_eq!(http(addr, "DELETE", "/models/ghost", "").0, 404);
    assert_eq!(http(addr, "GET", "/healthz", "").1, "ok");

    // GET /models lists the fleet
    let (status, body) = http(addr, "GET", "/models", "");
    assert_eq!(status, 200);
    let doc = Json::parse(&body).unwrap();
    assert_eq!(doc.get("default").as_str(), Some("base"));
    let listed = doc.get("models").as_arr().unwrap();
    assert_eq!(listed.len(), 1);
    assert_eq!(listed[0].get("name").as_str(), Some("base"));
    assert_eq!(listed[0].get("backend_kind").as_str(), Some("native"));
    assert_eq!(listed[0].get("draining").as_bool(), Some(false));

    // POST /models: bad specs rejected, good one deployed, dup conflicts
    assert_eq!(http(addr, "POST", "/models", "{nope").0, 400);
    assert_eq!(http(addr, "POST", "/models", r#"{"backend": "native"}"#).0, 400);
    assert_eq!(http(addr, "POST", "/models", r#"{"name": "x", "backend": "gpu"}"#).0, 400);
    let spec = r#"{"name": "added", "backend": "native", "seed": 0, "k_ratio": 0.5, "batch": 2}"#;
    assert_eq!(http(addr, "POST", "/models", spec).0, 200);
    assert_eq!(http(addr, "POST", "/models", spec).0, 409, "duplicate name conflicts");

    // the runtime-added model serves traffic at its own operating point
    let (status, doc) = generate(addr, Some("added"), "the capital of ", 12);
    assert_eq!(status, 200);
    let reference = direct_engine_text(0, 0.5, 2, "the capital of ", 12);
    assert_eq!(doc.get("text").as_str().unwrap(), reference);

    // DELETE removes it from routing
    assert_eq!(http(addr, "DELETE", "/models/added", "").0, 200);
    assert_eq!(http(addr, "POST", "/generate", r#"{"prompt": "x", "model": "added"}"#).0, 404);
    let (_, body) = http(addr, "GET", "/models", "");
    assert!(!body.contains("added"), "deleted model still listed: {body}");

    reg.shutdown_all().unwrap();
}

#[test]
fn size_one_registry_matches_single_engine_path() {
    // one deployment, classic flags: this must behave exactly like the
    // pre-registry single-engine serve path
    let reg = registry_of(&["name=default,backend=native,seed=0,k=1.0,batch=4,queue=32"]);
    let addr = start_server(reg.clone());
    let prompt = "the capital of ";

    let (status, doc) = generate(addr, None, prompt, 24);
    assert_eq!(status, 200);
    let text = doc.get("text").as_str().unwrap().to_string();
    assert_eq!(text, direct_engine_text(0, 1.0, 4, prompt, 24), "registry of size 1 must \
                reproduce the single-engine output");
    for f in ["id", "tokens", "ttft_us", "total_us"] {
        assert!(doc.get(f).as_f64().is_some(), "legacy response field '{f}' missing");
    }
    // determinism across repeated requests (greedy sampler)
    let (_, doc2) = generate(addr, None, prompt, 24);
    assert_eq!(doc2.get("text").as_str(), Some(text.as_str()));

    // /stats keeps the legacy headline fields at the top level
    let (status, body) = http(addr, "GET", "/stats", "");
    assert_eq!(status, 200);
    let stats = Json::parse(&body).unwrap();
    for f in [
        "requests_done",
        "tokens_generated",
        "decode_tok_per_s",
        "mean_ttft_ms",
        "p99_ttft_ms",
        "h2o_evictions",
    ] {
        assert!(stats.get(f).as_f64().is_some(), "legacy stats field '{f}' missing");
    }
    assert_eq!(stats.get("requests_done").as_i64(), Some(2));
    // /metrics adds the kernel observability fields, as before
    let m = metrics(addr);
    for f in ["kernel_dense", "kernel_sparse", "kernel_packed", "decode_calls", "prefill_calls"] {
        assert!(m.get(f).as_f64().is_some(), "legacy metrics field '{f}' missing");
    }
    reg.shutdown_all().unwrap();
}

#[test]
fn delete_drains_in_flight_requests() {
    let reg = registry_of(&["name=victim,backend=native,seed=0,k=1.0,batch=2,queue=4"]);
    let addr = start_server(reg.clone());

    // a long-running request (no stop token, 100 tokens)...
    let worker = std::thread::spawn(move || {
        let body = r#"{"prompt": "the capital of ", "max_new_tokens": 100,
                       "stop_newline": false, "model": "victim"}"#;
        http(addr, "POST", "/generate", body)
    });
    // ...observed in flight through /metrics...
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let m = metrics(addr);
        if m.get("models").get("victim").get("queue_depth").as_i64() == Some(1) {
            break;
        }
        assert!(Instant::now() < deadline, "request never became visible in flight");
        std::thread::sleep(Duration::from_millis(2));
    }
    // ...survives DELETE: removal drains the lane instead of killing it
    assert_eq!(http(addr, "DELETE", "/models/victim", "").0, 200);
    let (status, body) = worker.join().unwrap();
    assert_eq!(status, 200, "in-flight request must drain to completion: {body}");
    let doc = Json::parse(&body).unwrap();
    assert_eq!(doc.get("tokens").as_i64(), Some(100), "drained request kept all its tokens");

    // the fleet no longer routes to it
    assert_eq!(http(addr, "POST", "/generate", r#"{"prompt": "x", "model": "victim"}"#).0, 404);
    let (_, body) = http(addr, "GET", "/models", "");
    let doc = Json::parse(&body).unwrap();
    assert_eq!(doc.get("models").as_arr().unwrap().len(), 0);
    assert_eq!(doc.get("default"), &Json::Null);
    reg.shutdown_all().unwrap();
}

#[test]
fn fleet_config_example_file_loads() {
    // the committed examples/fleet.json must stay a valid fleet config
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/fleet.json");
    let text = std::fs::read_to_string(path).expect("examples/fleet.json readable");
    let doc = Json::parse(&text).expect("examples/fleet.json parses");
    let reg = ModelRegistry::from_fleet_json(&doc, "no-such-artifacts-dir").unwrap();
    assert_eq!(reg.names(), vec!["exact".to_string(), "pruned".to_string()]);
    assert_eq!(reg.default_name().as_deref(), Some("exact"));
    let dep = reg.get(None).unwrap();
    assert_eq!(dep.backend_kind(), "native");
    reg.shutdown_all().unwrap();
}

#[test]
fn prefix_cache_knob_round_trips_all_three_surfaces() {
    // 1) CLI kv-spec surface
    let kv_spec =
        DeploymentSpec::parse_kv("name=shared,backend=native,batch=2,prefix=1,prefix_pages=32")
            .unwrap();
    assert!(kv_spec.prefix_cache);
    assert_eq!(kv_spec.prefix_cache_pages, 32);

    // 2) fleet-JSON surface (and the committed example demos the knob)
    let fleet = Json::parse(
        r#"{"models": [{"name": "cold", "backend": "native", "batch": 2,
                        "prefix_cache": false}]}"#,
    )
    .unwrap();
    let reg = ModelRegistry::from_fleet_json(&fleet, "no-such-artifacts-dir").unwrap();
    reg.deploy(kv_spec.clone()).unwrap();
    let example = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../examples/fleet.json"
    ))
    .unwrap();
    let example = Json::parse(&example).unwrap();
    let exact = example.get("models").idx(0);
    assert_eq!(exact.get("prefix_cache").as_bool(), Some(true), "fleet.json demos the knob");
    assert!(DeploymentSpec::from_json(exact).unwrap().prefix_cache);

    // 3) GET /models echo round-trips byte-for-byte through from_json
    let reg = Arc::new(reg);
    let addr = start_server(reg.clone());
    let (status, body) = http(addr, "GET", "/models", "");
    assert_eq!(status, 200);
    let doc = Json::parse(&body).unwrap();
    let models = doc.get("models").as_arr().unwrap();
    let echoed = models
        .iter()
        .find(|m| m.get("name").as_str() == Some("shared"))
        .expect("deployed model echoed");
    assert_eq!(echoed.get("prefix_cache").as_bool(), Some(true));
    assert_eq!(echoed.get("prefix_cache_pages").as_i64(), Some(32));
    let back = DeploymentSpec::from_json(echoed).unwrap();
    assert_eq!(back, kv_spec, "GET /models echo must round-trip the spec");
    let cold = models.iter().find(|m| m.get("name").as_str() == Some("cold")).unwrap();
    assert_eq!(cold.get("prefix_cache").as_bool(), Some(false));

    // the serving metrics expose the prefix/pool observability everywhere
    let m = metrics(addr);
    for field in ["prefix_hit_tokens", "prefix_hit_rate"] {
        assert!(m.get(field).as_f64().is_some(), "fleet aggregate missing {field}");
        assert!(
            m.get("models").get("shared").get(field).as_f64().is_some(),
            "per-model section missing {field}"
        );
    }
    for field in ["kv_pages_free", "kv_shared_pages", "kv_cow_copies"] {
        assert!(
            m.get("models").get("shared").get(field).as_f64().is_some(),
            "per-model section missing {field}"
        );
    }
    reg.shutdown_all().unwrap();
}
