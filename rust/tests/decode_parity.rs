//! Decode hot-path parity (hermetic): the sparse/packed score routings
//! must match the masked-dense oracle end-to-end under realistic serving
//! conditions — random k ∈ {d/4, d/2, d}, several batch sizes, and H2O
//! eviction interleavings driven by real attention mass — and the
//! lane-sharded multi-threaded backend must be *bit-identical* to the
//! single-threaded native backend at every thread count.
//!
//! CI runs this file under `--release` too (the sharded scheduling is
//! timing-sensitive in ways a debug build can mask).

use std::sync::Arc;

use aqua_serve::aqua::policy::AquaConfig;
use aqua_serve::coordinator::h2o::H2oPolicy;
use aqua_serve::coordinator::kvcache::LaneKv;
use aqua_serve::coordinator::{Engine, EngineConfig, GenRequest};
use aqua_serve::model::config::ModelConfig;
use aqua_serve::runtime::{
    AquaKnobs, BackendSpec, ExecBackend, NativeBackend, NativeModel, ScoreMode, ShardedBackend,
};
use aqua_serve::util::prng::Rng;

/// Drive identical decode traffic through several backends: random tokens,
/// per-lane write cursors, and slot masks evolved by an H2O policy fed the
/// *first* backend's attention mass (so every backend sees the exact same
/// eviction interleaving). Returns each backend's per-step logits.
fn drive_parity(
    backends: &mut [&mut dyn ExecBackend],
    b: usize,
    k_dims: usize,
    steps: usize,
    h2o: &H2oPolicy,
    seed: u64,
) -> Vec<Vec<Vec<f32>>> {
    let cfg = backends[0].model_config().clone();
    let (s_cap, d, n_layers) = (cfg.max_seq, cfg.d_head, cfg.n_layers);
    assert!(steps < s_cap, "test drives more steps than KV capacity");
    let knobs = AquaKnobs { k_dims, dim_keep: vec![1.0; d], use_projection: true };
    let mut rng = Rng::new(seed);
    for be in backends.iter_mut() {
        be.empty_cache(b).unwrap();
    }
    let mut lanes: Vec<LaneKv> = (0..b).map(|_| LaneKv::new(s_cap)).collect();
    let mut outs: Vec<Vec<Vec<f32>>> = vec![vec![]; backends.len()];
    for _ in 0..steps {
        let tokens: Vec<i32> = (0..b).map(|_| 32 + rng.below(90) as i32).collect();
        let pos: Vec<i32> = lanes.iter().map(|l| l.len as i32).collect();
        let mut mask = vec![0.0f32; b * s_cap];
        for (lane, kv) in lanes.iter().enumerate() {
            mask[lane * s_cap..(lane + 1) * s_cap].copy_from_slice(&kv.slot_mask);
        }
        let mut step_outs = vec![];
        for be in backends.iter_mut() {
            step_outs.push(be.decode(b, &tokens, &pos, &mask, &knobs).unwrap());
        }
        for lane in 0..b {
            lanes[lane].commit_write(1);
            let mut mass = vec![0.0f32; s_cap];
            for l in 0..n_layers {
                let base = (l * b + lane) * s_cap;
                for s in 0..s_cap {
                    mass[s] += step_outs[0].attn_acc[base + s];
                }
            }
            lanes[lane].accumulate(&mass);
            h2o.apply(&mut lanes[lane]);
        }
        for (i, o) in step_outs.into_iter().enumerate() {
            outs[i].push(o.logits);
        }
    }
    outs
}

#[test]
fn sparse_and_packed_decode_match_masked_oracle_under_h2o() {
    let cfg = ModelConfig::tiny("parity");
    let d = cfg.d_head;
    let model = Arc::new(NativeModel::new(cfg, 0xBEEF).unwrap());
    // ratio 0.3 evicts hard enough that Auto's subset-sparse route fires
    // (2·live < prefix) on later steps, so all three kernels are exercised
    let h2o = H2oPolicy::new(0.3, 3);
    for &k_dims in &[d / 4, d / 2, d] {
        for &b in &[1usize, 3] {
            let mut oracle = NativeBackend::from_model(model.clone());
            oracle.set_score_mode(ScoreMode::MaskedDense);
            let mut sparse = NativeBackend::from_model(model.clone());
            sparse.set_score_mode(ScoreMode::Sparse);
            let mut packed = NativeBackend::from_model(model.clone());
            packed.set_score_mode(ScoreMode::Packed);
            let mut auto = NativeBackend::from_model(model.clone());
            let mut bes: Vec<&mut dyn ExecBackend> =
                vec![&mut oracle, &mut sparse, &mut packed, &mut auto];
            let outs = drive_parity(&mut bes, b, k_dims, 30, &h2o, 42 + k_dims as u64);
            for (name, i) in [("sparse", 1usize), ("packed", 2), ("auto", 3)] {
                for (step, (a, c)) in outs[0].iter().zip(&outs[i]).enumerate() {
                    let diff =
                        a.iter().zip(c.iter()).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
                    assert!(
                        diff <= 1e-4,
                        "{name} vs oracle: diff {diff} at step {step} (k={k_dims}, b={b})"
                    );
                }
            }
        }
    }
}

#[test]
fn sharded_backend_is_bit_identical_to_native() {
    let cfg = ModelConfig::tiny("parity-shard");
    let d = cfg.d_head;
    let model = Arc::new(NativeModel::new(cfg, 0xFEED).unwrap());
    let h2o = H2oPolicy::new(0.5, 4);
    for &threads in &[1usize, 2, 4] {
        let mut native = NativeBackend::from_model(model.clone());
        let mut sharded = ShardedBackend::from_model(model.clone(), threads);
        let mut bes: Vec<&mut dyn ExecBackend> = vec![&mut native, &mut sharded];
        let outs = drive_parity(&mut bes, 8, d / 2, 24, &h2o, 7);
        for (step, (a, s)) in outs[0].iter().zip(&outs[1]).enumerate() {
            assert_eq!(a, s, "sharded(threads={threads}) logits diverged at step {step}");
        }
    }
}

#[test]
fn engine_results_identical_across_native_and_sharded_specs() {
    let cfg = ModelConfig::tiny("parity-engine");
    let run = |spec: BackendSpec| {
        let aqua = AquaConfig { k_ratio: 0.5, h2o_ratio: 0.6, ..Default::default() };
        let mut engine =
            Engine::with_spec(&spec, EngineConfig { batch: 4, aqua, ..Default::default() })
                .unwrap();
        let reqs: Vec<GenRequest> = (0..6)
            .map(|i| GenRequest::new(i as u64 + 1, vec![65 + i as i32, 66, 67, 68], 16))
            .collect();
        let results = engine.run_batch(reqs).unwrap();
        let snap = engine.metrics.snapshot();
        (results.into_iter().map(|r| r.tokens).collect::<Vec<_>>(), snap)
    };
    let (native_tokens, ns) = run(BackendSpec::native(cfg.clone(), 5).unwrap());
    let (sharded_tokens, ss) = run(BackendSpec::sharded(cfg, 5, 3).unwrap());
    assert_eq!(native_tokens, sharded_tokens, "greedy generations diverged across backends");
    // kernel observability flows through the engine for both backends, and
    // the sharded split does not change how many head-calls ran
    assert!(ns.kernels.calls() > 0 && ss.kernels.calls() > 0);
    assert_eq!(ns.kernels.calls(), ss.kernels.calls());
    assert!(ss.kernels.packed > 0, "k=0.5 decode should route packed");
}
