//! Runtime-level integration: HLO loading, decode/prefill consistency,
//! HLO-vs-native-kernel numeric cross-check. Skips without artifacts.
//! The backend-generic equivalents live in `runtime::native` unit tests.
#![cfg(feature = "pjrt")]

use aqua_serve::runtime::{Artifacts, ModelRuntime};

#[test]
fn runtime_decode_prefill_consistency() {
    let Ok(arts) = Artifacts::load(aqua_serve::ARTIFACTS_DIR) else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = ModelRuntime::load(arts.model("llama-analog").unwrap()).unwrap();
    let cfg = rt.cfg.clone();
    let d = cfg.d_head;
    let s_cap = cfg.max_seq;
    let keep = vec![1.0f32; d];

    // Feed 8 tokens one-by-one via decode; then the same 8 via one prefill
    // chunk; the resulting logits for the last position must agree.
    let toks: Vec<i32> = "the blue ".bytes().map(|b| b as i32).collect();
    let n = toks.len().min(8);

    // decode chain (b=1)
    let (mut kc, mut vc) = rt.empty_cache(1).unwrap();
    let mut mask = vec![0.0f32; s_cap];
    let mut last_logits = vec![];
    for (i, &t) in toks.iter().take(n).enumerate() {
        let out = rt
            .decode(1, &[t], &[i as i32], &kc, &vc, &mask, d as i32, &keep, true)
            .unwrap();
        kc = out.k_cache;
        vc = out.v_cache;
        mask[i] = 1.0;
        last_logits = out.logits;
        // logits finite
        assert!(last_logits.iter().all(|x| x.is_finite()));
        // attn mass ≈ n_layers * n_q (each head's row sums to 1)
        let mass: f32 = out.attn_acc.iter().sum();
        let expect = (cfg.n_layers * cfg.n_q_heads) as f32;
        assert!((mass - expect).abs() < 1e-2, "attn mass {mass} vs {expect}");
    }

    // prefill chunk (b=1), pad to chunk length
    let chunk = rt.prefill_chunk;
    let mut ptoks = vec![0i32; chunk];
    ptoks[..n].copy_from_slice(&toks[..n]);
    let (kc2, vc2) = rt.empty_cache(1).unwrap();
    let mask2 = vec![0.0f32; s_cap];
    let out = rt
        .prefill(1, &ptoks, &[0], &kc2, &vc2, &mask2, d as i32, &keep, true)
        .unwrap();
    let vocab = cfg.vocab;
    let pre_logits = &out.logits[(n - 1) * vocab..n * vocab];
    let max_diff = pre_logits
        .iter()
        .zip(&last_logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-3, "prefill/decode disagree by {max_diff}");

    // model slot mask marks exactly the chunk's positions
    assert!(out.slot_mask[..chunk].iter().all(|&m| m > 0.5));
    assert!(out.slot_mask[chunk..].iter().all(|&m| m < 0.5));

    // knob inputs actually matter: k=2 must change the logits
    let out_k2 = rt
        .decode(1, &[toks[0]], &[n as i32], &kc, &vc, &mask, 2, &keep, true)
        .unwrap();
    let out_kd = rt
        .decode(1, &[toks[0]], &[n as i32], &kc, &vc, &mask, d as i32, &keep, true)
        .unwrap();
    let diff: f32 = out_k2
        .logits
        .iter()
        .zip(&out_kd.logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(diff > 1e-4, "k_dims input has no effect");

    // AQUA-Memory dim_keep must change cached keys (and logits downstream)
    let mut keep_sliced = vec![1.0f32; d];
    for k in keep_sliced.iter_mut().skip(d - d / 4) {
        *k = 0.0;
    }
    let out_mem = rt
        .decode(1, &[toks[0]], &[n as i32], &kc, &vc, &mask, d as i32, &keep_sliced, true)
        .unwrap();
    let diff: f32 = out_mem
        .logits
        .iter()
        .zip(&out_kd.logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(diff > 1e-5, "dim_keep input has no effect");
}

#[test]
fn manifest_covers_both_models() {
    let Ok(arts) = Artifacts::load(aqua_serve::ARTIFACTS_DIR) else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    for name in ["llama-analog", "olmoe-analog"] {
        let m = arts.model(name).unwrap();
        assert!(m.hlo.contains_key("decode_b1"), "{name} missing decode_b1");
        assert!(m.hlo.contains_key("decode_b4"), "{name} missing decode_b4");
        assert!(m.params_npz.exists());
        assert!(m.proj_npz.exists());
    }
    // GQA vs MHA contrast present (the Table 1 architecture axis)
    assert_eq!(arts.model("llama-analog").unwrap().config.group_size(), 4);
    assert!(arts.model("olmoe-analog").unwrap().config.is_mha());
}
