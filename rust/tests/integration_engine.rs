//! Integration tests over the full stack: artifacts → PJRT runtime →
//! engine. One #[test] per concern-group, executed sequentially inside
//! (PJRT handles are !Send; a single ModelRuntime is reused).
//!
//! Skipped (pass trivially) when artifacts are not built. The hermetic
//! equivalents that always run live in `integration_native.rs`.
#![cfg(feature = "pjrt")]

use std::sync::Arc;

use aqua_serve::aqua::policy::AquaConfig;
use aqua_serve::coordinator::{Engine, EngineConfig, FinishReason, GenRequest};
use aqua_serve::runtime::{Artifacts, ExecBackend, ModelRuntime, PjrtBackend};
use aqua_serve::tokenizer::ByteTokenizer;

fn artifacts() -> Option<Artifacts> {
    let a = Artifacts::load(aqua_serve::ARTIFACTS_DIR).ok()?;
    Some(a)
}

fn backend(rt: &Arc<ModelRuntime>) -> Box<dyn ExecBackend> {
    Box::new(PjrtBackend::new(rt.clone()))
}

fn greedy(engine: &mut Engine, prompt: &str, n: usize) -> (String, FinishReason) {
    let tok = ByteTokenizer;
    let mut req = GenRequest::new(1, tok.encode(prompt), n);
    req.stop_token = Some(b'\n' as i32);
    let res = engine.run_batch(vec![req]).expect("run").remove(0);
    (tok.decode(&res.tokens), res.finish)
}

#[test]
fn engine_end_to_end() {
    let Some(arts) = artifacts() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = Arc::new(ModelRuntime::load(arts.model("llama-analog").unwrap()).unwrap());

    // --- determinism: greedy generation is reproducible -------------------
    let mut e1 = Engine::new(backend(&rt), EngineConfig { batch: 1, ..Default::default() }).unwrap();
    let (a, _) = greedy(&mut e1, "the capital of ", 24);
    let (b, _) = greedy(&mut e1, "the capital of ", 24);
    assert_eq!(a, b, "greedy generation must be deterministic");
    assert!(!a.is_empty());

    // --- batch invariance: B=1 and B=4 lanes give the same greedy text ----
    let mut e4 = Engine::new(backend(&rt), EngineConfig { batch: 4, ..Default::default() }).unwrap();
    let tok = ByteTokenizer;
    let reqs: Vec<GenRequest> = (0..4)
        .map(|i| {
            let mut r = GenRequest::new(i + 1, tok.encode("the capital of "), 24);
            r.stop_token = Some(b'\n' as i32);
            r
        })
        .collect();
    let results = e4.run_batch(reqs).unwrap();
    for r in &results {
        assert_eq!(tok.decode(&r.tokens), a, "lane output differs from B=1 output");
    }

    // --- mixed-length batch: continuous batching must not cross-talk ------
    // lanes finish at different times; each result must equal its B=1 run.
    let prompts = ["the capital of ", "the color of ", "7 plus 5 equals", "the "];
    let mut singles = vec![];
    for p in prompts {
        singles.push(greedy(&mut e1, p, 16).0);
    }
    let reqs: Vec<GenRequest> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let mut r = GenRequest::new(i as u64 + 50, tok.encode(p), 16);
            r.stop_token = Some(b'\n' as i32);
            r
        })
        .collect();
    let mixed = e4.run_batch(reqs).unwrap();
    for (res, single) in mixed.iter().zip(&singles) {
        assert_eq!(&tok.decode(&res.tokens), single, "lane cross-talk detected");
    }

    // --- rotation invariance through the whole stack ----------------------
    // k_ratio=1.0 + calibrated orthogonal P must match the identity-P
    // baseline (Lemma A.4), end to end.
    let mut eb = Engine::new(
        backend(&rt),
        EngineConfig { batch: 1, aqua: AquaConfig::baseline(), ..Default::default() },
    )
    .unwrap();
    let (base, _) = greedy(&mut eb, "the color of ", 24);
    let mut ep = Engine::new(
        backend(&rt),
        EngineConfig {
            batch: 1,
            aqua: AquaConfig { k_ratio: 1.0, ..Default::default() },
            ..Default::default()
        },
    )
    .unwrap();
    let (rot, _) = greedy(&mut ep, "the color of ", 24);
    assert_eq!(base, rot, "orthogonal projection at k=d changed the output");

    // --- score_only: prompt logprobs are sane ------------------------------
    let mut req = GenRequest::new(9, tok.encode("the capital of "), 0);
    req.score_only = true;
    let res = eb.run_batch(vec![req]).unwrap().remove(0);
    assert_eq!(res.prompt_logprobs.len(), "the capital of ".len() - 1);
    assert!(res.prompt_logprobs.iter().all(|&lp| lp <= 0.0 && lp.is_finite()));
    assert!(res.tokens.is_empty());

    // --- moderate pruning barely moves scores; aggressive pruning does ----
    let score = |engine: &mut Engine| -> f64 {
        let mut req = GenRequest::new(11, tok.encode("the capital of "), 0);
        req.score_only = true;
        let res = engine.run_batch(vec![req]).unwrap().remove(0);
        res.prompt_logprobs.iter().map(|&x| x as f64).sum()
    };
    let base_lp = score(&mut eb);
    let mut e75 = Engine::new(
        backend(&rt),
        EngineConfig {
            batch: 1,
            aqua: AquaConfig { k_ratio: 0.75, ..Default::default() },
            ..Default::default()
        },
    )
    .unwrap();
    let lp75 = score(&mut e75);
    let mut e10 = Engine::new(
        backend(&rt),
        EngineConfig {
            batch: 1,
            aqua: AquaConfig { k_ratio: 0.1, ..Default::default() },
            ..Default::default()
        },
    )
    .unwrap();
    let lp10 = score(&mut e10);
    assert!((base_lp - lp75).abs() < (base_lp - lp10).abs(),
            "k=0.75 ({lp75:.3}) should be closer to baseline ({base_lp:.3}) than k=0.1 ({lp10:.3})");

    // --- H2O eviction engages and output stays sane ------------------------
    let corpus = std::fs::read(arts.corpus_path("valid").unwrap()).unwrap();
    let long_prompt = tok.encode_bytes(&corpus[..300]);
    let mut eh = Engine::new(
        backend(&rt),
        EngineConfig {
            batch: 1,
            aqua: AquaConfig { k_ratio: 0.75, h2o_ratio: 0.25, ..Default::default() },
            h2o_recent_window: 8,
            ..Default::default()
        },
    )
    .unwrap();
    let mut req = GenRequest::new(21, long_prompt, 16);
    req.stop_token = None;
    let res = eh.run_batch(vec![req]).unwrap().remove(0);
    assert_eq!(res.tokens.len(), 16);
    assert!(eh.metrics.snapshot().h2o_evictions > 0, "H2O at ratio 0.25 must evict");

    // --- request validation -------------------------------------------------
    let too_long = GenRequest::new(31, vec![1i32; rt.cfg.max_seq + 1], 4);
    let res = eb.run_batch(vec![too_long]).unwrap().remove(0);
    assert_eq!(res.finish, FinishReason::PromptTooLong);

    // --- AQUA-Memory: dim slice still produces coherent output -------------
    let mut em = Engine::new(
        backend(&rt),
        EngineConfig {
            batch: 1,
            aqua: AquaConfig { k_ratio: 0.9, s_ratio: 0.1, ..Default::default() },
            ..Default::default()
        },
    )
    .unwrap();
    let (mem_out, _) = greedy(&mut em, "the capital of ", 24);
    assert!(!mem_out.is_empty());
}
