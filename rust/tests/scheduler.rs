//! Continuous-scheduler tests: chunked-prefill interleaving bounds decode
//! stalls, token budgets defer-or-reject correctly, and none of it changes
//! a single greedy output bit (native, sharded, H2O on/off).

use aqua_serve::aqua::policy::AquaConfig;
use aqua_serve::coordinator::engine::{plan_prefill, EngineCmd, EngineHandle};
use aqua_serve::coordinator::{Engine, EngineConfig, FinishReason, GenRequest};
use aqua_serve::model::config::ModelConfig;
use aqua_serve::runtime::{synthetic_corpus, BackendSpec, NATIVE_PREFILL_CHUNK};
use aqua_serve::tokenizer::ByteTokenizer;
use aqua_serve::util::testkit::check;

fn native_spec(seed: u64) -> BackendSpec {
    BackendSpec::native(ModelConfig::tiny("sched-test"), seed).unwrap()
}

fn prompt_of(len: usize, salt: usize) -> Vec<i32> {
    let corpus = synthetic_corpus(4096, 11);
    ByteTokenizer.encode_bytes(&corpus[salt..salt + len])
}

// ---------------------------------------------------------------------------
// Starvation bound (the bug this scheduler fixes), measured in engine steps
// so it is fully deterministic: with interleaving on, a long prefill never
// blocks in-flight decode for more than one consecutive scheduling pass;
// with the legacy FIFO scheduler the same injection stalls decode for the
// whole chunk-by-chunk prefill.
// ---------------------------------------------------------------------------

/// Warm `decode_lanes` short requests into steady decode, inject one
/// `long_len`-token prompt, and return the longest run of consecutive
/// steps during which no decode token was produced (until the long
/// request completes).
fn max_decode_stall(interleave: bool, long_len: usize) -> usize {
    let spec = native_spec(42);
    let max_seq = spec.model_config().max_seq;
    let mut e = Engine::with_spec(
        &spec,
        EngineConfig {
            batch: 4,
            max_batch_prefill_tokens: if interleave { NATIVE_PREFILL_CHUNK } else { 0 },
            interleave,
            ..Default::default()
        },
    )
    .unwrap();

    // three short-prompt lanes with enough max_new to decode throughout
    for i in 0..3u64 {
        let req = GenRequest::new(i + 1, prompt_of(8, 31 * i as usize), max_seq - 16);
        assert!(e.submit(req));
    }
    // warm until every lane has produced decode tokens
    let mut guard = 0;
    while e.metrics.snapshot().tokens_generated < 6 {
        assert!(e.step().unwrap(), "engine went idle during warmup");
        guard += 1;
        assert!(guard < 1000, "warmup never produced decode tokens");
    }

    // inject the long prompt and watch decode progress step by step
    assert!(long_len + 8 <= max_seq);
    assert!(e.submit(GenRequest::new(9, prompt_of(long_len, 7), 4)));
    let mut prev = e.metrics.snapshot().tokens_generated;
    let (mut stall, mut max_stall) = (0usize, 0usize);
    let mut guard = 0;
    while e.take_result(9).is_none() {
        assert!(e.step().unwrap(), "engine went idle with request 9 pending");
        let now = e.metrics.snapshot().tokens_generated;
        if now > prev {
            stall = 0;
        } else {
            stall += 1;
            max_stall = max_stall.max(stall);
        }
        prev = now;
        guard += 1;
        assert!(guard < 10_000, "request 9 never completed");
    }
    max_stall
}

#[test]
fn interleave_keeps_decode_advancing_during_long_prefill() {
    let long_len = 8 * NATIVE_PREFILL_CHUNK; // 8 whole chunks
    let stalled = max_decode_stall(true, long_len);
    assert!(
        stalled <= 1,
        "interleaved scheduler stalled decode for {stalled} consecutive steps"
    );
}

#[test]
fn fifo_scheduler_starves_decode_during_long_prefill() {
    // The regression this PR fixes: absolute prefill priority runs every
    // chunk back-to-back, so decode stalls for ~long_len/chunk steps.
    let long_len = 8 * NATIVE_PREFILL_CHUNK;
    let stalled = max_decode_stall(false, long_len);
    assert!(
        stalled >= long_len / NATIVE_PREFILL_CHUNK - 1,
        "expected legacy FIFO to stall decode for the whole prefill, got {stalled}"
    );
}

// ---------------------------------------------------------------------------
// Bit-parity: scheduling is invisible to the math. Greedy outputs (tokens,
// finish reasons, generation and teacher-forced logprobs, bit-for-bit) are
// identical whether the scheduler interleaves, budgets, and overtakes — or
// runs the legacy FIFO — across native and sharded backends, H2O on or off.
// ---------------------------------------------------------------------------

fn parity_requests() -> Vec<GenRequest> {
    let shapes: &[(usize, usize)] =
        &[(12, 12), (130, 8), (30, 16), (8, 20), (60, 10), (20, 12)];
    shapes
        .iter()
        .enumerate()
        .map(|(i, &(plen, max_new))| {
            GenRequest::new(i as u64 + 1, prompt_of(plen, 17 * i), max_new)
        })
        .collect()
}

#[test]
fn scheduler_outputs_bit_identical_to_fifo_greedy() {
    let cfg_tiny = ModelConfig::tiny("sched-parity");
    let specs: Vec<BackendSpec> = vec![
        BackendSpec::native(cfg_tiny.clone(), 42).unwrap(),
        BackendSpec::sharded(cfg_tiny.clone(), 42, 2).unwrap(),
        BackendSpec::sharded(cfg_tiny.clone(), 42, 4).unwrap(),
    ];
    let aquas: Vec<(AquaConfig, usize)> = vec![
        // (aqua knobs, h2o_recent_window)
        (AquaConfig { k_ratio: 0.75, ..Default::default() }, 16),
        (AquaConfig { k_ratio: 0.75, h2o_ratio: 0.25, ..Default::default() }, 8),
    ];
    for spec in &specs {
        for (aqua, window) in &aquas {
            let base = EngineConfig {
                batch: 3,
                aqua: aqua.clone(),
                h2o_recent_window: *window,
                ..Default::default()
            };
            // reference: legacy FIFO scheduler
            let fifo = EngineConfig { interleave: false, ..base.clone() };
            // chunked interleaving with a per-pass prefill budget
            let chunked = EngineConfig {
                interleave: true,
                max_batch_prefill_tokens: NATIVE_PREFILL_CHUNK,
                ..base.clone()
            };
            // budgets tight enough to defer admissions and trigger
            // pressure overtakes (every request still fits alone)
            let budgeted = EngineConfig {
                interleave: true,
                max_batch_prefill_tokens: NATIVE_PREFILL_CHUNK,
                max_batch_total_tokens: 200,
                waiting_served_ratio: 1.0,
                ..base.clone()
            };

            let run = |cfg: EngineConfig| {
                let mut e = Engine::with_spec(spec, cfg).unwrap();
                e.run_batch(parity_requests()).unwrap()
            };
            let want = run(fifo);
            for (label, cfg) in [("chunked", chunked), ("budgeted", budgeted)] {
                let got = run(cfg);
                assert_eq!(want.len(), got.len());
                for (a, b) in want.iter().zip(&got) {
                    assert_eq!(a.id, b.id);
                    assert_eq!(a.finish, b.finish, "req {} finish ({label})", a.id);
                    assert_eq!(a.tokens, b.tokens, "req {} tokens ({label})", a.id);
                    // logprobs must match bit-for-bit, not approximately:
                    // the scheduler feeds whole chunks only, so the
                    // computed values are the same floats
                    assert_eq!(
                        a.gen_logprobs, b.gen_logprobs,
                        "req {} gen_logprobs ({label})",
                        a.id
                    );
                    assert_eq!(
                        a.prompt_logprobs, b.prompt_logprobs,
                        "req {} prompt_logprobs ({label})",
                        a.id
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// plan_prefill: whole chunks, budget respected, greedy, no lane skipped
// that still fits. Property-tested over random lane shapes.
// ---------------------------------------------------------------------------

#[test]
fn prop_plan_prefill_whole_chunks_and_budget() {
    #[derive(Debug)]
    struct Case {
        remaining: Vec<usize>,
        chunk: usize,
        budget: usize,
    }
    check(
        "plan-prefill-invariants",
        300,
        |g| {
            let lanes = 1 + g.rng.below(8);
            Case {
                remaining: (0..lanes).map(|_| g.rng.below(200)).collect(),
                chunk: 1 + g.rng.below(32),
                budget: g.rng.below(64),
            }
        },
        |c| {
            let mut fed = vec![0usize; c.remaining.len()];
            let used = plan_prefill(&c.remaining, c.chunk, c.budget, &mut fed);
            let effective =
                if c.budget == 0 { usize::MAX } else { c.budget.max(c.chunk) };
            if fed.iter().sum::<usize>() != used {
                return Err(format!("used {used} != sum {fed:?}"));
            }
            if used > effective {
                return Err(format!("used {used} over budget {effective}"));
            }
            let mut before = 0usize;
            for (i, (&f, &rem)) in fed.iter().zip(&c.remaining).enumerate() {
                let slice = rem.min(c.chunk);
                if f != 0 && f != slice {
                    return Err(format!("lane {i} fed partial slice {f} != {slice}"));
                }
                if rem == 0 && f != 0 {
                    return Err(format!("lane {i} fed with nothing remaining"));
                }
                // greedy: a lane is only skipped when its slice overflows
                if rem > 0 && f == 0 && before + slice <= effective {
                    return Err(format!("lane {i} skipped though {slice} fits"));
                }
                before += f;
            }
            // a prefill pass with work always makes progress
            if c.remaining.iter().any(|&r| r > 0) && used == 0 {
                return Err("pass made no progress".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Scheduler gauges flow into the metrics snapshot.
// ---------------------------------------------------------------------------

#[test]
fn scheduler_gauges_populate_after_a_run() {
    let spec = native_spec(5);
    let mut e = Engine::with_spec(
        &spec,
        EngineConfig {
            batch: 2,
            max_batch_prefill_tokens: NATIVE_PREFILL_CHUNK,
            ..Default::default()
        },
    )
    .unwrap();
    let reqs: Vec<GenRequest> =
        (0..4).map(|i| GenRequest::new(i + 1, prompt_of(40, 13 * i as usize), 8)).collect();
    let results = e.run_batch(reqs).unwrap();
    assert!(results.iter().all(|r| r.finish == FinishReason::Length));

    let s = e.metrics.snapshot();
    assert!(s.sched_steps > 0, "sched_steps not counted");
    assert!(s.prefill_calls > 0 && s.decode_calls > 0);
    assert!(
        s.batch_occupancy > 0.0 && s.batch_occupancy <= 1.0,
        "batch_occupancy {} out of range",
        s.batch_occupancy
    );
    assert!(s.prefill_tokens_per_step > 0.0);
    assert!(s.queue_wait_p50_ms.is_finite() && s.queue_wait_p50_ms >= 0.0);
    assert!(s.queue_wait_p99_ms >= s.queue_wait_p50_ms);
    // 8 new tokens per request → at least 7 inter-token gaps recorded each
    assert!(s.itl_mean_ms.is_finite() && s.itl_mean_ms >= 0.0);
    assert!(s.itl_p99_ms.is_finite() && s.itl_p99_ms >= 0.0);
}

// ---------------------------------------------------------------------------
// Duplicate request ids: refused at submit, synthesized as terminal
// results, and leak-proof through the pump thread.
// ---------------------------------------------------------------------------

#[test]
fn duplicate_ids_are_rejected_at_submit() {
    let spec = native_spec(9);
    let mut e = Engine::with_spec(&spec, EngineConfig::default()).unwrap();
    assert!(e.submit(GenRequest::new(1, prompt_of(8, 0), 4)));
    // same id while queued: refused
    assert!(!e.submit(GenRequest::new(1, prompt_of(8, 40), 4)));
    e.run_until_idle().unwrap();
    // same id while its result is still unclaimed: refused
    assert!(!e.submit(GenRequest::new(1, prompt_of(8, 80), 4)));
    let first = e.take_result(1).expect("original result survives duplicates");
    assert_eq!(first.finish, FinishReason::Length);
    assert_eq!(first.tokens.len(), 4);
    // once claimed, the id is free again
    assert!(e.submit(GenRequest::new(1, prompt_of(8, 120), 4)));
    e.run_until_idle().unwrap();
    assert!(e.take_result(1).is_some());
    let s = e.metrics.snapshot();
    assert_eq!(s.requests_rejected, 2);
    assert_eq!(s.requests_done, 4); // 2 served + 2 duplicate rejects
}

#[test]
fn run_batch_synthesizes_duplicate_results_in_order() {
    let spec = native_spec(9);
    let mut e = Engine::with_spec(&spec, EngineConfig::default()).unwrap();
    let reqs = vec![
        GenRequest::new(1, prompt_of(8, 0), 4),
        GenRequest::new(1, prompt_of(12, 50), 6), // duplicate id
        GenRequest::new(2, prompt_of(8, 100), 4),
    ];
    let results = e.run_batch(reqs).unwrap();
    assert_eq!(results.len(), 3);
    assert_eq!(results[0].id, 1);
    assert_eq!(results[0].finish, FinishReason::Length);
    assert_eq!(results[0].tokens.len(), 4, "first submission keeps the id");
    assert_eq!(results[1].id, 1);
    assert_eq!(results[1].finish, FinishReason::DuplicateId);
    assert!(results[1].tokens.is_empty());
    assert_eq!(results[2].id, 2);
    assert_eq!(results[2].finish, FinishReason::Length);
}

#[test]
fn engine_handle_pump_answers_duplicates_without_leaking() {
    let h = EngineHandle::spawn(|| {
        Engine::with_spec(
            &BackendSpec::native(ModelConfig::tiny("sched-handle"), 7)?,
            EngineConfig { batch: 2, ..Default::default() },
        )
    });
    let send = |req: GenRequest| h.cmd_tx.send(EngineCmd::Submit(req)).unwrap();
    send(GenRequest::new(1, prompt_of(8, 0), 4));
    send(GenRequest::new(1, prompt_of(8, 30), 4)); // duplicate
    send(GenRequest::new(2, prompt_of(8, 60), 4));
    h.cmd_tx.send(EngineCmd::Shutdown).unwrap();
    let mut results = vec![];
    while let Ok(r) = h.result_rx.recv() {
        results.push(r);
    }
    h.join.join().unwrap();
    assert_eq!(results.len(), 3, "every submission answered exactly once");
    let dup: Vec<&_> =
        results.iter().filter(|r| r.finish == FinishReason::DuplicateId).collect();
    assert_eq!(dup.len(), 1);
    assert_eq!(dup[0].id, 1);
    for id in [1u64, 2] {
        let real = results
            .iter()
            .find(|r| r.id == id && r.finish == FinishReason::Length)
            .unwrap_or_else(|| panic!("request {id} never completed"));
        assert_eq!(real.tokens.len(), 4);
    }
}

// ---------------------------------------------------------------------------
// Token-budget admission: requests that fit alone are serialized (deferred,
// never dropped); requests that can never fit are terminally rejected and
// reconcile through the rejected counter.
// ---------------------------------------------------------------------------

#[test]
fn total_token_budget_serializes_and_rejects() {
    let spec = native_spec(3);
    let mut e = Engine::with_spec(
        &spec,
        EngineConfig {
            batch: 4,
            interleave: true,
            max_batch_prefill_tokens: NATIVE_PREFILL_CHUNK,
            max_batch_total_tokens: 64,
            waiting_served_ratio: 1.0,
            ..Default::default()
        },
    )
    .unwrap();
    let reqs = vec![
        // want = 40 each: both fit alone, never together (80 > 64)
        GenRequest::new(1, prompt_of(8, 0), 32),
        GenRequest::new(2, prompt_of(8, 90), 32),
        // want = 90 > 64: impossible at this budget even on an empty
        // engine — must be rejected, not deferred forever
        GenRequest::new(3, prompt_of(30, 180), 60),
    ];
    let results = e.run_batch(reqs).unwrap();
    assert_eq!(results[0].finish, FinishReason::Length);
    assert_eq!(results[0].tokens.len(), 32);
    assert_eq!(results[1].finish, FinishReason::Length);
    assert_eq!(results[1].tokens.len(), 32);
    assert_eq!(results[2].finish, FinishReason::OverKvBudget);
    assert!(results[2].tokens.is_empty());

    let s = e.metrics.snapshot();
    assert_eq!(s.requests_done, 3, "every submission reaches a terminal state");
    assert_eq!(s.requests_rejected, 1);
}
