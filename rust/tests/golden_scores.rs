//! Golden parity: the AQUA score kernels against checked-in integer
//! fixtures (exact in f32), and the sparse path against dense end-to-end
//! through the engine on the native backend. Hermetic — no artifacts.

use aqua_serve::aqua::native::{aqua_scores_masked, aqua_scores_sparse, dense_scores};
use aqua_serve::aqua::policy::AquaConfig;
use aqua_serve::coordinator::{Engine, EngineConfig, GenRequest};
use aqua_serve::model::config::ModelConfig;
use aqua_serve::runtime::BackendSpec;
use aqua_serve::tensor::topk::{topk_indices_by_abs, topk_mask_by_abs};
use aqua_serve::tokenizer::ByteTokenizer;
use aqua_serve::util::json::Json;

fn fixture() -> Json {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/aqua_scores.json");
    let text = std::fs::read_to_string(path).expect("fixture file");
    Json::parse(&text).expect("fixture json")
}

fn f32s(j: &Json) -> Vec<f32> {
    j.as_arr().expect("array").iter().map(|v| v.as_f64().unwrap() as f32).collect()
}

#[test]
fn kernels_match_checked_in_fixtures() {
    let fix = fixture();
    let d = fix.req_i64("d").unwrap() as usize;
    let seq = fix.req_i64("seq").unwrap() as usize;
    let q = f32s(fix.get("q"));
    let keys = f32s(fix.get("keys"));
    let dense_expected = f32s(fix.get("dense"));
    assert_eq!(q.len(), d);
    assert_eq!(keys.len(), seq * d);

    // dense baseline matches
    let mut out = vec![0.0f32; seq];
    dense_scores(&q, &keys, seq, d, &mut out);
    assert_eq!(out, dense_expected, "dense_scores drifted from fixture");

    // every k case: sparse gather == masked-dense == fixture (exact — the
    // fixture is integer-valued, so no tolerance is needed)
    let cases = fix.get("cases").as_arr().expect("cases");
    assert_eq!(cases.len(), 3, "fixture should cover k in {{d/4, d/2, d}}");
    for case in cases {
        let k = case.req_i64("k").unwrap() as usize;
        let expected = f32s(case.get("expected"));
        let dims: Vec<usize> = case
            .get("topk_dims")
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_i64().unwrap() as usize)
            .collect();
        assert_eq!(topk_indices_by_abs(&q, k), dims, "selection drifted at k={k}");

        let mut sparse = vec![0.0f32; seq];
        aqua_scores_sparse(&q, &keys, seq, d, k, &mut sparse);
        assert_eq!(sparse, expected, "sparse kernel vs fixture at k={k}");

        let mask = topk_mask_by_abs(&q, k);
        let mut masked = vec![0.0f32; seq];
        aqua_scores_masked(&q, &mask, &keys, seq, d, &mut masked);
        assert_eq!(masked, expected, "masked kernel vs fixture at k={k}");

        if k == d {
            assert_eq!(sparse, dense_expected, "k=d must equal dense");
        }
    }
}

/// End-to-end through the engine: at k = d the sparse path must equal the
/// dense baseline (teacher-forced logprobs agree to f32 rounding), while
/// k < d must actually change the scores — both on the native backend.
#[test]
fn sparse_equals_dense_at_k_d_through_engine() {
    let spec = BackendSpec::native(ModelConfig::tiny("golden"), 0xD00D).unwrap();
    let tok = ByteTokenizer;
    let prompt = tok.encode("the capital of velor is tamrin and the sea is cold");

    let score = |aqua: AquaConfig| -> Vec<f32> {
        let mut engine = Engine::with_spec(
            &spec,
            EngineConfig { batch: 1, aqua, ..Default::default() },
        )
        .unwrap();
        let mut req = GenRequest::new(1, prompt.clone(), 0);
        req.score_only = true;
        engine.run_batch(vec![req]).unwrap().remove(0).prompt_logprobs
    };

    // identity P, k = d: exact standard attention
    let baseline = score(AquaConfig::baseline());
    // orthogonal P, k = d: sparse-at-full-width + rotation — still exact
    let full = score(AquaConfig { k_ratio: 1.0, ..Default::default() });
    assert_eq!(baseline.len(), prompt.len() - 1);
    let max_diff = baseline
        .iter()
        .zip(&full)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 2e-3, "k=d sparse path deviates from dense by {max_diff}");

    // k = d/4: the knob must bite
    let pruned = score(AquaConfig { k_ratio: 0.25, ..Default::default() });
    let max_diff = baseline
        .iter()
        .zip(&pruned)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff > 1e-3, "k=d/4 left the scores untouched ({max_diff})");
}
