//! Flight-recorder suite: span timelines from admission to last token,
//! per-request timings over HTTP, postmortem dumps on engine failure —
//! hermetic, on the native/sharded backends plus the deterministic
//! `fault:` chaos wrapper.
//!
//! Acceptance surface (ROADMAP PR 8): `"timings": true` on `/generate`
//! returns an enqueue-relative span breakdown that reconciles
//! (queue_wait + prefill + decode ≈ total, ttft ≤ total); the ring keeps
//! only the newest events across wraps; `trace=errors` records nothing
//! for healthy traffic; a lane kill under `fault:` leaves a postmortem
//! naming the blamed lane with its trailing steps, served over
//! `GET /trace/postmortem`; sharded and native engines produce identical
//! per-request event timelines.
//!
//! CI runs this file under `--release` too (like the chaos suite — the
//! engine threads and result pump are timing-sensitive).

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener};
use std::sync::Arc;
use std::time::{Duration, Instant};

use aqua_serve::coordinator::{Engine, EngineConfig, FinishReason, GenRequest, Health};
use aqua_serve::registry::{Admission, DeploymentSpec, ModelRegistry};
use aqua_serve::runtime::BackendSpec;
use aqua_serve::server;
use aqua_serve::tokenizer::ByteTokenizer;
use aqua_serve::trace::{TraceMode, TracePhase, TraceRecorder};
use aqua_serve::util::json::Json;

// ---------------------------------------------------------------- helpers

fn registry_of(specs: &[&str]) -> Arc<ModelRegistry> {
    let reg = ModelRegistry::new("no-such-artifacts-dir");
    for s in specs {
        reg.deploy(DeploymentSpec::parse_kv(s).unwrap()).unwrap();
    }
    Arc::new(reg)
}

fn start_server(registry: Arc<ModelRegistry>) -> SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let _ = server::serve_on(listener, registry);
    });
    addr
}

fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    server::http::client_request(addr, method, path, body).expect("http request")
}

fn prompt_tokens(text: &str) -> Vec<i32> {
    ByteTokenizer.encode(text)
}

fn wait_for<F: FnMut() -> bool>(what: &str, deadline: Duration, mut cond: F) {
    let end = Instant::now() + deadline;
    while !cond() {
        assert!(Instant::now() < end, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

// ------------------------------------------------------------------ tests

/// `"timings": true` over real HTTP: the enqueue-relative spans reconcile
/// — queue_wait + prefill + decode equals total up to µs truncation, ttft
/// never exceeds total — and the same request's timeline shows up on
/// `GET /trace` with admission and retire events.
#[test]
fn generate_timings_reconcile_and_trace_shows_the_timeline() {
    let reg = registry_of(&["name=traced,backend=native,seed=0,k=1.0,batch=2,queue=8,trace=full"]);
    let addr = start_server(reg.clone());

    let (status, body) = http(
        addr,
        "POST",
        "/generate",
        r#"{"prompt": "the capital of ", "max_new_tokens": 8, "stop_newline": false,
            "timings": true}"#,
    );
    assert_eq!(status, 200, "generate failed: {body}");
    let doc = Json::parse(&body).unwrap();
    let t = doc.get("timings");
    assert!(t.get("queue_wait_ms").as_f64().is_some(), "timings missing: {body}");
    let total = t.get("total_ms").as_f64().unwrap();
    let parts = t.get("queue_wait_ms").as_f64().unwrap()
        + t.get("prefill_ms").as_f64().unwrap()
        + t.get("decode_ms").as_f64().unwrap();
    assert!(
        (parts - total).abs() <= 0.02 + total * 0.01,
        "span breakdown must reconcile: queue+prefill+decode = {parts}ms, total = {total}ms"
    );
    let ttft = t.get("ttft_ms").as_f64().unwrap();
    assert!(ttft <= total + 1e-9, "ttft {ttft}ms exceeds total {total}ms");
    assert!(ttft >= t.get("queue_wait_ms").as_f64().unwrap() - 1e-9, "ttft includes queue wait");
    assert!(t.get("prefix_hit_tokens").as_f64().is_some());

    // timings stay opt-in
    let (_, body) = http(addr, "POST", "/generate", r#"{"prompt": "hi", "max_new_tokens": 2}"#);
    assert_eq!(Json::parse(&body).unwrap().get("timings"), &Json::Null);

    // the flight recorder saw the whole story
    let (status, body) = http(addr, "GET", "/trace?model=traced&n=512", "");
    assert_eq!(status, 200);
    let doc = Json::parse(&body).unwrap();
    assert_eq!(doc.get("mode").as_str(), Some("full"));
    assert!(doc.get("total_recorded").as_i64().unwrap() > 0);
    let events = doc.get("events").as_arr().unwrap();
    let has = |phase: &str| events.iter().any(|e| e.get("phase").as_str() == Some(phase));
    for phase in ["enqueue", "admit", "prefill_chunk", "decode_batch", "retire", "score"] {
        assert!(has(phase), "missing {phase} in /trace: {body}");
    }
    // the JSONL dump is line-per-event Chrome-trace JSON
    let (status, dump) = http(addr, "GET", "/trace?model=traced&format=jsonl", "");
    assert_eq!(status, 200);
    assert!(dump.lines().count() > 0);
    for line in dump.lines() {
        let j = Json::parse(line).unwrap();
        assert_eq!(j.get("ph").as_str(), Some("i"), "chrome instant event: {line}");
        assert!(j.get("ts").as_f64().is_some());
    }
    reg.shutdown_all().unwrap();
}

/// Ring wraparound through the public API: capacity bounds residency,
/// only the newest events survive, the lifetime count stays monotone.
#[test]
fn ring_wraparound_keeps_only_the_newest_events() {
    let t = TraceRecorder::with_capacity(TraceMode::Full, 16);
    for i in 0..100u64 {
        t.record(TracePhase::DecodeBatch, 0, -1, i);
    }
    assert_eq!(t.total_recorded(), 100);
    let all = t.recent(1000);
    assert_eq!(all.len(), 16, "ring residency is bounded by capacity");
    let args: Vec<u64> = all.iter().map(|e| e.arg).collect();
    assert_eq!(args, (84..100).collect::<Vec<u64>>(), "newest only, oldest first");
    assert!(all.windows(2).all(|w| w[0].at_ns <= w[1].at_ns), "timestamps monotone");
}

/// `trace=errors` on a healthy deployment: full request lifecycles leave
/// the ring empty — the recorder arms only on the failure path.
#[test]
fn errors_mode_records_nothing_for_healthy_traffic() {
    let reg =
        registry_of(&["name=quiet,backend=native,seed=0,k=1.0,batch=2,queue=8,trace=errors"]);
    let dep = reg.get(Some("quiet")).unwrap();
    for _ in 0..3 {
        let id = dep.fresh_id();
        assert_eq!(
            dep.submit(GenRequest::new(id, prompt_tokens("the capital of "), 4)).unwrap(),
            Admission::Accepted
        );
        let res = dep.wait_result(id, Duration::from_secs(30)).expect("healthy result");
        assert_eq!(res.finish, FinishReason::Length);
    }
    assert_eq!(dep.trace().mode(), TraceMode::Errors);
    assert_eq!(dep.trace().total_recorded(), 0, "healthy traffic must not touch the ring");
    assert!(dep.trace().recent(100).is_empty());
    assert!(dep.trace().postmortems().is_empty());
    reg.shutdown_all().unwrap();
}

/// A scripted lane kill leaves a postmortem naming the blamed lane, with
/// the lane's trailing request events plus engine-level steps frozen at
/// containment time.
#[test]
fn lane_failure_postmortem_names_the_blamed_lane() {
    let spec =
        BackendSpec::from_kind("fault:native,err_every=1,err_count=1,err_lane=1", "pm", 3, 2, "x")
            .unwrap();
    let cfg = EngineConfig { batch: 2, trace: TraceMode::Full, ..EngineConfig::default() };
    let mut engine = Engine::with_spec(&spec, cfg).unwrap();
    let reqs: Vec<GenRequest> = (0..4)
        .map(|i| GenRequest::new(i + 1, prompt_tokens(&format!("the color {i} of ")), 4))
        .collect();
    let res = engine.run_batch(reqs).unwrap();
    assert_eq!(res[1].finish, FinishReason::BackendError, "blamed lane fails");

    let pms = engine.trace.postmortems();
    assert_eq!(pms.len(), 1, "exactly one containment, one postmortem");
    let pm = &pms[0];
    assert_eq!(pm.blamed_lane, 1, "the postmortem names the faulted lane");
    assert!(pm.note.contains("lane failure"), "note explains itself: {}", pm.note);
    assert!(!pm.events.is_empty(), "trailing steps are frozen into the dump");
    assert!(
        pm.events.iter().all(|e| e.lane == 1 || e.lane < 0),
        "dump is filtered to the blamed lane + engine-level events"
    );
    assert!(
        pm.events.iter().any(|e| e.phase == TracePhase::LaneFailure),
        "the failure event itself is in the dump"
    );
}

/// An engine panic under supervision: the shared recorder survives the
/// incarnation, the supervisor freezes an engine-wide postmortem and
/// stamps the restart, and `GET /trace/postmortem` serves it — all in
/// `trace=errors`, the always-on production setting.
#[test]
fn panic_postmortem_is_served_over_http() {
    let reg = registry_of(&[
        "name=pm,backend=fault:native;panic_at=12,seed=0,k=1.0,batch=1,queue=4,\
         restart=1,restart_backoff_ms=1,trace=errors",
    ]);
    let dep = reg.get(Some("pm")).unwrap();
    let addr = start_server(reg.clone());

    let id = dep.fresh_id();
    assert_eq!(
        dep.submit(GenRequest::new(id, prompt_tokens("hi"), 100)).unwrap(),
        Admission::Accepted
    );
    let res = dep.wait_result(id, Duration::from_secs(10)).expect("terminal result");
    assert_eq!(res.finish, FinishReason::EngineFailed);
    wait_for("postmortem snapshot", Duration::from_secs(10), || {
        !dep.trace().postmortems().is_empty()
    });
    wait_for("supervised restart", Duration::from_secs(10), || {
        dep.health() == Health::Healthy
    });

    let pm = &dep.trace().postmortems()[0];
    assert_eq!(pm.blamed_lane, -1, "a panic is engine-wide, no single blamed lane");
    assert!(pm.note.contains("panic"), "note explains itself: {}", pm.note);

    let (status, body) = http(addr, "GET", "/trace/postmortem?model=pm", "");
    assert_eq!(status, 200);
    let doc = Json::parse(&body).unwrap();
    assert!(doc.get("postmortems_total").as_i64().unwrap() >= 1);
    let dumps = doc.get("models").get("pm").as_arr().unwrap();
    assert!(!dumps.is_empty());
    assert!(dumps[0].get("note").as_str().unwrap().contains("panic"));
    assert!(dumps[0].get("events").as_arr().is_some());

    // errors mode still stamped the restart into the ring
    wait_for("engine_restart event", Duration::from_secs(10), || {
        dep.trace().recent(100).iter().any(|e| e.phase == TracePhase::EngineRestart)
    });
    assert_eq!(http(addr, "GET", "/trace/postmortem?model=nope", "").0, 404);
    reg.shutdown_all().unwrap();
}

/// The lane-sharded backend must tell the same story as the native one:
/// identical per-request counts of admission-to-retire events for the
/// same workload (and exactly one enqueue/admit/retire per request).
#[test]
fn sharded_matches_native_event_counts_per_request() {
    let mut per_backend: Vec<BTreeMap<(u64, &'static str), usize>> = vec![];
    for kind in ["native", "sharded"] {
        let spec = BackendSpec::from_kind(kind, "trace", 3, 2, "x").unwrap();
        let cfg = EngineConfig { batch: 2, trace: TraceMode::Full, ..EngineConfig::default() };
        let mut engine = Engine::with_spec(&spec, cfg).unwrap();
        let reqs: Vec<GenRequest> = (0..4)
            .map(|i| GenRequest::new(i + 1, prompt_tokens(&format!("the color {i} of ")), 4))
            .collect();
        engine.run_batch(reqs).unwrap();
        let mut counts: BTreeMap<(u64, &'static str), usize> = BTreeMap::new();
        for e in engine.trace.recent(usize::MAX) {
            let per_request = matches!(
                e.phase,
                TracePhase::Enqueue
                    | TracePhase::Admit
                    | TracePhase::PrefillChunk
                    | TracePhase::Retire
            );
            if e.req != 0 && per_request {
                *counts.entry((e.req, e.phase.name())).or_insert(0) += 1;
            }
        }
        for id in 1..=4u64 {
            for phase in ["enqueue", "admit", "retire"] {
                assert_eq!(
                    counts.get(&(id, phase)),
                    Some(&1),
                    "{kind}: req {id} must {phase} exactly once"
                );
            }
        }
        per_backend.push(counts);
    }
    assert_eq!(
        per_backend[0], per_backend[1],
        "sharded and native engines must record identical per-request timelines"
    );
}
