//! Fused streaming-decode parity (hermetic): the page-fused online-softmax
//! path (`ScoreMode::Fused`) must match the three-pass packed routing
//! within 1e-5 and the masked-dense oracle end-to-end — across
//! k ∈ {d/4, d/2, d}, with H2O eviction on and off, on the native backend
//! and bit-identically on the lane-sharded backend at 2 and 4 threads.
//! The int8-quantized resident-KV path must stay inside its measured
//! quantization-error bound on raw logits, keep greedy generations exactly
//! equal to f32 on seed workloads, cut resident KV bytes by >= 40% at
//! equal kv_keep, and round-trip its per-page dequantization scales
//! through prefix-shared / COW pages.
//!
//! CI runs this file under `--release` (the fused kernel's SIMD path and
//! the sharded scheduling are both release-sensitive).

use std::sync::Arc;

use aqua_serve::aqua::policy::AquaConfig;
use aqua_serve::coordinator::h2o::H2oPolicy;
use aqua_serve::coordinator::kvcache::LaneKv;
use aqua_serve::coordinator::{Engine, EngineConfig, GenRequest};
use aqua_serve::kvpool::{KvPoolConfig, KvQuant};
use aqua_serve::model::config::ModelConfig;
use aqua_serve::runtime::{
    AquaKnobs, BackendSpec, ExecBackend, NativeBackend, NativeModel, ScoreMode, ShardedBackend,
};
use aqua_serve::util::prng::Rng;

/// Drive identical decode traffic through several backends (same shape as
/// `decode_parity.rs`): random tokens, per-lane write cursors, and slot
/// masks evolved by an H2O policy fed the *first* backend's attention
/// mass, so every backend sees the exact same eviction interleaving.
fn drive_parity(
    backends: &mut [&mut dyn ExecBackend],
    b: usize,
    k_dims: usize,
    steps: usize,
    h2o: &H2oPolicy,
    seed: u64,
) -> Vec<Vec<Vec<f32>>> {
    let cfg = backends[0].model_config().clone();
    let (s_cap, d, n_layers) = (cfg.max_seq, cfg.d_head, cfg.n_layers);
    assert!(steps < s_cap, "test drives more steps than KV capacity");
    let knobs = AquaKnobs { k_dims, dim_keep: vec![1.0; d], use_projection: true };
    let mut rng = Rng::new(seed);
    for be in backends.iter_mut() {
        be.empty_cache(b).unwrap();
    }
    let mut lanes: Vec<LaneKv> = (0..b).map(|_| LaneKv::new(s_cap)).collect();
    let mut outs: Vec<Vec<Vec<f32>>> = vec![vec![]; backends.len()];
    for _ in 0..steps {
        let tokens: Vec<i32> = (0..b).map(|_| 32 + rng.below(90) as i32).collect();
        let pos: Vec<i32> = lanes.iter().map(|l| l.len as i32).collect();
        let mut mask = vec![0.0f32; b * s_cap];
        for (lane, kv) in lanes.iter().enumerate() {
            mask[lane * s_cap..(lane + 1) * s_cap].copy_from_slice(&kv.slot_mask);
        }
        let mut step_outs = vec![];
        for be in backends.iter_mut() {
            step_outs.push(be.decode(b, &tokens, &pos, &mask, &knobs).unwrap());
        }
        for lane in 0..b {
            lanes[lane].commit_write(1);
            let mut mass = vec![0.0f32; s_cap];
            for l in 0..n_layers {
                let base = (l * b + lane) * s_cap;
                for s in 0..s_cap {
                    mass[s] += step_outs[0].attn_acc[base + s];
                }
            }
            lanes[lane].accumulate(&mass);
            h2o.apply(&mut lanes[lane]);
        }
        for (i, o) in step_outs.into_iter().enumerate() {
            outs[i].push(o.logits);
        }
    }
    outs
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b.iter()).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
}

#[test]
fn fused_matches_packed_and_masked_oracle() {
    let cfg = ModelConfig::tiny("fused-parity");
    let d = cfg.d_head;
    let model = Arc::new(NativeModel::new(cfg, 0xF0D5).unwrap());
    // h2o ratio 1.0 disables eviction entirely; 0.3 evicts hard enough
    // that fused page passes see real holes and fully-dead pages
    for &h2o_ratio in &[1.0f64, 0.3] {
        let h2o = H2oPolicy::new(h2o_ratio, 3);
        for &k_dims in &[d / 4, d / 2, d] {
            let mut oracle = NativeBackend::from_model(model.clone());
            oracle.set_score_mode(ScoreMode::MaskedDense);
            let mut packed = NativeBackend::from_model(model.clone());
            packed.set_score_mode(ScoreMode::Packed);
            let mut fused = NativeBackend::from_model(model.clone());
            fused.set_score_mode(ScoreMode::Fused);
            let mut bes: Vec<&mut dyn ExecBackend> = vec![&mut oracle, &mut packed, &mut fused];
            let outs = drive_parity(&mut bes, 3, k_dims, 30, &h2o, 77 + k_dims as u64);
            for (step, ((orc, pck), fus)) in
                outs[0].iter().zip(&outs[1]).zip(&outs[2]).enumerate()
            {
                let dp = max_abs_diff(pck, fus);
                assert!(
                    dp <= 1e-5,
                    "fused vs packed diff {dp} at step {step} (k={k_dims}, h2o={h2o_ratio})"
                );
                let do_ = max_abs_diff(orc, fus);
                assert!(
                    do_ <= 1e-4,
                    "fused vs oracle diff {do_} at step {step} (k={k_dims}, h2o={h2o_ratio})"
                );
            }
        }
    }
}

#[test]
fn sharded_fused_decode_is_bit_identical_to_native() {
    let cfg = ModelConfig::tiny("fused-shard");
    let d = cfg.d_head;
    let model = Arc::new(NativeModel::new(cfg, 0x5A5A).unwrap());
    let h2o = H2oPolicy::new(0.5, 4);
    for &threads in &[2usize, 4] {
        let mut native = NativeBackend::from_model(model.clone());
        native.set_score_mode(ScoreMode::Fused);
        let mut sharded = ShardedBackend::from_model(model.clone(), threads);
        sharded.set_score_mode(ScoreMode::Fused).unwrap();
        let mut bes: Vec<&mut dyn ExecBackend> = vec![&mut native, &mut sharded];
        let outs = drive_parity(&mut bes, 8, d / 2, 24, &h2o, 9);
        for (step, (a, s)) in outs[0].iter().zip(&outs[1]).enumerate() {
            assert_eq!(a, s, "sharded(threads={threads}) fused logits diverged at step {step}");
        }
    }
}

#[test]
fn int8_decode_stays_within_quantization_bound() {
    let cfg = ModelConfig::tiny("fused-int8");
    let d = cfg.d_head;
    let model = Arc::new(NativeModel::new(cfg, 0x17A8).unwrap());
    let h2o = H2oPolicy::new(1.0, 3);
    for &k_dims in &[d / 2, d] {
        let mut f32_be = NativeBackend::from_model(model.clone());
        f32_be.set_score_mode(ScoreMode::Fused);
        let mut int8_be = NativeBackend::from_model(model.clone());
        int8_be
            .configure_kv_pool(KvPoolConfig { kv_quant: KvQuant::Int8, ..Default::default() })
            .unwrap();
        let mut bes: Vec<&mut dyn ExecBackend> = vec![&mut f32_be, &mut int8_be];
        let outs = drive_parity(&mut bes, 2, k_dims, 24, &h2o, 13 + k_dims as u64);
        // symmetric int8 with per-page amax scales keeps each resident
        // element within scale/2 ≈ 0.4% of its block amax; through score,
        // softmax, AV mix and the output head the logit error stays well
        // inside this empirical bound on the tiny analog models
        for (step, (a, q)) in outs[0].iter().zip(&outs[1]).enumerate() {
            let diff = max_abs_diff(a, q);
            assert!(diff <= 0.25, "int8 logit drift {diff} at step {step} (k={k_dims})");
        }
    }
}

/// Run one greedy seed workload through an engine and return the token
/// streams plus the resident-KV peak the metrics pipeline observed.
fn engine_run(spec: &BackendSpec, quant: KvQuant) -> (Vec<Vec<i32>>, u64) {
    let aqua = AquaConfig { k_ratio: 0.5, ..Default::default() };
    let cfg = EngineConfig { batch: 4, aqua, kv_quant: quant, ..Default::default() };
    let mut engine = Engine::with_spec(spec, cfg).unwrap();
    let reqs: Vec<GenRequest> = (0..6)
        .map(|i| GenRequest::new(i as u64 + 1, vec![65 + i as i32, 66, 67, 68, 69, 70], 20))
        .collect();
    let results = engine.run_batch(reqs).unwrap();
    let snap = engine.metrics.snapshot();
    (results.into_iter().map(|r| r.tokens).collect(), snap.kv_resident_peak_bytes)
}

#[test]
fn int8_greedy_outputs_match_f32_and_cut_resident_bytes() {
    let cfg = ModelConfig::tiny("fused-int8-engine");
    let native = BackendSpec::native(cfg.clone(), 11).unwrap();
    let (f32_tokens, f32_peak) = engine_run(&native, KvQuant::F32);
    let (int8_tokens, int8_peak) = engine_run(&native, KvQuant::Int8);
    assert_eq!(f32_tokens, int8_tokens, "int8 residency changed greedy outputs");
    assert!(f32_peak > 0 && int8_peak > 0, "kv gauges did not flow");
    // acceptance bound: >= 40% resident-KV reduction at equal kv_keep
    assert!(
        (int8_peak as f64) <= 0.6 * f32_peak as f64,
        "int8 resident peak {int8_peak} vs f32 {f32_peak}: less than 40% saved"
    );
    // and the sharded backend decodes the quantized pool bit-identically
    let sharded = BackendSpec::sharded(cfg, 11, 3).unwrap();
    let (sharded_tokens, _) = engine_run(&sharded, KvQuant::Int8);
    assert_eq!(int8_tokens, sharded_tokens, "int8 greedy diverged native vs sharded");
}

#[test]
fn int8_scales_round_trip_through_prefix_shared_pages() {
    // Property-style sweep: across seeds, a prefix-sharing int8 engine
    // (COW pages + scale sidecars riding the share/copy path) must emit
    // exactly what the sharing-disabled int8 engine emits.
    let cfg = ModelConfig::tiny("fused-int8-prefix");
    for seed in [1u64, 2, 3, 4, 5] {
        let spec = BackendSpec::native(cfg.clone(), seed).unwrap();
        let run = |prefix_cache: bool| {
            let aqua = AquaConfig { k_ratio: 0.5, ..Default::default() };
            let ecfg = EngineConfig {
                batch: 4,
                aqua,
                kv_quant: KvQuant::Int8,
                prefix_cache,
                ..Default::default()
            };
            let mut engine = Engine::with_spec(&spec, ecfg).unwrap();
            // shared long prefix, divergent tails → page-granular sharing
            // with COW on the partially-filled tail page
            let prefix: Vec<i32> = (0..40).map(|i| 40 + (i % 50) as i32).collect();
            let reqs: Vec<GenRequest> = (0..4)
                .map(|i| {
                    let mut toks = prefix.clone();
                    toks.push(90 + i as i32);
                    GenRequest::new(i as u64 + 1, toks, 12)
                })
                .collect();
            let results = engine.run_batch(reqs).unwrap();
            results.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
        };
        assert_eq!(run(true), run(false), "prefix-shared int8 diverged (seed {seed})");
    }
}
