//! Property tests for the KV-slot bookkeeping invariants under arbitrary
//! interleavings of `commit_write` / `accumulate` / `H2oPolicy::apply`:
//!
//! * `live_slots() <= len <= capacity` at every point,
//! * the policy never evicts a slot inside the recent window,
//! * `reset` always restores the empty state.

use aqua_serve::coordinator::h2o::H2oPolicy;
use aqua_serve::coordinator::kvcache::LaneKv;
use aqua_serve::util::prng::Rng;
use aqua_serve::util::testkit::check;

/// One step of the interleaving the engine can produce.
#[derive(Debug, Clone, Copy)]
enum Op {
    /// commit_write(n) after a prefill chunk or decode step
    Commit(usize),
    /// fold one step's attention mass, then run the eviction policy —
    /// the exact order the engine uses
    AccumulateAndApply(u64),
}

fn invariants(lane: &LaneKv, context: &str) -> Result<(), String> {
    if lane.len > lane.capacity {
        return Err(format!("{context}: len {} > capacity {}", lane.len, lane.capacity));
    }
    if lane.live_slots() > lane.len {
        return Err(format!("{context}: live {} > len {}", lane.live_slots(), lane.len));
    }
    Ok(())
}

#[test]
fn prop_interleavings_preserve_kv_invariants() {
    check(
        "kv-interleaving-invariants",
        200,
        |g| {
            let cap = 8 + g.rng.below(64);
            let ratio = 0.1 + g.rng.f64() * 0.9;
            let window = 1 + g.rng.below(12);
            let n_ops = 1 + g.rng.below(40);
            let ops: Vec<Op> = (0..n_ops)
                .map(|_| {
                    if g.rng.f64() < 0.55 {
                        Op::Commit(1 + g.rng.below(6))
                    } else {
                        Op::AccumulateAndApply(g.rng.next_u64())
                    }
                })
                .collect();
            (cap, ratio, window, ops)
        },
        |(cap, ratio, window, ops)| {
            let mut lane = LaneKv::new(*cap);
            let policy = H2oPolicy::new(*ratio, *window);
            for (step, op) in ops.iter().enumerate() {
                match *op {
                    Op::Commit(n) => {
                        let before = lane.len;
                        lane.commit_write(n);
                        if lane.len < before {
                            return Err(format!("step {step}: commit_write shrank len"));
                        }
                    }
                    Op::AccumulateAndApply(seed) => {
                        let mut rng = Rng::new(seed);
                        let mass: Vec<f32> = (0..*cap).map(|_| rng.f32()).collect();
                        lane.accumulate(&mass);
                        policy.apply(&mut lane);
                        // eviction never clears slots inside the recent window
                        let recent_start = lane.len.saturating_sub(*window);
                        for s in recent_start..lane.len {
                            if lane.slot_mask[s] < 0.5 {
                                return Err(format!(
                                    "step {step}: recent slot {s} evicted (len {}, window {window})",
                                    lane.len
                                ));
                            }
                        }
                        // the budget is respected once eviction ran
                        if lane.live_slots() > policy.budget(lane.len) {
                            return Err(format!(
                                "step {step}: live {} > budget {}",
                                lane.live_slots(),
                                policy.budget(lane.len)
                            ));
                        }
                    }
                }
                invariants(&lane, &format!("step {step}"))?;
            }
            // reset restores the empty state no matter what happened
            lane.reset();
            if lane.len != 0 || lane.live_slots() != 0 {
                return Err("reset left residue (len/live)".into());
            }
            if lane.h2o_acc.iter().any(|&a| a != 0.0) {
                return Err("reset left residue (h2o_acc)".into());
            }
            if lane.slot_mask.iter().any(|&m| m != 0.0) {
                return Err("reset left residue (slot_mask)".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_apply_is_idempotent_and_monotone_in_budget() {
    // At fixed len: a second apply evicts nothing, and a looser ratio never
    // keeps fewer slots than a tighter one on the same lane state.
    check(
        "h2o-idempotent-monotone",
        150,
        |g| {
            let cap = 8 + g.rng.below(48);
            let len = 1 + g.rng.below(cap);
            let tight = 0.1 + g.rng.f64() * 0.4;
            let loose = tight + g.rng.f64() * (1.0 - tight);
            let window = 1 + g.rng.below(8);
            let seed = g.rng.next_u64();
            (cap, len, tight, loose, window, seed)
        },
        |(cap, len, tight, loose, window, seed)| {
            let mut rng = Rng::new(*seed);
            let mass: Vec<f32> = (0..*cap).map(|_| rng.f32() * 10.0).collect();
            let build = |ratio: f64| -> LaneKv {
                let mut lane = LaneKv::new(*cap);
                lane.commit_write(*len);
                lane.accumulate(&mass);
                H2oPolicy::new(ratio, *window).apply(&mut lane);
                lane
            };
            let mut tight_lane = build(*tight);
            if H2oPolicy::new(*tight, *window).apply(&mut tight_lane) != 0 {
                return Err("second apply evicted more".into());
            }
            let loose_lane = build(*loose);
            if loose_lane.live_slots() < tight_lane.live_slots() {
                return Err(format!(
                    "looser ratio {loose:.2} kept {} < tighter {tight:.2} kept {}",
                    loose_lane.live_slots(),
                    tight_lane.live_slots()
                ));
            }
            Ok(())
        },
    );
}
