//! Property tests for the JSON substrate: parse ∘ print == id on random
//! documents (proptest substitute — see util::testkit).

use aqua_serve::util::json::Json;
use aqua_serve::util::prng::Rng;
use aqua_serve::util::testkit::{check, Gen};

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.f64() < 0.5),
        2 => Json::Num((rng.normal() * 100.0 * 8.0).round() / 8.0),
        3 => {
            let n = rng.below(12);
            let s: String = (0..n)
                .map(|_| {
                    let choices = ['a', 'Z', '0', ' ', '"', '\\', '\n', 'é', 'ÿ', '😀', '\t'];
                    choices[rng.below(choices.len())]
                })
                .collect();
            Json::Str(s)
        }
        4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => {
            let mut m = std::collections::BTreeMap::new();
            for i in 0..rng.below(5) {
                m.insert(format!("k{i}"), random_json(rng, depth - 1));
            }
            Json::Obj(m)
        }
    }
}

#[test]
fn prop_roundtrip_identity() {
    check(
        "json-roundtrip",
        300,
        |g: &mut Gen| random_json(&mut g.rng, 3),
        |doc| {
            let printed = doc.to_string();
            let reparsed = Json::parse(&printed).map_err(|e| format!("reparse: {e}"))?;
            if &reparsed == doc {
                Ok(())
            } else {
                Err(format!("mismatch: {printed}"))
            }
        },
    );
}

#[test]
fn prop_printed_is_single_document() {
    check(
        "json-no-trailing",
        100,
        |g: &mut Gen| random_json(&mut g.rng, 2),
        |doc| {
            let printed = doc.to_string();
            // appending junk must fail (parser consumes exactly one doc)
            if Json::parse(&format!("{printed} x")).is_ok() {
                return Err("accepted trailing garbage".into());
            }
            Ok(())
        },
    );
}

#[test]
fn parses_real_manifest_shapes() {
    // The exact structural shape aot.py emits.
    let doc = r#"{"models":{"llama-analog":{"config":{"d_head":32},"hlo":{"decode_b1":"llama-analog/decode_b1.hlo.txt"},"param_order":["embed"]}},"train":{"llama-analog":{"curve":[{"step":0,"train_loss":5.55}],"wall_s":296.7}}}"#;
    let j = Json::parse(doc).unwrap();
    assert_eq!(j.get("models").get("llama-analog").get("config").get("d_head").as_i64(), Some(32));
    assert_eq!(
        j.get("train").get("llama-analog").get("curve").idx(0).get("train_loss").as_f64(),
        Some(5.55)
    );
}
