//! Hand-rolled CLI argument parsing (clap unavailable offline).
//!
//! Grammar: `aqua <subcommand> [--flag value]... [--switch]...`

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: String,
    /// Flag values in occurrence order — flags are repeatable
    /// (`--model a --model b`); scalar accessors read the last value.
    flags: BTreeMap<String, Vec<String>>,
    switches: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        if argv.is_empty() {
            bail!("missing subcommand");
        }
        let subcommand = argv[0].clone();
        let mut flags: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut switches = vec![];
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.entry(name.to_string()).or_default().push(argv[i + 1].clone());
                    i += 1;
                } else {
                    switches.push(name.to_string());
                }
            } else {
                bail!("unexpected positional argument '{a}'");
            }
            i += 1;
        }
        Ok(Args { subcommand, flags, switches })
    }

    fn last(&self, name: &str) -> Option<&String> {
        self.flags.get(name).and_then(|v| v.last())
    }

    pub fn str(&self, name: &str, default: &str) -> String {
        self.last(name).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Every value a repeatable flag was given, in order (empty when the
    /// flag is absent).
    pub fn strs(&self, name: &str) -> Vec<String> {
        self.flags.get(name).cloned().unwrap_or_default()
    }

    pub fn usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.last(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.last(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.last(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    /// Comma-separated f64 list flag.
    pub fn f64_list(&self, name: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.last(name) {
            Some(v) => v.split(',').map(|s| Ok(s.trim().parse()?)).collect(),
            None => Ok(default.to_vec()),
        }
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_switches() {
        let a = Args::parse(&argv("table1 --model llama-analog --items 20 --fast")).unwrap();
        assert_eq!(a.subcommand, "table1");
        assert_eq!(a.str("model", "x"), "llama-analog");
        assert_eq!(a.usize("items", 60).unwrap(), 20);
        assert!(a.switch("fast"));
        assert!(!a.switch("slow"));
    }

    #[test]
    fn equals_syntax() {
        let a = Args::parse(&argv("fig2 --model=m --ratios=0.5,0.75")).unwrap();
        assert_eq!(a.str("model", ""), "m");
        assert_eq!(a.f64_list("ratios", &[]).unwrap(), vec![0.5, 0.75]);
    }

    #[test]
    fn rejects_positional() {
        assert!(Args::parse(&argv("x stray")).is_err());
        assert!(Args::parse(&[]).is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&argv("serve")).unwrap();
        assert_eq!(a.f64("k-ratio", 1.0).unwrap(), 1.0);
        assert_eq!(a.str("addr", "127.0.0.1:8080"), "127.0.0.1:8080");
        assert_eq!(a.u64("seed", 7).unwrap(), 7);
    }

    #[test]
    fn repeated_flags_collect_in_order() {
        let a = Args::parse(&argv("serve --model name=a,k=1.0 --model name=b,k=0.25")).unwrap();
        assert_eq!(a.strs("model"), vec!["name=a,k=1.0".to_string(), "name=b,k=0.25".to_string()]);
        // scalar accessors read the last occurrence
        assert_eq!(a.str("model", "x"), "name=b,k=0.25");
        assert!(a.strs("fleet").is_empty());
        let b = Args::parse(&argv("serve --seed 1 --seed 9")).unwrap();
        assert_eq!(b.u64("seed", 0).unwrap(), 9);
    }

    #[test]
    fn backend_flags_parse() {
        let a = Args::parse(&argv("generate --backend native --seed 42")).unwrap();
        assert_eq!(a.str("backend", "auto"), "native");
        assert_eq!(a.u64("seed", 0).unwrap(), 42);
        assert!(Args::parse(&argv("generate --seed nope")).unwrap().u64("seed", 0).is_err());
    }
}
