//! Flight recorder: a fixed-capacity per-engine ring buffer of compact
//! trace events, recording the request timeline from admission to last
//! token plus the failure path (lane failures, restarts, escalation).
//!
//! Design constraints (see ROADMAP "Flight recorder (PR 8)"):
//!
//! * **Branch-cheap when off.** [`TraceRecorder::record`] loads one
//!   relaxed atomic and returns; the event struct is `Copy` and is never
//!   formatted on the hot path.
//! * **Zero steady-state allocations.** The ring is preallocated at
//!   construction ([`RING_CAP`] slots) and recording overwrites slots in
//!   place — the `interleave` bench's counting global allocator holds at
//!   `steady_decode_allocs == 0` with `trace=full`.
//! * **Survives engine incarnations.** Like `Metrics`, the recorder is an
//!   `Arc` owned by the deployment and re-attached to every supervised
//!   engine rebuild, so a postmortem taken after a panic still holds the
//!   events leading up to it.
//!
//! Modes (`trace=` knob on `EngineConfig`/`DeploymentSpec`/CLI/fleet
//! JSON): `off`, `errors` (only failure-path phases), `sampled:N`
//! (failure-path phases plus full timelines for 1-in-N request ids), and
//! `full`. Exposed via `GET /trace?model=&n=[&format=jsonl]` (the JSONL
//! dump is Chrome-trace compatible — load it in chrome://tracing or
//! Perfetto, recipe in BENCHES.md) and `GET /trace/postmortem`.

use std::sync::atomic::{AtomicU32, AtomicU8, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::util::json::Json;

/// Ring capacity in events (~32 B each, so ~128 KiB per engine).
pub const RING_CAP: usize = 4096;

/// How many trailing ring events a postmortem snapshot scans.
pub const POSTMORTEM_TAIL: usize = 256;

/// How many postmortem dumps are retained (oldest evicted first).
pub const POSTMORTEM_KEEP: usize = 8;

// ---------------------------------------------------------------- mode

const MODE_OFF: u8 = 0;
const MODE_ERRORS: u8 = 1;
const MODE_SAMPLED: u8 = 2;
const MODE_FULL: u8 = 3;

/// Recording mode. `Sampled(n)` records the failure-path phases always
/// and the full timeline for request ids divisible by `n` (engine-level
/// events, which carry no request id, are always recorded).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub enum TraceMode {
    #[default]
    Off,
    Errors,
    Sampled(u32),
    Full,
}

impl TraceMode {
    /// Parse the knob's string form: `off`, `errors`, `sampled:N`, `full`.
    pub fn parse(s: &str) -> Result<TraceMode> {
        match s {
            "" | "off" => Ok(TraceMode::Off),
            "errors" => Ok(TraceMode::Errors),
            "full" => Ok(TraceMode::Full),
            other => {
                if let Some(n) = other.strip_prefix("sampled:") {
                    let n: u32 = n
                        .parse()
                        .map_err(|_| anyhow::anyhow!("trace sampled:N needs an integer, got {n:?}"))?;
                    if n == 0 {
                        bail!("trace sampled:N needs N >= 1");
                    }
                    return Ok(TraceMode::Sampled(n));
                }
                bail!("unknown trace mode '{other}' (off|errors|sampled:N|full)")
            }
        }
    }

    /// The string form `parse` accepts (spec round-trips through this).
    pub fn as_string(&self) -> String {
        match self {
            TraceMode::Off => "off".to_string(),
            TraceMode::Errors => "errors".to_string(),
            TraceMode::Sampled(n) => format!("sampled:{n}"),
            TraceMode::Full => "full".to_string(),
        }
    }

    fn code(&self) -> (u8, u32) {
        match self {
            TraceMode::Off => (MODE_OFF, 0),
            TraceMode::Errors => (MODE_ERRORS, 0),
            TraceMode::Sampled(n) => (MODE_SAMPLED, *n),
            TraceMode::Full => (MODE_FULL, 0),
        }
    }
}

// --------------------------------------------------------------- events

/// What happened. The failure-path phases (`is_error`) are recorded in
/// every mode except `off`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum TracePhase {
    /// Request entered the admission queue (`arg` = prompt tokens).
    Enqueue,
    /// Request took a lane (`arg` = prompt tokens left to prefill).
    Admit,
    /// Queue head deferred — no lane/budget/KV room (`arg` = reason code:
    /// 0 lane/token budget, 1 KV memory).
    Defer,
    /// A later request overtook a budget-blocked head (`arg` = queue
    /// depth at the overtake).
    Overtake,
    /// Prefix-cache pages adopted at admission (`arg` = tokens served
    /// from cache).
    PrefixAttach,
    /// One chunked-prefill slice fed for a lane (`arg` = tokens fed).
    PrefillChunk,
    /// One decode pass over the live batch (engine-level; `arg` = lanes
    /// decoded).
    DecodeBatch,
    /// Request finished and released its lane (`arg` = finish-reason
    /// code, see `FinishReason` ordering in `coordinator::request`).
    Retire,
    /// Score-path kernel time for one pass (engine-level; `lane` = mode
    /// code 0 dense / 1 sparse / 2 packed / 3 mixed / 4 fused, `arg` =
    /// ns; see `KernelCounters::dominant_mode`).
    Score,
    /// Speculative draft block emitted for a lane (`arg` = tokens
    /// drafted via the sparse score path).
    DraftBlock,
    /// Exact verify pass committed tokens for a lane (`arg` = tokens
    /// committed, accepted drafts + the one verify-sampled token).
    VerifyBlock,
    /// Rejected drafts rolled back for a lane (`arg` = tokens whose KV
    /// pages were un-appended; only recorded when nonzero).
    Rollback,
    /// A backend step error retired this lane (`arg` = consecutive
    /// engine-level failures so far).
    LaneFailure,
    /// The supervisor rebuilt the engine (`arg` = restarts used).
    EngineRestart,
    /// Consecutive step failures hit the cap; the engine is failing
    /// (`arg` = the cap).
    Escalate,
}

impl TracePhase {
    /// Failure-path phases recorded by `errors` (and `sampled`) mode.
    pub fn is_error(&self) -> bool {
        matches!(self, TracePhase::LaneFailure | TracePhase::EngineRestart | TracePhase::Escalate)
    }

    pub fn name(&self) -> &'static str {
        match self {
            TracePhase::Enqueue => "enqueue",
            TracePhase::Admit => "admit",
            TracePhase::Defer => "defer",
            TracePhase::Overtake => "overtake",
            TracePhase::PrefixAttach => "prefix_attach",
            TracePhase::PrefillChunk => "prefill_chunk",
            TracePhase::DecodeBatch => "decode_batch",
            TracePhase::Retire => "retire",
            TracePhase::Score => "score",
            TracePhase::DraftBlock => "draft_block",
            TracePhase::VerifyBlock => "verify_block",
            TracePhase::Rollback => "rollback",
            TracePhase::LaneFailure => "lane_failure",
            TracePhase::EngineRestart => "engine_restart",
            TracePhase::Escalate => "escalate",
        }
    }
}

/// One compact recorded event. `req == 0` marks engine-level events;
/// `lane == -1` marks events not tied to a lane.
#[derive(Debug, Clone, Copy)]
pub struct TraceEvent {
    /// Monotonic ns since the recorder's epoch (deployment launch).
    pub at_ns: u64,
    pub req: u64,
    pub lane: i32,
    pub phase: TracePhase,
    /// Phase-specific payload word (documented per phase).
    pub arg: u64,
}

impl TraceEvent {
    /// JSON object form (`GET /trace` default format).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("at_ns", Json::Num(self.at_ns as f64)),
            ("req", Json::Num(self.req as f64)),
            ("lane", Json::Num(self.lane as f64)),
            ("phase", Json::Str(self.phase.name().to_string())),
            ("arg", Json::Num(self.arg as f64)),
        ])
    }

    /// One Chrome-trace-compatible instant-event line (`ts` in µs,
    /// `tid` = lane). Concatenated lines load in chrome://tracing /
    /// Perfetto as a JSONL stream (recipe in BENCHES.md).
    pub fn to_chrome_line(&self) -> String {
        format!(
            r#"{{"name":"{}","ph":"i","ts":{:.3},"pid":1,"tid":{},"s":"t","args":{{"req":{},"arg":{}}}}}"#,
            self.phase.name(),
            self.at_ns as f64 / 1e3,
            self.lane,
            self.req,
            self.arg
        )
    }
}

/// Render events as a Chrome-trace JSONL dump (one event per line).
pub fn events_jsonl(events: &[TraceEvent]) -> String {
    let mut out = String::with_capacity(events.len() * 96);
    for e in events {
        out.push_str(&e.to_chrome_line());
        out.push('\n');
    }
    out
}

// ----------------------------------------------------------- postmortem

/// A failure snapshot: the trailing events relevant to a blamed lane (or
/// the whole engine), frozen at the moment the failure was contained.
#[derive(Debug, Clone)]
pub struct Postmortem {
    /// What failed, e.g. `lane failure (backend error)` or
    /// `engine panicked`.
    pub note: String,
    /// The faulted lane, or -1 when the failure is engine-wide.
    pub blamed_lane: i32,
    /// Monotonic ns (recorder epoch) the snapshot was taken.
    pub at_ns: u64,
    /// Trailing ring events: the blamed lane's plus engine-level ones,
    /// oldest first.
    pub events: Vec<TraceEvent>,
}

impl Postmortem {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("note", Json::Str(self.note.clone())),
            ("blamed_lane", Json::Num(self.blamed_lane as f64)),
            ("at_ns", Json::Num(self.at_ns as f64)),
            ("events", Json::Arr(self.events.iter().map(|e| e.to_json()).collect())),
        ])
    }
}

// ------------------------------------------------------------- recorder

struct Ring {
    /// Preallocated to capacity at construction; pushes within capacity
    /// never allocate, wrap overwrites in place.
    buf: Vec<TraceEvent>,
    /// Next slot to (over)write; equals `buf.len()` until the first wrap.
    next: usize,
    /// Total events ever recorded (wraps excluded events are gone, this
    /// count is not).
    seq: u64,
}

/// The per-engine flight recorder. Cheap to share (`Arc`); all methods
/// take `&self`.
pub struct TraceRecorder {
    mode: AtomicU8,
    sample_n: AtomicU32,
    epoch: Instant,
    ring: Mutex<Ring>,
    postmortems: Mutex<Vec<Postmortem>>,
}

impl Default for TraceRecorder {
    fn default() -> Self {
        TraceRecorder::new(TraceMode::Off)
    }
}

impl TraceRecorder {
    pub fn new(mode: TraceMode) -> TraceRecorder {
        TraceRecorder::with_capacity(mode, RING_CAP)
    }

    /// Test hook: a recorder with a custom ring capacity.
    pub fn with_capacity(mode: TraceMode, cap: usize) -> TraceRecorder {
        let (m, n) = mode.code();
        TraceRecorder {
            mode: AtomicU8::new(m),
            sample_n: AtomicU32::new(n.max(1)),
            epoch: Instant::now(),
            ring: Mutex::new(Ring { buf: Vec::with_capacity(cap.max(1)), next: 0, seq: 0 }),
            postmortems: Mutex::new(Vec::new()),
        }
    }

    pub fn mode(&self) -> TraceMode {
        match self.mode.load(Ordering::Relaxed) {
            MODE_ERRORS => TraceMode::Errors,
            MODE_SAMPLED => TraceMode::Sampled(self.sample_n.load(Ordering::Relaxed)),
            MODE_FULL => TraceMode::Full,
            _ => TraceMode::Off,
        }
    }

    // Poison-tolerant locks, same rationale as `Metrics`: a panicked
    // engine incarnation must not wipe the flight recorder — the
    // postmortem is exactly the artifact we want after a panic.
    fn ring_locked(&self) -> MutexGuard<'_, Ring> {
        self.ring.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn pm_locked(&self) -> MutexGuard<'_, Vec<Postmortem>> {
        self.postmortems.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Record one event. Hot path: one relaxed atomic load when off; one
    /// short mutex-guarded slot write otherwise. Never allocates.
    #[inline]
    pub fn record(&self, phase: TracePhase, req: u64, lane: i32, arg: u64) {
        let mode = self.mode.load(Ordering::Relaxed);
        if mode == MODE_OFF {
            return;
        }
        if !phase.is_error() {
            match mode {
                MODE_ERRORS => return,
                MODE_SAMPLED => {
                    let n = self.sample_n.load(Ordering::Relaxed) as u64;
                    if req != 0 && req % n != 0 {
                        return;
                    }
                }
                _ => {}
            }
        }
        let ev = TraceEvent { at_ns: self.epoch.elapsed().as_nanos() as u64, req, lane, phase, arg };
        let mut g = self.ring_locked();
        let cap = g.buf.capacity();
        if g.buf.len() < cap {
            g.buf.push(ev);
        } else {
            let at = g.next;
            g.buf[at] = ev;
        }
        g.next = (g.next + 1) % cap;
        g.seq += 1;
    }

    /// Total events ever recorded (monotone across ring wraps).
    pub fn total_recorded(&self) -> u64 {
        self.ring_locked().seq
    }

    /// The newest `n` events, oldest first.
    pub fn recent(&self, n: usize) -> Vec<TraceEvent> {
        let g = self.ring_locked();
        let len = g.buf.len();
        let take = n.min(len);
        let mut out = Vec::with_capacity(take);
        for i in 0..take {
            // the i-th of the `take` newest: when wrapped (len == cap)
            // the oldest live slot is at `next`
            let idx = if len < g.buf.capacity() {
                len - take + i
            } else {
                (g.next + (len - take) + i) % len
            };
            out.push(g.buf[idx]);
        }
        out
    }

    /// Nanoseconds since the recorder's epoch (for stamping snapshots).
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Freeze a failure snapshot: the trailing [`POSTMORTEM_TAIL`] ring
    /// events filtered to the blamed lane + engine-level events
    /// (`blamed_lane == -1` keeps everything). Failure path — allocation
    /// here is fine.
    pub fn snapshot_postmortem(&self, note: &str, blamed_lane: i32) {
        let tail = self.recent(POSTMORTEM_TAIL);
        let events: Vec<TraceEvent> = tail
            .into_iter()
            .filter(|e| blamed_lane < 0 || e.lane == blamed_lane || e.lane < 0)
            .collect();
        let pm = Postmortem {
            note: note.to_string(),
            blamed_lane,
            at_ns: self.now_ns(),
            events,
        };
        let mut g = self.pm_locked();
        g.push(pm);
        let excess = g.len().saturating_sub(POSTMORTEM_KEEP);
        if excess > 0 {
            g.drain(..excess);
        }
    }

    /// All retained postmortem dumps, oldest first.
    pub fn postmortems(&self) -> Vec<Postmortem> {
        self.pm_locked().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_roundtrip() {
        for s in ["off", "errors", "sampled:16", "full"] {
            let m = TraceMode::parse(s).unwrap();
            assert_eq!(m.as_string(), s);
            assert_eq!(TraceMode::parse(&m.as_string()).unwrap(), m);
        }
        assert_eq!(TraceMode::parse("").unwrap(), TraceMode::Off);
        assert!(TraceMode::parse("sampled:0").is_err());
        assert!(TraceMode::parse("sampled:x").is_err());
        assert!(TraceMode::parse("verbose").is_err());
    }

    #[test]
    fn ring_wraparound_keeps_only_newest() {
        let t = TraceRecorder::with_capacity(TraceMode::Full, 8);
        for i in 0..20u64 {
            t.record(TracePhase::DecodeBatch, 0, -1, i);
        }
        assert_eq!(t.total_recorded(), 20);
        let all = t.recent(100);
        assert_eq!(all.len(), 8, "ring holds at most its capacity");
        let args: Vec<u64> = all.iter().map(|e| e.arg).collect();
        assert_eq!(args, (12..20).collect::<Vec<u64>>(), "only the newest survive, oldest first");
        let last3: Vec<u64> = t.recent(3).iter().map(|e| e.arg).collect();
        assert_eq!(last3, vec![17, 18, 19]);
        // timestamps are monotone
        assert!(all.windows(2).all(|w| w[0].at_ns <= w[1].at_ns));
    }

    #[test]
    fn errors_mode_filters_and_sampled_keeps_one_in_n() {
        let t = TraceRecorder::with_capacity(TraceMode::Errors, 32);
        t.record(TracePhase::Enqueue, 1, -1, 0);
        t.record(TracePhase::DecodeBatch, 0, -1, 4);
        assert_eq!(t.total_recorded(), 0, "healthy traffic records nothing in errors mode");
        t.record(TracePhase::LaneFailure, 1, 2, 1);
        t.record(TracePhase::EngineRestart, 0, -1, 1);
        assert_eq!(t.total_recorded(), 2);

        let s = TraceRecorder::with_capacity(TraceMode::Sampled(4), 64);
        for id in 1..=12u64 {
            s.record(TracePhase::Enqueue, id, -1, 0);
        }
        let kept: Vec<u64> = s.recent(64).iter().map(|e| e.req).collect();
        assert_eq!(kept, vec![4, 8, 12], "1-in-N by request id");
        s.record(TracePhase::DecodeBatch, 0, -1, 4);
        s.record(TracePhase::LaneFailure, 7, 0, 1);
        assert_eq!(s.total_recorded(), 5, "engine-level + error events always recorded");
    }

    #[test]
    fn off_mode_records_nothing() {
        let t = TraceRecorder::new(TraceMode::Off);
        t.record(TracePhase::LaneFailure, 1, 0, 1);
        t.record(TracePhase::Enqueue, 1, -1, 0);
        assert_eq!(t.total_recorded(), 0);
        assert!(t.recent(10).is_empty());
    }

    #[test]
    fn postmortem_filters_to_blamed_lane_and_caps_retention() {
        let t = TraceRecorder::with_capacity(TraceMode::Full, 64);
        t.record(TracePhase::PrefillChunk, 1, 0, 8);
        t.record(TracePhase::PrefillChunk, 2, 1, 8);
        t.record(TracePhase::DecodeBatch, 0, -1, 2);
        t.record(TracePhase::LaneFailure, 2, 1, 1);
        t.snapshot_postmortem("lane failure (backend error)", 1);
        let pms = t.postmortems();
        assert_eq!(pms.len(), 1);
        let pm = &pms[0];
        assert_eq!(pm.blamed_lane, 1);
        assert!(pm.note.contains("lane failure"));
        assert!(pm.events.iter().all(|e| e.lane == 1 || e.lane < 0));
        assert!(pm.events.iter().any(|e| e.phase == TracePhase::LaneFailure));
        assert!(pm.events.iter().any(|e| e.phase == TracePhase::PrefillChunk && e.req == 2));
        assert!(
            !pm.events.iter().any(|e| e.req == 1 && e.phase == TracePhase::PrefillChunk),
            "other lanes' request events are excluded"
        );

        for i in 0..(POSTMORTEM_KEEP + 3) {
            t.snapshot_postmortem(&format!("dump {i}"), -1);
        }
        assert_eq!(t.postmortems().len(), POSTMORTEM_KEEP, "retention is capped");
    }

    #[test]
    fn chrome_jsonl_lines_parse_as_json() {
        let t = TraceRecorder::with_capacity(TraceMode::Full, 8);
        t.record(TracePhase::Admit, 3, 1, 24);
        t.record(TracePhase::Score, 0, 2, 12345);
        let dump = events_jsonl(&t.recent(8));
        let lines: Vec<&str> = dump.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            let j = Json::parse(line).unwrap();
            assert!(j.get("name").as_str().is_some());
            assert_eq!(j.get("ph").as_str(), Some("i"));
            assert!(j.get("ts").as_f64().is_some());
            assert!(j.get("tid").as_i64().is_some());
            assert!(j.get("args").get("req").as_i64().is_some());
        }
        let first = Json::parse(dump.lines().next().unwrap()).unwrap();
        assert_eq!(first.get("name").as_str(), Some("admit"));
        assert_eq!(first.get("args").get("arg").as_i64(), Some(24));
    }
}
