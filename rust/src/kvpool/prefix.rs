//! Prefix index: content-addressed lookup of shared KV page chains.
//!
//! A page's identity is a **token-hash chain**: the running FNV-1a hash of
//! every prompt token from position 0 through the end of that page, seeded
//! with a fingerprint of the cache-write knobs (`dim_keep`, projection on/
//! off) — two pages carry the same key iff the same token prefix was
//! written under the same knobs into the same backend's pool, which is
//! exactly when their KV content is bit-identical. The index is a radix
//! structure in disguise: node `H_c` (the chain after `c` full
//! `page_slots`-sized chunks) implies all its ancestors, so resolving the
//! longest reusable chain for a new prompt is a walk that stops at the
//! first miss.
//!
//! The index holds **no references**: a node is a weak pointer validated
//! against [`PagePool::page_key`] at lookup time (a recycled page's key is
//! cleared, so stale nodes prune themselves lazily), which keeps the
//! churn invariant — when the last lane retires, every page's refcount
//! reaches zero and `kv_pages_in_use` returns to zero; cached chains live
//! on the free list, resurrectable until recycled. Hash collisions cannot
//! corrupt the math: each node stores its chunk's token ids and a lookup
//! whose tokens differ is a miss, never a false share.

use std::collections::HashMap;

use super::pool::PagePool;

/// FNV-1a 64-bit offset basis — the chain seed before the knob
/// fingerprint is folded in.
pub const PREFIX_SEED: u64 = 0xcbf2_9ce4_8422_2325;

const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Fold one byte into an FNV-1a chain.
pub fn fold_byte(h: u64, b: u8) -> u64 {
    (h ^ b as u64).wrapping_mul(FNV_PRIME)
}

/// Fold one token id into the chain.
pub fn fold_token(h: u64, tok: i32) -> u64 {
    tok.to_le_bytes().iter().fold(h, |h, &b| fold_byte(h, b))
}

/// Fold one `page_slots`-sized chunk of token ids into the chain.
pub fn fold_chunk(h: u64, chunk: &[i32]) -> u64 {
    chunk.iter().fold(h, |h, &t| fold_token(h, t))
}

struct Node {
    page: u32,
    /// The chunk's token ids — compared verbatim at lookup so a 64-bit
    /// hash collision degrades to a cache miss, never a false share.
    tokens: Vec<i32>,
    /// Monotonic recency stamp: bumped on registration and on every
    /// successful lookup (an attach walk touches each chain link it
    /// reuses), so the LRU victim is the least-recently-attached chain.
    last_used: u64,
}

/// Outcome of [`PrefixIndex::insert`]. The caller stamps the page key
/// only on acceptance, and unkeys a displaced or evicted page so it
/// cannot linger as an unreachable "cached" page that plain leases skip.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Register {
    /// Registered under a fresh chain hash.
    Fresh,
    /// Registered, displacing the named page's node (unkey that page).
    Displaced(u32),
    /// Registered at capacity by evicting the least-recently-used chain
    /// node (unkey that page and count a `prefix_evictions`).
    Evicted(u32),
}

/// Chain-hash → page map over registered full prompt chunks, LRU-bounded.
pub struct PrefixIndex {
    nodes: HashMap<u64, Node>,
    /// Max registered nodes (0 = unlimited). Since every registered page
    /// carries exactly one node's key, this also bounds the keyed
    /// (resurrectable) page set; registration at the cap evicts the
    /// least-recently-used chain instead of refusing.
    capacity: usize,
    /// Monotonic recency clock.
    tick: u64,
}

impl PrefixIndex {
    pub fn new(capacity: usize) -> PrefixIndex {
        PrefixIndex { nodes: HashMap::new(), capacity, tick: 0 }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    fn next_tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Register `page` as holding the chunk whose chain hash is `hash`.
    /// A node with the same hash is replaced (its page was recycled or the
    /// chunk was re-written by another lane) and the displaced page id is
    /// reported so the caller can drop its stale key. At capacity the
    /// least-recently-used node is evicted to make room and its page
    /// reported for unkeying.
    pub fn insert(&mut self, hash: u64, page: u32, tokens: Vec<i32>) -> Register {
        use std::collections::hash_map::Entry;
        let len = self.nodes.len();
        let stamp = self.next_tick();
        match self.nodes.entry(hash) {
            Entry::Occupied(mut o) => {
                let old = o.insert(Node { page, tokens, last_used: stamp });
                Register::Displaced(old.page)
            }
            Entry::Vacant(v) => {
                v.insert(Node { page, tokens, last_used: stamp });
                if self.capacity != 0 && len >= self.capacity {
                    let victim = self
                        .nodes
                        .iter()
                        .min_by_key(|(_, n)| n.last_used)
                        .map(|(&h, n)| (h, n.page))
                        .expect("over-capacity index cannot be empty");
                    self.nodes.remove(&victim.0);
                    return Register::Evicted(victim.1);
                }
                Register::Fresh
            }
        }
    }

    /// Resolve the page holding chain `hash`, validating both liveness
    /// (the page still carries this key in `pool` — leased *or* cached)
    /// and content (the chunk tokens match). Stale nodes are pruned; a
    /// hit refreshes the chain's LRU recency.
    pub fn lookup(&mut self, pool: &PagePool, hash: u64, chunk: &[i32]) -> Option<u32> {
        let (page, content_ok) = {
            let node = self.nodes.get(&hash)?;
            (node.page, node.tokens == chunk)
        };
        if pool.page_key(page) != hash {
            // the page was recycled (or re-keyed): the node is dead
            self.nodes.remove(&hash);
            return None;
        }
        if !content_ok {
            // 64-bit collision: refuse the share, keep the honest entry
            return None;
        }
        let stamp = self.next_tick();
        if let Some(node) = self.nodes.get_mut(&hash) {
            node.last_used = stamp;
        }
        Some(page)
    }
}

#[cfg(test)]
mod tests {
    use super::super::pool::PoolLayout;
    use super::*;

    fn pool() -> PagePool {
        let layout = PoolLayout {
            page_slots: 4,
            key_dims: 2,
            head_dim: 4,
            layers: 1,
            kv_heads: 1,
            kv_quant: super::super::KvQuant::F32,
        };
        PagePool::new(layout, 8)
    }

    #[test]
    fn chain_is_order_and_value_sensitive() {
        let h0 = fold_chunk(PREFIX_SEED, &[1, 2, 3, 4]);
        assert_eq!(h0, fold_chunk(PREFIX_SEED, &[1, 2, 3, 4]));
        assert_ne!(h0, fold_chunk(PREFIX_SEED, &[1, 2, 4, 3]));
        assert_ne!(h0, fold_chunk(PREFIX_SEED, &[1, 2, 3, 5]));
        // chains compose: H(a ++ b) = fold(H(a), b)
        let ha = fold_chunk(PREFIX_SEED, &[9, 8]);
        assert_eq!(fold_chunk(ha, &[7, 6]), fold_chunk(PREFIX_SEED, &[9, 8, 7, 6]));
    }

    #[test]
    fn lookup_validates_liveness_and_content() {
        // max_pages 1: growth is exhausted, so the cached page is the one
        // a plain lease recycles
        let layout = PoolLayout {
            page_slots: 4,
            key_dims: 2,
            head_dim: 4,
            layers: 1,
            kv_heads: 1,
            kv_quant: super::super::KvQuant::F32,
        };
        let mut p = PagePool::new(layout, 1);
        let mut idx = PrefixIndex::new(0);
        let chunk = [10, 11, 12, 13];
        let h = fold_chunk(PREFIX_SEED, &chunk);
        let page = p.lease().unwrap();
        p.set_page_key(page, h).unwrap();
        assert_eq!(idx.insert(h, page, chunk.to_vec()), Register::Fresh);

        assert_eq!(idx.lookup(&p, h, &chunk), Some(page));
        // same hash, different tokens (simulated collision): miss, entry kept
        assert_eq!(idx.lookup(&p, h, &[10, 11, 12, 99]), None);
        assert_eq!(idx.len(), 1);
        assert_eq!(idx.lookup(&p, h, &chunk), Some(page));

        // cached (freed, key intact) pages still resolve
        p.free(page).unwrap();
        assert_eq!(idx.lookup(&p, h, &chunk), Some(page));

        // a recycling lease clears the key: the node self-prunes
        let recycled = p.lease().unwrap();
        assert_eq!(recycled, page, "test setup: the cached page was recycled");
        assert_eq!(idx.lookup(&p, h, &chunk), None);
        assert!(idx.is_empty(), "stale node pruned on lookup");
    }

    #[test]
    fn capacity_evicts_lru_and_reports_displacement() {
        let mut p = pool();
        let mut idx = PrefixIndex::new(1);
        let a = p.lease().unwrap();
        let b = p.lease().unwrap();
        let (ha, hb) = (fold_token(PREFIX_SEED, 1), fold_token(PREFIX_SEED, 2));
        p.set_page_key(a, ha).unwrap();
        assert_eq!(idx.insert(ha, a, vec![1]), Register::Fresh);
        // at capacity a new chain evicts the least-recently-used node
        assert_eq!(idx.insert(hb, b, vec![2]), Register::Evicted(a), "LRU eviction at cap");
        assert_eq!(idx.len(), 1);
        // replacing an existing hash is not growth, and names the loser
        assert_eq!(idx.insert(hb, a, vec![2]), Register::Displaced(b));
        assert_eq!(idx.len(), 1);
    }

    #[test]
    fn lru_eviction_prefers_the_least_recently_attached_chain() {
        let mut p = pool();
        let mut idx = PrefixIndex::new(2);
        let a = p.lease().unwrap();
        let b = p.lease().unwrap();
        let c = p.lease().unwrap();
        let ha = fold_chunk(PREFIX_SEED, &[1, 1, 1, 1]);
        let hb = fold_chunk(PREFIX_SEED, &[2, 2, 2, 2]);
        let hc = fold_chunk(PREFIX_SEED, &[3, 3, 3, 3]);
        p.set_page_key(a, ha).unwrap();
        p.set_page_key(b, hb).unwrap();
        assert_eq!(idx.insert(ha, a, vec![1, 1, 1, 1]), Register::Fresh);
        assert_eq!(idx.insert(hb, b, vec![2, 2, 2, 2]), Register::Fresh);
        // touch `a` via lookup: `b` becomes the LRU victim
        assert_eq!(idx.lookup(&p, ha, &[1, 1, 1, 1]), Some(a));
        assert_eq!(idx.insert(hc, c, vec![3, 3, 3, 3]), Register::Evicted(b));
        assert_eq!(idx.len(), 2);
        // the survivor and the newcomer both still resolve
        p.set_page_key(c, hc).unwrap();
        assert_eq!(idx.lookup(&p, ha, &[1, 1, 1, 1]), Some(a));
        assert_eq!(idx.lookup(&p, hc, &[3, 3, 3, 3]), Some(c));
    }
}
