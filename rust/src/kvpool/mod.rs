//! Paged KV-memory pool: lease-on-demand pages with AQUA-truncated
//! resident keys.
//!
//! Before this subsystem every lane preallocated a dense
//! `[L, n_kv, d, max_seq]` key cache and `[L, n_kv, max_seq, d]` value
//! cache regardless of how long the sequence actually ran, and
//! `AquaConfig::mem_dims` (the paper's AQUA-Memory knob, `kv_keep =
//! 1 - S_ratio`) was only cost-model arithmetic — the backends allocated
//! full-width keys no matter what. The pool makes both memory levers real:
//!
//! * **Paging** — a lane's KV storage is a list of fixed-size *pages*
//!   ([`PagePool`], [`LanePageTable`]) leased on demand as the sequence
//!   grows (prefill chunks, decode steps) and returned to the free list
//!   when H2O eviction kills every slot on a page or the lane retires.
//!   Resident bytes track actual context, not `max_seq`.
//! * **Truncated resident keys** — each page stores keys in the same
//!   dim-major packed layout the PR 2 score kernels consume, but only the
//!   leading [`PoolLayout::key_dims`] projected dimensions (`mem_dims(d)`)
//!   are resident; values stay full width. With `kv_keep = 1.0` the layout
//!   is byte-for-byte the dense dim-major cache cut into pages, and the
//!   score path is bit-identical to the pre-pool packed kernels.
//!
//! One page holds `page_slots` consecutive token positions of one lane
//! across *all* layers and KV heads:
//!
//! ```text
//! page = [ K: (L, n_kv, key_dims, page_slots) dim-major
//!        | V: (L, n_kv, page_slots, d)        row-major ]
//! ```
//!
//! so the packed kernel streams `key_dims`-contiguous runs of
//! `page_slots` floats per (layer, head) exactly as it streamed
//! `max_seq`-strided runs before — compute and memory traffic both scale
//! with the AQUA knobs.
//!
//! The pool is the *backend-side* half of the memory story. The
//! *admission-side* half lives in `registry::Deployment`: a deployment's
//! `kv_budget_mb` caps [`PagePool::max_pages`], and submits reserve their
//! worst-case page growth up front (shedding with a distinct
//! memory-pressure 429 when the pool cannot cover it), so a leased page is
//! always available when the backend asks — lease failure is a bug
//! surfaced as a deterministic error, never an over-allocation.

//! **Prefix sharing & copy-on-write (PR 5).** Pages are refcounted and
//! content-addressed: a full prompt chunk written under fixed knobs gets a
//! token-chain [`prefix::PrefixIndex`] key, and a later request whose
//! prompt shares that chain *adopts* the resident pages instead of
//! re-running prefill — one prefill serves every lane with the prefix.
//! Shared pages are read in place (scores don't care who owns a page);
//! a write to one goes through copy-on-write
//! ([`LanePageTable::ensure_mut`]); H2O reclaim and lane retirement drop
//! references, freeing only at refcount zero. Freed pages that still
//! carry a key stay "cached" on the free list — reusable by any lease,
//! but resurrectable with their content until recycled — so the AQUA
//! twist compounds: shared pages store the same *truncated* `mem_dims(d)`
//! keys, and sharing multiplies the `kv_keep` savings byte-for-byte.

pub mod lane;
pub mod pool;
pub mod prefix;

pub use lane::LanePageTable;
pub use pool::{PagePool, PoolLayout};
pub use prefix::{PrefixIndex, Register};

use anyhow::{bail, Result};

/// Element type of the resident KV payload (PR 10). `F32` is the
/// byte-for-byte pre-quantization layout; `Int8` stores both the
/// truncated projected keys and the values as int8 with per-page,
/// per-(layer, kv-head) dequantization scales in a small f32 sidecar —
/// dequantization is fused into the streaming score/AV loop, so the
/// payload is never materialized at full width.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum KvQuant {
    /// Full-precision resident KV (the default; bit-identical to the
    /// pre-PR-10 pool).
    #[default]
    F32,
    /// Int8 payload + per-page f32 scale sidecar (~4x smaller resident
    /// pages; readable only through the fused dequantizing kernels).
    Int8,
}

impl KvQuant {
    /// Bytes per payload element.
    pub fn elem_bytes(self) -> usize {
        match self {
            KvQuant::F32 => 4,
            KvQuant::Int8 => 1,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            KvQuant::F32 => "f32",
            KvQuant::Int8 => "int8",
        }
    }

    /// Parse the deployment-spec / CLI spelling.
    pub fn parse(s: &str) -> Result<KvQuant> {
        match s {
            "f32" => Ok(KvQuant::F32),
            "int8" => Ok(KvQuant::Int8),
            other => bail!("kv_quant must be \"f32\" or \"int8\", got {other:?}"),
        }
    }
}

/// Default page size in token slots. Matches the native prefill chunk so
/// one prefill call touches at most two pages per lane.
pub const DEFAULT_PAGE_SLOTS: usize = 16;

/// Point-in-time pool gauges, reported by backends in every `StepOut` so
/// they flow through engine metrics to `/stats` and `/metrics` without a
/// cross-thread query path (the sharded backend just sums its workers').
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct KvPoolGauges {
    /// Bytes held by currently leased pages (`pages_in_use · page_bytes`)
    /// — the "resident KV" headline the AQUA-Memory claim is about.
    pub resident_bytes: u64,
    /// Bytes of backing storage ever grown (`pages_hwm · page_bytes`);
    /// freed pages stay allocated on the free list for reuse.
    pub backing_bytes: u64,
    /// Pages currently leased.
    pub pages_in_use: u64,
    /// High-water mark of distinct pages ever leased.
    pub pages_hwm: u64,
    /// Pool headroom: pages still leasable before the cap
    /// (`max_pages - pages_in_use`). For an unbudgeted deployment the cap
    /// is the worst case the batch can ever touch (which never stalls),
    /// so the headroom is to that bound, not to a memory budget.
    pub pages_free: u64,
    /// Pages currently mapped by more than one lane (prefix sharing).
    pub shared_pages: u64,
    /// Token slots per page (0 when no pool is configured).
    pub page_slots: u64,
    /// Bytes per page (0 when no pool is configured).
    pub page_bytes: u64,
    /// Cumulative successful leases.
    pub leases: u64,
    /// Cumulative frees.
    pub frees: u64,
    /// Cumulative lease attempts refused because `max_pages` was reached
    /// (admission should keep this at 0; nonzero means the budget gate and
    /// the pool disagree).
    pub alloc_stalls: u64,
    /// Cumulative copy-on-write page copies (a write hit a shared page).
    pub cow_copies: u64,
    /// Cumulative prefix-index LRU evictions: chains unkeyed because the
    /// `prefix_cache_pages` cap displaced the least-recently-attached one.
    pub prefix_evictions: u64,
}

impl KvPoolGauges {
    /// Fold another backend shard's gauges in (the sharded backend's
    /// workers each own an independent sub-pool).
    pub fn merge(&mut self, o: &KvPoolGauges) {
        self.resident_bytes += o.resident_bytes;
        self.backing_bytes += o.backing_bytes;
        self.pages_in_use += o.pages_in_use;
        self.pages_hwm += o.pages_hwm;
        self.pages_free += o.pages_free;
        self.shared_pages += o.shared_pages;
        self.page_slots = self.page_slots.max(o.page_slots);
        self.page_bytes = self.page_bytes.max(o.page_bytes);
        self.leases += o.leases;
        self.frees += o.frees;
        self.alloc_stalls += o.alloc_stalls;
        self.cow_copies += o.cow_copies;
        self.prefix_evictions += o.prefix_evictions;
    }
}

/// How a backend should shape its KV pool. Applied at the next
/// `empty_cache` (the pool is a per-batch allocation, like the dense
/// caches it replaces).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KvPoolConfig {
    /// Resident projected key dims per slot (`AquaConfig::mem_dims`);
    /// `None` = full head width (no truncation).
    pub key_dims: Option<usize>,
    /// Token slots per page; `None` = [`DEFAULT_PAGE_SLOTS`].
    pub page_slots: Option<usize>,
    /// Hard cap on leased pages (the deployment's `kv_budget_mb` in page
    /// units); `None` = worst case (`batch · ceil(max_seq / page_slots)`),
    /// which can never stall.
    pub max_pages: Option<usize>,
    /// Enable page-granular prefix sharing: register full prompt chunks in
    /// a [`PrefixIndex`] and let `attach_prefix` map them into new lanes.
    pub prefix_cache: bool,
    /// Max chains the prefix index registers (0 = unlimited).
    pub prefix_cache_pages: usize,
    /// Resident KV payload element type (default [`KvQuant::F32`]).
    pub kv_quant: KvQuant,
}

/// Pages a `kv_budget_mb` megabyte budget buys under `layout`; `None` when
/// the budget is unlimited (<= 0). Shared by the engine (pool cap) and the
/// registry's admission gate so the two can never disagree.
pub fn budget_pages(kv_budget_mb: f64, layout: &PoolLayout) -> Option<usize> {
    if kv_budget_mb <= 0.0 {
        return None;
    }
    let bytes = kv_budget_mb * (1 << 20) as f64;
    Some((bytes / layout.page_bytes() as f64).floor() as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> PoolLayout {
        PoolLayout {
            page_slots: 16,
            key_dims: 4,
            head_dim: 8,
            layers: 2,
            kv_heads: 2,
            kv_quant: KvQuant::F32,
        }
    }

    #[test]
    fn budget_pages_floor_and_unlimited() {
        let l = layout();
        // page = 2*2*16*(4+8)*4 = 3072 bytes
        assert_eq!(l.page_bytes(), 3072);
        assert_eq!(budget_pages(0.0, &l), None);
        assert_eq!(budget_pages(-1.0, &l), None);
        assert_eq!(budget_pages(1.0, &l), Some((1 << 20) / 3072)); // 341
        // a budget smaller than one page buys zero pages (sheds everything
        // deterministically rather than over-allocating)
        assert_eq!(budget_pages(0.001, &l), Some(0));
    }

    #[test]
    fn int8_budget_buys_almost_4x_the_pages() {
        let f = layout();
        let q = PoolLayout { kv_quant: KvQuant::Int8, ..f };
        // payload 768 int8 bytes + 2*2*2 f32 scales = 800 bytes/page
        assert_eq!(q.page_bytes(), 768 + 32);
        let (pf, pq) = (budget_pages(4.0, &f).unwrap(), budget_pages(4.0, &q).unwrap());
        assert!(pq > 3 * pf, "int8 budget pages {pq} vs f32 {pf}");
    }

    #[test]
    fn gauges_merge_sums_and_keeps_shape() {
        let mut a = KvPoolGauges {
            resident_bytes: 100,
            backing_bytes: 200,
            pages_in_use: 1,
            pages_hwm: 2,
            pages_free: 7,
            shared_pages: 1,
            page_slots: 16,
            page_bytes: 100,
            leases: 3,
            frees: 1,
            alloc_stalls: 0,
            cow_copies: 1,
            prefix_evictions: 2,
        };
        let b = KvPoolGauges {
            resident_bytes: 50,
            backing_bytes: 100,
            pages_in_use: 1,
            pages_hwm: 1,
            pages_free: 3,
            shared_pages: 0,
            page_slots: 16,
            page_bytes: 100,
            leases: 1,
            frees: 0,
            alloc_stalls: 2,
            cow_copies: 0,
            prefix_evictions: 1,
        };
        a.merge(&b);
        assert_eq!(a.resident_bytes, 150);
        assert_eq!(a.pages_in_use, 2);
        assert_eq!(a.pages_hwm, 3);
        assert_eq!(a.pages_free, 10, "shard headroom adds");
        assert_eq!(a.shared_pages, 1);
        assert_eq!(a.page_slots, 16);
        assert_eq!(a.leases, 4);
        assert_eq!(a.alloc_stalls, 2);
        assert_eq!(a.cow_copies, 1);
        assert_eq!(a.prefix_evictions, 3);
    }
}
