//! The block allocator: fixed-size KV pages with a free list, refcounts,
//! and content identity.
//!
//! Backing storage grows lazily — the data vector extends by one page at a
//! time up to `max_pages`, so a pool sized for the worst case costs only
//! what the high-water mark of concurrent context actually touched.
//! Freed pages go on a free list and are recycled (zeroed at lease) before
//! the backing vector grows again.
//!
//! Since the prefix-sharing refactor a page is **refcounted**: several
//! lanes may map the same page ([`PagePool::retain`]), `free` decrements
//! and only returns the page to the free list at refcount zero, and a
//! write to a shared page goes through [`PagePool::cow`] (lease a fresh
//! page, memcpy the resident dims, drop one ref). A page can also carry a
//! **content key** ([`PagePool::set_page_key`]) — the token-chain identity
//! the [`super::PrefixIndex`] resolves shared prefixes by. Keyed pages
//! whose last ref drops are returned to the free list *with their content
//! and key intact* ("cached"): they count as free (reusable — a later
//! lease zeroes and unkeys them), but an attach that arrives first can
//! [`PagePool::resurrect`] them without re-running prefill.

use anyhow::{bail, Result};

use super::KvPoolGauges;

/// Geometry of one page (see the module docs for the memory layout).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolLayout {
    /// Token slots per page.
    pub page_slots: usize,
    /// Resident projected key dims per slot (`mem_dims(d)`, <= head_dim).
    pub key_dims: usize,
    /// Full head width `d` (values are stored at this width).
    pub head_dim: usize,
    pub layers: usize,
    pub kv_heads: usize,
}

impl PoolLayout {
    /// f32 elements per page: K region + V region.
    pub fn page_elems(&self) -> usize {
        self.layers * self.kv_heads * self.page_slots * (self.key_dims + self.head_dim)
    }

    pub fn page_bytes(&self) -> usize {
        self.page_elems() * std::mem::size_of::<f32>()
    }

    /// Resident KV bytes per token slot (`page_bytes / page_slots`): the
    /// quantity `AquaConfig::kv_bytes_per_slot` models.
    pub fn bytes_per_slot(&self) -> usize {
        self.layers * self.kv_heads * (self.key_dims + self.head_dim) * 4
    }

    /// Offset of the (layer, kv-head) dim-major key block inside a page;
    /// dim `i`, local slot `s` live at `key_off + i * page_slots + s`.
    pub fn key_off(&self, l: usize, g: usize) -> usize {
        (l * self.kv_heads + g) * self.key_dims * self.page_slots
    }

    /// Offset of the (layer, kv-head, local slot) value row (head_dim
    /// contiguous floats).
    pub fn val_off(&self, l: usize, g: usize, local: usize) -> usize {
        let v_base = self.layers * self.kv_heads * self.key_dims * self.page_slots;
        v_base + ((l * self.kv_heads + g) * self.page_slots + local) * self.head_dim
    }

    /// Pages needed to hold `slots` token positions (ceiling).
    pub fn pages_for_slots(&self, slots: usize) -> usize {
        slots.div_ceil(self.page_slots)
    }

    /// Worst-case pages a request with `want_slots = prompt + max_new`
    /// can grow to on a `max_seq`-capacity lane — the **single** formula
    /// the engine's memory-aware admission and the registry's reservation
    /// gate both use (they must never disagree).
    pub fn worst_case_pages(&self, want_slots: usize, max_seq: usize) -> usize {
        self.pages_for_slots(want_slots.min(max_seq))
    }
}

/// Page allocator with a free list. Page ids are dense indices into the
/// backing vector; a leased bitmap catches double-frees and stale ids.
pub struct PagePool {
    layout: PoolLayout,
    max_pages: usize,
    data: Vec<f32>,
    /// Free pages with no content identity — the O(1) hot-path pop.
    free_plain: Vec<u32>,
    /// Free pages still carrying a key ("cached"): resurrectable until a
    /// plain lease runs out of growth and recycles them.
    free_cached: Vec<u32>,
    leased: Vec<bool>,
    /// Per-page refcount (0 while free/cached).
    refs: Vec<u32>,
    /// Per-page content identity (token-chain hash; 0 = none). Survives
    /// the last free so the page stays resurrectable until recycled.
    keys: Vec<u64>,
    leases: u64,
    frees: u64,
    stalls: u64,
    cow_copies: u64,
    prefix_evictions: u64,
}

impl PagePool {
    pub fn new(layout: PoolLayout, max_pages: usize) -> PagePool {
        PagePool {
            layout,
            max_pages,
            data: vec![],
            free_plain: vec![],
            free_cached: vec![],
            leased: vec![],
            refs: vec![],
            keys: vec![],
            leases: 0,
            frees: 0,
            stalls: 0,
            cow_copies: 0,
            prefix_evictions: 0,
        }
    }

    pub fn layout(&self) -> &PoolLayout {
        &self.layout
    }

    pub fn max_pages(&self) -> usize {
        self.max_pages
    }

    /// Turn a popped free page into a fresh zeroed single-ref lease.
    fn reset_page(&mut self, id: u32) {
        let elems = self.layout.page_elems();
        let base = id as usize * elems;
        self.data[base..base + elems].fill(0.0);
        self.leased[id as usize] = true;
        self.refs[id as usize] = 1;
        self.keys[id as usize] = 0;
        self.leases += 1;
    }

    /// Lease one zeroed page. Preference order: the newest *plain* free
    /// page (O(1) pop — the hot write path never scans), then backing
    /// growth, then — only when growth is exhausted — recycling a cached
    /// (keyed) page, so resurrectable prefix content survives as long as
    /// the budget allows (the budget caps *leased* pages; freed backing
    /// stays allocated for reuse either way, exactly as before). Errors
    /// (after counting an alloc stall) when `max_pages` are already
    /// leased — the admission layer's reservation gate exists so this
    /// never fires in a correctly configured deployment.
    pub fn lease(&mut self) -> Result<u32> {
        if let Some(id) = self.free_plain.pop() {
            self.reset_page(id);
            return Ok(id);
        }
        let hwm = self.leased.len();
        if hwm < self.max_pages {
            let elems = self.layout.page_elems();
            self.data.resize((hwm + 1) * elems, 0.0);
            self.leased.push(true);
            self.refs.push(1);
            self.keys.push(0);
            self.leases += 1;
            return Ok(hwm as u32);
        }
        if let Some(id) = self.free_cached.pop() {
            self.reset_page(id);
            return Ok(id);
        }
        self.stalls += 1;
        bail!(
            "kv pool exhausted: {} pages leased of max {} (budget too small for this load)",
            self.pages_in_use(),
            self.max_pages
        );
    }

    /// Add one reference to a leased page (a second lane mapping it).
    pub fn retain(&mut self, id: u32) -> Result<()> {
        match self.leased.get(id as usize).copied() {
            Some(true) => {
                self.refs[id as usize] += 1;
                Ok(())
            }
            Some(false) => bail!("kv pool: retain of free page {id}"),
            None => bail!("kv pool: retain of unknown page {id}"),
        }
    }

    /// Drop one reference; the page returns to the free list when the last
    /// ref drops (keyed pages keep content + key — "cached" — until a
    /// plain lease recycles them). Double-frees and unknown ids error.
    pub fn free(&mut self, id: u32) -> Result<()> {
        match self.leased.get(id as usize).copied() {
            Some(true) => {
                self.refs[id as usize] -= 1;
                if self.refs[id as usize] == 0 {
                    self.leased[id as usize] = false;
                    if self.keys[id as usize] == 0 {
                        self.free_plain.push(id);
                    } else {
                        self.free_cached.push(id);
                    }
                    self.frees += 1;
                }
                Ok(())
            }
            Some(false) => bail!("kv pool: double free of page {id}"),
            None => bail!("kv pool: free of unknown page {id}"),
        }
    }

    /// Revive a cached page (free, key intact, content intact) as a fresh
    /// single-ref lease *without* zeroing — the prefix-attach fast path.
    /// Errors if the page is leased, was recycled, or carries another key.
    pub fn resurrect(&mut self, id: u32, key: u64) -> Result<()> {
        match self.leased.get(id as usize).copied() {
            Some(false) if key != 0 && self.keys[id as usize] == key => {
                let at = self
                    .free_cached
                    .iter()
                    .position(|&f| f == id)
                    .ok_or_else(|| anyhow::anyhow!("kv pool: cached page {id} not on free list"))?;
                self.free_cached.swap_remove(at);
                self.leased[id as usize] = true;
                self.refs[id as usize] = 1;
                self.leases += 1;
                Ok(())
            }
            Some(false) => bail!("kv pool: page {id} no longer caches key {key:#x}"),
            Some(true) => bail!("kv pool: resurrect of leased page {id}"),
            None => bail!("kv pool: resurrect of unknown page {id}"),
        }
    }

    /// Copy-on-write: lease a fresh page, memcpy the shared page's resident
    /// content into it, and drop one ref from the original. The copy is
    /// unkeyed (its content is about to diverge). Errors if the page is
    /// not actually shared (refs < 2) or the pool is exhausted.
    pub fn cow(&mut self, id: u32) -> Result<u32> {
        if self.leased.get(id as usize) != Some(&true) || self.refs[id as usize] < 2 {
            bail!("kv pool: cow of unshared page {id}");
        }
        let fresh = self.lease()?;
        let elems = self.layout.page_elems();
        let src = id as usize * elems;
        self.data.copy_within(src..src + elems, fresh as usize * elems);
        self.refs[id as usize] -= 1;
        self.cow_copies += 1;
        Ok(fresh)
    }

    /// Stamp a leased page's content identity (the prefix chain hash).
    pub fn set_page_key(&mut self, id: u32, key: u64) -> Result<()> {
        if self.leased.get(id as usize) != Some(&true) {
            bail!("kv pool: set_page_key on unleased page {id}");
        }
        self.keys[id as usize] = key;
        Ok(())
    }

    /// Drop a page's content identity (its index node was displaced or
    /// refused). A cached page becomes a plain free page again, so the
    /// hot-path lease recycles it before growing backing. No-op for
    /// unknown/unkeyed ids.
    pub fn clear_page_key(&mut self, id: u32) {
        let Some(k) = self.keys.get_mut(id as usize) else { return };
        if *k == 0 {
            return;
        }
        *k = 0;
        if self.leased[id as usize] {
            return; // still mapped; it frees as plain later
        }
        if let Some(at) = self.free_cached.iter().position(|&f| f == id) {
            self.free_cached.swap_remove(at);
            self.free_plain.push(id);
        }
    }

    /// Count one prefix-index LRU eviction (the caller just unkeyed the
    /// victim chain's page via [`PagePool::clear_page_key`]).
    pub fn note_prefix_eviction(&mut self) {
        self.prefix_evictions += 1;
    }

    /// A page's content key (0 = none / recycled). Valid for leased pages
    /// and cached (freed-but-keyed) pages alike.
    pub fn page_key(&self, id: u32) -> u64 {
        self.keys.get(id as usize).copied().unwrap_or(0)
    }

    /// Current reference count (0 while free/cached).
    pub fn ref_count(&self, id: u32) -> u32 {
        self.refs.get(id as usize).copied().unwrap_or(0)
    }

    pub fn is_leased(&self, id: u32) -> bool {
        self.leased.get(id as usize) == Some(&true)
    }

    pub fn page(&self, id: u32) -> &[f32] {
        let elems = self.layout.page_elems();
        let base = id as usize * elems;
        &self.data[base..base + elems]
    }

    pub fn page_mut(&mut self, id: u32) -> &mut [f32] {
        let elems = self.layout.page_elems();
        let base = id as usize * elems;
        &mut self.data[base..base + elems]
    }

    pub fn pages_in_use(&self) -> usize {
        self.leased.len() - self.free_plain.len() - self.free_cached.len()
    }

    /// Distinct pages ever leased (the backing vector's size in pages).
    pub fn pages_hwm(&self) -> usize {
        self.leased.len()
    }

    /// Pages currently mapped by more than one holder.
    pub fn shared_pages(&self) -> usize {
        self.refs.iter().filter(|&&r| r >= 2).count()
    }

    /// Bytes held by currently leased pages.
    pub fn resident_bytes(&self) -> usize {
        self.pages_in_use() * self.layout.page_bytes()
    }

    pub fn gauges(&self) -> KvPoolGauges {
        KvPoolGauges {
            resident_bytes: self.resident_bytes() as u64,
            backing_bytes: (self.pages_hwm() * self.layout.page_bytes()) as u64,
            pages_in_use: self.pages_in_use() as u64,
            pages_hwm: self.pages_hwm() as u64,
            pages_free: self.max_pages.saturating_sub(self.pages_in_use()) as u64,
            shared_pages: self.shared_pages() as u64,
            page_slots: self.layout.page_slots as u64,
            page_bytes: self.layout.page_bytes() as u64,
            leases: self.leases,
            frees: self.frees,
            alloc_stalls: self.stalls,
            cow_copies: self.cow_copies,
            prefix_evictions: self.prefix_evictions,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> PoolLayout {
        PoolLayout { page_slots: 4, key_dims: 2, head_dim: 4, layers: 1, kv_heads: 1 }
    }

    #[test]
    fn layout_offsets_tile_the_page() {
        let l = PoolLayout { page_slots: 8, key_dims: 3, head_dim: 4, layers: 2, kv_heads: 2 };
        // K region: 2*2*3*8 = 96 elems, V region: 2*2*8*4 = 128 elems
        assert_eq!(l.page_elems(), 96 + 128);
        assert_eq!(l.page_bytes(), (96 + 128) * 4);
        assert_eq!(l.bytes_per_slot() * l.page_slots, l.page_bytes());
        assert_eq!(l.key_off(0, 0), 0);
        assert_eq!(l.key_off(1, 1), 3 * 3 * 8);
        assert_eq!(l.val_off(0, 0, 0), 96);
        // last value row ends exactly at the page boundary
        assert_eq!(l.val_off(1, 1, 7) + l.head_dim, l.page_elems());
        assert_eq!(l.pages_for_slots(0), 0);
        assert_eq!(l.pages_for_slots(8), 1);
        assert_eq!(l.pages_for_slots(9), 2);
    }

    #[test]
    fn lease_free_recycles_without_growth() {
        let mut p = PagePool::new(layout(), 4);
        let a = p.lease().unwrap();
        let b = p.lease().unwrap();
        assert_eq!(p.pages_in_use(), 2);
        assert_eq!(p.pages_hwm(), 2);
        p.page_mut(a)[0] = 7.0;
        p.free(a).unwrap();
        assert_eq!(p.pages_in_use(), 1);
        let c = p.lease().unwrap();
        assert_eq!(c, a, "free list recycles before growing");
        assert_eq!(p.pages_hwm(), 2, "recycling must not grow backing");
        assert_eq!(p.page(c)[0], 0.0, "recycled pages are zeroed");
        assert_ne!(b, c);
        assert_eq!(p.resident_bytes(), 2 * p.layout().page_bytes());
        assert_eq!(p.gauges().pages_free, 2, "headroom = max_pages - in_use");
    }

    #[test]
    fn exhaustion_errors_and_counts_stalls() {
        let mut p = PagePool::new(layout(), 2);
        p.lease().unwrap();
        p.lease().unwrap();
        assert!(p.lease().is_err());
        assert!(p.lease().is_err());
        assert_eq!(p.gauges().alloc_stalls, 2);
        assert_eq!(p.pages_in_use(), 2);
        assert_eq!(p.gauges().pages_free, 0);
    }

    #[test]
    fn double_free_and_bad_id_error() {
        let mut p = PagePool::new(layout(), 2);
        let a = p.lease().unwrap();
        p.free(a).unwrap();
        assert!(p.free(a).is_err(), "double free must error");
        assert!(p.free(99).is_err(), "unknown id must error");
        assert_eq!(p.gauges().frees, 1);
    }

    #[test]
    fn shared_pages_free_once_per_holder() {
        let mut p = PagePool::new(layout(), 4);
        let a = p.lease().unwrap();
        p.retain(a).unwrap();
        p.retain(a).unwrap();
        assert_eq!(p.ref_count(a), 3);
        assert_eq!(p.shared_pages(), 1);
        assert_eq!(p.gauges().shared_pages, 1);
        p.free(a).unwrap();
        p.free(a).unwrap();
        assert!(p.is_leased(a), "page lives while any holder remains");
        assert_eq!(p.shared_pages(), 0, "one holder left is not shared");
        p.free(a).unwrap();
        assert!(!p.is_leased(a));
        assert!(p.free(a).is_err(), "refcounts must not underflow");
        assert!(p.retain(a).is_err(), "cannot retain a free page");
    }

    #[test]
    fn cow_copies_content_and_drops_one_ref() {
        let mut p = PagePool::new(layout(), 4);
        let a = p.lease().unwrap();
        p.page_mut(a)[3] = 9.5;
        assert!(p.cow(a).is_err(), "unshared pages never cow");
        p.retain(a).unwrap();
        let b = p.cow(a).unwrap();
        assert_ne!(a, b);
        assert_eq!(p.page(b)[3], 9.5, "cow must copy the resident content");
        assert_eq!(p.ref_count(a), 1);
        assert_eq!(p.ref_count(b), 1);
        assert_eq!(p.gauges().cow_copies, 1);
        // the copy diverges independently
        p.page_mut(b)[3] = 1.0;
        assert_eq!(p.page(a)[3], 9.5);
    }

    #[test]
    fn cached_pages_resurrect_with_content_and_stay_reusable() {
        let mut p = PagePool::new(layout(), 2);
        let a = p.lease().unwrap();
        p.page_mut(a)[1] = 4.25;
        p.set_page_key(a, 0xFEED).unwrap();
        p.free(a).unwrap();
        assert_eq!(p.pages_in_use(), 0, "cached pages count as free");
        assert_eq!(p.page_key(a), 0xFEED, "key survives the last free");

        // wrong key refuses; right key revives without zeroing
        assert!(p.resurrect(a, 0xBAD).is_err());
        p.resurrect(a, 0xFEED).unwrap();
        assert!(p.is_leased(a));
        assert_eq!(p.page(a)[1], 4.25, "resurrected content is intact");
        assert!(p.resurrect(a, 0xFEED).is_err(), "cannot resurrect a leased page");
        p.free(a).unwrap();

        // plain leases prefer unkeyed pages, then recycle cached ones
        let b = p.lease().unwrap();
        assert_ne!(b, a, "unkeyed growth preferred over destroying the cache");
        let c = p.lease().unwrap();
        assert_eq!(c, a, "cache recycled once nothing else is free");
        assert_eq!(p.page(c)[1], 0.0, "recycling zeroes");
        assert_eq!(p.page_key(c), 0, "recycling unkeys");
        assert!(p.resurrect(a, 0xFEED).is_err());
    }

    #[test]
    fn clear_page_key_returns_cached_pages_to_the_plain_pool() {
        let mut p = PagePool::new(layout(), 4);
        let a = p.lease().unwrap();
        p.set_page_key(a, 0xA).unwrap();
        p.free(a).unwrap();
        // a displaced/refused registration unkeys: the page becomes plain
        // free again, so the hot-path lease recycles it before growing
        p.clear_page_key(a);
        assert_eq!(p.page_key(a), 0);
        assert!(p.resurrect(a, 0xA).is_err());
        let b = p.lease().unwrap();
        assert_eq!(b, a, "unkeyed page recycles before backing growth");
        assert_eq!(p.pages_hwm(), 1);
        // clearing a leased page's key just unkeys it in place
        p.set_page_key(b, 0xB).unwrap();
        p.clear_page_key(b);
        assert_eq!(p.page_key(b), 0);
        assert!(p.is_leased(b));
        // unknown / unkeyed ids are no-ops
        p.clear_page_key(99);
        p.clear_page_key(b);
    }
}
