//! The block allocator: fixed-size KV pages with a free list, refcounts,
//! and content identity.
//!
//! Backing storage grows lazily — the data vector extends by one page at a
//! time up to `max_pages`, so a pool sized for the worst case costs only
//! what the high-water mark of concurrent context actually touched.
//! Freed pages go on a free list and are recycled (zeroed at lease) before
//! the backing vector grows again.
//!
//! Since the prefix-sharing refactor a page is **refcounted**: several
//! lanes may map the same page ([`PagePool::retain`]), `free` decrements
//! and only returns the page to the free list at refcount zero, and a
//! write to a shared page goes through [`PagePool::cow`] (lease a fresh
//! page, memcpy the resident dims, drop one ref). A page can also carry a
//! **content key** ([`PagePool::set_page_key`]) — the token-chain identity
//! the [`super::PrefixIndex`] resolves shared prefixes by. Keyed pages
//! whose last ref drops are returned to the free list *with their content
//! and key intact* ("cached"): they count as free (reusable — a later
//! lease zeroes and unkeys them), but an attach that arrives first can
//! [`PagePool::resurrect`] them without re-running prefill.

use anyhow::{bail, Result};

use super::{KvPoolGauges, KvQuant};

/// Geometry of one page (see the module docs for the memory layout).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolLayout {
    /// Token slots per page.
    pub page_slots: usize,
    /// Resident projected key dims per slot (`mem_dims(d)`, <= head_dim).
    pub key_dims: usize,
    /// Full head width `d` (values are stored at this width).
    pub head_dim: usize,
    pub layers: usize,
    pub kv_heads: usize,
    /// Payload element type: f32, or int8 + per-page scale sidecar.
    pub kv_quant: KvQuant,
}

impl PoolLayout {
    /// Payload elements per page: K region + V region (element width set
    /// by `kv_quant`; offsets are element indices either way).
    pub fn page_elems(&self) -> usize {
        self.layers * self.kv_heads * self.page_slots * (self.key_dims + self.head_dim)
    }

    /// f32 scale-sidecar elements per page: one K scale and one V scale
    /// per (layer, kv-head) under int8, none under f32.
    pub fn scale_elems(&self) -> usize {
        match self.kv_quant {
            KvQuant::F32 => 0,
            KvQuant::Int8 => self.layers * self.kv_heads * 2,
        }
    }

    pub fn page_bytes(&self) -> usize {
        self.page_elems() * self.kv_quant.elem_bytes()
            + self.scale_elems() * std::mem::size_of::<f32>()
    }

    /// Resident KV bytes per token slot (`page_bytes / page_slots`,
    /// rounded up — exact for f32, where the scale sidecar is empty): the
    /// quantity `AquaConfig::kv_bytes_per_slot` models.
    pub fn bytes_per_slot(&self) -> usize {
        self.page_bytes().div_ceil(self.page_slots)
    }

    /// Offset of the (layer, kv-head) dim-major key block inside a page;
    /// dim `i`, local slot `s` live at `key_off + i * page_slots + s`.
    pub fn key_off(&self, l: usize, g: usize) -> usize {
        (l * self.kv_heads + g) * self.key_dims * self.page_slots
    }

    /// Offset of the (layer, kv-head, local slot) value row (head_dim
    /// contiguous floats).
    pub fn val_off(&self, l: usize, g: usize, local: usize) -> usize {
        let v_base = self.layers * self.kv_heads * self.key_dims * self.page_slots;
        v_base + ((l * self.kv_heads + g) * self.page_slots + local) * self.head_dim
    }

    /// Pages needed to hold `slots` token positions (ceiling).
    pub fn pages_for_slots(&self, slots: usize) -> usize {
        slots.div_ceil(self.page_slots)
    }

    /// Worst-case pages a request with `want_slots = prompt + max_new`
    /// can grow to on a `max_seq`-capacity lane — the **single** formula
    /// the engine's memory-aware admission and the registry's reservation
    /// gate both use (they must never disagree).
    pub fn worst_case_pages(&self, want_slots: usize, max_seq: usize) -> usize {
        self.pages_for_slots(want_slots.min(max_seq))
    }
}

/// Max-abs of a row (0.0 for empty rows).
fn amax(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |a, &x| a.max(x.abs()))
}

/// Symmetric int8 quantization: `round(x / scale)` clamped to ±127.
/// A zero scale means the region has only ever seen zeros.
fn quantize(x: f32, scale: f32) -> i8 {
    if scale <= 0.0 {
        0
    } else {
        (x / scale).round().clamp(-127.0, 127.0) as i8
    }
}

/// Grow a quantized region's scale to cover a new row magnitude,
/// deterministically requantizing the existing int8 content under the new
/// scale (bounded extra error ≤ old quantization step; never widens).
/// Shrinking never happens — the scale is monotone per region lifetime,
/// so requantization order (and therefore content) is a pure function of
/// the write sequence, which is what keeps warm prefix pages bit-equal to
/// cold ones and the sharded workers bit-equal to the native backend.
fn grow_scale(region: &mut [i8], scale: &mut f32, new_amax: f32) {
    let need = new_amax / 127.0;
    if need <= *scale {
        return;
    }
    if *scale > 0.0 {
        let r = *scale / need;
        for q in region.iter_mut() {
            *q = ((*q as f32) * r).round().clamp(-127.0, 127.0) as i8;
        }
    }
    *scale = need;
}

/// Page allocator with a free list. Page ids are dense indices into the
/// backing vector; a leased bitmap catches double-frees and stale ids.
pub struct PagePool {
    layout: PoolLayout,
    max_pages: usize,
    /// f32 payload (empty under `KvQuant::Int8`).
    data: Vec<f32>,
    /// int8 payload (empty under `KvQuant::F32`).
    qdata: Vec<i8>,
    /// Per-page dequantization scales (`layout.scale_elems()` per page):
    /// `[(l, g) K scale, (l, g) V scale, ...]`. Rides every page copy
    /// (COW) and survives cache/resurrect exactly like the payload.
    scales: Vec<f32>,
    /// Free pages with no content identity — the O(1) hot-path pop.
    free_plain: Vec<u32>,
    /// Free pages still carrying a key ("cached"): resurrectable until a
    /// plain lease runs out of growth and recycles them.
    free_cached: Vec<u32>,
    leased: Vec<bool>,
    /// Per-page refcount (0 while free/cached).
    refs: Vec<u32>,
    /// Per-page content identity (token-chain hash; 0 = none). Survives
    /// the last free so the page stays resurrectable until recycled.
    keys: Vec<u64>,
    leases: u64,
    frees: u64,
    stalls: u64,
    cow_copies: u64,
    prefix_evictions: u64,
}

impl PagePool {
    pub fn new(layout: PoolLayout, max_pages: usize) -> PagePool {
        PagePool {
            layout,
            max_pages,
            data: vec![],
            qdata: vec![],
            scales: vec![],
            free_plain: vec![],
            free_cached: vec![],
            leased: vec![],
            refs: vec![],
            keys: vec![],
            leases: 0,
            frees: 0,
            stalls: 0,
            cow_copies: 0,
            prefix_evictions: 0,
        }
    }

    pub fn layout(&self) -> &PoolLayout {
        &self.layout
    }

    pub fn max_pages(&self) -> usize {
        self.max_pages
    }

    /// Turn a popped free page into a fresh zeroed single-ref lease.
    fn reset_page(&mut self, id: u32) {
        let elems = self.layout.page_elems();
        let base = id as usize * elems;
        match self.layout.kv_quant {
            KvQuant::F32 => self.data[base..base + elems].fill(0.0),
            KvQuant::Int8 => {
                self.qdata[base..base + elems].fill(0);
                let se = self.layout.scale_elems();
                self.scales[id as usize * se..(id as usize + 1) * se].fill(0.0);
            }
        }
        self.leased[id as usize] = true;
        self.refs[id as usize] = 1;
        self.keys[id as usize] = 0;
        self.leases += 1;
    }

    /// Lease one zeroed page. Preference order: the newest *plain* free
    /// page (O(1) pop — the hot write path never scans), then backing
    /// growth, then — only when growth is exhausted — recycling a cached
    /// (keyed) page, so resurrectable prefix content survives as long as
    /// the budget allows (the budget caps *leased* pages; freed backing
    /// stays allocated for reuse either way, exactly as before). Errors
    /// (after counting an alloc stall) when `max_pages` are already
    /// leased — the admission layer's reservation gate exists so this
    /// never fires in a correctly configured deployment.
    pub fn lease(&mut self) -> Result<u32> {
        if let Some(id) = self.free_plain.pop() {
            self.reset_page(id);
            return Ok(id);
        }
        let hwm = self.leased.len();
        if hwm < self.max_pages {
            let elems = self.layout.page_elems();
            match self.layout.kv_quant {
                KvQuant::F32 => self.data.resize((hwm + 1) * elems, 0.0),
                KvQuant::Int8 => {
                    self.qdata.resize((hwm + 1) * elems, 0);
                    self.scales.resize((hwm + 1) * self.layout.scale_elems(), 0.0);
                }
            }
            self.leased.push(true);
            self.refs.push(1);
            self.keys.push(0);
            self.leases += 1;
            return Ok(hwm as u32);
        }
        if let Some(id) = self.free_cached.pop() {
            self.reset_page(id);
            return Ok(id);
        }
        self.stalls += 1;
        bail!(
            "kv pool exhausted: {} pages leased of max {} (budget too small for this load)",
            self.pages_in_use(),
            self.max_pages
        );
    }

    /// Add one reference to a leased page (a second lane mapping it).
    pub fn retain(&mut self, id: u32) -> Result<()> {
        match self.leased.get(id as usize).copied() {
            Some(true) => {
                self.refs[id as usize] += 1;
                Ok(())
            }
            Some(false) => bail!("kv pool: retain of free page {id}"),
            None => bail!("kv pool: retain of unknown page {id}"),
        }
    }

    /// Drop one reference; the page returns to the free list when the last
    /// ref drops (keyed pages keep content + key — "cached" — until a
    /// plain lease recycles them). Double-frees and unknown ids error.
    pub fn free(&mut self, id: u32) -> Result<()> {
        match self.leased.get(id as usize).copied() {
            Some(true) => {
                self.refs[id as usize] -= 1;
                if self.refs[id as usize] == 0 {
                    self.leased[id as usize] = false;
                    if self.keys[id as usize] == 0 {
                        self.free_plain.push(id);
                    } else {
                        self.free_cached.push(id);
                    }
                    self.frees += 1;
                }
                Ok(())
            }
            Some(false) => bail!("kv pool: double free of page {id}"),
            None => bail!("kv pool: free of unknown page {id}"),
        }
    }

    /// Revive a cached page (free, key intact, content intact) as a fresh
    /// single-ref lease *without* zeroing — the prefix-attach fast path.
    /// Errors if the page is leased, was recycled, or carries another key.
    pub fn resurrect(&mut self, id: u32, key: u64) -> Result<()> {
        match self.leased.get(id as usize).copied() {
            Some(false) if key != 0 && self.keys[id as usize] == key => {
                let at = self
                    .free_cached
                    .iter()
                    .position(|&f| f == id)
                    .ok_or_else(|| anyhow::anyhow!("kv pool: cached page {id} not on free list"))?;
                self.free_cached.swap_remove(at);
                self.leased[id as usize] = true;
                self.refs[id as usize] = 1;
                self.leases += 1;
                Ok(())
            }
            Some(false) => bail!("kv pool: page {id} no longer caches key {key:#x}"),
            Some(true) => bail!("kv pool: resurrect of leased page {id}"),
            None => bail!("kv pool: resurrect of unknown page {id}"),
        }
    }

    /// Copy-on-write: lease a fresh page, memcpy the shared page's resident
    /// content into it, and drop one ref from the original. The copy is
    /// unkeyed (its content is about to diverge). Errors if the page is
    /// not actually shared (refs < 2) or the pool is exhausted.
    pub fn cow(&mut self, id: u32) -> Result<u32> {
        if self.leased.get(id as usize) != Some(&true) || self.refs[id as usize] < 2 {
            bail!("kv pool: cow of unshared page {id}");
        }
        let fresh = self.lease()?;
        let elems = self.layout.page_elems();
        let src = id as usize * elems;
        match self.layout.kv_quant {
            KvQuant::F32 => self.data.copy_within(src..src + elems, fresh as usize * elems),
            KvQuant::Int8 => {
                self.qdata.copy_within(src..src + elems, fresh as usize * elems);
                // the scale sidecar is content: it rides every copy, or
                // dequantized reads of the copy would silently diverge
                let se = self.layout.scale_elems();
                let ssrc = id as usize * se;
                self.scales.copy_within(ssrc..ssrc + se, fresh as usize * se);
            }
        }
        self.refs[id as usize] -= 1;
        self.cow_copies += 1;
        Ok(fresh)
    }

    /// Stamp a leased page's content identity (the prefix chain hash).
    pub fn set_page_key(&mut self, id: u32, key: u64) -> Result<()> {
        if self.leased.get(id as usize) != Some(&true) {
            bail!("kv pool: set_page_key on unleased page {id}");
        }
        self.keys[id as usize] = key;
        Ok(())
    }

    /// Drop a page's content identity (its index node was displaced or
    /// refused). A cached page becomes a plain free page again, so the
    /// hot-path lease recycles it before growing backing. No-op for
    /// unknown/unkeyed ids.
    pub fn clear_page_key(&mut self, id: u32) {
        let Some(k) = self.keys.get_mut(id as usize) else { return };
        if *k == 0 {
            return;
        }
        *k = 0;
        if self.leased[id as usize] {
            return; // still mapped; it frees as plain later
        }
        if let Some(at) = self.free_cached.iter().position(|&f| f == id) {
            self.free_cached.swap_remove(at);
            self.free_plain.push(id);
        }
    }

    /// Count one prefix-index LRU eviction (the caller just unkeyed the
    /// victim chain's page via [`PagePool::clear_page_key`]).
    pub fn note_prefix_eviction(&mut self) {
        self.prefix_evictions += 1;
    }

    /// A page's content key (0 = none / recycled). Valid for leased pages
    /// and cached (freed-but-keyed) pages alike.
    pub fn page_key(&self, id: u32) -> u64 {
        self.keys.get(id as usize).copied().unwrap_or(0)
    }

    /// Current reference count (0 while free/cached).
    pub fn ref_count(&self, id: u32) -> u32 {
        self.refs.get(id as usize).copied().unwrap_or(0)
    }

    pub fn is_leased(&self, id: u32) -> bool {
        self.leased.get(id as usize) == Some(&true)
    }

    /// f32 payload of one page. Valid only under [`KvQuant::F32`] (int8
    /// pages are read through [`PagePool::page_i8`] + the scale getters).
    pub fn page(&self, id: u32) -> &[f32] {
        debug_assert_eq!(self.layout.kv_quant, KvQuant::F32, "f32 read of an int8 pool");
        let elems = self.layout.page_elems();
        let base = id as usize * elems;
        &self.data[base..base + elems]
    }

    /// f32 payload of one page, mutable (see [`PagePool::page`]).
    pub fn page_mut(&mut self, id: u32) -> &mut [f32] {
        debug_assert_eq!(self.layout.kv_quant, KvQuant::F32, "f32 write of an int8 pool");
        let elems = self.layout.page_elems();
        let base = id as usize * elems;
        &mut self.data[base..base + elems]
    }

    /// int8 payload of one page (same element offsets as the f32 layout).
    /// Valid only under [`KvQuant::Int8`].
    pub fn page_i8(&self, id: u32) -> &[i8] {
        debug_assert_eq!(self.layout.kv_quant, KvQuant::Int8, "int8 read of an f32 pool");
        let elems = self.layout.page_elems();
        let base = id as usize * elems;
        &self.qdata[base..base + elems]
    }

    fn scale_slot(&self, id: u32, l: usize, g: usize) -> usize {
        id as usize * self.layout.scale_elems() + (l * self.layout.kv_heads + g) * 2
    }

    /// Dequantization scale of the (layer, kv-head) key block (int8 only;
    /// 0.0 means the block has only ever held zeros).
    pub fn k_scale(&self, id: u32, l: usize, g: usize) -> f32 {
        self.scales[self.scale_slot(id, l, g)]
    }

    /// Dequantization scale of the (layer, kv-head) value block (int8).
    pub fn v_scale(&self, id: u32, l: usize, g: usize) -> f32 {
        self.scales[self.scale_slot(id, l, g) + 1]
    }

    /// One resident key element, dequantized as needed — the slow generic
    /// read the masked-dense oracle's shadow sync uses (hot paths stream
    /// whole blocks through `page` / `page_i8` instead).
    pub fn key_at(&self, id: u32, l: usize, g: usize, dim: usize, local: usize) -> f32 {
        let off = self.layout.key_off(l, g) + dim * self.layout.page_slots + local;
        match self.layout.kv_quant {
            KvQuant::F32 => self.page(id)[off],
            KvQuant::Int8 => self.page_i8(id)[off] as f32 * self.k_scale(id, l, g),
        }
    }

    /// Write one token's resident KV — the `key_dims` projected/truncated
    /// key dims (dim-major strided) and the full-width value row — into a
    /// leased page. Under f32 this is exactly the pre-PR-10 store
    /// sequence (bit-identical); under int8 it quantizes against the
    /// page's (layer, kv-head) block scales, deterministically requantizing
    /// the block first whenever a new token's magnitude outgrows them.
    pub fn write_token(
        &mut self,
        id: u32,
        l: usize,
        g: usize,
        local: usize,
        khat: &[f32],
        vrow: &[f32],
    ) {
        let layout = self.layout;
        let (ps, kd, d) = (layout.page_slots, layout.key_dims, layout.head_dim);
        debug_assert!(khat.len() == kd && vrow.len() == d && local < ps);
        let base = id as usize * layout.page_elems();
        let ko = base + layout.key_off(l, g);
        let vo = base + layout.val_off(l, g, local);
        match layout.kv_quant {
            KvQuant::F32 => {
                for (i, &kv) in khat.iter().enumerate() {
                    self.data[ko + i * ps + local] = kv;
                }
                self.data[vo..vo + d].copy_from_slice(vrow);
            }
            KvQuant::Int8 => {
                let sb = self.scale_slot(id, l, g);
                let kreg = &mut self.qdata[ko..ko + kd * ps];
                grow_scale(kreg, &mut self.scales[sb], amax(khat));
                let sk = self.scales[sb];
                for (i, &kv) in khat.iter().enumerate() {
                    kreg[i * ps + local] = quantize(kv, sk);
                }
                let v0 = base + layout.val_off(l, g, 0);
                let vreg = &mut self.qdata[v0..v0 + ps * d];
                grow_scale(vreg, &mut self.scales[sb + 1], amax(vrow));
                let sv = self.scales[sb + 1];
                for (q, &x) in vreg[local * d..(local + 1) * d].iter_mut().zip(vrow) {
                    *q = quantize(x, sv);
                }
            }
        }
    }

    pub fn pages_in_use(&self) -> usize {
        self.leased.len() - self.free_plain.len() - self.free_cached.len()
    }

    /// Distinct pages ever leased (the backing vector's size in pages).
    pub fn pages_hwm(&self) -> usize {
        self.leased.len()
    }

    /// Pages currently mapped by more than one holder.
    pub fn shared_pages(&self) -> usize {
        self.refs.iter().filter(|&&r| r >= 2).count()
    }

    /// Bytes held by currently leased pages.
    pub fn resident_bytes(&self) -> usize {
        self.pages_in_use() * self.layout.page_bytes()
    }

    pub fn gauges(&self) -> KvPoolGauges {
        KvPoolGauges {
            resident_bytes: self.resident_bytes() as u64,
            backing_bytes: (self.pages_hwm() * self.layout.page_bytes()) as u64,
            pages_in_use: self.pages_in_use() as u64,
            pages_hwm: self.pages_hwm() as u64,
            pages_free: self.max_pages.saturating_sub(self.pages_in_use()) as u64,
            shared_pages: self.shared_pages() as u64,
            page_slots: self.layout.page_slots as u64,
            page_bytes: self.layout.page_bytes() as u64,
            leases: self.leases,
            frees: self.frees,
            alloc_stalls: self.stalls,
            cow_copies: self.cow_copies,
            prefix_evictions: self.prefix_evictions,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> PoolLayout {
        PoolLayout {
            page_slots: 4,
            key_dims: 2,
            head_dim: 4,
            layers: 1,
            kv_heads: 1,
            kv_quant: KvQuant::F32,
        }
    }

    #[test]
    fn layout_offsets_tile_the_page() {
        let l = PoolLayout {
            page_slots: 8,
            key_dims: 3,
            head_dim: 4,
            layers: 2,
            kv_heads: 2,
            kv_quant: KvQuant::F32,
        };
        // K region: 2*2*3*8 = 96 elems, V region: 2*2*8*4 = 128 elems
        assert_eq!(l.page_elems(), 96 + 128);
        assert_eq!(l.page_bytes(), (96 + 128) * 4);
        assert_eq!(l.bytes_per_slot() * l.page_slots, l.page_bytes());
        assert_eq!(l.key_off(0, 0), 0);
        assert_eq!(l.key_off(1, 1), 3 * 3 * 8);
        assert_eq!(l.val_off(0, 0, 0), 96);
        // last value row ends exactly at the page boundary
        assert_eq!(l.val_off(1, 1, 7) + l.head_dim, l.page_elems());
        assert_eq!(l.pages_for_slots(0), 0);
        assert_eq!(l.pages_for_slots(8), 1);
        assert_eq!(l.pages_for_slots(9), 2);
    }

    #[test]
    fn lease_free_recycles_without_growth() {
        let mut p = PagePool::new(layout(), 4);
        let a = p.lease().unwrap();
        let b = p.lease().unwrap();
        assert_eq!(p.pages_in_use(), 2);
        assert_eq!(p.pages_hwm(), 2);
        p.page_mut(a)[0] = 7.0;
        p.free(a).unwrap();
        assert_eq!(p.pages_in_use(), 1);
        let c = p.lease().unwrap();
        assert_eq!(c, a, "free list recycles before growing");
        assert_eq!(p.pages_hwm(), 2, "recycling must not grow backing");
        assert_eq!(p.page(c)[0], 0.0, "recycled pages are zeroed");
        assert_ne!(b, c);
        assert_eq!(p.resident_bytes(), 2 * p.layout().page_bytes());
        assert_eq!(p.gauges().pages_free, 2, "headroom = max_pages - in_use");
    }

    #[test]
    fn exhaustion_errors_and_counts_stalls() {
        let mut p = PagePool::new(layout(), 2);
        p.lease().unwrap();
        p.lease().unwrap();
        assert!(p.lease().is_err());
        assert!(p.lease().is_err());
        assert_eq!(p.gauges().alloc_stalls, 2);
        assert_eq!(p.pages_in_use(), 2);
        assert_eq!(p.gauges().pages_free, 0);
    }

    #[test]
    fn double_free_and_bad_id_error() {
        let mut p = PagePool::new(layout(), 2);
        let a = p.lease().unwrap();
        p.free(a).unwrap();
        assert!(p.free(a).is_err(), "double free must error");
        assert!(p.free(99).is_err(), "unknown id must error");
        assert_eq!(p.gauges().frees, 1);
    }

    #[test]
    fn shared_pages_free_once_per_holder() {
        let mut p = PagePool::new(layout(), 4);
        let a = p.lease().unwrap();
        p.retain(a).unwrap();
        p.retain(a).unwrap();
        assert_eq!(p.ref_count(a), 3);
        assert_eq!(p.shared_pages(), 1);
        assert_eq!(p.gauges().shared_pages, 1);
        p.free(a).unwrap();
        p.free(a).unwrap();
        assert!(p.is_leased(a), "page lives while any holder remains");
        assert_eq!(p.shared_pages(), 0, "one holder left is not shared");
        p.free(a).unwrap();
        assert!(!p.is_leased(a));
        assert!(p.free(a).is_err(), "refcounts must not underflow");
        assert!(p.retain(a).is_err(), "cannot retain a free page");
    }

    #[test]
    fn cow_copies_content_and_drops_one_ref() {
        let mut p = PagePool::new(layout(), 4);
        let a = p.lease().unwrap();
        p.page_mut(a)[3] = 9.5;
        assert!(p.cow(a).is_err(), "unshared pages never cow");
        p.retain(a).unwrap();
        let b = p.cow(a).unwrap();
        assert_ne!(a, b);
        assert_eq!(p.page(b)[3], 9.5, "cow must copy the resident content");
        assert_eq!(p.ref_count(a), 1);
        assert_eq!(p.ref_count(b), 1);
        assert_eq!(p.gauges().cow_copies, 1);
        // the copy diverges independently
        p.page_mut(b)[3] = 1.0;
        assert_eq!(p.page(a)[3], 9.5);
    }

    #[test]
    fn cached_pages_resurrect_with_content_and_stay_reusable() {
        let mut p = PagePool::new(layout(), 2);
        let a = p.lease().unwrap();
        p.page_mut(a)[1] = 4.25;
        p.set_page_key(a, 0xFEED).unwrap();
        p.free(a).unwrap();
        assert_eq!(p.pages_in_use(), 0, "cached pages count as free");
        assert_eq!(p.page_key(a), 0xFEED, "key survives the last free");

        // wrong key refuses; right key revives without zeroing
        assert!(p.resurrect(a, 0xBAD).is_err());
        p.resurrect(a, 0xFEED).unwrap();
        assert!(p.is_leased(a));
        assert_eq!(p.page(a)[1], 4.25, "resurrected content is intact");
        assert!(p.resurrect(a, 0xFEED).is_err(), "cannot resurrect a leased page");
        p.free(a).unwrap();

        // plain leases prefer unkeyed pages, then recycle cached ones
        let b = p.lease().unwrap();
        assert_ne!(b, a, "unkeyed growth preferred over destroying the cache");
        let c = p.lease().unwrap();
        assert_eq!(c, a, "cache recycled once nothing else is free");
        assert_eq!(p.page(c)[1], 0.0, "recycling zeroes");
        assert_eq!(p.page_key(c), 0, "recycling unkeys");
        assert!(p.resurrect(a, 0xFEED).is_err());
    }

    #[test]
    fn clear_page_key_returns_cached_pages_to_the_plain_pool() {
        let mut p = PagePool::new(layout(), 4);
        let a = p.lease().unwrap();
        p.set_page_key(a, 0xA).unwrap();
        p.free(a).unwrap();
        // a displaced/refused registration unkeys: the page becomes plain
        // free again, so the hot-path lease recycles it before growing
        p.clear_page_key(a);
        assert_eq!(p.page_key(a), 0);
        assert!(p.resurrect(a, 0xA).is_err());
        let b = p.lease().unwrap();
        assert_eq!(b, a, "unkeyed page recycles before backing growth");
        assert_eq!(p.pages_hwm(), 1);
        // clearing a leased page's key just unkeys it in place
        p.set_page_key(b, 0xB).unwrap();
        p.clear_page_key(b);
        assert_eq!(p.page_key(b), 0);
        assert!(p.is_leased(b));
        // unknown / unkeyed ids are no-ops
        p.clear_page_key(99);
        p.clear_page_key(b);
    }

    fn layout_i8() -> PoolLayout {
        PoolLayout { kv_quant: KvQuant::Int8, ..layout() }
    }

    /// All dequantized elements of one (l, g) block of a page.
    fn dequant_block(p: &PagePool, id: u32, l: usize, g: usize) -> (Vec<f32>, Vec<f32>) {
        let lay = *p.layout();
        let (ps, kd, d) = (lay.page_slots, lay.key_dims, lay.head_dim);
        let page = p.page_i8(id);
        let (sk, sv) = (p.k_scale(id, l, g), p.v_scale(id, l, g));
        let ko = lay.key_off(l, g);
        let keys = (0..kd * ps).map(|i| page[ko + i] as f32 * sk).collect();
        let vo = lay.val_off(l, g, 0);
        let vals = (0..ps * d).map(|i| page[vo + i] as f32 * sv).collect();
        (keys, vals)
    }

    #[test]
    fn int8_layout_shrinks_pages_but_keeps_offsets() {
        let (f, q) = (layout(), layout_i8());
        assert_eq!(f.page_elems(), q.page_elems(), "offsets are element indices either way");
        // payload 4x smaller + the small scale sidecar (1*1*2 f32 = 8B)
        assert_eq!(q.page_bytes(), f.page_elems() + q.scale_elems() * 4);
        assert!(q.page_bytes() * 2 < f.page_bytes(), "int8 page must be < half the f32 page");
        assert!(
            (q.page_bytes() as f64) < 0.6 * f.page_bytes() as f64,
            "int8 resident bytes must clear the ≥40% reduction bound at equal kv_keep"
        );
    }

    #[test]
    fn int8_write_read_round_trips_within_the_scale_bound() {
        let mut p = PagePool::new(layout_i8(), 4);
        let id = p.lease().unwrap();
        let lay = *p.layout();
        let (kd, d) = (lay.key_dims, lay.head_dim);
        // growing magnitudes force a deterministic requantization of the
        // earlier slots; the error bound must still hold afterwards
        let rows: Vec<(Vec<f32>, Vec<f32>)> = (0..lay.page_slots)
            .map(|s| {
                let k: Vec<f32> = (0..kd).map(|i| (s as f32 + 1.0) * (i as f32 - 0.7)).collect();
                let v: Vec<f32> = (0..d).map(|i| (s as f32 + 1.0) * (0.3 - i as f32)).collect();
                (k, v)
            })
            .collect();
        for (s, (k, v)) in rows.iter().enumerate() {
            p.write_token(id, 0, 0, s, k, v);
        }
        let (sk, sv) = (p.k_scale(id, 0, 0), p.v_scale(id, 0, 0));
        assert!(sk > 0.0 && sv > 0.0);
        let (keys, vals) = dequant_block(&p, id, 0, 0);
        for (s, (k, v)) in rows.iter().enumerate() {
            for (i, &want) in k.iter().enumerate() {
                let got = keys[i * lay.page_slots + s];
                // one quantization + at most a chain of requantizations:
                // each step adds ≤ scale/2 at the final (monotone) scale
                assert!(
                    (got - want).abs() <= 1.5 * sk,
                    "key[{i},{s}] dequant {got} vs {want} (scale {sk})"
                );
            }
            for (i, &want) in v.iter().enumerate() {
                let got = vals[s * d + i];
                assert!((got - want).abs() <= 1.5 * sv, "val[{s},{i}] {got} vs {want}");
            }
        }
    }

    #[test]
    fn int8_scales_ride_cow_copies_and_resurrection() {
        // the property the prefix-sharing paths depend on: a COW copy and
        // a cached/resurrected page dequantize to exactly the same values
        // as the original — payload AND scale sidecar both travel
        let mut p = PagePool::new(layout_i8(), 4);
        let a = p.lease().unwrap();
        let lay = *p.layout();
        let k: Vec<f32> = (0..lay.key_dims).map(|i| 3.25 * (i as f32 + 1.0)).collect();
        let v: Vec<f32> = (0..lay.head_dim).map(|i| -1.5 * (i as f32 + 1.0)).collect();
        p.write_token(a, 0, 0, 1, &k, &v);
        let before = dequant_block(&p, a, 0, 0);

        p.retain(a).unwrap();
        let b = p.cow(a).unwrap();
        assert_eq!(dequant_block(&p, b, 0, 0), before, "cow copy dequantizes identically");
        assert_eq!(p.k_scale(b, 0, 0), p.k_scale(a, 0, 0));
        assert_eq!(p.v_scale(b, 0, 0), p.v_scale(a, 0, 0));

        // diverge the copy with a larger-magnitude token: only the copy's
        // scale grows
        let big: Vec<f32> = k.iter().map(|&x| 10.0 * x).collect();
        p.write_token(b, 0, 0, 2, &big, &v);
        assert!(p.k_scale(b, 0, 0) > p.k_scale(a, 0, 0));
        assert_eq!(dequant_block(&p, a, 0, 0), before, "original page untouched");

        // cached → resurrected pages keep payload + scales intact
        p.set_page_key(a, 0xCAFE).unwrap();
        p.free(a).unwrap();
        p.resurrect(a, 0xCAFE).unwrap();
        assert_eq!(dequant_block(&p, a, 0, 0), before, "resurrection keeps scales");

        // recycling zeroes the sidecar along with the payload
        p.free(a).unwrap();
        p.clear_page_key(a);
        let c = p.lease().unwrap();
        assert_eq!(c, a);
        assert_eq!(p.k_scale(c, 0, 0), 0.0, "recycled page has no stale scale");
        assert!(p.page_i8(c).iter().all(|&q| q == 0));
    }

    #[test]
    fn f32_write_token_is_the_old_store_sequence() {
        // write_token under f32 must land exactly where the old direct
        // page_mut stores landed (bit-identity of the pre-PR-10 layout)
        let mut p = PagePool::new(layout(), 2);
        let id = p.lease().unwrap();
        let lay = *p.layout();
        let k: Vec<f32> = (0..lay.key_dims).map(|i| i as f32 + 0.5).collect();
        let v: Vec<f32> = (0..lay.head_dim).map(|i| -(i as f32) - 0.25).collect();
        p.write_token(id, 0, 0, 3, &k, &v);
        let page = p.page(id);
        let ko = lay.key_off(0, 0);
        for (i, &kv) in k.iter().enumerate() {
            assert_eq!(page[ko + i * lay.page_slots + 3], kv);
        }
        let vo = lay.val_off(0, 0, 3);
        assert_eq!(&page[vo..vo + lay.head_dim], &v[..]);
    }
}
