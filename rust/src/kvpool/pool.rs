//! The block allocator: fixed-size KV pages with a free list.
//!
//! Backing storage grows lazily — the data vector extends by one page at a
//! time up to `max_pages`, so a pool sized for the worst case costs only
//! what the high-water mark of concurrent context actually touched.
//! Freed pages go on a free list and are recycled (zeroed at lease) before
//! the backing vector grows again.

use anyhow::{bail, Result};

use super::KvPoolGauges;

/// Geometry of one page (see the module docs for the memory layout).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolLayout {
    /// Token slots per page.
    pub page_slots: usize,
    /// Resident projected key dims per slot (`mem_dims(d)`, <= head_dim).
    pub key_dims: usize,
    /// Full head width `d` (values are stored at this width).
    pub head_dim: usize,
    pub layers: usize,
    pub kv_heads: usize,
}

impl PoolLayout {
    /// f32 elements per page: K region + V region.
    pub fn page_elems(&self) -> usize {
        self.layers * self.kv_heads * self.page_slots * (self.key_dims + self.head_dim)
    }

    pub fn page_bytes(&self) -> usize {
        self.page_elems() * std::mem::size_of::<f32>()
    }

    /// Resident KV bytes per token slot (`page_bytes / page_slots`): the
    /// quantity `AquaConfig::kv_bytes_per_slot` models.
    pub fn bytes_per_slot(&self) -> usize {
        self.layers * self.kv_heads * (self.key_dims + self.head_dim) * 4
    }

    /// Offset of the (layer, kv-head) dim-major key block inside a page;
    /// dim `i`, local slot `s` live at `key_off + i * page_slots + s`.
    pub fn key_off(&self, l: usize, g: usize) -> usize {
        (l * self.kv_heads + g) * self.key_dims * self.page_slots
    }

    /// Offset of the (layer, kv-head, local slot) value row (head_dim
    /// contiguous floats).
    pub fn val_off(&self, l: usize, g: usize, local: usize) -> usize {
        let v_base = self.layers * self.kv_heads * self.key_dims * self.page_slots;
        v_base + ((l * self.kv_heads + g) * self.page_slots + local) * self.head_dim
    }

    /// Pages needed to hold `slots` token positions (ceiling).
    pub fn pages_for_slots(&self, slots: usize) -> usize {
        slots.div_ceil(self.page_slots)
    }

    /// Worst-case pages a request with `want_slots = prompt + max_new`
    /// can grow to on a `max_seq`-capacity lane — the **single** formula
    /// the engine's memory-aware admission and the registry's reservation
    /// gate both use (they must never disagree).
    pub fn worst_case_pages(&self, want_slots: usize, max_seq: usize) -> usize {
        self.pages_for_slots(want_slots.min(max_seq))
    }
}

/// Page allocator with a free list. Page ids are dense indices into the
/// backing vector; a leased bitmap catches double-frees and stale ids.
pub struct PagePool {
    layout: PoolLayout,
    max_pages: usize,
    data: Vec<f32>,
    free: Vec<u32>,
    leased: Vec<bool>,
    leases: u64,
    frees: u64,
    stalls: u64,
}

impl PagePool {
    pub fn new(layout: PoolLayout, max_pages: usize) -> PagePool {
        PagePool {
            layout,
            max_pages,
            data: vec![],
            free: vec![],
            leased: vec![],
            leases: 0,
            frees: 0,
            stalls: 0,
        }
    }

    pub fn layout(&self) -> &PoolLayout {
        &self.layout
    }

    pub fn max_pages(&self) -> usize {
        self.max_pages
    }

    /// Lease one zeroed page: recycle from the free list, else grow the
    /// backing vector. Errors (after counting an alloc stall) when
    /// `max_pages` are already leased — the admission layer's reservation
    /// gate exists so this never fires in a correctly configured
    /// deployment.
    pub fn lease(&mut self) -> Result<u32> {
        let elems = self.layout.page_elems();
        if let Some(id) = self.free.pop() {
            let base = id as usize * elems;
            self.data[base..base + elems].fill(0.0);
            self.leased[id as usize] = true;
            self.leases += 1;
            return Ok(id);
        }
        let hwm = self.leased.len();
        if hwm >= self.max_pages {
            self.stalls += 1;
            bail!(
                "kv pool exhausted: {} pages leased of max {} (budget too small for this load)",
                self.pages_in_use(),
                self.max_pages
            );
        }
        self.data.resize((hwm + 1) * elems, 0.0);
        self.leased.push(true);
        self.leases += 1;
        Ok(hwm as u32)
    }

    /// Return a page to the free list. Double-frees and unknown ids error.
    pub fn free(&mut self, id: u32) -> Result<()> {
        match self.leased.get_mut(id as usize) {
            Some(l @ true) => {
                *l = false;
                self.free.push(id);
                self.frees += 1;
                Ok(())
            }
            Some(false) => bail!("kv pool: double free of page {id}"),
            None => bail!("kv pool: free of unknown page {id}"),
        }
    }

    pub fn page(&self, id: u32) -> &[f32] {
        let elems = self.layout.page_elems();
        let base = id as usize * elems;
        &self.data[base..base + elems]
    }

    pub fn page_mut(&mut self, id: u32) -> &mut [f32] {
        let elems = self.layout.page_elems();
        let base = id as usize * elems;
        &mut self.data[base..base + elems]
    }

    pub fn pages_in_use(&self) -> usize {
        self.leased.len() - self.free.len()
    }

    /// Distinct pages ever leased (the backing vector's size in pages).
    pub fn pages_hwm(&self) -> usize {
        self.leased.len()
    }

    /// Bytes held by currently leased pages.
    pub fn resident_bytes(&self) -> usize {
        self.pages_in_use() * self.layout.page_bytes()
    }

    pub fn gauges(&self) -> KvPoolGauges {
        KvPoolGauges {
            resident_bytes: self.resident_bytes() as u64,
            backing_bytes: (self.pages_hwm() * self.layout.page_bytes()) as u64,
            pages_in_use: self.pages_in_use() as u64,
            pages_hwm: self.pages_hwm() as u64,
            page_slots: self.layout.page_slots as u64,
            page_bytes: self.layout.page_bytes() as u64,
            leases: self.leases,
            frees: self.frees,
            alloc_stalls: self.stalls,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> PoolLayout {
        PoolLayout { page_slots: 4, key_dims: 2, head_dim: 4, layers: 1, kv_heads: 1 }
    }

    #[test]
    fn layout_offsets_tile_the_page() {
        let l = PoolLayout { page_slots: 8, key_dims: 3, head_dim: 4, layers: 2, kv_heads: 2 };
        // K region: 2*2*3*8 = 96 elems, V region: 2*2*8*4 = 128 elems
        assert_eq!(l.page_elems(), 96 + 128);
        assert_eq!(l.page_bytes(), (96 + 128) * 4);
        assert_eq!(l.bytes_per_slot() * l.page_slots, l.page_bytes());
        assert_eq!(l.key_off(0, 0), 0);
        assert_eq!(l.key_off(1, 1), 3 * 3 * 8);
        assert_eq!(l.val_off(0, 0, 0), 96);
        // last value row ends exactly at the page boundary
        assert_eq!(l.val_off(1, 1, 7) + l.head_dim, l.page_elems());
        assert_eq!(l.pages_for_slots(0), 0);
        assert_eq!(l.pages_for_slots(8), 1);
        assert_eq!(l.pages_for_slots(9), 2);
    }

    #[test]
    fn lease_free_recycles_without_growth() {
        let mut p = PagePool::new(layout(), 4);
        let a = p.lease().unwrap();
        let b = p.lease().unwrap();
        assert_eq!(p.pages_in_use(), 2);
        assert_eq!(p.pages_hwm(), 2);
        p.page_mut(a)[0] = 7.0;
        p.free(a).unwrap();
        assert_eq!(p.pages_in_use(), 1);
        let c = p.lease().unwrap();
        assert_eq!(c, a, "free list recycles before growing");
        assert_eq!(p.pages_hwm(), 2, "recycling must not grow backing");
        assert_eq!(p.page(c)[0], 0.0, "recycled pages are zeroed");
        assert_ne!(b, c);
        assert_eq!(p.resident_bytes(), 2 * p.layout().page_bytes());
    }

    #[test]
    fn exhaustion_errors_and_counts_stalls() {
        let mut p = PagePool::new(layout(), 2);
        p.lease().unwrap();
        p.lease().unwrap();
        assert!(p.lease().is_err());
        assert!(p.lease().is_err());
        assert_eq!(p.gauges().alloc_stalls, 2);
        assert_eq!(p.pages_in_use(), 2);
    }

    #[test]
    fn double_free_and_bad_id_error() {
        let mut p = PagePool::new(layout(), 2);
        let a = p.lease().unwrap();
        p.free(a).unwrap();
        assert!(p.free(a).is_err(), "double free must error");
        assert!(p.free(99).is_err(), "unknown id must error");
        assert_eq!(p.gauges().frees, 1);
    }
}
