//! Per-lane page table: which pool page backs each `page_slots`-sized
//! window of the lane's token positions.
//!
//! Leasing is on demand at the write path ([`LanePageTable::ensure_mut`],
//! which also performs **copy-on-write** when the backing page is shared
//! with another lane); freeing happens in two places —
//! [`LanePageTable::reclaim`] returns pages the engine's H2O policy has
//! fully evicted (no live slot in the mask, page fully behind the write
//! cursor), and [`LanePageTable::release_all`] drops everything on lane
//! retirement. With refcounted pages both paths *drop this lane's
//! reference*; the pool frees the page only when the last holder lets go.
//! Prefix sharing maps already-resident pages into a fresh lane via
//! [`LanePageTable::adopt`] + [`LanePageTable::set_written`] (the caller
//! retains/resurrects the pool refs). Positions are monotonic within a
//! lane's lifetime (the engine resets lanes between requests), so a
//! reclaimed page is never written again by the same occupant.

use anyhow::Result;

use super::pool::PagePool;

#[derive(Debug, Clone)]
pub struct LanePageTable {
    pages: Vec<Option<u32>>,
    /// Tokens written so far (max written position + 1).
    written: usize,
}

impl LanePageTable {
    pub fn new(num_pages: usize) -> LanePageTable {
        LanePageTable { pages: vec![None; num_pages], written: 0 }
    }

    /// The pool page backing page index `idx`, if leased.
    pub fn page(&self, idx: usize) -> Option<u32> {
        self.pages.get(idx).copied().flatten()
    }

    pub fn written(&self) -> usize {
        self.written
    }

    pub fn leased_pages(&self) -> usize {
        self.pages.iter().flatten().count()
    }

    /// Capacity of the table in pages (`ceil(max_seq / page_slots)`).
    pub fn num_pages(&self) -> usize {
        self.pages.len()
    }

    /// Lease-on-demand: the page backing index `idx`, leasing a fresh one
    /// from the pool on first touch.
    pub fn ensure(&mut self, pool: &mut PagePool, idx: usize) -> Result<u32> {
        match self.pages[idx] {
            Some(id) => Ok(id),
            None => {
                let id = pool.lease()?;
                self.pages[idx] = Some(id);
                Ok(id)
            }
        }
    }

    /// `ensure` for the *write* path: a page shared with another holder is
    /// copied first (lease fresh, memcpy resident dims, drop one ref), so
    /// writes never leak into someone else's context.
    pub fn ensure_mut(&mut self, pool: &mut PagePool, idx: usize) -> Result<u32> {
        let id = self.ensure(pool, idx)?;
        if pool.ref_count(id) < 2 {
            return Ok(id);
        }
        let fresh = pool.cow(id)?;
        self.pages[idx] = Some(fresh);
        Ok(fresh)
    }

    /// Map an already-resident pool page (a shared prefix chunk) into this
    /// lane at page index `idx`. The caller holds the pool reference
    /// (retain/resurrect); this only records the mapping.
    pub fn adopt(&mut self, idx: usize, id: u32) {
        debug_assert!(self.pages[idx].is_none(), "adopt over a mapped page");
        self.pages[idx] = Some(id);
    }

    /// Place the write cursor after an adopted prefix (the attached
    /// positions were written by the donor).
    pub fn set_written(&mut self, n: usize) {
        self.written = n;
    }

    /// Advance the write cursor over `pos`.
    pub fn note_write(&mut self, pos: usize) {
        self.written = self.written.max(pos + 1);
    }

    /// Drop this lane's reference to every mapped page that is fully
    /// behind the write cursor and has no live slot left in `slot_mask`
    /// (H2O evicted them all) — the pool frees a page once its last
    /// holder lets go. Returns the number of pages unmapped.
    pub fn reclaim(&mut self, pool: &mut PagePool, slot_mask: &[f32]) -> usize {
        let ps = pool.layout().page_slots;
        let mut freed = 0;
        for (p, slot) in self.pages.iter_mut().enumerate() {
            let Some(id) = *slot else { continue };
            let lo = p * ps;
            let hi = ((p + 1) * ps).min(slot_mask.len());
            if hi > self.written {
                // page still growing (contains or is beyond the cursor)
                continue;
            }
            if slot_mask[lo..hi].iter().all(|&m| m <= 0.5) {
                // the pool's leased bitmap guarantees this id is valid
                pool.free(id).expect("reclaim freed a page the pool disowned");
                *slot = None;
                freed += 1;
            }
        }
        freed
    }

    /// Rewind the write cursor to `new_written` and drop this lane's
    /// reference to every mapped page that lies *wholly* at or past it —
    /// the un-append path speculative decoding takes when the verifier
    /// rejects drafted tokens. The page containing the new cursor is kept
    /// (its slots past the cursor are dead in the engine's mask and get
    /// overwritten positionally on the next write). Pages this lane wrote
    /// during the draft were either freshly leased or copied-on-write
    /// first, so dropping them never disturbs a COW donor. Returns the
    /// number of pages unmapped.
    pub fn rollback(&mut self, pool: &mut PagePool, new_written: usize) -> usize {
        let mut freed = 0;
        if new_written < self.written {
            let ps = pool.layout().page_slots;
            for (p, slot) in self.pages.iter_mut().enumerate() {
                if p * ps < new_written {
                    continue;
                }
                if let Some(id) = slot.take() {
                    pool.free(id).expect("rollback freed a page the pool disowned");
                    freed += 1;
                }
            }
            self.written = new_written;
        }
        freed
    }

    /// Lane retirement: drop every mapped page's reference and rewind the
    /// cursor.
    pub fn release_all(&mut self, pool: &mut PagePool) -> usize {
        let mut freed = 0;
        for slot in &mut self.pages {
            if let Some(id) = slot.take() {
                pool.free(id).expect("release freed a page the pool disowned");
                freed += 1;
            }
        }
        self.written = 0;
        freed
    }
}

#[cfg(test)]
mod tests {
    use super::super::pool::PoolLayout;
    use super::*;

    fn pool() -> PagePool {
        let layout = PoolLayout {
            page_slots: 4,
            key_dims: 2,
            head_dim: 4,
            layers: 1,
            kv_heads: 1,
            kv_quant: super::super::KvQuant::F32,
        };
        PagePool::new(layout, 8)
    }

    #[test]
    fn ensure_leases_once_per_page() {
        let mut pool = pool();
        let mut t = LanePageTable::new(4);
        let a = t.ensure(&mut pool, 0).unwrap();
        let b = t.ensure(&mut pool, 0).unwrap();
        assert_eq!(a, b);
        assert_eq!(pool.pages_in_use(), 1);
        t.ensure(&mut pool, 2).unwrap();
        assert_eq!(t.leased_pages(), 2);
        assert_eq!(pool.pages_in_use(), 2);
        assert!(t.page(1).is_none());
    }

    #[test]
    fn reclaim_frees_only_dead_full_pages() {
        let mut pool = pool();
        let mut t = LanePageTable::new(4);
        // write 10 positions: pages 0, 1 full; page 2 partial (cursor)
        for pos in 0..10 {
            t.ensure(&mut pool, pos / 4).unwrap();
            t.note_write(pos);
        }
        assert_eq!(pool.pages_in_use(), 3);
        let mut mask = vec![1.0f32; 16];
        // kill all of page 0, half of page 1, all of page 2's written slots
        for s in 0..4 {
            mask[s] = 0.0;
        }
        mask[4] = 0.0;
        mask[8] = 0.0;
        mask[9] = 0.0;
        let freed = t.reclaim(&mut pool, &mask);
        assert_eq!(freed, 1, "only the fully dead, fully written page 0 frees");
        assert!(t.page(0).is_none());
        assert!(t.page(1).is_some(), "page 1 has a live slot");
        assert!(t.page(2).is_some(), "cursor page never reclaimed");
        assert_eq!(pool.pages_in_use(), 2);
        // idempotent
        assert_eq!(t.reclaim(&mut pool, &mask), 0);
    }

    #[test]
    fn ensure_mut_cows_shared_pages_only() {
        let mut pool = pool();
        let mut donor = LanePageTable::new(4);
        let page = donor.ensure(&mut pool, 0).unwrap();
        pool.page_mut(page)[2] = 3.5;
        donor.note_write(3);

        // a second lane adopts the page (sharing); its first write copies
        let mut sharer = LanePageTable::new(4);
        pool.retain(page).unwrap();
        sharer.adopt(0, page);
        sharer.set_written(4);
        assert_eq!(sharer.ensure(&mut pool, 0).unwrap(), page, "reads stay in place");
        let copy = sharer.ensure_mut(&mut pool, 0).unwrap();
        assert_ne!(copy, page, "write to a shared page must cow");
        assert_eq!(pool.page(copy)[2], 3.5, "cow carries the content");
        assert_eq!(pool.ref_count(page), 1);
        assert_eq!(pool.gauges().cow_copies, 1);

        // unshared pages write in place
        assert_eq!(sharer.ensure_mut(&mut pool, 0).unwrap(), copy);
        assert_eq!(pool.gauges().cow_copies, 1);
        assert_eq!(donor.release_all(&mut pool) + sharer.release_all(&mut pool), 2);
        assert_eq!(pool.pages_in_use(), 0);
    }

    #[test]
    fn rollback_frees_only_pages_wholly_past_the_cursor() {
        let mut pool = pool();
        let mut t = LanePageTable::new(4);
        // write 14 positions: pages 0..2 full, page 3 partial (cursor at 14)
        for pos in 0..14 {
            t.ensure(&mut pool, pos / 4).unwrap();
            t.note_write(pos);
        }
        assert_eq!(pool.pages_in_use(), 4);
        // rewind into the middle of page 1: pages 2 and 3 unmap, page 1
        // (contains the new cursor) stays
        let freed = t.rollback(&mut pool, 6);
        assert_eq!(freed, 2);
        assert_eq!(t.written(), 6);
        assert!(t.page(1).is_some());
        assert!(t.page(2).is_none());
        assert!(t.page(3).is_none());
        assert_eq!(pool.pages_in_use(), 2);
        // idempotent / no-op when the cursor is already at or below
        assert_eq!(t.rollback(&mut pool, 6), 0);
        assert_eq!(t.rollback(&mut pool, 10), 0);
        assert_eq!(t.written(), 6);
        // writes resume and re-lease on demand
        t.ensure(&mut pool, 1).unwrap();
        t.ensure(&mut pool, 2).unwrap();
        t.note_write(8);
        assert_eq!(t.written(), 9);
        assert_eq!(pool.pages_in_use(), 3);
    }

    #[test]
    fn rollback_drops_a_cow_sharers_reference_without_touching_the_donor() {
        let mut pool = pool();
        let mut donor = LanePageTable::new(4);
        let page = donor.ensure(&mut pool, 0).unwrap();
        pool.page_mut(page)[1] = 2.5;
        donor.note_write(3);
        let mut sharer = LanePageTable::new(4);
        pool.retain(page).unwrap();
        sharer.adopt(0, page);
        sharer.set_written(4);
        // sharer drafts into page 1 (fresh) and rolls all of it back
        sharer.ensure_mut(&mut pool, 1).unwrap();
        sharer.note_write(5);
        assert_eq!(sharer.rollback(&mut pool, 4), 1);
        assert_eq!(pool.ref_count(page), 2, "shared page refs untouched");
        assert_eq!(pool.page(page)[1], 2.5);
        sharer.release_all(&mut pool);
        donor.release_all(&mut pool);
        assert_eq!(pool.pages_in_use(), 0);
    }

    #[test]
    fn release_all_returns_everything() {
        let mut pool = pool();
        let mut t = LanePageTable::new(4);
        for pos in 0..12 {
            t.ensure(&mut pool, pos / 4).unwrap();
            t.note_write(pos);
        }
        assert_eq!(t.written(), 12);
        let freed = t.release_all(&mut pool);
        assert_eq!(freed, 3);
        assert_eq!(t.written(), 0);
        assert_eq!(t.leased_pages(), 0);
        assert_eq!(pool.pages_in_use(), 0);
        // the lane can start over and recycle the same backing pages
        t.ensure(&mut pool, 0).unwrap();
        assert_eq!(pool.pages_hwm(), 3, "reuse must not grow the pool");
    }
}
