//! Self-speculative decoding bookkeeping: AQUA-sparse draft, dense
//! verify, one shared KV cache.
//!
//! The AQUA insight that powers this subsystem: the *same* weights score
//! attention cheaply (query-magnitude top-k over the truncated resident
//! keys, the lane's configured `k_ratio`) or exactly (`k_ratio = 1.0`,
//! every resident dimension). That duality is a draft/verifier pair for
//! free — no second model, no separate KV cache, no extra weights.
//!
//! Per engine duty cycle (see `coordinator::engine`):
//!
//! 1. **Draft.** Each live lane greedily decodes up to `speculate`
//!    tokens through the sparse score path, appending *approximate* KV
//!    entries to its own page chain.
//! 2. **Rewind.** Every lane's KV is rolled back to its pre-draft
//!    length (mask + page write-index; shared COW donor pages are never
//!    disturbed).
//! 3. **Verify.** One batched exact pass re-scores the drafted block
//!    (width `max_draft + 1`), rewriting the drafted positions' KV
//!    through the normal causal write path.
//! 4. **Commit.** The longest prefix of drafts matching the exact
//!    argmax is accepted, plus the one token the verify pass itself
//!    produces; the KV is rolled back past the first rejection.
//!
//! The output is **lossless**: bit-identical to plain dense decoding,
//! because every committed token is the exact path's argmax — the
//! sparse draft only decides how many positions the exact pass gets to
//! score per step.
//!
//! [`SpecController`] owns the per-lane draft state. All buffers are
//! preallocated at construction and sized `batch x speculate`; the
//! steady-state draft/verify loop performs zero heap allocations (the
//! `interleave` bench's counting allocator enforces this with
//! `trace=full`).

/// Per-lane draft bookkeeping for one engine. Reused across cycles;
/// never allocates after construction.
#[derive(Debug)]
pub struct SpecController {
    /// Configured draft depth (`EngineConfig::speculate`, >= 1 here —
    /// the engine never constructs a controller when speculation is off).
    speculate: usize,
    /// Engine batch width (lane count).
    batch: usize,
    /// Lane participates in the current cycle.
    active: Vec<bool>,
    /// Committed KV length when the cycle began (rollback target).
    base_len: Vec<usize>,
    /// The lane's pending token when the cycle began (first verify row
    /// entry; re-fed unchanged if the cycle aborts).
    base_pending: Vec<i32>,
    /// Drafted tokens, lane-major `[batch * speculate]`.
    drafts: Vec<i32>,
    /// Tokens drafted so far this cycle, per lane.
    n_draft: Vec<usize>,
    /// Planned draft depth for this cycle, per lane (`<= speculate`;
    /// truncated when a draft emits the stop token).
    n_plan: Vec<usize>,
}

impl SpecController {
    pub fn new(batch: usize, speculate: usize) -> SpecController {
        assert!(speculate >= 1, "SpecController requires speculate >= 1");
        assert!(batch >= 1, "SpecController requires batch >= 1");
        SpecController {
            speculate,
            batch,
            active: vec![false; batch],
            base_len: vec![0; batch],
            base_pending: vec![-1; batch],
            drafts: vec![-1; batch * speculate],
            n_draft: vec![0; batch],
            n_plan: vec![0; batch],
        }
    }

    pub fn speculate(&self) -> usize {
        self.speculate
    }

    /// Reset all per-lane state for a fresh draft/verify cycle.
    pub fn begin_cycle(&mut self) {
        for lane in 0..self.batch {
            self.active[lane] = false;
            self.base_len[lane] = 0;
            self.base_pending[lane] = -1;
            self.n_draft[lane] = 0;
            self.n_plan[lane] = 0;
        }
        self.drafts.fill(-1);
    }

    /// Enroll a lane in the cycle. `n_plan` may be 0 (the lane still
    /// joins the verify pass at width 1 — a degenerate exact decode);
    /// it is clamped to `speculate`.
    pub fn plan_lane(&mut self, lane: usize, base_len: usize, pending: i32, n_plan: usize) {
        self.active[lane] = true;
        self.base_len[lane] = base_len;
        self.base_pending[lane] = pending;
        self.n_draft[lane] = 0;
        self.n_plan[lane] = n_plan.min(self.speculate);
    }

    pub fn is_active(&self, lane: usize) -> bool {
        self.active[lane]
    }

    /// Lane still has draft steps left in its plan.
    pub fn wants_draft(&self, lane: usize) -> bool {
        self.active[lane] && self.n_draft[lane] < self.n_plan[lane]
    }

    pub fn base_len(&self, lane: usize) -> usize {
        self.base_len[lane]
    }

    pub fn base_pending(&self, lane: usize) -> i32 {
        self.base_pending[lane]
    }

    pub fn n_draft(&self, lane: usize) -> usize {
        self.n_draft[lane]
    }

    pub fn n_plan(&self, lane: usize) -> usize {
        self.n_plan[lane]
    }

    /// The token the lane feeds at draft step `j` (0-based): the pending
    /// token for step 0, the previous draft after.
    pub fn feed_token(&self, lane: usize, j: usize) -> i32 {
        if j == 0 {
            self.base_pending[lane]
        } else {
            self.drafts[lane * self.speculate + (j - 1)]
        }
    }

    /// Append a drafted token for a lane.
    pub fn push_draft(&mut self, lane: usize, token: i32) {
        let j = self.n_draft[lane];
        debug_assert!(j < self.n_plan[lane], "draft past the lane's plan");
        self.drafts[lane * self.speculate + j] = token;
        self.n_draft[lane] = j + 1;
    }

    /// Truncate the lane's plan at its current draft count (drafted a
    /// stop token — no point speculating past it).
    pub fn truncate_plan(&mut self, lane: usize) {
        self.n_plan[lane] = self.n_draft[lane];
    }

    /// The lane's drafted tokens so far.
    pub fn drafts(&self, lane: usize) -> &[i32] {
        &self.drafts[lane * self.speculate..lane * self.speculate + self.n_draft[lane]]
    }

    /// Widest draft among active lanes — the verify window is this + 1.
    pub fn max_draft(&self) -> usize {
        let mut m = 0;
        for lane in 0..self.batch {
            if self.active[lane] && self.n_draft[lane] > m {
                m = self.n_draft[lane];
            }
        }
        m
    }

    /// Total tokens drafted across active lanes this cycle.
    pub fn total_drafted(&self) -> u64 {
        let mut total = 0u64;
        for lane in 0..self.batch {
            if self.active[lane] {
                total += self.n_draft[lane] as u64;
            }
        }
        total
    }

    /// Active lane count this cycle.
    pub fn active_lanes(&self) -> u64 {
        self.active.iter().filter(|&&a| a).count() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_draft_and_feed_sequence() {
        let mut c = SpecController::new(4, 3);
        c.begin_cycle();
        c.plan_lane(0, 10, 42, 3);
        c.plan_lane(2, 5, 7, 2);
        assert!(c.is_active(0) && c.is_active(2));
        assert!(!c.is_active(1) && !c.is_active(3));
        assert_eq!(c.active_lanes(), 2);
        assert_eq!(c.base_len(0), 10);
        assert_eq!(c.base_pending(2), 7);

        // step 0 feeds the pending token
        assert_eq!(c.feed_token(0, 0), 42);
        assert_eq!(c.feed_token(2, 0), 7);
        c.push_draft(0, 100);
        c.push_draft(2, 200);
        // step 1 feeds the previous draft
        assert_eq!(c.feed_token(0, 1), 100);
        assert_eq!(c.feed_token(2, 1), 200);
        c.push_draft(0, 101);
        c.push_draft(2, 201);
        assert!(!c.wants_draft(2), "lane 2 planned only 2");
        assert!(c.wants_draft(0));
        c.push_draft(0, 102);
        assert!(!c.wants_draft(0));

        assert_eq!(c.drafts(0), &[100, 101, 102]);
        assert_eq!(c.drafts(2), &[200, 201]);
        assert_eq!(c.max_draft(), 3);
        assert_eq!(c.total_drafted(), 5);
    }

    #[test]
    fn zero_plan_lane_joins_without_drafting() {
        let mut c = SpecController::new(2, 4);
        c.begin_cycle();
        c.plan_lane(1, 3, 9, 0);
        assert!(c.is_active(1));
        assert!(!c.wants_draft(1));
        assert_eq!(c.n_draft(1), 0);
        assert_eq!(c.drafts(1), &[] as &[i32]);
        assert_eq!(c.max_draft(), 0, "verify window degenerates to width 1");
        assert_eq!(c.total_drafted(), 0);
    }

    #[test]
    fn truncate_plan_stops_at_stop_token() {
        let mut c = SpecController::new(1, 4);
        c.begin_cycle();
        c.plan_lane(0, 0, 1, 4);
        c.push_draft(0, 2);
        c.push_draft(0, 0); // stop token drafted
        c.truncate_plan(0);
        assert!(!c.wants_draft(0));
        assert_eq!(c.n_plan(0), 2);
        assert_eq!(c.drafts(0), &[2, 0]);
    }

    #[test]
    fn begin_cycle_clears_previous_state() {
        let mut c = SpecController::new(2, 2);
        c.begin_cycle();
        c.plan_lane(0, 8, 3, 2);
        c.push_draft(0, 5);
        c.begin_cycle();
        assert!(!c.is_active(0));
        assert_eq!(c.n_draft(0), 0);
        assert_eq!(c.max_draft(), 0);
        assert_eq!(c.total_drafted(), 0);
    }

    #[test]
    fn plan_clamps_to_speculate() {
        let mut c = SpecController::new(1, 2);
        c.begin_cycle();
        c.plan_lane(0, 0, 1, 99);
        assert_eq!(c.n_plan(0), 2);
    }
}
