//! `aqua` — CLI for the AQUA serving stack.
//!
//! Subcommands (see README):
//!   serve       start the HTTP server
//!   generate    one-off generation from a prompt
//!   eval        run one SynthBench task / perplexity at given knobs
//!   table1..3   regenerate the paper's Tables 1/4, 2/5, 3/6
//!   table7      qualitative generations vs k_ratio
//!   fig2 fig3 fig5   regenerate the paper's figures (printed series)
//!   breakeven   §5 break-even measurement (native kernels)
//!   selftest    engine smoke test against the artifacts

mod cli;

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use aqua_serve::aqua::policy::AquaConfig;
use aqua_serve::bench::Bencher;
use aqua_serve::coordinator::engine::EngineHandle;
use aqua_serve::coordinator::{Engine, EngineConfig, GenRequest};
use aqua_serve::eval::experiments as exp;
use aqua_serve::eval::ppl::{perplexity, PplConfig};
use aqua_serve::eval::tasks::{run_task, TaskSet};
use aqua_serve::runtime::{Artifacts, ModelRuntime};
use aqua_serve::tokenizer::ByteTokenizer;
use cli::Args;

const USAGE: &str = "usage: aqua <serve|generate|eval|table1|table2|table3|table7|fig2|fig3|fig5|ablation|breakeven|selftest> [flags]
common flags: --artifacts DIR --model NAME --k-ratio R --s-ratio R --h2o-ratio R --batch N --items N --fast";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!("{USAGE}");
        std::process::exit(2);
    }
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn aqua_from(args: &Args) -> Result<AquaConfig> {
    Ok(AquaConfig {
        k_ratio: args.f64("k-ratio", 1.0)?,
        s_ratio: args.f64("s-ratio", 0.0)?,
        h2o_ratio: args.f64("h2o-ratio", 1.0)?,
        use_projection: !args.switch("identity-proj"),
    })
}

fn sweep_opts(args: &Args) -> Result<exp::SweepOptions> {
    let mut opt = exp::SweepOptions {
        batch: args.usize("batch", 4)?,
        items_per_task: args.usize("items", 60)?,
        ppl_windows: args.usize("ppl-windows", 8)?,
        ..Default::default()
    };
    if args.switch("fast") {
        opt.items_per_task = opt.items_per_task.min(12);
        opt.ppl_windows = 2;
    }
    Ok(opt)
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    let arts_dir = args.str("artifacts", aqua_serve::ARTIFACTS_DIR);
    let model = args.str("model", "llama-analog");

    match args.subcommand.as_str() {
        "serve" => {
            let addr = args.str("addr", "127.0.0.1:8080");
            let aqua = aqua_from(&args)?;
            let batch = args.usize("batch", 4)?;
            let arts = Artifacts::load(&arts_dir)?;
            let mart = arts.model(&model)?.clone();
            let handle = EngineHandle::spawn(move || {
                let rt = Arc::new(ModelRuntime::load(&mart)?);
                Engine::new(rt, EngineConfig { batch, aqua, ..Default::default() })
            });
            aqua_serve::server::serve(&addr, handle)
        }
        "generate" => {
            let prompt = args.str("prompt", "the capital of ");
            let max_new = args.usize("max-new", 64)?;
            let arts = Artifacts::load(&arts_dir)?;
            let rt = Arc::new(ModelRuntime::load(arts.model(&model)?)?);
            let mut engine = Engine::new(
                rt,
                EngineConfig { batch: 1, aqua: aqua_from(&args)?, ..Default::default() },
            )?;
            let tok = ByteTokenizer;
            let mut req = GenRequest::new(1, tok.encode(&prompt), max_new);
            req.stop_token = Some(b'\n' as i32);
            let res = engine.run_batch(vec![req])?.remove(0);
            println!("{}{}", prompt, tok.decode(&res.tokens));
            eprintln!("-- {} tokens, ttft {}µs, total {}µs, finish {:?}",
                      res.tokens.len(), res.ttft_us, res.total_us, res.finish);
            Ok(())
        }
        "eval" => {
            let arts = Artifacts::load(&arts_dir)?;
            let rt = Arc::new(ModelRuntime::load(arts.model(&model)?)?);
            let opt = sweep_opts(&args)?;
            let mut engine = Engine::new(
                rt,
                EngineConfig { batch: opt.batch, aqua: aqua_from(&args)?, ..Default::default() },
            )?;
            let task = args.str("task", "all");
            if task == "ppl" || task == "all" {
                let corpus = std::fs::read(arts.corpus_path("valid")?)?;
                let p = perplexity(&mut engine, &corpus,
                                   PplConfig { window: 256, windows: opt.ppl_windows })?;
                println!("perplexity(valid) = {p:.3}");
            }
            for name in exp::TASK_ORDER {
                if task != "all" && task != name {
                    continue;
                }
                let (path, analog) = arts.tasks.get(name)
                    .with_context(|| format!("task {name} missing"))?;
                let set = TaskSet::load(name, analog, path)?.truncated(opt.items_per_task);
                let s = run_task(&mut engine, &set)?;
                println!("{:<18} ({:<14}) acc {:.3} ± {:.3}  (n={})",
                         s.task, s.analog_of, s.acc, s.stderr, s.n);
            }
            eprintln!("{}", engine.metrics.snapshot().report());
            Ok(())
        }
        "table1" => {
            let arts = Artifacts::load(&arts_dir)?;
            let ratios = args.f64_list("ratios", &[0.9, 0.75, 0.5, 0.4, 0.3, 0.2, 0.1])?;
            let rows = exp::table1(&arts, &model, &ratios, &sweep_opts(&args)?)?;
            exp::print_table(&format!("Table 1/4 — standalone AQUA ({model})"), &rows);
            Ok(())
        }
        "table2" => {
            let arts = Artifacts::load(&arts_dir)?;
            let h2o = args.f64_list("h2o-ratios", &[0.25, 0.5, 0.75, 1.0])?;
            let k = args.f64_list("ratios", &[0.3, 0.5, 0.75, 1.0])?;
            let rows = exp::table2(&arts, &model, &h2o, &k, &sweep_opts(&args)?)?;
            exp::print_table(&format!("Table 2/5 — AQUA-H2O ({model})"), &rows);
            Ok(())
        }
        "table3" => {
            let arts = Artifacts::load(&arts_dir)?;
            let s = args.f64_list("s-ratios", &[0.1, 0.25])?;
            let k = args.f64_list("ratios", &[0.75, 0.9, 1.0])?;
            let rows = exp::table3(&arts, &model, &s, &k, &sweep_opts(&args)?)?;
            exp::print_table(&format!("Table 3/6 — AQUA-Memory ({model})"), &rows);
            Ok(())
        }
        "table7" => {
            let arts = Artifacts::load(&arts_dir)?;
            let prompt = args.str("prompt", "the capital of ");
            let ratios = args.f64_list("ratios", &[1.0, 0.9, 0.75, 0.5, 0.4, 0.3, 0.2])?;
            println!("# Table 7 — qualitative generations (greedy), prompt: {prompt:?}");
            for (label, text) in exp::table7(&arts, &model, &prompt, &ratios)? {
                println!("k_ratio {label:<16} | {text:?}");
            }
            Ok(())
        }
        "fig2" => {
            let arts = Artifacts::load(&arts_dir)?;
            exp::print_fig2(&exp::fig2(&arts, &model)?);
            Ok(())
        }
        "fig3" => {
            let arts = Artifacts::load(&arts_dir)?;
            exp::print_fig3(&exp::fig3(&arts, &model)?);
            Ok(())
        }
        "fig5" => {
            let arts = Artifacts::load(&arts_dir)?;
            exp::print_fig5(&exp::fig5(&arts, &model)?);
            Ok(())
        }
        "ablation" => {
            let arts = Artifacts::load(&arts_dir)?;
            exp::print_ablation(&exp::ablation_projection_source(&arts, &model)?);
            Ok(())
        }
        "breakeven" => {
            let bencher = if args.switch("fast") { Bencher::quick() } else { Bencher::default() };
            let ds = args
                .f64_list("d", &[32.0, 64.0, 128.0])?
                .into_iter()
                .map(|d| d as usize)
                .collect::<Vec<_>>();
            let kf = args.f64_list("k-fracs", &[0.125, 0.25, 0.5, 0.75, 0.875])?;
            exp::print_breakeven(&exp::breakeven(&ds, &kf, &bencher));
            Ok(())
        }
        "selftest" => {
            let arts = Artifacts::load(&arts_dir)?;
            let rt = Arc::new(ModelRuntime::load(arts.model(&model)?)?);
            let mut engine = Engine::new(rt, EngineConfig { batch: 4, ..Default::default() })?;
            let tok = ByteTokenizer;
            let reqs: Vec<GenRequest> = (0..6)
                .map(|i| {
                    let mut r = GenRequest::new(
                        i + 1,
                        tok.encode("the capital of "),
                        24,
                    );
                    r.stop_token = Some(b'\n' as i32);
                    r
                })
                .collect();
            let results = engine.run_batch(reqs)?;
            for r in &results {
                println!("req {}: {:?} ({:?})", r.id, tok.decode(&r.tokens), r.finish);
            }
            println!("{}", engine.metrics.snapshot().report());
            println!("selftest OK");
            Ok(())
        }
        other => bail!("unknown subcommand '{other}'\n{USAGE}"),
    }
}
