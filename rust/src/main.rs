//! `aqua` — CLI for the AQUA serving stack.
//!
//! Subcommands (see README):
//!   serve       start the HTTP server (multi-model: repeated --model
//!               name=...,k=... kv-specs or --fleet fleet.json; admin
//!               endpoints mutate the fleet at runtime)
//!   generate    one-off generation from a prompt
//!   eval        run one SynthBench task / perplexity at given knobs
//!   table1..3   regenerate the paper's Tables 1/4, 2/5, 3/6
//!   table7      qualitative generations vs k_ratio
//!   fig2 fig3 fig5   regenerate the paper's figures (needs --features pjrt)
//!   breakeven   §5 break-even measurement (native kernels)
//!   selftest    engine smoke test through the selected backend
//!
//! Backend selection (`--backend auto|native|sharded|pjrt`): `native` is
//! the hermetic pure-rust reference backend (no artifacts needed, weights
//! seeded from `--seed`); `sharded` splits the batch's lanes and KV shards
//! across `--threads N` worker threads (bit-identical to native); `pjrt`
//! executes the AOT artifacts and requires building with `--features
//! pjrt`; `auto` (default) picks pjrt when available and falls back to
//! native.

mod cli;

use anyhow::{bail, Context, Result};

use aqua_serve::aqua::policy::AquaConfig;
use aqua_serve::bench::Bencher;
use aqua_serve::coordinator::{Engine, EngineConfig, GenRequest};
use aqua_serve::eval::experiments as exp;
use aqua_serve::eval::ppl::{perplexity, PplConfig};
use aqua_serve::eval::tasks::{run_task, TaskSet};
use aqua_serve::registry::{DeploymentSpec, ModelRegistry};
use aqua_serve::runtime::{Artifacts, BackendSpec, ExecBackend};
use aqua_serve::tokenizer::ByteTokenizer;
use cli::Args;

const USAGE: &str = "usage: aqua <serve|generate|eval|table1|table2|table3|table7|fig2|fig3|fig5|ablation|breakeven|benchcheck|selftest> [flags]
common flags: --backend auto|native|sharded|pjrt --threads N --seed N --artifacts DIR --model NAME --k-ratio R --s-ratio R --h2o-ratio R --batch N --items N --fast
serve fleet: --fleet fleet.json | repeated --model name=N,backend=B,k=R,threads=T,batch=B,queue=Q,kv_mb=M,prefix=0|1,prefix_pages=P,prefill_tokens=N,total_tokens=N,wsr=R,interleave=0|1 [--default-model N] (plain --model NAME [--kv-budget-mb M] [--prefix-cache] [--prefix-pages P] serves one deployment named 'default'; kv_mb caps resident KV pages — over-budget requests shed with a memory-pressure 429; prefix enables page-granular prefix sharing: one prefill's KV pages serve every lane with the prefix)
serve kv residency: --kv-quant f32|int8 (resident-KV payload element type; int8 quantizes truncated keys and values with per-page scales and routes decode through the fused streaming kernel — resident KV bytes drop >= 40% at equal kv_keep with greedy outputs unchanged; kv-spec key kv_quant= sets it per deployment)
serve scheduling: --max-prefill-tokens N (per-step prefill token budget, 0 = unlimited) --max-total-tokens N (admission cap on worst-case batch tokens, 0 = unlimited) --waiting-ratio R (queue pressure threshold for bounded head overtakes) --no-interleave (legacy FIFO run-to-completion; disables chunked-prefill/decode interleaving) --speculate N (self-speculative decoding: AQUA-sparse draft depth per duty cycle, dense verify over the same KV; 0 = off, lossless when on; kv-spec key speculate= sets it per deployment; requests may send 'priority': N to jump the admission queue)
serve lifecycle: --restart N (engine rebuilds after a crash; 0 = fail fast) --restart-backoff-ms MS --deadline-ms MS (default per-request deadline from enqueue, 0 = none; requests may override via the JSON 'deadline_ms' field) --max-step-failures N (consecutive failing passes before the engine is declared failed); kv-spec keys restart=,restart_backoff_ms=,deadline_ms=,max_step_failures= set the same per deployment
serve tracing: --trace off|errors|sampled:N|full (flight recorder; kv-spec key trace= sets it per deployment). GET /trace?model=&n= dumps recent events (format=jsonl → Perfetto-loadable), GET /trace/postmortem serves failure snapshots, and 'timings': true on /generate returns the request's span breakdown; AQUA_LOG=level,module=level tunes stderr logging
chaos: --backend fault:<inner>,err_every=N,err_p=R,err_count=N,err_lane=L,unattributed=1,panic_at=N,delay_every=N,delay_ms=MS,seed=N (deterministic fault injection over any backend; inside a --model kv-spec use ';' between fault params: backend=fault:native;err_every=50)";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!("{USAGE}");
        std::process::exit(2);
    }
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn aqua_from(args: &Args) -> Result<AquaConfig> {
    Ok(AquaConfig {
        k_ratio: args.f64("k-ratio", 1.0)?,
        s_ratio: args.f64("s-ratio", 0.0)?,
        h2o_ratio: args.f64("h2o-ratio", 1.0)?,
        use_projection: !args.switch("identity-proj"),
    })
}

fn sweep_opts(args: &Args) -> Result<exp::SweepOptions> {
    let mut opt = exp::SweepOptions {
        batch: args.usize("batch", 4)?,
        items_per_task: args.usize("items", 60)?,
        ppl_windows: args.usize("ppl-windows", 8)?,
        ..Default::default()
    };
    if args.switch("fast") {
        opt.items_per_task = opt.items_per_task.min(12);
        opt.ppl_windows = 2;
    }
    Ok(opt)
}

/// Resolve `--backend` into a spec. `auto` prefers the PJRT artifacts when
/// the feature is compiled in and `make artifacts` has run.
fn backend_spec(args: &Args, arts_dir: &str, model: &str) -> Result<BackendSpec> {
    let choice = args.str("backend", "auto");
    let seed = args.u64("seed", 0)?;
    let threads = args.usize("threads", 4)?;
    BackendSpec::from_kind(&choice, model, seed, threads, arts_dir)
}

/// Build the serve fleet: `--fleet cfg.json`, repeated `--model
/// name=...,k=...` deployment kv-specs, or — when neither is given — one
/// deployment named "default" from the classic single-engine flags
/// (byte-compatible with the pre-registry `aqua serve`).
fn fleet_registry(args: &Args, arts_dir: &str) -> Result<ModelRegistry> {
    let fleet = args.str("fleet", "");
    if !fleet.is_empty() {
        let text = std::fs::read_to_string(&fleet)
            .with_context(|| format!("reading fleet config {fleet}"))?;
        let doc = aqua_serve::util::json::Json::parse(&text)
            .with_context(|| format!("parsing {fleet}"))?;
        return ModelRegistry::from_fleet_json(&doc, arts_dir);
    }
    let registry = ModelRegistry::new(arts_dir);
    let kv_specs: Vec<String> =
        args.strs("model").into_iter().filter(|m| m.contains('=')).collect();
    if kv_specs.is_empty() {
        registry.deploy(DeploymentSpec {
            name: "default".to_string(),
            backend: args.str("backend", "auto"),
            model: args.str("model", "llama-analog"),
            seed: args.u64("seed", 0)?,
            threads: args.usize("threads", 4)?,
            batch: args.usize("batch", 4)?,
            max_inflight: args.usize("queue", aqua_serve::registry::DEFAULT_MAX_INFLIGHT)?,
            kv_budget_mb: args.f64("kv-budget-mb", 0.0)?,
            prefix_cache: args.switch("prefix-cache"),
            prefix_cache_pages: args.usize("prefix-pages", 0)?,
            kv_quant: args.str("kv-quant", "f32"),
            max_batch_prefill_tokens: args.usize("max-prefill-tokens", 0)?,
            max_batch_total_tokens: args.usize("max-total-tokens", 0)?,
            waiting_served_ratio: args.f64("waiting-ratio", 1.2)?,
            interleave: !args.switch("no-interleave"),
            speculate: args.usize("speculate", 0)?,
            restart: args.u64("restart", 0)? as u32,
            restart_backoff_ms: args.u64("restart-backoff-ms", 50)?,
            deadline_ms: args.u64("deadline-ms", 0)?,
            max_step_failures: args.usize("max-step-failures", 3)?,
            trace: args.str("trace", "off"),
            aqua: aqua_from(args)?,
        })?;
    } else {
        for s in &kv_specs {
            registry.deploy(DeploymentSpec::parse_kv(s)?)?;
        }
        let default = args.str("default-model", "");
        if !default.is_empty() {
            registry.set_default(&default)?;
        }
    }
    Ok(registry)
}

/// The npz-dump figure/ablation regenerators only exist on the PJRT path.
#[cfg(feature = "pjrt")]
fn run_figure(which: &str, arts_dir: &str, model: &str) -> Result<()> {
    let arts = Artifacts::load(arts_dir)?;
    match which {
        "fig2" => exp::print_fig2(&exp::fig2(&arts, model)?),
        "fig3" => exp::print_fig3(&exp::fig3(&arts, model)?),
        "fig5" => exp::print_fig5(&exp::fig5(&arts, model)?),
        "ablation" => exp::print_ablation(&exp::ablation_projection_source(&arts, model)?),
        other => bail!("unknown figure '{other}'"),
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn run_figure(which: &str, _arts_dir: &str, _model: &str) -> Result<()> {
    bail!("{which} reads the npz calibration dump; rebuild with `--features pjrt`")
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    let arts_dir = args.str("artifacts", aqua_serve::ARTIFACTS_DIR);
    let model = args.str("model", "llama-analog");

    match args.subcommand.as_str() {
        "serve" => {
            let addr = args.str("addr", "127.0.0.1:8080");
            let registry = std::sync::Arc::new(fleet_registry(&args, &arts_dir)?);
            aqua_serve::log_info!(
                "serving {} model(s): {} (default: {})",
                registry.len(),
                registry.names().join(", "),
                registry.default_name().unwrap_or_else(|| "-".to_string())
            );
            aqua_serve::server::serve(&addr, registry)
        }
        "generate" => {
            let prompt = args.str("prompt", "the capital of ");
            let max_new = args.usize("max-new", 64)?;
            let spec = backend_spec(&args, &arts_dir, &model)?;
            let mut engine = Engine::with_spec(
                &spec,
                EngineConfig { batch: 1, aqua: aqua_from(&args)?, ..Default::default() },
            )?;
            let tok = ByteTokenizer;
            let mut req = GenRequest::new(1, tok.encode(&prompt), max_new);
            req.stop_token = Some(b'\n' as i32);
            let res = engine.run_batch(vec![req])?.remove(0);
            println!("{}{}", prompt, tok.decode(&res.tokens));
            eprintln!("-- [{}] {} tokens, ttft {}µs, total {}µs, finish {:?}",
                      engine.backend().name(), res.tokens.len(), res.ttft_us, res.total_us,
                      res.finish);
            Ok(())
        }
        "eval" => {
            let arts = Artifacts::load(&arts_dir)
                .context("eval needs the task/corpus artifacts (run `make artifacts`)")?;
            let spec = backend_spec(&args, &arts_dir, &model)?;
            let opt = sweep_opts(&args)?;
            let mut engine = Engine::with_spec(
                &spec,
                EngineConfig { batch: opt.batch, aqua: aqua_from(&args)?, ..Default::default() },
            )?;
            let task = args.str("task", "all");
            if task == "ppl" || task == "all" {
                let corpus = std::fs::read(arts.corpus_path("valid")?)?;
                let cfg = PplConfig::for_capacity(engine.model_config().max_seq, opt.ppl_windows);
                let p = perplexity(&mut engine, &corpus, cfg)?;
                println!("perplexity(valid) = {p:.3}");
            }
            for name in exp::TASK_ORDER {
                if task != "all" && task != name {
                    continue;
                }
                let (path, analog) = arts.tasks.get(name)
                    .with_context(|| format!("task {name} missing"))?;
                let set = TaskSet::load(name, analog, path)?.truncated(opt.items_per_task);
                let s = run_task(&mut engine, &set)?;
                println!("{:<18} ({:<14}) acc {:.3} ± {:.3}  (n={})",
                         s.task, s.analog_of, s.acc, s.stderr, s.n);
            }
            eprintln!("{}", engine.metrics.snapshot().report());
            Ok(())
        }
        "table1" => {
            let arts = Artifacts::load(&arts_dir)?;
            let spec = backend_spec(&args, &arts_dir, &model)?;
            let ratios = args.f64_list("ratios", &[0.9, 0.75, 0.5, 0.4, 0.3, 0.2, 0.1])?;
            let rows = exp::table1(&arts, &spec, &ratios, &sweep_opts(&args)?)?;
            exp::print_table(&format!("Table 1/4 — standalone AQUA ({model})"), &rows);
            Ok(())
        }
        "table2" => {
            let arts = Artifacts::load(&arts_dir)?;
            let spec = backend_spec(&args, &arts_dir, &model)?;
            let h2o = args.f64_list("h2o-ratios", &[0.25, 0.5, 0.75, 1.0])?;
            let k = args.f64_list("ratios", &[0.3, 0.5, 0.75, 1.0])?;
            let rows = exp::table2(&arts, &spec, &h2o, &k, &sweep_opts(&args)?)?;
            exp::print_table(&format!("Table 2/5 — AQUA-H2O ({model})"), &rows);
            Ok(())
        }
        "table3" => {
            let arts = Artifacts::load(&arts_dir)?;
            let spec = backend_spec(&args, &arts_dir, &model)?;
            let s = args.f64_list("s-ratios", &[0.1, 0.25])?;
            let k = args.f64_list("ratios", &[0.75, 0.9, 1.0])?;
            let rows = exp::table3(&arts, &spec, &s, &k, &sweep_opts(&args)?)?;
            exp::print_table(&format!("Table 3/6 — AQUA-Memory ({model})"), &rows);
            Ok(())
        }
        "table7" => {
            let spec = backend_spec(&args, &arts_dir, &model)?;
            let prompt = args.str("prompt", "the capital of ");
            let ratios = args.f64_list("ratios", &[1.0, 0.9, 0.75, 0.5, 0.4, 0.3, 0.2])?;
            println!("# Table 7 — qualitative generations (greedy), prompt: {prompt:?}");
            for (label, text) in exp::table7(&spec, &prompt, &ratios)? {
                println!("k_ratio {label:<16} | {text:?}");
            }
            Ok(())
        }
        "fig2" | "fig3" | "fig5" | "ablation" => {
            run_figure(args.subcommand.as_str(), &arts_dir, &model)
        }
        "benchcheck" => {
            // Validate BENCH_decode.json (CI runs this after the bench
            // smoke; --strict additionally asserts the perf invariants —
            // packed beats masked-dense at k=d/4, sharded t=4 beats t=1).
            let default = aqua_serve::bench::report::default_path().to_string();
            let path = args.str("path", &default);
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading {path} (run the decode benches first)"))?;
            let doc = aqua_serve::util::json::Json::parse(&text)
                .with_context(|| format!("parsing {path}"))?;
            aqua_serve::bench::report::validate(&doc, args.switch("strict"))
                .with_context(|| format!("validating {path}"))?;
            println!(
                "{path} ok (schema v{}, strict={})",
                aqua_serve::bench::report::SCHEMA_VERSION,
                args.switch("strict")
            );
            // BENCH_serving.json (openloop_load example) is validated when
            // present — it only exists after a serving bench run.
            let sdefault = aqua_serve::bench::report::serving_path().to_string();
            let spath = args.str("serving-path", &sdefault);
            if std::path::Path::new(&spath).exists() {
                let text = std::fs::read_to_string(&spath)?;
                let doc = aqua_serve::util::json::Json::parse(&text)
                    .with_context(|| format!("parsing {spath}"))?;
                aqua_serve::bench::report::validate_serving(&doc, args.switch("strict"))
                    .with_context(|| format!("validating {spath}"))?;
                println!("{spath} ok (serving schema)");
            }
            // BENCH_kvmem.json (kvmem bench): same convention.
            let kdefault = aqua_serve::bench::report::kvmem_path().to_string();
            let kpath = args.str("kvmem-path", &kdefault);
            if std::path::Path::new(&kpath).exists() {
                let text = std::fs::read_to_string(&kpath)?;
                let doc = aqua_serve::util::json::Json::parse(&text)
                    .with_context(|| format!("parsing {kpath}"))?;
                aqua_serve::bench::report::validate_kvmem(&doc, args.switch("strict"))
                    .with_context(|| format!("validating {kpath}"))?;
                println!("{kpath} ok (kvmem schema)");
            }
            // BENCH_prefix.json (prefixshare bench): same convention.
            let pdefault = aqua_serve::bench::report::prefix_path().to_string();
            let ppath = args.str("prefix-path", &pdefault);
            if std::path::Path::new(&ppath).exists() {
                let text = std::fs::read_to_string(&ppath)?;
                let doc = aqua_serve::util::json::Json::parse(&text)
                    .with_context(|| format!("parsing {ppath}"))?;
                aqua_serve::bench::report::validate_prefix(&doc, args.switch("strict"))
                    .with_context(|| format!("validating {ppath}"))?;
                println!("{ppath} ok (prefixshare schema)");
            }
            // BENCH_interleave.json (interleave bench): same convention.
            let idefault = aqua_serve::bench::report::interleave_path().to_string();
            let ipath = args.str("interleave-path", &idefault);
            if std::path::Path::new(&ipath).exists() {
                let text = std::fs::read_to_string(&ipath)?;
                let doc = aqua_serve::util::json::Json::parse(&text)
                    .with_context(|| format!("parsing {ipath}"))?;
                aqua_serve::bench::report::validate_interleave(&doc, args.switch("strict"))
                    .with_context(|| format!("validating {ipath}"))?;
                println!("{ipath} ok (interleave schema)");
            }
            // BENCH_fused.json (fused bench): same convention.
            let fdefault = aqua_serve::bench::report::fused_path().to_string();
            let fpath = args.str("fused-path", &fdefault);
            if std::path::Path::new(&fpath).exists() {
                let text = std::fs::read_to_string(&fpath)?;
                let doc = aqua_serve::util::json::Json::parse(&text)
                    .with_context(|| format!("parsing {fpath}"))?;
                aqua_serve::bench::report::validate_fused(&doc, args.switch("strict"))
                    .with_context(|| format!("validating {fpath}"))?;
                println!("{fpath} ok (fused schema)");
            }
            // BENCH_speculate.json (speculate bench): same convention.
            let xdefault = aqua_serve::bench::report::speculate_path().to_string();
            let xpath = args.str("speculate-path", &xdefault);
            if std::path::Path::new(&xpath).exists() {
                let text = std::fs::read_to_string(&xpath)?;
                let doc = aqua_serve::util::json::Json::parse(&text)
                    .with_context(|| format!("parsing {xpath}"))?;
                aqua_serve::bench::report::validate_speculate(&doc, args.switch("strict"))
                    .with_context(|| format!("validating {xpath}"))?;
                println!("{xpath} ok (speculate schema)");
            }
            Ok(())
        }
        "breakeven" => {
            let bencher = if args.switch("fast") { Bencher::quick() } else { Bencher::default() };
            let ds = args
                .f64_list("d", &[32.0, 64.0, 128.0])?
                .into_iter()
                .map(|d| d as usize)
                .collect::<Vec<_>>();
            let kf = args.f64_list("k-fracs", &[0.125, 0.25, 0.5, 0.75, 0.875])?;
            exp::print_breakeven(&exp::breakeven(&ds, &kf, &bencher));
            Ok(())
        }
        "selftest" => {
            let spec = backend_spec(&args, &arts_dir, &model)?;
            let mut engine = Engine::with_spec(
                &spec,
                EngineConfig { batch: 4, aqua: aqua_from(&args)?, ..Default::default() },
            )?;
            let tok = ByteTokenizer;
            let reqs: Vec<GenRequest> = (0..6)
                .map(|i| {
                    let mut r = GenRequest::new(
                        i + 1,
                        tok.encode("the capital of "),
                        24,
                    );
                    r.stop_token = Some(b'\n' as i32);
                    r
                })
                .collect();
            let results = engine.run_batch(reqs)?;
            for r in &results {
                println!("req {}: {:?} ({:?})", r.id, tok.decode(&r.tokens), r.finish);
            }
            println!("{}", engine.metrics.snapshot().report());
            println!("selftest OK ({} backend)", engine.backend().name());
            Ok(())
        }
        other => bail!("unknown subcommand '{other}'\n{USAGE}"),
    }
}
