//! Minimal HTTP/1.1 front-end (hyper/tokio unavailable offline).
//!
//! `POST /generate {"prompt": "...", "max_new_tokens": N}` → generated text
//! `GET  /stats` → engine metrics snapshot (latency/throughput headline)
//! `GET  /metrics` → full snapshot incl. score-kernel variant counters
//!                   (which AQUA kernel — dense/sparse/packed — actually
//!                   ran) and attention-score-path timing
//! `GET  /healthz` → ok
//!
//! The engine is !Send (PJRT handles), so it lives on its own thread behind
//! an `EngineHandle`; the accept loop and per-connection workers only move
//! plain data.

pub mod http;

use std::net::TcpListener;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::coordinator::engine::{EngineCmd, EngineHandle};
use crate::coordinator::GenRequest;
use crate::tokenizer::ByteTokenizer;
use crate::util::json::Json;
use http::{Request, Response};

/// Serve until the process is killed. `handle` must already be running.
pub fn serve(addr: &str, handle: EngineHandle) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    crate::log_info!("listening on http://{addr}");
    let cmd_tx = handle.cmd_tx.clone();
    let results = Arc::new(Mutex::new(std::collections::HashMap::new()));

    // Result pump: engine thread -> shared map.
    {
        let results = results.clone();
        std::thread::spawn(move || {
            while let Ok(res) = handle.result_rx.recv() {
                results.lock().unwrap().insert(res.id, res);
            }
        });
    }

    let next_id = Arc::new(Mutex::new(1u64));
    for stream in listener.incoming() {
        let Ok(stream) = stream else { continue };
        let cmd_tx = cmd_tx.clone();
        let results = results.clone();
        let next_id = next_id.clone();
        std::thread::spawn(move || {
            let _ = http::handle_connection(stream, |req| {
                route(req, &cmd_tx, &results, &next_id)
            });
        });
    }
    Ok(())
}

fn route(
    req: &Request,
    cmd_tx: &mpsc::Sender<EngineCmd>,
    results: &Arc<Mutex<std::collections::HashMap<u64, crate::coordinator::GenResult>>>,
    next_id: &Arc<Mutex<u64>>,
) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => Response::text(200, "ok"),
        ("GET", "/stats") | ("GET", "/metrics") => {
            let (tx, rx) = mpsc::channel();
            if cmd_tx.send(EngineCmd::Stats(tx)).is_err() {
                return Response::text(500, "engine gone");
            }
            match rx.recv_timeout(std::time::Duration::from_secs(5)) {
                Ok(s) => {
                    let mut fields = vec![
                        ("requests_done", Json::Num(s.requests_done as f64)),
                        ("tokens_generated", Json::Num(s.tokens_generated as f64)),
                        ("decode_tok_per_s", Json::Num(s.decode_tok_per_s)),
                        ("mean_ttft_ms", Json::Num(s.mean_ttft_ms)),
                        ("p99_ttft_ms", Json::Num(s.p99_ttft_ms)),
                        ("h2o_evictions", Json::Num(s.h2o_evictions as f64)),
                    ];
                    if req.path == "/metrics" {
                        fields.extend([
                            ("kernel_dense", Json::Num(s.kernels.dense as f64)),
                            ("kernel_sparse", Json::Num(s.kernels.sparse as f64)),
                            ("kernel_packed", Json::Num(s.kernels.packed as f64)),
                            ("score_time_s", Json::Num(s.kernels.score_ns as f64 / 1e9)),
                            ("score_us_per_decode", Json::Num(s.score_us_per_decode)),
                            ("decode_calls", Json::Num(s.decode_calls as f64)),
                            ("prefill_calls", Json::Num(s.prefill_calls as f64)),
                            ("wall_tok_per_s", Json::Num(s.wall_tok_per_s)),
                        ]);
                    }
                    Response::json(200, &Json::obj(fields))
                }
                Err(_) => Response::text(504, "stats timeout"),
            }
        }
        ("POST", "/generate") => {
            let body = match Json::parse(&req.body) {
                Ok(b) => b,
                Err(e) => return Response::text(400, &format!("bad json: {e}")),
            };
            let prompt = match body.get("prompt").as_str() {
                Some(p) => p.to_string(),
                None => return Response::text(400, "missing 'prompt'"),
            };
            let max_new = body.get("max_new_tokens").as_i64().unwrap_or(64) as usize;
            let id = {
                let mut g = next_id.lock().unwrap();
                *g += 1;
                *g
            };
            let tok = ByteTokenizer;
            let mut r = GenRequest::new(id, tok.encode(&prompt), max_new);
            r.stop_token = Some(b'\n' as i32);
            if cmd_tx.send(EngineCmd::Submit(r)).is_err() {
                return Response::text(500, "engine gone");
            }
            // Poll the shared result map (bounded wait).
            let deadline = std::time::Instant::now() + std::time::Duration::from_secs(120);
            loop {
                if let Some(res) = results.lock().unwrap().remove(&id) {
                    let text = tok.decode(&res.tokens);
                    return Response::json(200, &Json::obj(vec![
                        ("id", Json::Num(id as f64)),
                        ("text", Json::Str(text)),
                        ("tokens", Json::Num(res.tokens.len() as f64)),
                        ("ttft_us", Json::Num(res.ttft_us as f64)),
                        ("total_us", Json::Num(res.total_us as f64)),
                    ]));
                }
                if std::time::Instant::now() > deadline {
                    return Response::text(504, "generation timeout");
                }
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }
        _ => Response::text(404, "not found"),
    }
}
