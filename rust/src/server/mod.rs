//! Minimal HTTP/1.1 front-end (hyper/tokio unavailable offline): a router
//! over the multi-model [`crate::registry::ModelRegistry`].
//!
//! `POST /generate {"prompt": "...", "max_new_tokens": N, "model": "m"}`
//!     → generated text; `"model"` picks the deployment (fleet default
//!     when omitted → 404 if unknown), `"stop_newline": false` disables
//!     the newline stop token. Over-capacity deployments shed with 429 —
//!     the body (and the `shed_capacity_total`/`shed_memory_total`
//!     counters) distinguish the in-flight bound from KV memory pressure
//!     (`kv_budget_mb` cannot cover the request's worst-case page growth).
//! `GET  /stats` → fleet headline + per-model sections (incl. the
//!     prefix-cache hit rate: prompt tokens served by attaching shared KV
//!     pages instead of running prefill)
//! `GET  /metrics` → full snapshots incl. score-kernel variant counters
//!     (which AQUA kernel — dense/sparse/packed — actually ran per model),
//!     admission queue-depth/shed counters, and the KV-pool gauges
//!     (headroom `kv_pages_free`, `kv_shared_pages`, `kv_cow_copies`);
//!     `?format=prometheus` renders the same numbers as a Prometheus
//!     exposition (`# HELP`/`# TYPE` per series, label values escaped)
//! `GET  /trace?model=&n=` → the deployment's last N flight-recorder
//!     events (`?format=jsonl` streams a Chrome-trace/Perfetto-loadable
//!     JSONL dump — recipe in BENCHES.md)
//! `GET  /trace/postmortem` → failure snapshots (blamed lane + trailing
//!     events) captured on lane failure / engine death; `?model=` filters
//! `GET  /models` → deployment specs + live status
//! `POST /models {spec}` → add a deployment at runtime (409 on name clash)
//! `DELETE /models/{name}` → drain in-flight requests, join the engine
//! `GET  /healthz` → ok
//!
//! Engines are !Send (PJRT handles), so each deployment's engine lives on
//! its own thread behind the registry; the accept loop and per-connection
//! workers only move plain data.

// Server code must never silently discard a Result — count it or log it.
#![deny(clippy::let_underscore_must_use)]

pub mod http;

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::metrics::Snapshot;
use crate::coordinator::{FinishReason, GenRequest, Health};
use crate::registry::{Admission, AdmissionStats, DeploymentSpec, ModelRegistry, ShedReason};
use crate::tokenizer::ByteTokenizer;
use crate::trace::events_jsonl;
use crate::util::json::Json;
use http::{Request, Response};

/// How long one `/generate` worker waits for its result before giving up
/// (an abandoned result is then TTL-swept by the deployment's pump).
const GENERATE_DEADLINE: Duration = Duration::from_secs(120);

/// How often a waiting `/generate` worker probes its connection for
/// client disconnect (each probe is one non-blocking `peek` syscall).
const DISCONNECT_PROBE: Duration = Duration::from_millis(50);

/// Accept-loop failures since process start (`/metrics`
/// `accept_errors_total`). Process-wide: transient accept errors (fd
/// exhaustion, aborted handshakes) are a host condition, not a
/// per-deployment one.
static ACCEPT_ERRORS: AtomicU64 = AtomicU64::new(0);

/// Serve until the process is killed. Deployments stay mutable at runtime
/// through the `/models` admin endpoints.
pub fn serve(addr: &str, registry: Arc<ModelRegistry>) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    crate::log_info!("listening on http://{addr}");
    serve_on(listener, registry)
}

/// Accept loop over an already-bound listener (tests and examples bind
/// port 0 themselves and run this on a background thread). Accept
/// failures (fd exhaustion, aborted handshakes) are counted and retried
/// with bounded backoff instead of spinning hot or killing the server.
pub fn serve_on(listener: TcpListener, registry: Arc<ModelRegistry>) -> Result<()> {
    const BACKOFF_START: Duration = Duration::from_millis(10);
    const BACKOFF_MAX: Duration = Duration::from_secs(1);
    let mut backoff = BACKOFF_START;
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => {
                backoff = BACKOFF_START;
                s
            }
            Err(e) => {
                ACCEPT_ERRORS.fetch_add(1, Ordering::Relaxed);
                crate::log_warn!("accept failed (backing off {:?}): {e}", backoff);
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(BACKOFF_MAX);
                continue;
            }
        };
        let registry = registry.clone();
        std::thread::spawn(move || {
            if let Err(e) = http::handle_connection(stream, |req, conn| {
                route_conn(req, Some(conn), &registry)
            }) {
                // half-open sockets and malformed requests land here; the
                // client is gone or hopeless, but leave a trace
                crate::log_debug!("connection error: {e:#}");
            }
        });
    }
    Ok(())
}

/// Dispatch one request against the fleet (no connection — test entry
/// point; `/generate` cannot probe for disconnect).
pub fn route(req: &Request, registry: &ModelRegistry) -> Response {
    route_conn(req, None, registry)
}

/// Dispatch one request against the fleet. `conn` (when present) lets
/// `/generate` detect client disconnect mid-wait and cancel the request.
pub fn route_conn(req: &Request, conn: Option<&TcpStream>, registry: &ModelRegistry) -> Response {
    // the path may carry a query string (`/trace?model=m&n=64`)
    let (path, query) = req.path.split_once('?').unwrap_or((req.path.as_str(), ""));
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => healthz(registry),
        ("GET", "/stats") => stats_route(registry, false, query),
        ("GET", "/metrics") => stats_route(registry, true, query),
        ("GET", "/trace") => trace_route(query, registry),
        ("GET", "/trace/postmortem") => trace_postmortem(query, registry),
        ("POST", "/generate") => generate(req, conn, registry),
        ("GET", "/models") => list_models(registry),
        ("POST", "/models") => add_model(req, registry),
        ("DELETE", path) => match path.strip_prefix("/models/") {
            Some(name) => delete_model(name, registry),
            None => Response::text(404, "not found"),
        },
        _ => Response::text(404, "not found"),
    }
}

/// First value of `key` in a raw query string (no percent-decoding — the
/// trace/metrics parameters are plain identifiers).
fn query_param(query: &str, key: &str) -> Option<String> {
    query.split('&').find_map(|pair| {
        let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
        (k == key).then(|| v.to_string())
    })
}

fn health_str(h: Health) -> &'static str {
    match h {
        Health::Starting => "starting",
        Health::Healthy => "healthy",
        Health::Unhealthy => "unhealthy",
        Health::Failed => "failed",
    }
}

/// Liveness + fleet health: 200 while every deployment's engine is
/// healthy (or still starting), 503 naming the sick ones otherwise — so
/// a load balancer stops routing to a host whose engines are crashed or
/// restarting.
fn healthz(registry: &ModelRegistry) -> Response {
    let sick: Vec<String> = registry
        .deployments()
        .iter()
        .filter(|d| matches!(d.health(), Health::Unhealthy | Health::Failed))
        .map(|d| format!("{}={}", d.spec.name, health_str(d.health())))
        .collect();
    if sick.is_empty() {
        Response::text(200, "ok")
    } else {
        Response::text(503, &format!("unhealthy: {}", sick.join(",")))
    }
}

fn generate(req: &Request, conn: Option<&TcpStream>, registry: &ModelRegistry) -> Response {
    let body = match Json::parse(&req.body) {
        Ok(b) => b,
        Err(e) => return Response::text(400, &format!("bad json: {e}")),
    };
    let prompt = match body.get("prompt").as_str() {
        Some(p) => p.to_string(),
        None => return Response::text(400, "missing 'prompt'"),
    };
    let max_new = body.get("max_new_tokens").as_i64().unwrap_or(64) as usize;
    let model = body.get("model").as_str();
    let Some(dep) = registry.get(model) else {
        return match model {
            Some(m) => Response::text(404, &format!("unknown model '{m}'")),
            None => Response::text(404, "no models deployed"),
        };
    };
    let tok = ByteTokenizer;
    let id = dep.fresh_id();
    let mut r = GenRequest::new(id, tok.encode(&prompt), max_new);
    if body.get("stop_newline").as_bool() != Some(false) {
        r.stop_token = Some(b'\n' as i32);
    }
    // per-request deadline (ms from enqueue, 0 = the spec's default)
    r.deadline_ms = body.get("deadline_ms").as_i64().unwrap_or(0).max(0) as u64;
    // admission priority (higher first, FIFO within a class; default 0)
    r.priority = body.get("priority").as_i64().unwrap_or(0);
    // opt-in span breakdown in the response (`"timings": true`)
    let want_timings = body.get("timings").as_bool() == Some(true);
    match dep.submit(r) {
        Ok(Admission::Accepted) => {}
        Ok(Admission::Shed(ShedReason::Capacity)) => {
            return Response::text(
                429,
                &format!(
                    "model '{}' over capacity (in-flight limit {})",
                    dep.spec.name, dep.spec.max_inflight
                ),
            );
        }
        Ok(Admission::Shed(ShedReason::KvMemory)) => {
            return Response::text(
                429,
                &format!(
                    "model '{}' under memory pressure (in-flight requests hold the kv budget's \
                     {} MB of pages — retry once they finish)",
                    dep.spec.name, dep.spec.kv_budget_mb
                ),
            );
        }
        Ok(Admission::Shed(ShedReason::OverBudget)) => {
            return Response::text(
                413,
                &format!(
                    "request's worst-case KV growth exceeds model '{}'s entire kv budget \
                     ({} MB) — retrying cannot succeed; shorten the request or raise the budget",
                    dep.spec.name, dep.spec.kv_budget_mb
                ),
            );
        }
        Ok(Admission::Shed(ShedReason::Unhealthy)) => {
            return Response::text(
                503,
                &format!(
                    "model '{}' engine is {} — retry once /healthz recovers",
                    dep.spec.name,
                    health_str(dep.health())
                ),
            );
        }
        Err(e) => return Response::text(503, &format!("{e:#}")),
    }
    // Wait for the result, probing the connection so an abandoned request
    // is cancelled (lane retired, KV pages freed) instead of decoding for
    // a client that already hung up.
    let end = Instant::now() + GENERATE_DEADLINE;
    let mut next_probe = Instant::now() + DISCONNECT_PROBE;
    let res = loop {
        if let Some(r) = dep.take_result(id) {
            break r;
        }
        if Instant::now() >= end {
            return Response::text(504, "generation timeout");
        }
        if let Some(stream) = conn {
            if Instant::now() >= next_probe {
                next_probe = Instant::now() + DISCONNECT_PROBE;
                if http::client_gone(stream) {
                    dep.cancel(id);
                    // nobody reads this response; the terminal Cancelled
                    // result flows through the pump and is TTL-swept
                    return Response::text(503, "client disconnected; request cancelled");
                }
            }
        }
        std::thread::sleep(Duration::from_millis(2));
    };
    match res.finish {
        FinishReason::DeadlineExpired => Response::text(
            504,
            &format!("request deadline expired after {} generated tokens", res.tokens.len()),
        ),
        FinishReason::BackendError => Response::text(
            503,
            &format!("backend failed after {} tokens — retryable", res.tokens.len()),
        ),
        FinishReason::EngineFailed => Response::text(
            503,
            &format!("model '{}' engine failed mid-request — retry once healthy", dep.spec.name),
        ),
        _ => {
            let text = tok.decode(&res.tokens);
            let mut fields = vec![
                ("id", Json::Num(id as f64)),
                ("model", Json::Str(dep.spec.name.clone())),
                ("text", Json::Str(text)),
                ("tokens", Json::Num(res.tokens.len() as f64)),
                ("finish", Json::Str(format!("{:?}", res.finish))),
                ("ttft_us", Json::Num(res.ttft_us as f64)),
                ("total_us", Json::Num(res.total_us as f64)),
            ];
            if want_timings {
                // enqueue-relative spans: queue_wait + prefill + decode
                // reconciles with total (±µs rounding), ttft ≤ total
                let t = &res.timings;
                fields.push((
                    "timings",
                    Json::obj(vec![
                        ("queue_wait_ms", Json::Num(t.queue_wait_us as f64 / 1e3)),
                        ("prefill_ms", Json::Num(t.prefill_us as f64 / 1e3)),
                        ("decode_ms", Json::Num(t.decode_us as f64 / 1e3)),
                        ("ttft_ms", Json::Num(t.ttft_us as f64 / 1e3)),
                        ("total_ms", Json::Num(t.total_us as f64 / 1e3)),
                        ("prefix_hit_tokens", Json::Num(t.prefix_hit_tokens as f64)),
                    ]),
                ));
            }
            Response::json(200, &Json::obj(fields))
        }
    }
}

/// `GET /trace?model=&n=&format=` — the deployment's most recent flight-
/// recorder events, oldest-first. `format=jsonl` emits one Chrome-trace
/// instant event per line (load in Perfetto / chrome://tracing).
fn trace_route(query: &str, registry: &ModelRegistry) -> Response {
    let model = query_param(query, "model");
    let Some(dep) = registry.get(model.as_deref()) else {
        return match model {
            Some(m) => Response::text(404, &format!("unknown model '{m}'")),
            None => Response::text(404, "no models deployed"),
        };
    };
    let n = query_param(query, "n").and_then(|v| v.parse::<usize>().ok()).unwrap_or(256);
    let events = dep.trace().recent(n);
    if query_param(query, "format").as_deref() == Some("jsonl") {
        return Response::text(200, &events_jsonl(&events));
    }
    Response::json(
        200,
        &Json::obj(vec![
            ("model", Json::Str(dep.spec.name.clone())),
            ("mode", Json::Str(dep.trace().mode().as_string())),
            ("total_recorded", Json::Num(dep.trace().total_recorded() as f64)),
            ("events", Json::Arr(events.iter().map(|e| e.to_json()).collect())),
        ]),
    )
}

/// `GET /trace/postmortem[?model=]` — failure snapshots (blamed lane +
/// the trailing events leading up to the failure) per deployment.
fn trace_postmortem(query: &str, registry: &ModelRegistry) -> Response {
    let model = query_param(query, "model");
    if let Some(m) = model.as_deref() {
        if registry.get(Some(m)).is_none() {
            return Response::text(404, &format!("unknown model '{m}'"));
        }
    }
    let mut total = 0usize;
    let mut models = std::collections::BTreeMap::new();
    for dep in registry.deployments() {
        if model.as_deref().is_some_and(|m| m != dep.spec.name) {
            continue;
        }
        let pms = dep.trace().postmortems();
        total += pms.len();
        models.insert(
            dep.spec.name.clone(),
            Json::Arr(pms.iter().map(|p| p.to_json()).collect()),
        );
    }
    Response::json(
        200,
        &Json::obj(vec![
            ("postmortems_total", Json::Num(total as f64)),
            ("models", Json::Obj(models)),
        ]),
    )
}

/// The engine-snapshot fields both `/stats` (headline) and `/metrics`
/// (full) expose — the same keys the single-engine server served, so
/// fleet aggregates stay drop-in readable.
fn snapshot_fields(s: &Snapshot, full: bool) -> Vec<(&'static str, Json)> {
    let mut fields = vec![
        ("requests_done", Json::Num(s.requests_done as f64)),
        ("tokens_generated", Json::Num(s.tokens_generated as f64)),
        ("decode_tok_per_s", Json::Num(s.decode_tok_per_s)),
        ("mean_ttft_ms", Json::Num(s.mean_ttft_ms)),
        ("p99_ttft_ms", Json::Num(s.p99_ttft_ms)),
        ("ttft_p50_ms", Json::Num(s.p50_ttft_ms)),
        ("ttft_p99_ms", Json::Num(s.p99_ttft_ms)),
        ("h2o_evictions", Json::Num(s.h2o_evictions as f64)),
        ("kv_resident_bytes", Json::Num(s.kv_resident_bytes as f64)),
        ("prefix_hit_tokens", Json::Num(s.prefix_hit_tokens as f64)),
        ("prefix_hit_rate", Json::Num(s.prefix_hit_rate())),
        ("requests_rejected", Json::Num(s.requests_rejected as f64)),
        ("requests_served", Json::Num(s.requests_served as f64)),
        ("requests_cancelled", Json::Num(s.requests_cancelled as f64)),
        ("requests_expired", Json::Num(s.requests_expired as f64)),
        ("requests_failed", Json::Num(s.requests_failed as f64)),
        ("batch_occupancy", Json::Num(s.batch_occupancy)),
        ("itl_p99_ms", Json::Num(s.itl_p99_ms)),
        ("spec_acceptance_rate", Json::Num(s.spec_acceptance_rate)),
        ("tokens_per_step_effective", Json::Num(s.tokens_per_step_effective)),
    ];
    if full {
        fields.extend([
            ("lane_failures", Json::Num(s.lane_failures as f64)),
            ("sched_steps", Json::Num(s.sched_steps as f64)),
            ("prefill_tokens_per_step", Json::Num(s.prefill_tokens_per_step)),
            ("itl_mean_ms", Json::Num(s.itl_mean_ms)),
            ("queue_wait_p50_ms", Json::Num(s.queue_wait_p50_ms)),
            ("queue_wait_p99_ms", Json::Num(s.queue_wait_p99_ms)),
            ("kernel_dense", Json::Num(s.kernels.dense as f64)),
            ("kernel_sparse", Json::Num(s.kernels.sparse as f64)),
            ("kernel_packed", Json::Num(s.kernels.packed as f64)),
            ("kernel_fused_passes", Json::Num(s.kernels.fused_passes as f64)),
            ("kernel_simd_lanes", Json::Num(s.kernels.simd_lanes_used as f64)),
            ("score_time_s", Json::Num(s.kernels.score_ns as f64 / 1e9)),
            ("dequant_time_s", Json::Num(s.kernels.dequant_ns as f64 / 1e9)),
            ("score_us_per_decode", Json::Num(s.score_us_per_decode)),
            ("decode_calls", Json::Num(s.decode_calls as f64)),
            ("prefill_calls", Json::Num(s.prefill_calls as f64)),
            ("wall_tok_per_s", Json::Num(s.wall_tok_per_s)),
            ("kv_resident_peak_bytes", Json::Num(s.kv_resident_peak_bytes as f64)),
            ("kv_pages_in_use", Json::Num(s.kv_pages_in_use as f64)),
            ("kv_pages_free", Json::Num(s.kv_pages_free as f64)),
            ("kv_shared_pages", Json::Num(s.kv_shared_pages as f64)),
            ("kv_cow_copies", Json::Num(s.kv_cow_copies as f64)),
            ("kv_page_utilization", Json::Num(s.kv_page_utilization)),
            ("kv_alloc_stalls", Json::Num(s.kv_alloc_stalls as f64)),
            ("prefix_evictions", Json::Num(s.kv_prefix_evictions as f64)),
            ("spec_drafted", Json::Num(s.spec_drafted as f64)),
            ("spec_accepted", Json::Num(s.spec_accepted as f64)),
            ("spec_rejected", Json::Num(s.spec_rejected as f64)),
            ("spec_verify_passes", Json::Num(s.spec_verify_passes as f64)),
        ]);
    }
    fields
}

fn admission_fields(a: &AdmissionStats, full: bool) -> Vec<(&'static str, Json)> {
    let mut fields = vec![
        ("queue_depth", Json::Num(a.queue_depth as f64)),
        ("shed_total", Json::Num(a.shed as f64)),
        ("submitted_total", Json::Num(a.submitted as f64)),
    ];
    if full {
        fields.extend([
            ("shed_capacity_total", Json::Num(a.shed_capacity as f64)),
            ("shed_memory_total", Json::Num(a.shed_memory as f64)),
            ("shed_unhealthy_total", Json::Num(a.shed_unhealthy as f64)),
            ("engine_restarts", Json::Num(a.engine_restarts as f64)),
            ("kv_reserved_pages", Json::Num(a.kv_reserved_pages as f64)),
            ("kv_pages_total", Json::Num(a.kv_pages_total as f64)),
            ("results_swept", Json::Num(a.swept_results as f64)),
        ]);
    }
    fields
}

fn stats_route(registry: &ModelRegistry, full: bool, query: &str) -> Response {
    let mut fleet = Snapshot::default();
    let mut fleet_adm = AdmissionStats::default();
    // `kv_pages_total = 0` is the "unlimited" sentinel: the fleet total is
    // a real cap only when *every* deployment is budgeted.
    let mut kv_unbounded = false;
    let mut models = std::collections::BTreeMap::new();
    for dep in registry.deployments() {
        let adm = dep.admission_stats();
        // A dead or mid-drain engine degrades to an error section for
        // that model instead of failing the whole fleet's observability.
        let mut fields = match dep.stats() {
            Ok(snap) => {
                fleet.merge(&snap);
                snapshot_fields(&snap, full)
            }
            Err(e) => vec![("error", Json::Str(format!("{e:#}")))],
        };
        fields.push(("backend", Json::Str(dep.backend_kind().to_string())));
        fields.push(("k_ratio", Json::Num(dep.spec.aqua.k_ratio)));
        fields.push(("health", Json::Str(health_str(dep.health()).to_string())));
        fields.extend(admission_fields(&adm, full));
        models.insert(dep.spec.name.clone(), Json::obj(fields));

        fleet_adm.queue_depth += adm.queue_depth;
        fleet_adm.submitted += adm.submitted;
        fleet_adm.shed += adm.shed;
        fleet_adm.shed_capacity += adm.shed_capacity;
        fleet_adm.shed_memory += adm.shed_memory;
        fleet_adm.shed_unhealthy += adm.shed_unhealthy;
        fleet_adm.engine_restarts += adm.engine_restarts;
        fleet_adm.kv_reserved_pages += adm.kv_reserved_pages;
        fleet_adm.kv_pages_total += adm.kv_pages_total;
        kv_unbounded |= adm.kv_pages_total == 0;
        fleet_adm.swept_results += adm.swept_results;
    }
    if kv_unbounded {
        fleet_adm.kv_pages_total = 0;
    }
    let mut fields = snapshot_fields(&fleet, full);
    fields.extend(admission_fields(&fleet_adm, full));
    if full {
        let accepts = ACCEPT_ERRORS.load(Ordering::Relaxed) as f64;
        fields.push(("accept_errors_total", Json::Num(accepts)));
    }
    fields.push(("models", Json::Obj(models)));
    match registry.default_name() {
        Some(d) => fields.push(("default_model", Json::Str(d))),
        None => fields.push(("default_model", Json::Null)),
    }
    let doc = Json::obj(fields);
    if query_param(query, "format").as_deref() == Some("prometheus") {
        return Response::text(200, &prometheus_render(&doc));
    }
    Response::json(200, &doc)
}

/// Escape a Prometheus label value: backslash, double-quote and newline
/// must be backslash-escaped inside the quoted label string.
fn prometheus_escape(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Monotone counters get `# TYPE … counter`; everything else is a gauge
/// (rates, percentiles, occupancy, pool headroom all move both ways).
fn prometheus_kind(name: &str) -> &'static str {
    if name.ends_with("_total")
        || name.starts_with("requests_")
        || matches!(
            name,
            "tokens_generated"
                | "h2o_evictions"
                | "prefix_hit_tokens"
                | "lane_failures"
                | "sched_steps"
                | "decode_calls"
                | "prefill_calls"
                | "engine_restarts"
                | "results_swept"
                | "kv_cow_copies"
                | "kernel_dense"
                | "kernel_sparse"
                | "kernel_packed"
                | "kernel_fused_passes"
                | "prefix_evictions"
                | "spec_drafted"
                | "spec_accepted"
                | "spec_rejected"
                | "spec_verify_passes"
        )
    {
        "counter"
    } else {
        "gauge"
    }
}

/// Render a `/stats`-shaped JSON document as a Prometheus text exposition:
/// fleet-level numeric fields become unlabeled series, per-model numeric
/// fields become the same series labeled `{model="…"}`, every series gets
/// exactly one `# HELP` + `# TYPE` header. Non-numeric fields (health,
/// backend, default_model) are skipped — Prometheus samples are numbers.
fn prometheus_render(doc: &Json) -> String {
    // series name → (unlabeled fleet value?, per-model values)
    let mut series: std::collections::BTreeMap<String, (Option<f64>, Vec<(String, f64)>)> =
        std::collections::BTreeMap::new();
    if let Json::Obj(top) = doc {
        for (k, v) in top {
            match v {
                Json::Num(n) => series.entry(k.clone()).or_default().0 = Some(*n),
                Json::Obj(models) if k == "models" => {
                    for (model, fields) in models {
                        if let Json::Obj(f) = fields {
                            for (fk, fv) in f {
                                if let Json::Num(n) = fv {
                                    series
                                        .entry(fk.clone())
                                        .or_default()
                                        .1
                                        .push((model.clone(), *n));
                                }
                            }
                        }
                    }
                }
                _ => {}
            }
        }
    }
    let mut out = String::new();
    for (name, (fleet, per_model)) in &series {
        let metric = format!("aqua_{name}");
        out.push_str(&format!("# HELP {metric} aqua-serve `{name}` (fleet and per-model).\n"));
        out.push_str(&format!("# TYPE {metric} {}\n", prometheus_kind(name)));
        if let Some(v) = fleet {
            out.push_str(&format!("{metric} {v}\n"));
        }
        for (model, v) in per_model {
            out.push_str(&format!("{metric}{{model=\"{}\"}} {v}\n", prometheus_escape(model)));
        }
    }
    out
}

fn list_models(registry: &ModelRegistry) -> Response {
    let models: Vec<Json> = registry
        .deployments()
        .iter()
        .map(|d| {
            let mut j = d.spec.to_json();
            if let Json::Obj(o) = &mut j {
                o.insert("backend_kind".into(), Json::Str(d.backend_kind().to_string()));
                let adm = d.admission_stats();
                o.insert("queue_depth".into(), Json::Num(adm.queue_depth as f64));
                o.insert("draining".into(), Json::Bool(d.is_draining()));
                o.insert("health".into(), Json::Str(health_str(d.health()).to_string()));
                o.insert("engine_restarts".into(), Json::Num(adm.engine_restarts as f64));
            }
            j
        })
        .collect();
    Response::json(
        200,
        &Json::obj(vec![
            ("default", registry.default_name().map(Json::Str).unwrap_or(Json::Null)),
            ("models", Json::Arr(models)),
        ]),
    )
}

fn add_model(req: &Request, registry: &ModelRegistry) -> Response {
    let body = match Json::parse(&req.body) {
        Ok(b) => b,
        Err(e) => return Response::text(400, &format!("bad json: {e}")),
    };
    let spec = match DeploymentSpec::from_json(&body) {
        Ok(s) => s,
        Err(e) => return Response::text(400, &format!("bad deployment spec: {e:#}")),
    };
    let name = spec.name.clone();
    match registry.deploy(spec) {
        Ok(()) => Response::json(
            200,
            &Json::obj(vec![("ok", Json::Bool(true)), ("name", Json::Str(name))]),
        ),
        // deploy refuses duplicates internally (race-safe): if the name is
        // registered now, the failure was a conflict, not a bad spec
        Err(_) if registry.get(Some(&name)).is_some() => {
            Response::text(409, &format!("model '{name}' already exists"))
        }
        Err(e) => Response::text(400, &format!("{e:#}")),
    }
}

fn delete_model(name: &str, registry: &ModelRegistry) -> Response {
    if name.is_empty() || name.contains('/') {
        return Response::text(400, "expected /models/{name}");
    }
    if registry.get(Some(name)).is_none() {
        return Response::text(404, &format!("unknown model '{name}'"));
    }
    match registry.remove(name) {
        Ok(()) => Response::json(
            200,
            &Json::obj(vec![("ok", Json::Bool(true)), ("removed", Json::Str(name.to_string()))]),
        ),
        Err(e) => Response::text(500, &format!("{e:#}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn request(method: &str, path: &str, body: &str) -> Request {
        Request {
            method: method.to_string(),
            path: path.to_string(),
            headers: vec![],
            body: body.to_string(),
        }
    }

    #[test]
    fn routes_without_models() {
        let reg = ModelRegistry::new("no-such-dir");
        assert_eq!(route(&request("GET", "/healthz", ""), &reg).status, 200);
        assert_eq!(route(&request("GET", "/nope", ""), &reg).status, 404);
        assert_eq!(route(&request("POST", "/generate", "{not json"), &reg).status, 400);
        assert_eq!(route(&request("POST", "/generate", "{}"), &reg).status, 400);
        let r = route(&request("POST", "/generate", r#"{"prompt": "hi"}"#), &reg);
        assert_eq!(r.status, 404, "empty fleet has no default model");
        assert_eq!(route(&request("DELETE", "/models/", ""), &reg).status, 400);
        assert_eq!(route(&request("DELETE", "/models/x", ""), &reg).status, 404);
        // empty fleet stats still render
        let s = route(&request("GET", "/stats", ""), &reg);
        assert_eq!(s.status, 200);
        let doc = Json::parse(&s.body).unwrap();
        assert_eq!(doc.get("requests_done").as_i64(), Some(0));
        assert_eq!(doc.get("requests_rejected").as_i64(), Some(0));
        assert!(doc.get("batch_occupancy").as_f64().is_some());
        assert!(doc.get("itl_p99_ms").as_f64().is_some());
        assert_eq!(doc.get("default_model"), &Json::Null);
        // scheduler detail gauges are /metrics (full) only
        assert_eq!(doc.get("queue_wait_p99_ms"), &Json::Null);
        // speculation headline gauges are in /stats; counters /metrics-only
        assert!(doc.get("spec_acceptance_rate").as_f64().is_some());
        assert!(doc.get("tokens_per_step_effective").as_f64().is_some());
        assert_eq!(doc.get("spec_drafted"), &Json::Null);
        let m = route(&request("GET", "/metrics", ""), &reg);
        let mdoc = Json::parse(&m.body).unwrap();
        assert!(mdoc.get("queue_wait_p99_ms").as_f64().is_some());
        assert!(mdoc.get("prefill_tokens_per_step").as_f64().is_some());
        assert!(mdoc.get("sched_steps").as_i64().is_some());
        assert_eq!(mdoc.get("spec_drafted").as_i64(), Some(0));
        assert_eq!(mdoc.get("spec_rejected").as_i64(), Some(0));
        assert_eq!(mdoc.get("prefix_evictions").as_i64(), Some(0));
    }

    #[test]
    fn query_strings_route_and_trace_endpoints_respond() {
        let reg = ModelRegistry::new("no-such-dir");
        // query strings must not break path matching
        assert_eq!(route(&request("GET", "/stats?x=1", ""), &reg).status, 200);
        // empty fleet: /trace has no default model, postmortem list is empty
        assert_eq!(route(&request("GET", "/trace", ""), &reg).status, 404);
        let pm = route(&request("GET", "/trace/postmortem", ""), &reg);
        assert_eq!(pm.status, 200);
        let pmdoc = Json::parse(&pm.body).unwrap();
        assert_eq!(pmdoc.get("postmortems_total").as_i64(), Some(0));
        assert_eq!(route(&request("GET", "/trace/postmortem?model=nope", ""), &reg).status, 404);

        let spec = r#"{"name": "t1", "backend": "native", "batch": 2, "k_ratio": 0.5, "trace": "full"}"#;
        assert_eq!(route(&request("POST", "/models", spec), &reg).status, 200);
        assert_eq!(route(&request("GET", "/trace?model=nope", ""), &reg).status, 404);
        let t = route(&request("GET", "/trace?model=t1&n=8", ""), &reg);
        assert_eq!(t.status, 200);
        let tdoc = Json::parse(&t.body).unwrap();
        assert_eq!(tdoc.get("model").as_str(), Some("t1"));
        assert_eq!(tdoc.get("mode").as_str(), Some("full"));
        assert!(tdoc.get("events").as_arr().is_some());
        // jsonl variant is plain text, one event per line (possibly empty)
        assert_eq!(route(&request("GET", "/trace?model=t1&format=jsonl", ""), &reg).status, 200);
        reg.shutdown_all().unwrap();
    }

    #[test]
    fn generate_timings_are_opt_in() {
        let reg = ModelRegistry::new("no-such-dir");
        let spec = r#"{"name": "g1", "backend": "native", "batch": 2, "k_ratio": 0.5}"#;
        assert_eq!(route(&request("POST", "/models", spec), &reg).status, 200);

        let r = route(&request("POST", "/generate", r#"{"prompt": "hi", "max_new_tokens": 4}"#), &reg);
        assert_eq!(r.status, 200);
        let doc = Json::parse(&r.body).unwrap();
        assert_eq!(doc.get("timings"), &Json::Null, "timings must be opt-in");

        let r = route(
            &request(
                "POST",
                "/generate",
                r#"{"prompt": "hi", "max_new_tokens": 4, "timings": true}"#,
            ),
            &reg,
        );
        assert_eq!(r.status, 200);
        let doc = Json::parse(&r.body).unwrap();
        let t = doc.get("timings");
        let total = t.get("total_ms").as_f64().unwrap();
        let parts = t.get("queue_wait_ms").as_f64().unwrap()
            + t.get("prefill_ms").as_f64().unwrap()
            + t.get("decode_ms").as_f64().unwrap();
        assert!((parts - total).abs() <= 0.01 + total * 0.01, "spans must reconcile: {parts} vs {total}");
        assert!(t.get("ttft_ms").as_f64().unwrap() <= total + 1e-9);
        reg.shutdown_all().unwrap();
    }

    #[test]
    fn prometheus_exposition_round_trips() {
        assert_eq!(prometheus_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        let reg = ModelRegistry::new("no-such-dir");
        let spec = r#"{"name": "p1", "backend": "native", "batch": 2, "k_ratio": 0.5}"#;
        assert_eq!(route(&request("POST", "/models", spec), &reg).status, 200);
        let r = route(&request("GET", "/metrics?format=prometheus", ""), &reg);
        assert_eq!(r.status, 200);

        // round-trip parse of the exposition: every sample's metric must
        // have exactly one HELP + TYPE header emitted before it, every
        // value must parse as f64, labels must stay inside quotes.
        let mut helped = std::collections::BTreeSet::new();
        let mut typed = std::collections::BTreeSet::new();
        let mut sampled = std::collections::BTreeSet::new();
        for line in r.body.lines().filter(|l| !l.is_empty()) {
            if let Some(rest) = line.strip_prefix("# HELP ") {
                let name = rest.split(' ').next().unwrap().to_string();
                assert!(helped.insert(name), "duplicate HELP: {line}");
            } else if let Some(rest) = line.strip_prefix("# TYPE ") {
                let mut it = rest.split(' ');
                let name = it.next().unwrap().to_string();
                let kind = it.next().unwrap();
                assert!(kind == "counter" || kind == "gauge", "bad type: {line}");
                assert!(typed.insert(name), "duplicate TYPE: {line}");
            } else {
                let (series, value) = line.rsplit_once(' ').unwrap();
                value.parse::<f64>().unwrap_or_else(|_| panic!("bad value in: {line}"));
                let name = series.split('{').next().unwrap().to_string();
                assert!(helped.contains(&name), "sample before HELP: {line}");
                assert!(typed.contains(&name), "sample before TYPE: {line}");
                if let Some(labels) = series.strip_suffix('}').and_then(|s| s.split_once('{')) {
                    assert!(labels.1.starts_with("model=\""), "bad label set: {line}");
                }
                sampled.insert((series.to_string(), name));
            }
        }
        // fleet-level and per-model samples of the same series both exist
        assert!(sampled.contains(&("aqua_requests_done".into(), "aqua_requests_done".into())));
        assert!(sampled
            .contains(&("aqua_requests_done{model=\"p1\"}".into(), "aqua_requests_done".into())));
        assert!(helped.contains("aqua_ttft_p99_ms"));
        assert_eq!(helped, typed, "HELP and TYPE must pair up");
        reg.shutdown_all().unwrap();
    }

    #[test]
    fn add_model_validates_and_conflicts() {
        let reg = ModelRegistry::new("no-such-dir");
        let spec = r#"{"name": "m1", "backend": "native", "batch": 2, "k_ratio": 0.5}"#;
        assert_eq!(route(&request("POST", "/models", spec), &reg).status, 200);
        assert_eq!(route(&request("POST", "/models", spec), &reg).status, 409);
        assert_eq!(route(&request("POST", "/models", "{}"), &reg).status, 400);
        let bad = r#"{"name": "m2", "backend": "gpu"}"#;
        assert_eq!(route(&request("POST", "/models", bad), &reg).status, 400);
        let listed = route(&request("GET", "/models", ""), &reg);
        let doc = Json::parse(&listed.body).unwrap();
        assert_eq!(doc.get("default").as_str(), Some("m1"));
        assert_eq!(doc.get("models").as_arr().unwrap().len(), 1);
        assert_eq!(route(&request("DELETE", "/models/m1", ""), &reg).status, 200);
        reg.shutdown_all().unwrap();
    }
}
