//! Hand-rolled HTTP/1.1 subset: one request per connection (Connection:
//! close), request bodies via Content-Length. Enough for the JSON API and
//! for `curl`.

// Server code must never silently discard a Result — count it or log it.
#![deny(clippy::let_underscore_must_use)]

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

use anyhow::{bail, Result};

use crate::util::json::Json;

/// Largest request body the server will read. A client-supplied
/// Content-Length used to size the read buffer unchecked — a single
/// `Content-Length: 999999999999` allocated that many bytes before one
/// payload byte arrived. Anything above this cap is answered 413 without
/// allocating.
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// Typed parse failure for an over-cap Content-Length, so
/// [`handle_connection`] can answer 413 instead of dropping the
/// connection silently.
#[derive(Debug)]
pub struct BodyTooLarge(pub usize);

impl std::fmt::Display for BodyTooLarge {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "request body of {} bytes exceeds the {} byte cap", self.0, MAX_BODY_BYTES)
    }
}

impl std::error::Error for BodyTooLarge {}

#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: String,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: String,
}

impl Response {
    pub fn text(status: u16, body: &str) -> Response {
        Response { status, content_type: "text/plain", body: body.to_string() }
    }

    pub fn json(status: u16, body: &Json) -> Response {
        Response { status, content_type: "application/json", body: body.to_string() }
    }
}

fn status_line(code: u16) -> &'static str {
    match code {
        200 => "200 OK",
        400 => "400 Bad Request",
        404 => "404 Not Found",
        409 => "409 Conflict",
        413 => "413 Payload Too Large",
        429 => "429 Too Many Requests",
        500 => "500 Internal Server Error",
        503 => "503 Service Unavailable",
        504 => "504 Gateway Timeout",
        _ => "500 Internal Server Error",
    }
}

/// Parse one request from a reader.
pub fn parse_request<R: BufRead>(reader: &mut R) -> Result<Request> {
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let (method, path) = match (parts.next(), parts.next()) {
        (Some(m), Some(p)) => (m.to_string(), p.to_string()),
        _ => bail!("malformed request line: {line:?}"),
    };
    let mut headers = vec![];
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.push((k.trim().to_string(), v.trim().to_string()));
        }
    }
    let len: usize = headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
        .and_then(|(_, v)| v.parse().ok())
        .unwrap_or(0);
    if len > MAX_BODY_BYTES {
        return Err(anyhow::Error::new(BodyTooLarge(len)));
    }
    let mut body = vec![0u8; len];
    if len > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(Request { method, path, headers, body: String::from_utf8_lossy(&body).into_owned() })
}

/// Serialize a response.
pub fn write_response<W: Write>(w: &mut W, resp: &Response) -> Result<()> {
    write!(
        w,
        "HTTP/1.1 {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
        status_line(resp.status),
        resp.content_type,
        resp.body.len(),
        resp.body
    )?;
    Ok(())
}

/// Minimal client counterpart of this module's server subset: open a
/// connection, send one request, return `(status, body)`. Keeps the
/// examples and integration tests off hand-rolled copies (and curl).
pub fn client_request(
    addr: std::net::SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> Result<(u16, String)> {
    let mut s = TcpStream::connect(addr)?;
    s.set_read_timeout(Some(std::time::Duration::from_secs(150)))?;
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nHost: aqua\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )?;
    let mut buf = String::new();
    s.read_to_string(&mut buf)?;
    let status: u16 = match buf.split_whitespace().nth(1).and_then(|c| c.parse().ok()) {
        Some(c) => c,
        None => bail!("malformed response status line: {buf:?}"),
    };
    let body = buf.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    Ok((status, body))
}

/// Read one request off the stream, dispatch, write the response. The
/// handler also receives the connection so long-running routes can probe
/// for client disconnect (see the `/generate` cancellation path).
pub fn handle_connection<F>(stream: TcpStream, handler: F) -> Result<()>
where
    F: FnOnce(&Request, &TcpStream) -> Response,
{
    stream.set_read_timeout(Some(std::time::Duration::from_secs(30)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let req = match parse_request(&mut reader) {
        Ok(r) => r,
        Err(e) if e.downcast_ref::<BodyTooLarge>().is_some() => {
            // over-cap Content-Length: tell the client instead of
            // silently dropping the connection
            let mut stream = stream;
            write_response(&mut stream, &Response::text(413, &format!("{e:#}")))?;
            return Ok(());
        }
        Err(e) => return Err(e),
    };
    let resp = handler(&req, &stream);
    let mut stream = stream;
    write_response(&mut stream, &resp)?;
    Ok(())
}

/// Has the peer hung up? Non-destructive probe: a zero-byte `peek` in
/// non-blocking mode means orderly shutdown; `WouldBlock` means the
/// client is alive and quiet; hard errors (reset) also count as gone.
/// Pipelined extra bytes count as alive — only the response write will
/// sort those out.
pub fn client_gone(stream: &TcpStream) -> bool {
    if stream.set_nonblocking(true).is_err() {
        return true;
    }
    let mut probe = [0u8; 1];
    let gone = match stream.peek(&mut probe) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => false,
        Err(_) => true,
    };
    if stream.set_nonblocking(false).is_err() {
        return true;
    }
    gone
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_post_with_body() {
        let raw = "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: 14\r\n\r\n{\"prompt\":\"a\"}";
        let mut r = BufReader::new(Cursor::new(raw.as_bytes()));
        let req = parse_request(&mut r).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/generate");
        assert_eq!(req.body, "{\"prompt\":\"a\"}");
        assert_eq!(req.header("host"), Some("x"));
    }

    #[test]
    fn parses_get_without_body() {
        let raw = "GET /stats HTTP/1.1\r\n\r\n";
        let mut r = BufReader::new(Cursor::new(raw.as_bytes()));
        let req = parse_request(&mut r).unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
    }

    #[test]
    fn response_wire_format() {
        let mut out = vec![];
        write_response(&mut out, &Response::text(200, "hi")).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.ends_with("\r\n\r\nhi"));
        assert!(s.contains("Content-Length: 2"));
    }

    #[test]
    fn rejects_garbage() {
        let mut r = BufReader::new(Cursor::new(b"\r\n".as_slice()));
        assert!(parse_request(&mut r).is_err());
    }

    #[test]
    fn rejects_oversized_content_length_without_allocating() {
        // a body cap violation must be typed (handle_connection answers
        // 413 from it) and must fire before any payload is read
        let raw = format!(
            "POST /generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        );
        let mut r = BufReader::new(Cursor::new(raw.into_bytes()));
        let err = parse_request(&mut r).unwrap_err();
        let too_large = err.downcast_ref::<BodyTooLarge>().expect("typed BodyTooLarge");
        assert_eq!(too_large.0, MAX_BODY_BYTES + 1);
        // a body exactly at the cap parses (read stops at the bytes given)
        let raw = format!("POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}", 2, "ok");
        let mut r = BufReader::new(Cursor::new(raw.into_bytes()));
        assert_eq!(parse_request(&mut r).unwrap().body, "ok");
    }

    #[test]
    fn admission_status_lines() {
        assert_eq!(status_line(429), "429 Too Many Requests");
        assert_eq!(status_line(409), "409 Conflict");
        assert_eq!(status_line(503), "503 Service Unavailable");
        assert_eq!(status_line(999), "500 Internal Server Error");
    }

    #[test]
    fn parses_delete_with_path_segment() {
        let raw = "DELETE /models/fast HTTP/1.1\r\n\r\n";
        let mut r = BufReader::new(Cursor::new(raw.as_bytes()));
        let req = parse_request(&mut r).unwrap();
        assert_eq!(req.method, "DELETE");
        assert_eq!(req.path, "/models/fast");
    }
}
