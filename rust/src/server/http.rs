//! Hand-rolled HTTP/1.1 subset: one request per connection (Connection:
//! close), request bodies via Content-Length. Enough for the JSON API and
//! for `curl`.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

use anyhow::{bail, Result};

use crate::util::json::Json;

#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: String,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: String,
}

impl Response {
    pub fn text(status: u16, body: &str) -> Response {
        Response { status, content_type: "text/plain", body: body.to_string() }
    }

    pub fn json(status: u16, body: &Json) -> Response {
        Response { status, content_type: "application/json", body: body.to_string() }
    }
}

fn status_line(code: u16) -> &'static str {
    match code {
        200 => "200 OK",
        400 => "400 Bad Request",
        404 => "404 Not Found",
        409 => "409 Conflict",
        413 => "413 Payload Too Large",
        429 => "429 Too Many Requests",
        500 => "500 Internal Server Error",
        503 => "503 Service Unavailable",
        504 => "504 Gateway Timeout",
        _ => "500 Internal Server Error",
    }
}

/// Parse one request from a reader.
pub fn parse_request<R: BufRead>(reader: &mut R) -> Result<Request> {
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let (method, path) = match (parts.next(), parts.next()) {
        (Some(m), Some(p)) => (m.to_string(), p.to_string()),
        _ => bail!("malformed request line: {line:?}"),
    };
    let mut headers = vec![];
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.push((k.trim().to_string(), v.trim().to_string()));
        }
    }
    let len: usize = headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
        .and_then(|(_, v)| v.parse().ok())
        .unwrap_or(0);
    let mut body = vec![0u8; len];
    if len > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(Request { method, path, headers, body: String::from_utf8_lossy(&body).into_owned() })
}

/// Serialize a response.
pub fn write_response<W: Write>(w: &mut W, resp: &Response) -> Result<()> {
    write!(
        w,
        "HTTP/1.1 {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
        status_line(resp.status),
        resp.content_type,
        resp.body.len(),
        resp.body
    )?;
    Ok(())
}

/// Minimal client counterpart of this module's server subset: open a
/// connection, send one request, return `(status, body)`. Keeps the
/// examples and integration tests off hand-rolled copies (and curl).
pub fn client_request(
    addr: std::net::SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> Result<(u16, String)> {
    let mut s = TcpStream::connect(addr)?;
    s.set_read_timeout(Some(std::time::Duration::from_secs(150)))?;
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nHost: aqua\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )?;
    let mut buf = String::new();
    s.read_to_string(&mut buf)?;
    let status: u16 = match buf.split_whitespace().nth(1).and_then(|c| c.parse().ok()) {
        Some(c) => c,
        None => bail!("malformed response status line: {buf:?}"),
    };
    let body = buf.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    Ok((status, body))
}

/// Read one request off the stream, dispatch, write the response.
pub fn handle_connection<F>(stream: TcpStream, handler: F) -> Result<()>
where
    F: FnOnce(&Request) -> Response,
{
    stream.set_read_timeout(Some(std::time::Duration::from_secs(30)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let req = parse_request(&mut reader)?;
    let resp = handler(&req);
    let mut stream = stream;
    write_response(&mut stream, &resp)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_post_with_body() {
        let raw = "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: 14\r\n\r\n{\"prompt\":\"a\"}";
        let mut r = BufReader::new(Cursor::new(raw.as_bytes()));
        let req = parse_request(&mut r).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/generate");
        assert_eq!(req.body, "{\"prompt\":\"a\"}");
        assert_eq!(req.header("host"), Some("x"));
    }

    #[test]
    fn parses_get_without_body() {
        let raw = "GET /stats HTTP/1.1\r\n\r\n";
        let mut r = BufReader::new(Cursor::new(raw.as_bytes()));
        let req = parse_request(&mut r).unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
    }

    #[test]
    fn response_wire_format() {
        let mut out = vec![];
        write_response(&mut out, &Response::text(200, "hi")).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.ends_with("\r\n\r\nhi"));
        assert!(s.contains("Content-Length: 2"));
    }

    #[test]
    fn rejects_garbage() {
        let mut r = BufReader::new(Cursor::new(b"\r\n".as_slice()));
        assert!(parse_request(&mut r).is_err());
    }

    #[test]
    fn admission_status_lines() {
        assert_eq!(status_line(429), "429 Too Many Requests");
        assert_eq!(status_line(409), "409 Conflict");
        assert_eq!(status_line(503), "503 Service Unavailable");
        assert_eq!(status_line(999), "500 Internal Server Error");
    }

    #[test]
    fn parses_delete_with_path_segment() {
        let raw = "DELETE /models/fast HTTP/1.1\r\n\r\n";
        let mut r = BufReader::new(Cursor::new(raw.as_bytes()));
        let req = parse_request(&mut r).unwrap();
        assert_eq!(req.method, "DELETE");
        assert_eq!(req.path, "/models/fast");
    }
}
