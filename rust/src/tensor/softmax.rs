//! Numerically-stable softmax (used by the native kernels and the eval
//! harness's logprob scoring).

/// In-place stable softmax over a slice.
pub fn softmax_inplace(xs: &mut [f32]) {
    if xs.is_empty() {
        return;
    }
    let m = xs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    if sum > 0.0 {
        for x in xs.iter_mut() {
            *x /= sum;
        }
    }
}

/// log-softmax value at one index (stable), without materializing the
/// full distribution twice.
pub fn log_softmax_at(xs: &[f32], idx: usize) -> f32 {
    let m = xs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let lse = xs.iter().map(|&x| (x - m).exp()).sum::<f32>().ln() + m;
    xs[idx] - lse
}

/// Streaming (running-max) softmax state for the page-fused attention
/// path: fold one segment's maximum at a time, push exponent weights as
/// their rows stream by, and normalize once at the end — O(1) state
/// instead of a second O(S) pass over the scores.
///
/// The caller owns any accumulators that are relative to the running max
/// (the fused kernel's value accumulator): [`OnlineSoftmax::fold_max`]
/// returns the factor `alpha` they must be rescaled by when the max
/// advances. `denom` is rescaled internally.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OnlineSoftmax {
    /// Running maximum over everything folded so far (`-inf` while empty).
    pub m: f32,
    /// Exponent sum, always relative to the current `m`.
    pub denom: f32,
}

impl Default for OnlineSoftmax {
    fn default() -> OnlineSoftmax {
        OnlineSoftmax::new()
    }
}

impl OnlineSoftmax {
    pub fn new() -> OnlineSoftmax {
        OnlineSoftmax { m: f32::NEG_INFINITY, denom: 0.0 }
    }

    /// Fold one segment's maximum into the running max; returns the
    /// rescale factor `alpha` for caller-held accumulators.
    ///
    /// Fully-masked / zero-length segments (`chunk_max = -inf`, or NaN
    /// from a max over no rows) are identities: without the guard the
    /// very first masked segment would compute `exp(-inf - -inf)` = NaN
    /// and poison every later row.
    pub fn fold_max(&mut self, chunk_max: f32) -> f32 {
        if !(chunk_max > self.m) {
            return 1.0; // covers chunk_max <= m, -inf == -inf, and NaN
        }
        let alpha = (self.m - chunk_max).exp(); // m = -inf → alpha = 0, never NaN
        self.m = chunk_max;
        self.denom *= alpha;
        alpha
    }

    /// Accumulate one row's weight `exp(z - m)` into `denom` and return
    /// it. Masked rows (`z = -inf`) weigh 0; pushing into an empty
    /// accumulator (`m = -inf`, nothing folded yet) is a 0-weight no-op
    /// rather than NaN.
    pub fn push(&mut self, z: f32) -> f32 {
        if self.m == f32::NEG_INFINITY {
            return 0.0;
        }
        let e = (z - self.m).exp();
        self.denom += e;
        e
    }

    /// `1 / denom`, or `None` when nothing (or only fully-masked rows)
    /// was folded — callers skip normalization instead of dividing by 0.
    pub fn finish(&self) -> Option<f32> {
        if self.denom > 0.0 {
            Some(1.0 / self.denom)
        } else {
            None
        }
    }
}

/// Argmax index (first on ties).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::check;

    #[test]
    fn sums_to_one_and_is_shift_invariant() {
        check(
            "softmax-props",
            100,
            |g| {
                let n = 1 + g.rng.below(32);
                g.vec_f32(n, 3.0)
            },
            |v| {
                let mut a = v.clone();
                softmax_inplace(&mut a);
                let s: f32 = a.iter().sum();
                if (s - 1.0).abs() > 1e-4 {
                    return Err(format!("sum {s}"));
                }
                let mut b: Vec<f32> = v.iter().map(|x| x + 100.0).collect();
                softmax_inplace(&mut b);
                for (x, y) in a.iter().zip(&b) {
                    if (x - y).abs() > 1e-4 {
                        return Err("not shift invariant".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn handles_extremes() {
        let mut v = vec![-1e9f32, 0.0, -1e9];
        softmax_inplace(&mut v);
        assert!((v[1] - 1.0).abs() < 1e-5);
        let mut v = vec![1e4f32, 1e4];
        softmax_inplace(&mut v);
        assert!((v[0] - 0.5).abs() < 1e-5);
    }

    #[test]
    fn log_softmax_consistent() {
        let v = [1.0f32, 2.0, 3.0];
        let mut s = v.to_vec();
        softmax_inplace(&mut s);
        for i in 0..3 {
            assert!((log_softmax_at(&v, i) - s[i].ln()).abs() < 1e-5);
        }
    }

    #[test]
    fn argmax_first_on_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn online_softmax_matches_batch_softmax_over_random_chunks() {
        check(
            "online-softmax-props",
            100,
            |g| {
                let n = 1 + g.rng.below(48);
                (g.vec_f32(n, 4.0), 1 + g.rng.below(7))
            },
            |(v, chunk)| {
                // streaming pass: fold per-chunk maxima, push rows, keep a
                // scalar accumulator Σ e·x the way the fused kernel keeps
                // its value accumulator
                let mut osm = OnlineSoftmax::new();
                let mut acc = 0.0f64;
                for seg in v.chunks(*chunk) {
                    let cmax = seg.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                    let alpha = osm.fold_max(cmax);
                    acc *= alpha as f64;
                    for &z in seg {
                        let e = osm.push(z);
                        acc += e as f64 * z as f64;
                    }
                }
                let inv = osm.finish().ok_or("finish() empty on non-empty input")?;
                // reference: plain two-pass softmax
                let mut probs = v.clone();
                softmax_inplace(&mut probs);
                let want: f64 = probs.iter().zip(v).map(|(&p, &z)| p as f64 * z as f64).sum();
                let got = acc * inv as f64;
                if (got - want).abs() > 1e-4 * (1.0 + want.abs()) {
                    return Err(format!("Σp·z online {got} vs batch {want}"));
                }
                // per-row probabilities agree too
                let m = osm.m;
                for (&p, &z) in probs.iter().zip(v) {
                    let online = (z - m).exp() * inv;
                    if (online - p).abs() > 1e-5 {
                        return Err(format!("row prob {online} vs {p}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn online_softmax_survives_fully_masked_segments_without_nan() {
        // the bugfix this PR pins: an all-(-inf) (fully-masked / empty)
        // segment folded first, last, or in the middle must never produce
        // NaN in m, denom, alpha, or any later weight
        let mut osm = OnlineSoftmax::new();
        let a = osm.fold_max(f32::NEG_INFINITY); // empty segment first
        assert_eq!(a, 1.0);
        assert_eq!(osm.push(f32::NEG_INFINITY), 0.0, "masked row in empty state");
        assert!(osm.finish().is_none(), "nothing folded → no normalizer");

        let alpha = osm.fold_max(2.0);
        assert!(alpha.is_finite() && !osm.m.is_nan());
        let e = osm.push(2.0);
        assert!((e - 1.0).abs() < 1e-6);
        let a2 = osm.fold_max(f32::NEG_INFINITY); // masked segment in the middle
        assert_eq!(a2, 1.0);
        assert_eq!(osm.push(f32::NEG_INFINITY), 0.0, "masked row weighs zero");
        let a3 = osm.fold_max(f32::NAN); // max over zero rows can be NaN
        assert_eq!(a3, 1.0);
        assert!(!osm.m.is_nan() && !osm.denom.is_nan());
        let inv = osm.finish().unwrap();
        assert!((inv - 1.0).abs() < 1e-6, "one real row → prob 1");
    }

    #[test]
    fn online_softmax_all_masked_is_empty() {
        let mut osm = OnlineSoftmax::new();
        for _ in 0..4 {
            assert_eq!(osm.fold_max(f32::NEG_INFINITY), 1.0);
            assert_eq!(osm.push(f32::NEG_INFINITY), 0.0);
        }
        assert!(osm.finish().is_none());
        assert!(!osm.m.is_nan() && !osm.denom.is_nan());
    }
}
