//! Numerically-stable softmax (used by the native kernels and the eval
//! harness's logprob scoring).

/// In-place stable softmax over a slice.
pub fn softmax_inplace(xs: &mut [f32]) {
    if xs.is_empty() {
        return;
    }
    let m = xs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    if sum > 0.0 {
        for x in xs.iter_mut() {
            *x /= sum;
        }
    }
}

/// log-softmax value at one index (stable), without materializing the
/// full distribution twice.
pub fn log_softmax_at(xs: &[f32], idx: usize) -> f32 {
    let m = xs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let lse = xs.iter().map(|&x| (x - m).exp()).sum::<f32>().ln() + m;
    xs[idx] - lse
}

/// Argmax index (first on ties).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::check;

    #[test]
    fn sums_to_one_and_is_shift_invariant() {
        check(
            "softmax-props",
            100,
            |g| {
                let n = 1 + g.rng.below(32);
                g.vec_f32(n, 3.0)
            },
            |v| {
                let mut a = v.clone();
                softmax_inplace(&mut a);
                let s: f32 = a.iter().sum();
                if (s - 1.0).abs() > 1e-4 {
                    return Err(format!("sum {s}"));
                }
                let mut b: Vec<f32> = v.iter().map(|x| x + 100.0).collect();
                softmax_inplace(&mut b);
                for (x, y) in a.iter().zip(&b) {
                    if (x - y).abs() > 1e-4 {
                        return Err("not shift invariant".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn handles_extremes() {
        let mut v = vec![-1e9f32, 0.0, -1e9];
        softmax_inplace(&mut v);
        assert!((v[1] - 1.0).abs() < 1e-5);
        let mut v = vec![1e4f32, 1e4];
        softmax_inplace(&mut v);
        assert!((v[0] - 0.5).abs() < 1e-5);
    }

    #[test]
    fn log_softmax_consistent() {
        let v = [1.0f32, 2.0, 3.0];
        let mut s = v.to_vec();
        softmax_inplace(&mut s);
        for i in 0..3 {
            assert!((log_softmax_at(&v, i) - s[i].ln()).abs() < 1e-5);
        }
    }

    #[test]
    fn argmax_first_on_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }
}
