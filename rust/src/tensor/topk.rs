//! Top-k selection utilities (paper Algorithm 1 lines 4-6).
//!
//! `topk_indices_by_abs` is the O(d) average selection the paper's
//! complexity analysis assumes (Blum et al. select / introselect — rust's
//! `select_nth_unstable` is exactly that).

/// Write the indices of the k largest |x| entries into `idx` (ascending
/// index order), reusing the caller's allocation — the decode hot path
/// calls this once per query head per step, so the buffer is provided by
/// the backend's scratch rather than allocated here. O(d) average
/// introselect partition + an O(k log k) tidy of the winners (k ≤ d).
pub fn topk_indices_into(xs: &[f32], k: usize, idx: &mut Vec<usize>) {
    let d = xs.len();
    let k = k.min(d);
    idx.clear();
    if k == 0 {
        return;
    }
    idx.extend(0..d);
    if k == d {
        return;
    }
    // Partition so the k largest-|·| are in the first k slots: O(d) average.
    idx.select_nth_unstable_by(k - 1, |&a, &b| {
        xs[b].abs().partial_cmp(&xs[a].abs()).unwrap_or(std::cmp::Ordering::Equal)
    });
    idx.truncate(k);
    idx.sort_unstable();
}

/// Indices of the k largest |x| entries, ascending index order (allocating
/// wrapper over [`topk_indices_into`]).
pub fn topk_indices_by_abs(xs: &[f32], k: usize) -> Vec<usize> {
    let mut idx = Vec::with_capacity(xs.len());
    topk_indices_into(xs, k, &mut idx);
    idx
}

/// Binary keep-mask (1.0/0.0) from the same selection, written into a
/// caller-provided mask buffer (len d) with `idx` as selection scratch.
pub fn topk_mask_into(xs: &[f32], k: usize, idx: &mut Vec<usize>, mask: &mut [f32]) {
    topk_indices_into(xs, k, idx);
    mask[..xs.len()].fill(0.0);
    for &i in idx.iter() {
        mask[i] = 1.0;
    }
}

/// Binary keep-mask (1.0/0.0) from the same selection.
pub fn topk_mask_by_abs(xs: &[f32], k: usize) -> Vec<f32> {
    let mut m = vec![0.0f32; xs.len()];
    let mut idx = Vec::with_capacity(xs.len());
    topk_mask_into(xs, k, &mut idx, &mut m);
    m
}

/// The runtime-knob *threshold* formulation used by the lowered HLO
/// (`mask = |x| >= sorted|x|[d-k]`). Exposed so equivalence with the gather
/// formulation can be property-tested from rust too.
pub fn threshold_mask_by_abs(xs: &[f32], k: usize) -> Vec<f32> {
    let d = xs.len();
    if k >= d {
        return vec![1.0; d];
    }
    if k == 0 {
        return vec![0.0; d];
    }
    let mut mags: Vec<f32> = xs.iter().map(|x| x.abs()).collect();
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let thr = mags[d - k];
    xs.iter().map(|x| if x.abs() >= thr { 1.0 } else { 0.0 }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::testkit::check;

    #[test]
    fn selects_largest() {
        let xs = [0.1f32, -5.0, 3.0, -0.2, 4.0];
        assert_eq!(topk_indices_by_abs(&xs, 2), vec![1, 4]);
        assert_eq!(topk_indices_by_abs(&xs, 0), Vec::<usize>::new());
        assert_eq!(topk_indices_by_abs(&xs, 5), vec![0, 1, 2, 3, 4]);
        assert_eq!(topk_indices_by_abs(&xs, 99), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn into_variant_reuses_buffer_across_calls() {
        let mut idx = Vec::new();
        topk_indices_into(&[0.1f32, -5.0, 3.0, -0.2, 4.0], 2, &mut idx);
        assert_eq!(idx, vec![1, 4]);
        // a second call with a different k must fully overwrite the buffer
        topk_indices_into(&[9.0f32, 1.0, 2.0], 1, &mut idx);
        assert_eq!(idx, vec![0]);
        topk_indices_into(&[1.0f32], 0, &mut idx);
        assert!(idx.is_empty());
    }

    #[test]
    fn mask_matches_indices() {
        let xs = [0.5f32, 2.0, -1.5];
        assert_eq!(topk_mask_by_abs(&xs, 2), vec![0.0, 1.0, 1.0]);
    }

    #[test]
    fn prop_threshold_equals_gather_without_ties() {
        check(
            "threshold==gather",
            200,
            |g| {
                let d = 2 + g.rng.below(48);
                let k = 1 + g.rng.below(d);
                (g.vec_f32(d, 1.0), k)
            },
            |(xs, k)| {
                let a = topk_mask_by_abs(xs, *k);
                let b = threshold_mask_by_abs(xs, *k);
                if a == b {
                    Ok(())
                } else {
                    Err(format!("masks differ for k={k}: {a:?} vs {b:?}"))
                }
            },
        );
    }

    #[test]
    fn prop_mask_keeps_exactly_k() {
        check(
            "mask-popcount",
            200,
            |g| {
                let d = 1 + g.rng.below(64);
                let k = g.rng.below(d + 1);
                (g.vec_f32(d, 2.0), k)
            },
            |(xs, k)| {
                let kept = topk_mask_by_abs(xs, *k).iter().filter(|&&m| m > 0.5).count();
                if kept == *k {
                    Ok(())
                } else {
                    Err(format!("kept {kept} != k {k}"))
                }
            },
        );
    }

    #[test]
    fn prop_kept_energy_dominates() {
        // The kept-k subset must hold at least k/d of the total energy.
        check(
            "energy-dominance",
            100,
            |g| {
                let d = 4 + g.rng.below(60);
                let k = 1 + g.rng.below(d);
                (g.vec_f32(d, 1.0), k)
            },
            |(xs, k)| {
                let mask = topk_mask_by_abs(xs, *k);
                let kept: f32 = xs.iter().zip(&mask).map(|(x, m)| x * x * m).sum();
                let total: f32 = xs.iter().map(|x| x * x).sum();
                let frac = *k as f32 / xs.len() as f32;
                if kept + 1e-6 >= total * frac {
                    Ok(())
                } else {
                    Err(format!("kept energy {kept} < fair share {}", total * frac))
                }
            },
        );
        let _ = Rng::new(0); // silence unused import in some cfgs
    }
}
