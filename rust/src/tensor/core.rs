//! Minimal row-major f32 tensor. Only what the analyses and native kernels
//! need: views by row, matmul, transpose, norms, elementwise ops.

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn eye(n: usize) -> Self {
        let mut t = Self::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    pub fn from_rows(rows: &[Vec<f32>]) -> Result<Self> {
        let c = rows.first().map(|r| r.len()).unwrap_or(0);
        if rows.iter().any(|r| r.len() != c) {
            bail!("ragged rows");
        }
        Ok(Tensor {
            shape: vec![rows.len(), c],
            data: rows.iter().flatten().copied().collect(),
        })
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rows(&self) -> usize {
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        *self.shape.last().unwrap()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols() + j]
    }

    /// Matrix multiply (2-D only): [m,k]·[k,n] -> [m,n].
    /// ikj loop order with a row accumulator — the cache-friendly layout for
    /// row-major data.
    pub fn matmul(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape.len() != 2 || other.shape.len() != 2 || self.cols() != other.rows() {
            bail!("matmul shape mismatch {:?} x {:?}", self.shape, other.shape);
        }
        let (m, k, n) = (self.rows(), self.cols(), other.cols());
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = self.row(i);
            let orow = &mut out[i * n..(i + 1) * n];
            for (p, &a) in arow.iter().enumerate().take(k) {
                let brow = &other.data[p * n..(p + 1) * n];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        Tensor::new(&[m, n], out)
    }

    pub fn transpose2(&self) -> Result<Tensor> {
        if self.shape.len() != 2 {
            bail!("transpose2 wants 2-D");
        }
        let (m, n) = (self.rows(), self.cols());
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::new(&[n, m], out)
    }

    pub fn l2_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Dot product of two slices.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Euclidean norm of a slice.
pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Tensor::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        let i = Tensor::eye(2);
        assert_eq!(a.matmul(&i).unwrap(), a);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        let b = Tensor::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let t = a.transpose2().unwrap().transpose2().unwrap();
        assert_eq!(a, t);
    }

    #[test]
    fn shape_validation() {
        assert!(Tensor::new(&[2, 2], vec![1.0]).is_err());
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 3]);
        assert!(a.matmul(&b).is_err());
    }
}
