//! Numerical substrate: row-major f32 tensors + the linear algebra the
//! figure analyses need (notably a one-sided Jacobi SVD for the paper's
//! Fig. 2 "online same-matrix SVD" condition).

mod core;
pub mod softmax;
pub mod svd;
pub mod topk;

pub use core::{dot, norm, Tensor};
