//! One-sided Jacobi SVD.
//!
//! Needed for the Fig. 2 "online Same-Matrix SVD" condition (paper §6.2):
//! the ideal-but-impractical baseline computes the projection from the very
//! activation matrix under evaluation. We only need the right singular
//! vectors `V` (the principal directions), with columns ordered by
//! decreasing singular value — the same convention as the python
//! calibration path's `np.linalg.svd` (validated here by the Gram
//! reconstruction and dominant-axis tests below).
//!
//! One-sided Jacobi orthogonalizes the *columns* of A by right rotations:
//! A·J₁·J₂·… → A·V = U·Σ, so V is the accumulated rotation product. It is
//! numerically robust and simple; complexity O(m·n²) per sweep, fine for
//! the calibration-scale matrices (≤ a few thousand × d_head).

use super::Tensor;
use anyhow::Result;

/// Result of `svd_right`: right singular vectors (columns) + singular
/// values, ordered by decreasing σ.
pub struct SvdRight {
    /// [n, n]; column j is the j-th principal direction.
    pub v: Tensor,
    /// [n] decreasing.
    pub sigma: Vec<f32>,
}

/// Compute V and Σ of A = UΣVᵀ for a (tall) [m, n] matrix.
pub fn svd_right(a: &Tensor, max_sweeps: usize, tol: f32) -> Result<SvdRight> {
    let (m, n) = (a.rows(), a.cols());
    // Work on a column-major copy of A (columns contiguous) for cache-
    // friendly column rotations.
    let mut w: Vec<Vec<f32>> = (0..n)
        .map(|j| (0..m).map(|i| a.at2(i, j)).collect())
        .collect();
    let mut v = vec![vec![0.0f32; n]; n];
    for (j, col) in v.iter_mut().enumerate() {
        col[j] = 1.0;
    }

    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    let (x, y) = (w[p][i] as f64, w[q][i] as f64);
                    app += x * x;
                    aqq += y * y;
                    apq += x * y;
                }
                if apq.abs() <= tol as f64 * (app * aqq).sqrt() {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation zeroing the (p,q) entry of WᵀW.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                let (c, s) = (c as f32, s as f32);
                for i in 0..m {
                    let (x, y) = (w[p][i], w[q][i]);
                    w[p][i] = c * x - s * y;
                    w[q][i] = s * x + c * y;
                }
                for vrow in v.iter_mut() {
                    let (x, y) = (vrow[p], vrow[q]);
                    vrow[p] = c * x - s * y;
                    vrow[q] = s * x + c * y;
                }
            }
        }
        if off == 0.0 {
            break;
        }
    }

    // Singular values = column norms; sort columns by decreasing σ.
    let mut sig: Vec<(f32, usize)> = (0..n)
        .map(|j| (w[j].iter().map(|x| x * x).sum::<f32>().sqrt(), j))
        .collect();
    sig.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    let mut vt = Tensor::zeros(&[n, n]);
    for (newj, &(_, oldj)) in sig.iter().enumerate() {
        for i in 0..n {
            vt.data_mut()[i * n + newj] = v[i][oldj];
        }
    }
    Ok(SvdRight { v: vt, sigma: sig.into_iter().map(|(s, _)| s).collect() })
}

/// Convenience: principal-direction projection matrix P (= V) from a data
/// matrix, as used by the paper's offline calibration.
pub fn projection_from_data(data: &Tensor) -> Result<Tensor> {
    Ok(svd_right(data, 30, 1e-10)?.v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn random_matrix(rng: &mut Rng, m: usize, n: usize) -> Tensor {
        Tensor::new(&[m, n], rng.normal_vec(m * n, 1.0)).unwrap()
    }

    fn assert_orthogonal(v: &Tensor, tol: f32) {
        let vtv = v.transpose2().unwrap().matmul(v).unwrap();
        let err = vtv.max_abs_diff(&Tensor::eye(v.rows()));
        assert!(err < tol, "VᵀV deviates from I by {err}");
    }

    #[test]
    fn v_is_orthogonal() {
        let mut rng = Rng::new(5);
        let a = random_matrix(&mut rng, 64, 8);
        let s = svd_right(&a, 30, 1e-10).unwrap();
        assert_orthogonal(&s.v, 1e-4);
    }

    #[test]
    fn sigma_decreasing_and_reconstructs_gram() {
        let mut rng = Rng::new(6);
        let a = random_matrix(&mut rng, 100, 6);
        let s = svd_right(&a, 30, 1e-10).unwrap();
        for w in s.sigma.windows(2) {
            assert!(w[0] >= w[1] - 1e-4);
        }
        // AᵀA = V Σ² Vᵀ
        let ata = a.transpose2().unwrap().matmul(&a).unwrap();
        let mut sig2 = Tensor::zeros(&[6, 6]);
        for i in 0..6 {
            sig2.data_mut()[i * 6 + i] = s.sigma[i] * s.sigma[i];
        }
        let rec = s.v.matmul(&sig2).unwrap().matmul(&s.v.transpose2().unwrap()).unwrap();
        let rel = rec.max_abs_diff(&ata) / ata.l2_norm();
        assert!(rel < 1e-4, "gram reconstruction error {rel}");
    }

    #[test]
    fn first_direction_captures_dominant_axis() {
        // Data concentrated along a known direction -> v₀ ≈ ±that direction.
        let mut rng = Rng::new(7);
        let dir = [0.6f32, 0.8, 0.0, 0.0];
        let rows: Vec<Vec<f32>> = (0..200)
            .map(|_| {
                let a = rng.normal() as f32 * 5.0;
                let noise: Vec<f32> = rng.normal_vec(4, 0.05);
                (0..4).map(|j| a * dir[j] + noise[j]).collect()
            })
            .collect();
        let a = Tensor::from_rows(&rows).unwrap();
        let s = svd_right(&a, 30, 1e-10).unwrap();
        let v0: Vec<f32> = (0..4).map(|i| s.v.at2(i, 0)).collect();
        let cos = super::super::core::dot(&v0, &dir).abs();
        assert!(cos > 0.99, "cos = {cos}");
    }
}
