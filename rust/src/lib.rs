//! # aqua-serve — AQUA attention serving stack (paper reproduction)
//!
//! Layer-3 of the three-layer reproduction of *AQUA: Attention via QUery
//! mAgnitudes for Memory and Compute Efficient Inference in LLMs*.
//!
//! The rust side owns the entire request path: request admission,
//! continuous batching, prefill/decode scheduling, the KV-slot manager with
//! the H2O heavy-hitter eviction policy, sampling, metrics, and a
//! **pluggable execution backend** behind `runtime::backend::ExecBackend`.
//! The default backend is a hermetic pure-rust transformer (no artifacts,
//! no network — the whole serving path is testable offline); the AOT-
//! compiled JAX/Pallas PJRT path ships behind the `pjrt` feature, with
//! python as build-time only (`make artifacts`).
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! * [`util`] — JSON, PRNG, logging, small substrates (the offline build
//!   uses only the in-tree `vendor/` path dependencies).
//! * [`tensor`] — row-major f32 tensors, one-sided Jacobi SVD, top-k,
//!   softmax: the numerical substrate for the figure analyses and the
//!   native kernels.
//! * [`tokenizer`] — byte-level tokenizer.
//! * [`runtime`] — the `ExecBackend` trait + backend selection
//!   (`BackendSpec`), the hermetic native backend, the artifact manifest,
//!   and (behind `pjrt`) the PJRT client and executable registry.
//! * [`model`] — model configs (incl. the native backend's tiny preset),
//!   sampling.
//! * [`aqua`] — the paper's algorithm in native rust: policy knobs +
//!   cost model (§5), sparse/dense score kernels, information-retention
//!   loss (§6.2), magnitude/PCA overlap (§7, Fig. 5).
//! * [`kvpool`] — paged KV-memory pool: block/page allocator with free
//!   lists, lane page tables, AQUA-truncated resident keys (the memory
//!   half of the paper's claim made real — see its module docs).
//! * [`coordinator`] — engine (backend-generic), scheduler, batcher,
//!   KV cache, H2O.
//! * [`registry`] — multi-model fleet: named deployments (engine thread +
//!   result pump + bounded admission) behind one mutable registry.
//! * [`server`] — minimal HTTP/1.1 front-end, routing over the registry.
//! * [`spec`] — self-speculative decoding: per-lane draft bookkeeping for
//!   the AQUA-sparse draft / dense verify duty cycle (one shared KV cache,
//!   no second model).
//! * [`trace`] — per-engine flight recorder: compact event ring, request
//!   span timelines, postmortem dumps on lane/engine failure.
//! * [`eval`] — perplexity + SynthBench harness (the paper's tables).
//! * [`bench`] — criterion-lite measurement harness.

// Kernel-style modules index several parallel buffers per loop; the
// iterator rewrites clippy suggests there hurt readability without
// changing codegen.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

pub mod aqua;
pub mod bench;
pub mod coordinator;
pub mod eval;
pub mod kvpool;
pub mod model;
pub mod registry;
pub mod runtime;
pub mod server;
pub mod spec;
pub mod tensor;
pub mod tokenizer;
pub mod trace;
pub mod util;

pub use anyhow::{anyhow, bail, Context, Result};

/// Default artifacts directory (relative to the repo root / CWD).
pub const ARTIFACTS_DIR: &str = "artifacts";
