//! Multi-threaded sharded execution backend.
//!
//! [`ShardedBackend`] splits the batch's lanes — and with them the KV-cache
//! shards those lanes own — across persistent worker threads, each running
//! the hermetic [`NativeBackend`] forward pass on its shard. Lanes never
//! interact inside a step (attention is per-lane over per-lane caches), so
//! the shard decomposition is exact: the assembled output is **bit-identical**
//! to a single `NativeBackend` over the full batch, for every score mode
//! and knob setting (property-tested in `tests/decode_parity.rs`).
//!
//! The model weights are shared (`Arc<NativeModel>`); only the per-lane KV
//! tensors and scratch are per-worker, so memory overhead is the KV split
//! plus one scratch set per thread. Workers are spawned once at
//! construction and fed through channels; a step scatters the per-lane
//! inputs, runs all shards concurrently, and gathers `StepOut` slices back
//! into engine order. Layer-pipelined sharding (splitting *layers* across
//! threads, overlapping microbatches) is the complementary strategy for
//! single-lane latency and is left to a future PR — lane sharding is the
//! one that pays off on batched decode throughput, which is what the
//! serving stack optimizes for (see `BENCHES.md`).

use std::ops::Range;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Result};

use super::backend::{AquaKnobs, ExecBackend, KernelCounters, PrefixAttach, StepOut};
use super::native::{NativeBackend, NativeModel, ScoreMode};
use crate::kvpool::{KvPoolConfig, KvPoolGauges};
use crate::model::config::ModelConfig;

/// Which forward-pass entry point a `Cmd::Run` scatters to the shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum StepOp {
    Prefill,
    Decode,
    /// Multi-position speculative verify: `t` window tokens per lane,
    /// rewriting drafted KV exactly (see [`ExecBackend::verify`]).
    Verify,
}

/// One step's inputs, copied once and shared (`Arc`) by every worker —
/// each worker slices out its own lane range, so scatter cost does not
/// scale with the thread count.
struct StepInputs {
    op: StepOp,
    /// Tokens per lane (1 for decode, prefill chunk for prefill, the
    /// verify window width for verify).
    t: usize,
    s_cap: usize,
    tokens: Vec<i32>,
    pos: Vec<i32>,
    slot_mask: Vec<f32>,
    knobs: AquaKnobs,
}

enum Cmd {
    EmptyCache(usize),
    SetScoreMode(ScoreMode),
    /// Forwarded pool shape (applied at the worker's next EmptyCache).
    ConfigureKvPool(KvPoolConfig),
    /// Free one worker-local lane's pages (fire-and-forget, like
    /// SetScoreMode — the ordered channel serializes it against steps).
    RetireLane(usize),
    /// Un-append one worker-local lane's KV past `to_len` (speculative
    /// rollback; fire-and-forget, serialized by the ordered channel).
    RollbackLane { lane: usize, to_len: usize },
    /// Prefix-cache attach for one worker-local lane; replies on its own
    /// channel so the Run gather never sees a stray message. Sharing is
    /// per worker sub-pool: lanes on the same shard share pages, a prefix
    /// resident only on another shard falls back to a fresh prefill
    /// (copy) — cross-shard attach would mean cross-thread page traffic.
    AttachPrefix {
        lane: usize,
        tokens: Vec<i32>,
        knobs: AquaKnobs,
        reply: mpsc::Sender<Result<PrefixAttach>>,
    },
    /// Point-in-time pool gauges (own reply channel, same reasoning).
    Gauges(mpsc::Sender<KvPoolGauges>),
    Run { inputs: Arc<StepInputs>, lanes: Range<usize> },
    Shutdown,
}

struct Worker {
    tx: mpsc::Sender<Cmd>,
    rx: mpsc::Receiver<Result<StepOut>>,
    join: Option<JoinHandle<()>>,
}

fn spawn_worker(model: Arc<NativeModel>) -> Worker {
    let (tx, cmd_rx) = mpsc::channel::<Cmd>();
    let (res_tx, rx) = mpsc::channel::<Result<StepOut>>();
    let join = std::thread::spawn(move || {
        let mut be = NativeBackend::from_model(model);
        while let Ok(cmd) = cmd_rx.recv() {
            let resp = match cmd {
                Cmd::EmptyCache(b) => be.empty_cache(b).map(|_| StepOut::default()),
                Cmd::SetScoreMode(mode) => {
                    be.set_score_mode(mode);
                    continue;
                }
                Cmd::ConfigureKvPool(cfg) => {
                    let _ = be.configure_kv_pool(cfg);
                    continue;
                }
                Cmd::RetireLane(lane) => {
                    be.retire_lane(lane);
                    continue;
                }
                Cmd::RollbackLane { lane, to_len } => {
                    be.rollback_lane(lane, to_len);
                    continue;
                }
                Cmd::AttachPrefix { lane, tokens, knobs, reply } => {
                    let _ = reply.send(be.attach_prefix(lane, &tokens, &knobs));
                    continue;
                }
                Cmd::Gauges(reply) => {
                    let _ = reply.send(be.kv_gauges());
                    continue;
                }
                Cmd::Run { inputs, lanes } => {
                    let (bw, t, s_cap) = (lanes.len(), inputs.t, inputs.s_cap);
                    let toks = &inputs.tokens[lanes.start * t..lanes.end * t];
                    let pos = &inputs.pos[lanes.start..lanes.end];
                    let mask = &inputs.slot_mask[lanes.start * s_cap..lanes.end * s_cap];
                    match inputs.op {
                        StepOp::Decode => be.decode(bw, toks, pos, mask, &inputs.knobs),
                        StepOp::Prefill => be.prefill(bw, toks, pos, mask, &inputs.knobs),
                        StepOp::Verify => be.verify(bw, toks, pos, t, mask, &inputs.knobs),
                    }
                }
                Cmd::Shutdown => return,
            };
            if res_tx.send(resp).is_err() {
                return;
            }
        }
    });
    Worker { tx, rx, join: Some(join) }
}

/// Contiguous lane ranges, sizes differing by at most one.
fn split_lanes(b: usize, n: usize) -> Vec<Range<usize>> {
    let n = n.max(1);
    let (base, rem) = (b / n, b % n);
    let mut shards = Vec::with_capacity(n);
    let mut start = 0;
    for w in 0..n {
        let len = base + usize::from(w < rem);
        shards.push(start..start + len);
        start += len;
    }
    shards
}

/// Lane-sharded multi-threaded [`ExecBackend`] over the native model (see
/// module docs). Selected via `--backend sharded --threads N`.
pub struct ShardedBackend {
    model: Arc<NativeModel>,
    workers: Vec<Worker>,
    /// Lane range per worker for the current batch (empty range = idle).
    shards: Vec<Range<usize>>,
    batch: usize,
    prefill_chunk: usize,
}

impl ShardedBackend {
    pub fn new(cfg: ModelConfig, seed: u64, threads: usize) -> Result<ShardedBackend> {
        Ok(Self::from_model(Arc::new(NativeModel::new(cfg, seed)?), threads))
    }

    pub fn from_model(model: Arc<NativeModel>, threads: usize) -> ShardedBackend {
        let threads = threads.clamp(1, 64);
        let workers = (0..threads).map(|_| spawn_worker(model.clone())).collect();
        let chunk = super::native::NATIVE_PREFILL_CHUNK.clamp(1, model.cfg.max_seq);
        ShardedBackend { model, workers, shards: vec![], batch: 0, prefill_chunk: chunk }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    pub fn model(&self) -> &NativeModel {
        &self.model
    }

    /// Forward the score-kernel routing policy to every worker (takes
    /// effect from the next step; the channel is ordered).
    pub fn set_score_mode(&mut self, mode: ScoreMode) -> Result<()> {
        for w in &self.workers {
            w.tx.send(Cmd::SetScoreMode(mode)).map_err(|_| anyhow!("sharded worker died"))?;
        }
        Ok(())
    }

    /// Scatter one step across the shards, run concurrently, gather the
    /// outputs back into engine lane order. `t` is the window width per
    /// lane (1 for decode, the prefill chunk for prefill, the caller's
    /// width for verify).
    fn run(
        &mut self,
        op: StepOp,
        b: usize,
        tokens: &[i32],
        pos: &[i32],
        t: usize,
        slot_mask: &[f32],
        knobs: &AquaKnobs,
    ) -> Result<StepOut> {
        let c = &self.model.cfg;
        let (s_cap, vocab, n_layers) = (c.max_seq, c.vocab, c.n_layers);
        if b != self.batch {
            bail!("sharded step: batch {b} but shards sized for {} (call empty_cache)", self.batch);
        }
        if t == 0 || tokens.len() != b * t || pos.len() != b || slot_mask.len() != b * s_cap {
            bail!("sharded step: arg shape mismatch (b={b}, t={t})");
        }

        let inputs = Arc::new(StepInputs {
            op,
            t,
            s_cap,
            tokens: tokens.to_vec(),
            pos: pos.to_vec(),
            slot_mask: slot_mask.to_vec(),
            knobs: knobs.clone(),
        });
        // A failed send means that worker is dead (its result channel is
        // dropped, so the gather below errors fast instead of blocking);
        // keep scattering so live workers stay in step.
        for (w, shard) in self.workers.iter().zip(&self.shards) {
            if shard.is_empty() {
                continue;
            }
            let cmd = Cmd::Run { inputs: inputs.clone(), lanes: shard.start..shard.end };
            let _ = w.tx.send(cmd);
        }

        let mut logits = vec![0.0f32; b * t * vocab];
        let mut attn_acc = vec![0.0f32; n_layers * b * s_cap];
        let mut kernels = KernelCounters::default();
        let mut kv = KvPoolGauges::default();
        // Drain every dispatched shard even after a failure — an early
        // return would leave the remaining StepOuts queued and pair them
        // with the *next* call's gather (silent step desync).
        let mut first_err: Option<anyhow::Error> = None;
        for (w, shard) in self.workers.iter().zip(&self.shards) {
            if shard.is_empty() {
                continue;
            }
            let out = match w.rx.recv() {
                Err(_) => {
                    first_err.get_or_insert_with(|| anyhow!("sharded worker died"));
                    continue;
                }
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                    continue;
                }
                Ok(Ok(out)) => out,
            };
            let bw = shard.len();
            if out.logits.len() != bw * t * vocab || out.attn_acc.len() != n_layers * bw * s_cap {
                let e = anyhow!("sharded step: worker output shape mismatch");
                first_err.get_or_insert(e);
                continue;
            }
            // Lanes are contiguous per shard, so logits rows concatenate.
            logits[shard.start * t * vocab..shard.end * t * vocab].copy_from_slice(&out.logits);
            // attn_acc is [L, B, S]: interleave per layer.
            for l in 0..n_layers {
                let src = &out.attn_acc[l * bw * s_cap..(l + 1) * bw * s_cap];
                let dst = (l * b + shard.start) * s_cap;
                attn_acc[dst..dst + bw * s_cap].copy_from_slice(src);
            }
            kernels.merge(&out.kernels);
            // each worker owns an independent sub-pool over its lanes;
            // the batch's resident bytes are the sum
            kv.merge(&out.kv);
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        Ok(StepOut { logits, attn_acc, kernels, kv })
    }
}

impl ExecBackend for ShardedBackend {
    fn name(&self) -> &'static str {
        "sharded"
    }

    fn model_config(&self) -> &ModelConfig {
        &self.model.cfg
    }

    fn prefill_chunk(&self) -> usize {
        self.prefill_chunk
    }

    fn empty_cache(&mut self, b: usize) -> Result<()> {
        if b == 0 {
            bail!("sharded empty_cache: batch must be >= 1");
        }
        self.shards = split_lanes(b, self.workers.len());
        self.batch = b;
        // as in `run`: a failed send = dead worker, surfaced by the drain
        for (w, shard) in self.workers.iter().zip(&self.shards) {
            if shard.is_empty() {
                continue;
            }
            let _ = w.tx.send(Cmd::EmptyCache(shard.len()));
        }
        // drain every ack before surfacing an error (same reasoning as in
        // `run`: a leftover ack would desync the next gather)
        let mut first_err: Option<anyhow::Error> = None;
        for (w, shard) in self.workers.iter().zip(&self.shards) {
            if shard.is_empty() {
                continue;
            }
            match w.rx.recv() {
                Err(_) => {
                    first_err.get_or_insert_with(|| anyhow!("sharded worker died"));
                }
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Ok(Ok(_)) => {}
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    fn configure_kv_pool(&mut self, cfg: KvPoolConfig) -> Result<()> {
        // Every worker gets the same shape; a pinned `max_pages` budget
        // acts per worker as a backstop only — the *global* bound is
        // enforced by the engine's memory-aware admission (and, in the
        // registry, by the deployment's reservation gate), which defers
        // requests whose worst-case growth doesn't fit. A proportional
        // per-worker split would be unsafe: lane→worker assignment is
        // static, so a globally-fitting reservation could still overflow
        // one worker's share mid-decode.
        for w in &self.workers {
            w.tx.send(Cmd::ConfigureKvPool(cfg)).map_err(|_| anyhow!("sharded worker died"))?;
        }
        Ok(())
    }

    fn retire_lane(&mut self, lane: usize) {
        // Map the engine lane onto its shard's worker-local index.
        for (w, shard) in self.workers.iter().zip(&self.shards) {
            if shard.contains(&lane) {
                let _ = w.tx.send(Cmd::RetireLane(lane - shard.start));
                return;
            }
        }
    }

    fn attach_prefix(
        &mut self,
        lane: usize,
        tokens: &[i32],
        knobs: &AquaKnobs,
    ) -> Result<PrefixAttach> {
        for (w, shard) in self.workers.iter().zip(&self.shards) {
            if shard.contains(&lane) {
                let (reply, rx) = mpsc::channel();
                let cmd = Cmd::AttachPrefix {
                    lane: lane - shard.start,
                    tokens: tokens.to_vec(),
                    knobs: knobs.clone(),
                    reply,
                };
                w.tx.send(cmd).map_err(|_| anyhow!("sharded worker died"))?;
                return rx.recv().map_err(|_| anyhow!("sharded worker died"))?;
            }
        }
        Ok(PrefixAttach::default())
    }

    fn kv_gauges(&mut self) -> KvPoolGauges {
        // one ask per live shard, gathered after all sends (workers run
        // concurrently); a dead worker just drops out of the sum
        let mut pending = vec![];
        for (w, shard) in self.workers.iter().zip(&self.shards) {
            if shard.is_empty() {
                continue;
            }
            let (reply, rx) = mpsc::channel();
            if w.tx.send(Cmd::Gauges(reply)).is_ok() {
                pending.push(rx);
            }
        }
        let mut total = KvPoolGauges::default();
        for rx in pending {
            if let Ok(g) = rx.recv() {
                total.merge(&g);
            }
        }
        total
    }

    fn prefill(
        &mut self,
        b: usize,
        tokens: &[i32],
        pos0: &[i32],
        slot_mask: &[f32],
        knobs: &AquaKnobs,
    ) -> Result<StepOut> {
        self.run(StepOp::Prefill, b, tokens, pos0, self.prefill_chunk, slot_mask, knobs)
    }

    fn decode(
        &mut self,
        b: usize,
        tokens: &[i32],
        pos: &[i32],
        slot_mask: &[f32],
        knobs: &AquaKnobs,
    ) -> Result<StepOut> {
        self.run(StepOp::Decode, b, tokens, pos, 1, slot_mask, knobs)
    }

    fn verify(
        &mut self,
        b: usize,
        tokens: &[i32],
        pos0: &[i32],
        t: usize,
        slot_mask: &[f32],
        knobs: &AquaKnobs,
    ) -> Result<StepOut> {
        // Lanes never interact inside a step, so verify shards exactly
        // like decode — the gather already handles arbitrary `t` (logits
        // rows concatenate per shard).
        self.run(StepOp::Verify, b, tokens, pos0, t, slot_mask, knobs)
    }

    fn supports_verify(&self) -> bool {
        true
    }

    fn rollback_lane(&mut self, lane: usize, to_len: usize) {
        for (w, shard) in self.workers.iter().zip(&self.shards) {
            if shard.contains(&lane) {
                let _ = w.tx.send(Cmd::RollbackLane { lane: lane - shard.start, to_len });
                return;
            }
        }
    }
}

impl Drop for ShardedBackend {
    fn drop(&mut self) {
        for w in &mut self.workers {
            let _ = w.tx.send(Cmd::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(join) = w.join.take() {
                let _ = join.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelConfig {
        ModelConfig::tiny("sharded-test")
    }

    #[test]
    fn lane_split_covers_batch_evenly() {
        assert_eq!(split_lanes(8, 4), vec![0..2, 2..4, 4..6, 6..8]);
        assert_eq!(split_lanes(5, 2), vec![0..3, 3..5]);
        assert_eq!(split_lanes(2, 4), vec![0..1, 1..2, 2..2, 2..2]);
        assert_eq!(split_lanes(3, 1), vec![0..3]);
    }

    #[test]
    fn matches_native_backend_exactly() {
        let cfg = tiny();
        let d = cfg.d_head;
        let model = Arc::new(NativeModel::new(cfg.clone(), 13).unwrap());
        let knobs = AquaKnobs { k_dims: d / 2, dim_keep: vec![1.0; d], use_projection: true };
        let b = 5;

        let mut native = NativeBackend::from_model(model.clone());
        native.empty_cache(b).unwrap();
        let mut sharded = ShardedBackend::from_model(model, 3);
        sharded.empty_cache(b).unwrap();

        let mut mask = vec![0.0f32; b * cfg.max_seq];
        for i in 0..6usize {
            let tokens: Vec<i32> = (0..b).map(|lane| 40 + (lane + i) as i32).collect();
            let pos = vec![i as i32; b];
            let a = native.decode(b, &tokens, &pos, &mask, &knobs).unwrap();
            let s = sharded.decode(b, &tokens, &pos, &mask, &knobs).unwrap();
            assert_eq!(a.logits, s.logits, "logits diverged at step {i}");
            assert_eq!(a.attn_acc, s.attn_acc, "attn mass diverged at step {i}");
            assert_eq!(a.kernels.calls(), s.kernels.calls());
            for lane in 0..b {
                mask[lane * cfg.max_seq + i] = 1.0;
            }
        }
    }

    #[test]
    fn verify_matches_native_backend_exactly() {
        let cfg = tiny();
        let d = cfg.d_head;
        let model = Arc::new(NativeModel::new(cfg.clone(), 17).unwrap());
        let knobs = AquaKnobs { k_dims: d, dim_keep: vec![1.0; d], use_projection: true };
        let b = 3;

        let mut native = NativeBackend::from_model(model.clone());
        native.empty_cache(b).unwrap();
        let mut sharded = ShardedBackend::from_model(model, 2);
        sharded.empty_cache(b).unwrap();

        // two decode steps of shared context
        let mut mask = vec![0.0f32; b * cfg.max_seq];
        for i in 0..2usize {
            let tokens: Vec<i32> = (0..b).map(|lane| 30 + (lane + i) as i32).collect();
            let pos = vec![i as i32; b];
            native.decode(b, &tokens, &pos, &mask, &knobs).unwrap();
            sharded.decode(b, &tokens, &pos, &mask, &knobs).unwrap();
            for lane in 0..b {
                mask[lane * cfg.max_seq + i] = 1.0;
            }
        }
        // a width-3 verify window (-1 pads a ragged lane)
        let t = 3usize;
        let tokens: Vec<i32> =
            vec![50, 51, 52, /* lane 1 */ 60, 61, -1, /* lane 2 */ 70, 71, 72];
        let pos = vec![2i32; b];
        let a = native.verify(b, &tokens, &pos, t, &mask, &knobs).unwrap();
        let s = sharded.verify(b, &tokens, &pos, t, &mask, &knobs).unwrap();
        assert_eq!(a.logits.len(), b * t * cfg.vocab);
        assert_eq!(a.logits, s.logits, "verify logits diverged");
        assert_eq!(a.attn_acc, s.attn_acc, "verify attn mass diverged");

        // rollback keeps both backends in lockstep for the next decode
        for lane in 0..b {
            native.rollback_lane(lane, 3);
            sharded.rollback_lane(lane, 3);
            mask[lane * cfg.max_seq + 2] = 1.0;
        }
        let tokens: Vec<i32> = (0..b).map(|lane| 80 + lane as i32).collect();
        let pos = vec![3i32; b];
        let a = native.decode(b, &tokens, &pos, &mask, &knobs).unwrap();
        let s = sharded.decode(b, &tokens, &pos, &mask, &knobs).unwrap();
        assert_eq!(a.logits, s.logits, "post-rollback decode diverged");
    }

    #[test]
    fn more_threads_than_lanes_is_fine() {
        let cfg = tiny();
        let d = cfg.d_head;
        let mut be = ShardedBackend::new(cfg.clone(), 7, 8).unwrap();
        be.empty_cache(2).unwrap();
        let mask = vec![0.0f32; 2 * cfg.max_seq];
        let out = be.decode(2, &[65, 66], &[0, 0], &mask, &AquaKnobs::exact(d)).unwrap();
        assert_eq!(out.logits.len(), 2 * cfg.vocab);
        assert!(out.logits.iter().all(|x| x.is_finite()));
        assert!(out.kernels.calls() > 0);
    }
}
