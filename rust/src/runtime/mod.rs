//! Execution runtimes behind the [`backend::ExecBackend`] trait.
//!
//! * [`backend`] — the pluggable-backend contract the engine consumes
//!   (empty_cache / prefill / decode with AQUA knob inputs), plus the
//!   [`backend::BackendSpec`] selection surface and the PJRT adapter.
//! * [`native`] — hermetic pure-rust reference backend (default): a tiny
//!   deterministic transformer on `tensor::core` + `aqua::native`, real KV
//!   tensors owned in rust (dim-major packed key cache; see its docs).
//!   Makes the full serving path testable offline.
//! * [`sharded`] — multi-threaded lane-sharded backend over the native
//!   model: the batch's lanes and their KV shards split across persistent
//!   worker threads, bit-identical to [`native`].
//! * [`fault`] — deterministic fault-injection wrapper over any inner
//!   backend (scripted step errors / panics / latency spikes), the chaos
//!   hook behind `--backend fault:<inner>,...`.
//! * [`artifacts`] — manifest.json parsing, model/corpus/task locations
//!   (feature-independent: the eval harness reads tasks from here).
//! * [`exec`] (`--features pjrt`) — PJRT client, HLO-text → compiled
//!   executable registry, typed decode/prefill call wrappers.

pub mod artifacts;
pub mod backend;
pub mod fault;
pub mod native;
pub mod sharded;

#[cfg(feature = "pjrt")]
pub mod exec;

pub use artifacts::{Artifacts, ModelArtifacts};
pub use backend::{
    corpus_or_synthetic, default_backend, default_spec, default_spec_in, AquaKnobs, BackendRecipe,
    BackendSpec, ExecBackend, KernelCounters, LaneError, PrefixAttach, StepOut,
};
pub use crate::kvpool::{KvPoolConfig, KvPoolGauges};
pub use fault::{FaultBackend, FaultPlan};
pub use native::{synthetic_corpus, NativeBackend, NativeModel, ScoreMode, NATIVE_PREFILL_CHUNK};
pub use sharded::ShardedBackend;

#[cfg(feature = "pjrt")]
pub use backend::PjrtBackend;
#[cfg(feature = "pjrt")]
pub use exec::{DecodeOut, ModelRuntime, PrefillOut};
