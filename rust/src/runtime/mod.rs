//! PJRT runtime: loads the AOT artifacts produced by `make artifacts` and
//! executes them on the request path.
//!
//! * [`artifacts`] — manifest.json parsing, model/corpus/task locations.
//! * [`exec`] — HLO-text → compiled executable registry + typed call
//!   wrappers for the decode/prefill entry points.

pub mod artifacts;
pub mod exec;

pub use artifacts::{Artifacts, ModelArtifacts};
pub use exec::{DecodeOut, ModelRuntime, PrefillOut};
