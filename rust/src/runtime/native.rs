//! Hermetic pure-rust reference backend.
//!
//! A tiny decoder-only transformer executed entirely on `tensor::core`
//! primitives and the `aqua::native` score kernels, with real KV tensors
//! owned in rust — no PJRT, no artifacts, no network. Weights are drawn
//! deterministically from a seed, so the full serving path (engine →
//! batcher → KV cache → H2O → AQUA selection) is exercisable and
//! reproducible in any offline environment. The text it produces is
//! gibberish; the *system behavior* (batching invariance, determinism,
//! knob semantics, eviction, metrics) is exactly what the tier-1 tests
//! pin down.
//!
//! Model shape (mirrors the PJRT analog models, minus RoPE):
//! * byte-level embedding + learned absolute position embedding — the
//!   position input is driven by `LaneKv.len` through the engine, so
//!   positional handling needs no rotation state in the cache;
//! * per layer: RMSNorm → GQA attention (AQUA on the score path) →
//!   residual, RMSNorm → SiLU MLP → residual;
//! * final RMSNorm → unembedding to byte logits.
//!
//! AQUA integration matches the lowered HLO semantics: keys are projected
//! by a per-(layer, kv-head) *orthogonal* P and statically sliced by
//! `dim_keep` **at cache-write time**; queries are projected/sliced at
//! read time, the top-`k_dims` magnitude mask is applied to the query, and
//! scores come from `aqua_scores_masked` (numerically identical to the
//! sparse gather — property-tested in `aqua::native`). With `k = d` and
//! `use_projection = false` this is exact standard attention.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::backend::{AquaKnobs, ExecBackend, StepOut};
use crate::aqua::native::{aqua_scores_masked, project};
use crate::model::config::ModelConfig;
use crate::tensor::topk::topk_mask_by_abs;
use crate::util::prng::Rng;

/// Default tokens per lane per prefill call (small: the native model is a
/// test vehicle, not a throughput record).
pub const NATIVE_PREFILL_CHUNK: usize = 16;

// ---------------------------------------------------------------------------
// Weights
// ---------------------------------------------------------------------------

struct LayerWeights {
    attn_norm: Vec<f32>, // [dm]
    wq: Vec<f32>,        // [dm, nq*d]
    wk: Vec<f32>,        // [dm, nkv*d]
    wv: Vec<f32>,        // [dm, nkv*d]
    wo: Vec<f32>,        // [nq*d, dm]
    mlp_norm: Vec<f32>,  // [dm]
    w1: Vec<f32>,        // [dm, dff]
    w2: Vec<f32>,        // [dff, dm]
}

/// Deterministic random transformer weights for one served model. Shared
/// (`Arc`) across backends so sweeps pay model construction once.
pub struct NativeModel {
    pub cfg: ModelConfig,
    pub seed: u64,
    embed: Vec<f32>,     // [vocab, dm]
    pos_embed: Vec<f32>, // [max_seq, dm]
    layers: Vec<LayerWeights>,
    final_norm: Vec<f32>, // [dm]
    unembed: Vec<f32>,    // [dm, vocab]
    /// [L, n_kv, d, d] orthogonal projections (rows orthonormal), the
    /// native analog of the calibrated P. Orthogonality is what makes
    /// `use_projection` at k = d an exact rotation (Lemma A.4).
    proj: Vec<f32>,
}

impl NativeModel {
    pub fn new(cfg: ModelConfig, seed: u64) -> Result<NativeModel> {
        if cfg.vocab < 2 || cfg.d_head == 0 || cfg.d_model == 0 || cfg.max_seq == 0 {
            bail!("native model: degenerate config {cfg:?}");
        }
        if cfg.n_kv_heads == 0 || cfg.n_q_heads % cfg.n_kv_heads != 0 {
            bail!("native model: n_q_heads must be a multiple of n_kv_heads");
        }
        let (dm, d, nq, nkv, dff) =
            (cfg.d_model, cfg.d_head, cfg.n_q_heads, cfg.n_kv_heads, cfg.d_ff);
        let mut rng = Rng::new(seed ^ 0xAB5EED);
        let lin = |rng: &mut Rng, n_in: usize, n_out: usize| -> Vec<f32> {
            rng.normal_vec(n_in * n_out, (n_in as f32).powf(-0.5))
        };

        let embed = rng.normal_vec(cfg.vocab * dm, 1.0);
        let pos_embed = rng.normal_vec(cfg.max_seq * dm, 0.5);
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for _ in 0..cfg.n_layers {
            layers.push(LayerWeights {
                attn_norm: vec![1.0; dm],
                wq: lin(&mut rng, dm, nq * d),
                wk: lin(&mut rng, dm, nkv * d),
                wv: lin(&mut rng, dm, nkv * d),
                wo: lin(&mut rng, nq * d, dm),
                mlp_norm: vec![1.0; dm],
                w1: lin(&mut rng, dm, dff),
                w2: lin(&mut rng, dff, dm),
            });
        }
        let final_norm = vec![1.0; dm];
        let unembed = rng.normal_vec(dm * cfg.vocab, 2.0 * (dm as f32).powf(-0.5));
        let mut proj = Vec::with_capacity(cfg.n_layers * nkv * d * d);
        for _ in 0..cfg.n_layers * nkv {
            proj.extend_from_slice(&orthonormal(&mut rng, d)?);
        }
        Ok(NativeModel { cfg, seed, embed, pos_embed, layers, final_norm, unembed, proj })
    }

    /// Row-major [d, d] projection for (layer, kv-head group).
    pub fn projection(&self, layer: usize, group: usize) -> &[f32] {
        let d = self.cfg.d_head;
        let base = (layer * self.cfg.n_kv_heads + group) * d * d;
        &self.proj[base..base + d * d]
    }
}

/// Random orthogonal [d, d] matrix (rows orthonormal) via modified
/// Gram-Schmidt on gaussian rows, f64 accumulation.
fn orthonormal(rng: &mut Rng, d: usize) -> Result<Vec<f32>> {
    let mut m = vec![0.0f32; d * d];
    for i in 0..d {
        let mut ok = false;
        for _attempt in 0..16 {
            let mut row: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            for j in 0..i {
                let prev = &m[j * d..(j + 1) * d];
                let dot: f64 = row.iter().zip(prev).map(|(a, &b)| a * b as f64).sum();
                for (r, &p) in row.iter_mut().zip(prev) {
                    *r -= dot * p as f64;
                }
            }
            let norm: f64 = row.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm > 1e-6 {
                for (slot, r) in m[i * d..(i + 1) * d].iter_mut().zip(&row) {
                    *slot = (r / norm) as f32;
                }
                ok = true;
                break;
            }
        }
        if !ok {
            bail!("orthonormal basis generation failed (d={d})");
        }
    }
    Ok(m)
}

// ---------------------------------------------------------------------------
// Elementwise helpers
// ---------------------------------------------------------------------------

fn rmsnorm(x: &[f32], gain: &[f32], eps: f32, out: &mut [f32]) {
    let ms: f32 = x.iter().map(|v| v * v).sum::<f32>() / x.len().max(1) as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    for ((o, &v), &g) in out.iter_mut().zip(x).zip(gain) {
        *o = v * inv * g;
    }
}

/// out[j] = Σ_i x[i]·w[i, j] for row-major `w` [n_in, n_out] — the same
/// ikj-accumulator layout as `Tensor::matmul`.
fn matvec(x: &[f32], w: &[f32], n_out: usize, out: &mut [f32]) {
    out.fill(0.0);
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let wrow = &w[i * n_out..(i + 1) * n_out];
        for (o, &wv) in out.iter_mut().zip(wrow) {
            *o += xi * wv;
        }
    }
}

fn silu_inplace(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x *= 1.0 / (1.0 + (-*x).exp());
    }
}

// ---------------------------------------------------------------------------
// Backend
// ---------------------------------------------------------------------------

/// The hermetic reference [`ExecBackend`]: owns real per-batch KV tensors
/// (layout `[L, B, n_kv, S, d]`, keys stored projected+sliced, values raw).
pub struct NativeBackend {
    model: Arc<NativeModel>,
    batch: usize,
    prefill_chunk: usize,
    k_cache: Vec<f32>,
    v_cache: Vec<f32>,
}

impl NativeBackend {
    pub fn new(cfg: ModelConfig, seed: u64) -> Result<NativeBackend> {
        Ok(Self::from_model(Arc::new(NativeModel::new(cfg, seed)?)))
    }

    pub fn from_model(model: Arc<NativeModel>) -> NativeBackend {
        let chunk = NATIVE_PREFILL_CHUNK.clamp(1, model.cfg.max_seq);
        NativeBackend { model, batch: 0, prefill_chunk: chunk, k_cache: vec![], v_cache: vec![] }
    }

    pub fn model(&self) -> &NativeModel {
        &self.model
    }

    fn cache_base(&self, l: usize, lane: usize, g: usize) -> usize {
        let c = &self.model.cfg;
        (((l * self.batch + lane) * c.n_kv_heads + g) * c.max_seq) * c.d_head
    }

    /// One forward pass over `t` sequential tokens per lane (t = 1 for
    /// decode, t = chunk for prefill — identical arithmetic, so the
    /// decode/prefill consistency the PJRT path is tested for holds here
    /// by construction).
    fn step(
        &mut self,
        b: usize,
        tokens: &[i32],
        pos0: &[i32],
        t: usize,
        slot_mask: &[f32],
        knobs: &AquaKnobs,
    ) -> Result<StepOut> {
        let model = self.model.clone();
        let c = &model.cfg;
        let (dm, d, nq, nkv, dff, s_cap, vocab) =
            (c.d_model, c.d_head, c.n_q_heads, c.n_kv_heads, c.d_ff, c.max_seq, c.vocab);
        let gsz = nq / nkv;
        if b != self.batch {
            bail!("native step: batch {b} but caches sized for {} (call empty_cache)", self.batch);
        }
        if tokens.len() != b * t || pos0.len() != b || slot_mask.len() != b * s_cap {
            bail!("native step: arg shape mismatch (b={b}, t={t})");
        }
        if knobs.dim_keep.len() != d {
            bail!("native step: dim_keep len {} != d_head {d}", knobs.dim_keep.len());
        }
        let k_dims = knobs.k_dims.clamp(1, d);
        let scale = (d as f32).powf(-0.5);
        let eps = c.norm_eps as f32;

        let mut logits_out = vec![0.0f32; b * t * vocab];
        let mut attn_acc = vec![0.0f32; c.n_layers * b * s_cap];

        // Scratch buffers reused across tokens/layers/heads.
        let mut x = vec![0.0f32; dm];
        let mut h = vec![0.0f32; dm];
        let mut qs = vec![0.0f32; nq * d];
        let mut ks = vec![0.0f32; nkv * d];
        let mut vs = vec![0.0f32; nkv * d];
        let mut khat = vec![0.0f32; d];
        let mut qhat = vec![0.0f32; d];
        let mut scores = vec![0.0f32; s_cap];
        let mut attn_out = vec![0.0f32; nq * d];
        let mut o_proj = vec![0.0f32; dm];
        let mut ff1 = vec![0.0f32; dff];
        let mut ff2 = vec![0.0f32; dm];
        let mut xf = vec![0.0f32; dm];

        for lane in 0..b {
            let lane_mask = &slot_mask[lane * s_cap..(lane + 1) * s_cap];
            // Attendable slots: committed (engine's slot_mask) + positions
            // written earlier in this call. Committed indices are always
            // below the write cursor, so the list stays sorted.
            let mut att: Vec<usize> = (0..s_cap).filter(|&s| lane_mask[s] > 0.5).collect();

            for ci in 0..t {
                let tok_raw = tokens[lane * t + ci];
                if tok_raw < 0 {
                    // padding / dead lane: no write, no compute; the logits
                    // row stays zero (the engine never reads it). Real
                    // tokens are always a chunk prefix, so nothing after
                    // this position needs the attendable set extended.
                    continue;
                }
                let pos = pos0[lane].max(0) as usize + ci;
                let writable = pos < s_cap;
                // `att` stays sorted: committed slots all sit below the
                // write cursor. The binary_search guards the clamped
                // full-lane case where `pos` is already attendable.
                if writable && att.binary_search(&pos).is_err() {
                    att.push(pos);
                }
                let tok = tok_raw.min(vocab as i32 - 1) as usize;
                let pe = pos.min(s_cap - 1);
                for (j, xv) in x.iter_mut().enumerate() {
                    *xv = model.embed[tok * dm + j] + model.pos_embed[pe * dm + j];
                }

                for (l, lw) in model.layers.iter().enumerate() {
                    // ---- attention block --------------------------------
                    rmsnorm(&x, &lw.attn_norm, eps, &mut h);
                    matvec(&h, &lw.wq, nq * d, &mut qs);
                    matvec(&h, &lw.wk, nkv * d, &mut ks);
                    matvec(&h, &lw.wv, nkv * d, &mut vs);

                    if writable {
                        for g in 0..nkv {
                            let k_raw = &ks[g * d..(g + 1) * d];
                            if knobs.use_projection {
                                project(k_raw, model.projection(l, g), d, &mut khat);
                            } else {
                                khat.copy_from_slice(k_raw);
                            }
                            for (kv, &keep) in khat.iter_mut().zip(&knobs.dim_keep) {
                                *kv *= keep;
                            }
                            let kb = self.cache_base(l, lane, g) + pos * d;
                            self.k_cache[kb..kb + d].copy_from_slice(&khat);
                            let vb = kb; // same layout for both caches
                            self.v_cache[vb..vb + d].copy_from_slice(&vs[g * d..(g + 1) * d]);
                        }
                    }

                    attn_out.fill(0.0);
                    if let Some(&hi) = att.last() {
                        for qh in 0..nq {
                            let g = qh / gsz;
                            let q_raw = &qs[qh * d..(qh + 1) * d];
                            if knobs.use_projection {
                                project(q_raw, model.projection(l, g), d, &mut qhat);
                            } else {
                                qhat.copy_from_slice(q_raw);
                            }
                            for (qv, &keep) in qhat.iter_mut().zip(&knobs.dim_keep) {
                                *qv *= keep;
                            }
                            // AQUA Algorithm 1: top-k |q̂| dims, masked-dense
                            // scores (== sparse gather; see aqua::native).
                            let mask = topk_mask_by_abs(&qhat, k_dims);
                            let kb = self.cache_base(l, lane, g);
                            aqua_scores_masked(
                                &qhat,
                                &mask,
                                &self.k_cache[kb..kb + (hi + 1) * d],
                                hi + 1,
                                d,
                                &mut scores[..hi + 1],
                            );
                            // Softmax over the attendable set only.
                            let m = att
                                .iter()
                                .map(|&s| scores[s] * scale)
                                .fold(f32::NEG_INFINITY, f32::max);
                            let mut denom = 0.0f32;
                            for &s in &att {
                                let e = (scores[s] * scale - m).exp();
                                scores[s] = e; // reuse as unnormalized prob
                                denom += e;
                            }
                            if denom <= 0.0 {
                                continue;
                            }
                            let acc_base = (l * b + lane) * s_cap;
                            let out_h = &mut attn_out[qh * d..(qh + 1) * d];
                            for &s in &att {
                                let p = scores[s] / denom;
                                attn_acc[acc_base + s] += p;
                                let vrow = &self.v_cache[kb + s * d..kb + (s + 1) * d];
                                for (o, &vv) in out_h.iter_mut().zip(vrow) {
                                    *o += p * vv;
                                }
                            }
                        }
                    }
                    matvec(&attn_out, &lw.wo, dm, &mut o_proj);
                    for (xv, &ov) in x.iter_mut().zip(&o_proj) {
                        *xv += ov;
                    }

                    // ---- MLP block --------------------------------------
                    rmsnorm(&x, &lw.mlp_norm, eps, &mut h);
                    matvec(&h, &lw.w1, dff, &mut ff1);
                    silu_inplace(&mut ff1);
                    matvec(&ff1, &lw.w2, dm, &mut ff2);
                    for (xv, &fv) in x.iter_mut().zip(&ff2) {
                        *xv += fv;
                    }
                }

                rmsnorm(&x, &model.final_norm, eps, &mut xf);
                let row = &mut logits_out[(lane * t + ci) * vocab..(lane * t + ci + 1) * vocab];
                matvec(&xf, &model.unembed, vocab, row);
            }
        }
        Ok(StepOut { logits: logits_out, attn_acc })
    }
}

impl ExecBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn model_config(&self) -> &ModelConfig {
        &self.model.cfg
    }

    fn prefill_chunk(&self) -> usize {
        self.prefill_chunk
    }

    fn empty_cache(&mut self, b: usize) -> Result<()> {
        if b == 0 {
            bail!("native empty_cache: batch must be >= 1");
        }
        let c = &self.model.cfg;
        let n = c.n_layers * b * c.n_kv_heads * c.max_seq * c.d_head;
        self.batch = b;
        self.k_cache.clear();
        self.k_cache.resize(n, 0.0);
        self.v_cache.clear();
        self.v_cache.resize(n, 0.0);
        Ok(())
    }

    fn prefill(
        &mut self,
        b: usize,
        tokens: &[i32],
        pos0: &[i32],
        slot_mask: &[f32],
        knobs: &AquaKnobs,
    ) -> Result<StepOut> {
        let chunk = self.prefill_chunk;
        self.step(b, tokens, pos0, chunk, slot_mask, knobs)
    }

    fn decode(
        &mut self,
        b: usize,
        tokens: &[i32],
        pos: &[i32],
        slot_mask: &[f32],
        knobs: &AquaKnobs,
    ) -> Result<StepOut> {
        self.step(b, tokens, pos, 1, slot_mask, knobs)
    }
}

// ---------------------------------------------------------------------------
// Synthetic corpus (hermetic stand-in for artifacts/corpus/valid.txt)
// ---------------------------------------------------------------------------

/// Deterministic synthetic text corpus: newline-separated sentences over a
/// small lexicon, shaped like the build pipeline's anglish corpus. Lets
/// corpus-driven examples/benches/evals run with no artifacts present.
pub fn synthetic_corpus(bytes: usize, seed: u64) -> Vec<u8> {
    const SUBJECTS: [&str; 8] =
        ["the capital", "the color", "the sound", "the king", "the river", "the square root",
         "the opposite", "the shape"];
    const OBJECTS: [&str; 8] =
        ["velor", "tamrin", "the sky", "the sea", "marden", "oblon", "the moon", "quarzel"];
    const VALUES: [&str; 8] =
        ["blue", "loud", "round", "tamrin", "seven", "cold", "bright", "hollow"];
    let mut rng = Rng::new(seed ^ 0x5EED);
    let mut out = Vec::with_capacity(bytes + 64);
    while out.len() < bytes {
        let s = SUBJECTS[rng.below(SUBJECTS.len())];
        let o = OBJECTS[rng.below(OBJECTS.len())];
        let v = VALUES[rng.below(VALUES.len())];
        out.extend_from_slice(format!("{s} of {o} is {v} .\n").as_bytes());
    }
    out.truncate(bytes);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::dot;

    fn tiny() -> ModelConfig {
        ModelConfig::tiny("native-test")
    }

    fn exact_knobs(d: usize) -> AquaKnobs {
        AquaKnobs::exact(d)
    }

    #[test]
    fn projections_are_orthogonal() {
        let m = NativeModel::new(tiny(), 3).unwrap();
        let d = m.cfg.d_head;
        for l in 0..m.cfg.n_layers {
            for g in 0..m.cfg.n_kv_heads {
                let p = m.projection(l, g);
                for i in 0..d {
                    for j in 0..d {
                        let got = dot(&p[i * d..(i + 1) * d], &p[j * d..(j + 1) * d]);
                        let want = if i == j { 1.0 } else { 0.0 };
                        assert!((got - want).abs() < 1e-4, "P·Pᵀ[{i},{j}] = {got}");
                    }
                }
            }
        }
    }

    #[test]
    fn decode_is_deterministic_and_seed_sensitive() {
        let cfg = tiny();
        let d = cfg.d_head;
        let run = |seed: u64| -> Vec<f32> {
            let mut be = NativeBackend::new(tiny(), seed).unwrap();
            be.empty_cache(1).unwrap();
            let mask = vec![0.0f32; cfg.max_seq];
            be.decode(1, &[65], &[0], &mask, &exact_knobs(d)).unwrap().logits
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn attention_mass_sums_to_layers_times_heads() {
        let cfg = tiny();
        let d = cfg.d_head;
        let mut be = NativeBackend::new(cfg.clone(), 1).unwrap();
        be.empty_cache(2).unwrap();
        let mut mask = vec![0.0f32; 2 * cfg.max_seq];
        for (i, &t) in [10i32, 20, 30].iter().enumerate() {
            let out = be
                .decode(2, &[t, t + 1], &[i as i32, i as i32], &mask, &exact_knobs(d))
                .unwrap();
            for lane in 0..2 {
                let mut mass = 0.0f32;
                for l in 0..cfg.n_layers {
                    let base = (l * 2 + lane) * cfg.max_seq;
                    mass += out.attn_acc[base..base + cfg.max_seq].iter().sum::<f32>();
                }
                let expect = (cfg.n_layers * cfg.n_q_heads) as f32;
                assert!((mass - expect).abs() < 1e-3, "lane {lane} mass {mass} vs {expect}");
            }
            mask[i] = 1.0;
            mask[cfg.max_seq + i] = 1.0;
            assert!(out.logits.iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn prefill_matches_token_by_token_decode() {
        let cfg = tiny();
        let d = cfg.d_head;
        let toks: Vec<i32> = b"the blue sea".iter().map(|&b| b as i32).collect();
        let n = toks.len();
        let knobs = AquaKnobs { k_dims: d / 2, dim_keep: vec![1.0; d], use_projection: true };

        // decode chain
        let mut bd = NativeBackend::new(cfg.clone(), 5).unwrap();
        bd.empty_cache(1).unwrap();
        let mut mask = vec![0.0f32; cfg.max_seq];
        let mut last = vec![];
        for (i, &t) in toks.iter().enumerate() {
            last = bd.decode(1, &[t], &[i as i32], &mask, &knobs).unwrap().logits;
            mask[i] = 1.0;
        }

        // one prefill call (pad to the chunk)
        let mut bp = NativeBackend::new(cfg.clone(), 5).unwrap();
        bp.empty_cache(1).unwrap();
        let chunk = bp.prefill_chunk();
        assert!(n <= chunk, "test prompt must fit one chunk");
        let mut padded = vec![0i32; chunk];
        padded[..n].copy_from_slice(&toks);
        let mask0 = vec![0.0f32; cfg.max_seq];
        let out = bp.prefill(1, &padded, &[0], &mask0, &knobs).unwrap();
        let pre = &out.logits[(n - 1) * cfg.vocab..n * cfg.vocab];
        let diff = pre.iter().zip(&last).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(diff < 1e-4, "prefill/decode disagree by {diff}");
    }

    #[test]
    fn knob_inputs_change_the_logits() {
        let cfg = tiny();
        let d = cfg.d_head;
        let mut be = NativeBackend::new(cfg.clone(), 9).unwrap();
        be.empty_cache(1).unwrap();
        let mut mask = vec![0.0f32; cfg.max_seq];
        // build a few slots of context first (projected cache, all dims kept)
        let ctx = AquaKnobs { k_dims: d, dim_keep: vec![1.0; d], use_projection: true };
        for i in 0..6usize {
            be.decode(1, &[40 + i as i32], &[i as i32], &mask, &ctx).unwrap();
            mask[i] = 1.0;
        }
        let probe = |be: &mut NativeBackend, knobs: &AquaKnobs| -> Vec<f32> {
            be.decode(1, &[46], &[6], &mask, knobs).unwrap().logits
        };
        let full = probe(&mut be, &AquaKnobs { k_dims: d, dim_keep: vec![1.0; d], use_projection: true });
        let k2 = probe(&mut be, &AquaKnobs { k_dims: 2, dim_keep: vec![1.0; d], use_projection: true });
        let max_diff =
            full.iter().zip(&k2).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(max_diff > 1e-4, "k_dims input has no effect");

        let mut keep = vec![1.0f32; d];
        for k in keep.iter_mut().skip(d - d / 4) {
            *k = 0.0;
        }
        let sliced = probe(&mut be, &AquaKnobs { k_dims: d, dim_keep: keep, use_projection: true });
        let max_diff =
            full.iter().zip(&sliced).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(max_diff > 1e-5, "dim_keep input has no effect");
    }

    #[test]
    fn orthogonal_projection_is_exact_at_k_equals_d() {
        // Lemma A.4 natively: projecting q and k by the same orthogonal P
        // preserves scores, so k = d with projection must match the
        // identity-P baseline up to f32 rounding.
        let cfg = tiny();
        let d = cfg.d_head;
        let toks: Vec<i32> = b"rotation".iter().map(|&b| b as i32).collect();
        let run = |use_projection: bool| -> Vec<f32> {
            let knobs = AquaKnobs { k_dims: d, dim_keep: vec![1.0; d], use_projection };
            let mut be = NativeBackend::new(tiny(), 11).unwrap();
            be.empty_cache(1).unwrap();
            let mut mask = vec![0.0f32; cfg.max_seq];
            let mut last = vec![];
            for (i, &t) in toks.iter().enumerate() {
                last = be.decode(1, &[t], &[i as i32], &mask, &knobs).unwrap().logits;
                mask[i] = 1.0;
            }
            last
        };
        let base = run(false);
        let rot = run(true);
        let diff = base.iter().zip(&rot).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(diff < 1e-2, "rotation changed logits by {diff}");
    }

    #[test]
    fn negative_tokens_are_skipped_as_padding() {
        let cfg = tiny();
        let d = cfg.d_head;
        // lane 1 is dead (-1): its logits row stays zero, and lane 0's
        // output matches a solo batch=1 run exactly
        let mut b2 = NativeBackend::new(tiny(), 4).unwrap();
        b2.empty_cache(2).unwrap();
        let mask2 = vec![0.0f32; 2 * cfg.max_seq];
        let out = b2.decode(2, &[65, -1], &[0, 0], &mask2, &exact_knobs(d)).unwrap();
        assert!(out.logits[cfg.vocab..].iter().all(|&x| x == 0.0), "pad lane logits not zero");
        assert!(out.attn_acc.iter().sum::<f32>() > 0.0);

        let mut b1 = NativeBackend::new(tiny(), 4).unwrap();
        b1.empty_cache(1).unwrap();
        let mask1 = vec![0.0f32; cfg.max_seq];
        let solo = b1.decode(1, &[65], &[0], &mask1, &exact_knobs(d)).unwrap();
        assert_eq!(&out.logits[..cfg.vocab], &solo.logits[..]);
    }

    #[test]
    fn synthetic_corpus_is_deterministic_lines() {
        let a = synthetic_corpus(2048, 1);
        let b = synthetic_corpus(2048, 1);
        assert_eq!(a, b);
        assert_eq!(a.len(), 2048);
        assert!(a.split(|&b| b == b'\n').next().unwrap().len() > 8);
        assert_ne!(a, synthetic_corpus(2048, 2));
    }
}
