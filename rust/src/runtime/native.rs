//! Hermetic pure-rust reference backend.
//!
//! A tiny decoder-only transformer executed entirely on `tensor::core`
//! primitives and the `aqua::native` score kernels, with real KV tensors
//! owned in rust — no PJRT, no artifacts, no network. Weights are drawn
//! deterministically from a seed, so the full serving path (engine →
//! batcher → KV cache → H2O → AQUA selection) is exercisable and
//! reproducible in any offline environment. The text it produces is
//! gibberish; the *system behavior* (batching invariance, determinism,
//! knob semantics, eviction, metrics) is exactly what the tier-1 tests
//! pin down.
//!
//! Model shape (mirrors the PJRT analog models, minus RoPE):
//! * byte-level embedding + learned absolute position embedding — the
//!   position input is driven by `LaneKv.len` through the engine, so
//!   positional handling needs no rotation state in the cache;
//! * per layer: RMSNorm → GQA attention (AQUA on the score path) →
//!   residual, RMSNorm → SiLU MLP → residual;
//! * final RMSNorm → unembedding to byte logits.
//!
//! AQUA integration matches the lowered HLO semantics: keys are projected
//! by a per-(layer, kv-head) *orthogonal* P and statically sliced by
//! `dim_keep` **once, at cache-write time** (the O(d²) projection is paid
//! per token, never per decode step); queries are projected/sliced at read
//! time and the top-`k_dims` magnitude selection picks the dims the score
//! kernel touches. With `k = d` and `use_projection = false` this is exact
//! standard attention.
//!
//! Decode hot path (this is the layout/kernel co-design the break-even
//! bench measures):
//! * the key cache is **dim-major** and **paged** (`crate::kvpool`): a
//!   lane's positions are covered by `page_slots`-sized pages leased on
//!   demand, each storing keys as `[L, n_kv, key_dims, page_slots]` (one
//!   projected dimension contiguous across the page's slots) and values at
//!   full width. The packed kernel [`aqua_scores_packed_cols`] streams
//!   exactly `k` contiguous runs per page — compute and memory traffic
//!   both scale with k, and *resident bytes* scale with the AQUA-Memory
//!   knob (`key_dims = mem_dims(d)`) and the actual context length instead
//!   of a dense `max_seq` preallocation;
//! * pages whose slots H2O has fully evicted return to the pool (so do a
//!   retired lane's); slots in never-leased pages score exactly 0.0, the
//!   value the old dense zeroed cache produced for never-written slots;
//! * when H2O has evicted enough of the context, scoring switches to a
//!   paged slot-subset kernel (the `aqua_scores_packed_cols_at` analog),
//!   touching only the attendable slots;
//! * the masked-dense formulation stays available as [`ScoreMode::MaskedDense`],
//!   the parity oracle the property tests compare against — it scores a
//!   dense row-major *shadow* cache with its own write path, so pool bugs
//!   cannot cancel out of the parity tests (the pooled packed kernels are
//!   *bit-identical* to it at `kv_keep = 1.0` — see `aqua::native` and
//!   `tests/kvpool_props.rs`);
//! * all step scratch (activations, selections, scores, the attendable
//!   list) lives in a persistent [`Scratch`] owned by the backend, so the
//!   steady-state decode path allocates nothing but its two output vectors
//!   (page leases amortize to one allocation per `page_slots` tokens, and
//!   recycled pages allocate nothing);
//! * with `KvPoolConfig::prefix_cache` on, every full `page_slots`-sized
//!   chunk of contiguous prompt tokens is registered in a
//!   [`PrefixIndex`] under its token-chain hash as it is written, and
//!   `attach_prefix` maps a new lane onto the longest registered chain of
//!   its prompt (refcounted; reads score shared pages in place, writes
//!   copy-on-write) — one prefill's pages serve every lane that shares
//!   the prefix, and skipped prefill work scales with the hit rate. The
//!   chain hash is seeded with a fingerprint of the cache-shaping knobs,
//!   so knob changes can never alias content.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use super::backend::{AquaKnobs, ExecBackend, KernelCounters, PrefixAttach, StepOut};
use crate::aqua::fused::{fused_attend, simd_lanes, FusedStats};
use crate::aqua::native::{aqua_scores_masked, aqua_scores_packed_cols, project};
use crate::kvpool::prefix::{fold_byte, fold_chunk, fold_token, Register, PREFIX_SEED};
use crate::kvpool::{
    KvPoolConfig, KvPoolGauges, KvQuant, LanePageTable, PagePool, PoolLayout, PrefixIndex,
    DEFAULT_PAGE_SLOTS,
};
use crate::model::config::ModelConfig;
use crate::tensor::topk::{topk_indices_into, topk_mask_into};
use crate::util::prng::Rng;

/// Default tokens per lane per prefill call (small: the native model is a
/// test vehicle, not a throughput record).
pub const NATIVE_PREFILL_CHUNK: usize = 16;

// ---------------------------------------------------------------------------
// Weights
// ---------------------------------------------------------------------------

struct LayerWeights {
    attn_norm: Vec<f32>, // [dm]
    wq: Vec<f32>,        // [dm, nq*d]
    wk: Vec<f32>,        // [dm, nkv*d]
    wv: Vec<f32>,        // [dm, nkv*d]
    wo: Vec<f32>,        // [nq*d, dm]
    mlp_norm: Vec<f32>,  // [dm]
    w1: Vec<f32>,        // [dm, dff]
    w2: Vec<f32>,        // [dff, dm]
}

/// Deterministic random transformer weights for one served model. Shared
/// (`Arc`) across backends so sweeps pay model construction once.
pub struct NativeModel {
    pub cfg: ModelConfig,
    pub seed: u64,
    embed: Vec<f32>,     // [vocab, dm]
    pos_embed: Vec<f32>, // [max_seq, dm]
    layers: Vec<LayerWeights>,
    final_norm: Vec<f32>, // [dm]
    unembed: Vec<f32>,    // [dm, vocab]
    /// [L, n_kv, d, d] orthogonal projections (rows orthonormal), the
    /// native analog of the calibrated P. Orthogonality is what makes
    /// `use_projection` at k = d an exact rotation (Lemma A.4).
    proj: Vec<f32>,
}

impl NativeModel {
    pub fn new(cfg: ModelConfig, seed: u64) -> Result<NativeModel> {
        if cfg.vocab < 2 || cfg.d_head == 0 || cfg.d_model == 0 || cfg.max_seq == 0 {
            bail!("native model: degenerate config {cfg:?}");
        }
        if cfg.n_kv_heads == 0 || cfg.n_q_heads % cfg.n_kv_heads != 0 {
            bail!("native model: n_q_heads must be a multiple of n_kv_heads");
        }
        let (dm, d, nq, nkv, dff) =
            (cfg.d_model, cfg.d_head, cfg.n_q_heads, cfg.n_kv_heads, cfg.d_ff);
        let mut rng = Rng::new(seed ^ 0xAB5EED);
        let lin = |rng: &mut Rng, n_in: usize, n_out: usize| -> Vec<f32> {
            rng.normal_vec(n_in * n_out, (n_in as f32).powf(-0.5))
        };

        let embed = rng.normal_vec(cfg.vocab * dm, 1.0);
        let pos_embed = rng.normal_vec(cfg.max_seq * dm, 0.5);
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for _ in 0..cfg.n_layers {
            layers.push(LayerWeights {
                attn_norm: vec![1.0; dm],
                wq: lin(&mut rng, dm, nq * d),
                wk: lin(&mut rng, dm, nkv * d),
                wv: lin(&mut rng, dm, nkv * d),
                wo: lin(&mut rng, nq * d, dm),
                mlp_norm: vec![1.0; dm],
                w1: lin(&mut rng, dm, dff),
                w2: lin(&mut rng, dff, dm),
            });
        }
        let final_norm = vec![1.0; dm];
        let unembed = rng.normal_vec(dm * cfg.vocab, 2.0 * (dm as f32).powf(-0.5));
        let mut proj = Vec::with_capacity(cfg.n_layers * nkv * d * d);
        for _ in 0..cfg.n_layers * nkv {
            proj.extend_from_slice(&orthonormal(&mut rng, d)?);
        }
        Ok(NativeModel { cfg, seed, embed, pos_embed, layers, final_norm, unembed, proj })
    }

    /// Row-major [d, d] projection for (layer, kv-head group).
    pub fn projection(&self, layer: usize, group: usize) -> &[f32] {
        let d = self.cfg.d_head;
        let base = (layer * self.cfg.n_kv_heads + group) * d * d;
        &self.proj[base..base + d * d]
    }
}

/// Random orthogonal [d, d] matrix (rows orthonormal) via modified
/// Gram-Schmidt on gaussian rows, f64 accumulation.
fn orthonormal(rng: &mut Rng, d: usize) -> Result<Vec<f32>> {
    let mut m = vec![0.0f32; d * d];
    for i in 0..d {
        let mut ok = false;
        for _attempt in 0..16 {
            let mut row: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            for j in 0..i {
                let prev = &m[j * d..(j + 1) * d];
                let dot: f64 = row.iter().zip(prev).map(|(a, &b)| a * b as f64).sum();
                for (r, &p) in row.iter_mut().zip(prev) {
                    *r -= dot * p as f64;
                }
            }
            let norm: f64 = row.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm > 1e-6 {
                for (slot, r) in m[i * d..(i + 1) * d].iter_mut().zip(&row) {
                    *slot = (r / norm) as f32;
                }
                ok = true;
                break;
            }
        }
        if !ok {
            bail!("orthonormal basis generation failed (d={d})");
        }
    }
    Ok(m)
}

// ---------------------------------------------------------------------------
// Elementwise helpers
// ---------------------------------------------------------------------------

fn rmsnorm(x: &[f32], gain: &[f32], eps: f32, out: &mut [f32]) {
    let ms: f32 = x.iter().map(|v| v * v).sum::<f32>() / x.len().max(1) as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    for ((o, &v), &g) in out.iter_mut().zip(x).zip(gain) {
        *o = v * inv * g;
    }
}

/// out[j] = Σ_i x[i]·w[i, j] for row-major `w` [n_in, n_out] — the same
/// ikj-accumulator layout as `Tensor::matmul`.
fn matvec(x: &[f32], w: &[f32], n_out: usize, out: &mut [f32]) {
    out.fill(0.0);
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let wrow = &w[i * n_out..(i + 1) * n_out];
        for (o, &wv) in out.iter_mut().zip(wrow) {
            *o += xi * wv;
        }
    }
}

fn silu_inplace(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x *= 1.0 / (1.0 + (-*x).exp());
    }
}

// ---------------------------------------------------------------------------
// Paged score path
// ---------------------------------------------------------------------------

/// Fingerprint of the knobs that shape *cache content* (the AQUA-Memory
/// keep mask and the projection toggle — `k_dims` only shapes the read
/// path). Seeds every prefix chain, so pages written under different
/// knobs can never be mistaken for each other.
fn knob_fingerprint(knobs: &AquaKnobs) -> u64 {
    let mut h = fold_byte(PREFIX_SEED, knobs.use_projection as u8);
    for &keep in &knobs.dim_keep {
        for b in keep.to_bits().to_le_bytes() {
            h = fold_byte(h, b);
        }
    }
    h
}

/// Per-lane prompt-chunk hashing state: tracks the token-chain hash of
/// the contiguous prompt prefix written so far, so each full page of
/// prompt tokens can be registered in the prefix index the moment its
/// last slot is written. Killed by the first decode write (generated
/// tokens end the shareable prompt) or any non-contiguous write.
#[derive(Debug, Clone, Default)]
struct PrefixCursor {
    /// Chain hash over tokens `0..next` (valid once seeded).
    hash: u64,
    /// Next expected contiguous write position.
    next: usize,
    /// Tokens of the current (partial) chunk, pending registration.
    pending: Vec<i32>,
    seeded: bool,
    dead: bool,
}

/// Resolve a [`KvPoolConfig`] against a model shape.
fn pool_layout(c: &ModelConfig, cfg: &KvPoolConfig) -> PoolLayout {
    let d = c.d_head;
    PoolLayout {
        page_slots: cfg.page_slots.unwrap_or(DEFAULT_PAGE_SLOTS).clamp(1, c.max_seq),
        key_dims: cfg.key_dims.unwrap_or(d).clamp(1, d),
        head_dim: d,
        layers: c.n_layers,
        kv_heads: c.n_kv_heads,
        kv_quant: cfg.kv_quant,
    }
}

/// Packed contiguous scores over a paged lane: one
/// [`aqua_scores_packed_cols`] call per leased page (per-slot accumulation
/// order identical to the monolithic dim-major kernel, so results are
/// bit-identical). Slots in never-leased pages score exactly 0.0 — the
/// value the old dense zeroed cache produced for never-written slots.
fn scores_packed_paged(
    qk: &[f32],
    idx: &[usize],
    pool: &PagePool,
    table: &LanePageTable,
    l: usize,
    g: usize,
    n: usize,
    out: &mut [f32],
) {
    let layout = pool.layout();
    let (ps, kd) = (layout.page_slots, layout.key_dims);
    let ko = layout.key_off(l, g);
    let mut base = 0;
    let mut p = 0;
    while base < n {
        let n_local = (n - base).min(ps);
        match table.page(p) {
            Some(id) => {
                let kcols = &pool.page(id)[ko..ko + kd * ps];
                let out_page = &mut out[base..base + n_local];
                aqua_scores_packed_cols(qk, idx, kcols, ps, n_local, out_page);
            }
            None => out[base..base + n_local].fill(0.0),
        }
        base += n_local;
        p += 1;
    }
}

/// Slot-subset scores over a paged lane (the shape H2O holes want): the
/// paged analog of `aqua_scores_packed_cols_at`, same ascending-dim
/// accumulation order per slot, O(|slots|·k) regardless of the cursor.
fn scores_at_paged(
    qk: &[f32],
    idx: &[usize],
    pool: &PagePool,
    table: &LanePageTable,
    l: usize,
    g: usize,
    slots: &[usize],
    out: &mut [f32],
) {
    let layout = pool.layout();
    let ps = layout.page_slots;
    let ko = layout.key_off(l, g);
    for &s in slots {
        match table.page(s / ps) {
            Some(id) => {
                let kcols = &pool.page(id)[ko..];
                let local = s % ps;
                let mut acc = 0.0f32;
                for (j, &i) in idx.iter().enumerate() {
                    acc += qk[j] * kcols[i * ps + local];
                }
                out[s] = acc;
            }
            None => out[s] = 0.0,
        }
    }
}

// ---------------------------------------------------------------------------
// Backend
// ---------------------------------------------------------------------------

/// Which score kernel the backend routes through (see the module docs).
/// `Auto` is the production policy; the explicit variants exist for the
/// parity tests and the break-even benches.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum ScoreMode {
    /// k = d → dense; heavy eviction → sparse subset; otherwise packed.
    #[default]
    Auto,
    /// Full-width masked-dense oracle (the lowered-HLO formulation).
    MaskedDense,
    /// Always the slot-subset sparse kernel.
    Sparse,
    /// Always the contiguous dim-major packed kernel.
    Packed,
    /// The page-fused streaming path ([`crate::aqua::fused`]): packed
    /// scores + online softmax + value reduction in one pass per KV page,
    /// `O(page_slots)` kernel scratch, SIMD with a bit-identical scalar
    /// fallback. An `Int8` pool routes every non-oracle mode here (the
    /// quantized payload is only readable through the fused dequant).
    Fused,
}

/// Persistent per-backend step scratch: every buffer the forward pass
/// needs, sized once from the model config so the steady-state decode path
/// performs zero allocations (satellite of the decode hot-path overhaul).
struct Scratch {
    x: Vec<f32>,
    h: Vec<f32>,
    qs: Vec<f32>,
    ks: Vec<f32>,
    vs: Vec<f32>,
    khat: Vec<f32>,
    qhat: Vec<f32>,
    /// Gathered query values: `qsel[j] = qhat[idx[j]]`.
    qsel: Vec<f32>,
    /// Binary keep-mask for the oracle's masked-dense formulation.
    mask: Vec<f32>,
    /// Selected dim indices (ascending), reused across heads/steps.
    idx: Vec<usize>,
    /// The identity index set 0..d (the dense kernel's "selection").
    all_dims: Vec<usize>,
    scores: Vec<f32>,
    /// Fused-path per-page score block — the kernel's whole working set is
    /// this `O(page_slots)` window (sized `max_seq` only because the pool
    /// may be reshaped after scratch allocation; the used region is always
    /// the pool's `page_slots`).
    page_scores: Vec<f32>,
    attn_out: Vec<f32>,
    o_proj: Vec<f32>,
    ff1: Vec<f32>,
    ff2: Vec<f32>,
    xf: Vec<f32>,
    /// Attendable slot list for the current lane (sorted ascending).
    att: Vec<usize>,
}

impl Scratch {
    fn new(c: &ModelConfig) -> Scratch {
        let (dm, d, nq, nkv, dff, s_cap) =
            (c.d_model, c.d_head, c.n_q_heads, c.n_kv_heads, c.d_ff, c.max_seq);
        Scratch {
            x: vec![0.0; dm],
            h: vec![0.0; dm],
            qs: vec![0.0; nq * d],
            ks: vec![0.0; nkv * d],
            vs: vec![0.0; nkv * d],
            khat: vec![0.0; d],
            qhat: vec![0.0; d],
            qsel: vec![0.0; d],
            mask: vec![0.0; d],
            idx: Vec::with_capacity(d),
            all_dims: (0..d).collect(),
            scores: vec![0.0; s_cap],
            page_scores: vec![0.0; s_cap],
            attn_out: vec![0.0; nq * d],
            o_proj: vec![0.0; dm],
            ff1: vec![0.0; dff],
            ff2: vec![0.0; dm],
            xf: vec![0.0; dm],
            att: Vec::with_capacity(s_cap),
        }
    }
}

/// The hermetic reference [`ExecBackend`]: owns real per-batch KV tensors
/// in a paged pool (`crate::kvpool`). Keys are stored projected, truncated
/// to the pool's resident dims, in per-page **dim-major** layout; values
/// full width (see module docs).
pub struct NativeBackend {
    model: Arc<NativeModel>,
    batch: usize,
    prefill_chunk: usize,
    score_mode: ScoreMode,
    /// Pool shape requested via `configure_kv_pool`; applied by
    /// `empty_cache`.
    pool_cfg: KvPoolConfig,
    pool: PagePool,
    tables: Vec<LanePageTable>,
    /// Prefix-sharing index over registered full prompt chunks (empty and
    /// inert unless `pool_cfg.prefix_cache`).
    index: PrefixIndex,
    /// Per-lane prompt-chain hashing state (see [`PrefixCursor`]).
    cursors: Vec<PrefixCursor>,
    /// Row-major `[L, B, n_kv, S, d]` *shadow* key cache, populated only in
    /// [`ScoreMode::MaskedDense`]: the oracle scores against its own dense
    /// layout and write path, so a bug in the paged dim-major cache or the
    /// packed kernels cannot cancel out of the parity tests.
    k_cache_rows: Vec<f32>,
    scratch: Scratch,
}

impl NativeBackend {
    pub fn new(cfg: ModelConfig, seed: u64) -> Result<NativeBackend> {
        Ok(Self::from_model(Arc::new(NativeModel::new(cfg, seed)?)))
    }

    pub fn from_model(model: Arc<NativeModel>) -> NativeBackend {
        let chunk = NATIVE_PREFILL_CHUNK.clamp(1, model.cfg.max_seq);
        let scratch = Scratch::new(&model.cfg);
        let layout = pool_layout(&model.cfg, &KvPoolConfig::default());
        NativeBackend {
            model,
            batch: 0,
            prefill_chunk: chunk,
            score_mode: ScoreMode::Auto,
            pool_cfg: KvPoolConfig::default(),
            pool: PagePool::new(layout, 0),
            tables: vec![],
            index: PrefixIndex::new(0),
            cursors: vec![],
            k_cache_rows: vec![],
            scratch,
        }
    }

    pub fn model(&self) -> &NativeModel {
        &self.model
    }

    /// Current pool gauges (what this backend reports in every `StepOut`).
    pub fn kv_gauges(&self) -> KvPoolGauges {
        self.pool.gauges()
    }

    fn shadow_elems(&self, b: usize) -> usize {
        let c = &self.model.cfg;
        c.n_layers * b * c.n_kv_heads * c.max_seq * c.d_head
    }

    /// Select the score-kernel routing policy (default [`ScoreMode::Auto`]).
    pub fn set_score_mode(&mut self, mode: ScoreMode) {
        self.score_mode = mode;
        if mode == ScoreMode::MaskedDense {
            self.sync_oracle_cache();
        }
    }

    /// (Re)build the oracle's row-major shadow key cache. Tokens written
    /// *before* switching into oracle mode are transposed over from the
    /// paged dim-major cache (they mirror it; truncated dims stay zero,
    /// exactly what the `dim_keep` mask wrote); tokens written afterwards
    /// go through the independent row-major write path — set the mode
    /// before the first write for a fully independent oracle.
    fn sync_oracle_cache(&mut self) {
        let c = &self.model.cfg;
        let (d, s_cap, nkv, nl, b) = (c.d_head, c.max_seq, c.n_kv_heads, c.n_layers, self.batch);
        let n = self.shadow_elems(b);
        let rows = &mut self.k_cache_rows;
        rows.clear();
        rows.resize(n, 0.0);
        let layout = *self.pool.layout();
        let (ps, kd) = (layout.page_slots, layout.key_dims);
        for (lane, table) in self.tables.iter().enumerate() {
            for p in 0..s_cap.div_ceil(ps) {
                let Some(id) = table.page(p) else { continue };
                let filled = table.written().saturating_sub(p * ps).min(ps);
                for l in 0..nl {
                    for g in 0..nkv {
                        for local in 0..filled {
                            let s = p * ps + local;
                            let rb = (((l * b + lane) * nkv + g) * s_cap + s) * d;
                            for i in 0..kd {
                                // quant-generic read: dequantizes int8
                                // payloads, passes f32 through bit-exactly
                                rows[rb + i] = self.pool.key_at(id, l, g, i, local);
                            }
                        }
                    }
                }
            }
        }
    }

    pub fn score_mode(&self) -> ScoreMode {
        self.score_mode
    }

    /// One forward pass over `t` sequential tokens per lane (t = 1 for
    /// decode, t = chunk for prefill — identical arithmetic, so the
    /// decode/prefill consistency the PJRT path is tested for holds here
    /// by construction).
    fn step(
        &mut self,
        b: usize,
        tokens: &[i32],
        pos0: &[i32],
        t: usize,
        is_prefill: bool,
        slot_mask: &[f32],
        knobs: &AquaKnobs,
    ) -> Result<StepOut> {
        let model = self.model.clone();
        let c = &model.cfg;
        let (dm, d, nq, nkv, dff, s_cap, vocab) =
            (c.d_model, c.d_head, c.n_q_heads, c.n_kv_heads, c.d_ff, c.max_seq, c.vocab);
        let gsz = nq / nkv;
        if b != self.batch {
            bail!("native step: batch {b} but caches sized for {} (call empty_cache)", self.batch);
        }
        if tokens.len() != b * t || pos0.len() != b || slot_mask.len() != b * s_cap {
            bail!("native step: arg shape mismatch (b={b}, t={t})");
        }
        if knobs.dim_keep.len() != d {
            bail!("native step: dim_keep len {} != d_head {d}", knobs.dim_keep.len());
        }
        let k_dims = knobs.k_dims.clamp(1, d);
        let scale = (d as f32).powf(-0.5);
        let eps = c.norm_eps as f32;
        let score_mode = self.score_mode;
        if score_mode == ScoreMode::MaskedDense && self.k_cache_rows.len() != self.shadow_elems(b)
        {
            // mode was switched after empty_cache — bring the shadow up
            self.sync_oracle_cache();
        }
        let layout = *self.pool.layout();
        let (ps, kd) = (layout.page_slots, layout.key_dims);
        // Int8 pages are only readable through the fused dequantizing
        // kernels, so a quantized pool routes every non-oracle mode fused
        // (the oracle scores its own f32 shadow and dequantizes V reads).
        let use_fused = score_mode == ScoreMode::Fused
            || (layout.kv_quant == KvQuant::Int8 && score_mode != ScoreMode::MaskedDense);
        if kd < d && knobs.dim_keep[kd..].iter().any(|&m| m != 0.0) {
            bail!(
                "native step: dim_keep keeps dims beyond the pool's {kd} resident key dims \
                 (the memory knob is a cache-layout property — reconfigure the kv pool)"
            );
        }

        // Row-major [L, B, n_kv, S, d] base for the oracle's dense shadow.
        let vrow_base = |l: usize, lane: usize, g: usize| (((l * b + lane) * nkv + g) * s_cap) * d;

        // Prompt-chunk registration is live only on the shareable path
        // (the masked-dense oracle keeps an independent write path).
        let prefix_on = self.pool_cfg.prefix_cache && score_mode != ScoreMode::MaskedDense;
        let fp = if prefix_on { knob_fingerprint(knobs) } else { 0 };

        let mut logits_out = vec![0.0f32; b * t * vocab];
        let mut attn_acc = vec![0.0f32; c.n_layers * b * s_cap];
        let mut kernels = KernelCounters::default();

        // Split disjoint field borrows once: the persistent scratch, the
        // pool + page tables, the oracle shadow, and the (cloned-Arc)
        // model are independent.
        let pool = &mut self.pool;
        let tables = &mut self.tables;
        let index = &mut self.index;
        let cursors = &mut self.cursors;
        let k_rows = &mut self.k_cache_rows;
        let sc = &mut self.scratch;

        for lane in 0..b {
            let lane_mask = &slot_mask[lane * s_cap..(lane + 1) * s_cap];
            // Return pages H2O has fully drained (every slot in the mask
            // dead, page fully behind the write cursor) to the pool before
            // this call touches the lane.
            tables[lane].reclaim(pool, lane_mask);
            // Attendable slots: committed (engine's slot_mask) + positions
            // written earlier in this call. Committed indices are always
            // below the write cursor, so the list stays sorted.
            sc.att.clear();
            sc.att.extend((0..s_cap).filter(|&s| lane_mask[s] > 0.5));

            for ci in 0..t {
                let tok_raw = tokens[lane * t + ci];
                if tok_raw < 0 {
                    // padding / dead lane: no write, no compute; the logits
                    // row stays zero (the engine never reads it). Real
                    // tokens are always a chunk prefix, so nothing after
                    // this position needs the attendable set extended.
                    continue;
                }
                let pos = pos0[lane].max(0) as usize + ci;
                let writable = pos < s_cap;
                // `att` stays sorted: committed slots all sit below the
                // write cursor. The binary_search guards the clamped
                // full-lane case where `pos` is already attendable.
                if writable && sc.att.binary_search(&pos).is_err() {
                    sc.att.push(pos);
                }
                // Lease the page backing this position on first touch (one
                // page covers every layer and KV head of `page_slots`
                // consecutive positions, so this is the only lease point).
                // `ensure_mut` copies first when the page is shared with
                // another lane — writes never leak into a shared prefix.
                let page_id = if writable {
                    let id = tables[lane].ensure_mut(pool, pos / ps)?;
                    tables[lane].note_write(pos);
                    Some(id)
                } else {
                    None
                };
                let local = pos % ps;
                let tok = tok_raw.min(vocab as i32 - 1) as usize;
                let pe = pos.min(s_cap - 1);
                for (j, xv) in sc.x.iter_mut().enumerate() {
                    *xv = model.embed[tok * dm + j] + model.pos_embed[pe * dm + j];
                }

                for (l, lw) in model.layers.iter().enumerate() {
                    // ---- attention block --------------------------------
                    rmsnorm(&sc.x, &lw.attn_norm, eps, &mut sc.h);
                    matvec(&sc.h, &lw.wq, nq * d, &mut sc.qs);
                    matvec(&sc.h, &lw.wk, nkv * d, &mut sc.ks);
                    matvec(&sc.h, &lw.wv, nkv * d, &mut sc.vs);

                    if let Some(pid) = page_id {
                        for g in 0..nkv {
                            let k_raw = &sc.ks[g * d..(g + 1) * d];
                            if knobs.use_projection {
                                project(k_raw, model.projection(l, g), d, &mut sc.khat);
                            } else {
                                sc.khat.copy_from_slice(k_raw);
                            }
                            for (kv, &keep) in sc.khat.iter_mut().zip(&knobs.dim_keep) {
                                *kv *= keep;
                            }
                            if score_mode == ScoreMode::MaskedDense {
                                // oracle shadow: independent row-major write
                                // at full width (truncated dims are zeros —
                                // dim_keep already zeroed them)
                                let rb = vrow_base(l, lane, g) + pos * d;
                                k_rows[rb..rb + d].copy_from_slice(&sc.khat);
                            }
                            // dim-major key write into the leased page: one
                            // strided store per *resident* dim, paid once
                            // per token (not per decode step). Under int8
                            // the pool quantizes against (and deterministically
                            // grows) the page's per-(l, g) block scales.
                            pool.write_token(
                                pid,
                                l,
                                g,
                                local,
                                &sc.khat[..kd],
                                &sc.vs[g * d..(g + 1) * d],
                            );
                        }
                    }

                    sc.attn_out.fill(0.0);
                    let t_score = Instant::now();
                    if let Some(&hi) = sc.att.last() {
                        let n = hi + 1;
                        for qh in 0..nq {
                            let g = qh / gsz;
                            let q_raw = &sc.qs[qh * d..(qh + 1) * d];
                            if knobs.use_projection {
                                project(q_raw, model.projection(l, g), d, &mut sc.qhat);
                            } else {
                                sc.qhat.copy_from_slice(q_raw);
                            }
                            for (qv, &keep) in sc.qhat.iter_mut().zip(&knobs.dim_keep) {
                                *qv *= keep;
                            }
                            // Page-fused streaming path (PR 10): scores,
                            // online softmax, and the value reduction in one
                            // pass per resident page — each page loaded
                            // once, O(page_slots) kernel scratch. Selection
                            // is identical to the packed route below, so
                            // f32 scores are bit-identical to packed.
                            if use_fused {
                                let (qk, idx): (&[f32], &[usize]) = if k_dims == d {
                                    (&sc.qhat[..kd], &sc.all_dims[..kd])
                                } else {
                                    topk_indices_into(&sc.qhat, k_dims, &mut sc.idx);
                                    if kd < d {
                                        sc.idx.retain(|&i| i < kd);
                                    }
                                    for (j, &i) in sc.idx.iter().enumerate() {
                                        sc.qsel[j] = sc.qhat[i];
                                    }
                                    (&sc.qsel[..sc.idx.len()], &sc.idx[..])
                                };
                                let mut stats = FusedStats::default();
                                let out_h = &mut sc.attn_out[qh * d..(qh + 1) * d];
                                let osm = fused_attend(
                                    qk,
                                    idx,
                                    pool,
                                    &tables[lane],
                                    l,
                                    g,
                                    &sc.att,
                                    scale,
                                    &mut sc.page_scores,
                                    &mut sc.scores,
                                    out_h,
                                    &mut stats,
                                );
                                kernels.fused_passes += stats.pages;
                                kernels.dequant_ns += stats.dequant_ns;
                                kernels.simd_lanes_used =
                                    kernels.simd_lanes_used.max(simd_lanes() as u64);
                                if let Some(inv) = osm.finish() {
                                    let acc_base = (l * b + lane) * s_cap;
                                    for &s in &sc.att {
                                        attn_acc[acc_base + s] +=
                                            (sc.scores[s] - osm.m).exp() * inv;
                                    }
                                    for o in out_h.iter_mut() {
                                        *o *= inv;
                                    }
                                } else {
                                    out_h.fill(0.0);
                                }
                                continue;
                            }
                            // AQUA Algorithm 1: top-k |q̂| dims, then route to
                            // the cheapest equivalent kernel (all variants are
                            // bit-identical — see aqua::native tests).
                            if score_mode == ScoreMode::MaskedDense {
                                // Oracle: the pre-overhaul formulation —
                                // top-k mask, full-width masked-dense dot
                                // over the independent dense row-major
                                // shadow (no pool involvement at all).
                                topk_mask_into(&sc.qhat, k_dims, &mut sc.idx, &mut sc.mask);
                                let rb = vrow_base(l, lane, g);
                                aqua_scores_masked(
                                    &sc.qhat,
                                    &sc.mask,
                                    &k_rows[rb..rb + n * d],
                                    n,
                                    d,
                                    &mut sc.scores[..n],
                                );
                                kernels.dense += 1;
                            } else if k_dims == d && score_mode == ScoreMode::Auto {
                                // Full width: the selection is the identity
                                // over the resident dims (truncated dims are
                                // zero in q̂ and skipped by the kernel).
                                let table = &tables[lane];
                                scores_packed_paged(
                                    &sc.qhat[..kd],
                                    &sc.all_dims[..kd],
                                    pool,
                                    table,
                                    l,
                                    g,
                                    n,
                                    &mut sc.scores,
                                );
                                kernels.dense += 1;
                            } else {
                                topk_indices_into(&sc.qhat, k_dims, &mut sc.idx);
                                if kd < d {
                                    // non-resident dims carry q̂ = 0 (guard
                                    // above); dropping them preserves the
                                    // accumulation order of the kept dims
                                    sc.idx.retain(|&i| i < kd);
                                }
                                for (j, &i) in sc.idx.iter().enumerate() {
                                    sc.qsel[j] = sc.qhat[i];
                                }
                                let table = &tables[lane];
                                let use_sparse = match score_mode {
                                    ScoreMode::Sparse => true,
                                    ScoreMode::Packed => false,
                                    // eviction heuristic: holes in more than
                                    // half the prefix → touch only live slots
                                    _ => 2 * sc.att.len() < n,
                                };
                                if use_sparse {
                                    scores_at_paged(
                                        &sc.qsel, &sc.idx, pool, table, l, g, &sc.att,
                                        &mut sc.scores,
                                    );
                                    kernels.sparse += 1;
                                } else {
                                    scores_packed_paged(
                                        &sc.qsel, &sc.idx, pool, table, l, g, n, &mut sc.scores,
                                    );
                                    kernels.packed += 1;
                                }
                            }
                            // Softmax over the attendable set only.
                            let m = sc
                                .att
                                .iter()
                                .map(|&s| sc.scores[s] * scale)
                                .fold(f32::NEG_INFINITY, f32::max);
                            let mut denom = 0.0f32;
                            for &s in &sc.att {
                                let e = (sc.scores[s] * scale - m).exp();
                                sc.scores[s] = e; // reuse as unnormalized prob
                                denom += e;
                            }
                            if denom <= 0.0 {
                                continue;
                            }
                            let acc_base = (l * b + lane) * s_cap;
                            let out_h = &mut sc.attn_out[qh * d..(qh + 1) * d];
                            let table = &tables[lane];
                            for &s in &sc.att {
                                let p = sc.scores[s] / denom;
                                attn_acc[acc_base + s] += p;
                                // never-leased pages hold no values (the
                                // dense cache's zeros): probability mass is
                                // still accounted, the mix contributes 0
                                let Some(pid) = table.page(s / ps) else { continue };
                                let vo = layout.val_off(l, g, s % ps);
                                match layout.kv_quant {
                                    KvQuant::F32 => {
                                        let vrow = &pool.page(pid)[vo..vo + d];
                                        for (o, &vv) in out_h.iter_mut().zip(vrow) {
                                            *o += p * vv;
                                        }
                                    }
                                    KvQuant::Int8 => {
                                        // oracle under int8: dequantize the
                                        // value row through the block scale
                                        let a = p * pool.v_scale(pid, l, g);
                                        let qrow = &pool.page_i8(pid)[vo..vo + d];
                                        for (o, &qv) in out_h.iter_mut().zip(qrow) {
                                            *o += a * qv as f32;
                                        }
                                    }
                                }
                            }
                        }
                    }
                    kernels.score_ns += t_score.elapsed().as_nanos() as u64;
                    matvec(&sc.attn_out, &lw.wo, dm, &mut sc.o_proj);
                    for (xv, &ov) in sc.x.iter_mut().zip(&sc.o_proj) {
                        *xv += ov;
                    }

                    // ---- MLP block --------------------------------------
                    rmsnorm(&sc.x, &lw.mlp_norm, eps, &mut sc.h);
                    matvec(&sc.h, &lw.w1, dff, &mut sc.ff1);
                    silu_inplace(&mut sc.ff1);
                    matvec(&sc.ff1, &lw.w2, dm, &mut sc.ff2);
                    for (xv, &fv) in sc.x.iter_mut().zip(&sc.ff2) {
                        *xv += fv;
                    }
                }

                // Prompt-chunk registration: every layer of this token is
                // now written, so a page whose last slot this was becomes
                // shareable under its token-chain key. Decode tokens end
                // the prompt (generated content is never registered), and
                // so does any *causal impurity*: a token written while the
                // attendable set was not the full prefix (an H2O hole)
                // carries KV that is no longer a pure function of the
                // token chain — sharing it would break warm == cold.
                if prefix_on {
                    let pure = sc.att.len() == pos + 1;
                    let cur = &mut cursors[lane];
                    if !is_prefill || page_id.is_none() || !pure {
                        cur.dead = true;
                    } else {
                        if !cur.seeded && pos == 0 {
                            *cur = PrefixCursor { hash: fp, seeded: true, ..Default::default() };
                        }
                        if cur.seeded && !cur.dead {
                            if pos == cur.next {
                                cur.hash = fold_token(cur.hash, tok_raw);
                                cur.pending.push(tok_raw);
                                cur.next += 1;
                                if cur.next % ps == 0 {
                                    let chunk = std::mem::take(&mut cur.pending);
                                    let pid = tables[lane].page((cur.next - 1) / ps);
                                    if let Some(pid) = pid {
                                        // only pages this lane owns outright
                                        // and that carry no identity yet;
                                        // key the page only when the index
                                        // accepts it, and unkey a displaced
                                        // loser so it cannot strand as an
                                        // unreachable cached page
                                        if pool.ref_count(pid) == 1 && pool.page_key(pid) == 0 {
                                            match index.insert(cur.hash, pid, chunk) {
                                                Register::Fresh => {
                                                    pool.set_page_key(pid, cur.hash)?;
                                                }
                                                Register::Displaced(old) => {
                                                    pool.set_page_key(pid, cur.hash)?;
                                                    if old != pid {
                                                        pool.clear_page_key(old);
                                                    }
                                                }
                                                Register::Evicted(old) => {
                                                    pool.set_page_key(pid, cur.hash)?;
                                                    if old != pid {
                                                        pool.clear_page_key(old);
                                                    }
                                                    pool.note_prefix_eviction();
                                                }
                                            }
                                        }
                                    }
                                }
                            } else {
                                cur.dead = true;
                            }
                        }
                    }
                }

                rmsnorm(&sc.x, &model.final_norm, eps, &mut sc.xf);
                let row = &mut logits_out[(lane * t + ci) * vocab..(lane * t + ci + 1) * vocab];
                matvec(&sc.xf, &model.unembed, vocab, row);
            }
        }
        Ok(StepOut { logits: logits_out, attn_acc, kernels, kv: pool.gauges() })
    }
}

impl ExecBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn model_config(&self) -> &ModelConfig {
        &self.model.cfg
    }

    fn prefill_chunk(&self) -> usize {
        self.prefill_chunk
    }

    fn empty_cache(&mut self, b: usize) -> Result<()> {
        if b == 0 {
            bail!("native empty_cache: batch must be >= 1");
        }
        let c = &self.model.cfg;
        let layout = pool_layout(c, &self.pool_cfg);
        let pages_per_lane = layout.pages_for_slots(c.max_seq);
        // Uncapped default: the worst case every lane can ever need, so a
        // lease can only fail when a deployment pins a smaller budget (and
        // then its admission gate sheds before the backend ever stalls).
        let max_pages = self.pool_cfg.max_pages.unwrap_or(b * pages_per_lane);
        self.batch = b;
        self.pool = PagePool::new(layout, max_pages);
        self.tables = (0..b).map(|_| LanePageTable::new(pages_per_lane)).collect();
        self.index = PrefixIndex::new(self.pool_cfg.prefix_cache_pages);
        self.cursors = vec![PrefixCursor::default(); b];
        self.k_cache_rows.clear();
        if self.score_mode == ScoreMode::MaskedDense {
            self.k_cache_rows.resize(self.shadow_elems(b), 0.0);
        }
        Ok(())
    }

    fn configure_kv_pool(&mut self, cfg: KvPoolConfig) -> Result<()> {
        self.pool_cfg = cfg;
        Ok(())
    }

    fn retire_lane(&mut self, lane: usize) {
        if let Some(table) = self.tables.get_mut(lane) {
            table.release_all(&mut self.pool);
        }
        if let Some(cur) = self.cursors.get_mut(lane) {
            *cur = PrefixCursor::default();
        }
    }

    fn attach_prefix(
        &mut self,
        lane: usize,
        tokens: &[i32],
        knobs: &AquaKnobs,
    ) -> Result<PrefixAttach> {
        let none = PrefixAttach::default();
        if !self.pool_cfg.prefix_cache || self.score_mode == ScoreMode::MaskedDense {
            // the oracle scores an independent dense shadow with its own
            // write path — it must never skip writes, so it never attaches
            return Ok(none);
        }
        let Some(table) = self.tables.get(lane) else {
            bail!("attach_prefix: lane {lane} out of range (batch {})", self.batch);
        };
        if table.written() != 0 || table.leased_pages() != 0 {
            return Ok(none); // only a fresh lane can adopt a chain
        }
        let ps = self.pool.layout().page_slots;
        if tokens.len() <= ps {
            return Ok(none);
        }
        // Cap the walk so at least one prompt token still runs through
        // prefill — its logits seed the first sampled token.
        let max_chunks = ((tokens.len() - 1) / ps).min(table.num_pages());
        let mut h = knob_fingerprint(knobs);
        let mut attached = 0usize;
        let mut resurrected = 0usize;
        for c in 0..max_chunks {
            let chunk = &tokens[c * ps..(c + 1) * ps];
            if chunk.iter().any(|&t| t < 0) {
                break; // padding sentinels are not content
            }
            let h2 = fold_chunk(h, chunk);
            let Some(page) = self.index.lookup(&self.pool, h2, chunk) else { break };
            if self.pool.is_leased(page) {
                self.pool.retain(page)?;
            } else if self.pool.resurrect(page, h2).is_ok() {
                resurrected += 1;
            } else {
                break; // lost a race with a recycling lease
            }
            self.tables[lane].adopt(c, page);
            attached += ps;
            h = h2;
        }
        if attached > 0 {
            self.tables[lane].set_written(attached);
            // seed the cursor past the adopted prefix so the unmatched
            // tail keeps extending the registered chain
            self.cursors[lane] = PrefixCursor {
                hash: h,
                next: attached,
                pending: vec![],
                seeded: true,
                dead: false,
            };
        }
        Ok(PrefixAttach { tokens: attached, resurrected_pages: resurrected })
    }

    fn kv_gauges(&mut self) -> KvPoolGauges {
        self.pool.gauges()
    }

    fn prefill(
        &mut self,
        b: usize,
        tokens: &[i32],
        pos0: &[i32],
        slot_mask: &[f32],
        knobs: &AquaKnobs,
    ) -> Result<StepOut> {
        let chunk = self.prefill_chunk;
        self.step(b, tokens, pos0, chunk, true, slot_mask, knobs)
    }

    fn decode(
        &mut self,
        b: usize,
        tokens: &[i32],
        pos: &[i32],
        slot_mask: &[f32],
        knobs: &AquaKnobs,
    ) -> Result<StepOut> {
        self.step(b, tokens, pos, 1, false, slot_mask, knobs)
    }

    fn verify(
        &mut self,
        b: usize,
        tokens: &[i32],
        pos0: &[i32],
        t: usize,
        slot_mask: &[f32],
        knobs: &AquaKnobs,
    ) -> Result<StepOut> {
        // A verify pass is a multi-token decode: step() already handles
        // arbitrary window widths with in-call causality (each written
        // position joins the attendable set for the next), rewrites the
        // drafted KV in place through the normal write path (COW-safe),
        // and registers nothing in the prefix index (is_prefill = false
        // kills the cursor, so drafted content never becomes shareable).
        self.step(b, tokens, pos0, t, false, slot_mask, knobs)
    }

    fn supports_verify(&self) -> bool {
        true
    }

    fn rollback_lane(&mut self, lane: usize, to_len: usize) {
        if let Some(table) = self.tables.get_mut(lane) {
            table.rollback(&mut self.pool, to_len);
        }
    }
}

// ---------------------------------------------------------------------------
// Synthetic corpus (hermetic stand-in for artifacts/corpus/valid.txt)
// ---------------------------------------------------------------------------

/// Deterministic synthetic text corpus: newline-separated sentences over a
/// small lexicon, shaped like the build pipeline's anglish corpus. Lets
/// corpus-driven examples/benches/evals run with no artifacts present.
pub fn synthetic_corpus(bytes: usize, seed: u64) -> Vec<u8> {
    const SUBJECTS: [&str; 8] =
        ["the capital", "the color", "the sound", "the king", "the river", "the square root",
         "the opposite", "the shape"];
    const OBJECTS: [&str; 8] =
        ["velor", "tamrin", "the sky", "the sea", "marden", "oblon", "the moon", "quarzel"];
    const VALUES: [&str; 8] =
        ["blue", "loud", "round", "tamrin", "seven", "cold", "bright", "hollow"];
    let mut rng = Rng::new(seed ^ 0x5EED);
    let mut out = Vec::with_capacity(bytes + 64);
    while out.len() < bytes {
        let s = SUBJECTS[rng.below(SUBJECTS.len())];
        let o = OBJECTS[rng.below(OBJECTS.len())];
        let v = VALUES[rng.below(VALUES.len())];
        out.extend_from_slice(format!("{s} of {o} is {v} .\n").as_bytes());
    }
    out.truncate(bytes);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::dot;

    fn tiny() -> ModelConfig {
        ModelConfig::tiny("native-test")
    }

    fn exact_knobs(d: usize) -> AquaKnobs {
        AquaKnobs::exact(d)
    }

    #[test]
    fn projections_are_orthogonal() {
        let m = NativeModel::new(tiny(), 3).unwrap();
        let d = m.cfg.d_head;
        for l in 0..m.cfg.n_layers {
            for g in 0..m.cfg.n_kv_heads {
                let p = m.projection(l, g);
                for i in 0..d {
                    for j in 0..d {
                        let got = dot(&p[i * d..(i + 1) * d], &p[j * d..(j + 1) * d]);
                        let want = if i == j { 1.0 } else { 0.0 };
                        assert!((got - want).abs() < 1e-4, "P·Pᵀ[{i},{j}] = {got}");
                    }
                }
            }
        }
    }

    #[test]
    fn decode_is_deterministic_and_seed_sensitive() {
        let cfg = tiny();
        let d = cfg.d_head;
        let run = |seed: u64| -> Vec<f32> {
            let mut be = NativeBackend::new(tiny(), seed).unwrap();
            be.empty_cache(1).unwrap();
            let mask = vec![0.0f32; cfg.max_seq];
            be.decode(1, &[65], &[0], &mask, &exact_knobs(d)).unwrap().logits
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn attention_mass_sums_to_layers_times_heads() {
        let cfg = tiny();
        let d = cfg.d_head;
        let mut be = NativeBackend::new(cfg.clone(), 1).unwrap();
        be.empty_cache(2).unwrap();
        let mut mask = vec![0.0f32; 2 * cfg.max_seq];
        for (i, &t) in [10i32, 20, 30].iter().enumerate() {
            let out = be
                .decode(2, &[t, t + 1], &[i as i32, i as i32], &mask, &exact_knobs(d))
                .unwrap();
            for lane in 0..2 {
                let mut mass = 0.0f32;
                for l in 0..cfg.n_layers {
                    let base = (l * 2 + lane) * cfg.max_seq;
                    mass += out.attn_acc[base..base + cfg.max_seq].iter().sum::<f32>();
                }
                let expect = (cfg.n_layers * cfg.n_q_heads) as f32;
                assert!((mass - expect).abs() < 1e-3, "lane {lane} mass {mass} vs {expect}");
            }
            mask[i] = 1.0;
            mask[cfg.max_seq + i] = 1.0;
            assert!(out.logits.iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn prefill_matches_token_by_token_decode() {
        let cfg = tiny();
        let d = cfg.d_head;
        let toks: Vec<i32> = b"the blue sea".iter().map(|&b| b as i32).collect();
        let n = toks.len();
        let knobs = AquaKnobs { k_dims: d / 2, dim_keep: vec![1.0; d], use_projection: true };

        // decode chain
        let mut bd = NativeBackend::new(cfg.clone(), 5).unwrap();
        bd.empty_cache(1).unwrap();
        let mut mask = vec![0.0f32; cfg.max_seq];
        let mut last = vec![];
        for (i, &t) in toks.iter().enumerate() {
            last = bd.decode(1, &[t], &[i as i32], &mask, &knobs).unwrap().logits;
            mask[i] = 1.0;
        }

        // one prefill call (pad to the chunk)
        let mut bp = NativeBackend::new(cfg.clone(), 5).unwrap();
        bp.empty_cache(1).unwrap();
        let chunk = bp.prefill_chunk();
        assert!(n <= chunk, "test prompt must fit one chunk");
        let mut padded = vec![0i32; chunk];
        padded[..n].copy_from_slice(&toks);
        let mask0 = vec![0.0f32; cfg.max_seq];
        let out = bp.prefill(1, &padded, &[0], &mask0, &knobs).unwrap();
        let pre = &out.logits[(n - 1) * cfg.vocab..n * cfg.vocab];
        let diff = pre.iter().zip(&last).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(diff < 1e-4, "prefill/decode disagree by {diff}");
    }

    #[test]
    fn knob_inputs_change_the_logits() {
        let cfg = tiny();
        let d = cfg.d_head;
        let mut be = NativeBackend::new(cfg.clone(), 9).unwrap();
        be.empty_cache(1).unwrap();
        let mut mask = vec![0.0f32; cfg.max_seq];
        // build a few slots of context first (projected cache, all dims kept)
        let ctx = AquaKnobs { k_dims: d, dim_keep: vec![1.0; d], use_projection: true };
        for i in 0..6usize {
            be.decode(1, &[40 + i as i32], &[i as i32], &mask, &ctx).unwrap();
            mask[i] = 1.0;
        }
        let probe = |be: &mut NativeBackend, knobs: &AquaKnobs| -> Vec<f32> {
            be.decode(1, &[46], &[6], &mask, knobs).unwrap().logits
        };
        let full = probe(&mut be, &AquaKnobs { k_dims: d, dim_keep: vec![1.0; d], use_projection: true });
        let k2 = probe(&mut be, &AquaKnobs { k_dims: 2, dim_keep: vec![1.0; d], use_projection: true });
        let max_diff =
            full.iter().zip(&k2).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(max_diff > 1e-4, "k_dims input has no effect");

        let mut keep = vec![1.0f32; d];
        for k in keep.iter_mut().skip(d - d / 4) {
            *k = 0.0;
        }
        let sliced = probe(&mut be, &AquaKnobs { k_dims: d, dim_keep: keep, use_projection: true });
        let max_diff =
            full.iter().zip(&sliced).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(max_diff > 1e-5, "dim_keep input has no effect");
    }

    #[test]
    fn orthogonal_projection_is_exact_at_k_equals_d() {
        // Lemma A.4 natively: projecting q and k by the same orthogonal P
        // preserves scores, so k = d with projection must match the
        // identity-P baseline up to f32 rounding.
        let cfg = tiny();
        let d = cfg.d_head;
        let toks: Vec<i32> = b"rotation".iter().map(|&b| b as i32).collect();
        let run = |use_projection: bool| -> Vec<f32> {
            let knobs = AquaKnobs { k_dims: d, dim_keep: vec![1.0; d], use_projection };
            let mut be = NativeBackend::new(tiny(), 11).unwrap();
            be.empty_cache(1).unwrap();
            let mut mask = vec![0.0f32; cfg.max_seq];
            let mut last = vec![];
            for (i, &t) in toks.iter().enumerate() {
                last = be.decode(1, &[t], &[i as i32], &mask, &knobs).unwrap().logits;
                mask[i] = 1.0;
            }
            last
        };
        let base = run(false);
        let rot = run(true);
        let diff = base.iter().zip(&rot).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(diff < 1e-2, "rotation changed logits by {diff}");
    }

    #[test]
    fn score_modes_agree_and_count_their_kernels() {
        // The four routings must produce identical logits (the kernels are
        // bit-identical; the oracle differs only in touching zeroed dims)
        // and must report the kernel variant they actually ran.
        let cfg = tiny();
        let d = cfg.d_head;
        let toks: Vec<i32> = b"parity".iter().map(|&b| b as i32).collect();
        let run = |mode: ScoreMode, k_dims: usize| -> (Vec<f32>, KernelCounters) {
            let mut be = NativeBackend::new(tiny(), 21).unwrap();
            be.set_score_mode(mode);
            be.empty_cache(1).unwrap();
            let knobs = AquaKnobs { k_dims, dim_keep: vec![1.0; d], use_projection: true };
            let mut mask = vec![0.0f32; cfg.max_seq];
            let mut last = vec![];
            let mut counters = KernelCounters::default();
            for (i, &t) in toks.iter().enumerate() {
                let out = be.decode(1, &[t], &[i as i32], &mask, &knobs).unwrap();
                counters.merge(&out.kernels);
                last = out.logits;
                mask[i] = 1.0;
            }
            (last, counters)
        };
        for k_dims in [d / 4, d / 2, d] {
            let (oracle, co) = run(ScoreMode::MaskedDense, k_dims);
            assert!(co.dense > 0 && co.sparse == 0 && co.packed == 0);
            let (packed, cp) = run(ScoreMode::Packed, k_dims);
            assert!(cp.packed > 0 && cp.dense == 0);
            let (sparse, cs) = run(ScoreMode::Sparse, k_dims);
            assert!(cs.sparse > 0 && cs.dense == 0);
            let (auto, ca) = run(ScoreMode::Auto, k_dims);
            assert!(ca.calls() > 0);
            if k_dims == d {
                assert!(ca.dense > 0, "auto at k=d must route dense");
            }
            assert_eq!(oracle, packed, "packed vs oracle at k={k_dims}");
            assert_eq!(oracle, sparse, "sparse vs oracle at k={k_dims}");
            assert_eq!(oracle, auto, "auto vs oracle at k={k_dims}");
        }
    }

    #[test]
    fn negative_tokens_are_skipped_as_padding() {
        let cfg = tiny();
        let d = cfg.d_head;
        // lane 1 is dead (-1): its logits row stays zero, and lane 0's
        // output matches a solo batch=1 run exactly
        let mut b2 = NativeBackend::new(tiny(), 4).unwrap();
        b2.empty_cache(2).unwrap();
        let mask2 = vec![0.0f32; 2 * cfg.max_seq];
        let out = b2.decode(2, &[65, -1], &[0, 0], &mask2, &exact_knobs(d)).unwrap();
        assert!(out.logits[cfg.vocab..].iter().all(|&x| x == 0.0), "pad lane logits not zero");
        assert!(out.attn_acc.iter().sum::<f32>() > 0.0);

        let mut b1 = NativeBackend::new(tiny(), 4).unwrap();
        b1.empty_cache(1).unwrap();
        let mask1 = vec![0.0f32; cfg.max_seq];
        let solo = b1.decode(1, &[65], &[0], &mask1, &exact_knobs(d)).unwrap();
        assert_eq!(&out.logits[..cfg.vocab], &solo.logits[..]);
    }

    #[test]
    fn pool_pages_lease_on_demand_and_free_on_retire() {
        let cfg = tiny();
        let d = cfg.d_head;
        let mut be = NativeBackend::new(tiny(), 2).unwrap();
        be.empty_cache(2).unwrap();
        assert_eq!(be.kv_gauges().pages_in_use, 0, "no pages before the first write");
        let mut mask = vec![0.0f32; 2 * cfg.max_seq];
        let mut last = KvPoolGauges::default();
        for i in 0..20usize {
            let out =
                be.decode(2, &[65, 66], &[i as i32, i as i32], &mask, &exact_knobs(d)).unwrap();
            mask[i] = 1.0;
            mask[cfg.max_seq + i] = 1.0;
            last = out.kv;
        }
        // 20 positions at 16 slots/page = 2 pages per lane, 2 lanes — far
        // below the dense preallocation (ceil(160/16) = 10 pages per lane)
        assert_eq!(last.pages_in_use, 4);
        assert_eq!(last.resident_bytes, last.pages_in_use * last.page_bytes);
        assert!(last.alloc_stalls == 0 && last.leases == 4);
        be.retire_lane(0);
        assert_eq!(be.kv_gauges().pages_in_use, 2, "retire frees lane 0's pages");
        be.retire_lane(1);
        let g = be.kv_gauges();
        assert_eq!(g.pages_in_use, 0);
        assert_eq!(g.pages_hwm, 4, "freed backing stays on the free list for reuse");
    }

    #[test]
    fn truncated_pool_matches_oracle_and_shrinks_pages() {
        // kv_keep = 0.5 (s_ratio = 0.5): resident key dims halve, page
        // bytes shrink by the (kd + d) / 2d ratio, and the packed score
        // path over the truncated pool still matches the full-width
        // masked-dense oracle exactly (the truncated dims were zeroed by
        // dim_keep before they ever reached either cache).
        use crate::aqua::policy::AquaConfig;
        let cfg = tiny();
        let d = cfg.d_head;
        let aqua = AquaConfig { s_ratio: 0.5, ..Default::default() };
        let knobs = AquaKnobs::from_config(&aqua, d);
        let kd = aqua.mem_dims(d);
        let run = |mode: ScoreMode, truncate: bool| -> (Vec<f32>, u64) {
            let mut be = NativeBackend::new(tiny(), 31).unwrap();
            if truncate {
                be.configure_kv_pool(KvPoolConfig { key_dims: Some(kd), ..Default::default() })
                    .unwrap();
            }
            be.set_score_mode(mode);
            be.empty_cache(1).unwrap();
            let mut mask = vec![0.0f32; cfg.max_seq];
            let (mut last, mut bytes) = (vec![], 0u64);
            for (i, &t) in b"memory".iter().enumerate() {
                let out = be.decode(1, &[t as i32], &[i as i32], &mask, &knobs).unwrap();
                mask[i] = 1.0;
                last = out.logits;
                bytes = out.kv.page_bytes;
            }
            (last, bytes)
        };
        let (oracle, full_bytes) = run(ScoreMode::MaskedDense, false);
        let (trunc, trunc_bytes) = run(ScoreMode::Auto, true);
        assert_eq!(oracle, trunc, "truncated pool output diverged from the oracle");
        assert!(trunc_bytes < full_bytes);
        assert_eq!(trunc_bytes as usize * 2 * d, full_bytes as usize * (kd + d));
    }

    #[test]
    fn pool_rejects_dim_keep_beyond_resident_dims() {
        let cfg = tiny();
        let d = cfg.d_head;
        let mut be = NativeBackend::new(tiny(), 1).unwrap();
        be.configure_kv_pool(KvPoolConfig { key_dims: Some(d / 2), ..Default::default() })
            .unwrap();
        be.empty_cache(1).unwrap();
        let mask = vec![0.0f32; cfg.max_seq];
        // full-width dim_keep against a half-width pool must error, not
        // silently drop key data
        let err = be.decode(1, &[65], &[0], &mask, &exact_knobs(d));
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err()).contains("resident key dims"));
    }

    #[test]
    fn exhausted_pool_fails_deterministically() {
        let cfg = tiny();
        let d = cfg.d_head;
        let mut be = NativeBackend::new(tiny(), 1).unwrap();
        // one page of 16 slots: position 16 needs a second page → error
        be.configure_kv_pool(KvPoolConfig { max_pages: Some(1), ..Default::default() })
            .unwrap();
        be.empty_cache(1).unwrap();
        let mut mask = vec![0.0f32; cfg.max_seq];
        for i in 0..16usize {
            be.decode(1, &[65], &[i as i32], &mask, &exact_knobs(d)).unwrap();
            mask[i] = 1.0;
        }
        let err = be.decode(1, &[65], &[16], &mask, &exact_knobs(d));
        assert!(err.is_err(), "lease beyond the page budget must fail");
        assert!(format!("{:#}", err.unwrap_err()).contains("kv pool exhausted"));
        assert_eq!(be.kv_gauges().alloc_stalls, 1);
    }

    #[test]
    fn synthetic_corpus_is_deterministic_lines() {
        let a = synthetic_corpus(2048, 1);
        let b = synthetic_corpus(2048, 1);
        assert_eq!(a, b);
        assert_eq!(a.len(), 2048);
        assert!(a.split(|&b| b == b'\n').next().unwrap().len() > 8);
        assert_ne!(a, synthetic_corpus(2048, 2));
    }
}
