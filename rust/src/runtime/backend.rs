//! Pluggable execution backends: the contract between the coordinator and
//! whatever actually runs the model.
//!
//! The engine consumes exactly three operations — allocate/zero the KV
//! caches for a batch, run one prefill chunk, run one decode step — plus
//! logits/attention readback. [`ExecBackend`] captures that surface; the
//! caches themselves are *owned by the backend* (PJRT keeps them as
//! device literals, the native backend as plain `Vec<f32>`), while the
//! engine stays the authority on slot validity via the `slot_mask` input
//! it passes on every call (see `coordinator::kvcache`).
//!
//! Implementations:
//! * [`super::native::NativeBackend`] — hermetic pure-rust reference
//!   backend (default; makes the full serving path testable offline).
//! * `runtime::exec::ModelRuntime` behind [`PjrtBackend`] — the
//!   AOT-compiled PJRT production path (`--features pjrt`).

use std::sync::Arc;

use anyhow::Result;

use super::fault::{FaultBackend, FaultPlan};
use super::native::{synthetic_corpus, NativeBackend, NativeModel};
use crate::aqua::policy::AquaConfig;
use crate::kvpool::{KvPoolConfig, KvPoolGauges};
use crate::model::config::ModelConfig;

#[cfg(feature = "pjrt")]
use super::artifacts::ModelArtifacts;
#[cfg(feature = "pjrt")]
use super::exec::ModelRuntime;

/// Resolved AQUA runtime inputs for one prefill/decode call (the knobs are
/// *inputs*, not compile-time state — switching configs never recompiles).
#[derive(Debug, Clone)]
pub struct AquaKnobs {
    /// Top-k dims retained by the dynamic magnitude selection (≤ d_head).
    pub k_dims: usize,
    /// [d_head] AQUA-Memory static keep mask (leading dims kept).
    pub dim_keep: Vec<f32>,
    /// Calibrated projection on (false = identity P: exact baseline).
    pub use_projection: bool,
}

impl AquaKnobs {
    pub fn from_config(aqua: &AquaConfig, d_head: usize) -> AquaKnobs {
        AquaKnobs {
            k_dims: aqua.k_dims(d_head),
            dim_keep: aqua.dim_keep_mask(d_head),
            use_projection: aqua.use_projection,
        }
    }

    /// Exact standard attention (k = d, all dims kept, identity P).
    pub fn exact(d_head: usize) -> AquaKnobs {
        AquaKnobs { k_dims: d_head, dim_keep: vec![1.0; d_head], use_projection: false }
    }
}

/// A backend step failure the backend can blame on one specific lane.
/// Carried in the `anyhow` error chain (`err.downcast_ref::<LaneError>()`
/// traverses contexts) so the engine can contain the failure to that lane
/// instead of killing the whole pass.
///
/// **Contract:** a backend returning a `LaneError` must not have mutated
/// *any* lane's KV or cache state in the failing call — the engine retires
/// only the blamed lane and re-runs the pass, and the surviving lanes'
/// greedy outputs must stay bit-identical to a failure-free run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaneError(pub usize);

impl std::fmt::Display for LaneError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "backend step failed for lane {}", self.0)
    }
}

impl std::error::Error for LaneError {}

/// Which score kernels a backend step actually ran, plus the time spent on
/// the attention score path — the observability the serving demo and the
/// `/stats`/`/metrics` endpoints surface (backends that cannot introspect,
/// like PJRT's fused executables, report zeros).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct KernelCounters {
    /// Full-width dense/masked-dense score computations (per head-call).
    pub dense: u64,
    /// Slot-subset sparse score computations.
    pub sparse: u64,
    /// Contiguous packed (dim-major) score computations.
    pub packed: u64,
    /// Nanoseconds in the attention score path (selection + scores +
    /// softmax + value mix), summed over lanes/tokens/layers. For threaded
    /// backends this is CPU time across workers, not wall time.
    pub score_ns: u64,
    /// Page-fused streaming attention passes: one per resident KV page
    /// streamed (scores + online softmax + value mix in a single load of
    /// the page). `fused_passes / (lanes · layers · heads)` = pages each
    /// decode call touched — the read-each-page-once invariant the fused
    /// bench asserts.
    pub fused_passes: u64,
    /// f32 lanes per SIMD op on the fused path (8 = AVX f32x8, 1 =
    /// scalar fallback, 0 = fused path not used). Merged by max, not sum.
    pub simd_lanes_used: u64,
    /// Nanoseconds inside int8-dequantizing fused page passes (subset of
    /// `score_ns`); 0 under `kv_quant=f32`.
    pub dequant_ns: u64,
}

impl KernelCounters {
    pub fn merge(&mut self, other: &KernelCounters) {
        self.dense += other.dense;
        self.sparse += other.sparse;
        self.packed += other.packed;
        self.score_ns += other.score_ns;
        self.fused_passes += other.fused_passes;
        self.simd_lanes_used = self.simd_lanes_used.max(other.simd_lanes_used);
        self.dequant_ns += other.dequant_ns;
    }

    /// Total score-kernel invocations of any variant (the fused path
    /// counts per-page passes separately in `fused_passes`).
    pub fn calls(&self) -> u64 {
        self.dense + self.sparse + self.packed
    }

    /// Which score path dominated this step, as a small stable code for
    /// the trace `Score` event: 0 dense, 1 sparse, 2 packed, 3 mixed (or
    /// none — e.g. PJRT's opaque fused executables), 4 fused-only.
    pub fn dominant_mode(&self) -> u64 {
        let nonzero = [self.dense, self.sparse, self.packed];
        let variants = nonzero.iter().filter(|&&c| c > 0).count();
        if self.fused_passes > 0 {
            return if variants == 0 { 4 } else { 3 };
        }
        match variants {
            1 if self.dense > 0 => 0,
            1 if self.sparse > 0 => 1,
            1 => 2,
            _ => 3,
        }
    }
}

/// Result of a prefix-cache attach attempt (`ExecBackend::attach_prefix`).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PrefixAttach {
    /// Prompt tokens covered by adopted shared pages (always a multiple of
    /// the pool's `page_slots`; 0 = no reusable chain).
    pub tokens: usize,
    /// Of the adopted pages, how many were *resurrected* from the cached
    /// (refcount-zero) state rather than shared with a live holder — new
    /// resident memory the admission accounting must charge to this
    /// request (live-shared pages are already covered by their holders'
    /// reservations).
    pub resurrected_pages: usize,
}

/// Outputs of one backend step (prefill chunk or decode step).
#[derive(Debug, Default)]
pub struct StepOut {
    /// Decode: [B, vocab]. Prefill: [B, C, vocab]. Row-major.
    pub logits: Vec<f32>,
    /// [L, B, S] attention mass per KV slot accumulated over this call
    /// (summed over query heads, and over the chunk for prefill) — the
    /// H2O policy's food.
    pub attn_acc: Vec<f32>,
    /// Score-kernel accounting for this call.
    pub kernels: KernelCounters,
    /// KV-pool gauges at the end of this call (zeros for backends with
    /// opaque/dense caches, e.g. PJRT). Reported per step so threaded
    /// backends need no cross-thread query path — the sharded backend sums
    /// its workers' gauges during the gather.
    pub kv: KvPoolGauges,
}

/// One served model's execution surface. Object-safe: the engine holds a
/// `Box<dyn ExecBackend>` and never learns which implementation it drives.
pub trait ExecBackend {
    /// Short implementation tag for logs/UIs ("native", "pjrt").
    fn name(&self) -> &'static str;

    /// The model being served.
    fn model_config(&self) -> &ModelConfig;

    /// Tokens consumed per lane per prefill call.
    fn prefill_chunk(&self) -> usize;

    /// Allocate (or reset) zeroed KV caches for `b` lanes. Must be called
    /// before the first prefill/decode and whenever the batch size changes.
    fn empty_cache(&mut self, b: usize) -> Result<()>;

    /// Shape the backend's paged KV pool (resident key dims, page size,
    /// page budget). Takes effect at the next `empty_cache`. Backends with
    /// dense/opaque caches (PJRT) ignore it — the engine still reports
    /// their cost-model bytes, it just cannot page them.
    fn configure_kv_pool(&mut self, _cfg: KvPoolConfig) -> Result<()> {
        Ok(())
    }

    /// The engine finished (or is recycling) `lane`: backends with paged
    /// caches drop the lane's page references (pages free at refcount
    /// zero). Dense backends ignore it (the slots are simply overwritten
    /// by the next occupant). Also undoes a prior `attach_prefix` on a
    /// lane the engine decided not to admit after all.
    fn retire_lane(&mut self, _lane: usize) {}

    /// Try to adopt a shared KV page chain for `lane`'s prompt before any
    /// prefill work is spent: the longest registered prefix of `tokens`
    /// (in full `page_slots` chunks, capped so at least one prompt token
    /// still runs through `prefill` to produce logits) is mapped into the
    /// lane and its pages' refcounts raised. Returns how much was
    /// attached; the lane's positions `0..tokens` are then already written
    /// and attendable. Backends without a prefix cache attach nothing.
    fn attach_prefix(
        &mut self,
        _lane: usize,
        _tokens: &[i32],
        _knobs: &AquaKnobs,
    ) -> Result<PrefixAttach> {
        Ok(PrefixAttach::default())
    }

    /// Point-in-time KV pool gauges (the same numbers `StepOut::kv`
    /// reports, queryable between steps — the engine's memory-aware
    /// admission and the leak audits use this). Dense backends report
    /// zeros.
    fn kv_gauges(&mut self) -> KvPoolGauges {
        KvPoolGauges::default()
    }

    /// One prefill chunk: `tokens` is [B, C] row-major, `pos0` the per-lane
    /// write position of the chunk's first token, `slot_mask` [B, S] the
    /// currently attendable slots (freshly written chunk positions become
    /// attendable causally within the call). Token values `< 0` are
    /// padding/dead positions: backends may skip them and their logits are
    /// unspecified (the engine never reads them).
    fn prefill(
        &mut self,
        b: usize,
        tokens: &[i32],
        pos0: &[i32],
        slot_mask: &[f32],
        knobs: &AquaKnobs,
    ) -> Result<StepOut>;

    /// One decode step: `tokens`/`pos` are [B]; each lane's token is
    /// written at `pos` and attends over `slot_mask` ∪ {pos}. Token values
    /// `< 0` mark dead lanes (same contract as prefill padding).
    fn decode(
        &mut self,
        b: usize,
        tokens: &[i32],
        pos: &[i32],
        slot_mask: &[f32],
        knobs: &AquaKnobs,
    ) -> Result<StepOut>;

    /// Multi-position verify scoring for self-speculative decoding:
    /// `tokens` is [B, t] row-major (each lane's pending token followed by
    /// its drafted block, `-1`-padded), `pos0` [B] the per-lane write
    /// position of the window's first token. Every non-padding token is
    /// (re)written at `pos0 + i` — *overwriting* any approximate KV the
    /// sparse draft pass left there — and attends causally over
    /// `slot_mask` ∪ the window's earlier positions, exactly like a
    /// prefill chunk but without registering anything in a prefix cache.
    /// Logits are [B, t, vocab]; row `i` is the exact next-token
    /// distribution after the window's first `i + 1` tokens. Backends that
    /// cannot score multiple positions mid-sequence (`supports_verify()
    /// == false`) error.
    fn verify(
        &mut self,
        b: usize,
        tokens: &[i32],
        pos0: &[i32],
        t: usize,
        slot_mask: &[f32],
        knobs: &AquaKnobs,
    ) -> Result<StepOut> {
        let _ = (b, tokens, pos0, t, slot_mask, knobs);
        anyhow::bail!("backend '{}' does not support speculative verify", self.name())
    }

    /// Whether `verify` is implemented — the engine only enables
    /// speculative decoding on backends that report true.
    fn supports_verify(&self) -> bool {
        false
    }

    /// Rewind `lane`'s KV write cursor to `to_len` tokens, un-appending
    /// (freeing) any pages that lie wholly past it — the speculative
    /// rollback past the verifier's first rejection. Never touches pages
    /// shared with other lanes (drafted pages are lane-private by the COW
    /// write path). Dense backends ignore it: the engine's slot mask
    /// already marks the rolled-back positions dead, and their slots are
    /// overwritten positionally on the next write.
    fn rollback_lane(&mut self, _lane: usize, _to_len: usize) {}
}

// ---------------------------------------------------------------------------
// PJRT adapter
// ---------------------------------------------------------------------------

/// `ModelRuntime` behind the trait: caches round-trip as device literals
/// owned here; the runtime (params + compiled executables) is shared.
#[cfg(feature = "pjrt")]
pub struct PjrtBackend {
    rt: Arc<ModelRuntime>,
    cache: Option<(xla::Literal, xla::Literal)>,
}

#[cfg(feature = "pjrt")]
impl PjrtBackend {
    pub fn new(rt: Arc<ModelRuntime>) -> PjrtBackend {
        PjrtBackend { rt, cache: None }
    }

    pub fn runtime(&self) -> &ModelRuntime {
        &self.rt
    }

    fn caches(&self) -> Result<(&xla::Literal, &xla::Literal)> {
        let (k, v) = self
            .cache
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("PjrtBackend: empty_cache not called"))?;
        Ok((k, v))
    }
}

#[cfg(feature = "pjrt")]
impl ExecBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn model_config(&self) -> &ModelConfig {
        &self.rt.cfg
    }

    fn prefill_chunk(&self) -> usize {
        self.rt.prefill_chunk
    }

    fn empty_cache(&mut self, b: usize) -> Result<()> {
        self.cache = Some(self.rt.empty_cache(b)?);
        Ok(())
    }

    fn prefill(
        &mut self,
        b: usize,
        tokens: &[i32],
        pos0: &[i32],
        slot_mask: &[f32],
        knobs: &AquaKnobs,
    ) -> Result<StepOut> {
        // The AOT executables have fixed shapes and gather embed[token]
        // unconditionally — map the `< 0` padding sentinel back to the
        // harmless token 0 they were compiled against.
        let toks: Vec<i32> = tokens.iter().map(|&t| t.max(0)).collect();
        let (k, v) = self.caches()?;
        let out = self.rt.prefill(
            b,
            &toks,
            pos0,
            k,
            v,
            slot_mask,
            knobs.k_dims as i32,
            &knobs.dim_keep,
            knobs.use_projection,
        )?;
        self.cache = Some((out.k_cache, out.v_cache));
        Ok(StepOut {
            logits: out.logits,
            attn_acc: out.attn_acc,
            kernels: KernelCounters::default(),
            kv: KvPoolGauges::default(),
        })
    }

    fn decode(
        &mut self,
        b: usize,
        tokens: &[i32],
        pos: &[i32],
        slot_mask: &[f32],
        knobs: &AquaKnobs,
    ) -> Result<StepOut> {
        let toks: Vec<i32> = tokens.iter().map(|&t| t.max(0)).collect();
        let (k, v) = self.caches()?;
        let out = self.rt.decode(
            b,
            &toks,
            pos,
            k,
            v,
            slot_mask,
            knobs.k_dims as i32,
            &knobs.dim_keep,
            knobs.use_projection,
        )?;
        self.cache = Some((out.k_cache, out.v_cache));
        Ok(StepOut {
            logits: out.logits,
            attn_acc: out.attn_acc,
            kernels: KernelCounters::default(),
            kv: KvPoolGauges::default(),
        })
    }
}

// ---------------------------------------------------------------------------
// Backend selection surface
// ---------------------------------------------------------------------------

/// A `Send`-able recipe that constructs its backend *on the calling
/// thread* — required for `EngineHandle::spawn`, because PJRT handles are
/// not `Send` (the native model, plain f32 buffers, is). `Clone` so the
/// supervisor can rebuild the backend across engine restarts.
#[derive(Clone)]
pub enum BackendRecipe {
    Native(Arc<NativeModel>),
    Sharded(Arc<NativeModel>, usize),
    /// Fault-injecting wrapper over an inner recipe (chaos testing).
    Fault(Box<BackendRecipe>, FaultPlan),
    #[cfg(feature = "pjrt")]
    Pjrt(ModelArtifacts),
}

impl BackendRecipe {
    /// The backend kind this recipe constructs ("native", "sharded",
    /// "pjrt") — mirrors `BackendSpec::name`.
    pub fn kind(&self) -> &'static str {
        match self {
            BackendRecipe::Native(_) => "native",
            BackendRecipe::Sharded(..) => "sharded",
            BackendRecipe::Fault(..) => "fault",
            #[cfg(feature = "pjrt")]
            BackendRecipe::Pjrt(_) => "pjrt",
        }
    }

    pub fn build(&self) -> Result<Box<dyn ExecBackend>> {
        match self {
            BackendRecipe::Native(model) => {
                Ok(Box::new(NativeBackend::from_model(model.clone())))
            }
            BackendRecipe::Sharded(model, threads) => {
                Ok(Box::new(super::sharded::ShardedBackend::from_model(model.clone(), *threads)))
            }
            BackendRecipe::Fault(inner, plan) => {
                Ok(Box::new(FaultBackend::new(inner.build()?, plan.clone())))
            }
            #[cfg(feature = "pjrt")]
            BackendRecipe::Pjrt(mart) => {
                let rt = Arc::new(ModelRuntime::load(mart)?);
                Ok(Box::new(PjrtBackend::new(rt)))
            }
        }
    }
}

/// How to construct backends for one serving/eval session. Sweeps build
/// one engine per operating point; the spec shares the expensive state
/// across builds (native weights; the PJRT runtime with its compiled
/// executables, memoized on first use).
pub enum BackendSpec {
    Native(Arc<NativeModel>),
    /// Lane-sharded multi-threaded native backend (`threads` workers).
    Sharded(Arc<NativeModel>, usize),
    /// Deterministic fault-injection wrapper over an inner spec — spelled
    /// `fault:<inner>,k=v,...` (or with `;` separators) on the CLI and in
    /// deployment specs; see [`FaultPlan`] for the knobs.
    Fault(Box<BackendSpec>, FaultPlan),
    #[cfg(feature = "pjrt")]
    Pjrt {
        mart: ModelArtifacts,
        rt: std::cell::RefCell<Option<Arc<ModelRuntime>>>,
    },
}

impl BackendSpec {
    /// Hermetic native backend: a deterministic tiny transformer seeded
    /// from `seed` (see `NativeModel`).
    pub fn native(cfg: ModelConfig, seed: u64) -> Result<BackendSpec> {
        Ok(BackendSpec::Native(Arc::new(NativeModel::new(cfg, seed)?)))
    }

    /// Sharded backend over the same deterministic native model.
    pub fn sharded(cfg: ModelConfig, seed: u64, threads: usize) -> Result<BackendSpec> {
        Ok(BackendSpec::Sharded(Arc::new(NativeModel::new(cfg, seed)?), threads))
    }

    #[cfg(feature = "pjrt")]
    pub fn pjrt(mart: ModelArtifacts) -> BackendSpec {
        BackendSpec::Pjrt { mart, rt: std::cell::RefCell::new(None) }
    }

    /// Parse a backend kind string (`auto | native | sharded | pjrt`, or
    /// `fault:<inner>[,k=v...]`) into a spec — the single place the CLI's
    /// `--backend` flag and the registry's deployment specs agree on
    /// backend names. `threads` is consumed by the sharded backend,
    /// `arts_dir` by pjrt/auto.
    pub fn from_kind(
        kind: &str,
        model: &str,
        seed: u64,
        threads: usize,
        arts_dir: &str,
    ) -> Result<BackendSpec> {
        if let Some(rest) = kind.strip_prefix("fault:") {
            // `fault:native,err_every=50` — inner kind up to the first
            // separator, the rest is the FaultPlan. `;` separators are
            // accepted too (deployment kv-specs split on commas).
            let (inner_kind, params) = match rest.find([',', ';']) {
                Some(i) => (&rest[..i], &rest[i + 1..]),
                None => (rest, ""),
            };
            if inner_kind.starts_with("fault") {
                anyhow::bail!("fault backend cannot wrap another fault backend");
            }
            let inner = BackendSpec::from_kind(inner_kind, model, seed, threads, arts_dir)?;
            return Ok(BackendSpec::Fault(Box::new(inner), FaultPlan::parse(params)?));
        }
        match kind {
            "native" => BackendSpec::native(ModelConfig::tiny(model), seed),
            "sharded" => BackendSpec::sharded(ModelConfig::tiny(model), seed, threads),
            "auto" => default_spec_in(arts_dir, model, seed),
            "pjrt" => {
                #[cfg(feature = "pjrt")]
                {
                    use anyhow::Context;
                    let arts = super::Artifacts::load(arts_dir)
                        .context("backend 'pjrt' needs artifacts (run `make artifacts`)")?;
                    Ok(BackendSpec::pjrt(arts.model(model)?.clone()))
                }
                #[cfg(not(feature = "pjrt"))]
                {
                    anyhow::bail!("backend 'pjrt' requires building with `--features pjrt`")
                }
            }
            other => anyhow::bail!(
                "unknown backend '{other}' (expected auto|native|sharded|pjrt|fault:<inner>)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendSpec::Native(_) => "native",
            BackendSpec::Sharded(..) => "sharded",
            BackendSpec::Fault(..) => "fault",
            #[cfg(feature = "pjrt")]
            BackendSpec::Pjrt { .. } => "pjrt",
        }
    }

    pub fn model_config(&self) -> &ModelConfig {
        match self {
            BackendSpec::Native(m) => &m.cfg,
            BackendSpec::Sharded(m, _) => &m.cfg,
            BackendSpec::Fault(inner, _) => inner.model_config(),
            #[cfg(feature = "pjrt")]
            BackendSpec::Pjrt { mart, .. } => &mart.config,
        }
    }

    /// Longest prompt a request generating `gen_len` tokens can carry
    /// without being rejected at admission (`prompt + max_new <= max_seq`).
    /// Workload builders clamp their corpus cuts with this. If the KV
    /// capacity cannot fit `gen_len` plus one prompt byte, no length
    /// passes admission — shrink `gen_len` in that case.
    pub fn max_prompt(&self, gen_len: usize) -> usize {
        self.model_config().max_seq.saturating_sub(gen_len).max(1)
    }

    pub fn build(&self) -> Result<Box<dyn ExecBackend>> {
        match self {
            BackendSpec::Native(model) => {
                Ok(Box::new(NativeBackend::from_model(model.clone())))
            }
            BackendSpec::Sharded(model, threads) => {
                Ok(Box::new(super::sharded::ShardedBackend::from_model(model.clone(), *threads)))
            }
            BackendSpec::Fault(inner, plan) => {
                Ok(Box::new(FaultBackend::new(inner.build()?, plan.clone())))
            }
            #[cfg(feature = "pjrt")]
            BackendSpec::Pjrt { mart, rt } => {
                let mut slot = rt.borrow_mut();
                if slot.is_none() {
                    *slot = Some(Arc::new(ModelRuntime::load(mart)?));
                }
                Ok(Box::new(PjrtBackend::new(slot.as_ref().unwrap().clone())))
            }
        }
    }

    /// A `Send` recipe for constructing this spec's backend on another
    /// thread (the threaded engine front-end).
    pub fn recipe(&self) -> BackendRecipe {
        match self {
            BackendSpec::Native(m) => BackendRecipe::Native(m.clone()),
            BackendSpec::Sharded(m, threads) => BackendRecipe::Sharded(m.clone(), *threads),
            BackendSpec::Fault(inner, plan) => {
                BackendRecipe::Fault(Box::new(inner.recipe()), plan.clone())
            }
            #[cfg(feature = "pjrt")]
            BackendSpec::Pjrt { mart, .. } => BackendRecipe::Pjrt(mart.clone()),
        }
    }
}

/// The auto-selection policy: the PJRT artifacts under `arts_dir` when the
/// feature is on and `make artifacts` has run, the hermetic native backend
/// otherwise. The CLI's `--backend auto` and `default_spec` both route
/// through here so the fallback rule lives in one place.
pub fn default_spec_in(arts_dir: &str, model: &str, seed: u64) -> Result<BackendSpec> {
    #[cfg(feature = "pjrt")]
    {
        if let Ok(arts) = super::Artifacts::load(arts_dir) {
            if let Ok(mart) = arts.model(model) {
                return Ok(BackendSpec::pjrt(mart.clone()));
            }
        }
    }
    #[cfg(not(feature = "pjrt"))]
    let _ = arts_dir;
    BackendSpec::native(ModelConfig::tiny(model), seed)
}

/// `default_spec_in` against the default artifacts directory.
pub fn default_spec(model: &str, seed: u64) -> Result<BackendSpec> {
    default_spec_in(crate::ARTIFACTS_DIR, model, seed)
}

/// Convenience: `default_spec(..).build()`.
pub fn default_backend(model: &str, seed: u64) -> Result<Box<dyn ExecBackend>> {
    default_spec(model, seed)?.build()
}

/// The artifacts' validation corpus when present, else a deterministic
/// synthetic corpus — so corpus-driven examples/benches run hermetically.
pub fn corpus_or_synthetic(bytes: usize) -> Vec<u8> {
    if let Ok(arts) = super::Artifacts::load(crate::ARTIFACTS_DIR) {
        if let Ok(path) = arts.corpus_path("valid") {
            if let Ok(data) = std::fs::read(path) {
                if !data.is_empty() {
                    return data;
                }
            }
        }
    }
    synthetic_corpus(bytes, 0xC0FFEE)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knobs_resolve_from_config() {
        let aqua = AquaConfig { k_ratio: 0.5, ..Default::default() };
        let k = AquaKnobs::from_config(&aqua, 8);
        assert_eq!(k.k_dims, 4);
        assert_eq!(k.dim_keep, vec![1.0; 8]);
        assert!(k.use_projection);
        let e = AquaKnobs::exact(4);
        assert_eq!(e.k_dims, 4);
        assert!(!e.use_projection);
    }

    #[test]
    fn default_spec_is_native_without_artifacts() {
        // Hermetic environments have no artifacts dir; the spec must fall
        // back to the native backend and still build an engine-ready
        // backend either way.
        let spec = default_spec("llama-analog", 7).unwrap();
        let mut be = spec.build().unwrap();
        be.empty_cache(2).unwrap();
        assert!(!be.model_config().name.is_empty());
        assert!(be.prefill_chunk() > 0);
        // clamped workload prompts always pass the admission check
        assert!(spec.max_prompt(48) + 48 <= spec.model_config().max_seq);
    }

    #[test]
    fn sharded_spec_builds_and_names_itself() {
        let spec = BackendSpec::sharded(ModelConfig::tiny("shard-spec"), 3, 2).unwrap();
        assert_eq!(spec.name(), "sharded");
        let mut be = spec.build().unwrap();
        assert_eq!(be.name(), "sharded");
        be.empty_cache(3).unwrap();
        // the recipe route (engine-thread construction) works too
        let mut from_recipe = spec.recipe().build().unwrap();
        from_recipe.empty_cache(1).unwrap();
        assert_eq!(from_recipe.name(), "sharded");
    }

    #[test]
    fn from_kind_parses_and_rejects() {
        let spec = BackendSpec::from_kind("native", "m", 1, 4, "no-such-dir").unwrap();
        assert_eq!(spec.name(), "native");
        assert_eq!(spec.recipe().kind(), "native");
        let spec = BackendSpec::from_kind("sharded", "m", 1, 2, "no-such-dir").unwrap();
        assert_eq!(spec.name(), "sharded");
        assert_eq!(spec.recipe().kind(), "sharded");
        // auto falls back to native in hermetic environments
        let spec = BackendSpec::from_kind("auto", "m", 1, 4, "no-such-dir").unwrap();
        spec.build().unwrap();
        assert!(BackendSpec::from_kind("gpu", "m", 0, 1, "x").is_err());
        #[cfg(not(feature = "pjrt"))]
        assert!(BackendSpec::from_kind("pjrt", "m", 0, 1, "x").is_err());
    }

    #[test]
    fn kernel_counters_merge_and_count() {
        let mut a = KernelCounters {
            dense: 1,
            sparse: 2,
            packed: 3,
            score_ns: 10,
            fused_passes: 2,
            simd_lanes_used: 8,
            dequant_ns: 7,
        };
        a.merge(&KernelCounters {
            dense: 4,
            sparse: 0,
            packed: 1,
            score_ns: 5,
            fused_passes: 3,
            simd_lanes_used: 1,
            dequant_ns: 2,
        });
        assert_eq!(
            a,
            KernelCounters {
                dense: 5,
                sparse: 2,
                packed: 4,
                score_ns: 15,
                fused_passes: 5,
                simd_lanes_used: 8,
                dequant_ns: 9,
            }
        );
        assert_eq!(a.calls(), 11, "fused passes are counted separately");
    }

    #[test]
    fn dominant_mode_codes_cover_the_fused_path() {
        let f = |dense, sparse, packed, fused_passes| {
            KernelCounters { dense, sparse, packed, fused_passes, ..Default::default() }
                .dominant_mode()
        };
        assert_eq!(f(1, 0, 0, 0), 0);
        assert_eq!(f(0, 1, 0, 0), 1);
        assert_eq!(f(0, 0, 1, 0), 2);
        assert_eq!(f(1, 1, 0, 0), 3);
        assert_eq!(f(0, 0, 0, 4), 4, "fused-only steps report code 4");
        assert_eq!(f(1, 0, 0, 4), 3, "fused + oracle is mixed");
    }

    #[test]
    fn corpus_fallback_is_nonempty_text() {
        let c = corpus_or_synthetic(4096);
        assert!(c.len() >= 1024);
        assert!(c.contains(&b'\n'));
    }
}
