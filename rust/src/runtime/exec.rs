//! Compiled-executable registry and typed call wrappers.
//!
//! One `ModelRuntime` per served model: it owns the PJRT client, the
//! parameter/projection literals (uploaded once), and lazily-compiled
//! decode/prefill executables per batch size. The KV caches round-trip as
//! literals between steps (on the CPU plugin "device" memory is host
//! memory, so this is a memcpy; see EXPERIMENTS.md §Perf for the measured
//! overhead).

use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};
use xla::{FromRawBytes, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::artifacts::ModelArtifacts;
use crate::model::config::ModelConfig;

/// Outputs of one decode step.
pub struct DecodeOut {
    /// [B, vocab] row-major.
    pub logits: Vec<f32>,
    /// Updated cache literals, fed back on the next call.
    pub k_cache: Literal,
    pub v_cache: Literal,
    /// [L, B, S] attention mass per slot this step (H2O food).
    pub attn_acc: Vec<f32>,
}

/// Outputs of one prefill chunk.
pub struct PrefillOut {
    /// [B, C, vocab] row-major.
    pub logits: Vec<f32>,
    pub k_cache: Literal,
    pub v_cache: Literal,
    /// [B, S] updated slot mask as computed by the model.
    pub slot_mask: Vec<f32>,
    /// [L, B, S] summed over the chunk.
    pub attn_acc: Vec<f32>,
}

pub struct ModelRuntime {
    pub cfg: ModelConfig,
    client: PjRtClient,
    /// Parameter buffers in manifest order, uploaded once and device-
    /// resident for every call (§Perf: avoids ~40 serialized host→device
    /// transfers per decode step).
    params: Vec<PjRtBuffer>,
    /// [L, n_kv, d, d] calibrated projection (device-resident).
    proj: PjRtBuffer,
    /// [L, n_kv, d, d] identity projection (exact-baseline mode).
    proj_identity: PjRtBuffer,
    /// tag -> compiled executable (lazy).
    exes: Mutex<HashMap<String, std::sync::Arc<PjRtLoadedExecutable>>>,
    hlo_paths: BTreeMap<String, std::path::PathBuf>,
    pub prefill_chunk: usize,
}

impl ModelRuntime {
    pub fn load(art: &ModelArtifacts) -> Result<Self> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let named: BTreeMap<String, Literal> =
            Literal::read_npz(&art.params_npz, &())
                .map_err(|e| anyhow!("reading {:?}: {e:?}", art.params_npz))?
                .into_iter()
                .collect();
        let mut params = Vec::with_capacity(art.param_order.len());
        for name in &art.param_order {
            let lit = named
                .get(name)
                .ok_or_else(|| anyhow!("param '{name}' missing from params.npz"))?;
            params.push(upload(&client, lit).with_context(|| format!("param '{name}'"))?);
        }
        let proj_lit = Literal::read_npz_by_name(&art.proj_npz, &(), &["proj"])
            .map_err(|e| anyhow!("reading {:?}: {e:?}", art.proj_npz))?
            .remove(0);
        let proj = upload(&client, &proj_lit).context("proj")?;
        let cfg = art.config.clone();
        let proj_identity = upload(&client, &identity_proj_literal(&cfg)?)?;
        Ok(ModelRuntime {
            cfg,
            client,
            params,
            proj,
            proj_identity,
            exes: Mutex::new(HashMap::new()),
            hlo_paths: art.hlo.clone(),
            prefill_chunk: art.prefill_chunk,
        })
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    /// Compile (or fetch) the executable for `tag` ("decode_b4", ...).
    pub fn executable(&self, tag: &str) -> Result<std::sync::Arc<PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.lock().unwrap().get(tag) {
            return Ok(e.clone());
        }
        let path = self
            .hlo_paths
            .get(tag)
            .ok_or_else(|| anyhow!("no HLO artifact '{tag}'"))?;
        let t0 = std::time::Instant::now();
        let exe = compile_hlo(&self.client, path)?;
        crate::log_info!("compiled {tag} in {}", crate::util::fmt_duration(t0.elapsed()));
        let arc = std::sync::Arc::new(exe);
        self.exes.lock().unwrap().insert(tag.to_string(), arc.clone());
        Ok(arc)
    }

    /// Fresh zeroed KV cache literals + slot mask for batch `b`.
    pub fn empty_cache(&self, b: usize) -> Result<(Literal, Literal)> {
        let c = &self.cfg;
        let dims = [c.n_layers, b, c.max_seq, c.n_kv_heads, c.d_head];
        let n: usize = dims.iter().product();
        let zeros = vec![0.0f32; n];
        let k = literal_f32(&zeros, &dims)?;
        let v = literal_f32(&zeros, &dims)?;
        Ok((k, v))
    }

    fn common_args(&self, use_projection: bool) -> Vec<&PjRtBuffer> {
        let mut args: Vec<&PjRtBuffer> = self.params.iter().collect();
        args.push(if use_projection { &self.proj } else { &self.proj_identity });
        args
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload f32 {dims:?}: {e:?}"))
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload i32 {dims:?}: {e:?}"))
    }

    fn upload_literal(&self, lit: &Literal) -> Result<PjRtBuffer> {
        upload(&self.client, lit)
    }

    /// One decode step for a batch of lanes.
    #[allow(clippy::too_many_arguments)]
    pub fn decode(
        &self,
        b: usize,
        tokens: &[i32],
        pos: &[i32],
        k_cache: &Literal,
        v_cache: &Literal,
        slot_mask: &[f32],
        k_dims: i32,
        dim_keep: &[f32],
        use_projection: bool,
    ) -> Result<DecodeOut> {
        let c = &self.cfg;
        if tokens.len() != b || pos.len() != b || slot_mask.len() != b * c.max_seq {
            bail!("decode arg shape mismatch");
        }
        let exe = self.executable(&format!("decode_b{b}"))?;
        let tok = self.upload_i32(tokens, &[b])?;
        let posl = self.upload_i32(pos, &[b])?;
        let mask = self.upload_f32(slot_mask, &[b, c.max_seq])?;
        let kd = self.upload_literal(&Literal::scalar(k_dims))?;
        let keep = self.upload_f32(dim_keep, &[c.d_head])?;
        let kc = self.upload_literal(k_cache)?;
        let vc = self.upload_literal(v_cache)?;

        let mut args = self.common_args(use_projection);
        args.extend([&tok, &posl, &kc, &vc, &mask, &kd, &keep]);

        let result = exe
            .execute_b::<&PjRtBuffer>(&args)
            .map_err(|e| anyhow!("decode execute: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("decode output transfer: {e:?}"))?;
        let mut outs = tuple.to_tuple().map_err(|e| anyhow!("decode untuple: {e:?}"))?;
        if outs.len() != 4 {
            bail!("decode expected 4 outputs, got {}", outs.len());
        }
        let attn_acc = outs.pop().unwrap();
        let v_new = outs.pop().unwrap();
        let k_new = outs.pop().unwrap();
        let logits = outs.pop().unwrap();
        Ok(DecodeOut {
            logits: logits.to_vec::<f32>().map_err(|e| anyhow!("logits: {e:?}"))?,
            k_cache: k_new,
            v_cache: v_new,
            attn_acc: attn_acc.to_vec::<f32>().map_err(|e| anyhow!("attn_acc: {e:?}"))?,
        })
    }

    /// One prefill chunk ([B, C] tokens starting at per-lane pos0).
    #[allow(clippy::too_many_arguments)]
    pub fn prefill(
        &self,
        b: usize,
        tokens: &[i32],
        pos0: &[i32],
        k_cache: &Literal,
        v_cache: &Literal,
        slot_mask: &[f32],
        k_dims: i32,
        dim_keep: &[f32],
        use_projection: bool,
    ) -> Result<PrefillOut> {
        let c = &self.cfg;
        let chunk = self.prefill_chunk;
        if tokens.len() != b * chunk || pos0.len() != b {
            bail!("prefill arg shape mismatch");
        }
        let exe = self.executable(&format!("prefill_b{b}_c{chunk}"))?;
        let tok = self.upload_i32(tokens, &[b, chunk])?;
        let posl = self.upload_i32(pos0, &[b])?;
        let mask = self.upload_f32(slot_mask, &[b, c.max_seq])?;
        let kd = self.upload_literal(&Literal::scalar(k_dims))?;
        let keep = self.upload_f32(dim_keep, &[c.d_head])?;
        let kc = self.upload_literal(k_cache)?;
        let vc = self.upload_literal(v_cache)?;

        let mut args = self.common_args(use_projection);
        args.extend([&tok, &posl, &kc, &vc, &mask, &kd, &keep]);

        let result = exe
            .execute_b::<&PjRtBuffer>(&args)
            .map_err(|e| anyhow!("prefill execute: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("prefill output transfer: {e:?}"))?;
        let mut outs = tuple.to_tuple().map_err(|e| anyhow!("prefill untuple: {e:?}"))?;
        if outs.len() != 5 {
            bail!("prefill expected 5 outputs, got {}", outs.len());
        }
        let attn_acc = outs.pop().unwrap();
        let slot = outs.pop().unwrap();
        let v_new = outs.pop().unwrap();
        let k_new = outs.pop().unwrap();
        let logits = outs.pop().unwrap();
        Ok(PrefillOut {
            logits: logits.to_vec::<f32>().map_err(|e| anyhow!("logits: {e:?}"))?,
            k_cache: k_new,
            v_cache: v_new,
            slot_mask: slot.to_vec::<f32>().map_err(|e| anyhow!("slot_mask: {e:?}"))?,
            attn_acc: attn_acc.to_vec::<f32>().map_err(|e| anyhow!("attn_acc: {e:?}"))?,
        })
    }
}

// ---------------------------------------------------------------------------
// Literal helpers
// ---------------------------------------------------------------------------

pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow!("literal_f32 reshape {dims:?}: {e:?}"))
}

pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<Literal> {
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow!("literal_i32 reshape {dims:?}: {e:?}"))
}

/// Host→device upload via raw bytes (`buffer_from_host_literal` in this
/// xla_extension build mis-sizes non-default-layout literals; raw-bytes
/// transfer is layout-explicit and safe).
fn upload(client: &PjRtClient, lit: &Literal) -> Result<PjRtBuffer> {
    let shape = lit.array_shape().map_err(|e| anyhow!("{e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => {
            let data = lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
            client
                .buffer_from_host_buffer(&data, &dims, None)
                .map_err(|e| anyhow!("upload f32 {dims:?}: {e:?}"))
        }
        xla::ElementType::S32 => {
            let data = lit.to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?;
            client
                .buffer_from_host_buffer(&data, &dims, None)
                .map_err(|e| anyhow!("upload i32 {dims:?}: {e:?}"))
        }
        t => bail!("upload: unsupported element type {t:?}"),
    }
}

fn identity_proj_literal(cfg: &ModelConfig) -> Result<Literal> {
    let d = cfg.d_head;
    let mut data = vec![0.0f32; cfg.n_layers * cfg.n_kv_heads * d * d];
    for l in 0..cfg.n_layers {
        for g in 0..cfg.n_kv_heads {
            let base = (l * cfg.n_kv_heads + g) * d * d;
            for i in 0..d {
                data[base + i * d + i] = 1.0;
            }
        }
    }
    literal_f32(&data, &[cfg.n_layers, cfg.n_kv_heads, d, d])
}

pub fn compile_hlo(client: &PjRtClient, path: impl AsRef<Path>) -> Result<PjRtLoadedExecutable> {
    let path = path.as_ref();
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow!("parsing HLO text {path:?}: {e:?}"))
        .with_context(|| "run `make artifacts`?")?;
    let comp = XlaComputation::from_proto(&proto);
    client.compile(&comp).map_err(|e| anyhow!("compiling {path:?}: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_helpers_shape_and_roundtrip() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        let shape = l.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let l = literal_i32(&[7, 8], &[2]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![7, 8]);
        // element-count mismatch is an error
        assert!(literal_f32(&[1.0], &[2, 2]).is_err());
    }

    #[test]
    fn identity_proj_is_block_identity() {
        let cfg = crate::model::config::ModelConfig {
            name: "t".into(),
            vocab: 8,
            d_model: 8,
            n_layers: 2,
            n_q_heads: 2,
            n_kv_heads: 1,
            d_head: 4,
            d_ff: 8,
            rope_theta: 1e4,
            norm_eps: 1e-5,
            max_seq: 8,
            train_seq: 4,
        };
        let lit = identity_proj_literal(&cfg).unwrap();
        let v = lit.to_vec::<f32>().unwrap();
        assert_eq!(v.len(), 2 * 1 * 4 * 4);
        for l in 0..2 {
            for i in 0..4 {
                for j in 0..4 {
                    let got = v[l * 16 + i * 4 + j];
                    assert_eq!(got, if i == j { 1.0 } else { 0.0 });
                }
            }
        }
    }
}
