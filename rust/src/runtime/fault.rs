//! Deterministic fault injection behind the [`ExecBackend`] trait.
//!
//! [`FaultBackend`] wraps any inner backend and injects *scripted* step
//! errors, panics, and latency spikes by step count and lane, so every
//! failure path in the engine/deployment/server stack is exercisable in
//! hermetic CI. Configured through [`BackendSpec::from_kind`] as
//! `fault:<inner>,k=v,...` (e.g. `--backend fault:native,err_every=50`);
//! `;` also separates params, for contexts where the surrounding syntax
//! already splits on commas (deployment kv-specs).
//!
//! Injection happens **before** the inner call, so a failed step has no
//! side effects on any lane's KV state — the [`LaneError`] contract the
//! engine's containment relies on (retire the blamed lane, re-run the
//! pass, surviving lanes stay bit-identical).
//!
//! [`BackendSpec::from_kind`]: super::backend::BackendSpec::from_kind

use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::backend::{AquaKnobs, ExecBackend, LaneError, PrefixAttach, StepOut};
use crate::kvpool::{KvPoolConfig, KvPoolGauges};
use crate::model::config::ModelConfig;
use crate::util::prng::Rng;

/// The injection script. All knobs are optional; the default plan injects
/// nothing (a transparent wrapper). Steps count prefill + decode calls,
/// starting at 1.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Inject an error on every Nth step (0 = off).
    pub err_every: u64,
    /// Per-step error probability from the seeded RNG (0.0 = off).
    pub err_p: f64,
    /// Stop injecting errors after this many (0 = unlimited).
    pub err_count: u64,
    /// Lane to blame for injected errors; defaults to the first live lane
    /// of the failing call.
    pub err_lane: Option<usize>,
    /// Injected errors carry no lane attribution (simulates a backend
    /// that cannot say which lane failed — the engine must fail every
    /// lane scheduled in the pass).
    pub unattributed: bool,
    /// Panic on exactly this step (0 = off) — exercises the supervisor's
    /// `catch_unwind` path.
    pub panic_at: u64,
    /// Sleep `delay_ms` before every Nth step (0 = off).
    pub delay_every: u64,
    /// Latency-spike duration, milliseconds.
    pub delay_ms: u64,
    /// Seed for the probabilistic knobs.
    pub seed: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            err_every: 0,
            err_p: 0.0,
            err_count: 0,
            err_lane: None,
            unattributed: false,
            panic_at: 0,
            delay_every: 0,
            delay_ms: 0,
            seed: 0,
        }
    }
}

impl FaultPlan {
    /// Parse `k=v` params separated by `,` or `;` (either works in any
    /// position; empty input is the do-nothing plan).
    pub fn parse(params: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for kv in params.split([',', ';']).filter(|s| !s.trim().is_empty()) {
            let (k, v) = kv
                .split_once('=')
                .with_context(|| format!("fault param '{kv}' is not key=value"))?;
            let (k, v) = (k.trim(), v.trim());
            let bad = || format!("fault param '{k}' has invalid value '{v}'");
            match k {
                "err_every" => plan.err_every = v.parse().with_context(bad)?,
                "err_p" => plan.err_p = v.parse().with_context(bad)?,
                "err_count" => plan.err_count = v.parse().with_context(bad)?,
                "err_lane" => plan.err_lane = Some(v.parse().with_context(bad)?),
                "unattributed" => plan.unattributed = v.parse().with_context(bad)?,
                "panic_at" => plan.panic_at = v.parse().with_context(bad)?,
                "delay_every" => plan.delay_every = v.parse().with_context(bad)?,
                "delay_ms" => plan.delay_ms = v.parse().with_context(bad)?,
                "seed" => plan.seed = v.parse().with_context(bad)?,
                other => bail!(
                    "unknown fault param '{other}' (expected err_every|err_p|err_count|err_lane|\
                     unattributed|panic_at|delay_every|delay_ms|seed)"
                ),
            }
        }
        if !(0.0..=1.0).contains(&plan.err_p) {
            bail!("fault err_p must be in [0, 1], got {}", plan.err_p);
        }
        Ok(plan)
    }
}

/// Fault-injecting [`ExecBackend`] wrapper. Everything but the scripted
/// injection delegates to the inner backend verbatim, so a do-nothing plan
/// is bit-identical to serving the inner backend directly.
pub struct FaultBackend {
    inner: Box<dyn ExecBackend>,
    plan: FaultPlan,
    rng: Rng,
    /// Prefill + decode + verify calls so far (the injection clock).
    steps: u64,
    /// Errors injected so far (the `err_count` budget).
    injected: u64,
}

impl FaultBackend {
    pub fn new(inner: Box<dyn ExecBackend>, plan: FaultPlan) -> FaultBackend {
        let rng = Rng::new(plan.seed ^ 0xFA_17);
        FaultBackend { inner, plan, rng, steps: 0, injected: 0 }
    }

    /// Steps the injection clock and fires whatever the plan scripts for
    /// this step. Called before the inner prefill/decode, so an injected
    /// failure leaves every lane's state untouched.
    fn inject(&mut self, tokens: &[i32]) -> Result<()> {
        self.steps += 1;
        if self.plan.panic_at != 0 && self.steps == self.plan.panic_at {
            panic!("fault backend: scripted panic at step {}", self.steps);
        }
        if self.plan.delay_every != 0 && self.steps % self.plan.delay_every == 0 {
            std::thread::sleep(Duration::from_millis(self.plan.delay_ms));
        }
        let scripted = self.plan.err_every != 0 && self.steps % self.plan.err_every == 0;
        let random = self.plan.err_p > 0.0 && self.rng.f64() < self.plan.err_p;
        let budget_left = self.plan.err_count == 0 || self.injected < self.plan.err_count;
        if (scripted || random) && budget_left {
            self.injected += 1;
            if self.plan.unattributed {
                bail!("fault backend: injected unattributed error at step {}", self.steps);
            }
            // blame the scripted lane, else the first live lane of the call
            let lane = self
                .plan
                .err_lane
                .or_else(|| tokens.iter().position(|&t| t >= 0))
                .unwrap_or(0);
            return Err(anyhow::Error::new(LaneError(lane))
                .context(format!("fault backend: injected error at step {}", self.steps)));
        }
        Ok(())
    }
}

impl ExecBackend for FaultBackend {
    fn name(&self) -> &'static str {
        "fault"
    }

    fn model_config(&self) -> &ModelConfig {
        self.inner.model_config()
    }

    fn prefill_chunk(&self) -> usize {
        self.inner.prefill_chunk()
    }

    fn empty_cache(&mut self, b: usize) -> Result<()> {
        self.inner.empty_cache(b)
    }

    fn configure_kv_pool(&mut self, cfg: KvPoolConfig) -> Result<()> {
        self.inner.configure_kv_pool(cfg)
    }

    fn retire_lane(&mut self, lane: usize) {
        self.inner.retire_lane(lane)
    }

    fn attach_prefix(
        &mut self,
        lane: usize,
        tokens: &[i32],
        knobs: &AquaKnobs,
    ) -> Result<PrefixAttach> {
        self.inner.attach_prefix(lane, tokens, knobs)
    }

    fn kv_gauges(&mut self) -> KvPoolGauges {
        self.inner.kv_gauges()
    }

    fn prefill(
        &mut self,
        b: usize,
        tokens: &[i32],
        pos0: &[i32],
        slot_mask: &[f32],
        knobs: &AquaKnobs,
    ) -> Result<StepOut> {
        // a prefill call's live lanes are those with any non-dead token
        let chunk = self.inner.prefill_chunk().max(1);
        let lane_live: Vec<i32> = (0..b)
            .map(|lane| {
                let row = &tokens[lane * chunk..(lane + 1) * chunk];
                if row.iter().any(|&t| t >= 0) {
                    0
                } else {
                    -1
                }
            })
            .collect();
        self.inject(&lane_live)?;
        self.inner.prefill(b, tokens, pos0, slot_mask, knobs)
    }

    fn decode(
        &mut self,
        b: usize,
        tokens: &[i32],
        pos: &[i32],
        slot_mask: &[f32],
        knobs: &AquaKnobs,
    ) -> Result<StepOut> {
        self.inject(tokens)?;
        self.inner.decode(b, tokens, pos, slot_mask, knobs)
    }

    fn verify(
        &mut self,
        b: usize,
        tokens: &[i32],
        pos0: &[i32],
        t: usize,
        slot_mask: &[f32],
        knobs: &AquaKnobs,
    ) -> Result<StepOut> {
        // a verify call's live lanes are those whose t-wide row holds any
        // real token; dead rows are all -1 padding
        let t = t.max(1);
        let lane_live: Vec<i32> = (0..b)
            .map(|lane| {
                let row = &tokens[lane * t..(lane + 1) * t];
                if row.iter().any(|&tok| tok >= 0) {
                    0
                } else {
                    -1
                }
            })
            .collect();
        self.inject(&lane_live)?;
        self.inner.verify(b, tokens, pos0, t, slot_mask, knobs)
    }

    fn supports_verify(&self) -> bool {
        self.inner.supports_verify()
    }

    fn rollback_lane(&mut self, lane: usize, to_len: usize) {
        self.inner.rollback_lane(lane, to_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::runtime::backend::BackendSpec;

    fn fault_backend(plan: &str) -> FaultBackend {
        let spec = BackendSpec::native(ModelConfig::tiny("fault-test"), 1).unwrap();
        FaultBackend::new(spec.build().unwrap(), FaultPlan::parse(plan).unwrap())
    }

    #[test]
    fn plan_parses_both_separators() {
        let a = FaultPlan::parse("err_every=50,err_lane=2,seed=7").unwrap();
        let b = FaultPlan::parse("err_every=50;err_lane=2;seed=7").unwrap();
        assert_eq!(a, b);
        assert_eq!(a.err_every, 50);
        assert_eq!(a.err_lane, Some(2));
        assert_eq!(a.seed, 7);
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::default());
        assert!(FaultPlan::parse("bogus=1").is_err());
        assert!(FaultPlan::parse("err_every").is_err());
        assert!(FaultPlan::parse("err_p=1.5").is_err());
    }

    #[test]
    fn spec_parses_fault_kind() {
        let spec = BackendSpec::from_kind("fault:native,err_every=3", "m", 1, 1, "x").unwrap();
        assert_eq!(spec.name(), "fault");
        assert_eq!(spec.recipe().kind(), "fault");
        let mut be = spec.build().unwrap();
        assert_eq!(be.name(), "fault");
        be.empty_cache(1).unwrap();
        // `;` separators work too, and bare `fault:native` is a no-op plan
        BackendSpec::from_kind("fault:native;err_every=3;err_lane=0", "m", 1, 1, "x").unwrap();
        BackendSpec::from_kind("fault:native", "m", 1, 1, "x").unwrap();
        assert!(BackendSpec::from_kind("fault:fault:native", "m", 1, 1, "x").is_err());
        assert!(BackendSpec::from_kind("fault:gpu", "m", 1, 1, "x").is_err());
    }

    #[test]
    fn scripted_errors_fire_on_schedule_and_attribute_lane() {
        let mut be = fault_backend("err_every=3,err_count=1");
        be.empty_cache(2).unwrap();
        let knobs = AquaKnobs::exact(be.model_config().d_head);
        let s = be.model_config().max_seq;
        let mask = vec![0.0f32; 2 * s];
        // decode steps 1, 2 succeed; step 3 errs, blamed on the first live
        // lane (lane 1 here — lane 0 is dead)
        for step in 1..=4u64 {
            let r = be.decode(2, &[-1, 5], &[0, 0], &mask, &knobs);
            if step == 3 {
                let e = r.expect_err("step 3 must fail");
                assert_eq!(e.downcast_ref::<LaneError>(), Some(&LaneError(1)));
            } else {
                r.unwrap_or_else(|e| panic!("step {step} should pass: {e:#}"));
            }
        }
        // err_count=1 exhausted: step 6 passes
        for _ in 5..=6 {
            be.decode(2, &[-1, 5], &[0, 0], &mask, &knobs).unwrap();
        }
    }

    #[test]
    fn unattributed_errors_carry_no_lane() {
        let mut be = fault_backend("err_every=1,unattributed=true");
        be.empty_cache(1).unwrap();
        let knobs = AquaKnobs::exact(be.model_config().d_head);
        let mask = vec![0.0f32; be.model_config().max_seq];
        let e = be.decode(1, &[5], &[0], &mask, &knobs).expect_err("must fail");
        assert!(e.downcast_ref::<LaneError>().is_none());
    }

    #[test]
    fn injection_failure_has_no_side_effects() {
        // two identical backends; one injects an error mid-stream. After
        // the error, both must produce bit-identical outputs — the failed
        // call touched nothing.
        let mut clean = fault_backend("");
        let mut faulty = fault_backend("err_every=2,err_count=1,err_lane=0");
        let knobs = AquaKnobs::exact(clean.model_config().d_head);
        let s = clean.model_config().max_seq;
        let chunk = clean.prefill_chunk();
        clean.empty_cache(1).unwrap();
        faulty.empty_cache(1).unwrap();
        let mut prompt = vec![-1i32; chunk];
        prompt[0] = 7;
        prompt[1] = 13;
        let mut mask = vec![0.0f32; s];
        let a = clean.prefill(1, &prompt, &[0], &mask, &knobs).unwrap();
        let b = faulty.prefill(1, &prompt, &[0], &mask, &knobs).unwrap();
        assert_eq!(a.logits, b.logits);
        mask[0] = 1.0;
        mask[1] = 1.0;
        // step 2: faulty errs, clean proceeds — then both decode and the
        // logits must still match exactly
        assert!(faulty.decode(1, &[3], &[2], &mask, &knobs).is_err());
        let a = clean.decode(1, &[3], &[2], &mask, &knobs).unwrap();
        let b = faulty.decode(1, &[3], &[2], &mask, &knobs).unwrap();
        assert_eq!(a.logits, b.logits, "failed call must leave no side effects");
    }

    #[test]
    #[should_panic(expected = "scripted panic at step 1")]
    fn scripted_panic_fires() {
        let mut be = fault_backend("panic_at=1");
        be.empty_cache(1).unwrap();
        let knobs = AquaKnobs::exact(be.model_config().d_head);
        let mask = vec![0.0f32; be.model_config().max_seq];
        let _ = be.decode(1, &[5], &[0], &mask, &knobs);
    }
}
