//! Artifact manifest: the contract between the python build path and the
//! rust request path (written by `python/compile/aot.py`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::model::config::ModelConfig;
use crate::util::json::Json;

/// One model's artifact set.
#[derive(Debug, Clone)]
pub struct ModelArtifacts {
    pub config: ModelConfig,
    pub params_npz: PathBuf,
    pub proj_npz: PathBuf,
    pub calib_dump_npz: PathBuf,
    /// tag ("decode_b1", "prefill_b4_c32", ...) -> HLO text path
    pub hlo: BTreeMap<String, PathBuf>,
    pub param_order: Vec<String>,
    pub decode_batches: Vec<usize>,
    pub prefill_chunk: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Artifacts {
    pub root: PathBuf,
    pub models: BTreeMap<String, ModelArtifacts>,
    /// split name -> corpus path
    pub corpus: BTreeMap<String, PathBuf>,
    /// task name -> (path, analog_of)
    pub tasks: BTreeMap<String, (PathBuf, String)>,
}

impl Artifacts {
    /// Load `<root>/manifest.json`. Paths inside the manifest are relative
    /// to the directory the build ran from (the repo root), so we resolve
    /// them against `root`'s parent.
    pub fn load(root: impl AsRef<Path>) -> Result<Artifacts> {
        let root = root.as_ref().to_path_buf();
        let manifest_path = root.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        // Manifest paths are relative to the artifacts dir itself.
        let base = root.clone();
        let fix = |s: &str| -> PathBuf {
            let p = PathBuf::from(s);
            if p.is_absolute() {
                p
            } else {
                base.join(s)
            }
        };

        let mut models = BTreeMap::new();
        let mobj = j
            .get("models")
            .as_obj()
            .ok_or_else(|| anyhow!("manifest missing 'models'"))?;
        for (name, m) in mobj {
            let config = ModelConfig::from_json(name, m.get("config"))?;
            let mut hlo = BTreeMap::new();
            if let Some(h) = m.get("hlo").as_obj() {
                for (tag, p) in h {
                    hlo.insert(tag.clone(), fix(p.as_str().unwrap_or_default()));
                }
            }
            let param_order = m
                .get("param_order")
                .as_arr()
                .ok_or_else(|| anyhow!("missing param_order"))?
                .iter()
                .map(|v| v.as_str().unwrap_or_default().to_string())
                .collect();
            let decode_batches = m
                .get("decode_batches")
                .as_arr()
                .map(|a| a.iter().filter_map(|v| v.as_i64()).map(|v| v as usize).collect())
                .unwrap_or_else(|| vec![1]);
            models.insert(
                name.clone(),
                ModelArtifacts {
                    config,
                    params_npz: fix(m.req_str("params")?),
                    proj_npz: fix(m.req_str("proj")?),
                    calib_dump_npz: fix(m.req_str("calib_dump")?),
                    hlo,
                    param_order,
                    decode_batches,
                    prefill_chunk: m.get("prefill_chunk").as_i64().unwrap_or(32) as usize,
                },
            );
        }

        let mut corpus = BTreeMap::new();
        if let Some(c) = j.get("corpus").as_obj() {
            for (name, e) in c {
                corpus.insert(name.clone(), fix(e.req_str("path")?));
            }
        }
        let mut tasks = BTreeMap::new();
        if let Some(t) = j.get("tasks").as_obj() {
            for (name, e) in t {
                tasks.insert(
                    name.clone(),
                    (fix(e.req_str("path")?), e.get("analog_of").as_str().unwrap_or("").to_string()),
                );
            }
        }
        Ok(Artifacts { root, models, corpus, tasks })
    }

    pub fn model(&self, name: &str) -> Result<&ModelArtifacts> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model '{name}' not in manifest (have: {:?})",
                                   self.models.keys().collect::<Vec<_>>()))
    }

    pub fn corpus_path(&self, split: &str) -> Result<&PathBuf> {
        self.corpus.get(split).ok_or_else(|| anyhow!("corpus split '{split}' missing"))
    }
}

impl ModelArtifacts {
    pub fn hlo_path(&self, tag: &str) -> Result<&PathBuf> {
        self.hlo.get(tag).ok_or_else(|| {
            anyhow!("HLO '{tag}' not built (have: {:?})", self.hlo.keys().collect::<Vec<_>>())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join(format!("aqua_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = r#"{
          "models": {"m": {
            "config": {"name":"m","vocab":256,"d_model":128,"n_layers":4,
                       "n_q_heads":4,"n_kv_heads":1,"d_head":32,"d_ff":512,
                       "rope_theta":10000.0,"norm_eps":1e-5,"max_seq":512,
                       "train_seq":192,"group_size":4},
            "params": "artifacts/m/params.npz",
            "proj": "artifacts/m/proj.npz",
            "calib_dump": "artifacts/m/calib_dump.npz",
            "param_order": ["embed","final_norm"],
            "hlo": {"decode_b1": "artifacts/m/decode_b1.hlo.txt"},
            "decode_batches": [1,4],
            "prefill_chunk": 32
          }},
          "corpus": {"valid": {"path": "artifacts/corpus/valid.txt"}},
          "tasks": {"knowledge": {"path": "artifacts/tasks/knowledge.jsonl",
                                   "analog_of": "MMLU"}}
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let a = Artifacts::load(&dir).unwrap();
        let m = a.model("m").unwrap();
        assert_eq!(m.config.d_head, 32);
        assert_eq!(m.config.group_size(), 4);
        assert_eq!(m.decode_batches, vec![1, 4]);
        assert!(a.model("nope").is_err());
        assert_eq!(a.tasks["knowledge"].1, "MMLU");
        std::fs::remove_dir_all(&dir).ok();
    }
}
