//! Byte-level tokenizer (vocab = 256).
//!
//! The analog models are byte LMs: token id == byte value, mirroring the
//! build-time python pipeline (latin-1 ↔ byte identity). Kept as a module
//! so a subword tokenizer could slot in without touching the engine.

/// Vocabulary size shared with the python model definition.
pub const VOCAB: usize = 256;

#[derive(Debug, Clone, Copy, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn encode(&self, text: &str) -> Vec<i32> {
        // latin-1 semantics: chars above U+00FF cannot appear in the synthetic
        // corpora; map them to '?' defensively rather than panic.
        text.chars()
            .map(|c| if (c as u32) < 256 { c as u32 as i32 } else { b'?' as i32 })
            .collect()
    }

    pub fn encode_bytes(&self, bytes: &[u8]) -> Vec<i32> {
        bytes.iter().map(|&b| b as i32).collect()
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .map(|&t| char::from_u32((t.clamp(0, 255)) as u32).unwrap())
            .collect()
    }

    pub fn decode_bytes(&self, ids: &[i32]) -> Vec<u8> {
        ids.iter().map(|&t| t.clamp(0, 255) as u8).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer;
        let s = "the capital of velor is tamrin .";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn roundtrip_high_bytes() {
        let t = ByteTokenizer;
        // devan corpus uses latin-1 bytes 0xA1..0xDA
        let s: String = (0xA1u32..0xA8).map(|c| char::from_u32(c).unwrap()).collect();
        assert_eq!(t.decode(&t.encode(&s)), s);
    }

    #[test]
    fn non_latin1_mapped_to_question_mark() {
        let t = ByteTokenizer;
        assert_eq!(t.encode("€"), vec![b'?' as i32]);
    }

    #[test]
    fn ids_in_vocab() {
        let t = ByteTokenizer;
        for id in t.encode("any text ÿ") {
            assert!((0..VOCAB as i32).contains(&id));
        }
    }
}
