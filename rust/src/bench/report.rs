//! Machine-readable bench trajectory: `BENCH_decode.json` at the repo
//! root, written by the decode-path benches so the perf story is tracked
//! PR-over-PR (schema documented in `BENCHES.md`).
//!
//! Layout:
//!
//! ```json
//! {
//!   "schema_version": 1,
//!   "sections": {
//!     "kernel_breakeven": { "rows": [ {"d":…, "k":…, …} ] },
//!     "decode_e2e":       { "rows": [ {"backend":…, "score_mode":…, …} ] }
//!   }
//! }
//! ```
//!
//! Benches own one section each and leave the others intact, so running
//! `cargo bench --bench breakeven` and `--bench decode_e2e` in either
//! order converges to the same file. `aqua benchcheck` validates the
//! schema (and, with `--strict`, the decode-overhaul perf invariants).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

pub const SCHEMA_VERSION: i64 = 1;

/// Repo-root path of the report, resolved at compile time relative to the
/// rust crate (stable no matter which directory the bench runs from).
pub fn default_path() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_decode.json")
}

/// Repo-root path of the serving report (`BENCH_serving.json`), written by
/// `examples/openloop_load.rs` — same layout conventions as the decode
/// report, one `openloop_serving` section (schema in BENCHES.md).
pub fn serving_path() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serving.json")
}

/// Repo-root path of the KV-memory report (`BENCH_kvmem.json`), written by
/// the `kvmem` bench — bytes-per-token and max-concurrent-lanes vs
/// `kv_keep` through the paged KV pool (schema in BENCHES.md).
pub fn kvmem_path() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_kvmem.json")
}

/// Repo-root path of the prefix-sharing report (`BENCH_prefix.json`),
/// written by the `prefixshare` bench — TTFT, prefill token-work, and
/// resident bytes vs shared-prefix fraction × `kv_keep` (schema in
/// BENCHES.md).
pub fn prefix_path() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_prefix.json")
}

/// Repo-root path of the scheduler report (`BENCH_interleave.json`),
/// written by the `interleave` bench — in-flight vs quiet inter-token
/// latency with and without chunked-prefill interleaving (schema in
/// BENCHES.md).
pub fn interleave_path() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_interleave.json")
}

/// Repo-root path of the speculation report (`BENCH_speculate.json`),
/// written by the `speculate` bench — draft acceptance rate, effective
/// tokens per verify cycle, and ITL vs the speculate=0 baseline, one row
/// per (`k_ratio`, `speculate`) point (schema in BENCHES.md).
pub fn speculate_path() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_speculate.json")
}

/// Repo-root path of the fused-kernel report (`BENCH_fused.json`), written
/// by the `fused` bench — page-fused streaming decode vs the three-pass
/// packed baseline, per-page-pass cost, scratch footprint, and the int8
/// resident-KV ratio, one row per (`mode`, `kv_quant`, context) operating
/// point (schema in BENCHES.md).
pub fn fused_path() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_fused.json")
}

/// An on-disk report being updated section-by-section.
pub struct BenchReport {
    doc: Json,
}

impl BenchReport {
    /// Load an existing report (preserving the sections other benches
    /// wrote) or start a fresh one; malformed files are replaced.
    pub fn load_or_new(path: &Path) -> BenchReport {
        let parsed = std::fs::read_to_string(path).ok().and_then(|s| Json::parse(&s).ok());
        let mut doc = match parsed {
            Some(d @ Json::Obj(_)) => d,
            _ => Json::obj(vec![]),
        };
        if let Json::Obj(o) = &mut doc {
            o.insert("schema_version".into(), Json::Num(SCHEMA_VERSION as f64));
            // a real bench run supersedes a cost-model-projected snapshot
            // (the benches are the only writers; see BENCHES.md)
            o.remove("projected");
            if !matches!(o.get("sections"), Some(Json::Obj(_))) {
                o.insert("sections".into(), Json::obj(vec![]));
            }
        }
        BenchReport { doc }
    }

    /// Replace one named section (a `{"rows": [...]}`-shaped object).
    pub fn set_section(&mut self, name: &str, section: Json) {
        if let Json::Obj(o) = &mut self.doc {
            if let Some(Json::Obj(sections)) = o.get_mut("sections") {
                sections.insert(name.to_string(), section);
            }
        }
    }

    pub fn doc(&self) -> &Json {
        &self.doc
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, format!("{}\n", self.doc)).with_context(|| format!("writing {path:?}"))
    }
}

fn rows_of<'a>(doc: &'a Json, section: &str) -> Result<&'a [Json]> {
    match doc.get("sections").get(section).get("rows").as_arr() {
        Some(r) if !r.is_empty() => Ok(r),
        _ => bail!("section '{section}' missing or empty"),
    }
}

/// Validate a `BENCH_decode.json` document. Non-strict checks the schema
/// both benches emit; `strict` additionally asserts the decode-overhaul
/// acceptance invariants: packed sparse decode at k=d/4 beats the
/// masked-dense oracle, and the sharded backend at 4 threads beats 1
/// thread on a batch-8 decode workload.
pub fn validate(doc: &Json, strict: bool) -> Result<()> {
    let ver = doc.get("schema_version").as_i64().unwrap_or(0);
    if ver != SCHEMA_VERSION {
        bail!("schema_version {ver} != {SCHEMA_VERSION}");
    }
    for r in rows_of(doc, "kernel_breakeven")? {
        if r.get("d").as_i64().is_none() || r.get("k").as_i64().is_none() {
            bail!("kernel_breakeven row missing d/k: {r}");
        }
    }
    let de = rows_of(doc, "decode_e2e")?;
    for r in de {
        for f in ["backend", "score_mode"] {
            if r.get(f).as_str().is_none() {
                bail!("decode_e2e row missing '{f}': {r}");
            }
        }
        for f in ["k_ratio", "batch", "threads", "mean_step_us", "tok_per_s"] {
            if r.get(f).as_f64().is_none() {
                bail!("decode_e2e row missing '{f}': {r}");
            }
        }
    }
    if !strict {
        return Ok(());
    }
    if doc.get("projected").as_bool() == Some(true) {
        bail!("strict validation refused: numbers are cost-model projections, not measurements \
               (regenerate with the benches)");
    }

    let find = |backend: &str, mode: &str, k: f64, batch: i64, threads: i64| -> Option<f64> {
        de.iter()
            .find(|r| {
                r.get("backend").as_str() == Some(backend)
                    && r.get("score_mode").as_str() == Some(mode)
                    && (r.get("k_ratio").as_f64().unwrap_or(-1.0) - k).abs() < 1e-9
                    && r.get("batch").as_i64() == Some(batch)
                    && r.get("threads").as_i64() == Some(threads)
            })
            .and_then(|r| r.get("tok_per_s").as_f64())
    };
    let masked = find("native", "masked", 0.25, 4, 1).context("missing masked k=0.25 b=4 row")?;
    let packed = find("native", "packed", 0.25, 4, 1).context("missing packed k=0.25 b=4 row")?;
    if packed <= masked {
        bail!("packed k=0.25 ({packed:.1} tok/s) does not beat masked-dense ({masked:.1} tok/s)");
    }
    let t1 = find("sharded", "auto", 0.25, 8, 1).context("missing sharded threads=1 row")?;
    let t4 = find("sharded", "auto", 0.25, 8, 4).context("missing sharded threads=4 row")?;
    if t4 <= t1 {
        bail!("sharded threads=4 ({t4:.1} tok/s) does not beat threads=1 ({t1:.1} tok/s)");
    }
    Ok(())
}

/// Validate a `BENCH_serving.json` document (the `openloop_serving`
/// section `examples/openloop_load.rs` emits: per-model throughput and
/// shed-rate under open-loop Poisson load; schema in BENCHES.md).
/// `strict` refuses cost-model-projected snapshots, mirroring
/// [`validate`].
pub fn validate_serving(doc: &Json, strict: bool) -> Result<()> {
    let ver = doc.get("schema_version").as_i64().unwrap_or(0);
    if ver != SCHEMA_VERSION {
        bail!("schema_version {ver} != {SCHEMA_VERSION}");
    }
    for r in rows_of(doc, "openloop_serving")? {
        for f in ["model", "backend"] {
            if r.get(f).as_str().is_none() {
                bail!("openloop_serving row missing '{f}': {r}");
            }
        }
        let num_fields = [
            "rate_rps", "sent", "done", "shed", "shed_rate", "tok_per_s", "e2e_p50_ms",
            "e2e_p99_ms", "ttft_p50_ms", "ttft_p99_ms",
        ];
        for f in num_fields {
            if r.get(f).as_f64().is_none() {
                bail!("openloop_serving row missing '{f}': {r}");
            }
        }
        let (sent, done, shed) = (
            r.get("sent").as_i64().unwrap_or(0),
            r.get("done").as_i64().unwrap_or(0),
            r.get("shed").as_i64().unwrap_or(0),
        );
        if done + shed != sent {
            bail!("openloop_serving row inconsistent (done {done} + shed {shed} != sent {sent})");
        }
    }
    if strict && doc.get("projected").as_bool() == Some(true) {
        bail!("strict validation refused: numbers are cost-model projections, not measurements \
               (regenerate with the serving bench)");
    }
    Ok(())
}

/// Validate a `BENCH_kvmem.json` document (the `kvmem` section the kvmem
/// bench emits: resident bytes-per-token and lanes-per-budget vs the
/// AQUA-Memory knob; schema in BENCHES.md). `strict` refuses projected
/// snapshots and asserts the memory-claim invariants: the `kv_keep = 0.5`
/// row's measured resident-to-dense ratio is <= 0.6, and a fixed budget
/// fits at least as many lanes at `kv_keep = 0.5` as at 1.0.
pub fn validate_kvmem(doc: &Json, strict: bool) -> Result<()> {
    let ver = doc.get("schema_version").as_i64().unwrap_or(0);
    if ver != SCHEMA_VERSION {
        bail!("schema_version {ver} != {SCHEMA_VERSION}");
    }
    let rows = rows_of(doc, "kvmem")?;
    for r in rows {
        for f in ["kv_keep", "bytes_per_token", "dense_bytes_per_token", "peak_resident_bytes",
                  "resident_ratio", "budget_mb"] {
            if r.get(f).as_f64().is_none() {
                bail!("kvmem row missing '{f}': {r}");
            }
        }
        for f in ["mem_dims", "page_slots", "max_lanes"] {
            if r.get(f).as_i64().is_none() {
                bail!("kvmem row missing '{f}': {r}");
            }
        }
        let (bpt, dense) = (
            r.get("bytes_per_token").as_f64().unwrap_or(0.0),
            r.get("dense_bytes_per_token").as_f64().unwrap_or(0.0),
        );
        if bpt > dense {
            bail!("kvmem row: resident bytes_per_token {bpt} exceeds dense {dense}: {r}");
        }
        // `kv_quant` is optional (pre-PR-10 rows are f32)
        match r.get("kv_quant").as_str() {
            None | Some("f32") | Some("int8") => {}
            other => bail!("kvmem row has unknown kv_quant {other:?}: {r}"),
        }
    }
    if !strict {
        return Ok(());
    }
    if doc.get("projected").as_bool() == Some(true) {
        bail!("strict validation refused: numbers are cost-model projections, not measurements \
               (regenerate with the kvmem bench)");
    }
    let find_quant = |keep: f64, quant: &str| -> Option<&Json> {
        rows.iter().find(|r| {
            (r.get("kv_keep").as_f64().unwrap_or(-1.0) - keep).abs() < 1e-9
                && r.get("kv_quant").as_str().unwrap_or("f32") == quant
        })
    };
    // the memory-claim bounds are stated on the f32 pool; int8 rows
    // (when present) must compound on top of the same kv_keep point
    let find = |keep: f64| find_quant(keep, "f32");
    if let (Some(q), Some(f)) = (find_quant(0.5, "int8"), find_quant(0.5, "f32")) {
        let (qp, fp) = (
            q.get("peak_resident_bytes").as_f64().unwrap_or(f64::MAX),
            f.get("peak_resident_bytes").as_f64().unwrap_or(0.0),
        );
        if qp > 0.6 * fp {
            bail!("kv_quant=int8 at kv_keep=0.5 resides {qp} B vs f32's {fp} B — misses the \
                   >= 40% reduction bound");
        }
    }
    let half = find(0.5).context("missing kv_keep=0.5 row")?;
    let full = find(1.0).context("missing kv_keep=1.0 row")?;
    let ratio = half.get("resident_ratio").as_f64().unwrap_or(1.0);
    if ratio > 0.6 {
        bail!("kv_keep=0.5 resident ratio {ratio:.3} exceeds the 0.6 acceptance bound");
    }
    let (l_half, l_full) = (
        half.get("max_lanes").as_i64().unwrap_or(0),
        full.get("max_lanes").as_i64().unwrap_or(0),
    );
    if l_half < l_full {
        bail!("kv_keep=0.5 fits {l_half} lanes < kv_keep=1.0's {l_full} — truncation must not \
               shrink capacity");
    }
    Ok(())
}

/// Validate a `BENCH_prefix.json` document (the `prefixshare` section the
/// prefixshare bench emits: resident bytes, TTFT, and prefill token-work
/// vs shared-prefix fraction × `kv_keep`; schema in BENCHES.md). The
/// schema pass enforces the counter reconciliation the serving metrics
/// promise — prefill work + cache hits == total prompt volume, so skipped
/// prefill is exactly proportional to the hit rate. `strict` refuses
/// projected snapshots and asserts the sharing acceptance bounds: at a
/// 50%-shared workload resident bytes are <= 0.65x the unshared pool, and
/// the saving compounds with `kv_keep = 0.5` byte-for-byte.
pub fn validate_prefix(doc: &Json, strict: bool) -> Result<()> {
    let ver = doc.get("schema_version").as_i64().unwrap_or(0);
    if ver != SCHEMA_VERSION {
        bail!("schema_version {ver} != {SCHEMA_VERSION}");
    }
    let rows = rows_of(doc, "prefixshare")?;
    for r in rows {
        for f in [
            "kv_keep", "shared_frac", "hit_rate", "peak_resident_bytes",
            "resident_per_lane_bytes", "resident_ratio_vs_unshared", "mean_ttft_ms",
        ] {
            if r.get(f).as_f64().is_none() {
                bail!("prefixshare row missing '{f}': {r}");
            }
        }
        for f in ["hit_tokens", "prefill_tokens", "total_prompt_tokens", "requests", "page_slots"] {
            if r.get(f).as_i64().is_none() {
                bail!("prefixshare row missing '{f}': {r}");
            }
        }
        if r.get("prefix_cache").as_bool().is_none() {
            bail!("prefixshare row missing 'prefix_cache': {r}");
        }
        let (hit, fed, total) = (
            r.get("hit_tokens").as_i64().unwrap_or(0),
            r.get("prefill_tokens").as_i64().unwrap_or(0),
            r.get("total_prompt_tokens").as_i64().unwrap_or(0),
        );
        if hit + fed != total {
            bail!(
                "prefixshare row inconsistent (hits {hit} + prefill {fed} != prompt volume \
                 {total}): skipped prefill must reconcile with the hit counters"
            );
        }
        if r.get("prefix_cache").as_bool() == Some(false) && hit != 0 {
            bail!("prefixshare row: sharing-disabled run reports cache hits: {r}");
        }
    }
    if !strict {
        return Ok(());
    }
    if doc.get("projected").as_bool() == Some(true) {
        bail!("strict validation refused: numbers are cost-model projections, not measurements \
               (regenerate with the prefixshare bench)");
    }
    let find = |keep: f64, frac: f64, on: bool| -> Option<&Json> {
        rows.iter().find(|r| {
            (r.get("kv_keep").as_f64().unwrap_or(-1.0) - keep).abs() < 1e-9
                && (r.get("shared_frac").as_f64().unwrap_or(-1.0) - frac).abs() < 1e-9
                && r.get("prefix_cache").as_bool() == Some(on)
        })
    };
    for keep in [1.0, 0.5] {
        let row = find(keep, 0.5, true)
            .with_context(|| format!("missing shared_frac=0.5 kv_keep={keep} row"))?;
        let ratio = row.get("resident_ratio_vs_unshared").as_f64().unwrap_or(1.0);
        if ratio > 0.65 {
            bail!(
                "50%-shared workload at kv_keep={keep}: resident ratio {ratio:.3} exceeds the \
                 0.65 acceptance bound"
            );
        }
    }
    // byte-for-byte compounding: the shared pool at kv_keep=0.5 is itself
    // smaller than the shared pool at 1.0 (truncated resident keys)
    let full = find(1.0, 0.5, true).context("missing kv_keep=1.0 shared row")?;
    let half = find(0.5, 0.5, true).context("missing kv_keep=0.5 shared row")?;
    let (bf, bh) = (
        full.get("peak_resident_bytes").as_f64().unwrap_or(0.0),
        half.get("peak_resident_bytes").as_f64().unwrap_or(f64::MAX),
    );
    if bh >= bf {
        bail!("sharing does not compound with kv_keep: {bh} B at 0.5 vs {bf} B at 1.0");
    }
    Ok(())
}

/// Validate a `BENCH_interleave.json` document (the `interleave` section
/// the interleave bench emits: p99 inter-token latency on a quiet decode
/// batch vs the same batch while a max_seq-scale prompt prefills, one row
/// per scheduler mode; schema in BENCHES.md). `strict` refuses projected
/// snapshots and asserts the starvation-fix acceptance bounds: with
/// interleaving on, in-flight p99 ITL stays within 2x the quiet baseline
/// (`itl_ratio <= 2.0`), and the legacy FIFO row is measurably worse than
/// the interleaved row — otherwise the bench isn't actually exercising
/// the starvation it claims to bound.
pub fn validate_interleave(doc: &Json, strict: bool) -> Result<()> {
    let ver = doc.get("schema_version").as_i64().unwrap_or(0);
    if ver != SCHEMA_VERSION {
        bail!("schema_version {ver} != {SCHEMA_VERSION}");
    }
    let rows = rows_of(doc, "interleave")?;
    for r in rows {
        for f in ["mode", "backend"] {
            if r.get(f).as_str().is_none() {
                bail!("interleave row missing '{f}': {r}");
            }
        }
        match r.get("mode").as_str() {
            Some("interleave") | Some("fifo") => {}
            other => bail!("interleave row has unknown mode {other:?}: {r}"),
        }
        for f in [
            "quiet_p99_itl_ms", "inflight_p99_itl_ms", "itl_ratio", "prefill_tokens_per_step",
            "batch_occupancy",
        ] {
            if r.get(f).as_f64().is_none() {
                bail!("interleave row missing '{f}': {r}");
            }
        }
        for f in ["batch", "max_prefill_tokens", "prompt_tokens", "steady_decode_allocs"] {
            if r.get(f).as_i64().is_none() {
                bail!("interleave row missing '{f}': {r}");
            }
        }
        let (quiet, inflight, ratio) = (
            r.get("quiet_p99_itl_ms").as_f64().unwrap_or(0.0),
            r.get("inflight_p99_itl_ms").as_f64().unwrap_or(0.0),
            r.get("itl_ratio").as_f64().unwrap_or(0.0),
        );
        if quiet <= 0.0 || inflight <= 0.0 {
            bail!("interleave row has non-positive latency: {r}");
        }
        if (ratio - inflight / quiet).abs() > 0.05 * ratio.max(1e-9) {
            bail!("interleave row: itl_ratio {ratio} inconsistent with \
                   inflight/quiet = {}: {r}", inflight / quiet);
        }
        // satellite: the steady-state decode loop must be allocation-free
        if r.get("steady_decode_allocs").as_i64() != Some(0) {
            bail!("interleave row reports steady-state decode allocations: {r}");
        }
    }
    if !strict {
        return Ok(());
    }
    if doc.get("projected").as_bool() == Some(true) {
        bail!("strict validation refused: numbers are cost-model projections, not measurements \
               (regenerate with the interleave bench)");
    }
    let by_mode = |m: &str| rows.iter().find(|r| r.get("mode").as_str() == Some(m));
    let on = by_mode("interleave").context("missing mode=interleave row")?;
    let off = by_mode("fifo").context("missing mode=fifo row")?;
    let on_ratio = on.get("itl_ratio").as_f64().unwrap_or(f64::MAX);
    let off_ratio = off.get("itl_ratio").as_f64().unwrap_or(0.0);
    if on_ratio > 2.0 {
        bail!("interleave-on in-flight p99 ITL is {on_ratio:.2}x the quiet baseline — exceeds \
               the 2x acceptance bound");
    }
    if off_ratio <= on_ratio {
        bail!("FIFO ratio {off_ratio:.2} does not exceed interleave ratio {on_ratio:.2} — the \
               bench workload is not long enough to starve decode");
    }
    Ok(())
}

/// Validate a `BENCH_speculate.json` document (the `speculate` section the
/// speculate bench emits: per-(`k_ratio`, `speculate`) draft acceptance
/// rate, effective tokens per verify cycle, and ITL vs the speculate=0
/// baseline; schema in BENCHES.md). The schema pass enforces the counter
/// reconciliation the serving metrics promise — `accepted + rejected ==
/// drafted`, the acceptance rate and effective-tokens ratios re-derive
/// from the raw counters, and the steady-state draft/verify loop reported
/// zero heap allocations. `strict` refuses projected snapshots and asserts
/// the speculation acceptance bound: at `k_ratio = 0.25` (k = d/4) the
/// sparse draft must be right often enough that each exact verify pass
/// commits more than one token on average (`tokens_per_step_effective >
/// 1.0`) — otherwise speculating is pure overhead at that operating point.
pub fn validate_speculate(doc: &Json, strict: bool) -> Result<()> {
    let ver = doc.get("schema_version").as_i64().unwrap_or(0);
    if ver != SCHEMA_VERSION {
        bail!("schema_version {ver} != {SCHEMA_VERSION}");
    }
    let rows = rows_of(doc, "speculate")?;
    for r in rows {
        if r.get("backend").as_str().is_none() {
            bail!("speculate row missing 'backend': {r}");
        }
        for f in ["k_ratio", "acceptance_rate", "tokens_per_step_effective", "tok_per_s",
                  "itl_ratio_vs_off"] {
            if r.get(f).as_f64().is_none() {
                bail!("speculate row missing '{f}': {r}");
            }
        }
        for f in ["speculate", "batch", "drafted", "accepted", "rejected", "committed",
                  "lane_cycles", "steady_spec_allocs"] {
            if r.get(f).as_i64().is_none() {
                bail!("speculate row missing '{f}': {r}");
            }
        }
        let (drafted, accepted, rejected) = (
            r.get("drafted").as_i64().unwrap_or(0),
            r.get("accepted").as_i64().unwrap_or(0),
            r.get("rejected").as_i64().unwrap_or(0),
        );
        if accepted + rejected != drafted {
            bail!("speculate row inconsistent (accepted {accepted} + rejected {rejected} != \
                   drafted {drafted}): the draft ledger must reconcile");
        }
        if drafted > 0 {
            let rate = r.get("acceptance_rate").as_f64().unwrap_or(-1.0);
            let derived = accepted as f64 / drafted as f64;
            if (rate - derived).abs() > 1e-6 {
                bail!("speculate row: acceptance_rate {rate} != accepted/drafted {derived}: {r}");
            }
        }
        let cycles = r.get("lane_cycles").as_i64().unwrap_or(0);
        if cycles > 0 {
            let eff = r.get("tokens_per_step_effective").as_f64().unwrap_or(-1.0);
            let derived = r.get("committed").as_i64().unwrap_or(0) as f64 / cycles as f64;
            if (eff - derived).abs() > 1e-6 {
                bail!("speculate row: tokens_per_step_effective {eff} != committed/lane_cycles \
                       {derived}: {r}");
            }
        }
        if r.get("speculate").as_i64() == Some(0) && drafted != 0 {
            bail!("speculate row: speculate=0 baseline reports drafted tokens: {r}");
        }
        // tentpole acceptance: the draft/verify loop is allocation-free
        if r.get("steady_spec_allocs").as_i64() != Some(0) {
            bail!("speculate row reports steady-state draft/verify allocations: {r}");
        }
    }
    if !strict {
        return Ok(());
    }
    if doc.get("projected").as_bool() == Some(true) {
        bail!("strict validation refused: numbers are cost-model projections, not measurements \
               (regenerate with the speculate bench)");
    }
    let row = rows
        .iter()
        .find(|r| {
            (r.get("k_ratio").as_f64().unwrap_or(-1.0) - 0.25).abs() < 1e-9
                && r.get("speculate").as_i64().unwrap_or(0) > 0
        })
        .context("missing k_ratio=0.25 speculate>0 row")?;
    let eff = row.get("tokens_per_step_effective").as_f64().unwrap_or(0.0);
    if eff <= 1.0 {
        bail!("k_ratio=0.25 speculation commits only {eff:.3} tokens per verify cycle — \
               speculating must beat one-token-per-step to pay for itself");
    }
    Ok(())
}

/// Validate a `BENCH_fused.json` document (the `fused` section the fused
/// bench emits: page-fused streaming decode vs the three-pass packed
/// baseline, one row per (`mode`, `kv_quant`, context) operating point;
/// schema in BENCHES.md). The schema pass enforces the tentpole's
/// structural invariants — they are deterministic counter/byte arithmetic,
/// not timings, so a projected snapshot must satisfy them too: fused rows
/// keep `scratch_bytes` within one page (`<= page_bytes`, the O(page_slots)
/// claim), reconcile `fused_passes_per_step` with
/// `expected_page_loads_per_step` (each resident page read exactly once),
/// report zero steady-state decode allocations, carry a finite parity
/// delta (<= 1e-5 vs packed on f32; <= 0.5 on int8), and int8 rows cut
/// resident bytes to <= 0.6x their f32 twin. `strict` refuses projected
/// snapshots and asserts the perf acceptance bound: at `context_slots >=
/// 512` the fused f32 path sustains >= 1.3x the packed three-pass decode
/// throughput at the same operating point.
pub fn validate_fused(doc: &Json, strict: bool) -> Result<()> {
    let ver = doc.get("schema_version").as_i64().unwrap_or(0);
    if ver != SCHEMA_VERSION {
        bail!("schema_version {ver} != {SCHEMA_VERSION}");
    }
    let rows = rows_of(doc, "fused")?;
    for r in rows {
        for f in ["backend", "mode", "kv_quant"] {
            if r.get(f).as_str().is_none() {
                bail!("fused row missing '{f}': {r}");
            }
        }
        match r.get("mode").as_str() {
            Some("fused") | Some("packed") => {}
            other => bail!("fused row has unknown mode {other:?}: {r}"),
        }
        match r.get("kv_quant").as_str() {
            Some("f32") | Some("int8") => {}
            other => bail!("fused row has unknown kv_quant {other:?}: {r}"),
        }
        for f in ["k_ratio", "mean_step_us", "tok_per_s", "page_pass_ns", "parity_max_abs_delta",
                  "resident_bytes_ratio_vs_f32", "dequant_ns_per_step"] {
            if r.get(f).as_f64().is_none() {
                bail!("fused row missing '{f}': {r}");
            }
        }
        for f in ["batch", "threads", "context_slots", "page_slots", "page_bytes", "scratch_bytes",
                  "fused_passes_per_step", "expected_page_loads_per_step", "steady_decode_allocs",
                  "simd_lanes"] {
            if r.get(f).as_i64().is_none() {
                bail!("fused row missing '{f}': {r}");
            }
        }
        let parity = r.get("parity_max_abs_delta").as_f64().unwrap_or(f64::NAN);
        if !parity.is_finite() || parity < 0.0 {
            bail!("fused row has non-finite parity delta: {r}");
        }
        let fused = r.get("mode").as_str() == Some("fused");
        let int8 = r.get("kv_quant").as_str() == Some("int8");
        if fused {
            let (scratch, page) = (
                r.get("scratch_bytes").as_i64().unwrap_or(i64::MAX),
                r.get("page_bytes").as_i64().unwrap_or(0),
            );
            if scratch > page {
                bail!("fused row scratch {scratch} B exceeds one page ({page} B) — the kernel \
                       must stream with O(page_slots) scratch: {r}");
            }
            let (passes, expected) = (
                r.get("fused_passes_per_step").as_i64().unwrap_or(-1),
                r.get("expected_page_loads_per_step").as_i64().unwrap_or(-2),
            );
            if passes != expected {
                bail!("fused row reads each resident page {passes} times per step, expected \
                       {expected} (lanes x layers x heads x resident pages): {r}");
            }
            // satellite: the fused decode loop is allocation-free
            if r.get("steady_decode_allocs").as_i64() != Some(0) {
                bail!("fused row reports steady-state decode allocations: {r}");
            }
            let bound = if int8 { 0.5 } else { 1e-5 };
            if parity > bound {
                bail!("fused row parity delta {parity} exceeds the {bound} bound vs the packed \
                       three-pass baseline: {r}");
            }
        } else if r.get("fused_passes_per_step").as_i64() != Some(0) {
            bail!("packed baseline row claims fused page passes: {r}");
        }
        let ratio = r.get("resident_bytes_ratio_vs_f32").as_f64().unwrap_or(1.0);
        if int8 && ratio > 0.6 {
            bail!("int8 row resident bytes are {ratio:.3}x f32 — misses the >= 40% reduction \
                   acceptance bound: {r}");
        }
    }
    if !strict {
        return Ok(());
    }
    if doc.get("projected").as_bool() == Some(true) {
        bail!("strict validation refused: numbers are cost-model projections, not measurements \
               (regenerate with the fused bench)");
    }
    let find = |mode: &str| -> Option<f64> {
        rows.iter()
            .find(|r| {
                r.get("mode").as_str() == Some(mode)
                    && r.get("kv_quant").as_str() == Some("f32")
                    && r.get("context_slots").as_i64().unwrap_or(0) >= 512
            })
            .and_then(|r| r.get("tok_per_s").as_f64())
    };
    let fused = find("fused").context("missing fused f32 row at context_slots >= 512")?;
    let packed = find("packed").context("missing packed f32 row at context_slots >= 512")?;
    if fused < 1.3 * packed {
        bail!("fused decode ({fused:.1} tok/s) is under 1.3x the packed three-pass baseline \
               ({packed:.1} tok/s) at context >= 512");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn e2e_row(backend: &str, mode: &str, k: f64, batch: f64, threads: f64, tps: f64) -> Json {
        Json::obj(vec![
            ("backend", Json::Str(backend.into())),
            ("score_mode", Json::Str(mode.into())),
            ("k_ratio", Json::Num(k)),
            ("batch", Json::Num(batch)),
            ("threads", Json::Num(threads)),
            ("mean_step_us", Json::Num(1e6 / tps)),
            ("tok_per_s", Json::Num(tps)),
        ])
    }

    fn sample_report(packed_tps: f64, t4_tps: f64) -> Json {
        let kb = Json::obj(vec![(
            "rows",
            Json::Arr(vec![Json::obj(vec![("d", Json::Num(32.0)), ("k", Json::Num(8.0))])]),
        )]);
        let de = Json::obj(vec![(
            "rows",
            Json::Arr(vec![
                e2e_row("native", "masked", 0.25, 4.0, 1.0, 1000.0),
                e2e_row("native", "packed", 0.25, 4.0, 1.0, packed_tps),
                e2e_row("sharded", "auto", 0.25, 8.0, 1.0, 2000.0),
                e2e_row("sharded", "auto", 0.25, 8.0, 4.0, t4_tps),
            ]),
        )]);
        Json::obj(vec![
            ("schema_version", Json::Num(SCHEMA_VERSION as f64)),
            ("sections", Json::obj(vec![("kernel_breakeven", kb), ("decode_e2e", de)])),
        ])
    }

    #[test]
    fn roundtrip_preserves_other_sections() {
        let dir = std::env::temp_dir().join("aqua_bench_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_decode.json");
        let _ = std::fs::remove_file(&path);

        let mut rep = BenchReport::load_or_new(&path);
        rep.set_section("kernel_breakeven", Json::obj(vec![("rows", Json::Arr(vec![]))]));
        rep.save(&path).unwrap();

        // a second bench writing its own section keeps the first
        let mut rep2 = BenchReport::load_or_new(&path);
        rep2.set_section("decode_e2e", Json::obj(vec![("rows", Json::Arr(vec![]))]));
        rep2.save(&path).unwrap();

        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert!(doc.get("sections").get("kernel_breakeven").get("rows").as_arr().is_some());
        assert!(doc.get("sections").get("decode_e2e").get("rows").as_arr().is_some());
        assert_eq!(doc.get("schema_version").as_i64(), Some(SCHEMA_VERSION));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn validate_accepts_good_and_rejects_bad() {
        let good = sample_report(4000.0, 6000.0);
        validate(&good, false).unwrap();
        validate(&good, true).unwrap();

        // packed slower than masked: schema-valid, strict-invalid
        let slow = sample_report(500.0, 6000.0);
        validate(&slow, false).unwrap();
        assert!(validate(&slow, true).is_err());

        // sharded scaling regression: strict-invalid
        let flat = sample_report(4000.0, 1500.0);
        assert!(validate(&flat, true).is_err());

        // empty doc: schema-invalid
        assert!(validate(&Json::obj(vec![]), false).is_err());

        // projected snapshots pass the schema but refuse strict validation
        let mut projected = sample_report(4000.0, 6000.0);
        if let Json::Obj(o) = &mut projected {
            o.insert("projected".into(), Json::Bool(true));
        }
        validate(&projected, false).unwrap();
        assert!(validate(&projected, true).is_err());
    }

    fn serving_row(model: &str, sent: f64, done: f64, shed: f64) -> Json {
        Json::obj(vec![
            ("model", Json::Str(model.into())),
            ("backend", Json::Str("native".into())),
            ("rate_rps", Json::Num(6.0)),
            ("sent", Json::Num(sent)),
            ("done", Json::Num(done)),
            ("shed", Json::Num(shed)),
            ("shed_rate", Json::Num(if sent > 0.0 { shed / sent } else { 0.0 })),
            ("tok_per_s", Json::Num(120.0)),
            ("e2e_p50_ms", Json::Num(8.0)),
            ("e2e_p99_ms", Json::Num(30.0)),
            ("ttft_p50_ms", Json::Num(2.0)),
            ("ttft_p99_ms", Json::Num(9.0)),
        ])
    }

    #[test]
    fn validate_serving_checks_schema_and_accounting() {
        let good = Json::obj(vec![
            ("schema_version", Json::Num(SCHEMA_VERSION as f64)),
            (
                "sections",
                Json::obj(vec![(
                    "openloop_serving",
                    Json::obj(vec![(
                        "rows",
                        Json::Arr(vec![serving_row("exact", 12.0, 10.0, 2.0)]),
                    )]),
                )]),
            ),
        ]);
        validate_serving(&good, false).unwrap();
        validate_serving(&good, true).unwrap();

        // shed accounting must balance
        let bad = Json::obj(vec![
            ("schema_version", Json::Num(SCHEMA_VERSION as f64)),
            (
                "sections",
                Json::obj(vec![(
                    "openloop_serving",
                    Json::obj(vec![(
                        "rows",
                        Json::Arr(vec![serving_row("exact", 12.0, 10.0, 1.0)]),
                    )]),
                )]),
            ),
        ]);
        assert!(validate_serving(&bad, false).is_err());

        // empty / missing section is schema-invalid
        let empty =
            Json::obj(vec![("schema_version", Json::Num(SCHEMA_VERSION as f64))]);
        assert!(validate_serving(&empty, false).is_err());
        assert!(validate_serving(&Json::obj(vec![]), false).is_err());

        // projected snapshots pass the schema but refuse strict validation
        let mut projected = good.clone();
        if let Json::Obj(o) = &mut projected {
            o.insert("projected".into(), Json::Bool(true));
        }
        validate_serving(&projected, false).unwrap();
        assert!(validate_serving(&projected, true).is_err());
    }

    fn kvmem_row(keep: f64, bpt: f64, ratio: f64, lanes: f64) -> Json {
        Json::obj(vec![
            ("kv_keep", Json::Num(keep)),
            ("mem_dims", Json::Num((keep * 8.0).round())),
            ("page_slots", Json::Num(16.0)),
            ("bytes_per_token", Json::Num(bpt)),
            ("dense_bytes_per_token", Json::Num(256.0)),
            ("peak_resident_bytes", Json::Num(ratio * 163840.0)),
            ("resident_ratio", Json::Num(ratio)),
            ("max_lanes", Json::Num(lanes)),
            ("budget_mb", Json::Num(1.0)),
        ])
    }

    fn kvmem_doc(rows: Vec<Json>) -> Json {
        Json::obj(vec![
            ("schema_version", Json::Num(SCHEMA_VERSION as f64)),
            (
                "sections",
                Json::obj(vec![("kvmem", Json::obj(vec![("rows", Json::Arr(rows))]))]),
            ),
        ])
    }

    #[test]
    fn validate_kvmem_schema_and_invariants() {
        let good =
            kvmem_doc(vec![kvmem_row(1.0, 256.0, 0.40, 25.0), kvmem_row(0.5, 192.0, 0.30, 34.0)]);
        validate_kvmem(&good, false).unwrap();
        validate_kvmem(&good, true).unwrap();

        // resident exceeding dense is schema-invalid
        let inflated = kvmem_doc(vec![kvmem_row(1.0, 300.0, 0.4, 25.0)]);
        assert!(validate_kvmem(&inflated, false).is_err());

        // the 0.5 row must beat the 0.6 acceptance bound under --strict
        let weak =
            kvmem_doc(vec![kvmem_row(1.0, 256.0, 0.40, 25.0), kvmem_row(0.5, 192.0, 0.75, 34.0)]);
        validate_kvmem(&weak, false).unwrap();
        assert!(validate_kvmem(&weak, true).is_err());

        // fewer lanes at 0.5 than 1.0 is a strict failure too
        let shrunk =
            kvmem_doc(vec![kvmem_row(1.0, 256.0, 0.40, 25.0), kvmem_row(0.5, 192.0, 0.30, 20.0)]);
        assert!(validate_kvmem(&shrunk, true).is_err());

        // int8 rows ride the same section: they must compound the saving
        // at the same kv_keep point (and an unknown kv_quant is rejected)
        let int8_row = |ratio: f64| {
            let mut r = kvmem_row(0.5, 50.0, ratio, 131.0);
            if let Json::Obj(o) = &mut r {
                o.insert("kv_quant".into(), Json::Str("int8".into()));
            }
            r
        };
        let compounded = kvmem_doc(vec![
            kvmem_row(1.0, 256.0, 0.40, 25.0),
            kvmem_row(0.5, 192.0, 0.30, 34.0),
            int8_row(0.08),
        ]);
        validate_kvmem(&compounded, false).unwrap();
        validate_kvmem(&compounded, true).unwrap();
        let heavy = kvmem_doc(vec![
            kvmem_row(1.0, 256.0, 0.40, 25.0),
            kvmem_row(0.5, 192.0, 0.30, 34.0),
            int8_row(0.25),
        ]);
        validate_kvmem(&heavy, false).unwrap();
        assert!(validate_kvmem(&heavy, true).is_err());
        let mut odd = kvmem_row(0.5, 50.0, 0.08, 131.0);
        if let Json::Obj(o) = &mut odd {
            o.insert("kv_quant".into(), Json::Str("fp4".into()));
        }
        assert!(validate_kvmem(&kvmem_doc(vec![odd]), false).is_err());

        // projected snapshots pass the schema but refuse strict validation
        let mut projected = good.clone();
        if let Json::Obj(o) = &mut projected {
            o.insert("projected".into(), Json::Bool(true));
        }
        validate_kvmem(&projected, false).unwrap();
        assert!(validate_kvmem(&projected, true).is_err());

        assert!(validate_kvmem(&Json::obj(vec![]), false).is_err());
    }

    fn prefix_row(keep: f64, frac: f64, on: bool, hit: f64, peak: f64, ratio: f64) -> Json {
        let total = 864.0;
        Json::obj(vec![
            ("kv_keep", Json::Num(keep)),
            ("shared_frac", Json::Num(frac)),
            ("prefix_cache", Json::Bool(on)),
            ("requests", Json::Num(9.0)),
            ("page_slots", Json::Num(16.0)),
            ("hit_tokens", Json::Num(hit)),
            ("prefill_tokens", Json::Num(total - hit)),
            ("total_prompt_tokens", Json::Num(total)),
            ("hit_rate", Json::Num(hit / total)),
            ("peak_resident_bytes", Json::Num(peak)),
            ("resident_per_lane_bytes", Json::Num(peak / 8.0)),
            ("resident_ratio_vs_unshared", Json::Num(ratio)),
            ("mean_ttft_ms", Json::Num(if on { 1.0 } else { 2.0 })),
        ])
    }

    fn prefix_doc(rows: Vec<Json>) -> Json {
        Json::obj(vec![
            ("schema_version", Json::Num(SCHEMA_VERSION as f64)),
            (
                "sections",
                Json::obj(vec![("prefixshare", Json::obj(vec![("rows", Json::Arr(rows))]))]),
            ),
        ])
    }

    #[test]
    fn validate_prefix_schema_and_invariants() {
        let good = prefix_doc(vec![
            prefix_row(1.0, 0.5, true, 384.0, 143360.0, 0.625),
            prefix_row(1.0, 0.5, false, 0.0, 229376.0, 1.0),
            prefix_row(0.5, 0.5, true, 384.0, 107520.0, 0.625),
            prefix_row(0.5, 0.5, false, 0.0, 172032.0, 1.0),
        ]);
        validate_prefix(&good, false).unwrap();
        validate_prefix(&good, true).unwrap();

        // hit/prefill accounting must reconcile with the prompt volume
        let mut bad_row = prefix_row(1.0, 0.5, true, 384.0, 1.0, 0.5);
        if let Json::Obj(r) = &mut bad_row {
            r.insert("prefill_tokens".into(), Json::Num(999.0));
        }
        assert!(validate_prefix(&prefix_doc(vec![bad_row]), false).is_err());

        // a sharing-disabled run reporting hits is schema-invalid
        let lying = prefix_doc(vec![prefix_row(1.0, 0.5, false, 384.0, 1.0, 1.0)]);
        assert!(validate_prefix(&lying, false).is_err());

        // the 0.65 acceptance bound is a strict failure only
        let weak = prefix_doc(vec![
            prefix_row(1.0, 0.5, true, 384.0, 200000.0, 0.9),
            prefix_row(0.5, 0.5, true, 384.0, 107520.0, 0.625),
        ]);
        validate_prefix(&weak, false).unwrap();
        assert!(validate_prefix(&weak, true).is_err());

        // compounding: kv_keep=0.5 shared bytes must undercut kv_keep=1.0
        let flat = prefix_doc(vec![
            prefix_row(1.0, 0.5, true, 384.0, 143360.0, 0.625),
            prefix_row(0.5, 0.5, true, 384.0, 143360.0, 0.625),
        ]);
        assert!(validate_prefix(&flat, true).is_err());

        // projected snapshots pass the schema but refuse strict validation
        let mut projected = good.clone();
        if let Json::Obj(o) = &mut projected {
            o.insert("projected".into(), Json::Bool(true));
        }
        validate_prefix(&projected, false).unwrap();
        assert!(validate_prefix(&projected, true).is_err());

        assert!(validate_prefix(&Json::obj(vec![]), false).is_err());
    }

    fn interleave_row(mode: &str, quiet: f64, inflight: f64) -> Json {
        Json::obj(vec![
            ("mode", Json::Str(mode.into())),
            ("backend", Json::Str("native".into())),
            ("batch", Json::Num(4.0)),
            ("max_prefill_tokens", Json::Num(32.0)),
            ("prompt_tokens", Json::Num(192.0)),
            ("quiet_p99_itl_ms", Json::Num(quiet)),
            ("inflight_p99_itl_ms", Json::Num(inflight)),
            ("itl_ratio", Json::Num(inflight / quiet)),
            ("prefill_tokens_per_step", Json::Num(12.0)),
            ("batch_occupancy", Json::Num(0.9)),
            ("steady_decode_allocs", Json::Num(0.0)),
        ])
    }

    fn interleave_doc(rows: Vec<Json>) -> Json {
        Json::obj(vec![
            ("schema_version", Json::Num(SCHEMA_VERSION as f64)),
            (
                "sections",
                Json::obj(vec![("interleave", Json::obj(vec![("rows", Json::Arr(rows))]))]),
            ),
        ])
    }

    #[test]
    fn validate_interleave_schema_and_invariants() {
        let good = interleave_doc(vec![
            interleave_row("interleave", 0.35, 0.65),
            interleave_row("fifo", 0.35, 3.2),
        ]);
        validate_interleave(&good, false).unwrap();
        validate_interleave(&good, true).unwrap();

        // unknown mode is schema-invalid
        let odd = interleave_doc(vec![interleave_row("turbo", 0.35, 0.65)]);
        assert!(validate_interleave(&odd, false).is_err());

        // itl_ratio must reconcile with inflight/quiet
        let mut fudged = interleave_row("interleave", 0.35, 0.65);
        if let Json::Obj(r) = &mut fudged {
            r.insert("itl_ratio".into(), Json::Num(1.0));
        }
        assert!(validate_interleave(&interleave_doc(vec![fudged]), false).is_err());

        // a decode-loop allocation is a schema failure (no-alloc satellite)
        let mut leaky = interleave_row("interleave", 0.35, 0.65);
        if let Json::Obj(r) = &mut leaky {
            r.insert("steady_decode_allocs".into(), Json::Num(3.0));
        }
        assert!(validate_interleave(&interleave_doc(vec![leaky]), false).is_err());

        // the 2x in-flight bound is a strict failure only
        let weak = interleave_doc(vec![
            interleave_row("interleave", 0.35, 1.0),
            interleave_row("fifo", 0.35, 3.2),
        ]);
        validate_interleave(&weak, false).unwrap();
        assert!(validate_interleave(&weak, true).is_err());

        // FIFO must actually be worse, else the workload proves nothing
        let flat = interleave_doc(vec![
            interleave_row("interleave", 0.35, 0.65),
            interleave_row("fifo", 0.35, 0.60),
        ]);
        assert!(validate_interleave(&flat, true).is_err());

        // projected snapshots pass the schema but refuse strict validation
        let mut projected = good.clone();
        if let Json::Obj(o) = &mut projected {
            o.insert("projected".into(), Json::Bool(true));
        }
        validate_interleave(&projected, false).unwrap();
        assert!(validate_interleave(&projected, true).is_err());

        assert!(validate_interleave(&Json::obj(vec![]), false).is_err());
    }

    fn speculate_row(k: f64, spec: f64, drafted: f64, accepted: f64, eff: f64) -> Json {
        let cycles = 100.0;
        Json::obj(vec![
            ("backend", Json::Str("native".into())),
            ("k_ratio", Json::Num(k)),
            ("speculate", Json::Num(spec)),
            ("batch", Json::Num(4.0)),
            ("drafted", Json::Num(drafted)),
            ("accepted", Json::Num(accepted)),
            ("rejected", Json::Num(drafted - accepted)),
            ("committed", Json::Num(eff * cycles)),
            ("lane_cycles", Json::Num(cycles)),
            (
                "acceptance_rate",
                Json::Num(if drafted > 0.0 { accepted / drafted } else { 0.0 }),
            ),
            ("tokens_per_step_effective", Json::Num(eff)),
            ("tok_per_s", Json::Num(900.0)),
            ("itl_ratio_vs_off", Json::Num(1.0 / eff.max(1e-9))),
            ("steady_spec_allocs", Json::Num(0.0)),
        ])
    }

    fn speculate_doc(rows: Vec<Json>) -> Json {
        Json::obj(vec![
            ("schema_version", Json::Num(SCHEMA_VERSION as f64)),
            (
                "sections",
                Json::obj(vec![("speculate", Json::obj(vec![("rows", Json::Arr(rows))]))]),
            ),
        ])
    }

    #[test]
    fn validate_speculate_schema_and_invariants() {
        let good = speculate_doc(vec![
            speculate_row(0.25, 0.0, 0.0, 0.0, 1.0),
            speculate_row(0.25, 4.0, 380.0, 290.0, 2.9),
            speculate_row(0.5, 4.0, 390.0, 340.0, 3.4),
        ]);
        validate_speculate(&good, false).unwrap();
        validate_speculate(&good, true).unwrap();

        // the draft ledger must reconcile
        let mut bad = speculate_row(0.25, 4.0, 380.0, 290.0, 2.9);
        if let Json::Obj(r) = &mut bad {
            r.insert("rejected".into(), Json::Num(5.0));
        }
        assert!(validate_speculate(&speculate_doc(vec![bad]), false).is_err());

        // derived rates must match the raw counters
        let mut fudged = speculate_row(0.25, 4.0, 380.0, 290.0, 2.9);
        if let Json::Obj(r) = &mut fudged {
            r.insert("acceptance_rate".into(), Json::Num(0.99));
        }
        assert!(validate_speculate(&speculate_doc(vec![fudged]), false).is_err());

        // a speculate=0 baseline claiming drafts is lying
        let lying = speculate_doc(vec![speculate_row(0.25, 0.0, 10.0, 10.0, 1.0)]);
        assert!(validate_speculate(&lying, false).is_err());

        // a draft/verify-loop allocation is a schema failure
        let mut leaky = speculate_row(0.25, 4.0, 380.0, 290.0, 2.9);
        if let Json::Obj(r) = &mut leaky {
            r.insert("steady_spec_allocs".into(), Json::Num(2.0));
        }
        assert!(validate_speculate(&speculate_doc(vec![leaky]), false).is_err());

        // effective tokens/step must beat 1.0 at k=d/4 under --strict only
        let weak = speculate_doc(vec![
            speculate_row(0.25, 0.0, 0.0, 0.0, 1.0),
            speculate_row(0.25, 4.0, 380.0, 0.0, 1.0),
        ]);
        validate_speculate(&weak, false).unwrap();
        assert!(validate_speculate(&weak, true).is_err());

        // projected snapshots pass the schema but refuse strict validation
        let mut projected = good.clone();
        if let Json::Obj(o) = &mut projected {
            o.insert("projected".into(), Json::Bool(true));
        }
        validate_speculate(&projected, false).unwrap();
        assert!(validate_speculate(&projected, true).is_err());

        assert!(validate_speculate(&Json::obj(vec![]), false).is_err());
    }

    fn fused_row(mode: &str, quant: &str, ctx: f64, tps: f64) -> Json {
        let fused = mode == "fused";
        let pages = (ctx / 16.0).floor() + 1.0;
        Json::obj(vec![
            ("backend", Json::Str("native".into())),
            ("mode", Json::Str(mode.into())),
            ("kv_quant", Json::Str(quant.into())),
            ("k_ratio", Json::Num(0.25)),
            ("batch", Json::Num(4.0)),
            ("threads", Json::Num(1.0)),
            ("context_slots", Json::Num(ctx)),
            ("page_slots", Json::Num(16.0)),
            ("page_bytes", Json::Num(4096.0)),
            ("scratch_bytes", Json::Num(if fused { 64.0 } else { 2560.0 })),
            ("mean_step_us", Json::Num(1e6 * 4.0 / tps)),
            ("tok_per_s", Json::Num(tps)),
            ("page_pass_ns", Json::Num(if fused { 180.0 } else { 0.0 })),
            ("fused_passes_per_step", Json::Num(if fused { 4.0 * 2.0 * 4.0 * pages } else { 0.0 })),
            (
                "expected_page_loads_per_step",
                Json::Num(if fused { 4.0 * 2.0 * 4.0 * pages } else { 0.0 }),
            ),
            ("parity_max_abs_delta", Json::Num(if quant == "int8" { 0.08 } else { 0.0 })),
            (
                "resident_bytes_ratio_vs_f32",
                Json::Num(if quant == "int8" { 0.26 } else { 1.0 }),
            ),
            ("dequant_ns_per_step", Json::Num(if quant == "int8" { 900.0 } else { 0.0 })),
            ("steady_decode_allocs", Json::Num(0.0)),
            ("simd_lanes", Json::Num(8.0)),
        ])
    }

    fn fused_doc(rows: Vec<Json>) -> Json {
        Json::obj(vec![
            ("schema_version", Json::Num(SCHEMA_VERSION as f64)),
            (
                "sections",
                Json::obj(vec![("fused", Json::obj(vec![("rows", Json::Arr(rows))]))]),
            ),
        ])
    }

    #[test]
    fn validate_fused_schema_and_invariants() {
        let good = fused_doc(vec![
            fused_row("packed", "f32", 560.0, 1000.0),
            fused_row("fused", "f32", 560.0, 1500.0),
            fused_row("fused", "int8", 560.0, 1400.0),
        ]);
        validate_fused(&good, false).unwrap();
        validate_fused(&good, true).unwrap();

        // O(S) scratch on the fused path is a schema failure
        let mut fat = fused_row("fused", "f32", 560.0, 1500.0);
        if let Json::Obj(r) = &mut fat {
            r.insert("scratch_bytes".into(), Json::Num(999999.0));
        }
        assert!(validate_fused(&fused_doc(vec![fat]), false).is_err());

        // re-reading a page breaks the read-once invariant
        let mut rereads = fused_row("fused", "f32", 560.0, 1500.0);
        if let Json::Obj(r) = &mut rereads {
            r.insert("fused_passes_per_step".into(), Json::Num(9999.0));
        }
        assert!(validate_fused(&fused_doc(vec![rereads]), false).is_err());

        // a decode-loop allocation is a schema failure (no-alloc gate)
        let mut leaky = fused_row("fused", "f32", 560.0, 1500.0);
        if let Json::Obj(r) = &mut leaky {
            r.insert("steady_decode_allocs".into(), Json::Num(2.0));
        }
        assert!(validate_fused(&fused_doc(vec![leaky]), false).is_err());

        // f32 fused must match packed to 1e-5; int8 gets the loose bound
        let mut drifted = fused_row("fused", "f32", 560.0, 1500.0);
        if let Json::Obj(r) = &mut drifted {
            r.insert("parity_max_abs_delta".into(), Json::Num(0.01));
        }
        assert!(validate_fused(&fused_doc(vec![drifted]), false).is_err());

        // int8 missing the 40% resident-KV reduction is a schema failure
        let mut heavy = fused_row("fused", "int8", 560.0, 1400.0);
        if let Json::Obj(r) = &mut heavy {
            r.insert("resident_bytes_ratio_vs_f32".into(), Json::Num(0.8));
        }
        assert!(validate_fused(&fused_doc(vec![heavy]), false).is_err());

        // a packed baseline claiming fused passes is lying
        let mut fake = fused_row("packed", "f32", 560.0, 1000.0);
        if let Json::Obj(r) = &mut fake {
            r.insert("fused_passes_per_step".into(), Json::Num(64.0));
        }
        assert!(validate_fused(&fused_doc(vec![fake]), false).is_err());

        // the 1.3x throughput bound at S >= 512 is a strict failure only
        let slow = fused_doc(vec![
            fused_row("packed", "f32", 560.0, 1000.0),
            fused_row("fused", "f32", 560.0, 1100.0),
        ]);
        validate_fused(&slow, false).unwrap();
        assert!(validate_fused(&slow, true).is_err());

        // short-context rows alone cannot satisfy strict
        let short = fused_doc(vec![
            fused_row("packed", "f32", 80.0, 1000.0),
            fused_row("fused", "f32", 80.0, 1500.0),
        ]);
        validate_fused(&short, false).unwrap();
        assert!(validate_fused(&short, true).is_err());

        // projected snapshots pass the schema but refuse strict validation
        let mut projected = good.clone();
        if let Json::Obj(o) = &mut projected {
            o.insert("projected".into(), Json::Bool(true));
        }
        validate_fused(&projected, false).unwrap();
        assert!(validate_fused(&projected, true).is_err());

        assert!(validate_fused(&Json::obj(vec![]), false).is_err());
    }

    #[test]
    fn real_runs_clear_the_projected_flag() {
        let dir = std::env::temp_dir().join("aqua_bench_report_projected");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_decode.json");
        std::fs::write(&path, "{\"projected\":true,\"schema_version\":1,\"sections\":{}}\n")
            .unwrap();
        let rep = BenchReport::load_or_new(&path);
        rep.save(&path).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("projected"), &Json::Null, "projected flag must not survive a run");
        let _ = std::fs::remove_file(&path);
    }
}
