//! Criterion-lite measurement harness (criterion is unavailable offline).
//!
//! Warmup + timed iterations, reports mean/p50/p99 and derived throughput.
//! Used by `rust/benches/*` (cargo bench targets with `harness = false`)
//! and the CLI's table/figure regenerators. The [`report`] submodule owns
//! the machine-readable `BENCH_decode.json` trajectory file.

pub mod report;

use std::time::{Duration, Instant};

use crate::util::{mean, percentile, stddev};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10.2}µs ±{:>8.2}µs  p50 {:>10.2}µs  p99 {:>10.2}µs  ({} iters)",
            self.name,
            self.mean_ns / 1e3,
            self.std_ns / 1e3,
            self.p50_ns / 1e3,
            self.p99_ns / 1e3,
            self.iters
        )
    }
}

/// Measurement settings.
#[derive(Debug, Clone, Copy)]
pub struct Bencher {
    pub warmup: usize,
    pub iters: usize,
    /// Hard cap on total measurement time; iterations stop early past it.
    pub max_time: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 3, iters: 30, max_time: Duration::from_secs(10) }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { warmup: 1, iters: 10, max_time: Duration::from_secs(5) }
    }

    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        let start = Instant::now();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_nanos() as f64);
            if start.elapsed() > self.max_time && samples.len() >= 5 {
                break;
            }
        }
        BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            mean_ns: mean(&samples),
            std_ns: stddev(&samples),
            p50_ns: percentile(&samples, 50.0),
            p99_ns: percentile(&samples, 99.0),
        }
    }
}

/// Prevent the optimizer from discarding a computed value
/// (std::hint::black_box stabilized alternative that works on all types).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher { warmup: 1, iters: 8, max_time: Duration::from_secs(2) };
        let mut acc = 0u64;
        let r = b.run("spin", || {
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters >= 5);
        assert!(r.p99_ns >= r.p50_ns);
        assert!(!r.report().is_empty());
    }
}
