//! Per-lane KV-slot bookkeeping.
//!
//! The HLO executables carry the actual cache tensors; the engine is the
//! *authority* on which slots are attendable via the `slot_mask` input it
//! passes each call. This module tracks, per batch lane:
//!
//! * the logical write position (`len`, drives RoPE and the write index),
//! * the valid-slot mask,
//! * the H2O accumulated attention mass per slot.
//!
//! Eviction (h2o.rs) clears mask bits; since the paged KV pool
//! (`crate::kvpool`) the backend *actually frees* a page once every slot
//! on it is dead and the write cursor has moved past it.
//! [`LaneKv::resident_pages`] mirrors that rule engine-side, so
//! [`LaneKv::live_bytes`] reports the bytes the pool really holds for the
//! lane — not a cost-model projection (the two accountings are
//! property-tested against each other in `tests/kvpool_props.rs`).

/// State for one batch lane.
#[derive(Debug, Clone)]
pub struct LaneKv {
    pub capacity: usize,
    /// 1.0 = slot attendable.
    pub slot_mask: Vec<f32>,
    /// Accumulated attention mass per slot (summed over layers & steps).
    pub h2o_acc: Vec<f32>,
    /// Tokens written so far == next write position.
    pub len: usize,
}

impl LaneKv {
    pub fn new(capacity: usize) -> Self {
        LaneKv {
            capacity,
            slot_mask: vec![0.0; capacity],
            h2o_acc: vec![0.0; capacity],
            len: 0,
        }
    }

    pub fn reset(&mut self) {
        self.slot_mask.iter_mut().for_each(|m| *m = 0.0);
        self.h2o_acc.iter_mut().for_each(|a| *a = 0.0);
        self.len = 0;
    }

    pub fn is_full(&self) -> bool {
        self.len >= self.capacity
    }

    /// Mark `n` freshly written slots (positions len..len+n) valid.
    pub fn commit_write(&mut self, n: usize) {
        let end = (self.len + n).min(self.capacity);
        for i in self.len..end {
            self.slot_mask[i] = 1.0;
        }
        self.len = end;
    }

    /// Rewind the write cursor to `new_len`, marking every slot at or past
    /// it dead again. Used by speculative decoding to discard drafted
    /// positions past the verifier's accepted prefix; a no-op when the
    /// cursor is already at or below `new_len`.
    pub fn rollback(&mut self, new_len: usize) {
        if new_len >= self.len {
            return;
        }
        for i in new_len..self.len {
            self.slot_mask[i] = 0.0;
        }
        self.len = new_len;
    }

    /// Number of currently attendable slots.
    pub fn live_slots(&self) -> usize {
        self.slot_mask.iter().filter(|&&m| m > 0.5).count()
    }

    /// Fold one step's attention mass (already summed over layers) into the
    /// H2O accumulator. `acc` is [S].
    pub fn accumulate(&mut self, acc: &[f32]) {
        debug_assert_eq!(acc.len(), self.capacity);
        for (a, &x) in self.h2o_acc.iter_mut().zip(acc) {
            *a += x;
        }
    }

    /// Evict a specific slot (used by the H2O policy).
    pub fn evict(&mut self, slot: usize) {
        self.slot_mask[slot] = 0.0;
    }

    /// Pages the backend's pool holds for this lane, given its page size:
    /// every `page_slots` window that was written into (page index below
    /// the cursor) and is either still growing (contains the cursor) or
    /// retains at least one live slot. Mirrors `kvpool::LanePageTable`'s
    /// lease/reclaim rules exactly.
    pub fn resident_pages(&self, page_slots: usize) -> usize {
        let ps = page_slots.max(1);
        let mut pages = 0;
        let mut p = 0;
        while p * ps < self.len {
            let lo = p * ps;
            let hi = ((p + 1) * ps).min(self.capacity);
            if hi > self.len || self.slot_mask[lo..hi].iter().any(|&m| m > 0.5) {
                pages += 1;
            }
            p += 1;
        }
        pages
    }

    /// KV bytes the paged pool holds for this lane — page-granular
    /// resident bytes, not a cost-model projection. `bytes_per_slot` is
    /// `AquaConfig::kv_bytes_per_slot` (== `PoolLayout::bytes_per_slot`).
    pub fn live_bytes(&self, page_slots: usize, bytes_per_slot: usize) -> usize {
        self.resident_pages(page_slots) * page_slots.max(1) * bytes_per_slot
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::check;

    #[test]
    fn write_commit_advances() {
        let mut l = LaneKv::new(8);
        l.commit_write(3);
        assert_eq!(l.len, 3);
        assert_eq!(l.live_slots(), 3);
        l.commit_write(2);
        assert_eq!(l.len, 5);
        assert!(!l.is_full());
        l.commit_write(10); // clamped at capacity
        assert_eq!(l.len, 8);
        assert!(l.is_full());
    }

    #[test]
    fn reset_clears_everything() {
        let mut l = LaneKv::new(4);
        l.commit_write(4);
        l.accumulate(&[1.0, 2.0, 3.0, 4.0]);
        l.reset();
        assert_eq!(l.len, 0);
        assert_eq!(l.live_slots(), 0);
        assert!(l.h2o_acc.iter().all(|&a| a == 0.0));
    }

    #[test]
    fn rollback_rewinds_mask_and_cursor() {
        let mut l = LaneKv::new(8);
        l.commit_write(6);
        l.rollback(3);
        assert_eq!(l.len, 3);
        assert_eq!(l.live_slots(), 3);
        assert!(l.slot_mask[3..].iter().all(|&m| m == 0.0));
        // no-op when already at or below the target
        l.rollback(5);
        assert_eq!(l.len, 3);
        // writes resume at the rolled-back cursor
        l.commit_write(2);
        assert_eq!(l.len, 5);
        assert_eq!(l.live_slots(), 5);
    }

    #[test]
    fn eviction_reduces_live() {
        let mut l = LaneKv::new(4);
        l.commit_write(4);
        l.evict(1);
        assert_eq!(l.live_slots(), 3);
        assert_eq!(l.slot_mask, vec![1.0, 0.0, 1.0, 1.0]);
    }

    #[test]
    fn resident_pages_follow_cursor_and_holes() {
        let mut l = LaneKv::new(32);
        assert_eq!(l.resident_pages(8), 0, "nothing written, nothing resident");
        l.commit_write(10); // pages 0 (full) and 1 (cursor)
        assert_eq!(l.resident_pages(8), 2);
        assert_eq!(l.live_bytes(8, 100), 2 * 8 * 100);
        // kill all of page 0: fully written + fully dead → reclaimed
        for s in 0..8 {
            l.evict(s);
        }
        assert_eq!(l.resident_pages(8), 1);
        // the cursor page stays resident even when fully dead
        l.evict(8);
        l.evict(9);
        assert_eq!(l.resident_pages(8), 1);
        // filling to capacity: page 0 stays reclaimed, pages 1-3 are live
        l.commit_write(22);
        assert_eq!(l.resident_pages(8), 3);
        // all dead at a closed cursor → everything reclaimed
        for s in 8..32 {
            l.evict(s);
        }
        assert_eq!(l.resident_pages(8), 0);
    }

    #[test]
    fn prop_live_never_exceeds_len() {
        check(
            "live<=len",
            100,
            |g| {
                let cap = 4 + g.rng.below(32);
                let writes = g.rng.below(cap + 4);
                let evictions: Vec<usize> = (0..g.rng.below(8)).map(|_| g.rng.below(cap)).collect();
                (cap, writes, evictions)
            },
            |(cap, writes, evictions)| {
                let mut l = LaneKv::new(*cap);
                l.commit_write(*writes);
                for &e in evictions {
                    l.evict(e);
                }
                if l.live_slots() <= l.len {
                    Ok(())
                } else {
                    Err(format!("live {} > len {}", l.live_slots(), l.len))
                }
            },
        );
    }
}
