//! Per-lane KV-slot bookkeeping.
//!
//! The HLO executables carry the actual cache tensors; the engine is the
//! *authority* on which slots are attendable via the `slot_mask` input it
//! passes each call. This module tracks, per batch lane:
//!
//! * the logical write position (`len`, drives RoPE and the write index),
//! * the valid-slot mask,
//! * the H2O accumulated attention mass per slot.
//!
//! Eviction (h2o.rs) clears mask bits; the cache values stay in place but
//! become unreachable — equivalent to freeing the slot in a paged
//! allocator (the memory saving is reported analytically; slot *reuse*
//! would need a write-index decoupled from the RoPE position, noted as an
//! extension in DESIGN.md).

/// State for one batch lane.
#[derive(Debug, Clone)]
pub struct LaneKv {
    pub capacity: usize,
    /// 1.0 = slot attendable.
    pub slot_mask: Vec<f32>,
    /// Accumulated attention mass per slot (summed over layers & steps).
    pub h2o_acc: Vec<f32>,
    /// Tokens written so far == next write position.
    pub len: usize,
}

impl LaneKv {
    pub fn new(capacity: usize) -> Self {
        LaneKv {
            capacity,
            slot_mask: vec![0.0; capacity],
            h2o_acc: vec![0.0; capacity],
            len: 0,
        }
    }

    pub fn reset(&mut self) {
        self.slot_mask.iter_mut().for_each(|m| *m = 0.0);
        self.h2o_acc.iter_mut().for_each(|a| *a = 0.0);
        self.len = 0;
    }

    pub fn is_full(&self) -> bool {
        self.len >= self.capacity
    }

    /// Mark `n` freshly written slots (positions len..len+n) valid.
    pub fn commit_write(&mut self, n: usize) {
        let end = (self.len + n).min(self.capacity);
        for i in self.len..end {
            self.slot_mask[i] = 1.0;
        }
        self.len = end;
    }

    /// Number of currently attendable slots.
    pub fn live_slots(&self) -> usize {
        self.slot_mask.iter().filter(|&&m| m > 0.5).count()
    }

    /// Fold one step's attention mass (already summed over layers) into the
    /// H2O accumulator. `acc` is [S].
    pub fn accumulate(&mut self, acc: &[f32]) {
        debug_assert_eq!(acc.len(), self.capacity);
        for (a, &x) in self.h2o_acc.iter_mut().zip(acc) {
            *a += x;
        }
    }

    /// Evict a specific slot (used by the H2O policy).
    pub fn evict(&mut self, slot: usize) {
        self.slot_mask[slot] = 0.0;
    }

    /// KV bytes currently reachable (what a paged allocator would hold),
    /// given per-slot cost.
    pub fn live_bytes(&self, bytes_per_slot: usize) -> usize {
        self.live_slots() * bytes_per_slot
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::check;

    #[test]
    fn write_commit_advances() {
        let mut l = LaneKv::new(8);
        l.commit_write(3);
        assert_eq!(l.len, 3);
        assert_eq!(l.live_slots(), 3);
        l.commit_write(2);
        assert_eq!(l.len, 5);
        assert!(!l.is_full());
        l.commit_write(10); // clamped at capacity
        assert_eq!(l.len, 8);
        assert!(l.is_full());
    }

    #[test]
    fn reset_clears_everything() {
        let mut l = LaneKv::new(4);
        l.commit_write(4);
        l.accumulate(&[1.0, 2.0, 3.0, 4.0]);
        l.reset();
        assert_eq!(l.len, 0);
        assert_eq!(l.live_slots(), 0);
        assert!(l.h2o_acc.iter().all(|&a| a == 0.0));
    }

    #[test]
    fn eviction_reduces_live() {
        let mut l = LaneKv::new(4);
        l.commit_write(4);
        l.evict(1);
        assert_eq!(l.live_slots(), 3);
        assert_eq!(l.slot_mask, vec![1.0, 0.0, 1.0, 1.0]);
    }

    #[test]
    fn prop_live_never_exceeds_len() {
        check(
            "live<=len",
            100,
            |g| {
                let cap = 4 + g.rng.below(32);
                let writes = g.rng.below(cap + 4);
                let evictions: Vec<usize> = (0..g.rng.below(8)).map(|_| g.rng.below(cap)).collect();
                (cap, writes, evictions)
            },
            |(cap, writes, evictions)| {
                let mut l = LaneKv::new(*cap);
                l.commit_write(*writes);
                for &e in evictions {
                    l.evict(e);
                }
                if l.live_slots() <= l.len {
                    Ok(())
                } else {
                    Err(format!("live {} > len {}", l.live_slots(), l.len))
                }
            },
        );
    }
}
