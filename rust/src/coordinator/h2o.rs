//! H2O heavy-hitter eviction policy (Zhang et al. 2023), driven by AQUA's
//! *approximate* attention scores — the paper's §8.3 synergy.
//!
//! H2O keeps a budget of KV slots: the most recent `recent_window` tokens
//! are always kept ("recency"), the remainder of the budget goes to the
//! tokens with the largest accumulated attention mass ("heavy hitters").
//! In AQUA-H2O the mass comes from the approximate scores the decode step
//! already produced — no extra full-attention pass.
//!
//! The budget is `ceil(h2o_ratio · len)` where `len` is the number of
//! tokens written so far — matching the paper's `H2O_ratio` (fraction of
//! the context retained; 1.0 = eviction off).

use super::kvcache::LaneKv;

#[derive(Debug, Clone, Copy)]
pub struct H2oPolicy {
    /// Fraction of the live context to retain (1.0 disables eviction).
    pub ratio: f64,
    /// Most-recent tokens that are never evicted.
    pub recent_window: usize,
}

impl H2oPolicy {
    pub fn disabled() -> Self {
        H2oPolicy { ratio: 1.0, recent_window: 16 }
    }

    pub fn new(ratio: f64, recent_window: usize) -> Self {
        H2oPolicy { ratio: ratio.clamp(0.05, 1.0), recent_window }
    }

    pub fn enabled(&self) -> bool {
        self.ratio < 0.999
    }

    /// Token budget for a lane that has written `len` tokens.
    pub fn budget(&self, len: usize) -> usize {
        ((self.ratio * len as f64).ceil() as usize).max(self.recent_window.min(len)).max(1)
    }

    /// Apply the policy to one lane: evict lowest-mass non-recent slots
    /// until `live <= budget(len)`. Returns the number of evictions.
    pub fn apply(&self, lane: &mut LaneKv) -> usize {
        if !self.enabled() {
            return 0;
        }
        let budget = self.budget(lane.len);
        let live = lane.live_slots();
        if live <= budget {
            return 0;
        }
        let recent_start = lane.len.saturating_sub(self.recent_window);
        // Candidates: live, non-recent slots, sorted by accumulated mass asc.
        let mut cands: Vec<usize> = (0..recent_start)
            .filter(|&i| lane.slot_mask[i] > 0.5)
            .collect();
        cands.sort_by(|&a, &b| {
            lane.h2o_acc[a].partial_cmp(&lane.h2o_acc[b]).unwrap_or(std::cmp::Ordering::Equal)
        });
        let need = live - budget;
        let mut evicted = 0;
        for &slot in cands.iter().take(need) {
            lane.evict(slot);
            evicted += 1;
        }
        evicted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::testkit::check;

    fn lane_with(len: usize, cap: usize, acc: &[f32]) -> LaneKv {
        let mut l = LaneKv::new(cap);
        l.commit_write(len);
        l.accumulate(&{
            let mut a = vec![0.0; cap];
            a[..acc.len()].copy_from_slice(acc);
            a
        });
        l
    }

    #[test]
    fn disabled_never_evicts() {
        let mut l = lane_with(10, 16, &[0.0; 10]);
        assert_eq!(H2oPolicy::disabled().apply(&mut l), 0);
        assert_eq!(l.live_slots(), 10);
    }

    #[test]
    fn evicts_lowest_mass_first() {
        // 8 tokens, keep ratio 0.5 (budget 4), recent window 2 protects 6,7.
        let acc = [5.0, 0.1, 4.0, 0.2, 3.0, 0.3];
        let mut l = lane_with(8, 16, &acc);
        let p = H2oPolicy::new(0.5, 2);
        let n = p.apply(&mut l);
        assert_eq!(n, 4);
        assert_eq!(l.live_slots(), 4);
        // heavy hitters 0,2 survive; recents 6,7 survive
        for &keep in &[0usize, 2, 6, 7] {
            assert!(l.slot_mask[keep] > 0.5, "slot {keep} wrongly evicted");
        }
    }

    #[test]
    fn prop_budget_respected_and_recent_protected() {
        check(
            "h2o-invariants",
            150,
            |g| {
                let cap = 16 + g.rng.below(64);
                let len = 1 + g.rng.below(cap);
                let ratio = 0.1 + g.rng.f64() * 0.9;
                let window = 1 + g.rng.below(12);
                let mut rng = Rng::new(g.rng.next_u64());
                let acc: Vec<f32> = (0..len).map(|_| rng.f32() * 10.0).collect();
                (cap, len, ratio, window, acc)
            },
            |(cap, len, ratio, window, acc)| {
                let mut l = lane_with(*len, *cap, acc);
                let p = H2oPolicy::new(*ratio, *window);
                p.apply(&mut l);
                let budget = p.budget(*len);
                if l.live_slots() > budget {
                    return Err(format!("live {} > budget {budget}", l.live_slots()));
                }
                // recent window never evicted
                let recent_start = len.saturating_sub(*window);
                for i in recent_start..*len {
                    if l.slot_mask[i] < 0.5 {
                        return Err(format!("recent slot {i} evicted"));
                    }
                }
                // applying again is a no-op (idempotent at fixed len)
                if p.apply(&mut l) != 0 {
                    return Err("second apply evicted more".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn budget_monotone_in_ratio() {
        let a = H2oPolicy::new(0.25, 4).budget(100);
        let b = H2oPolicy::new(0.75, 4).budget(100);
        assert!(a < b);
        assert_eq!(H2oPolicy::new(1.0, 4).budget(100), 100);
    }
}
