//! Engine metrics: latency/throughput accounting for the serving benches,
//! plus score-kernel observability (which AQUA kernel variant actually ran
//! and how long the attention score path took) fed from the backend's
//! [`KernelCounters`].

use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Duration;

use crate::kvpool::KvPoolGauges;
use crate::runtime::KernelCounters;

/// Latency histogram resolution (shared by the ITL and TTFT stores):
/// geometric buckets at `floor(4·log2(µs))`, i.e. ~19% wide — fixed-size
/// so the steady-state decode loop records without allocating.
const ITL_BUCKETS: usize = 256;

fn itl_bucket(us: u64) -> usize {
    if us < 2 {
        return 0;
    }
    let idx = (4.0 * (us as f64).log2()).floor() as isize;
    idx.clamp(0, ITL_BUCKETS as isize - 1) as usize
}

/// Geometric midpoint of bucket `idx`, in ms.
fn itl_bucket_ms(idx: usize) -> f64 {
    2f64.powf((idx as f64 + 0.5) / 4.0) / 1e3
}

/// Percentile over the bucketed distribution (returns the holding
/// bucket's midpoint, so the answer is exact to one bucket ≈ ±10%).
fn hist_percentile_ms(hist: &[u64], count: u64, p: f64) -> f64 {
    if count == 0 || hist.is_empty() {
        return 0.0;
    }
    let rank = ((p / 100.0) * count as f64).ceil().max(1.0) as u64;
    let mut acc = 0u64;
    for (idx, &c) in hist.iter().enumerate() {
        acc += c;
        if acc >= rank {
            return itl_bucket_ms(idx);
        }
    }
    0.0
}

#[derive(Debug, Default)]
struct Inner {
    requests_done: u64,
    /// Of `requests_done`, submissions that resolved without running:
    /// admission rejects (PromptTooLong / OverKvBudget) and duplicate
    /// ids. Counted in both so `requests_done` reconciles with
    /// submissions.
    requests_rejected: u64,
    /// Of `requests_done`, requests cancelled by the client (explicit or
    /// via detected disconnect), in the queue or mid-flight.
    requests_cancelled: u64,
    /// Of `requests_done`, requests whose `deadline_ms` elapsed before
    /// completion.
    requests_expired: u64,
    /// Of `requests_done`, requests terminated by a contained backend
    /// step failure (`FinishReason::BackendError`) or an engine death
    /// (`EngineFailed`).
    requests_failed: u64,
    /// Lanes retired by contained backend step failures (one per blamed
    /// lane; an unattributed pass failure counts every scheduled lane).
    lane_failures: u64,
    tokens_generated: u64,
    prompt_tokens: u64,
    decode_calls: u64,
    prefill_calls: u64,
    /// Scheduling passes (prefill + decode) with their occupancy sums —
    /// the `batch_occupancy` / `prefill_tokens_per_step` denominators.
    sched_steps: u64,
    occupancy_lane_sum: u64,
    occupancy_cap_sum: u64,
    /// Queue wait per request (submit → admission or terminal reject), µs.
    queue_wait_us: Vec<f64>,
    /// ITL histogram (lazily sized to `ITL_BUCKETS` on first record).
    itl_hist: Vec<u64>,
    itl_sum_us: f64,
    itl_count: u64,
    decode_time: Duration,
    prefill_time: Duration,
    /// TTFT histogram (lazily sized to `ITL_BUCKETS`, same geometric
    /// buckets as ITL) with an exact-sum side channel for the mean —
    /// bounded storage no matter how many requests an engine serves.
    ttft_hist: Vec<u64>,
    ttft_sum_us: f64,
    ttft_count: u64,
    req_latency_us: Vec<f64>,
    h2o_evictions: u64,
    kernels: KernelCounters,
    /// Score-path time from decode calls only (the kernels pool above
    /// also includes prefill), so per-decode timing stays honest on
    /// prefill-heavy workloads.
    decode_score_ns: u64,
    /// Latest KV-pool gauges reported by the backend (see
    /// `crate::kvpool::KvPoolGauges`) plus the peak resident bytes seen.
    kv: KvPoolGauges,
    kv_resident_peak: u64,
    /// Live (attendable) slots at the last gauge sample — the
    /// page-utilization numerator.
    kv_live_slots: u64,
    /// Prompt tokens served from the prefix cache (pages attached instead
    /// of prefilled). `prompt_tokens` counts only *computed* tokens, so
    /// `prefix_hit_tokens + prompt_tokens` is the total prompt volume.
    prefix_hit_tokens: u64,
    /// Speculative decoding: tokens drafted via the sparse score path.
    spec_drafted: u64,
    /// Of `spec_drafted`, tokens the exact verify pass accepted.
    spec_accepted: u64,
    /// Of `spec_drafted`, tokens rolled back after verification
    /// (`drafted == accepted + rejected` is the reconciliation `/stats`
    /// and `benchcheck` assert).
    spec_rejected: u64,
    /// Tokens committed by speculative cycles (accepted drafts plus the
    /// verifier's own next-token per lane).
    spec_committed: u64,
    /// Lane-cycle participations: one per live lane per draft/verify
    /// cycle — the `tokens_per_step_effective` denominator.
    spec_lane_cycles: u64,
    /// Batched exact verification passes run.
    spec_verify_passes: u64,
    wall_start: Option<std::time::Instant>,
}

#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// A point-in-time snapshot for reporting.
#[derive(Debug, Clone, Default)]
pub struct Snapshot {
    pub requests_done: u64,
    /// Of `requests_done`, submissions resolved without running
    /// (admission rejects, duplicate ids).
    pub requests_rejected: u64,
    /// Of `requests_done`, cancelled by the client (explicit cancel or
    /// detected disconnect).
    pub requests_cancelled: u64,
    /// Of `requests_done`, expired past their `deadline_ms`.
    pub requests_expired: u64,
    /// Of `requests_done`, terminated by backend/engine failure.
    pub requests_failed: u64,
    /// Requests that ran to a normal completion:
    /// `done - rejected - cancelled - expired - failed` (derived, so the
    /// reconciliation `done == served + rejected + cancelled + expired +
    /// failed` holds by construction and survives fleet merges).
    pub requests_served: u64,
    /// Lanes retired by contained backend step failures.
    pub lane_failures: u64,
    pub tokens_generated: u64,
    pub prompt_tokens: u64,
    pub decode_calls: u64,
    pub prefill_calls: u64,
    /// Scheduling passes (prefill + decode) the engine ran.
    pub sched_steps: u64,
    /// Mean computed prompt tokens per scheduling pass — with chunked
    /// interleaving this sits near the per-pass budget instead of
    /// spiking with prompt length.
    pub prefill_tokens_per_step: f64,
    /// Mean occupied-lane fraction per scheduling pass, in [0, 1].
    pub batch_occupancy: f64,
    /// Queue wait (submit → admission or terminal reject).
    pub queue_wait_p50_ms: f64,
    pub queue_wait_p99_ms: f64,
    /// Decode inter-token latency: gap between consecutive tokens of the
    /// same request (bucketed to ~±10%; the starvation signal a long
    /// prefill used to spike).
    pub itl_mean_ms: f64,
    pub itl_p99_ms: f64,
    pub decode_time_s: f64,
    pub prefill_time_s: f64,
    pub mean_ttft_ms: f64,
    pub p50_ttft_ms: f64,
    pub p99_ttft_ms: f64,
    pub mean_latency_ms: f64,
    pub decode_tok_per_s: f64,
    pub wall_tok_per_s: f64,
    pub h2o_evictions: u64,
    /// Score-kernel variant counters + attention-score time, accumulated
    /// over every backend call (see `runtime::KernelCounters`).
    pub kernels: KernelCounters,
    /// Mean attention-score-path time per decode call, µs, from decode
    /// calls only (0 when the backend reports no timing, e.g. PJRT, or
    /// before the first decode).
    pub score_us_per_decode: f64,
    /// KV bytes held by leased pages at the last backend call (0 for
    /// backends without a paged pool, e.g. PJRT).
    pub kv_resident_bytes: u64,
    /// Peak of `kv_resident_bytes` over the engine's lifetime — the
    /// memory-footprint headline (what a dense preallocation would have to
    /// cover). In a fleet aggregate this is the *sum of per-engine peaks*:
    /// the capacity that covers every pool even if all hit peak at once —
    /// an upper bound, since staggered peaks may never coincide.
    pub kv_resident_peak_bytes: u64,
    /// Pages currently leased.
    pub kv_pages_in_use: u64,
    /// Live (attendable) slots per leased page slot, in [0, 1]: how much
    /// of the resident bytes is actually reachable context vs page-
    /// granularity slack and not-yet-reclaimed H2O holes.
    pub kv_page_utilization: f64,
    /// Lease attempts refused by the page budget (should stay 0 — the
    /// admission gate sheds before the pool stalls).
    pub kv_alloc_stalls: u64,
    /// Pool headroom: pages still leasable before the cap (for unbudgeted
    /// deployments, before the never-stalling worst-case bound).
    pub kv_pages_free: u64,
    /// Pages currently mapped by more than one lane (prefix sharing).
    pub kv_shared_pages: u64,
    /// Cumulative copy-on-write page copies.
    pub kv_cow_copies: u64,
    /// Prompt tokens served by attaching shared prefix pages instead of
    /// running prefill (`prompt_tokens` counts only computed tokens —
    /// the two reconcile to the total submitted prompt volume).
    pub prefix_hit_tokens: u64,
    /// Prefix-index LRU evictions (chains unkeyed by the
    /// `prefix_cache_pages` cap), from the latest pool gauges.
    pub kv_prefix_evictions: u64,
    /// Speculative decoding: tokens drafted via the sparse score path.
    pub spec_drafted: u64,
    /// Of `spec_drafted`, tokens the exact verify pass accepted.
    pub spec_accepted: u64,
    /// Of `spec_drafted`, tokens rolled back after verification. The
    /// reconciliation `spec_drafted == spec_accepted + spec_rejected`
    /// holds by construction and survives fleet merges.
    pub spec_rejected: u64,
    /// Tokens committed by speculative cycles (accepted drafts + the
    /// verifier's own next-token per lane) — the
    /// `tokens_per_step_effective` numerator.
    pub spec_committed: u64,
    /// Lane-cycle participations (one per live lane per cycle) — the
    /// `tokens_per_step_effective` denominator.
    pub spec_lane_cycles: u64,
    /// Batched exact verification passes run.
    pub spec_verify_passes: u64,
    /// `spec_accepted / spec_drafted` (0 with speculation off). Re-derived
    /// from the counters on every fleet merge.
    pub spec_acceptance_rate: f64,
    /// Mean tokens committed per lane per speculative cycle
    /// (`spec_committed / spec_lane_cycles`; > 1.0 means speculation is
    /// beating one-token-per-step decoding). 0 with speculation off.
    pub tokens_per_step_effective: f64,
}

impl Metrics {
    /// The metrics lock, poison-tolerant: a panic on a recording thread
    /// (e.g. a backend panic the supervisor catches) must not cascade
    /// into every later `/stats` call — counters are plain accumulators,
    /// valid regardless of where the holder died.
    fn locked(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    pub fn start_clock(&self) {
        let mut i = self.locked();
        if i.wall_start.is_none() {
            i.wall_start = Some(std::time::Instant::now());
        }
    }

    pub fn record_decode(&self, d: Duration, lanes: u64) {
        let mut i = self.locked();
        i.decode_calls += 1;
        i.decode_time += d;
        i.tokens_generated += lanes;
    }

    pub fn record_prefill(&self, d: Duration, tokens: u64) {
        let mut i = self.locked();
        i.prefill_calls += 1;
        i.prefill_time += d;
        i.prompt_tokens += tokens;
    }

    pub fn record_finish(&self, ttft: Option<Duration>, total: Duration) {
        let mut i = self.locked();
        i.requests_done += 1;
        if let Some(t) = ttft {
            if i.ttft_hist.is_empty() {
                i.ttft_hist.resize(ITL_BUCKETS, 0);
            }
            let us = t.as_micros() as u64;
            let b = itl_bucket(us);
            i.ttft_hist[b] += 1;
            i.ttft_sum_us += us as f64;
            i.ttft_count += 1;
        }
        i.req_latency_us.push(total.as_micros() as f64);
    }

    pub fn record_evictions(&self, n: u64) {
        self.locked().h2o_evictions += n;
    }

    /// One scheduling pass: `occupied` of `capacity` lanes carried work.
    pub fn record_step(&self, occupied: u64, capacity: u64) {
        let mut i = self.locked();
        i.sched_steps += 1;
        i.occupancy_lane_sum += occupied;
        i.occupancy_cap_sum += capacity;
    }

    /// Time a request spent queued before admission or terminal reject.
    pub fn record_queue_wait(&self, d: Duration) {
        self.locked().queue_wait_us.push(d.as_micros() as f64);
    }

    /// A submission resolved without running (admission reject, duplicate
    /// id): counts toward `requests_done` so `/stats` reconciles with
    /// submissions, and toward the distinct rejected counter.
    pub fn record_rejected(&self) {
        let mut i = self.locked();
        i.requests_done += 1;
        i.requests_rejected += 1;
    }

    /// A request was cancelled by the client. `ran: false` means it never
    /// left the queue (counts toward `requests_done` here — nothing else
    /// will); `ran: true` means the lane finished through `record_finish`
    /// and only the sub-counter is owed.
    pub fn record_cancelled(&self, ran: bool) {
        let mut i = self.locked();
        if !ran {
            i.requests_done += 1;
        }
        i.requests_cancelled += 1;
    }

    /// A request's `deadline_ms` elapsed (same `ran` contract as
    /// [`Metrics::record_cancelled`]).
    pub fn record_expired(&self, ran: bool) {
        let mut i = self.locked();
        if !ran {
            i.requests_done += 1;
        }
        i.requests_expired += 1;
    }

    /// A request was terminated by a backend/engine failure. `lanes` is
    /// how many lane retirements this failure caused (0 for unrun
    /// flush-on-engine-death terminals).
    pub fn record_failed(&self, ran: bool, lanes: u64) {
        let mut i = self.locked();
        if !ran {
            i.requests_done += 1;
        }
        i.requests_failed += 1;
        i.lane_failures += lanes;
    }

    /// Record one decode pass's inter-token gaps (µs). Bucketed into a
    /// fixed histogram so the hot loop never allocates (the 256-slot
    /// store is sized once, on the first call).
    pub fn record_itl(&self, gaps_us: &[u64]) {
        if gaps_us.is_empty() {
            return;
        }
        let mut i = self.locked();
        if i.itl_hist.is_empty() {
            i.itl_hist.resize(ITL_BUCKETS, 0);
        }
        for &g in gaps_us {
            let b = itl_bucket(g);
            i.itl_hist[b] += 1;
            i.itl_sum_us += g as f64;
            i.itl_count += 1;
        }
    }

    /// Fold one backend call's kernel accounting in; `decode` routes the
    /// score time into the decode-only pool as well.
    pub fn record_kernels(&self, k: &KernelCounters, decode: bool) {
        let mut i = self.locked();
        i.kernels.merge(k);
        if decode {
            i.decode_score_ns += k.score_ns;
        }
    }

    /// Record one backend call's KV-pool gauges (point-in-time, so the
    /// latest sample wins) along with the engine's live-slot count at the
    /// same instant.
    pub fn record_kv(&self, g: &KvPoolGauges, live_slots: u64) {
        let mut i = self.locked();
        i.kv = *g;
        i.kv_resident_peak = i.kv_resident_peak.max(g.resident_bytes);
        i.kv_live_slots = live_slots;
    }

    /// Record prompt tokens served from the prefix cache (no prefill run).
    pub fn record_prefix_hits(&self, tokens: u64) {
        self.locked().prefix_hit_tokens += tokens;
    }

    /// Record one speculative draft/verify cycle: `drafted` tokens drafted
    /// across the cycle's lanes, `accepted` of them verified, `committed`
    /// tokens emitted in total (accepted + one verifier token per lane),
    /// over `lane_cycles` participating lanes.
    pub fn record_spec(&self, drafted: u64, accepted: u64, committed: u64, lane_cycles: u64) {
        let mut i = self.locked();
        i.spec_drafted += drafted;
        i.spec_accepted += accepted;
        i.spec_rejected += drafted - accepted;
        i.spec_committed += committed;
        i.spec_lane_cycles += lane_cycles;
        i.spec_verify_passes += 1;
    }

    pub fn snapshot(&self) -> Snapshot {
        use crate::util::{mean, percentile};
        let i = self.locked();
        let decode_s = i.decode_time.as_secs_f64();
        let wall_s = i.wall_start.map(|w| w.elapsed().as_secs_f64()).unwrap_or(0.0);
        Snapshot {
            requests_done: i.requests_done,
            requests_rejected: i.requests_rejected,
            requests_cancelled: i.requests_cancelled,
            requests_expired: i.requests_expired,
            requests_failed: i.requests_failed,
            requests_served: i
                .requests_done
                .saturating_sub(i.requests_rejected)
                .saturating_sub(i.requests_cancelled)
                .saturating_sub(i.requests_expired)
                .saturating_sub(i.requests_failed),
            lane_failures: i.lane_failures,
            tokens_generated: i.tokens_generated,
            prompt_tokens: i.prompt_tokens,
            decode_calls: i.decode_calls,
            prefill_calls: i.prefill_calls,
            sched_steps: i.sched_steps,
            prefill_tokens_per_step: if i.sched_steps > 0 {
                i.prompt_tokens as f64 / i.sched_steps as f64
            } else {
                0.0
            },
            batch_occupancy: if i.occupancy_cap_sum > 0 {
                i.occupancy_lane_sum as f64 / i.occupancy_cap_sum as f64
            } else {
                0.0
            },
            queue_wait_p50_ms: percentile(&i.queue_wait_us, 50.0) / 1e3,
            queue_wait_p99_ms: percentile(&i.queue_wait_us, 99.0) / 1e3,
            itl_mean_ms: if i.itl_count > 0 { i.itl_sum_us / i.itl_count as f64 / 1e3 } else { 0.0 },
            itl_p99_ms: hist_percentile_ms(&i.itl_hist, i.itl_count, 99.0),
            decode_time_s: decode_s,
            prefill_time_s: i.prefill_time.as_secs_f64(),
            mean_ttft_ms: if i.ttft_count > 0 {
                i.ttft_sum_us / i.ttft_count as f64 / 1e3
            } else {
                0.0
            },
            p50_ttft_ms: hist_percentile_ms(&i.ttft_hist, i.ttft_count, 50.0),
            p99_ttft_ms: hist_percentile_ms(&i.ttft_hist, i.ttft_count, 99.0),
            mean_latency_ms: mean(&i.req_latency_us) / 1e3,
            decode_tok_per_s: if decode_s > 0.0 {
                i.tokens_generated as f64 / decode_s
            } else {
                0.0
            },
            wall_tok_per_s: if wall_s > 0.0 {
                (i.tokens_generated + i.prompt_tokens) as f64 / wall_s
            } else {
                0.0
            },
            h2o_evictions: i.h2o_evictions,
            kernels: i.kernels,
            score_us_per_decode: if i.decode_calls > 0 {
                i.decode_score_ns as f64 / 1e3 / i.decode_calls as f64
            } else {
                0.0
            },
            kv_resident_bytes: i.kv.resident_bytes,
            kv_resident_peak_bytes: i.kv_resident_peak,
            kv_pages_in_use: i.kv.pages_in_use,
            kv_page_utilization: {
                let leased_slots = i.kv.pages_in_use * i.kv.page_slots;
                if leased_slots > 0 {
                    (i.kv_live_slots as f64 / leased_slots as f64).min(1.0)
                } else {
                    0.0
                }
            },
            kv_alloc_stalls: i.kv.alloc_stalls,
            kv_pages_free: i.kv.pages_free,
            kv_shared_pages: i.kv.shared_pages,
            kv_cow_copies: i.kv.cow_copies,
            prefix_hit_tokens: i.prefix_hit_tokens,
            kv_prefix_evictions: i.kv.prefix_evictions,
            spec_drafted: i.spec_drafted,
            spec_accepted: i.spec_accepted,
            spec_rejected: i.spec_rejected,
            spec_committed: i.spec_committed,
            spec_lane_cycles: i.spec_lane_cycles,
            spec_verify_passes: i.spec_verify_passes,
            spec_acceptance_rate: if i.spec_drafted > 0 {
                i.spec_accepted as f64 / i.spec_drafted as f64
            } else {
                0.0
            },
            tokens_per_step_effective: if i.spec_lane_cycles > 0 {
                i.spec_committed as f64 / i.spec_lane_cycles as f64
            } else {
                0.0
            },
        }
    }
}

impl Snapshot {
    /// Fold another engine's snapshot into a fleet aggregate: counters and
    /// time pools add, throughputs add (the engines run concurrently),
    /// mean latencies combine weighted by their sample counts, and
    /// percentiles take the worst (exact percentiles cannot be merged
    /// from summaries — read the per-model sections for those).
    pub fn merge(&mut self, o: &Snapshot) {
        let (n0, n1) = (self.requests_done as f64, o.requests_done as f64);
        if n0 + n1 > 0.0 {
            self.mean_ttft_ms = (self.mean_ttft_ms * n0 + o.mean_ttft_ms * n1) / (n0 + n1);
            self.mean_latency_ms = (self.mean_latency_ms * n0 + o.mean_latency_ms * n1) / (n0 + n1);
        }
        // scheduler gauges: per-step means weight by steps, ITL mean by
        // token volume; wait/ITL percentiles take the worst (exact
        // percentiles cannot be merged from summaries)
        let (s0, s1) = (self.sched_steps as f64, o.sched_steps as f64);
        if s0 + s1 > 0.0 {
            self.prefill_tokens_per_step =
                (self.prefill_tokens_per_step * s0 + o.prefill_tokens_per_step * s1) / (s0 + s1);
            self.batch_occupancy = (self.batch_occupancy * s0 + o.batch_occupancy * s1) / (s0 + s1);
        }
        let (t0, t1) = (self.tokens_generated as f64, o.tokens_generated as f64);
        if t0 + t1 > 0.0 {
            self.itl_mean_ms = (self.itl_mean_ms * t0 + o.itl_mean_ms * t1) / (t0 + t1);
        }
        self.sched_steps += o.sched_steps;
        self.requests_rejected += o.requests_rejected;
        self.requests_cancelled += o.requests_cancelled;
        self.requests_expired += o.requests_expired;
        self.requests_failed += o.requests_failed;
        self.requests_served += o.requests_served;
        self.lane_failures += o.lane_failures;
        self.queue_wait_p50_ms = self.queue_wait_p50_ms.max(o.queue_wait_p50_ms);
        self.queue_wait_p99_ms = self.queue_wait_p99_ms.max(o.queue_wait_p99_ms);
        self.itl_p99_ms = self.itl_p99_ms.max(o.itl_p99_ms);
        let (d0, d1) = (self.decode_calls as f64, o.decode_calls as f64);
        if d0 + d1 > 0.0 {
            self.score_us_per_decode =
                (self.score_us_per_decode * d0 + o.score_us_per_decode * d1) / (d0 + d1);
        }
        // utilization combines weighted by leased pages; resident
        // bytes/pages/stalls add (the engines hold memory concurrently)
        let (p0, p1) = (self.kv_pages_in_use as f64, o.kv_pages_in_use as f64);
        if p0 + p1 > 0.0 {
            self.kv_page_utilization =
                (self.kv_page_utilization * p0 + o.kv_page_utilization * p1) / (p0 + p1);
        }
        self.kv_resident_bytes += o.kv_resident_bytes;
        self.kv_resident_peak_bytes += o.kv_resident_peak_bytes;
        self.kv_pages_in_use += o.kv_pages_in_use;
        self.kv_alloc_stalls += o.kv_alloc_stalls;
        // headroom is per-pool capacity and adds like the pages it counts;
        // the *budget* sentinel (kv_pages_total = 0 = unlimited) lives in
        // the admission stats, not here
        self.kv_pages_free += o.kv_pages_free;
        self.kv_shared_pages += o.kv_shared_pages;
        self.kv_cow_copies += o.kv_cow_copies;
        self.prefix_hit_tokens += o.prefix_hit_tokens;
        self.kv_prefix_evictions += o.kv_prefix_evictions;
        // speculative counters add; the derived rates re-derive from the
        // merged counters (like decode_tok_per_s below) so the aggregate
        // reconciliation drafted == accepted + rejected keeps holding
        self.spec_drafted += o.spec_drafted;
        self.spec_accepted += o.spec_accepted;
        self.spec_rejected += o.spec_rejected;
        self.spec_committed += o.spec_committed;
        self.spec_lane_cycles += o.spec_lane_cycles;
        self.spec_verify_passes += o.spec_verify_passes;
        self.spec_acceptance_rate = if self.spec_drafted > 0 {
            self.spec_accepted as f64 / self.spec_drafted as f64
        } else {
            0.0
        };
        self.tokens_per_step_effective = if self.spec_lane_cycles > 0 {
            self.spec_committed as f64 / self.spec_lane_cycles as f64
        } else {
            0.0
        };
        self.p50_ttft_ms = self.p50_ttft_ms.max(o.p50_ttft_ms);
        self.p99_ttft_ms = self.p99_ttft_ms.max(o.p99_ttft_ms);
        self.requests_done += o.requests_done;
        self.tokens_generated += o.tokens_generated;
        self.prompt_tokens += o.prompt_tokens;
        self.decode_calls += o.decode_calls;
        self.prefill_calls += o.prefill_calls;
        self.decode_time_s += o.decode_time_s;
        self.prefill_time_s += o.prefill_time_s;
        self.h2o_evictions += o.h2o_evictions;
        self.kernels.merge(&o.kernels);
        self.wall_tok_per_s += o.wall_tok_per_s;
        self.decode_tok_per_s = if self.decode_time_s > 0.0 {
            self.tokens_generated as f64 / self.decode_time_s
        } else {
            0.0
        };
    }

    /// Fraction of the total submitted prompt volume served from the
    /// prefix cache (`hits / (hits + computed prompt tokens)`).
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefix_hit_tokens + self.prompt_tokens;
        if total > 0 {
            self.prefix_hit_tokens as f64 / total as f64
        } else {
            0.0
        }
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} (served={} rejected={} cancelled={} expired={} failed={} lane_failures={})\n\
             gen_tokens={} prompt_tokens={} decode_calls={} prefill_calls={}\n\
             decode {:.2}s ({:.1} tok/s) prefill {:.2}s | wall {:.1} tok/s\n\
             ttft mean {:.2}ms p50 {:.2}ms p99 {:.2}ms | latency mean {:.2}ms | h2o_evictions={}\n\
             sched steps={} occupancy {:.0}% prefill {:.1} tok/step | itl mean {:.3}ms p99 {:.3}ms \
             | queue wait p50 {:.2}ms p99 {:.2}ms\n\
             kernels dense={} sparse={} packed={} fused_pages={} simd_lanes={} \
             | score path {:.2}µs/decode (dequant {:.1}µs total)\n\
             kv resident {:.1}KiB (peak {:.1}KiB) pages={} util {:.0}% stalls={} free={}\n\
             prefix hits={} tok ({:.0}% of prompt volume) shared_pages={} cow={} evictions={}\n\
             spec drafted={} accepted={} rejected={} (acceptance {:.0}%) \
             effective {:.2} tok/step over {} verify passes",
            self.requests_done, self.requests_served, self.requests_rejected,
            self.requests_cancelled, self.requests_expired, self.requests_failed,
            self.lane_failures, self.tokens_generated, self.prompt_tokens,
            self.decode_calls, self.prefill_calls, self.decode_time_s,
            self.decode_tok_per_s, self.prefill_time_s, self.wall_tok_per_s,
            self.mean_ttft_ms, self.p50_ttft_ms, self.p99_ttft_ms,
            self.mean_latency_ms, self.h2o_evictions,
            self.sched_steps, 100.0 * self.batch_occupancy, self.prefill_tokens_per_step,
            self.itl_mean_ms, self.itl_p99_ms, self.queue_wait_p50_ms, self.queue_wait_p99_ms,
            self.kernels.dense, self.kernels.sparse, self.kernels.packed,
            self.kernels.fused_passes, self.kernels.simd_lanes_used,
            self.score_us_per_decode,
            self.kernels.dequant_ns as f64 / 1000.0,
            self.kv_resident_bytes as f64 / 1024.0,
            self.kv_resident_peak_bytes as f64 / 1024.0,
            self.kv_pages_in_use,
            100.0 * self.kv_page_utilization,
            self.kv_alloc_stalls,
            self.kv_pages_free,
            self.prefix_hit_tokens,
            100.0 * self.prefix_hit_rate(),
            self.kv_shared_pages,
            self.kv_cow_copies,
            self.kv_prefix_evictions,
            self.spec_drafted,
            self.spec_accepted,
            self.spec_rejected,
            100.0 * self.spec_acceptance_rate,
            self.tokens_per_step_effective,
            self.spec_verify_passes,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let m = Metrics::default();
        m.start_clock();
        m.record_decode(Duration::from_millis(10), 4);
        m.record_decode(Duration::from_millis(10), 4);
        m.record_prefill(Duration::from_millis(5), 32);
        m.record_finish(Some(Duration::from_millis(15)), Duration::from_millis(50));
        m.record_evictions(3);
        m.record_kernels(
            &KernelCounters {
                dense: 2,
                sparse: 1,
                packed: 5,
                score_ns: 4_000,
                fused_passes: 2,
                simd_lanes_used: 8,
                dequant_ns: 500,
            },
            true,
        );
        m.record_kernels(
            &KernelCounters {
                dense: 0,
                sparse: 0,
                packed: 3,
                score_ns: 2_000,
                fused_passes: 3,
                simd_lanes_used: 1,
                dequant_ns: 250,
            },
            true,
        );
        // prefill score time counts in the pooled counters, not per-decode
        let prefill =
            KernelCounters { dense: 4, sparse: 0, packed: 0, score_ns: 9_000, ..Default::default() };
        m.record_kernels(&prefill, false);
        let s = m.snapshot();
        assert_eq!(s.tokens_generated, 8);
        assert_eq!(s.prompt_tokens, 32);
        assert_eq!(s.decode_calls, 2);
        assert_eq!(s.requests_done, 1);
        assert_eq!(s.h2o_evictions, 3);
        assert_eq!(s.kernels.dense, 6);
        assert_eq!(s.kernels.sparse, 1);
        assert_eq!(s.kernels.packed, 8);
        assert_eq!(s.kernels.score_ns, 15_000);
        assert_eq!(s.kernels.fused_passes, 5);
        assert_eq!(s.kernels.simd_lanes_used, 8, "lane width is max-merged, not summed");
        assert_eq!(s.kernels.dequant_ns, 750);
        // (4000 + 2000) ns of *decode* score time over 2 decode calls
        assert!((s.score_us_per_decode - 3.0).abs() < 1e-9);
        assert!((s.decode_tok_per_s - 400.0).abs() < 1.0);
        assert!(s.mean_ttft_ms > 14.0 && s.mean_ttft_ms < 16.0);
        assert!(s.report().contains("packed=8"));
        assert!(s.report().contains("fused_pages=5"));
    }

    #[test]
    fn kv_gauges_track_latest_and_peak() {
        let m = Metrics::default();
        let g1 = KvPoolGauges {
            resident_bytes: 4096,
            pages_in_use: 2,
            page_slots: 16,
            ..Default::default()
        };
        m.record_kv(&g1, 24);
        let g2 = KvPoolGauges {
            resident_bytes: 2048,
            pages_in_use: 1,
            page_slots: 16,
            ..Default::default()
        };
        m.record_kv(&g2, 10);
        let s = m.snapshot();
        assert_eq!(s.kv_resident_bytes, 2048, "latest sample wins");
        assert_eq!(s.kv_resident_peak_bytes, 4096, "peak survives");
        assert_eq!(s.kv_pages_in_use, 1);
        // 10 live slots over 1 page of 16 slots
        assert!((s.kv_page_utilization - 10.0 / 16.0).abs() < 1e-9);
        assert!(s.report().contains("kv resident"));

        // fleet merge: bytes add, utilization weights by pages
        let mut a = s.clone();
        let other = Snapshot {
            kv_resident_bytes: 1024,
            kv_resident_peak_bytes: 1024,
            kv_pages_in_use: 3,
            kv_page_utilization: 1.0,
            ..Default::default()
        };
        a.merge(&other);
        assert_eq!(a.kv_resident_bytes, 3072);
        assert_eq!(a.kv_resident_peak_bytes, 5120);
        assert_eq!(a.kv_pages_in_use, 4);
        let want = (10.0 / 16.0 + 3.0) / 4.0;
        assert!((a.kv_page_utilization - want).abs() < 1e-9);
    }

    #[test]
    fn prefix_hits_reconcile_with_prompt_tokens() {
        let m = Metrics::default();
        // 48 computed prompt tokens + 64 served from the prefix cache
        m.record_prefill(Duration::from_millis(1), 48);
        m.record_prefix_hits(48);
        m.record_prefix_hits(16);
        let g = KvPoolGauges { pages_free: 5, shared_pages: 2, cow_copies: 1, ..Default::default() };
        m.record_kv(&g, 0);
        let s = m.snapshot();
        assert_eq!(s.prefix_hit_tokens, 64);
        assert!((s.prefix_hit_rate() - 64.0 / 112.0).abs() < 1e-12);
        assert_eq!(s.kv_pages_free, 5);
        assert_eq!(s.kv_shared_pages, 2);
        assert_eq!(s.kv_cow_copies, 1);
        assert!(s.report().contains("prefix hits=64"));
        // fleet merge sums hit volume and pool gauges
        let mut a = s.clone();
        a.merge(&s);
        assert_eq!(a.prefix_hit_tokens, 128);
        assert_eq!(a.kv_pages_free, 10);
        assert_eq!(a.kv_shared_pages, 4);
        assert_eq!(a.kv_cow_copies, 2);
        assert!((a.prefix_hit_rate() - 128.0 / 224.0).abs() < 1e-12);
    }

    #[test]
    fn scheduler_gauges_reconcile() {
        let m = Metrics::default();
        // 4 passes at occupancy 2,4,4,2 of 4 lanes; 32 prompt tokens
        m.record_prefill(Duration::from_millis(1), 32);
        for occ in [2u64, 4, 4, 2] {
            m.record_step(occ, 4);
        }
        // rejected submissions count in requests_done AND the rejected
        // counter, so submissions reconcile
        m.record_finish(Some(Duration::from_millis(2)), Duration::from_millis(9));
        m.record_rejected();
        m.record_rejected();
        m.record_queue_wait(Duration::from_millis(4));
        m.record_queue_wait(Duration::from_millis(8));
        // ITL: 9 gaps near 1ms, one 10ms straggler → p99 lands on the
        // straggler's bucket, mean is exact
        let gaps: Vec<u64> = (0..9).map(|_| 1000u64).chain([10_000]).collect();
        m.record_itl(&gaps);
        let s = m.snapshot();
        assert_eq!(s.requests_done, 3, "1 finished + 2 rejected");
        assert_eq!(s.requests_rejected, 2);
        assert_eq!(s.sched_steps, 4);
        assert!((s.batch_occupancy - 12.0 / 16.0).abs() < 1e-9);
        assert!((s.prefill_tokens_per_step - 8.0).abs() < 1e-9);
        assert!((s.queue_wait_p99_ms - 8.0).abs() < 0.5);
        let exact_mean = (9.0 * 1.0 + 10.0) / 10.0;
        assert!((s.itl_mean_ms - exact_mean).abs() < 1e-6);
        // bucketed percentiles are exact to one ~19%-wide bucket
        assert!(s.itl_p99_ms > 8.0 && s.itl_p99_ms < 12.0, "p99 {} ≉ 10ms", s.itl_p99_ms);
        let p50 = hist_percentile_ms(&m.inner.lock().unwrap().itl_hist, 10, 50.0);
        assert!(p50 > 0.8 && p50 < 1.2, "p50 {p50} ≉ 1ms");
        assert!(s.report().contains("rejected=2"));
        assert!(s.report().contains("sched steps=4"));
    }

    #[test]
    fn outcome_counters_reconcile() {
        let m = Metrics::default();
        // 2 served; 1 rejected; cancelled in-queue + after running;
        // 1 expired in-queue; 1 failed after running (one lane retired)
        m.record_finish(None, Duration::from_millis(1));
        m.record_finish(None, Duration::from_millis(1));
        m.record_rejected();
        m.record_cancelled(false);
        m.record_finish(None, Duration::from_millis(1));
        m.record_cancelled(true);
        m.record_expired(false);
        m.record_finish(None, Duration::from_millis(1));
        m.record_failed(true, 1);
        let s = m.snapshot();
        assert_eq!(s.requests_done, 7);
        assert_eq!(s.requests_rejected, 1);
        assert_eq!(s.requests_cancelled, 2);
        assert_eq!(s.requests_expired, 1);
        assert_eq!(s.requests_failed, 1);
        assert_eq!(s.lane_failures, 1);
        assert_eq!(s.requests_served, 2);
        assert_eq!(
            s.requests_done,
            s.requests_served
                + s.requests_rejected
                + s.requests_cancelled
                + s.requests_expired
                + s.requests_failed,
            "outcome counters must reconcile"
        );
        assert!(s.report().contains("cancelled=2"));
        // fleet merge preserves the reconciliation (served is a counter
        // in the aggregate, not re-derived)
        let mut a = s.clone();
        a.merge(&s);
        assert_eq!(a.requests_done, 14);
        assert_eq!(a.requests_served, 4);
        assert_eq!(a.lane_failures, 2);
        assert_eq!(
            a.requests_done,
            a.requests_served
                + a.requests_rejected
                + a.requests_cancelled
                + a.requests_expired
                + a.requests_failed
        );
    }

    #[test]
    fn ttft_histogram_is_bounded_and_percentiled() {
        let m = Metrics::default();
        // 9 fast first tokens near 5ms, one 50ms straggler
        for _ in 0..9 {
            m.record_finish(Some(Duration::from_millis(5)), Duration::from_millis(20));
        }
        m.record_finish(Some(Duration::from_millis(50)), Duration::from_millis(80));
        let s = m.snapshot();
        // the mean stays exact (sum side channel), percentiles are exact
        // to one ~19%-wide bucket
        let exact_mean = (9.0 * 5.0 + 50.0) / 10.0;
        assert!((s.mean_ttft_ms - exact_mean).abs() < 1e-6, "mean {}", s.mean_ttft_ms);
        assert!(s.p50_ttft_ms > 4.0 && s.p50_ttft_ms < 6.0, "p50 {} ≉ 5ms", s.p50_ttft_ms);
        assert!(s.p99_ttft_ms > 40.0 && s.p99_ttft_ms < 60.0, "p99 {} ≉ 50ms", s.p99_ttft_ms);
        // score-only finishes (no first token) contribute no TTFT sample
        let m2 = Metrics::default();
        m2.record_finish(None, Duration::from_millis(5));
        let s2 = m2.snapshot();
        assert_eq!(s2.mean_ttft_ms, 0.0);
        assert_eq!(s2.p99_ttft_ms, 0.0);
    }

    #[test]
    fn spec_counters_reconcile_and_merge() {
        let m = Metrics::default();
        // cycle 1: 2 lanes, 6 drafted, 5 accepted, 7 committed
        m.record_spec(6, 5, 7, 2);
        // cycle 2: 1 lane, 4 drafted, 2 accepted, 3 committed
        m.record_spec(4, 2, 3, 1);
        let s = m.snapshot();
        assert_eq!(s.spec_drafted, 10);
        assert_eq!(s.spec_accepted, 7);
        assert_eq!(s.spec_rejected, 3);
        assert_eq!(s.spec_drafted, s.spec_accepted + s.spec_rejected, "must reconcile");
        assert_eq!(s.spec_verify_passes, 2);
        assert!((s.spec_acceptance_rate - 0.7).abs() < 1e-12);
        // 10 committed tokens over 3 lane-cycles
        assert!((s.tokens_per_step_effective - 10.0 / 3.0).abs() < 1e-12);
        assert!(s.report().contains("spec drafted=10"));

        // fleet merge: counters add, rates re-derive, reconciliation holds
        let mut a = s.clone();
        a.merge(&s);
        assert_eq!(a.spec_drafted, 20);
        assert_eq!(a.spec_drafted, a.spec_accepted + a.spec_rejected);
        assert!((a.spec_acceptance_rate - 0.7).abs() < 1e-12);
        assert!((a.tokens_per_step_effective - 10.0 / 3.0).abs() < 1e-12);

        // speculation off: rates report 0, not NaN
        let off = Metrics::default().snapshot();
        assert_eq!(off.spec_acceptance_rate, 0.0);
        assert_eq!(off.tokens_per_step_effective, 0.0);
    }

    #[test]
    fn locks_survive_poison() {
        // a panic while holding the metrics lock (e.g. a backend panic the
        // supervisor catches) must not cascade into later recording or
        // snapshot calls
        let m = std::sync::Arc::new(Metrics::default());
        let m2 = m.clone();
        let joined = std::thread::spawn(move || {
            let _g = m2.inner.lock().unwrap();
            panic!("poison the metrics lock");
        })
        .join();
        assert!(joined.is_err(), "the poisoning thread must have panicked");
        m.record_rejected();
        assert_eq!(m.snapshot().requests_rejected, 1, "poisoned lock still records");
    }

    #[test]
    fn scheduler_gauges_merge_weighted() {
        let mut a = Snapshot {
            sched_steps: 10,
            prefill_tokens_per_step: 8.0,
            batch_occupancy: 0.5,
            tokens_generated: 100,
            itl_mean_ms: 1.0,
            itl_p99_ms: 2.0,
            queue_wait_p99_ms: 3.0,
            requests_rejected: 1,
            ..Default::default()
        };
        let b = Snapshot {
            sched_steps: 30,
            prefill_tokens_per_step: 4.0,
            batch_occupancy: 1.0,
            tokens_generated: 300,
            itl_mean_ms: 3.0,
            itl_p99_ms: 1.0,
            queue_wait_p99_ms: 7.0,
            requests_rejected: 2,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.sched_steps, 40);
        assert_eq!(a.requests_rejected, 3);
        assert!((a.prefill_tokens_per_step - 5.0).abs() < 1e-9, "weighted by steps");
        assert!((a.batch_occupancy - 0.875).abs() < 1e-9);
        assert!((a.itl_mean_ms - 2.5).abs() < 1e-9, "weighted by tokens");
        assert!((a.itl_p99_ms - 2.0).abs() < 1e-9, "worst-of");
        assert!((a.queue_wait_p99_ms - 7.0).abs() < 1e-9, "worst-of");
    }

    #[test]
    fn snapshot_merge_aggregates_fleet() {
        let mut a = Snapshot {
            requests_done: 2,
            tokens_generated: 100,
            decode_calls: 10,
            decode_time_s: 1.0,
            mean_ttft_ms: 10.0,
            p99_ttft_ms: 20.0,
            h2o_evictions: 3,
            wall_tok_per_s: 50.0,
            score_us_per_decode: 4.0,
            kernels: KernelCounters {
                dense: 5,
                sparse: 0,
                packed: 0,
                score_ns: 100,
                fused_passes: 1,
                simd_lanes_used: 8,
                dequant_ns: 40,
            },
            ..Default::default()
        };
        let b = Snapshot {
            requests_done: 6,
            tokens_generated: 300,
            decode_calls: 30,
            decode_time_s: 1.0,
            mean_ttft_ms: 30.0,
            p99_ttft_ms: 15.0,
            h2o_evictions: 1,
            wall_tok_per_s: 150.0,
            score_us_per_decode: 8.0,
            kernels: KernelCounters {
                dense: 0,
                sparse: 2,
                packed: 7,
                score_ns: 50,
                fused_passes: 4,
                simd_lanes_used: 1,
                dequant_ns: 10,
            },
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.requests_done, 8);
        assert_eq!(a.tokens_generated, 400);
        assert_eq!(a.h2o_evictions, 4);
        assert_eq!(
            a.kernels,
            KernelCounters {
                dense: 5,
                sparse: 2,
                packed: 7,
                score_ns: 150,
                fused_passes: 5,
                simd_lanes_used: 8,
                dequant_ns: 50,
            }
        );
        assert!((a.mean_ttft_ms - 25.0).abs() < 1e-9, "weighted by requests: (10*2+30*6)/8");
        assert!((a.p99_ttft_ms - 20.0).abs() < 1e-9, "worst-of");
        assert!((a.wall_tok_per_s - 200.0).abs() < 1e-9, "concurrent engines add");
        assert!((a.decode_tok_per_s - 200.0).abs() < 1e-9, "400 tokens over 2s of decode");
        assert!((a.score_us_per_decode - 7.0).abs() < 1e-9, "weighted by decode calls");

        // merging into an empty aggregate is identity on counters
        let mut empty = Snapshot::default();
        empty.merge(&b);
        assert_eq!(empty.requests_done, 6);
        assert!((empty.mean_ttft_ms - 30.0).abs() < 1e-9);
    }
}
