//! Admission queue + lane table (continuous batching).
//!
//! Requests enter a FIFO; the lane table assigns them to free batch lanes
//! as capacity opens up (a finished request frees its lane immediately —
//! no epoch barriers). Invariants (property-tested):
//! * a request occupies at most one lane,
//! * admission order is FIFO among waiting requests,
//! * occupied lanes ≤ batch size.

use std::collections::VecDeque;

use super::request::GenRequest;

/// FIFO admission queue (engine-internal; thread-safe wrapper lives in the
/// engine).
#[derive(Debug, Default)]
pub struct AdmissionQueue {
    q: VecDeque<GenRequest>,
}

impl AdmissionQueue {
    pub fn push(&mut self, r: GenRequest) {
        self.q.push_back(r);
    }

    /// Return a popped request to the head of the queue (memory-aware
    /// admission defers the FIFO head until enough KV pages free up —
    /// order among waiting requests is preserved).
    pub fn push_front(&mut self, r: GenRequest) {
        self.q.push_front(r);
    }

    pub fn pop(&mut self) -> Option<GenRequest> {
        self.q.pop_front()
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }
}

/// Which request (by id) occupies each lane.
#[derive(Debug)]
pub struct LaneTable {
    lanes: Vec<Option<u64>>,
}

impl LaneTable {
    pub fn new(batch: usize) -> Self {
        LaneTable { lanes: vec![None; batch] }
    }

    pub fn batch(&self) -> usize {
        self.lanes.len()
    }

    pub fn free_lane(&self) -> Option<usize> {
        self.lanes.iter().position(|l| l.is_none())
    }

    pub fn occupy(&mut self, lane: usize, id: u64) {
        debug_assert!(self.lanes[lane].is_none(), "lane {lane} already occupied");
        self.lanes[lane] = Some(id);
    }

    pub fn release(&mut self, lane: usize) {
        self.lanes[lane] = None;
    }

    pub fn occupant(&self, lane: usize) -> Option<u64> {
        self.lanes[lane]
    }

    pub fn occupied(&self) -> usize {
        self.lanes.iter().filter(|l| l.is_some()).count()
    }

    pub fn is_idle(&self) -> bool {
        self.occupied() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::check;

    #[test]
    fn fifo_order() {
        let mut q = AdmissionQueue::default();
        for i in 0..5 {
            q.push(GenRequest::new(i, vec![], 1));
        }
        for i in 0..5 {
            assert_eq!(q.pop().unwrap().id, i);
        }
        assert!(q.pop().is_none());
    }

    #[test]
    fn lane_lifecycle() {
        let mut t = LaneTable::new(2);
        assert!(t.is_idle());
        let l0 = t.free_lane().unwrap();
        t.occupy(l0, 10);
        let l1 = t.free_lane().unwrap();
        assert_ne!(l0, l1);
        t.occupy(l1, 11);
        assert_eq!(t.free_lane(), None);
        assert_eq!(t.occupied(), 2);
        t.release(l0);
        assert_eq!(t.free_lane(), Some(l0));
        assert_eq!(t.occupant(l1), Some(11));
    }

    #[test]
    fn prop_no_double_occupancy() {
        check(
            "lane-exclusivity",
            100,
            |g| {
                let batch = 1 + g.rng.below(8);
                let ops: Vec<(bool, u64)> =
                    (0..g.rng.below(40)).map(|i| (g.rng.f64() < 0.6, i as u64)).collect();
                (batch, ops)
            },
            |(batch, ops)| {
                let mut t = LaneTable::new(*batch);
                let mut active: Vec<(usize, u64)> = vec![];
                for &(is_add, id) in ops {
                    if is_add {
                        if let Some(l) = t.free_lane() {
                            t.occupy(l, id);
                            active.push((l, id));
                        }
                    } else if let Some((l, _)) = active.pop() {
                        t.release(l);
                    }
                    if t.occupied() > *batch {
                        return Err("over capacity".into());
                    }
                    // each live id in exactly one lane
                    let mut seen = std::collections::HashSet::new();
                    for lane in 0..t.batch() {
                        if let Some(id) = t.occupant(lane) {
                            if !seen.insert(id) {
                                return Err(format!("id {id} in two lanes"));
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
