//! Admission queue + lane table (continuous batching).
//!
//! Requests enter a priority-ordered queue (higher `priority` first,
//! FIFO within a class); the lane table assigns them to free batch lanes
//! as capacity opens up (a finished request frees its lane immediately —
//! no epoch barriers). Under waiting-vs-served pressure the queue may
//! promote a later request past a head the budget cannot admit yet —
//! bounded by [`MAX_HEAD_OVERTAKES`] so the head is never starved
//! indefinitely either. Invariants (property-tested):
//! * a request occupies at most one lane,
//! * admission order is FIFO among waiting requests of the same priority
//!   class except for bounded pressure overtakes of a blocked head,
//! * a blocked head is overtaken at most `MAX_HEAD_OVERTAKES` times,
//! * occupied lanes ≤ batch size.

use std::collections::VecDeque;
use std::time::Instant;

use super::request::GenRequest;

/// How many times a budget-blocked queue head may be overtaken by smaller
/// requests before the queue insists on admitting it next. Bounds
/// head-of-line starvation in *both* directions: the head cannot block
/// admissible work forever, and pressure cannot starve the head forever.
pub const MAX_HEAD_OVERTAKES: u32 = 4;

/// One waiting request plus its queue bookkeeping.
#[derive(Debug)]
pub struct Queued {
    pub req: GenRequest,
    /// When the request entered the queue (drives the queue-wait gauges;
    /// survives memory-aware re-queueing so deferral shows up as wait).
    pub enqueued_at: Instant,
    /// Times a later request was admitted past this one while it sat at
    /// the head.
    overtaken: u32,
}

/// FIFO admission queue (engine-internal; thread-safe wrapper lives in the
/// engine).
#[derive(Debug, Default)]
pub struct AdmissionQueue {
    q: VecDeque<Queued>,
}

impl AdmissionQueue {
    /// Enqueue ordered by priority class: the new entry goes after the
    /// last waiter whose `priority >= r.priority`, so higher-priority
    /// requests jump ahead of lower ones while FIFO age is preserved
    /// within a class. Everything downstream (`requeue_front`,
    /// `pop_past_head`, the overtake bound) operates on positions, not
    /// priorities, so the `waiting_served_ratio` head-starvation bound
    /// holds for whatever sits at the head.
    pub fn push(&mut self, r: GenRequest) {
        let idx = self
            .q
            .iter()
            .rposition(|e| e.req.priority >= r.priority)
            .map_or(0, |i| i + 1);
        self.q.insert(idx, Queued { req: r, enqueued_at: Instant::now(), overtaken: 0 });
    }

    /// Return a popped entry to the head of the queue (memory-aware
    /// admission defers the head until enough KV pages or batch tokens
    /// free up — order among waiting requests is preserved, and the
    /// entry keeps its original enqueue time and overtake count).
    pub fn requeue_front(&mut self, e: Queued) {
        self.q.push_front(e);
    }

    /// Pop the head unconditionally (the plain FIFO step; the caller
    /// decides whether it can actually run and `requeue_front`s if not).
    pub fn pop_front(&mut self) -> Option<Queued> {
        self.q.pop_front()
    }

    /// Pressure path: the head is known-blocked, look *past* it for the
    /// first request `fits` accepts. Succeeds only while the head has
    /// been overtaken fewer than [`MAX_HEAD_OVERTAKES`] times (each
    /// success increments the head's count), so a blocked head is never
    /// starved indefinitely. Order among the remaining waiters is
    /// preserved.
    pub fn pop_past_head(&mut self, mut fits: impl FnMut(&GenRequest) -> bool) -> Option<Queued> {
        if self.q.front()?.overtaken >= MAX_HEAD_OVERTAKES {
            return None;
        }
        let idx = self.q.iter().skip(1).position(|e| fits(&e.req))? + 1;
        self.q[0].overtaken += 1;
        self.q.remove(idx)
    }

    pub fn contains(&self, id: u64) -> bool {
        self.q.iter().any(|e| e.req.id == id)
    }

    /// Remove and return every waiting entry `pred` accepts, preserving
    /// FIFO order (and overtake counts) among the rest. Drives queued-
    /// request cancellation and deadline-expiry sweeps — both terminal,
    /// so the removed entries leave the queue for good.
    pub fn drain_matching(&mut self, mut pred: impl FnMut(&Queued) -> bool) -> Vec<Queued> {
        let mut out = vec![];
        let mut i = 0;
        while i < self.q.len() {
            if pred(&self.q[i]) {
                // remove() preserves the order of the remaining entries
                if let Some(e) = self.q.remove(i) {
                    out.push(e);
                }
            } else {
                i += 1;
            }
        }
        out
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }
}

/// Which request (by id) occupies each lane.
#[derive(Debug)]
pub struct LaneTable {
    lanes: Vec<Option<u64>>,
}

impl LaneTable {
    pub fn new(batch: usize) -> Self {
        LaneTable { lanes: vec![None; batch] }
    }

    pub fn batch(&self) -> usize {
        self.lanes.len()
    }

    pub fn free_lane(&self) -> Option<usize> {
        self.lanes.iter().position(|l| l.is_none())
    }

    pub fn occupy(&mut self, lane: usize, id: u64) {
        debug_assert!(self.lanes[lane].is_none(), "lane {lane} already occupied");
        self.lanes[lane] = Some(id);
    }

    pub fn release(&mut self, lane: usize) {
        self.lanes[lane] = None;
    }

    pub fn occupant(&self, lane: usize) -> Option<u64> {
        self.lanes[lane]
    }

    pub fn occupied(&self) -> usize {
        self.lanes.iter().filter(|l| l.is_some()).count()
    }

    pub fn is_idle(&self) -> bool {
        self.occupied() == 0
    }

    pub fn contains(&self, id: u64) -> bool {
        self.lanes.iter().any(|l| *l == Some(id))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::check;

    #[test]
    fn fifo_order() {
        let mut q = AdmissionQueue::default();
        for i in 0..5 {
            q.push(GenRequest::new(i, vec![], 1));
        }
        for i in 0..5 {
            assert_eq!(q.pop_front().unwrap().req.id, i);
        }
        assert!(q.pop_front().is_none());
    }

    #[test]
    fn priority_orders_ahead_of_fifo_age() {
        let mut q = AdmissionQueue::default();
        let mut push = |id: u64, pri: i64| {
            let mut r = GenRequest::new(id, vec![], 1);
            r.priority = pri;
            q.push(r);
        };
        push(0, 0);
        push(1, 0);
        push(2, 5); // jumps both default-priority waiters
        push(3, 5); // same class — behind 2 (FIFO within class)
        push(4, -1); // below default — tail
        push(5, 0); // behind the existing default-class waiters
        let order: Vec<u64> = std::iter::from_fn(|| q.pop_front()).map(|e| e.req.id).collect();
        assert_eq!(order, vec![2, 3, 0, 1, 5, 4]);
    }

    #[test]
    fn priority_head_keeps_overtake_bound() {
        let mut q = AdmissionQueue::default();
        // a high-priority head too big for the budget must still be
        // admitted after MAX_HEAD_OVERTAKES pressure skips
        let mut big = GenRequest::new(0, vec![], 100);
        big.priority = 9;
        q.push(big);
        for i in 1..=MAX_HEAD_OVERTAKES + 1 {
            q.push(GenRequest::new(i as u64, vec![], 1));
        }
        let fits = |r: &GenRequest| r.max_new_tokens <= 10;
        for _ in 0..MAX_HEAD_OVERTAKES {
            assert!(q.pop_past_head(fits).is_some());
        }
        assert!(q.pop_past_head(fits).is_none(), "priority head keeps the bound");
        assert_eq!(q.pop_front().unwrap().req.id, 0);
    }

    #[test]
    fn requeue_preserves_head_metadata() {
        let mut q = AdmissionQueue::default();
        q.push(GenRequest::new(1, vec![], 1));
        q.push(GenRequest::new(2, vec![], 1));
        let head = q.pop_front().unwrap();
        let t0 = head.enqueued_at;
        q.requeue_front(head);
        assert!(q.contains(1));
        let again = q.pop_front().unwrap();
        assert_eq!(again.req.id, 1, "requeue restores FIFO order");
        assert_eq!(again.enqueued_at, t0, "wait clock survives deferral");
    }

    #[test]
    fn pop_past_head_skips_blocked_head_boundedly() {
        let mut q = AdmissionQueue::default();
        // head wants 100 tokens, the rest want 1 — a "budget" of 10 can
        // admit everyone but the head
        q.push(GenRequest::new(0, vec![], 100));
        for i in 1..=MAX_HEAD_OVERTAKES + 2 {
            q.push(GenRequest::new(i as u64, vec![], 1));
        }
        let fits = |r: &GenRequest| r.max_new_tokens <= 10;
        // the head may be overtaken exactly MAX_HEAD_OVERTAKES times...
        for i in 1..=MAX_HEAD_OVERTAKES {
            let e = q.pop_past_head(fits).expect("overtake allowed");
            assert_eq!(e.req.id, i as u64, "overtakes keep FIFO among the rest");
        }
        // ...then the queue insists on the head
        assert!(q.pop_past_head(fits).is_none(), "overtake bound reached");
        assert_eq!(q.pop_front().unwrap().req.id, 0);
        // with the head gone the counter belongs to the new head
        assert!(q.pop_past_head(fits).is_some());
    }

    #[test]
    fn pop_past_head_respects_fits() {
        let mut q = AdmissionQueue::default();
        q.push(GenRequest::new(0, vec![], 100));
        q.push(GenRequest::new(1, vec![], 90));
        assert!(q.pop_past_head(|r| r.max_new_tokens <= 10).is_none());
        assert_eq!(q.len(), 2, "nothing removed when no waiter fits");
    }

    #[test]
    fn drain_matching_removes_and_preserves_order() {
        let mut q = AdmissionQueue::default();
        for i in 0..6 {
            q.push(GenRequest::new(i, vec![], 1));
        }
        let out = q.drain_matching(|e| e.req.id % 2 == 1);
        assert_eq!(out.iter().map(|e| e.req.id).collect::<Vec<_>>(), vec![1, 3, 5]);
        assert_eq!(q.len(), 3);
        for want in [0, 2, 4] {
            assert_eq!(q.pop_front().unwrap().req.id, want, "survivors keep FIFO order");
        }
        assert!(q.drain_matching(|_| true).is_empty(), "empty queue drains nothing");
    }

    #[test]
    fn lane_lifecycle() {
        let mut t = LaneTable::new(2);
        assert!(t.is_idle());
        let l0 = t.free_lane().unwrap();
        t.occupy(l0, 10);
        let l1 = t.free_lane().unwrap();
        assert_ne!(l0, l1);
        t.occupy(l1, 11);
        assert_eq!(t.free_lane(), None);
        assert_eq!(t.occupied(), 2);
        assert!(t.contains(11));
        t.release(l0);
        assert_eq!(t.free_lane(), Some(l0));
        assert_eq!(t.occupant(l1), Some(11));
        assert!(!t.contains(10));
    }

    #[test]
    fn prop_no_double_occupancy() {
        check(
            "lane-exclusivity",
            100,
            |g| {
                let batch = 1 + g.rng.below(8);
                let ops: Vec<(bool, u64)> =
                    (0..g.rng.below(40)).map(|i| (g.rng.f64() < 0.6, i as u64)).collect();
                (batch, ops)
            },
            |(batch, ops)| {
                let mut t = LaneTable::new(*batch);
                let mut active: Vec<(usize, u64)> = vec![];
                for &(is_add, id) in ops {
                    if is_add {
                        if let Some(l) = t.free_lane() {
                            t.occupy(l, id);
                            active.push((l, id));
                        }
                    } else if let Some((l, _)) = active.pop() {
                        t.release(l);
                    }
                    if t.occupied() > *batch {
                        return Err("over capacity".into());
                    }
                    // each live id in exactly one lane
                    let mut seen = std::collections::HashSet::new();
                    for lane in 0..t.batch() {
                        if let Some(id) = t.occupant(lane) {
                            if !seen.insert(id) {
                                return Err(format!("id {id} in two lanes"));
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_pressure_overtakes_are_bounded_and_order_preserving() {
        check(
            "queue-overtake-fairness",
            100,
            |g| {
                // random sequence of pushes (cost 1..=20) and pops against
                // a random budget; head blocked when cost > budget
                let budget = 1 + g.rng.below(12);
                let ops: Vec<(bool, usize)> = (0..10 + g.rng.below(60))
                    .map(|_| (g.rng.f64() < 0.5, 1 + g.rng.below(20)))
                    .collect();
                (budget, ops)
            },
            |(budget, ops)| {
                let budget = *budget;
                let mut q = AdmissionQueue::default();
                let mut next_id = 0u64;
                let mut admitted: Vec<u64> = vec![];
                let mut pushed: Vec<(u64, usize)> = vec![];
                for &(is_push, cost) in ops {
                    if is_push {
                        q.push(GenRequest::new(next_id, vec![], cost));
                        pushed.push((next_id, cost));
                        next_id += 1;
                    } else {
                        // mimic the engine: head first, pressure skip second
                        let fits = |r: &GenRequest| r.max_new_tokens <= budget;
                        let head_fits = match q.pop_front() {
                            Some(e) if fits(&e.req) => {
                                admitted.push(e.req.id);
                                true
                            }
                            Some(e) => {
                                q.requeue_front(e);
                                false
                            }
                            None => false,
                        };
                        if !head_fits {
                            if let Some(e) = q.pop_past_head(fits) {
                                if !fits(&e.req) {
                                    return Err("pop_past_head ignored fits".into());
                                }
                                admitted.push(e.req.id);
                            }
                        }
                    }
                }
                // every admitted id was pushed exactly once
                let mut seen = std::collections::HashSet::new();
                for id in &admitted {
                    if !seen.insert(*id) {
                        return Err(format!("id {id} admitted twice"));
                    }
                }
                // among fitting requests, admission preserves push order
                let fit_order: Vec<u64> = pushed
                    .iter()
                    .filter(|(id, c)| *c <= budget && admitted.contains(id))
                    .map(|(id, _)| *id)
                    .collect();
                let admitted_fit: Vec<u64> = admitted
                    .iter()
                    .copied()
                    .filter(|id| fit_order.contains(id))
                    .collect();
                if fit_order != admitted_fit {
                    return Err(format!("fit order {fit_order:?} != admitted {admitted_fit:?}"));
                }
                // no still-queued fitting request was overtaken more than
                // the bound while at the head
                Ok(())
            },
        );
    }

    #[test]
    fn prop_priority_classes_order_and_bound_survive() {
        check(
            "queue-priority-fairness",
            100,
            |g| {
                // random pushes across 3 priority classes + random pops
                let budget = 1 + g.rng.below(12);
                let ops: Vec<(bool, usize, i64)> = (0..10 + g.rng.below(60))
                    .map(|_| (g.rng.f64() < 0.5, 1 + g.rng.below(20), g.rng.below(3) as i64))
                    .collect();
                (budget, ops)
            },
            |(budget, ops)| {
                let budget = *budget;
                let mut q = AdmissionQueue::default();
                let mut next_id = 0u64;
                let mut admitted: Vec<u64> = vec![];
                let mut pushed: Vec<(u64, usize, i64)> = vec![];
                for &(is_push, cost, pri) in ops {
                    if is_push {
                        let mut r = GenRequest::new(next_id, vec![], cost);
                        r.priority = pri;
                        q.push(r);
                        pushed.push((next_id, cost, pri));
                        next_id += 1;
                    } else {
                        let fits = |r: &GenRequest| r.max_new_tokens <= budget;
                        let head_fits = match q.pop_front() {
                            Some(e) if fits(&e.req) => {
                                admitted.push(e.req.id);
                                true
                            }
                            Some(e) => {
                                q.requeue_front(e);
                                false
                            }
                            None => false,
                        };
                        if !head_fits {
                            if let Some(e) = q.pop_past_head(fits) {
                                admitted.push(e.req.id);
                            }
                        }
                    }
                }
                // every admitted id was pushed exactly once
                let mut seen = std::collections::HashSet::new();
                for id in &admitted {
                    if !seen.insert(*id) {
                        return Err(format!("id {id} admitted twice"));
                    }
                }
                // within each priority class, fitting requests are
                // admitted in push order (cross-class jumps are the
                // feature; intra-class FIFO is the invariant)
                for class in 0..3i64 {
                    let fit_order: Vec<u64> = pushed
                        .iter()
                        .filter(|(id, c, p)| *p == class && *c <= budget && admitted.contains(id))
                        .map(|(id, _, _)| *id)
                        .collect();
                    let admitted_fit: Vec<u64> = admitted
                        .iter()
                        .copied()
                        .filter(|id| fit_order.contains(id))
                        .collect();
                    if fit_order != admitted_fit {
                        return Err(format!(
                            "class {class}: fit order {fit_order:?} != admitted {admitted_fit:?}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
