//! The engine: continuous-batching decode loop over a pluggable
//! [`ExecBackend`].
//!
//! Single-threaded by design — the production PJRT backend's handles are
//! !Send, so the engine owns its backend and the server front-end talks to
//! it through channels (see `EngineHandle`). One engine run has a fixed
//! [`AquaConfig`] (the knobs are runtime *inputs* to the backend step, so
//! switching configs needs no recompilation — `with_aqua` just changes the
//! scalars fed on the next call). The KV tensors live inside the backend;
//! the engine stays the authority on slot validity via the `slot_mask` it
//! passes on every call.

use std::collections::HashMap;
use std::sync::mpsc;
use std::time::Instant;

use anyhow::{bail, Result};

use super::batcher::{AdmissionQueue, LaneTable};
use super::h2o::H2oPolicy;
use super::kvcache::LaneKv;
use super::metrics::Metrics;
use super::request::{ActiveReq, FinishReason, GenRequest, GenResult};
use crate::aqua::policy::AquaConfig;
use crate::kvpool::{budget_pages, KvPoolConfig, PoolLayout, DEFAULT_PAGE_SLOTS};
use crate::model::sampling::Sampler;
use crate::runtime::backend::{AquaKnobs, BackendSpec, ExecBackend};
use crate::tensor::softmax::log_softmax_at;
use crate::util::prng::Rng;

#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub batch: usize,
    pub aqua: AquaConfig,
    pub h2o_recent_window: usize,
    pub sampler: Sampler,
    pub seed: u64,
    /// Token slots per KV page (see `crate::kvpool`).
    pub kv_page_slots: usize,
    /// KV pool budget in MiB; 0.0 = unlimited (worst-case pool, never
    /// stalls). The registry's admission gate uses the same number so a
    /// lease failure can only mean the gate was bypassed.
    pub kv_budget_mb: f64,
    /// Page-granular prefix sharing: admission consults the backend's
    /// prefix index and attaches matched page chains instead of spending
    /// prefill compute. Invisible to the math (greedy outputs are
    /// bit-identical to the sharing-disabled path), with one carve-out:
    /// the engine only attaches when H2O eviction is off, because skipped
    /// prefill queries contribute no eviction mass and would perturb
    /// H2O's choices. Off by default.
    pub prefix_cache: bool,
    /// Max chains the backend's prefix index registers (0 = unlimited).
    pub prefix_cache_pages: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            batch: 4,
            aqua: AquaConfig::default(),
            h2o_recent_window: 16,
            sampler: Sampler::Greedy,
            seed: 0,
            kv_page_slots: DEFAULT_PAGE_SLOTS,
            kv_budget_mb: 0.0,
            prefix_cache: false,
            prefix_cache_pages: 0,
        }
    }
}

impl EngineConfig {
    /// The KV pool geometry this config pins for a model — the **single
    /// source** both the engine's pool cap and the registry's admission
    /// gate derive from, so the two can never disagree on page
    /// arithmetic.
    pub fn pool_layout(&self, c: &crate::model::config::ModelConfig) -> PoolLayout {
        PoolLayout {
            page_slots: self.kv_page_slots.clamp(1, c.max_seq),
            key_dims: self.aqua.mem_dims(c.d_head),
            head_dim: c.d_head,
            layers: c.n_layers,
            kv_heads: c.n_kv_heads,
        }
    }

    /// The pool shape this config pins on its backend (one constructor so
    /// `Engine::new` and the `with_aqua` rebuild can never diverge).
    fn kv_pool_config(&self, layout: &PoolLayout, max_pages: Option<usize>) -> KvPoolConfig {
        KvPoolConfig {
            key_dims: Some(layout.key_dims),
            page_slots: Some(layout.page_slots),
            max_pages,
            prefix_cache: self.prefix_cache,
            prefix_cache_pages: self.prefix_cache_pages,
        }
    }
}

pub struct Engine {
    backend: Box<dyn ExecBackend>,
    pub cfg: EngineConfig,
    queue: AdmissionQueue,
    lanes: LaneTable,
    active: Vec<Option<ActiveReq>>,
    kv: Vec<LaneKv>,
    results: HashMap<u64, GenResult>,
    rng: Rng,
    pub metrics: Metrics,
    h2o: H2oPolicy,
    /// Resolved KV pool geometry (mirrors the backend's pool).
    kv_layout: PoolLayout,
    /// Page budget from `kv_budget_mb` (None = unlimited). Enforced at
    /// *admission*: a request only occupies a lane once its worst-case
    /// page growth fits next to the other occupants', so the pool cap can
    /// never stall mid-decode — for any backend, sharded included.
    kv_budget_pages: Option<usize>,
    /// Worst-case pages reserved per occupied lane.
    kv_reserved: Vec<usize>,
}

impl Engine {
    pub fn new(mut backend: Box<dyn ExecBackend>, cfg: EngineConfig) -> Result<Self> {
        if cfg.batch == 0 {
            bail!("batch must be >= 1");
        }
        let kv_layout = cfg.pool_layout(backend.model_config());
        let kv_budget_pages = budget_pages(cfg.kv_budget_mb, &kv_layout);
        backend.configure_kv_pool(cfg.kv_pool_config(&kv_layout, kv_budget_pages))?;
        backend.empty_cache(cfg.batch)?;
        let cap = backend.model_config().max_seq;
        let h2o = H2oPolicy::new(cfg.aqua.h2o_ratio, cfg.h2o_recent_window);
        Ok(Engine {
            backend,
            queue: AdmissionQueue::default(),
            lanes: LaneTable::new(cfg.batch),
            active: (0..cfg.batch).map(|_| None).collect(),
            kv: (0..cfg.batch).map(|_| LaneKv::new(cap)).collect(),
            results: HashMap::new(),
            rng: Rng::new(cfg.seed ^ 0xE17),
            metrics: Metrics::default(),
            h2o,
            kv_layout,
            kv_budget_pages,
            kv_reserved: vec![0; cfg.batch],
            cfg,
        })
    }

    /// Worst-case KV pages a request can grow to (whole prompt + every
    /// generated token resident, before any H2O reclaim).
    fn request_pages(&self, req: &GenRequest, max_seq: usize) -> usize {
        self.kv_layout.worst_case_pages(req.prompt.len() + req.max_new_tokens, max_seq)
    }

    /// Engine-side view of currently resident KV bytes: Σ per-lane
    /// page-granular [`LaneKv::live_bytes`]. Mirrors the backend pool's
    /// gauges without a backend call (the equivalence is property-tested
    /// in `tests/kvpool_props.rs`) — embedders can poll this between
    /// steps. With the prefix cache on this is an *upper bound*: pages
    /// shared between lanes are counted once per holder here, once total
    /// in the pool (read [`Engine::kv_gauges`] for the deduplicated view).
    pub fn kv_resident_bytes(&self) -> usize {
        let (ps, bps) = (self.kv_layout.page_slots, self.kv_layout.bytes_per_slot());
        self.kv.iter().map(|l| l.live_bytes(ps, bps)).sum()
    }

    /// The backend pool's point-in-time gauges (shared pages deduplicated;
    /// the sharded backend sums its workers'). Leak audits poll this after
    /// a drain: `pages_in_use` must return to zero.
    pub fn kv_gauges(&mut self) -> crate::kvpool::KvPoolGauges {
        self.backend.kv_gauges()
    }

    /// Whether this request is eligible for prefix sharing: the feature is
    /// on, H2O eviction is off (skipped prefill queries contribute no
    /// eviction mass, so attaching under H2O would perturb its choices and
    /// break bit-identity with the cold path), the request wants sampled
    /// output rather than full prompt logprobs (`score_only` always serves
    /// cold), and the prompt spans more than one page. Note the one
    /// observable side effect on eligible requests: `prompt_logprobs`
    /// covers only *computed* prompt positions, so attached tokens carry
    /// no teacher-forced entries (generated tokens and their logprobs are
    /// bit-identical either way).
    fn prefix_share_ok(&self, req: &GenRequest) -> bool {
        self.cfg.prefix_cache
            && !self.h2o.enabled()
            && !req.score_only
            && req.prompt.len() > self.kv_layout.page_slots
    }

    /// Build the engine from a backend spec (`spec.build()` + `new`).
    pub fn with_spec(spec: &BackendSpec, cfg: EngineConfig) -> Result<Self> {
        Engine::new(spec.build()?, cfg)
    }

    /// The execution backend this engine drives.
    pub fn backend(&self) -> &dyn ExecBackend {
        self.backend.as_ref()
    }

    /// Shorthand for `backend().model_config()`.
    pub fn model_config(&self) -> &crate::model::config::ModelConfig {
        self.backend.model_config()
    }

    /// Swap the AQUA knobs (takes effect on the next call; no recompile —
    /// with one exception: the AQUA-Memory knob `s_ratio` is a cache
    /// *layout* property, so changing `mem_dims` rebuilds the KV pool and
    /// drops cached context. Sweeps call this between batches, where every
    /// lane is idle, so nothing is lost in practice).
    pub fn with_aqua(&mut self, aqua: AquaConfig) {
        let d = self.backend.model_config().d_head;
        let old_kd = self.cfg.aqua.mem_dims(d);
        self.cfg.aqua = aqua;
        self.h2o = H2oPolicy::new(aqua.h2o_ratio, self.cfg.h2o_recent_window);
        if aqua.mem_dims(d) != old_kd {
            if !self.lanes.is_idle() || !self.queue.is_empty() {
                // Rebuilding would drop in-flight lanes' cached context and
                // zero their budget reservations mid-decode. Keep the old
                // pool: the new knobs still apply as call inputs, and a
                // wider dim_keep against the narrower resident width fails
                // loudly at the next write instead of silently corrupting.
                crate::log_warn!(
                    "with_aqua: memory-knob change with work in flight — kv pool rebuild skipped \
                     (drain the engine first)"
                );
                return;
            }
            self.kv_layout = self.cfg.pool_layout(self.backend.model_config());
            self.kv_budget_pages = budget_pages(self.cfg.kv_budget_mb, &self.kv_layout);
            let pool_cfg = self.cfg.kv_pool_config(&self.kv_layout, self.kv_budget_pages);
            let rebuilt = match self.backend.configure_kv_pool(pool_cfg) {
                Ok(()) => self.backend.empty_cache(self.cfg.batch),
                Err(e) => Err(e),
            };
            if let Err(e) = rebuilt {
                crate::log_warn!("kv pool rebuild after with_aqua failed: {e:#}");
            }
            for kv in &mut self.kv {
                kv.reset();
            }
            self.kv_reserved.iter_mut().for_each(|r| *r = 0);
        }
    }

    pub fn submit(&mut self, req: GenRequest) {
        self.metrics.start_clock();
        self.queue.push(req);
    }

    pub fn take_result(&mut self, id: u64) -> Option<GenResult> {
        self.results.remove(&id)
    }

    /// Convenience: run a whole batch of requests to completion, results in
    /// submission order.
    pub fn run_batch(&mut self, reqs: Vec<GenRequest>) -> Result<Vec<GenResult>> {
        let ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
        for r in reqs {
            self.submit(r);
        }
        self.run_until_idle()?;
        ids.iter()
            .map(|id| {
                self.take_result(*id)
                    .ok_or_else(|| anyhow::anyhow!("request {id} produced no result"))
            })
            .collect()
    }

    pub fn run_until_idle(&mut self) -> Result<()> {
        while self.step()? {}
        Ok(())
    }

    /// One scheduling pass. Returns false when there is nothing to do.
    pub fn step(&mut self) -> Result<bool> {
        self.admit();
        let needs_prefill = (0..self.cfg.batch).any(|l| {
            matches!(&self.active[l], Some(a) if a.prompt_fed < a.req.prompt.len())
        });
        if needs_prefill {
            self.prefill_pass()?;
            return Ok(true);
        }
        if !self.lanes.is_idle() {
            self.decode_pass()?;
            return Ok(true);
        }
        Ok(!self.queue.is_empty())
    }

    // ------------------------------------------------------------- admission

    fn admit(&mut self) {
        let max_seq = self.backend.model_config().max_seq;
        while let Some(lane) = self.lanes.free_lane() {
            let Some(req) = self.queue.pop() else { break };
            // Requests that can never run: longer than the KV capacity, or
            // worst-case page growth beyond the whole page budget — each
            // rejected with its own reason so clients know which knob to
            // turn.
            let need = self.request_pages(&req, max_seq);
            let impossible = if req.prompt.is_empty()
                || req.prompt.len() + req.max_new_tokens > max_seq
            {
                Some(FinishReason::PromptTooLong)
            } else if self.kv_budget_pages.is_some_and(|budget| need > budget) {
                Some(FinishReason::OverKvBudget)
            } else {
                None
            };
            if let Some(finish) = impossible {
                let id = req.id;
                self.results.insert(
                    id,
                    GenResult {
                        id,
                        tokens: vec![],
                        prompt_logprobs: vec![],
                        gen_logprobs: vec![],
                        finish,
                        ttft_us: 0,
                        total_us: 0,
                    },
                );
                continue;
            }
            // Prefix sharing: resolve the longest registered page chain of
            // this prompt before spending prefill compute (or budget). The
            // attach raises page refcounts; if admission defers after all,
            // retire_lane() rolls it back.
            let attach = if self.prefix_share_ok(&req) {
                let knobs =
                    AquaKnobs::from_config(&self.cfg.aqua, self.backend.model_config().d_head);
                match self.backend.attach_prefix(lane, &req.prompt, &knobs) {
                    Ok(a) => a,
                    Err(e) => {
                        crate::log_warn!("attach_prefix failed (serving cold): {e:#}");
                        Default::default()
                    }
                }
            } else {
                Default::default()
            };
            // Memory-aware admission: the FIFO head waits until its
            // worst-case pages fit next to the current occupants' — so a
            // budget-capped pool can never stall mid-decode, for any
            // backend (the sharded workers' per-worker caps are a
            // backstop, this is the global bound). Pages the prefix index
            // provably shares with a *live* holder are already covered by
            // that holder's reservation and are not charged again — a
            // budget-capped pool stops deferring requests that fit;
            // resurrected cached pages are new residency and stay charged.
            if let Some(budget) = self.kv_budget_pages {
                let reserved: usize = self.kv_reserved.iter().sum();
                let attached_pages = attach.tokens / self.kv_layout.page_slots;
                let live_shared = attached_pages - attach.resurrected_pages;
                let charge = need - live_shared;
                if reserved + charge > budget {
                    if attach.tokens > 0 {
                        self.backend.retire_lane(lane);
                    }
                    self.queue.push_front(req);
                    break;
                }
                // the lane's standing reservation is its full worst case:
                // shared pages must stay covered even after their donor
                // retires (the refs this lane holds keep them resident)
                self.kv_reserved[lane] = need;
            }
            self.kv[lane].reset();
            self.lanes.occupy(lane, req.id);
            if attach.tokens > 0 {
                // adopted positions are already written and attendable
                self.kv[lane].commit_write(attach.tokens);
                self.metrics.record_prefix_hits(attach.tokens as u64);
            }
            self.active[lane] = Some(ActiveReq {
                prompt_fed: attach.tokens,
                generated: vec![],
                prompt_logprobs: vec![],
                gen_logprobs: vec![],
                next_pos: attach.tokens,
                pending_token: -1,
                started_at: Instant::now(),
                first_token_at: None,
                req,
            });
        }
    }

    // --------------------------------------------------------------- prefill

    fn prefill_pass(&mut self) -> Result<()> {
        let b = self.cfg.batch;
        let chunk = self.backend.prefill_chunk();
        let (s_cap, d, n_layers, vocab) = {
            let c = self.backend.model_config();
            (c.max_seq, c.d_head, c.n_layers, c.vocab)
        };

        // -1 marks padding / lanes with nothing to feed; backends may skip
        // those positions entirely (the native backend does).
        let mut tokens = vec![-1i32; b * chunk];
        let mut pos0 = vec![0i32; b];
        let mut fed_now = vec![0usize; b];
        for lane in 0..b {
            pos0[lane] = self.kv[lane].len as i32;
            if let Some(a) = &self.active[lane] {
                let remaining = a.req.prompt.len() - a.prompt_fed;
                if remaining > 0 {
                    let n = remaining.min(chunk);
                    tokens[lane * chunk..lane * chunk + n]
                        .copy_from_slice(&a.req.prompt[a.prompt_fed..a.prompt_fed + n]);
                    fed_now[lane] = n;
                }
            }
        }
        let slot_mask = self.flat_mask();
        let knobs = AquaKnobs::from_config(&self.cfg.aqua, d);

        let t0 = Instant::now();
        let out = self.backend.prefill(b, &tokens, &pos0, &slot_mask, &knobs)?;
        let real_tokens: u64 = fed_now.iter().map(|&n| n as u64).sum();
        self.metrics.record_prefill(t0.elapsed(), real_tokens);
        self.metrics.record_kernels(&out.kernels, false);
        self.metrics.record_kv(&out.kv, self.live_slots_total());

        let mut finish_list: Vec<usize> = vec![];
        for lane in 0..b {
            let n = fed_now[lane];
            if n == 0 {
                continue;
            }
            self.kv[lane].commit_write(n);
            // fold this chunk's attention mass (sum over layers)
            let mut mass = vec![0.0f32; s_cap];
            for l in 0..n_layers {
                let base = (l * b + lane) * s_cap;
                for s in 0..s_cap {
                    mass[s] += out.attn_acc[base + s];
                }
            }
            self.kv[lane].accumulate(&mass);
            let evicted = self.h2o.apply(&mut self.kv[lane]) as u64;
            self.metrics.record_evictions(evicted);

            let a = self.active[lane].as_mut().unwrap();
            let fed_before = a.prompt_fed;
            a.prompt_fed += n;
            a.next_pos = self.kv[lane].len;
            // teacher-forced prompt logprobs
            for c in 0..n {
                let target_idx = fed_before + c + 1;
                if target_idx < a.req.prompt.len() {
                    let row = &out.logits[(lane * chunk + c) * vocab..(lane * chunk + c + 1) * vocab];
                    a.prompt_logprobs.push(log_softmax_at(row, a.req.prompt[target_idx] as usize));
                }
            }
            if a.prompt_fed == a.req.prompt.len() {
                // prompt complete: the logits at chunk step n-1 predict the
                // first new token
                let row = &out.logits[(lane * chunk + n - 1) * vocab..(lane * chunk + n) * vocab];
                if a.req.score_only || a.req.max_new_tokens == 0 {
                    finish_list.push(lane);
                } else {
                    let tok = self.cfg.sampler.sample(row, &mut self.rng);
                    a.first_token_at = Some(Instant::now());
                    a.gen_logprobs.push(log_softmax_at(row, tok as usize));
                    a.generated.push(tok);
                    a.pending_token = tok;
                    if self.lane_should_stop(lane) {
                        finish_list.push(lane);
                    }
                }
            }
        }
        for lane in finish_list {
            self.finish_lane(lane, None);
        }
        Ok(())
    }

    // ---------------------------------------------------------------- decode

    fn decode_pass(&mut self) -> Result<()> {
        let b = self.cfg.batch;
        let (s_cap, d, n_layers, vocab) = {
            let c = self.backend.model_config();
            (c.max_seq, c.d_head, c.n_layers, c.vocab)
        };

        // -1 marks dead lanes; backends may skip them entirely.
        let mut tokens = vec![-1i32; b];
        let mut pos = vec![0i32; b];
        let mut live = vec![false; b];
        for lane in 0..b {
            pos[lane] = self.kv[lane].len.min(s_cap - 1) as i32;
            if let Some(a) = &self.active[lane] {
                if a.pending_token >= 0 && !self.kv[lane].is_full() {
                    tokens[lane] = a.pending_token;
                    live[lane] = true;
                }
            }
        }
        if !live.iter().any(|&l| l) {
            // every active lane is blocked (capacity) — finish them
            for lane in 0..b {
                if self.active[lane].is_some() {
                    self.finish_lane(lane, Some(FinishReason::Length));
                }
            }
            return Ok(());
        }

        let slot_mask = self.flat_mask();
        let knobs = AquaKnobs::from_config(&self.cfg.aqua, d);

        let t0 = Instant::now();
        let out = self.backend.decode(b, &tokens, &pos, &slot_mask, &knobs)?;
        self.metrics.record_decode(t0.elapsed(), live.iter().filter(|&&l| l).count() as u64);
        self.metrics.record_kernels(&out.kernels, true);
        self.metrics.record_kv(&out.kv, self.live_slots_total());

        let mut finish_list: Vec<usize> = vec![];
        for lane in 0..b {
            if !live[lane] {
                continue;
            }
            self.kv[lane].commit_write(1);
            let mut mass = vec![0.0f32; s_cap];
            for l in 0..n_layers {
                let base = (l * b + lane) * s_cap;
                for s in 0..s_cap {
                    mass[s] += out.attn_acc[base + s];
                }
            }
            self.kv[lane].accumulate(&mass);
            let evicted = self.h2o.apply(&mut self.kv[lane]) as u64;
            self.metrics.record_evictions(evicted);

            let a = self.active[lane].as_mut().unwrap();
            a.next_pos = self.kv[lane].len;
            let row = &out.logits[lane * vocab..(lane + 1) * vocab];
            let tok = self.cfg.sampler.sample(row, &mut self.rng);
            if a.first_token_at.is_none() {
                a.first_token_at = Some(Instant::now());
            }
            a.gen_logprobs.push(log_softmax_at(row, tok as usize));
            a.generated.push(tok);
            a.pending_token = tok;
            if self.lane_should_stop(lane) {
                finish_list.push(lane);
            }
        }
        for lane in finish_list {
            self.finish_lane(lane, None);
        }
        Ok(())
    }

    // --------------------------------------------------------------- helpers

    /// Currently attendable slots across all lanes (the numerator of the
    /// page-utilization gauge).
    fn live_slots_total(&self) -> u64 {
        self.kv.iter().map(|l| l.live_slots() as u64).sum()
    }

    fn flat_mask(&self) -> Vec<f32> {
        let s = self.backend.model_config().max_seq;
        let mut m = vec![0.0f32; self.cfg.batch * s];
        for (lane, kv) in self.kv.iter().enumerate() {
            m[lane * s..(lane + 1) * s].copy_from_slice(&kv.slot_mask);
        }
        m
    }

    fn lane_should_stop(&self, lane: usize) -> bool {
        let a = self.active[lane].as_ref().unwrap();
        if a.generated.len() >= a.req.max_new_tokens {
            return true;
        }
        if let Some(stop) = a.req.stop_token {
            if a.generated.last() == Some(&stop) {
                return true;
            }
        }
        self.kv[lane].is_full()
    }

    fn finish_lane(&mut self, lane: usize, forced: Option<FinishReason>) {
        let Some(a) = self.active[lane].take() else { return };
        let finish = forced.unwrap_or_else(|| {
            if a.req.stop_token.is_some() && a.generated.last() == a.req.stop_token.as_ref() {
                FinishReason::Stop
            } else {
                FinishReason::Length
            }
        });
        let total = a.started_at.elapsed();
        let ttft = a.first_token_at.map(|t| t.duration_since(a.started_at));
        self.metrics.record_finish(ttft, total);
        self.results.insert(
            a.req.id,
            GenResult {
                id: a.req.id,
                tokens: a.generated,
                prompt_logprobs: a.prompt_logprobs,
                gen_logprobs: a.gen_logprobs,
                finish,
                ttft_us: ttft.map(|t| t.as_micros() as u64).unwrap_or(0),
                total_us: total.as_micros() as u64,
            },
        );
        self.lanes.release(lane);
        self.kv[lane].reset();
        self.kv_reserved[lane] = 0;
        // paged backends return the lane's KV pages to the pool here
        self.backend.retire_lane(lane);
    }
}

// ---------------------------------------------------------------------------
// Threaded front-end handle (for the HTTP server): the engine lives on its
// own thread because the production backend's PJRT handles are !Send.
// ---------------------------------------------------------------------------

pub enum EngineCmd {
    Submit(GenRequest),
    Stats(mpsc::Sender<super::metrics::Snapshot>),
    /// Graceful shutdown: the engine drains queued + in-flight lanes to
    /// completion and flushes every result before its thread exits (the
    /// registry's `DELETE /models/{name}` joins on this). Commands sent
    /// after `Shutdown` are dropped.
    Shutdown,
}

pub struct EngineHandle {
    pub cmd_tx: mpsc::Sender<EngineCmd>,
    pub result_rx: mpsc::Receiver<GenResult>,
    pub join: std::thread::JoinHandle<()>,
}

impl EngineHandle {
    /// Spawn an engine-owning thread. `make_engine` runs *on that thread*
    /// (constructs the backend there — see `BackendRecipe`).
    pub fn spawn<F>(make_engine: F) -> EngineHandle
    where
        F: FnOnce() -> Result<Engine> + Send + 'static,
    {
        let (cmd_tx, cmd_rx) = mpsc::channel::<EngineCmd>();
        let (res_tx, result_rx) = mpsc::channel::<GenResult>();
        let join = std::thread::spawn(move || {
            let mut engine = match make_engine() {
                Ok(e) => e,
                Err(e) => {
                    eprintln!("engine init failed: {e:#}");
                    return;
                }
            };
            let mut done_ids: Vec<u64> = vec![];
            loop {
                // drain commands (non-blocking while busy, blocking when idle)
                loop {
                    let cmd = if engine.lanes.is_idle() && engine.queue.is_empty() {
                        match cmd_rx.recv() {
                            Ok(c) => c,
                            Err(_) => return,
                        }
                    } else {
                        match cmd_rx.try_recv() {
                            Ok(c) => c,
                            Err(mpsc::TryRecvError::Empty) => break,
                            Err(mpsc::TryRecvError::Disconnected) => return,
                        }
                    };
                    match cmd {
                        EngineCmd::Submit(r) => {
                            done_ids.push(r.id);
                            engine.submit(r);
                        }
                        EngineCmd::Stats(tx) => {
                            let _ = tx.send(engine.metrics.snapshot());
                        }
                        EngineCmd::Shutdown => {
                            // drain: finish queued + in-flight work, flush
                            // results, then exit
                            if let Err(e) = engine.run_until_idle() {
                                eprintln!("engine drain failed: {e:#}");
                            }
                            for id in done_ids.drain(..) {
                                if let Some(res) = engine.take_result(id) {
                                    let _ = res_tx.send(res);
                                }
                            }
                            return;
                        }
                    }
                }
                if let Err(e) = engine.step() {
                    eprintln!("engine step failed: {e:#}");
                    return;
                }
                done_ids.retain(|id| {
                    if let Some(res) = engine.take_result(*id) {
                        let _ = res_tx.send(res);
                        false
                    } else {
                        true
                    }
                });
            }
        });
        EngineHandle { cmd_tx, result_rx, join }
    }
}
