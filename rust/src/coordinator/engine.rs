//! The engine: token-budget continuous-batching loop over a pluggable
//! [`ExecBackend`].
//!
//! Single-threaded by design — the production PJRT backend's handles are
//! !Send, so the engine owns its backend and the server front-end talks to
//! it through channels (see `EngineHandle`). One engine run has a fixed
//! [`AquaConfig`] (the knobs are runtime *inputs* to the backend step, so
//! switching configs needs no recompilation — `with_aqua` just changes the
//! scalars fed on the next call). The KV tensors live inside the backend;
//! the engine stays the authority on slot validity via the `slot_mask` it
//! passes on every call.
//!
//! ## Scheduling
//!
//! Each [`Engine::step`] is one scheduling pass. Lanes join (admission)
//! and leave (completion) the running batch on any pass — there are no
//! epoch barriers. With `interleave` on (the default) the scheduler
//! alternates prefill and decode passes whenever both have work — a
//! bounded 1:1 duty cycle — so one long prompt can no longer freeze every
//! decoding lane until its prefill completes. Prefill passes additionally
//! respect `max_batch_prefill_tokens` (whole per-lane chunks, see
//! [`plan_prefill`]), admission respects `max_batch_total_tokens`, and a
//! budget-blocked queue head can be overtaken by admissible smaller
//! requests under waiting-vs-served pressure (bounded by
//! [`super::batcher::MAX_HEAD_OVERTAKES`]).
//!
//! Scheduling never changes *what* a lane computes, only *when*: a lane's
//! prompt is always fed in the same whole `min(remaining, chunk)` slices,
//! lanes not scheduled in a pass ride along as `-1` (dead) positions the
//! backends skip, and every lane's KV/H2O state is per-lane. Greedy
//! outputs are therefore bit-identical to the legacy FIFO path
//! (`interleave: false`), which is kept verbatim for comparison.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::batcher::{AdmissionQueue, LaneTable, Queued};
use super::h2o::H2oPolicy;
use super::kvcache::LaneKv;
use super::metrics::Metrics;
use super::request::{ActiveReq, FinishReason, GenRequest, GenResult, ReqTimings};
use crate::aqua::policy::AquaConfig;
use crate::kvpool::{budget_pages, KvPoolConfig, KvQuant, PoolLayout, DEFAULT_PAGE_SLOTS};
use crate::model::sampling::Sampler;
use crate::runtime::backend::{AquaKnobs, BackendSpec, ExecBackend, LaneError};
use crate::spec::SpecController;
use crate::tensor::softmax::log_softmax_at;
use crate::trace::{TraceMode, TracePhase, TraceRecorder};
use crate::util::prng::Rng;

#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub batch: usize,
    pub aqua: AquaConfig,
    pub h2o_recent_window: usize,
    pub sampler: Sampler,
    pub seed: u64,
    /// Token slots per KV page (see `crate::kvpool`).
    pub kv_page_slots: usize,
    /// KV pool budget in MiB; 0.0 = unlimited (worst-case pool, never
    /// stalls). The registry's admission gate uses the same number so a
    /// lease failure can only mean the gate was bypassed.
    pub kv_budget_mb: f64,
    /// Page-granular prefix sharing: admission consults the backend's
    /// prefix index and attaches matched page chains instead of spending
    /// prefill compute. Invisible to the math (greedy outputs are
    /// bit-identical to the sharing-disabled path), with one carve-out:
    /// the engine only attaches when H2O eviction is off, because skipped
    /// prefill queries contribute no eviction mass and would perturb
    /// H2O's choices. Off by default.
    pub prefix_cache: bool,
    /// Max chains the backend's prefix index registers (0 = unlimited).
    pub prefix_cache_pages: usize,
    /// Resident KV payload element type: `F32` (default, bit-identical to
    /// the pre-quantization pool) or `Int8` (per-page block scales, ~4x
    /// smaller resident pages, decode routed through the fused
    /// dequantizing kernels).
    pub kv_quant: KvQuant,
    /// Per-pass cap on prefill tokens summed across lanes (0 = unlimited).
    /// Lanes are still fed whole `min(remaining, chunk)` slices — the cap
    /// is rounded up to one chunk so a prefill pass always makes progress
    /// — so outputs stay bit-identical to the uncapped path. Only
    /// consulted when `interleave` is on.
    pub max_batch_prefill_tokens: usize,
    /// Admission cap on Σ worst-case tokens (`prompt + max_new_tokens`)
    /// across occupied lanes (0 = unlimited). A head that does not fit
    /// waits, exactly like the KV page budget.
    pub max_batch_total_tokens: usize,
    /// Queue-pressure threshold for admitting past a budget-blocked head:
    /// when `waiting / served >= ratio`, later requests the budgets can
    /// admit may overtake the head (bounded per head — see
    /// `batcher::MAX_HEAD_OVERTAKES`). Only consulted when `interleave`
    /// is on.
    pub waiting_served_ratio: f64,
    /// Alternate prefill and decode passes when both have work (chunked-
    /// prefill duty cycle) and enable the prefill-token budget + pressure
    /// overtakes. `false` reproduces the legacy scheduler exactly:
    /// absolute prefill priority, plain FIFO admission.
    pub interleave: bool,
    /// Fault containment escalation: a backend pass error retires the
    /// affected lane(s) terminally and the loop keeps going, but after
    /// this many *back-to-back* failing passes (no success in between)
    /// `step` returns the error — the supervisor turns that into a Failed
    /// deployment instead of silently spinning. Clamped to ≥ 1.
    pub max_consecutive_step_failures: usize,
    /// Flight-recorder mode (see [`crate::trace`]): `Off` (default, one
    /// relaxed atomic load per would-be event), `Errors` (failure-path
    /// phases only), `Sampled(n)` (1-in-N request timelines), `Full`.
    pub trace: TraceMode,
    /// Self-speculative decoding draft depth (0 = off, byte-identical to
    /// the plain decode path). Each decode turn drafts up to this many
    /// tokens per lane through the configured sparse score path
    /// (`aqua.k_ratio`), then verifies the block in one batched exact
    /// pass over the same KV cache and commits the longest matching
    /// prefix — lossless: outputs are bit-identical to running
    /// `k_ratio = 1.0` with speculation off. Engages only with the
    /// greedy sampler, H2O eviction off, and a verify-capable backend;
    /// otherwise the engine silently falls back to plain decoding (see
    /// [`crate::spec`]).
    pub speculate: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            batch: 4,
            aqua: AquaConfig::default(),
            h2o_recent_window: 16,
            sampler: Sampler::Greedy,
            seed: 0,
            kv_page_slots: DEFAULT_PAGE_SLOTS,
            kv_budget_mb: 0.0,
            prefix_cache: false,
            prefix_cache_pages: 0,
            kv_quant: KvQuant::F32,
            max_batch_prefill_tokens: 0,
            max_batch_total_tokens: 0,
            waiting_served_ratio: 1.2,
            interleave: true,
            max_consecutive_step_failures: 3,
            trace: TraceMode::Off,
            speculate: 0,
        }
    }
}

impl EngineConfig {
    /// The KV pool geometry this config pins for a model — the **single
    /// source** both the engine's pool cap and the registry's admission
    /// gate derive from, so the two can never disagree on page
    /// arithmetic.
    pub fn pool_layout(&self, c: &crate::model::config::ModelConfig) -> PoolLayout {
        PoolLayout {
            page_slots: self.kv_page_slots.clamp(1, c.max_seq),
            key_dims: self.aqua.mem_dims(c.d_head),
            head_dim: c.d_head,
            layers: c.n_layers,
            kv_heads: c.n_kv_heads,
            kv_quant: self.kv_quant,
        }
    }

    /// The pool shape this config pins on its backend (one constructor so
    /// `Engine::new` and the `with_aqua` rebuild can never diverge).
    fn kv_pool_config(&self, layout: &PoolLayout, max_pages: Option<usize>) -> KvPoolConfig {
        KvPoolConfig {
            key_dims: Some(layout.key_dims),
            page_slots: Some(layout.page_slots),
            max_pages,
            prefix_cache: self.prefix_cache,
            prefix_cache_pages: self.prefix_cache_pages,
            kv_quant: self.kv_quant,
        }
    }
}

/// Split one prefill pass's token budget across lanes. `remaining[lane]`
/// is each lane's unfed prompt length; `fed[lane]` receives how many
/// tokens the pass feeds that lane. Invariants (property-tested in
/// `tests/scheduler.rs`):
/// * each lane gets exactly `min(remaining, chunk)` or `0` — never a
///   partial slice, so per-lane chunk boundaries (and thus H2O mass
///   grouping and logits) are identical whether or not a budget defers
///   the lane to a later pass;
/// * the planned total never exceeds `max(budget, chunk)` (`budget == 0`
///   means unlimited); the single-chunk floor guarantees progress;
/// * earlier lanes win ties, so planning is deterministic.
///
/// Returns the planned total.
pub fn plan_prefill(remaining: &[usize], chunk: usize, budget: usize, fed: &mut [usize]) -> usize {
    debug_assert_eq!(remaining.len(), fed.len());
    let chunk = chunk.max(1);
    let budget = if budget == 0 { usize::MAX } else { budget.max(chunk) };
    let mut used = 0usize;
    for (lane, &rem) in remaining.iter().enumerate() {
        fed[lane] = 0;
        if rem == 0 {
            continue;
        }
        let n = rem.min(chunk);
        if used + n <= budget {
            fed[lane] = n;
            used += n;
        }
    }
    used
}

/// Per-pass scratch buffers, allocated once at engine construction so the
/// steady-state prefill/decode loop performs no heap allocation (asserted
/// by the `interleave` bench's counting allocator).
struct StepScratch {
    /// [B, chunk] prefill / [B] decode token ids (-1 = dead position).
    tokens: Vec<i32>,
    /// Per-lane write positions.
    pos: Vec<i32>,
    /// Per-lane unfed prompt tokens (prefill planning input).
    remaining: Vec<usize>,
    /// Per-lane tokens fed this prefill pass (planning output).
    fed_now: Vec<usize>,
    /// Per-lane decode liveness.
    live: Vec<bool>,
    /// [B, S] attendable-slot mask fed to the backend.
    slot_mask: Vec<f32>,
    /// [S] per-lane attention-mass fold (reused across lanes).
    mass: Vec<f32>,
    /// Inter-token gaps observed this decode pass, µs.
    itl_us: Vec<u64>,
}

impl StepScratch {
    fn new(batch: usize, chunk: usize, s_cap: usize) -> Self {
        StepScratch {
            tokens: Vec::with_capacity(batch * chunk.max(1)),
            pos: Vec::with_capacity(batch),
            remaining: Vec::with_capacity(batch),
            fed_now: Vec::with_capacity(batch),
            live: Vec::with_capacity(batch),
            slot_mask: Vec::with_capacity(batch * s_cap),
            mass: Vec::with_capacity(s_cap),
            // a speculative cycle commits bursts of up to `chunk`
            // (= speculate + 1) tokens per lane in one pass
            itl_us: Vec::with_capacity(batch * chunk.max(1)),
        }
    }
}

/// What `try_admit` did with a popped queue entry.
enum AdmitOutcome {
    /// The entry left the queue for good: it occupies a lane now, or it
    /// was terminally rejected with a result. Admission keeps going.
    Placed,
    /// A budget says not yet — the entry went back to the queue head with
    /// its wait clock intact.
    Deferred,
}

pub struct Engine {
    backend: Box<dyn ExecBackend>,
    pub cfg: EngineConfig,
    queue: AdmissionQueue,
    lanes: LaneTable,
    active: Vec<Option<ActiveReq>>,
    kv: Vec<LaneKv>,
    results: HashMap<u64, GenResult>,
    rng: Rng,
    /// Shared so the supervisor can hand every engine incarnation the
    /// *same* accumulator — counters survive restarts and the outcome
    /// reconciliation (`done == served + rejected + cancelled + expired +
    /// failed`) holds across engine rebuilds.
    pub metrics: Arc<Metrics>,
    /// Flight recorder — shared across supervised incarnations exactly
    /// like `metrics`, so a postmortem taken after a panic still holds
    /// the events leading up to it.
    pub trace: Arc<TraceRecorder>,
    h2o: H2oPolicy,
    /// Resolved KV pool geometry (mirrors the backend's pool).
    kv_layout: PoolLayout,
    /// Page budget from `kv_budget_mb` (None = unlimited). Enforced at
    /// *admission*: a request only occupies a lane once its worst-case
    /// page growth fits next to the other occupants', so the pool cap can
    /// never stall mid-decode — for any backend, sharded included.
    kv_budget_pages: Option<usize>,
    /// Worst-case pages reserved per occupied lane.
    kv_reserved: Vec<usize>,
    /// Reusable per-pass buffers (no steady-state allocation).
    scratch: StepScratch,
    /// Score-path knobs derived from `cfg.aqua` (rebuilt by `with_aqua`;
    /// cached so the steady-state loop never re-allocates `dim_keep`).
    knobs: AquaKnobs,
    /// Exact-read knobs: `k_ratio = 1.0` over the resident key width.
    /// The verify pass's score path — and, when speculation is on, the
    /// prefill/attach knobs too (KV content depends on read knobs
    /// through layer stacking, so the whole non-draft path runs
    /// exact-read to keep committed outputs bit-identical to the
    /// `k_ratio = 1.0`, `speculate = 0` baseline).
    xknobs: AquaKnobs,
    /// Speculation engaged this run: `speculate > 0`, greedy sampler,
    /// H2O off, verify-capable backend. Re-evaluated by `with_aqua`.
    spec_on: bool,
    /// Draft bookkeeping (`Some` iff `cfg.speculate > 0`).
    spec: Option<SpecController>,
    /// Duty-cycle state: what the previous pass ran (drives the 1:1
    /// prefill/decode alternation when both have work).
    last_pass_was_prefill: bool,
    /// Back-to-back failing passes (reset by any successful pass) — the
    /// `max_consecutive_step_failures` escalation counter.
    consecutive_failures: usize,
}

impl Engine {
    pub fn new(mut backend: Box<dyn ExecBackend>, cfg: EngineConfig) -> Result<Self> {
        if cfg.batch == 0 {
            bail!("batch must be >= 1");
        }
        let kv_layout = cfg.pool_layout(backend.model_config());
        let kv_budget_pages = budget_pages(cfg.kv_budget_mb, &kv_layout);
        backend.configure_kv_pool(cfg.kv_pool_config(&kv_layout, kv_budget_pages))?;
        backend.empty_cache(cfg.batch)?;
        let cap = backend.model_config().max_seq;
        let chunk = backend.prefill_chunk();
        let h2o = H2oPolicy::new(cfg.aqua.h2o_ratio, cfg.h2o_recent_window);
        let d = backend.model_config().d_head;
        let knobs = AquaKnobs::from_config(&cfg.aqua, d);
        let xknobs = AquaKnobs::from_config(&AquaConfig { k_ratio: 1.0, ..cfg.aqua }, d);
        let spec_on = cfg.speculate > 0
            && !h2o.enabled()
            && matches!(cfg.sampler, Sampler::Greedy)
            && backend.supports_verify();
        let spec =
            if cfg.speculate > 0 { Some(SpecController::new(cfg.batch, cfg.speculate)) } else { None };
        Ok(Engine {
            backend,
            queue: AdmissionQueue::default(),
            lanes: LaneTable::new(cfg.batch),
            active: (0..cfg.batch).map(|_| None).collect(),
            kv: (0..cfg.batch).map(|_| LaneKv::new(cap)).collect(),
            results: HashMap::new(),
            rng: Rng::new(cfg.seed ^ 0xE17),
            metrics: Arc::new(Metrics::default()),
            trace: Arc::new(TraceRecorder::new(cfg.trace)),
            h2o,
            kv_layout,
            kv_budget_pages,
            kv_reserved: vec![0; cfg.batch],
            // the verify window is up to `speculate + 1` tokens wide, so
            // the token scratch must cover it allocation-free
            scratch: StepScratch::new(cfg.batch, chunk.max(cfg.speculate + 1), cap),
            knobs,
            xknobs,
            spec_on,
            spec,
            last_pass_was_prefill: false,
            consecutive_failures: 0,
            cfg,
        })
    }

    /// Worst-case KV pages a request can grow to (whole prompt + every
    /// generated token resident, before any H2O reclaim).
    fn request_pages(&self, req: &GenRequest, max_seq: usize) -> usize {
        self.kv_layout.worst_case_pages(req.prompt.len() + req.max_new_tokens, max_seq)
    }

    /// Engine-side view of currently resident KV bytes: Σ per-lane
    /// page-granular [`LaneKv::live_bytes`]. Mirrors the backend pool's
    /// gauges without a backend call (the equivalence is property-tested
    /// in `tests/kvpool_props.rs`) — embedders can poll this between
    /// steps. With the prefix cache on this is an *upper bound*: pages
    /// shared between lanes are counted once per holder here, once total
    /// in the pool (read [`Engine::kv_gauges`] for the deduplicated view).
    pub fn kv_resident_bytes(&self) -> usize {
        let (ps, bps) = (self.kv_layout.page_slots, self.kv_layout.bytes_per_slot());
        self.kv.iter().map(|l| l.live_bytes(ps, bps)).sum()
    }

    /// The backend pool's point-in-time gauges (shared pages deduplicated;
    /// the sharded backend sums its workers'). Leak audits poll this after
    /// a drain: `pages_in_use` must return to zero.
    pub fn kv_gauges(&mut self) -> crate::kvpool::KvPoolGauges {
        self.backend.kv_gauges()
    }

    /// Whether this request is eligible for prefix sharing: the feature is
    /// on, H2O eviction is off (skipped prefill queries contribute no
    /// eviction mass, so attaching under H2O would perturb its choices and
    /// break bit-identity with the cold path), the request wants sampled
    /// output rather than full prompt logprobs (`score_only` always serves
    /// cold), and the prompt spans more than one page. Note the one
    /// observable side effect on eligible requests: `prompt_logprobs`
    /// covers only *computed* prompt positions, so attached tokens carry
    /// no teacher-forced entries (generated tokens and their logprobs are
    /// bit-identical either way).
    fn prefix_share_ok(&self, req: &GenRequest) -> bool {
        self.cfg.prefix_cache
            && !self.h2o.enabled()
            && !req.score_only
            && req.prompt.len() > self.kv_layout.page_slots
    }

    /// Build the engine from a backend spec (`spec.build()` + `new`).
    pub fn with_spec(spec: &BackendSpec, cfg: EngineConfig) -> Result<Self> {
        Engine::new(spec.build()?, cfg)
    }

    /// The execution backend this engine drives.
    pub fn backend(&self) -> &dyn ExecBackend {
        self.backend.as_ref()
    }

    /// Shorthand for `backend().model_config()`.
    pub fn model_config(&self) -> &crate::model::config::ModelConfig {
        self.backend.model_config()
    }

    /// Swap the AQUA knobs (takes effect on the next call; no recompile —
    /// with one exception: the AQUA-Memory knob `s_ratio` is a cache
    /// *layout* property, so changing `mem_dims` rebuilds the KV pool and
    /// drops cached context. Sweeps call this between batches, where every
    /// lane is idle, so nothing is lost in practice).
    pub fn with_aqua(&mut self, aqua: AquaConfig) {
        let d = self.backend.model_config().d_head;
        let old_kd = self.cfg.aqua.mem_dims(d);
        self.cfg.aqua = aqua;
        self.h2o = H2oPolicy::new(aqua.h2o_ratio, self.cfg.h2o_recent_window);
        self.knobs = AquaKnobs::from_config(&self.cfg.aqua, d);
        self.xknobs = AquaKnobs::from_config(&AquaConfig { k_ratio: 1.0, ..self.cfg.aqua }, d);
        // knob swaps can flip H2O on/off, which gates speculation
        self.spec_on = self.cfg.speculate > 0
            && !self.h2o.enabled()
            && matches!(self.cfg.sampler, Sampler::Greedy)
            && self.backend.supports_verify();
        if aqua.mem_dims(d) != old_kd {
            if !self.lanes.is_idle() || !self.queue.is_empty() {
                // Rebuilding would drop in-flight lanes' cached context and
                // zero their budget reservations mid-decode. Keep the old
                // pool: the new knobs still apply as call inputs, and a
                // wider dim_keep against the narrower resident width fails
                // loudly at the next write instead of silently corrupting.
                crate::log_warn!(
                    "with_aqua: memory-knob change with work in flight — kv pool rebuild skipped \
                     (drain the engine first)"
                );
                return;
            }
            self.kv_layout = self.cfg.pool_layout(self.backend.model_config());
            self.kv_budget_pages = budget_pages(self.cfg.kv_budget_mb, &self.kv_layout);
            let pool_cfg = self.cfg.kv_pool_config(&self.kv_layout, self.kv_budget_pages);
            let rebuilt = match self.backend.configure_kv_pool(pool_cfg) {
                Ok(()) => self.backend.empty_cache(self.cfg.batch),
                Err(e) => Err(e),
            };
            if let Err(e) = rebuilt {
                crate::log_warn!("kv pool rebuild after with_aqua failed: {e:#}");
            }
            for kv in &mut self.kv {
                kv.reset();
            }
            self.kv_reserved.iter_mut().for_each(|r| *r = 0);
        }
    }

    /// Enqueue a request. Returns `false` (and records a rejected
    /// submission) when `req.id` is already queued, running, or holds an
    /// unclaimed result — admitting it would silently overwrite that
    /// state, so duplicates are refused at the door and the caller owns
    /// reporting (see `run_batch` / `EngineHandle`).
    #[must_use = "a false return means the request was rejected as a duplicate id"]
    pub fn submit(&mut self, req: GenRequest) -> bool {
        if self.queue.contains(req.id)
            || self.lanes.contains(req.id)
            || self.results.contains_key(&req.id)
        {
            self.metrics.record_rejected();
            return false;
        }
        self.metrics.start_clock();
        self.trace.record(TracePhase::Enqueue, req.id, -1, req.prompt.len() as u64);
        self.queue.push(req);
        true
    }

    pub fn take_result(&mut self, id: u64) -> Option<GenResult> {
        self.results.remove(&id)
    }

    /// Convenience: run a whole batch of requests to completion, results in
    /// submission order. Duplicate-id submissions resolve to a
    /// [`FinishReason::DuplicateId`] result (the first submission of the
    /// id keeps the real one).
    pub fn run_batch(&mut self, reqs: Vec<GenRequest>) -> Result<Vec<GenResult>> {
        let ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
        let mut dups: Vec<u64> = vec![];
        for r in reqs {
            let id = r.id;
            if !self.submit(r) {
                dups.push(id);
            }
        }
        self.run_until_idle()?;
        ids.iter()
            .map(|id| {
                if let Some(res) = self.take_result(*id) {
                    return Ok(res);
                }
                if dups.contains(id) {
                    return Ok(GenResult {
                        id: *id,
                        tokens: vec![],
                        prompt_logprobs: vec![],
                        gen_logprobs: vec![],
                        finish: FinishReason::DuplicateId,
                        ttft_us: 0,
                        total_us: 0,
                        timings: ReqTimings::default(),
                    });
                }
                Err(anyhow::anyhow!("request {id} produced no result"))
            })
            .collect()
    }

    pub fn run_until_idle(&mut self) -> Result<()> {
        while self.step()? {}
        Ok(())
    }

    /// One scheduling pass. Returns false when there is nothing to do.
    ///
    /// An `Err` here means the engine is *failing*, not one request: pass
    /// errors are contained per-lane (see [`Engine::contain`]) and only
    /// escalate after `max_consecutive_step_failures` back-to-back
    /// failures. The supervisor treats the error as fatal for this engine
    /// incarnation.
    pub fn step(&mut self) -> Result<bool> {
        self.sweep_deadlines();
        self.admit();
        let mut want_prefill = false;
        let mut want_decode = false;
        for a in self.active.iter().flatten() {
            if a.prompt_fed < a.req.prompt.len() {
                want_prefill = true;
            } else {
                want_decode = true;
            }
        }
        // Duty cycle: with work on both sides, alternate passes so one
        // long prefill can no longer freeze every decoding lane. Legacy
        // mode (`interleave: false`) keeps absolute prefill priority.
        let run_prefill =
            want_prefill && (!self.cfg.interleave || !want_decode || !self.last_pass_was_prefill);
        if run_prefill {
            self.metrics.record_step(self.lanes.occupied() as u64, self.cfg.batch as u64);
            let pass = self.prefill_pass();
            self.last_pass_was_prefill = true;
            self.contain(pass, true)?;
            return Ok(true);
        }
        if !self.lanes.is_idle() {
            self.metrics.record_step(self.lanes.occupied() as u64, self.cfg.batch as u64);
            let pass = if self.spec_on { self.spec_pass() } else { self.decode_pass() };
            self.last_pass_was_prefill = false;
            self.contain(pass, false)?;
            return Ok(true);
        }
        Ok(!self.queue.is_empty())
    }

    /// Fault containment. A failed pass had no side effects on the
    /// engine's per-lane state (commits happen only after a successful
    /// backend call, and the [`LaneError`] contract forbids backend-side
    /// mutation on attributed failures), so recovery is: retire the
    /// blamed lane — or, unattributed, every lane scheduled in the
    /// failing pass — with terminal [`FinishReason::BackendError`]
    /// results, release their KV pages, and keep the loop running. The
    /// re-run pass recomputes the surviving lanes identically (greedy
    /// sampling consumes no RNG), so their outputs stay bit-identical to
    /// a fault-free run.
    fn contain(&mut self, pass: Result<()>, was_prefill: bool) -> Result<()> {
        let err = match pass {
            Ok(()) => {
                self.consecutive_failures = 0;
                return Ok(());
            }
            Err(e) => e,
        };
        self.consecutive_failures += 1;
        if self.consecutive_failures >= self.cfg.max_consecutive_step_failures.max(1) {
            self.trace.record(TracePhase::Escalate, 0, -1, self.consecutive_failures as u64);
            return Err(err.context(format!(
                "engine failing: {} consecutive step failures",
                self.consecutive_failures
            )));
        }
        let blamed = err.downcast_ref::<LaneError>().map(|l| l.0);
        crate::log_warn!("backend step failed (contained): {err:#}");
        let mut failed_lanes: Vec<usize> = vec![];
        for lane in 0..self.cfg.batch {
            if self.active[lane].is_none() {
                continue;
            }
            let hit = match blamed {
                Some(b) => lane == b,
                // no attribution: every lane scheduled in the failing
                // pass is suspect (the scratch plan still describes it)
                None => {
                    if was_prefill {
                        self.scratch.fed_now.get(lane).is_some_and(|&n| n > 0)
                    } else {
                        self.scratch.live.get(lane).copied().unwrap_or(false)
                    }
                }
            };
            if hit {
                failed_lanes.push(lane);
            }
        }
        for &lane in &failed_lanes {
            let rid = self.active[lane].as_ref().map(|a| a.req.id).unwrap_or(0);
            self.trace.record(
                TracePhase::LaneFailure,
                rid,
                lane as i32,
                self.consecutive_failures as u64,
            );
            self.finish_lane(lane, Some(FinishReason::BackendError));
        }
        if !failed_lanes.is_empty() {
            // Freeze the faulted lane's trailing timeline while it is
            // still in the ring — the after-the-fact artifact `GET
            // /trace/postmortem` serves.
            let blamed = if failed_lanes.len() == 1 { failed_lanes[0] as i32 } else { -1 };
            self.trace.snapshot_postmortem(&format!("lane failure (contained): {err:#}"), blamed);
            crate::log_error!(
                "lane failure contained (blamed lane {blamed}, postmortem captured): {err:#}"
            );
        }
        Ok(())
    }

    /// Enforce per-request deadlines: queued requests whose `deadline_ms`
    /// elapsed resolve terminally without running; active lanes past
    /// theirs finish with their partial tokens and release lane + KV
    /// pages immediately. Runs at the top of every scheduling pass.
    fn sweep_deadlines(&mut self) {
        let expired = self.queue.drain_matching(|e| {
            e.req.deadline_ms > 0
                && e.enqueued_at.elapsed().as_millis() as u64 >= e.req.deadline_ms
        });
        for e in expired {
            self.metrics.record_queue_wait(e.enqueued_at.elapsed());
            self.finish_unrun(e.req.id, FinishReason::DeadlineExpired);
        }
        for lane in 0..self.cfg.batch {
            let hit = matches!(&self.active[lane], Some(a) if a.req.deadline_ms > 0
                && a.enqueued_at.elapsed().as_millis() as u64 >= a.req.deadline_ms);
            if hit {
                self.finish_lane(lane, Some(FinishReason::DeadlineExpired));
            }
        }
    }

    /// Cancel a request wherever it is. A queued entry resolves
    /// terminally without running; an active lane finishes with its
    /// partial tokens and releases its lane + KV pages immediately (the
    /// capacity point of cancellation under a `kv_budget_mb` cap).
    /// Returns `false` when the id is unknown — including already
    /// finished, where the existing result stands.
    pub fn cancel(&mut self, id: u64) -> bool {
        for lane in 0..self.cfg.batch {
            if self.lanes.occupant(lane) == Some(id) {
                self.finish_lane(lane, Some(FinishReason::Cancelled));
                return true;
            }
        }
        let removed = self.queue.drain_matching(|e| e.req.id == id);
        if removed.is_empty() {
            return false;
        }
        for e in removed {
            self.metrics.record_queue_wait(e.enqueued_at.elapsed());
            self.finish_unrun(e.req.id, FinishReason::Cancelled);
        }
        true
    }

    /// Terminal result for a request that never occupied a lane
    /// (queue-side cancel/expiry; admission rejects go through the same
    /// shape in `try_admit`), with the matching outcome counter.
    fn finish_unrun(&mut self, id: u64, finish: FinishReason) {
        match finish {
            FinishReason::Cancelled => self.metrics.record_cancelled(false),
            FinishReason::DeadlineExpired => self.metrics.record_expired(false),
            _ => self.metrics.record_rejected(),
        }
        self.trace.record(TracePhase::Retire, id, -1, finish.code());
        self.results.insert(
            id,
            GenResult {
                id,
                tokens: vec![],
                prompt_logprobs: vec![],
                gen_logprobs: vec![],
                finish,
                ttft_us: 0,
                total_us: 0,
                timings: ReqTimings::default(),
            },
        );
    }

    // ------------------------------------------------------------- admission

    /// Σ worst-case tokens (`prompt + max_new`) across occupied lanes —
    /// the `max_batch_total_tokens` accounting basis.
    fn active_worst_case_tokens(&self) -> usize {
        self.active
            .iter()
            .flatten()
            .map(|a| a.req.prompt.len() + a.req.max_new_tokens)
            .sum()
    }

    /// Waiting-vs-served pressure: enough requests queued per occupied
    /// lane that a blocked head should not also block admissible work.
    fn under_pressure(&self) -> bool {
        self.queue.len() as f64 / self.lanes.occupied().max(1) as f64
            >= self.cfg.waiting_served_ratio
    }

    fn admit(&mut self) {
        let max_seq = self.backend.model_config().max_seq;
        loop {
            let Some(lane) = self.lanes.free_lane() else { break };
            let Some(entry) = self.queue.pop_front() else { break };
            match self.try_admit(lane, entry, max_seq) {
                AdmitOutcome::Placed => continue,
                AdmitOutcome::Deferred => {
                    // The head can't run yet (it is back at the front,
                    // wait clock intact). Under queue pressure, look past
                    // it for work the budgets can admit right now —
                    // bounded per head so it is never starved.
                    if !self.cfg.interleave || !self.under_pressure() {
                        break;
                    }
                    // Conservative fit check: full worst-case charge, no
                    // prefix-share discount — anything it accepts,
                    // `try_admit` must accept too. Impossible requests
                    // "fit" so they get rejected promptly instead of
                    // clogging the queue behind the head.
                    let reserved: usize = self.kv_reserved.iter().sum();
                    let budget = self.kv_budget_pages;
                    let layout = self.kv_layout;
                    let active_tokens = self.active_worst_case_tokens();
                    let total_cap = self.cfg.max_batch_total_tokens;
                    let fits = move |r: &GenRequest| {
                        let want = r.prompt.len() + r.max_new_tokens;
                        if r.prompt.is_empty() || want > max_seq {
                            return true; // impossible: admit to reject
                        }
                        if total_cap > 0 && want > total_cap {
                            return true; // impossible at any occupancy
                        }
                        let need = layout.worst_case_pages(want, max_seq);
                        if let Some(b) = budget {
                            if need > b {
                                return true; // impossible at any occupancy
                            }
                            if reserved + need > b {
                                return false;
                            }
                        }
                        total_cap == 0 || active_tokens + want <= total_cap
                    };
                    let Some(entry) = self.queue.pop_past_head(fits) else { break };
                    self.trace.record(
                        TracePhase::Overtake,
                        entry.req.id,
                        -1,
                        self.queue.len() as u64,
                    );
                    match self.try_admit(lane, entry, max_seq) {
                        AdmitOutcome::Placed => continue,
                        // unreachable (`fits` is strictly conservative),
                        // but if it ever happens the entry is requeued,
                        // not dropped
                        AdmitOutcome::Deferred => break,
                    }
                }
            }
        }
    }

    /// Place one popped queue entry: terminal-reject, defer (budgets), or
    /// occupy `lane`.
    fn try_admit(&mut self, lane: usize, entry: Queued, max_seq: usize) -> AdmitOutcome {
        // Deadline gate at admission: an entry that expired while queued
        // resolves terminally instead of occupying a lane.
        if entry.req.deadline_ms > 0
            && entry.enqueued_at.elapsed().as_millis() as u64 >= entry.req.deadline_ms
        {
            self.metrics.record_queue_wait(entry.enqueued_at.elapsed());
            self.finish_unrun(entry.req.id, FinishReason::DeadlineExpired);
            return AdmitOutcome::Placed;
        }
        // Requests that can never run: longer than the KV capacity, or
        // worst-case page growth beyond the whole page budget — each
        // rejected with its own reason so clients know which knob to
        // turn.
        let need = self.request_pages(&entry.req, max_seq);
        let want = entry.req.prompt.len() + entry.req.max_new_tokens;
        let impossible = if entry.req.prompt.is_empty() || want > max_seq {
            Some(FinishReason::PromptTooLong)
        } else if self.kv_budget_pages.is_some_and(|budget| need > budget)
            || (self.cfg.max_batch_total_tokens > 0 && want > self.cfg.max_batch_total_tokens)
        {
            // can never be admitted at this budget, even alone — deferring
            // would wedge the queue behind it forever
            Some(FinishReason::OverKvBudget)
        } else {
            None
        };
        if let Some(finish) = impossible {
            self.metrics.record_queue_wait(entry.enqueued_at.elapsed());
            self.finish_unrun(entry.req.id, finish);
            return AdmitOutcome::Placed;
        }
        // Batch token budget: the occupants' summed worst-case token
        // growth stays under `max_batch_total_tokens`.
        if self.cfg.max_batch_total_tokens > 0 {
            if self.active_worst_case_tokens() + want > self.cfg.max_batch_total_tokens {
                self.trace.record(TracePhase::Defer, entry.req.id, -1, 0);
                self.queue.requeue_front(entry);
                return AdmitOutcome::Deferred;
            }
        }
        // Prefix sharing: resolve the longest registered page chain of
        // this prompt before spending prefill compute (or budget). The
        // attach raises page refcounts; if admission defers after all,
        // retire_lane() rolls it back.
        let attach = if self.prefix_share_ok(&entry.req) {
            // under speculation the whole committed path (prefill, attach,
            // verify) runs exact-read, so cached chains must match
            let knobs = if self.spec_on { &self.xknobs } else { &self.knobs };
            match self.backend.attach_prefix(lane, &entry.req.prompt, knobs) {
                Ok(a) => a,
                Err(e) => {
                    crate::log_warn!("attach_prefix failed (serving cold): {e:#}");
                    Default::default()
                }
            }
        } else {
            Default::default()
        };
        // Memory-aware admission: the request waits until its worst-case
        // pages fit next to the current occupants' — so a budget-capped
        // pool can never stall mid-decode, for any backend (the sharded
        // workers' per-worker caps are a backstop, this is the global
        // bound). Pages the prefix index provably shares with a *live*
        // holder are already covered by that holder's reservation and are
        // not charged again — a budget-capped pool stops deferring
        // requests that fit; resurrected cached pages are new residency
        // and stay charged.
        if let Some(budget) = self.kv_budget_pages {
            let reserved: usize = self.kv_reserved.iter().sum();
            let attached_pages = attach.tokens / self.kv_layout.page_slots;
            let live_shared = attached_pages - attach.resurrected_pages;
            let charge = need - live_shared;
            if reserved + charge > budget {
                if attach.tokens > 0 {
                    self.backend.retire_lane(lane);
                }
                self.trace.record(TracePhase::Defer, entry.req.id, -1, 1);
                self.queue.requeue_front(entry);
                return AdmitOutcome::Deferred;
            }
            // the lane's standing reservation is its full worst case:
            // shared pages must stay covered even after their donor
            // retires (the refs this lane holds keep them resident)
            self.kv_reserved[lane] = need;
        }
        self.metrics.record_queue_wait(entry.enqueued_at.elapsed());
        let req = entry.req;
        self.kv[lane].reset();
        self.lanes.occupy(lane, req.id);
        if attach.tokens > 0 {
            // adopted positions are already written and attendable
            self.kv[lane].commit_write(attach.tokens);
            self.metrics.record_prefix_hits(attach.tokens as u64);
            self.trace.record(TracePhase::PrefixAttach, req.id, lane as i32, attach.tokens as u64);
        }
        self.trace.record(
            TracePhase::Admit,
            req.id,
            lane as i32,
            (req.prompt.len() - attach.tokens) as u64,
        );
        self.active[lane] = Some(ActiveReq {
            prompt_fed: attach.tokens,
            generated: Vec::with_capacity(req.max_new_tokens),
            prompt_logprobs: Vec::with_capacity(req.prompt.len().saturating_sub(1)),
            gen_logprobs: Vec::with_capacity(req.max_new_tokens),
            next_pos: attach.tokens,
            prefix_hit_tokens: attach.tokens,
            pending_token: -1,
            enqueued_at: entry.enqueued_at,
            started_at: Instant::now(),
            first_token_at: None,
            last_token_at: None,
            req,
        });
        AdmitOutcome::Placed
    }

    // --------------------------------------------------------------- prefill

    fn prefill_pass(&mut self) -> Result<()> {
        let b = self.cfg.batch;
        let chunk = self.backend.prefill_chunk();
        let (s_cap, n_layers, vocab) = {
            let c = self.backend.model_config();
            (c.max_seq, c.n_layers, c.vocab)
        };

        // Plan the pass: whole per-lane chunks under the token budget
        // (unlimited in legacy mode — every lane with prompt left runs).
        self.scratch.remaining.clear();
        self.scratch.remaining.resize(b, 0);
        for lane in 0..b {
            if let Some(a) = &self.active[lane] {
                self.scratch.remaining[lane] = a.req.prompt.len() - a.prompt_fed;
            }
        }
        self.scratch.fed_now.clear();
        self.scratch.fed_now.resize(b, 0);
        let budget = if self.cfg.interleave { self.cfg.max_batch_prefill_tokens } else { 0 };
        plan_prefill(&self.scratch.remaining, chunk, budget, &mut self.scratch.fed_now);

        // -1 marks padding / lanes with nothing to feed; backends may skip
        // those positions entirely (the native backend does).
        self.scratch.tokens.clear();
        self.scratch.tokens.resize(b * chunk, -1);
        self.scratch.pos.clear();
        self.scratch.pos.resize(b, 0);
        for lane in 0..b {
            self.scratch.pos[lane] = self.kv[lane].len as i32;
            let n = self.scratch.fed_now[lane];
            if n > 0 {
                let a = self.active[lane].as_ref().unwrap();
                self.scratch.tokens[lane * chunk..lane * chunk + n]
                    .copy_from_slice(&a.req.prompt[a.prompt_fed..a.prompt_fed + n]);
            }
        }
        self.fill_mask();
        let knobs = if self.spec_on { &self.xknobs } else { &self.knobs };

        let t0 = Instant::now();
        let out = self.backend.prefill(
            b,
            &self.scratch.tokens,
            &self.scratch.pos,
            &self.scratch.slot_mask,
            knobs,
        )?;
        let real_tokens: u64 = self.scratch.fed_now.iter().map(|&n| n as u64).sum();
        self.metrics.record_prefill(t0.elapsed(), real_tokens);
        self.metrics.record_kernels(&out.kernels, false);
        self.metrics.record_kv(&out.kv, self.live_slots_total());
        self.trace.record(
            TracePhase::Score,
            0,
            out.kernels.dominant_mode() as i32,
            out.kernels.score_ns,
        );
        for lane in 0..b {
            let n = self.scratch.fed_now[lane];
            if n > 0 {
                let rid = self.active[lane].as_ref().map(|a| a.req.id).unwrap_or(0);
                self.trace.record(TracePhase::PrefillChunk, rid, lane as i32, n as u64);
            }
        }

        let mut finish_list: Vec<usize> = vec![];
        for lane in 0..b {
            let n = self.scratch.fed_now[lane];
            if n == 0 {
                continue;
            }
            self.kv[lane].commit_write(n);
            // fold this chunk's attention mass (sum over layers)
            self.scratch.mass.clear();
            self.scratch.mass.resize(s_cap, 0.0);
            for l in 0..n_layers {
                let base = (l * b + lane) * s_cap;
                for s in 0..s_cap {
                    self.scratch.mass[s] += out.attn_acc[base + s];
                }
            }
            self.kv[lane].accumulate(&self.scratch.mass);
            let evicted = self.h2o.apply(&mut self.kv[lane]) as u64;
            self.metrics.record_evictions(evicted);

            let a = self.active[lane].as_mut().unwrap();
            let fed_before = a.prompt_fed;
            a.prompt_fed += n;
            a.next_pos = self.kv[lane].len;
            // teacher-forced prompt logprobs
            for c in 0..n {
                let target_idx = fed_before + c + 1;
                if target_idx < a.req.prompt.len() {
                    let row =
                        &out.logits[(lane * chunk + c) * vocab..(lane * chunk + c + 1) * vocab];
                    a.prompt_logprobs.push(log_softmax_at(row, a.req.prompt[target_idx] as usize));
                }
            }
            if a.prompt_fed == a.req.prompt.len() {
                // prompt complete: the logits at chunk step n-1 predict the
                // first new token
                let row = &out.logits[(lane * chunk + n - 1) * vocab..(lane * chunk + n) * vocab];
                if a.req.score_only || a.req.max_new_tokens == 0 {
                    finish_list.push(lane);
                } else {
                    let tok = self.cfg.sampler.sample(row, &mut self.rng);
                    let now = Instant::now();
                    a.first_token_at = Some(now);
                    a.last_token_at = Some(now);
                    a.gen_logprobs.push(log_softmax_at(row, tok as usize));
                    a.generated.push(tok);
                    a.pending_token = tok;
                    if self.lane_should_stop(lane) {
                        finish_list.push(lane);
                    }
                }
            }
        }
        for lane in finish_list {
            self.finish_lane(lane, None);
        }
        Ok(())
    }

    // ---------------------------------------------------------------- decode

    fn decode_pass(&mut self) -> Result<()> {
        let b = self.cfg.batch;
        let (s_cap, n_layers, vocab) = {
            let c = self.backend.model_config();
            (c.max_seq, c.n_layers, c.vocab)
        };

        // -1 marks dead lanes (idle or still prefilling); backends may
        // skip them entirely.
        self.scratch.tokens.clear();
        self.scratch.tokens.resize(b, -1);
        self.scratch.pos.clear();
        self.scratch.pos.resize(b, 0);
        self.scratch.live.clear();
        self.scratch.live.resize(b, false);
        for lane in 0..b {
            self.scratch.pos[lane] = self.kv[lane].len.min(s_cap - 1) as i32;
            if let Some(a) = &self.active[lane] {
                if a.pending_token >= 0 && !self.kv[lane].is_full() {
                    self.scratch.tokens[lane] = a.pending_token;
                    self.scratch.live[lane] = true;
                }
            }
        }
        if !self.scratch.live.iter().any(|&l| l) {
            // every decode-ready lane is blocked (capacity) — finish them.
            // Lanes still mid-prefill were never decode-ready and keep
            // going on later passes.
            for lane in 0..b {
                if matches!(&self.active[lane], Some(a) if a.prompt_fed >= a.req.prompt.len()) {
                    self.finish_lane(lane, Some(FinishReason::Length));
                }
            }
            return Ok(());
        }

        self.fill_mask();

        let t0 = Instant::now();
        let out = self.backend.decode(
            b,
            &self.scratch.tokens,
            &self.scratch.pos,
            &self.scratch.slot_mask,
            &self.knobs,
        )?;
        let live_count = self.scratch.live.iter().filter(|&&l| l).count() as u64;
        self.metrics.record_decode(t0.elapsed(), live_count);
        self.metrics.record_kernels(&out.kernels, true);
        self.metrics.record_kv(&out.kv, self.live_slots_total());
        self.trace.record(TracePhase::DecodeBatch, 0, -1, live_count);
        self.trace.record(
            TracePhase::Score,
            0,
            out.kernels.dominant_mode() as i32,
            out.kernels.score_ns,
        );

        self.scratch.itl_us.clear();
        let mut finish_list: Vec<usize> = vec![];
        for lane in 0..b {
            if !self.scratch.live[lane] {
                continue;
            }
            self.kv[lane].commit_write(1);
            self.scratch.mass.clear();
            self.scratch.mass.resize(s_cap, 0.0);
            for l in 0..n_layers {
                let base = (l * b + lane) * s_cap;
                for s in 0..s_cap {
                    self.scratch.mass[s] += out.attn_acc[base + s];
                }
            }
            self.kv[lane].accumulate(&self.scratch.mass);
            let evicted = self.h2o.apply(&mut self.kv[lane]) as u64;
            self.metrics.record_evictions(evicted);

            let a = self.active[lane].as_mut().unwrap();
            a.next_pos = self.kv[lane].len;
            let row = &out.logits[lane * vocab..(lane + 1) * vocab];
            let tok = self.cfg.sampler.sample(row, &mut self.rng);
            let now = Instant::now();
            if let Some(prev) = a.last_token_at {
                self.scratch.itl_us.push(now.duration_since(prev).as_micros() as u64);
            }
            if a.first_token_at.is_none() {
                a.first_token_at = Some(now);
            }
            a.last_token_at = Some(now);
            a.gen_logprobs.push(log_softmax_at(row, tok as usize));
            a.generated.push(tok);
            a.pending_token = tok;
            if self.lane_should_stop(lane) {
                finish_list.push(lane);
            }
        }
        self.metrics.record_itl(&self.scratch.itl_us);
        for lane in finish_list {
            self.finish_lane(lane, None);
        }
        Ok(())
    }

    // ----------------------------------------------------------- speculation

    /// One self-speculative decode turn: AQUA-sparse draft, exact batched
    /// verify, longest-matching-prefix commit — all against the one
    /// shared KV cache (see [`crate::spec`] for the full protocol).
    ///
    /// Lossless by construction: every committed token is the argmax of
    /// an exact-read logits row, so outputs are bit-identical to plain
    /// decoding with `k_ratio = 1.0` and `speculate = 0`. On a backend
    /// error the pass restores every enrolled lane's committed state
    /// (mask + page write-index) before the error reaches
    /// [`Engine::contain`], preserving the no-side-effects contract the
    /// containment re-run relies on.
    fn spec_pass(&mut self) -> Result<()> {
        let mut spec = self.spec.take().expect("spec_pass requires a controller");
        let r = self.spec_cycle(&mut spec);
        if r.is_err() {
            for lane in 0..self.cfg.batch {
                if spec.is_active(lane) {
                    let base = spec.base_len(lane);
                    self.kv[lane].rollback(base);
                    self.backend.rollback_lane(lane, base);
                }
            }
        }
        self.spec = Some(spec);
        r
    }

    fn spec_cycle(&mut self, spec: &mut SpecController) -> Result<()> {
        let b = self.cfg.batch;
        let (s_cap, vocab) = {
            let c = self.backend.model_config();
            (c.max_seq, c.vocab)
        };

        // ---- enroll: every decode-ready lane joins the cycle
        spec.begin_cycle();
        for lane in 0..b {
            let Some(a) = &self.active[lane] else { continue };
            if a.pending_token < 0 || self.kv[lane].is_full() {
                continue;
            }
            let base_len = self.kv[lane].len;
            // the cycle commits up to `n_plan + 1` tokens: cap the plan
            // so neither `max_new_tokens` nor KV capacity can overrun
            let remaining = a.req.max_new_tokens - a.generated.len();
            let n_plan = self
                .cfg
                .speculate
                .min(remaining.saturating_sub(1))
                .min(s_cap - 1 - base_len);
            spec.plan_lane(lane, base_len, a.pending_token, n_plan);
        }
        if spec.active_lanes() == 0 {
            // every decode-ready lane is blocked (capacity) — finish
            // them, exactly like the plain decode pass
            for lane in 0..b {
                if matches!(&self.active[lane], Some(a) if a.prompt_fed >= a.req.prompt.len()) {
                    self.finish_lane(lane, Some(FinishReason::Length));
                }
            }
            return Ok(());
        }

        let t0 = Instant::now();

        // ---- draft: greedy steps through the configured sparse score
        // path; the KV these steps append is approximate (verify
        // rewrites every drafted position through the exact path)
        loop {
            self.scratch.tokens.clear();
            self.scratch.tokens.resize(b, -1);
            self.scratch.pos.clear();
            self.scratch.pos.resize(b, 0);
            self.scratch.live.clear();
            self.scratch.live.resize(b, false);
            let mut any = false;
            for lane in 0..b {
                self.scratch.pos[lane] = self.kv[lane].len.min(s_cap - 1) as i32;
                if spec.wants_draft(lane) {
                    self.scratch.tokens[lane] = spec.feed_token(lane, spec.n_draft(lane));
                    self.scratch.live[lane] = true;
                    any = true;
                }
            }
            if !any {
                break;
            }
            self.fill_mask();
            let out = self.backend.decode(
                b,
                &self.scratch.tokens,
                &self.scratch.pos,
                &self.scratch.slot_mask,
                &self.knobs,
            )?;
            self.metrics.record_kernels(&out.kernels, true);
            for lane in 0..b {
                if !self.scratch.live[lane] {
                    continue;
                }
                let row = &out.logits[lane * vocab..(lane + 1) * vocab];
                let tok = self.cfg.sampler.sample(row, &mut self.rng);
                spec.push_draft(lane, tok);
                self.kv[lane].commit_write(1);
                // no point drafting past a stop token
                if self.active[lane].as_ref().unwrap().req.stop_token == Some(tok) {
                    spec.truncate_plan(lane);
                }
            }
        }

        // ---- rewind: restore every enrolled lane's pre-draft attendable
        // mask, so verify scores against exactly the committed state
        for lane in 0..b {
            if spec.is_active(lane) {
                self.kv[lane].rollback(spec.base_len(lane));
            }
        }

        // ---- verify: one batched exact pass over [pending, drafts...]
        // rows; -1 pads shorter lanes and parks idle ones
        let t = spec.max_draft() + 1;
        self.scratch.tokens.clear();
        self.scratch.tokens.resize(b * t, -1);
        self.scratch.pos.clear();
        self.scratch.pos.resize(b, 0);
        self.scratch.live.clear();
        self.scratch.live.resize(b, false);
        for lane in 0..b {
            if spec.is_active(lane) {
                let row = lane * t;
                self.scratch.tokens[row] = spec.base_pending(lane);
                let drafts = spec.drafts(lane);
                self.scratch.tokens[row + 1..row + 1 + drafts.len()].copy_from_slice(drafts);
                self.scratch.pos[lane] = spec.base_len(lane) as i32;
                self.scratch.live[lane] = true;
            } else {
                self.scratch.pos[lane] = self.kv[lane].len.min(s_cap - 1) as i32;
            }
        }
        self.fill_mask();
        let out = self.backend.verify(
            b,
            &self.scratch.tokens,
            &self.scratch.pos,
            t,
            &self.scratch.slot_mask,
            &self.xknobs,
        )?;
        self.metrics.record_kernels(&out.kernels, true);
        self.trace.record(
            TracePhase::Score,
            0,
            out.kernels.dominant_mode() as i32,
            out.kernels.score_ns,
        );

        // ---- commit: per lane, the longest draft prefix matching the
        // exact argmax plus the one token the verify pass itself produced
        self.scratch.itl_us.clear();
        let now = Instant::now();
        let mut finish_list: Vec<usize> = vec![];
        let mut accepted_total = 0u64;
        let mut committed_total = 0u64;
        for lane in 0..b {
            if !self.scratch.live[lane] {
                continue;
            }
            let base_len = spec.base_len(lane);
            let n_draft = spec.n_draft(lane);
            let mut n_committed = 0usize;
            let mut lane_accepted = 0usize;
            let mut stop_hit = false;
            for j in 1..=n_draft + 1 {
                let row = &out.logits[(lane * t + j - 1) * vocab..(lane * t + j) * vocab];
                let tok = self.cfg.sampler.sample(row, &mut self.rng);
                let a = self.active[lane].as_mut().unwrap();
                // burst delivery, honestly: the first committed token of
                // the cycle carries the real inter-token gap, the rest
                // arrive in the same instant
                if n_committed == 0 {
                    if let Some(prev) = a.last_token_at {
                        self.scratch.itl_us.push(now.duration_since(prev).as_micros() as u64);
                    }
                } else {
                    self.scratch.itl_us.push(0);
                }
                if a.first_token_at.is_none() {
                    a.first_token_at = Some(now);
                }
                a.last_token_at = Some(now);
                a.gen_logprobs.push(log_softmax_at(row, tok as usize));
                a.generated.push(tok);
                a.pending_token = tok;
                n_committed = j;
                let matched = j <= n_draft && spec.drafts(lane)[j - 1] == tok;
                if matched {
                    lane_accepted += 1;
                }
                stop_hit = a.generated.len() >= a.req.max_new_tokens
                    || a.req.stop_token == Some(tok)
                    || base_len + j >= s_cap;
                if stop_hit || !matched {
                    break;
                }
            }
            // committed tokens become attendable; drafted-but-unverified
            // pages past the commit point return to the pool
            self.kv[lane].commit_write(n_committed);
            self.backend.rollback_lane(lane, base_len + n_committed);
            self.active[lane].as_mut().unwrap().next_pos = base_len + n_committed;
            accepted_total += lane_accepted as u64;
            committed_total += n_committed as u64;
            let rejected = n_draft - lane_accepted;
            let rid = self.active[lane].as_ref().map(|a| a.req.id).unwrap_or(0);
            if n_draft > 0 {
                self.trace.record(TracePhase::DraftBlock, rid, lane as i32, n_draft as u64);
            }
            self.trace.record(TracePhase::VerifyBlock, rid, lane as i32, n_committed as u64);
            if rejected > 0 {
                self.trace.record(TracePhase::Rollback, rid, lane as i32, rejected as u64);
            }
            if stop_hit {
                finish_list.push(lane);
            }
        }
        let lane_cycles = spec.active_lanes();
        self.metrics.record_decode(t0.elapsed(), committed_total);
        self.metrics.record_kv(&out.kv, self.live_slots_total());
        self.metrics.record_spec(spec.total_drafted(), accepted_total, committed_total, lane_cycles);
        self.metrics.record_itl(&self.scratch.itl_us);
        self.trace.record(TracePhase::DecodeBatch, 0, -1, lane_cycles);
        for lane in finish_list {
            self.finish_lane(lane, None);
        }
        Ok(())
    }

    // --------------------------------------------------------------- helpers

    /// Currently attendable slots across all lanes (the numerator of the
    /// page-utilization gauge).
    fn live_slots_total(&self) -> u64 {
        self.kv.iter().map(|l| l.live_slots() as u64).sum()
    }

    /// Refresh the [B, S] attendable-slot mask in scratch (no allocation).
    fn fill_mask(&mut self) {
        let s = self.backend.model_config().max_seq;
        self.scratch.slot_mask.clear();
        self.scratch.slot_mask.resize(self.cfg.batch * s, 0.0);
        for (lane, kv) in self.kv.iter().enumerate() {
            self.scratch.slot_mask[lane * s..(lane + 1) * s].copy_from_slice(&kv.slot_mask);
        }
    }

    fn lane_should_stop(&self, lane: usize) -> bool {
        let a = self.active[lane].as_ref().unwrap();
        if a.generated.len() >= a.req.max_new_tokens {
            return true;
        }
        if let Some(stop) = a.req.stop_token {
            if a.generated.last() == Some(&stop) {
                return true;
            }
        }
        self.kv[lane].is_full()
    }

    fn finish_lane(&mut self, lane: usize, forced: Option<FinishReason>) {
        let Some(a) = self.active[lane].take() else { return };
        let finish = forced.unwrap_or_else(|| {
            if a.req.stop_token.is_some() && a.generated.last() == a.req.stop_token.as_ref() {
                FinishReason::Stop
            } else {
                FinishReason::Length
            }
        });
        let done_at = Instant::now();
        let total = done_at.duration_since(a.started_at);
        let ttft = a.first_token_at.map(|t| t.duration_since(a.started_at));
        self.metrics.record_finish(ttft, total);
        match finish {
            FinishReason::Cancelled => self.metrics.record_cancelled(true),
            FinishReason::DeadlineExpired => self.metrics.record_expired(true),
            FinishReason::BackendError => self.metrics.record_failed(true, 1),
            _ => {}
        }
        // Client-visible span breakdown, all measured from *enqueue* so
        // queue_wait + prefill + decode == total by construction. A lane
        // that never emitted a token charges its whole admitted span to
        // prefill (nothing was ever decoded).
        let queue_wait = a.started_at.duration_since(a.enqueued_at);
        let (prefill_d, decode_d, client_ttft) = match a.first_token_at {
            Some(ft) => (
                ft.duration_since(a.started_at),
                done_at.duration_since(ft),
                ft.duration_since(a.enqueued_at),
            ),
            None => (total, std::time::Duration::ZERO, std::time::Duration::ZERO),
        };
        let timings = ReqTimings {
            queue_wait_us: queue_wait.as_micros() as u64,
            prefill_us: prefill_d.as_micros() as u64,
            decode_us: decode_d.as_micros() as u64,
            ttft_us: client_ttft.as_micros() as u64,
            total_us: done_at.duration_since(a.enqueued_at).as_micros() as u64,
            prefix_hit_tokens: a.prefix_hit_tokens as u64,
        };
        self.trace.record(TracePhase::Retire, a.req.id, lane as i32, finish.code());
        self.results.insert(
            a.req.id,
            GenResult {
                id: a.req.id,
                tokens: a.generated,
                prompt_logprobs: a.prompt_logprobs,
                gen_logprobs: a.gen_logprobs,
                finish,
                ttft_us: ttft.map(|t| t.as_micros() as u64).unwrap_or(0),
                total_us: total.as_micros() as u64,
                timings,
            },
        );
        self.lanes.release(lane);
        self.kv[lane].reset();
        self.kv_reserved[lane] = 0;
        // paged backends return the lane's KV pages to the pool here
        self.backend.retire_lane(lane);
    }
}

// ---------------------------------------------------------------------------
// Threaded front-end handle (for the HTTP server): the engine lives on its
// own thread because the production backend's PJRT handles are !Send.
// ---------------------------------------------------------------------------

pub enum EngineCmd {
    Submit(GenRequest),
    /// Cancel a queued or in-flight request: the lane is retired and its
    /// KV pages freed immediately; the waiter receives a terminal
    /// `Cancelled` result carrying whatever tokens were already
    /// generated. Unknown (or already finished) ids are ignored.
    Cancel(u64),
    Stats(mpsc::Sender<super::metrics::Snapshot>),
    /// Graceful shutdown: the engine drains queued + in-flight lanes to
    /// completion and flushes every result before its thread exits (the
    /// registry's `DELETE /models/{name}` joins on this). Commands sent
    /// after `Shutdown` are dropped.
    Shutdown,
}

/// Engine lifecycle health as the deployment's admission gate sees it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    /// Backend/engine under construction (initial spawn or rebuild).
    Starting,
    /// Serving.
    Healthy,
    /// The engine crashed and a restart is pending (backoff) — new work
    /// is shed until the rebuild reports healthy.
    Unhealthy,
    /// Dead for good (restart budget exhausted, or init failed with no
    /// restarts left). Residual commands are answered terminally with
    /// `EngineFailed`; the deployment sheds everything new.
    Failed,
}

/// Health + restart counters shared between the supervised engine thread
/// and its deployment — lock-free, because the admission gate reads the
/// health on every submit.
#[derive(Debug, Default)]
pub struct EngineStatus {
    /// 0 = Starting, 1 = Healthy, 2 = Unhealthy, 3 = Failed.
    health: AtomicU8,
    restarts: AtomicU64,
}

impl EngineStatus {
    pub fn health(&self) -> Health {
        match self.health.load(Ordering::Acquire) {
            0 => Health::Starting,
            1 => Health::Healthy,
            2 => Health::Unhealthy,
            _ => Health::Failed,
        }
    }

    /// Engine rebuilds performed so far (the `/metrics` counter).
    pub fn restarts(&self) -> u64 {
        self.restarts.load(Ordering::Relaxed)
    }

    fn set(&self, h: Health) {
        let v = match h {
            Health::Starting => 0,
            Health::Healthy => 1,
            Health::Unhealthy => 2,
            Health::Failed => 3,
        };
        self.health.store(v, Ordering::Release);
    }
}

/// Supervisor restart policy: how many times a crashed/failed engine is
/// rebuilt, with capped exponential backoff between attempts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RestartPolicy {
    /// Rebuilds allowed after abnormal exits (0 = fail fast: first crash
    /// flips the deployment to Failed).
    pub max_restarts: u32,
    /// Backoff before the first rebuild; doubles per consecutive crash.
    pub backoff: Duration,
    /// Backoff growth cap.
    pub backoff_max: Duration,
}

impl Default for RestartPolicy {
    fn default() -> Self {
        RestartPolicy {
            max_restarts: 0,
            backoff: Duration::from_millis(50),
            backoff_max: Duration::from_secs(5),
        }
    }
}

pub struct EngineHandle {
    pub cmd_tx: mpsc::Sender<EngineCmd>,
    pub result_rx: mpsc::Receiver<GenResult>,
    pub join: std::thread::JoinHandle<()>,
}

impl EngineHandle {
    /// Spawn an engine-owning thread with no restart budget (first crash
    /// → Failed). `make_engine` runs *on that thread* (constructs the
    /// backend there — see `BackendRecipe`).
    pub fn spawn<F>(make_engine: F) -> EngineHandle
    where
        F: Fn() -> Result<Engine> + Send + 'static,
    {
        Self::spawn_supervised(
            make_engine,
            RestartPolicy::default(),
            Arc::new(EngineStatus::default()),
            Arc::new(TraceRecorder::default()),
        )
    }

    /// Spawn a *supervised* engine thread: the engine loop runs under
    /// `catch_unwind`; on a panic or a fatal step error the supervisor
    /// flushes a terminal result to every waiter (a real one where the
    /// dead incarnation produced it, `EngineFailed` otherwise — nobody
    /// hangs to an HTTP deadline), publishes health through `status`,
    /// and rebuilds the engine up to `policy.max_restarts` times with
    /// capped exponential backoff. Metrics *and the trace recorder* are
    /// shared across incarnations, so counters survive restarts, outcome
    /// reconciliation holds for the deployment's whole lifetime, and
    /// postmortems from a dead incarnation stay readable.
    pub fn spawn_supervised<F>(
        make_engine: F,
        policy: RestartPolicy,
        status: Arc<EngineStatus>,
        trace: Arc<TraceRecorder>,
    ) -> EngineHandle
    where
        F: Fn() -> Result<Engine> + Send + 'static,
    {
        let (cmd_tx, cmd_rx) = mpsc::channel::<EngineCmd>();
        let (res_tx, result_rx) = mpsc::channel::<GenResult>();
        let join = std::thread::spawn(move || {
            supervise(make_engine, policy, status, trace, cmd_rx, res_tx)
        });
        EngineHandle { cmd_tx, result_rx, join }
    }
}

/// Terminal answer for a request the (dead) engine can no longer serve.
fn engine_failed_result(id: u64) -> GenResult {
    GenResult {
        id,
        tokens: vec![],
        prompt_logprobs: vec![],
        gen_logprobs: vec![],
        finish: FinishReason::EngineFailed,
        ttft_us: 0,
        total_us: 0,
        timings: ReqTimings::default(),
    }
}

/// Deliver every finished result among `pending` (keeps undelivered ids).
fn flush_results(engine: &mut Engine, pending: &mut Vec<u64>, res_tx: &mpsc::Sender<GenResult>) {
    pending.retain(|id| {
        if let Some(res) = engine.take_result(*id) {
            let _ = res_tx.send(res);
            false
        } else {
            true
        }
    });
}

/// How one engine incarnation ended.
enum Exit {
    /// Shutdown command or all clients gone — the thread is done.
    Clean,
}

/// The supervisor body: build → serve under `catch_unwind` → on abnormal
/// exit flush terminal answers, then restart (budget + backoff) or park
/// in [`failed_loop`]. The command/result channels never change across
/// incarnations, so the deployment side is oblivious to restarts.
fn supervise<F>(
    make_engine: F,
    policy: RestartPolicy,
    status: Arc<EngineStatus>,
    trace: Arc<TraceRecorder>,
    cmd_rx: mpsc::Receiver<EngineCmd>,
    res_tx: mpsc::Sender<GenResult>,
) where
    F: Fn() -> Result<Engine>,
{
    // One accumulator for every incarnation: counters survive restarts.
    let metrics = Arc::new(Metrics::default());
    // Accepted ids whose results have not been delivered yet. Lives
    // outside the incarnation so a crash can still answer every waiter.
    let mut pending: Vec<u64> = vec![];
    let mut backoff = policy.backoff.max(Duration::from_millis(1));
    let mut restarts_left = policy.max_restarts;
    loop {
        status.set(Health::Starting);
        let engine = match make_engine() {
            Ok(mut e) => {
                e.metrics = metrics.clone();
                e.trace = trace.clone();
                Some(e)
            }
            Err(e) => {
                crate::log_error!("engine init failed: {e:#}");
                None
            }
        };
        if let Some(mut engine) = engine {
            status.set(Health::Healthy);
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                incarnation_loop(&mut engine, &mut pending, &cmd_rx, &res_tx)
            }));
            match outcome {
                Ok(Ok(Exit::Clean)) => return,
                Ok(Err(e)) => {
                    crate::log_error!("engine failed (postmortem captured): {e:#}");
                    trace.snapshot_postmortem(&format!("engine failed: {e:#}"), -1);
                }
                Err(_) => {
                    crate::log_error!("engine panicked (caught by supervisor, postmortem captured)");
                    trace.snapshot_postmortem("engine panicked (caught by supervisor)", -1);
                }
            }
            // Abnormal exit: answer every undelivered waiter now — a real
            // result where the dead incarnation finished one, terminal
            // `EngineFailed` otherwise.
            for id in pending.drain(..) {
                match engine.take_result(id) {
                    Some(res) => {
                        let _ = res_tx.send(res);
                    }
                    None => {
                        metrics.record_failed(false, 0);
                        let _ = res_tx.send(engine_failed_result(id));
                    }
                }
            }
            // release the dead incarnation (backend, KV pool) before any
            // rebuild allocates a fresh one
            drop(engine);
        }
        if restarts_left == 0 {
            status.set(Health::Failed);
            failed_loop(&cmd_rx, &res_tx, &metrics);
            return;
        }
        restarts_left -= 1;
        status.set(Health::Unhealthy);
        std::thread::sleep(backoff);
        backoff = (backoff * 2).min(policy.backoff_max);
        status.restarts.fetch_add(1, Ordering::Relaxed);
        trace.record(TracePhase::EngineRestart, 0, -1, status.restarts());
    }
}

/// One engine incarnation's serve loop. Returns `Ok(Exit::Clean)` on
/// shutdown/disconnect; an `Err` is a fatal engine failure the supervisor
/// handles (a panic unwinds through instead).
fn incarnation_loop(
    engine: &mut Engine,
    pending: &mut Vec<u64>,
    cmd_rx: &mpsc::Receiver<EngineCmd>,
    res_tx: &mpsc::Sender<GenResult>,
) -> Result<Exit> {
    loop {
        // drain commands (non-blocking while busy, blocking when idle)
        loop {
            let cmd = if engine.lanes.is_idle() && engine.queue.is_empty() {
                match cmd_rx.recv() {
                    Ok(c) => c,
                    Err(_) => return Ok(Exit::Clean),
                }
            } else {
                match cmd_rx.try_recv() {
                    Ok(c) => c,
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => return Ok(Exit::Clean),
                }
            };
            match cmd {
                EngineCmd::Submit(r) => {
                    // Duplicate ids are refused at submit and answered
                    // immediately — `pending` only ever tracks accepted
                    // submissions, so a duplicate can neither overwrite
                    // the original's result nor leave a stale pump entry
                    // behind.
                    let id = r.id;
                    if engine.submit(r) {
                        pending.push(id);
                    } else {
                        let _ = res_tx.send(GenResult {
                            id,
                            tokens: vec![],
                            prompt_logprobs: vec![],
                            gen_logprobs: vec![],
                            finish: FinishReason::DuplicateId,
                            ttft_us: 0,
                            total_us: 0,
                            timings: ReqTimings::default(),
                        });
                    }
                }
                EngineCmd::Cancel(id) => {
                    // the cancel may finish a lane (or resolve a queued
                    // entry) — deliver immediately, before a possible
                    // blocking wait for the next command
                    engine.cancel(id);
                    flush_results(engine, pending, res_tx);
                }
                EngineCmd::Stats(tx) => {
                    let _ = tx.send(engine.metrics.snapshot());
                }
                EngineCmd::Shutdown => {
                    // drain: finish queued + in-flight work, flush
                    // results, then exit. If the drain itself fails the
                    // remaining waiters still get terminal answers.
                    if let Err(e) = engine.run_until_idle() {
                        eprintln!("engine drain failed: {e:#}");
                    }
                    for id in pending.drain(..) {
                        match engine.take_result(id) {
                            Some(res) => {
                                let _ = res_tx.send(res);
                            }
                            None => {
                                engine.metrics.record_failed(false, 0);
                                let _ = res_tx.send(engine_failed_result(id));
                            }
                        }
                    }
                    return Ok(Exit::Clean);
                }
            }
        }
        engine.step()?;
        flush_results(engine, pending, res_tx);
    }
}

/// Terminal service for a permanently failed engine: answer residual
/// commands (`EngineFailed` results, stats from the shared accumulator)
/// so no waiter ever hangs, until shutdown or disconnect.
fn failed_loop(
    cmd_rx: &mpsc::Receiver<EngineCmd>,
    res_tx: &mpsc::Sender<GenResult>,
    metrics: &Metrics,
) {
    while let Ok(cmd) = cmd_rx.recv() {
        match cmd {
            EngineCmd::Submit(r) => {
                metrics.record_failed(false, 0);
                let _ = res_tx.send(engine_failed_result(r.id));
            }
            EngineCmd::Cancel(_) => {}
            EngineCmd::Stats(tx) => {
                let _ = tx.send(metrics.snapshot());
            }
            EngineCmd::Shutdown => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::runtime::{FaultBackend, FaultPlan};

    fn prompt(seed: i32) -> Vec<i32> {
        (0..6).map(|i| (seed + i * 3) % 50).collect()
    }

    fn native_engine(batch: usize) -> Engine {
        let spec = BackendSpec::native(ModelConfig::tiny("engine-fault"), 9).unwrap();
        Engine::with_spec(&spec, EngineConfig { batch, ..EngineConfig::default() }).unwrap()
    }

    fn faulty_engine(batch: usize, plan: &str) -> Engine {
        let spec = BackendSpec::native(ModelConfig::tiny("engine-fault"), 9).unwrap();
        let be = FaultBackend::new(spec.build().unwrap(), FaultPlan::parse(plan).unwrap());
        Engine::new(Box::new(be), EngineConfig { batch, ..EngineConfig::default() }).unwrap()
    }

    #[test]
    fn contained_failure_kills_only_blamed_lane() {
        let reqs = vec![GenRequest::new(1, prompt(2), 4), GenRequest::new(2, prompt(11), 4)];
        let mut clean = native_engine(2);
        let clean_res = clean.run_batch(reqs.clone()).unwrap();

        // the first pass (prefill of both lanes) errs once, blamed on
        // lane 1; the engine keeps running
        let mut faulty = faulty_engine(2, "err_every=1,err_count=1,err_lane=1");
        let res = faulty.run_batch(reqs).unwrap();
        assert_eq!(res[1].finish, FinishReason::BackendError);
        assert!(res[1].tokens.is_empty());
        // the surviving lane is bit-identical to the fault-free run
        assert_eq!(res[0].finish, clean_res[0].finish);
        assert_eq!(res[0].tokens, clean_res[0].tokens);
        // both lanes released their KV pages (failure path included)
        assert_eq!(faulty.kv_gauges().pages_in_use, 0);
        let snap = faulty.metrics.snapshot();
        assert_eq!(snap.requests_done, 2);
        assert_eq!(snap.requests_failed, 1);
        assert_eq!(snap.lane_failures, 1);
        assert_eq!(snap.requests_served, 1);
    }

    #[test]
    fn consecutive_failures_escalate_to_engine_error() {
        // every pass fails; each failure retires one request, and the
        // third back-to-back failure (default cap) escalates instead of
        // silently draining the queue one casualty at a time
        let mut e = faulty_engine(1, "err_every=1");
        for id in 1..=3u64 {
            assert!(e.submit(GenRequest::new(id, prompt(id as i32), 4)));
        }
        let err = e.run_until_idle().expect_err("must escalate at the failure cap");
        assert!(
            format!("{err:#}").contains("consecutive step failures"),
            "unexpected escalation error: {err:#}"
        );
    }

    #[test]
    fn cancel_frees_lane_and_queue_entries() {
        let mut e = native_engine(1);
        assert!(e.submit(GenRequest::new(1, prompt(1), 8)));
        assert!(e.submit(GenRequest::new(2, prompt(5), 8)));
        // a couple of passes: id 1 occupies the lane, id 2 waits queued
        e.step().unwrap();
        e.step().unwrap();
        assert!(e.cancel(1), "active lane cancel");
        assert!(e.cancel(2), "queued cancel");
        assert!(!e.cancel(99), "unknown id");
        assert_eq!(e.take_result(1).unwrap().finish, FinishReason::Cancelled);
        let r2 = e.take_result(2).unwrap();
        assert_eq!(r2.finish, FinishReason::Cancelled);
        assert!(r2.tokens.is_empty(), "queued cancel never ran");
        // cancellation is a capacity event: pages freed immediately
        assert_eq!(e.kv_gauges().pages_in_use, 0);
        assert!(!e.step().unwrap(), "engine drained");
        let snap = e.metrics.snapshot();
        assert_eq!(snap.requests_done, 2);
        assert_eq!(snap.requests_cancelled, 2);
    }

    #[test]
    fn deadlines_expire_queued_and_active_requests() {
        // queue-side: expires before ever occupying a lane
        let mut e = native_engine(1);
        let mut req = GenRequest::new(1, prompt(4), 4);
        req.deadline_ms = 1;
        assert!(e.submit(req));
        std::thread::sleep(Duration::from_millis(5));
        e.step().unwrap();
        let r = e.take_result(1).unwrap();
        assert_eq!(r.finish, FinishReason::DeadlineExpired);
        assert!(r.tokens.is_empty());

        // lane-side: expires mid-decode with partial tokens, pages freed
        let mut req = GenRequest::new(2, prompt(7), 64);
        req.deadline_ms = 50;
        assert!(e.submit(req));
        e.step().unwrap(); // admit + prefill
        e.step().unwrap(); // first decode
        std::thread::sleep(Duration::from_millis(60));
        e.step().unwrap(); // sweep retires the lane
        let r = e.take_result(2).unwrap();
        assert_eq!(r.finish, FinishReason::DeadlineExpired);
        assert!(r.tokens.len() < 64, "must not have run to completion");
        assert_eq!(e.kv_gauges().pages_in_use, 0);
        let snap = e.metrics.snapshot();
        assert_eq!(snap.requests_expired, 2);
        assert_eq!(snap.requests_done, 2);
    }

    #[test]
    fn plan_prefill_whole_chunks_under_budget() {
        let remaining = [40usize, 3, 0, 16];
        let mut fed = [0usize; 4];
        // budget 20, chunk 16: lane 0 gets a full chunk (16), lane 1's
        // tail (3) still fits (19 <= 20), lane 3's chunk would overflow
        let total = plan_prefill(&remaining, 16, 20, &mut fed);
        assert_eq!(fed, [16, 3, 0, 0]);
        assert_eq!(total, 19);
        // unlimited: everyone gets min(remaining, chunk)
        let total = plan_prefill(&remaining, 16, 0, &mut fed);
        assert_eq!(fed, [16, 3, 0, 16]);
        assert_eq!(total, 35);
        // budget below one chunk is rounded up so the pass progresses
        let total = plan_prefill(&remaining, 16, 1, &mut fed);
        assert_eq!(fed, [16, 0, 0, 0]);
        assert_eq!(total, 16);
    }
}
