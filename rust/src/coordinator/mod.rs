//! Layer-3 coordinator: the serving system around the AOT decode step.
//!
//! Data flow (continuous batching, vLLM-style):
//!
//! ```text
//! submit() ──► admission queue ──► Scheduler.pack() ──► lanes [0..B)
//!                                       │ prefill chunks (C tokens/call)
//!                                       ▼
//!                              ModelRuntime.prefill/decode
//!                                       │ attn_acc
//!                                       ▼
//!                    KvState per lane ──► H2oPolicy.evict() ──► slot_mask
//!                                       │ logits
//!                                       ▼
//!                        Sampler ──► stream tokens ──► finish/stop
//! ```

pub mod batcher;
pub mod engine;
pub mod h2o;
pub mod kvcache;
pub mod metrics;
pub mod request;

pub use engine::{
    Engine, EngineCmd, EngineConfig, EngineHandle, EngineStatus, Health, RestartPolicy,
};
pub use metrics::{Metrics, Snapshot};
pub use request::{FinishReason, GenRequest, GenResult};
