//! Request/response types and lifecycle states.

use crate::aqua::policy::AquaConfig;

/// A generation request as submitted by a client.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Stop at this byte (e.g. b'\n') if present.
    pub stop_token: Option<i32>,
    /// Per-request AQUA override; engine default used when None.
    pub aqua: Option<AquaConfig>,
    /// If true, also return per-token logprobs of the *prompt* continuation
    /// (teacher forcing) instead of sampling — used by the eval harness for
    /// MC scoring and perplexity.
    pub score_only: bool,
    /// Wall-clock deadline in milliseconds, measured from enqueue (0 = no
    /// deadline). Enforced at queue admission and per-step: an expired
    /// request finishes terminally with [`FinishReason::DeadlineExpired`]
    /// and releases its lane + KV pages immediately.
    pub deadline_ms: u64,
    /// Admission priority (JSON `"priority"`; default 0, higher admits
    /// first). The queue orders by priority class ahead of FIFO age —
    /// FIFO is preserved within a class, and the `waiting_served_ratio`
    /// overtake bound applies to whatever sits at the head regardless of
    /// class (see `batcher::AdmissionQueue::push`).
    pub priority: i64,
}

impl GenRequest {
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        GenRequest {
            id,
            prompt,
            max_new_tokens,
            stop_token: None,
            aqua: None,
            score_only: false,
            deadline_ms: 0,
            priority: 0,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    Length,
    Stop,
    /// Prompt longer than the KV capacity.
    PromptTooLong,
    /// Worst-case KV page growth exceeds the engine's `kv_budget_mb` —
    /// the request can never be admitted at this budget (raising the
    /// budget, not shortening the prompt, is the fix).
    OverKvBudget,
    /// Submitted with an id that is already queued, running, or holding
    /// an unclaimed result. Refused at submit (nothing ran); resubmit
    /// under a fresh id.
    DuplicateId,
    /// The backend's step failed for this lane (or for a whole pass no
    /// lane could be blamed for). The lane's partial tokens are returned;
    /// its KV pages were released. Other lanes are unaffected — their
    /// greedy outputs stay bit-identical to a fault-free run.
    BackendError,
    /// Cancelled by the client (explicit cancel or detected disconnect).
    /// Partial tokens are returned; the lane and its KV pages were
    /// released immediately.
    Cancelled,
    /// The request's `deadline_ms` elapsed before completion — in the
    /// queue or mid-decode. Partial tokens (if any) are returned.
    DeadlineExpired,
    /// The engine died (panicked or exceeded its consecutive-failure cap)
    /// while this request was in flight. Emitted by the supervisor so
    /// waiters get a terminal answer instead of hanging to the HTTP
    /// deadline; nothing about the request's own input was wrong —
    /// resubmit once the deployment reports healthy again.
    EngineFailed,
}

impl FinishReason {
    /// Stable small integer for the trace `Retire` event's payload word.
    pub fn code(&self) -> u64 {
        match self {
            FinishReason::Length => 0,
            FinishReason::Stop => 1,
            FinishReason::PromptTooLong => 2,
            FinishReason::OverKvBudget => 3,
            FinishReason::DuplicateId => 4,
            FinishReason::BackendError => 5,
            FinishReason::Cancelled => 6,
            FinishReason::DeadlineExpired => 7,
            FinishReason::EngineFailed => 8,
        }
    }
}

/// Per-request wall-clock breakdown, computed from the request's own
/// lifecycle instants when it retires (opt-in over HTTP via
/// `"timings": true` on `POST /generate`). All spans are measured from
/// *enqueue* — client-visible time — so by construction
/// `queue_wait + prefill + decode == total` (±µs rounding) and
/// `ttft <= total`. Note [`GenResult::ttft_us`] keeps its historical
/// admission-relative meaning; `ttft_us` here is enqueue-relative.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReqTimings {
    /// Enqueue → lane admission.
    pub queue_wait_us: u64,
    /// Admission → first emitted token (or retire, if none was emitted).
    pub prefill_us: u64,
    /// First emitted token → retire (0 if none was emitted).
    pub decode_us: u64,
    /// Enqueue → first emitted token (0 if none was emitted).
    pub ttft_us: u64,
    /// Enqueue → retire.
    pub total_us: u64,
    /// Prompt tokens served from the prefix cache instead of prefill.
    pub prefix_hit_tokens: u64,
}

/// Completed request.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Log-prob of each *prompt* token given its prefix (teacher-forced),
    /// starting from prompt position 1. Filled for score_only requests
    /// (which always run the full prompt). On an engine with the prefix
    /// cache enabled, sampling requests whose prefix was served from
    /// shared pages carry entries only for the *computed* tail — skipped
    /// positions produced no logits.
    pub prompt_logprobs: Vec<f32>,
    /// Log-prob of each generated token.
    pub gen_logprobs: Vec<f32>,
    pub finish: FinishReason,
    /// Wall-clock metrics (admission-relative TTFT; see [`ReqTimings`]
    /// for the enqueue-relative breakdown).
    pub ttft_us: u64,
    pub total_us: u64,
    /// Client-visible span breakdown (all-zero for requests that never
    /// reached the engine, e.g. duplicate-id refusals).
    pub timings: ReqTimings,
}

/// Per-lane request state inside the engine.
#[derive(Debug)]
pub(crate) struct ActiveReq {
    pub req: GenRequest,
    /// Next prompt index to feed (prefill progress).
    pub prompt_fed: usize,
    /// Generated tokens so far.
    pub generated: Vec<i32>,
    pub prompt_logprobs: Vec<f32>,
    pub gen_logprobs: Vec<f32>,
    /// Logical position of the next token to write (monotone, drives RoPE).
    pub next_pos: usize,
    /// Prompt tokens adopted from the prefix cache at admission.
    pub prefix_hit_tokens: usize,
    /// Token to feed on the next decode step.
    pub pending_token: i32,
    /// When the request entered the queue — `deadline_ms` is measured
    /// from here (queue wait counts against the deadline).
    pub enqueued_at: std::time::Instant,
    pub started_at: std::time::Instant,
    pub first_token_at: Option<std::time::Instant>,
    /// When the most recent token was emitted — the decode pass measures
    /// inter-token latency against this (a long gap here is exactly the
    /// prefill-starves-decode signal the scheduler bounds).
    pub last_token_at: Option<std::time::Instant>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults() {
        let r = GenRequest::new(7, vec![1, 2, 3], 16);
        assert_eq!(r.id, 7);
        assert!(r.aqua.is_none());
        assert!(!r.score_only);
    }
}
