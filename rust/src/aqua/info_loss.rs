//! Information-retention loss (paper §6.2) and the Fig. 2 / Fig. 3-4
//! analyses.
//!
//! L_info(v, v̂, I_k) = | ‖v‖₂ − ‖v̂[I_k]‖₂ | / ‖v‖₂
//!
//! Two projection sources ("Same Matrix" online SVD vs "Different Dataset"
//! offline P) × two selection methods ("Top-K by Dimension" slicing vs
//! "Top-K by Magnitude") give Fig. 2's four series.

use crate::tensor::svd::projection_from_data;
use crate::tensor::topk::topk_indices_by_abs;
use crate::tensor::Tensor;
use anyhow::Result;

/// Selection method for the retained index set I_k.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Selection {
    /// First k dims after projection (LoKi-style static slice).
    ByDimension,
    /// k largest-|·| dims of each projected vector (AQUA).
    ByMagnitude,
}

/// L_info for one vector given its projected form and the keep set.
pub fn info_loss(v: &[f32], vhat: &[f32], keep: &[usize]) -> f32 {
    let nv = v.iter().map(|x| x * x).sum::<f32>().sqrt();
    if nv < 1e-12 {
        return 0.0;
    }
    let nr = keep.iter().map(|&i| vhat[i] * vhat[i]).sum::<f32>().sqrt();
    (nv - nr).abs() / nv
}

/// Mean L_info over the rows of `data` [n, d], projected by `p` [d, d],
/// keeping k dims by `sel`.
pub fn mean_info_loss(data: &Tensor, p: &Tensor, k: usize, sel: Selection) -> Result<f32> {
    let d = data.cols();
    let proj = data.matmul(p)?;
    let mut total = 0.0f64;
    for i in 0..data.rows() {
        let v = data.row(i);
        let vh = proj.row(i);
        let keep = match sel {
            Selection::ByDimension => (0..k.min(d)).collect::<Vec<_>>(),
            Selection::ByMagnitude => topk_indices_by_abs(vh, k),
        };
        total += info_loss(v, vh, &keep) as f64;
    }
    Ok((total / data.rows() as f64) as f32)
}

/// One Fig.-2 style series: mean loss at each k-ratio for a fixed
/// (projection, selection) condition.
pub fn loss_series(data: &Tensor, p: &Tensor, ratios: &[f64], sel: Selection)
                   -> Result<Vec<(f64, f32)>> {
    let d = data.cols();
    ratios
        .iter()
        .map(|&r| {
            let k = ((r * d as f64).round() as usize).clamp(1, d);
            Ok((r, mean_info_loss(data, p, k, sel)?))
        })
        .collect()
}

/// The Fig. 2 "Same Matrix" condition: SVD computed *from the evaluation
/// data itself* (the ideal online approach §6.1 rules out as too slow).
pub fn online_projection(data: &Tensor) -> Result<Tensor> {
    projection_from_data(data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::testkit::check;

    fn gaussian(rng: &mut Rng, n: usize, d: usize) -> Tensor {
        Tensor::new(&[n, d], rng.normal_vec(n * d, 1.0)).unwrap()
    }

    #[test]
    fn zero_loss_at_full_k_with_orthogonal_p() {
        let mut rng = Rng::new(11);
        let data = gaussian(&mut rng, 64, 8);
        let p = online_projection(&data).unwrap();
        for sel in [Selection::ByDimension, Selection::ByMagnitude] {
            let l = mean_info_loss(&data, &p, 8, sel).unwrap();
            assert!(l < 1e-3, "loss {l} at k=d should vanish (rotation is lossless)");
        }
    }

    #[test]
    fn magnitude_never_worse_than_slicing() {
        // Per-vector the magnitude top-k maximizes retained energy, so its
        // loss is pointwise <= any other selection of the same size.
        let mut rng = Rng::new(12);
        let data = gaussian(&mut rng, 80, 16);
        let p = online_projection(&data).unwrap();
        for k in [2usize, 4, 8, 12] {
            let lm = mean_info_loss(&data, &p, k, Selection::ByMagnitude).unwrap();
            let ls = mean_info_loss(&data, &p, k, Selection::ByDimension).unwrap();
            assert!(lm <= ls + 1e-5, "k={k}: magnitude {lm} > slice {ls}");
        }
    }

    #[test]
    fn loss_monotone_in_k_for_magnitude() {
        let mut rng = Rng::new(13);
        let data = gaussian(&mut rng, 50, 12);
        let p = online_projection(&data).unwrap();
        let series = loss_series(&data, &p, &[0.25, 0.5, 0.75, 1.0], Selection::ByMagnitude)
            .unwrap();
        for w in series.windows(2) {
            assert!(w[0].1 >= w[1].1 - 1e-5, "loss should fall as k grows: {series:?}");
        }
    }

    #[test]
    fn prop_loss_bounded() {
        check(
            "info-loss-in-[0,1]",
            100,
            |g| {
                let d = 2 + g.rng.below(16);
                (g.vec_f32(d, 2.0), g.vec_f32(d, 2.0), d)
            },
            |(v, vh, d)| {
                // any keep set
                let keep: Vec<usize> = (0..*d / 2).collect();
                let l = info_loss(v, vh, &keep);
                // ‖v̂[I]‖ can exceed ‖v‖ for non-orthogonal v̂, so the loss is
                // only guaranteed non-negative & finite here.
                if l.is_finite() && l >= 0.0 {
                    Ok(())
                } else {
                    Err(format!("loss {l}"))
                }
            },
        );
    }
}
