//! Magnitude-vs-PCA overlap analysis (paper §7 / Appendix A.6, Fig. 5).
//!
//! For a vector v: ρ(v, K, K′) = |S_mag(v,K) ∩ S_pca(K′)| / K where
//! S_mag is the top-K |·| index set of the *unprojected* vector and
//! S_pca(K′) = {0..K′-1} (the first K′ principal components).

use crate::tensor::topk::topk_indices_by_abs;
use crate::tensor::Tensor;

/// Distribution summary of ρ over a set of vectors (what Fig. 5's violins
/// show; we print quantiles).
#[derive(Debug, Clone)]
pub struct OverlapStats {
    pub k_frac: f64,
    pub kp_frac: f64,
    pub mean: f64,
    pub p10: f64,
    pub p50: f64,
    pub p90: f64,
}

/// ρ for one vector (projected form `vhat` used for magnitude ranking when
/// analysing projected space; pass the raw vector for the paper's
/// unprojected variant).
pub fn rho(vhat: &[f32], k: usize, kp: usize) -> f64 {
    if k == 0 {
        return 0.0;
    }
    let mag = topk_indices_by_abs(vhat, k);
    let hits = mag.iter().filter(|&&i| i < kp).count();
    hits as f64 / k as f64
}

/// Overlap stats over the rows of `data` (already in the projected space —
/// the PCA index set is only meaningful there).
pub fn overlap_stats(data: &Tensor, p: &Tensor, k_frac: f64, kp_frac: f64) -> OverlapStats {
    let d = data.cols();
    let k = ((k_frac * d as f64).round() as usize).clamp(1, d);
    let kp = ((kp_frac * d as f64).round() as usize).clamp(1, d);
    let proj = data.matmul(p).expect("shape");
    let mut rhos: Vec<f64> = (0..proj.rows()).map(|i| rho(proj.row(i), k, kp)).collect();
    rhos.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = rhos.iter().sum::<f64>() / rhos.len().max(1) as f64;
    let q = |f: f64| rhos[((rhos.len() - 1) as f64 * f).round() as usize];
    OverlapStats { k_frac, kp_frac, mean, p10: q(0.1), p50: q(0.5), p90: q(0.9) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn rho_bounds_and_full_overlap() {
        let v = [3.0f32, 2.0, 1.0, 0.5];
        assert!((rho(&v, 2, 4) - 1.0).abs() < 1e-12); // top-2 ⊂ first 4
        assert!((rho(&v, 2, 2) - 1.0).abs() < 1e-12); // sorted by magnitude already
        assert_eq!(rho(&v, 0, 2), 0.0);
    }

    #[test]
    fn rho_detects_mismatch() {
        // magnitudes concentrated in the *last* dims -> zero overlap with
        // leading PCA dims
        let v = [0.1f32, 0.1, 5.0, 6.0];
        assert_eq!(rho(&v, 2, 2), 0.0);
    }

    #[test]
    fn stats_monotone_in_kp() {
        let mut rng = Rng::new(21);
        let data = Tensor::new(&[60, 16], rng.normal_vec(60 * 16, 1.0)).unwrap();
        let p = Tensor::eye(16);
        let a = overlap_stats(&data, &p, 0.25, 0.25);
        let b = overlap_stats(&data, &p, 0.25, 0.75);
        assert!(b.mean >= a.mean, "larger PCA set must not reduce overlap");
        assert!(a.mean > 0.0 && a.mean <= 1.0);
    }

    #[test]
    fn gaussian_overlap_near_kp_fraction() {
        // For isotropic data, magnitudes are independent of index, so
        // E[ρ(·, K, K′)] ≈ K′/d.
        let mut rng = Rng::new(22);
        let data = Tensor::new(&[400, 32], rng.normal_vec(400 * 32, 1.0)).unwrap();
        let p = Tensor::eye(32);
        let s = overlap_stats(&data, &p, 0.25, 0.5);
        assert!((s.mean - 0.5).abs() < 0.1, "mean {}", s.mean);
    }
}
