//! The paper's algorithm, natively in rust.
//!
//! * [`policy`] — the knobs (`k_ratio`, `S_ratio`, `E_ratio`), the §5 cost
//!   model and break-even point.
//! * [`native`] — dense/sparse score kernels: the *real* O((i+1)·k) gather
//!   implementation the complexity claims are measured on (the HLO path
//!   uses the numerically-identical masked-dense formulation).
//! * [`fused`] — the PR 10 page-fused streaming decode path: packed
//!   scores + online softmax + value reduction in one pass per KV page,
//!   `O(page_slots)` scratch, SIMD (f32x8) score/AV loops with a
//!   bit-identical scalar fallback, and fused int8 dequantization.
//! * [`info_loss`] — §6.2 information-retention loss (Figures 2, 3/4).
//! * [`overlap`] — §7 / Fig. 5 magnitude-vs-PCA overlap analysis.

pub mod fused;
pub mod info_loss;
pub mod native;
pub mod overlap;
pub mod policy;
